//! Dynamic interval management (paper §3): region moves without
//! re-matching from scratch.
//!
//! Builds the two-tree dynamic DDM state, then streams region moves
//! and compares the incremental cost against full SBM re-matching —
//! the trade-off the paper highlights in its conclusions.
//!
//!     cargo run --release --example dynamic_regions -- --n 2e4 --moves 2000

use ddm::algos::dynamic::{DynamicDdm, Side};
use ddm::algos::sbm;
use ddm::cli::Args;
use ddm::core::interval::Interval;
use ddm::core::sink::CountSink;
use ddm::prng::Rng;
use ddm::sets::SetImpl;
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let args = Args::from_env();
    let n_total = args.size("n", 20_000);
    let n_moves = args.size("moves", 2_000);
    let params = AlphaParams {
        n_total,
        alpha: args.opt("alpha", 1.0),
        space: 1e6,
    };
    let (subs, upds) = alpha_workload(args.opt("seed", 11u64), &params);
    let l = params.region_len();

    println!("dynamic_regions: N={} α={} moves={}", n_total, params.alpha, n_moves);
    let t0 = std::time::Instant::now();
    let mut ddm_state = DynamicDdm::new(subs.clone(), upds.clone());
    println!(
        "built two interval trees in {}",
        ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
    );

    // Stream random moves through the incremental path.
    let mut rng = Rng::new(99);
    let t1 = std::time::Instant::now();
    let (mut added, mut removed) = (0usize, 0usize);
    for _ in 0..n_moves {
        let side = if rng.chance(0.5) { Side::Subscription } else { Side::Update };
        let count = match side {
            Side::Subscription => ddm_state.n_subs(),
            Side::Update => ddm_state.n_upds(),
        };
        let idx = rng.below(count as u64) as u32;
        let lo = rng.uniform(0.0, params.space - l);
        let diff = ddm_state.move_region(side, idx, Interval::new(lo, lo + l));
        added += diff.added.len();
        removed += diff.removed.len();
    }
    let t_inc = t1.elapsed();
    println!(
        "incremental: {n_moves} moves in {} ({:.1} µs/move; +{added} / -{removed} overlaps)",
        ddm::bench::stats::fmt_secs(t_inc.as_secs_f64()),
        t_inc.as_secs_f64() * 1e6 / n_moves as f64
    );

    // Compare: full SBM re-match after every move would cost ~moves × T(SBM).
    let t2 = std::time::Instant::now();
    let mut sink = CountSink::default();
    sbm::match_seq_with::<CountSink>(SetImpl::Bit, &subs, &upds);
    let _ = &mut sink;
    let t_full = t2.elapsed();
    println!(
        "one full SBM match: {} -> {n_moves} re-matches would cost ~{}",
        ddm::bench::stats::fmt_secs(t_full.as_secs_f64()),
        ddm::bench::stats::fmt_secs(t_full.as_secs_f64() * n_moves as f64)
    );
    let speedup = t_full.as_secs_f64() * n_moves as f64 / t_inc.as_secs_f64();
    println!("incremental advantage on this stream: {speedup:.0}x");
}

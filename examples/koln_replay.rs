//! Köln-trace replay (paper Fig. 14 workload as an application).
//!
//! Generates the Köln-like vehicular trace (or loads one from CSV),
//! runs the three algorithms the paper compares on it (GBM, ITM,
//! Parallel SBM), and reports wall-clock + K — a small version of the
//! paper's realistic-workload experiment usable as a library demo.
//!
//! Then replays the same trace **dynamically**: vehicles drift every
//! epoch, the churn is staged into a `DdmSession`, and each commit
//! reports only the `MatchDiff` — compare its per-epoch cost against
//! the full re-match printed above.
//!
//!     cargo run --release --example koln_replay -- --scale 0.05 --threads 4
//!     cargo run --release --example koln_replay -- --csv /tmp/trace.csv
//!     cargo run --release --example koln_replay -- --epochs 8 --churn 0.05

use ddm::algos::Algo;
use ddm::cli::Args;
use ddm::core::interval::Interval;
use ddm::engine::DdmEngine;
use ddm::exec::ThreadPool;
use ddm::prng::Rng;
use ddm::workload::koln::{koln_workload, load_positions_csv, save_positions_csv, KolnParams};

fn main() {
    let args = Args::from_env();
    let threads = args.opt("threads", 4usize);
    let params = KolnParams::default().scaled(args.opt("scale", 0.05f64));

    let (subs, upds) = match args.get("csv") {
        Some(path) => {
            let p = std::path::Path::new(path);
            println!("loading trace from {}", p.display());
            load_positions_csv(p, params.width).expect("trace CSV loads")
        }
        None => {
            let w = koln_workload(args.opt("seed", 62u64), &params);
            if let Some(out) = args.get("save-csv") {
                save_positions_csv(std::path::Path::new(out), &w.0).expect("CSV saved");
                println!("saved positions to {out}");
            }
            w
        }
    };
    println!(
        "koln-like trace: {} positions -> {} sub + {} upd regions of {} m",
        subs.len(),
        subs.len(),
        upds.len(),
        params.width
    );

    let pool = std::sync::Arc::new(ThreadPool::new(threads.saturating_sub(1)));
    // The paper's Fig. 14 algorithm set, each behind the same engine API.
    for algo in [Algo::Gbm, Algo::Itm, Algo::Psbm] {
        let engine = DdmEngine::builder()
            .algo(algo)
            .threads(threads)
            .ncells(args.opt("ncells", 3000usize))
            .pool(std::sync::Arc::clone(&pool))
            .build();
        let t0 = std::time::Instant::now();
        let k = engine.count_1d(&subs, &upds);
        println!(
            "  {:6} K={k:<14} {}",
            engine.algo_name(),
            ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
        );
    }

    // ---- session-driven replay: epochs of vehicular drift -----------------
    let epochs = args.opt("epochs", 5usize);
    let churn = args.opt("churn", 0.02f64);
    if epochs == 0 {
        return;
    }
    let engine = DdmEngine::builder()
        .threads(threads)
        .pool(std::sync::Arc::clone(&pool))
        .build();
    let hull = |r: &ddm::core::Regions1D| r.bounds().map(|b| b.hi).unwrap_or(0.0);
    let road_end = hull(&subs).max(hull(&upds));
    let (mut subs, mut upds) = (subs, upds);
    let mut sess = engine.session(1);
    let t0 = std::time::Instant::now();
    sess.load_dense_1d(&subs, &upds);
    let init = sess.commit();
    println!(
        "\nsession replay ({epochs} epochs, {:.0}% of vehicles drift per epoch):\n\
         epoch 0: {} initial pairs in {}",
        churn * 100.0,
        init.added.len(),
        ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
    );
    let n_moves = (((subs.len() + upds.len()) as f64) * churn).ceil().max(1.0) as usize;
    let mut rng = Rng::new(0x5E55);
    for e in 1..=epochs {
        let t1 = std::time::Instant::now();
        for _ in 0..n_moves {
            let on_subs = rng.chance(0.5);
            let regions = if on_subs { &mut subs } else { &mut upds };
            let idx = rng.below(regions.len() as u64) as usize;
            let iv = regions.get(idx);
            // Drift the vehicle along the road, clamped to the trace span.
            let drift = rng.uniform(-50.0, 50.0);
            let lo = (iv.lo + drift).clamp(0.0, (road_end - iv.len()).max(0.0));
            let moved = Interval::new(lo, lo + iv.len());
            regions.set(idx, moved);
            if on_subs {
                sess.upsert_subscription(idx as u32, &[moved]);
            } else {
                sess.upsert_update(idx as u32, &[moved]);
            }
        }
        let d = sess.commit();
        println!(
            "epoch {e}: +{} -{} pairs in {} ({} vehicles drifted)",
            d.added.len(),
            d.removed.len(),
            ddm::bench::stats::fmt_secs(t1.elapsed().as_secs_f64()),
            n_moves
        );
    }
    println!(
        "{} pairs live after {} epochs — every commit cost O(touched), \
         not O(full re-match)",
        sess.n_pairs(),
        epochs
    );
}

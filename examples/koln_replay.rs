//! Köln-trace replay (paper Fig. 14 workload as an application).
//!
//! Generates the Köln-like vehicular trace (or loads one from CSV),
//! runs the three algorithms the paper compares on it (GBM, ITM,
//! Parallel SBM), and reports wall-clock + K — a small version of the
//! paper's realistic-workload experiment usable as a library demo.
//!
//!     cargo run --release --example koln_replay -- --scale 0.05 --threads 4
//!     cargo run --release --example koln_replay -- --csv /tmp/trace.csv

use ddm::algos::Algo;
use ddm::cli::Args;
use ddm::engine::DdmEngine;
use ddm::exec::ThreadPool;
use ddm::workload::koln::{koln_workload, load_positions_csv, save_positions_csv, KolnParams};

fn main() {
    let args = Args::from_env();
    let threads = args.opt("threads", 4usize);
    let params = KolnParams::default().scaled(args.opt("scale", 0.05f64));

    let (subs, upds) = match args.get("csv") {
        Some(path) => {
            let p = std::path::Path::new(path);
            println!("loading trace from {}", p.display());
            load_positions_csv(p, params.width).expect("trace CSV loads")
        }
        None => {
            let w = koln_workload(args.opt("seed", 62u64), &params);
            if let Some(out) = args.get("save-csv") {
                save_positions_csv(std::path::Path::new(out), &w.0).expect("CSV saved");
                println!("saved positions to {out}");
            }
            w
        }
    };
    println!(
        "koln-like trace: {} positions -> {} sub + {} upd regions of {} m",
        subs.len(),
        subs.len(),
        upds.len(),
        params.width
    );

    let pool = std::sync::Arc::new(ThreadPool::new(threads.saturating_sub(1)));
    // The paper's Fig. 14 algorithm set, each behind the same engine API.
    for algo in [Algo::Gbm, Algo::Itm, Algo::Psbm] {
        let engine = DdmEngine::builder()
            .algo(algo)
            .threads(threads)
            .ncells(args.opt("ncells", 3000usize))
            .pool(std::sync::Arc::clone(&pool))
            .build();
        let t0 = std::time::Instant::now();
        let k = engine.count_1d(&subs, &upds);
        println!(
            "  {:6} K={k:<14} {}",
            engine.algo_name(),
            ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
        );
    }
}

//! Perf-pass diagnostic: per-region cost breakdown of Parallel SBM.
use ddm::algos::psbm;
use ddm::core::sink::CountSink;
use ddm::exec::ThreadPool;
use ddm::sets::SetImpl;
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let args = ddm::cli::Args::from_env();
    let n = args.size("n", 1_000_000);
    let p = args.opt("p", 16usize);
    let (subs, upds) = alpha_workload(1, &AlphaParams { n_total: n, alpha: 100.0, space: 1e6 });
    let pool = ThreadPool::new(31);
    // warmup
    let _: Vec<CountSink> = psbm::match_par_with(SetImpl::Sparse, &pool, p, &subs, &upds);
    pool.start_log();
    let _: Vec<CountSink> = psbm::match_par_with(SetImpl::Sparse, &pool, p, &subs, &upds);
    let log = pool.take_log();
    println!("P={p} regions={} serial={:?}", log.regions.len(), log.serial);
    for (i, r) in log.regions.iter().enumerate() {
        let sum: std::time::Duration = r.iter().sum();
        let max = r.iter().max().unwrap();
        println!("  region {i}: workers={} sum={:?} max={:?}", r.len(), sum, max);
    }
}

//! Quickstart: the region matching problem in 30 lines.
//!
//! Generates the paper's synthetic workload, runs every matching
//! algorithm, and checks they agree — the library's "hello world".
//!
//!     cargo run --release --example quickstart -- --n 1e5 --alpha 10 --threads 4

use ddm::algos::{Algo, MatchParams};
use ddm::cli::Args;
use ddm::exec::ThreadPool;
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let args = Args::from_env();
    let params = AlphaParams {
        n_total: args.size("n", 100_000),
        alpha: args.opt("alpha", 10.0),
        space: 1e6,
    };
    let threads = args.opt("threads", 4usize);
    let (subs, upds) = alpha_workload(args.opt("seed", 1u64), &params);
    println!(
        "workload: N={} α={} -> {} subscriptions, {} updates",
        params.n_total,
        params.alpha,
        subs.len(),
        upds.len()
    );

    let pool = ThreadPool::new(threads.saturating_sub(1));
    let mp = MatchParams::default();
    let mut last_k = None;
    for algo in Algo::ALL {
        let t0 = std::time::Instant::now();
        let k = ddm::algos::run_count(algo, &pool, threads, &subs, &upds, &mp);
        println!(
            "  {:10} K={k:<12} {}",
            algo.name(),
            ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
        );
        if let Some(prev) = last_k {
            assert_eq!(k, prev, "{} disagrees", algo.name());
        }
        last_k = Some(k);
    }
    println!("all {} algorithms agree ✓", Algo::ALL.len());
}

//! Quickstart: the region matching problem in 30 lines.
//!
//! Generates the paper's synthetic workload, builds one `DdmEngine`
//! per algorithm through the `EngineBuilder`, and checks they agree —
//! the library's "hello world" for the unified matcher API.
//!
//!     cargo run --release --example quickstart -- --n 1e5 --alpha 10 --threads 4

use std::sync::Arc;

use ddm::algos::Algo;
use ddm::cli::Args;
use ddm::engine::{DdmEngine, Matcher};
use ddm::exec::ThreadPool;
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let args = Args::from_env();
    let params = AlphaParams {
        n_total: args.size("n", 100_000),
        alpha: args.opt("alpha", 10.0),
        space: 1e6,
    };
    let threads = args.opt("threads", 4usize);
    let (subs, upds) = alpha_workload(args.opt("seed", 1u64), &params);
    println!(
        "workload: N={} α={} -> {} subscriptions, {} updates",
        params.n_total,
        params.alpha,
        subs.len(),
        upds.len()
    );

    // One pool, shared by every engine; swapping the algorithm is a
    // one-line builder change.
    let pool = Arc::new(ThreadPool::new(threads.saturating_sub(1)));
    let mut last_k = None;
    for algo in Algo::ALL {
        let engine = DdmEngine::builder()
            .algo(algo)
            .threads(threads)
            .pool(Arc::clone(&pool))
            .build();
        let t0 = std::time::Instant::now();
        let k = engine.count_1d(&subs, &upds);
        println!(
            "  {:10} K={k:<12} {}",
            engine.algo_name(),
            ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
        );
        if let Some(prev) = last_k {
            assert_eq!(k, prev, "{} disagrees", engine.algo_name());
        }
        last_k = Some(k);
    }

    // The adaptive engine picks a sensible algorithm by itself.
    let auto = DdmEngine::builder()
        .auto()
        .threads(threads)
        .pool(Arc::clone(&pool))
        .build();
    let k = auto.count_1d(&subs, &upds);
    assert_eq!(Some(k), last_k, "auto engine disagrees");
    println!(
        "all {} algorithms + auto agree ✓ (auto chose {})",
        Algo::ALL.len(),
        auto.matcher_for(subs.len(), upds.len()).name()
    );
}

//! **End-to-end driver** (DESIGN.md §4, experiment E2E): the paper's
//! Fig. 1 road-traffic scenario running on the full stack.
//!
//! Four federates — cars, scooters, trucks, traffic lights — register
//! subscription/update regions with the coordinator service. Vehicles
//! move every step (skewed subscription regions toward the direction
//! of motion, as in the paper's figure), lights only publish. Each step
//! the coordinator routes update notifications through the DDM service;
//! the run reports notification throughput and end-to-end latencies —
//! the paper's headline "DDM as a service" metric.
//!
//!     cargo run --release --example traffic_sim -- --steps 200 --vehicles 300

use std::time::Instant;

use ddm::cli::Args;
use ddm::coordinator::{Coordinator, CoordinatorConfig};
use ddm::hla::{RegionKind, RegionSpec, RoutingSpace};
use ddm::prng::Rng;

/// Road length (meters) and entity geometry, loosely scaled to Fig. 1.
const ROAD: u64 = 50_000;
const SUB_AHEAD: u64 = 120; // subscription skewed toward motion
const SUB_BEHIND: u64 = 20;
const UPD_HALF: u64 = 15;
const LIGHT_RANGE: u64 = 60;

struct Vehicle {
    x: u64,
    speed: u64,
    sub: ddm::hla::RegionHandle,
    upd: ddm::hla::RegionHandle,
}

fn vehicle_regions(x: u64) -> (RegionSpec, RegionSpec) {
    let sub = RegionSpec::interval(x.saturating_sub(SUB_BEHIND), (x + SUB_AHEAD).min(ROAD));
    let upd = RegionSpec::interval(x.saturating_sub(UPD_HALF), (x + UPD_HALF).min(ROAD));
    (sub, upd)
}

fn main() {
    let args = Args::from_env();
    let steps = args.opt("steps", 200usize);
    let n_vehicles = args.opt("vehicles", 300usize);
    let n_lights = args.opt("lights", 20usize);
    let threads = args.opt("threads", 4usize);
    let seed = args.opt("seed", 2026u64);

    println!("traffic_sim: {n_vehicles} vehicles, {n_lights} lights, {steps} steps");
    // The coordinator takes a fully-built engine: `--algo itm` (or any
    // other matcher) changes the backend with no other code changes.
    let engine = ddm::engine::DdmEngine::builder()
        .algo_str(args.get("algo").unwrap_or("psbm"))
        .unwrap_or_else(|e| panic!("{e}"))
        .threads(threads)
        .build();
    let coord = Coordinator::spawn(CoordinatorConfig::new(
        RoutingSpace::new(vec![ddm::hla::Dimension::new("road-x", ROAD)]),
        engine,
    ));
    let c = coord.client();

    // Federates as in Fig. 1 (bottom): F1 cars, F2 scooters, F3 trucks,
    // F4 traffic lights.
    let fleets = [c.join("cars"), c.join("scooters"), c.join("trucks")];
    let lights_fed = c.join("traffic-lights");

    let mut rng = Rng::new(seed);
    let mut vehicles: Vec<(usize, Vehicle)> = Vec::new();
    for i in 0..n_vehicles {
        let fleet = i % fleets.len();
        let x = rng.below(ROAD - SUB_AHEAD);
        let (sub_spec, upd_spec) = vehicle_regions(x);
        let sub = c
            .register(fleets[fleet], RegionKind::Subscription, sub_spec)
            .unwrap();
        let upd = c
            .register(fleets[fleet], RegionKind::Update, upd_spec)
            .unwrap();
        vehicles.push((
            fleet,
            Vehicle {
                x,
                speed: 5 + rng.below(20),
                sub,
                upd,
            },
        ));
    }
    // Traffic lights: pure publishers (update regions only).
    let lights: Vec<ddm::hla::RegionHandle> = (0..n_lights)
        .map(|i| {
            let x = (i as u64 + 1) * ROAD / (n_lights as u64 + 1);
            c.register(
                lights_fed,
                RegionKind::Update,
                RegionSpec::interval(x.saturating_sub(LIGHT_RANGE), x + LIGHT_RANGE),
            )
            .unwrap()
        })
        .collect();

    // Sanity: full match on the initial configuration.
    let k0 = c.match_all();
    println!("initial full match: {k0} overlapping (sub, upd) pairs");

    let t0 = Instant::now();
    let mut notifications = 0usize;
    let mut received = 0usize;
    for step in 0..steps {
        // Vehicles advance and publish their new position.
        for (_, v) in vehicles.iter_mut() {
            v.x = (v.x + v.speed) % (ROAD - SUB_AHEAD);
            let (sub_spec, upd_spec) = vehicle_regions(v.x);
            c.modify(v.sub, sub_spec).unwrap();
            c.modify(v.upd, upd_spec).unwrap();
            notifications += c.publish(v.upd, step as u64).unwrap();
        }
        // Lights change phase every 10 steps.
        if step % 10 == 0 {
            for &l in &lights {
                notifications += c.publish(l, step as u64).unwrap();
            }
        }
        // Fleets consume their mailboxes.
        for &f in fleets.iter() {
            received += c.poll(f).len();
        }
    }
    let dt = t0.elapsed();
    let published = steps * n_vehicles + (steps / 10 + usize::from(steps % 10 != 0)) * n_lights;

    println!("\n== results ==");
    println!("steps                : {steps}");
    println!("publishes            : {published}");
    println!("notifications routed : {notifications}");
    println!("notifications polled : {received}");
    println!(
        "wall-clock           : {} ({:.0} publishes/s, {:.0} notifications/s)",
        ddm::bench::stats::fmt_secs(dt.as_secs_f64()),
        published as f64 / dt.as_secs_f64(),
        notifications as f64 / dt.as_secs_f64()
    );
    assert_eq!(notifications, received, "all routed notifications polled");

    let metrics = coord.shutdown();
    println!("\ncoordinator metrics:");
    metrics.table().print();
}

//! The XLA/Pallas accelerator path: match with the AOT-compiled
//! JAX+Pallas kernels from Rust, and cross-check against native BFM.
//!
//! Requires `make artifacts` (Python runs once, at build time only).
//!
//!     cargo run --release --example xla_backend -- --n 4096 --alpha 10

use ddm::algos::bfm;
use ddm::cli::Args;
use ddm::core::sink::CountSink;
use ddm::runtime::XlaMatchBackend;
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let dir = std::path::Path::new(ddm::runtime::DEFAULT_ARTIFACT_DIR);
    if !ddm::runtime::artifacts_available(dir) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let args = Args::from_env();
    let params = AlphaParams {
        n_total: args.size("n", 4096),
        alpha: args.opt("alpha", 10.0),
        space: 1e5,
    };
    let (subs, upds) = alpha_workload(args.opt("seed", 3u64), &params);
    // The XLA kernels compute in f32; quantize so both backends see
    // bit-identical coordinates (see runtime::backend::quantize_f32).
    let subs = ddm::runtime::backend::quantize_f32(&subs);
    let upds = ddm::runtime::backend::quantize_f32(&upds);

    let t0 = std::time::Instant::now();
    let be = XlaMatchBackend::load(dir).expect("backend loads");
    println!(
        "backend: compiled {} artifacts in {}",
        5,
        ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
    );
    if let Some((n, m)) = be.counts_capacity(1) {
        println!("counts kernel capacity: {n} x {m} (d=1); larger inputs are tiled");
    }

    let t1 = std::time::Instant::now();
    let k_xla = be.match_counts_1d(&subs, &upds).expect("xla match");
    let t_xla = t1.elapsed();

    let t2 = std::time::Instant::now();
    let mut sink = CountSink::default();
    bfm::match_seq(&subs, &upds, &mut sink);
    let t_bfm = t2.elapsed();

    println!(
        "XLA tiled kernel : K={k_xla:<12} {}",
        ddm::bench::stats::fmt_secs(t_xla.as_secs_f64())
    );
    println!(
        "native serial BFM: K={:<12} {}",
        sink.count,
        ddm::bench::stats::fmt_secs(t_bfm.as_secs_f64())
    );
    assert_eq!(k_xla, sink.count, "backends must agree");
    println!("backends agree ✓");

    // Bonus: the compiled Fig.-7 prefix-sum pipeline.
    let xs: Vec<i32> = (0..1000).map(|i| (i % 7) - 3).collect();
    let ps = be.prefix_sum(&xs).expect("scan runs");
    let mut acc = 0;
    for (i, &x) in xs.iter().enumerate() {
        acc += x;
        assert_eq!(ps[i], acc);
    }
    println!("compiled prefix-sum pipeline verified ✓");
}

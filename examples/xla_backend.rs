//! The XLA/Pallas accelerator path: match with the AOT-compiled
//! JAX+Pallas kernels from Rust, and cross-check against native BFM.
//!
//! This is also the crate's demonstration of an **out-of-tree
//! matcher**: `XlaMatcher` wraps the backend in the engine's `Matcher`
//! trait, so the accelerator plugs into `EngineBuilder::matcher(..)`
//! exactly like the six native algorithms — no `Algo` enum change.
//!
//! Requires a build with `--features xla` plus `make artifacts`
//! (Python runs once, at build time only).
//!
//!     cargo run --release --features xla --example xla_backend -- --n 4096 --alpha 10

use std::sync::Arc;

use ddm::cli::Args;
use ddm::core::sink::MatchSink;
use ddm::core::Regions1D;
use ddm::engine::{DdmEngine, ExecCtx, Matcher};
use ddm::runtime::XlaMatchBackend;
use ddm::workload::{alpha_workload, AlphaParams};

/// Out-of-tree backend behind the unified `Matcher` trait.
struct XlaMatcher {
    be: XlaMatchBackend,
}

impl Matcher for XlaMatcher {
    fn name(&self) -> &str {
        "xla"
    }

    fn match_1d(
        &self,
        _ctx: &ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    ) {
        for (s, u) in self.be.match_pairs_1d(subs, upds).expect("xla pairs") {
            sink.report(s, u);
        }
    }

    fn count_1d(&self, _ctx: &ExecCtx<'_>, subs: &Regions1D, upds: &Regions1D) -> u64 {
        self.be.match_counts_1d(subs, upds).expect("xla counts")
    }
}

fn main() {
    let dir = std::path::Path::new(ddm::runtime::DEFAULT_ARTIFACT_DIR);
    if !ddm::runtime::artifacts_available(dir) {
        eprintln!(
            "artifacts missing — build with `--features xla` and run `make artifacts` first"
        );
        std::process::exit(1);
    }
    let args = Args::from_env();
    let params = AlphaParams {
        n_total: args.size("n", 4096),
        alpha: args.opt("alpha", 10.0),
        space: 1e5,
    };
    let (subs, upds) = alpha_workload(args.opt("seed", 3u64), &params);
    // The XLA kernels compute in f32; quantize so both backends see
    // bit-identical coordinates (see runtime::quantize_f32).
    let subs = ddm::runtime::quantize_f32(&subs);
    let upds = ddm::runtime::quantize_f32(&upds);

    let t0 = std::time::Instant::now();
    let be = XlaMatchBackend::load(dir).expect("backend loads");
    println!(
        "backend: compiled artifacts in {}",
        ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
    );
    if let Some((n, m)) = be.counts_capacity(1) {
        println!("counts kernel capacity: {n} x {m} (d=1); larger inputs are tiled");
    }
    let prefix_demo = be.prefix_sum(&(0..1000).map(|i| (i % 7) - 3).collect::<Vec<i32>>());

    // Register the accelerator behind the same engine API as the
    // native algorithms.
    let xla_engine = DdmEngine::builder()
        .matcher(Arc::new(XlaMatcher { be }))
        .threads(1)
        .build();
    let native_engine = DdmEngine::builder()
        .algo(ddm::algos::Algo::Bfm)
        .threads(1)
        .build();

    let t1 = std::time::Instant::now();
    let k_xla = xla_engine.count_1d(&subs, &upds);
    let t_xla = t1.elapsed();

    let t2 = std::time::Instant::now();
    let k_native = native_engine.count_1d(&subs, &upds);
    let t_bfm = t2.elapsed();

    println!(
        "XLA tiled kernel : K={k_xla:<12} {}",
        ddm::bench::stats::fmt_secs(t_xla.as_secs_f64())
    );
    println!(
        "native serial BFM: K={k_native:<12} {}",
        ddm::bench::stats::fmt_secs(t_bfm.as_secs_f64())
    );
    assert_eq!(k_xla, k_native, "backends must agree");
    println!("backends agree behind one Matcher trait ✓");

    // Bonus: the compiled Fig.-7 prefix-sum pipeline.
    let ps = prefix_demo.expect("scan runs");
    let mut acc = 0;
    for (i, x) in (0..1000).map(|i| (i % 7) - 3).enumerate() {
        acc += x;
        assert_eq!(ps[i], acc);
    }
    println!("compiled prefix-sum pipeline verified ✓");
}

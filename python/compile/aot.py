"""AOT compile path: lower the L2 graphs to HLO **text** artifacts.

Run once at build time (``make artifacts``); Python never appears on the
request path. The Rust runtime loads every artifact listed in
``artifacts/manifest.txt`` through ``HloModuleProto::from_text_file``.

Interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot [--out DIR]
"""

from __future__ import annotations

import argparse
import hashlib
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import scan

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _lower_match_mask(n, m, d, ts, tu):
    fn = lambda sl, sh, ul, uh: (model.match_mask(sl, sh, ul, uh, ts=ts, tu=tu),)
    args = (_spec((n, d)), _spec((n, d)), _spec((m, d)), _spec((m, d)))
    return jax.jit(fn).lower(*args)


def _lower_match_counts(n, m, d, ts, tu):
    fn = lambda sl, sh, ul, uh: model.match_counts(sl, sh, ul, uh, ts=ts, tu=tu)
    args = (_spec((n, d)), _spec((n, d)), _spec((m, d)), _spec((m, d)))
    return jax.jit(fn).lower(*args)


def _lower_prefix_sum(n, block):
    fn = lambda x: (model.parallel_prefix_sum(x, block=block),)
    return jax.jit(fn).lower(_spec((n,), I32))


# (name, kind, params) — the artifact set the Rust runtime expects.
# Shapes are fixed at AOT time; the Rust backend pads to the next
# compiled shape with the kernels' PAD sentinel.
ARTIFACTS = [
    ("match_mask_1024x1024_d1", "mask", dict(n=1024, m=1024, d=1, ts=256, tu=256)),
    ("match_mask_512x512_d2", "mask", dict(n=512, m=512, d=2, ts=128, tu=128)),
    ("match_counts_2048x2048_d1", "counts", dict(n=2048, m=2048, d=1, ts=256, tu=256)),
    ("match_counts_2048x2048_d2", "counts", dict(n=2048, m=2048, d=2, ts=256, tu=256)),
    ("prefix_sum_65536", "scan", dict(n=65536, block=4096)),
]


def build(outdir: str) -> None:
    os.makedirs(outdir, exist_ok=True)
    manifest_lines = []
    for name, kind, p in ARTIFACTS:
        if kind == "mask":
            lowered = _lower_match_mask(**p)
            meta = f"n={p['n']} m={p['m']} d={p['d']} ts={p['ts']} tu={p['tu']}"
        elif kind == "counts":
            lowered = _lower_match_counts(**p)
            meta = f"n={p['n']} m={p['m']} d={p['d']} ts={p['ts']} tu={p['tu']}"
        elif kind == "scan":
            lowered = _lower_prefix_sum(**p)
            meta = f"n={p['n']} block={p['block']}"
        else:  # pragma: no cover - config error
            raise ValueError(kind)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest_lines.append(f"{name} kind={kind} file={fname} sha256={digest} {meta}")
        print(f"  {fname}  {len(text)} chars  sha256={digest}")
    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(ARTIFACTS)} artifacts + manifest.txt to {outdir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()

"""L1 Pallas kernels: tiled d-dimensional region-overlap matching.

This is the TPU adaptation of the paper's data-parallel matching
discussion (§4 "remarks on GPU implementations" and §6): SBM and ITM are
branch- and pointer-heavy and therefore ill suited to SIMD hardware,
while the dense (brute-force / bit-vector) formulation maps naturally
onto wide vector units. On a GPU the paper would tile the n×m pair space
across threadblocks over shared memory; here the same decomposition is
expressed with a Pallas ``grid`` + ``BlockSpec`` schedule that stages
(TS × d) subscription and (TU × d) update tiles from HBM into VMEM and
evaluates a (TS × TU) intersection tile with vectorized compares (VPU
work — there is no matmul in this problem, so the MXU is intentionally
idle; see DESIGN.md §7 for the roofline accounting).

Interval semantics are half-open ``[lo, hi)`` (paper Algorithm 1):
``x.lo < y.hi and y.lo < x.hi``. Padding convention: rows with
``lo = hi`` (e.g. the ``PAD`` sentinel) never intersect anything, so
callers can pad batches up to the compiled tile multiple.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and the artifacts produced from these
kernels must run inside the Rust coordinator via the CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sentinel for padded (never-matching) regions: lo = hi = PAD.
PAD = 1.0e30

# Default tile sizes. 128 lanes is the TPU vector width; 8×128 is the
# native f32 VPU tile, so TS and TU default to multiples of those.
DEFAULT_TS = 256
DEFAULT_TU = 256


def _intersect_tile(s_lo, s_hi, u_lo, u_hi):
    """(TS×TU) boolean intersection tile from (TS,d) and (TU,d) bounds.

    The d-dimensional reduction of paper §2: rectangles intersect iff
    their projections intersect on every dimension. ``d`` is static at
    trace time, so the loop unrolls into ``d`` fused compare/and stages.
    """
    ts, d = s_lo.shape
    acc = None
    for k in range(d):
        slo = s_lo[:, k][:, None]  # [TS, 1]
        shi = s_hi[:, k][:, None]
        ulo = u_lo[:, k][None, :]  # [1, TU]
        uhi = u_hi[:, k][None, :]
        dim_mask = (slo < uhi) & (ulo < shi)
        acc = dim_mask if acc is None else (acc & dim_mask)
    return acc


def _mask_kernel(s_lo_ref, s_hi_ref, u_lo_ref, u_hi_ref, o_ref):
    """Write one (TS × TU) tile of the intersection mask as uint8."""
    tile = _intersect_tile(
        s_lo_ref[...], s_hi_ref[...], u_lo_ref[...], u_hi_ref[...]
    )
    o_ref[...] = tile.astype(jnp.uint8)


def _count_kernel(s_lo_ref, s_hi_ref, u_lo_ref, u_hi_ref, o_ref):
    """Accumulate per-subscription match counts across update tiles.

    The output block is indexed by the subscription tile only, so it is
    revisited for every update tile ``j``; the first visit initializes,
    later visits accumulate (the standard Pallas reduction idiom).
    """
    j = pl.program_id(1)
    tile = _intersect_tile(
        s_lo_ref[...], s_hi_ref[...], u_lo_ref[...], u_hi_ref[...]
    )
    partial = tile.astype(jnp.int32).sum(axis=1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        o_ref[...] = o_ref[...] + partial


def _check_args(s_lo, s_hi, u_lo, u_hi, ts, tu):
    n, d = s_lo.shape
    m, d2 = u_lo.shape
    if s_hi.shape != (n, d) or u_hi.shape != (m, d2) or d != d2:
        raise ValueError(
            f"inconsistent shapes: s {s_lo.shape}/{s_hi.shape}, "
            f"u {u_lo.shape}/{u_hi.shape}"
        )
    if n % ts != 0 or m % tu != 0:
        raise ValueError(
            f"n={n} and m={m} must be multiples of the tile sizes "
            f"ts={ts}, tu={tu}; pad with PAD rows"
        )
    return n, m, d


@functools.partial(jax.jit, static_argnames=("ts", "tu"))
def overlap_mask(s_lo, s_hi, u_lo, u_hi, *, ts=DEFAULT_TS, tu=DEFAULT_TU):
    """Dense intersection mask ``[n, m]`` (uint8) via the tiled kernel.

    Args:
      s_lo, s_hi: ``[n, d]`` f32 subscription bounds (n multiple of ts).
      u_lo, u_hi: ``[m, d]`` f32 update bounds (m multiple of tu).
    """
    n, m, d = _check_args(s_lo, s_hi, u_lo, u_hi, ts, tu)
    grid = (n // ts, m // tu)
    return pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ts, d), lambda i, j: (i, 0)),
            pl.BlockSpec((ts, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tu, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tu, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ts, tu), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.uint8),
        interpret=True,
    )(s_lo, s_hi, u_lo, u_hi)


@functools.partial(jax.jit, static_argnames=("ts", "tu"))
def overlap_counts(s_lo, s_hi, u_lo, u_hi, *, ts=DEFAULT_TS, tu=DEFAULT_TU):
    """Per-subscription match counts ``[n]`` (int32) via the tiled kernel."""
    n, m, d = _check_args(s_lo, s_hi, u_lo, u_hi, ts, tu)
    grid = (n // ts, m // tu)
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ts, d), lambda i, j: (i, 0)),
            pl.BlockSpec((ts, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tu, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tu, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ts,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(s_lo, s_hi, u_lo, u_hi)


def pad_regions(lo, hi, multiple):
    """Pad ``[k, d]`` bounds with PAD sentinel rows up to ``multiple``."""
    k, d = lo.shape
    rem = (-k) % multiple
    if rem == 0:
        return lo, hi
    pad = jnp.full((rem, d), PAD, lo.dtype)
    return jnp.concatenate([lo, pad]), jnp.concatenate([hi, pad])

"""Pure-jnp correctness oracles for the Pallas kernels (L1).

These are the *reference semantics* every kernel is tested against.
Intervals are half-open ``[lo, hi)`` exactly as paper Algorithm 1
(Intersect-1D): two intervals x, y intersect iff

    x.lo < y.hi  and  y.lo < x.hi

d-dimensional rectangles intersect iff their projections intersect on
every dimension (paper §2).
"""

from __future__ import annotations

import jax.numpy as jnp


def intersect_mask(s_lo, s_hi, u_lo, u_hi):
    """Dense d-dimensional intersection mask.

    Args:
      s_lo, s_hi: ``[n, d]`` subscription lower/upper bounds.
      u_lo, u_hi: ``[m, d]`` update lower/upper bounds.

    Returns:
      ``[n, m]`` bool — ``mask[i, j]`` iff subscription ``i`` and update
      ``j`` intersect on every dimension.
    """
    s_lo = jnp.asarray(s_lo)[:, None, :]  # [n, 1, d]
    s_hi = jnp.asarray(s_hi)[:, None, :]
    u_lo = jnp.asarray(u_lo)[None, :, :]  # [1, m, d]
    u_hi = jnp.asarray(u_hi)[None, :, :]
    per_dim = (s_lo < u_hi) & (u_lo < s_hi)  # [n, m, d]
    return jnp.all(per_dim, axis=-1)


def intersect_counts(s_lo, s_hi, u_lo, u_hi):
    """Per-subscription intersection counts ``[n]`` (int32)."""
    return intersect_mask(s_lo, s_hi, u_lo, u_hi).sum(axis=1, dtype=jnp.int32)


def intersect_total(s_lo, s_hi, u_lo, u_hi):
    """Total number of intersecting (subscription, update) pairs."""
    return intersect_mask(s_lo, s_hi, u_lo, u_hi).sum(dtype=jnp.int32)


def prefix_sum(x):
    """Inclusive prefix sum along axis 0 (oracle for the scan kernel)."""
    return jnp.cumsum(jnp.asarray(x), axis=0, dtype=jnp.int32)


def active_counts(markers):
    """SBM sweep oracle: given endpoint markers sorted by position
    (``+1`` for a lower endpoint, ``-1`` for an upper endpoint), return
    the number of active regions *after* processing each endpoint.

    This is the data-parallel reformulation of the paper's SubSet/UpdSet
    cardinality tracking (§4): an inclusive prefix sum of the markers.
    """
    return prefix_sum(markers)

"""L1 Pallas kernels: blocked parallel prefix sum (paper Fig. 7).

The parallel SBM initialization (paper §4, Algorithm 7) is a prefix
computation: each processor scans its segment locally, a master combines
the per-segment summaries, and each processor applies its incoming
offset. These kernels express exactly that three-step schedule on the
TPU grid:

  step 1  ``block_scan``    — per-block inclusive scan + block totals
  step 2  (L2, tiny)        — exclusive scan of the block totals
  step 3  ``block_add``     — add each block's incoming offset

The L2 composition lives in ``compile.model.parallel_prefix_sum``. The
cardinality form of SBM's SubSet/UpdSet tracking (`active_counts`) is a
direct client: markers are +1 at a region's lower endpoint and -1 at its
upper endpoint, and the inclusive scan yields the number of active
regions after each endpoint of the sorted sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8×128 int32 VPU tile => 1024 elements is the natural minimum block.
DEFAULT_BLOCK = 4096


def _block_scan_kernel(x_ref, scan_ref, tot_ref):
    """Inclusive scan of one block; emit the block total."""
    x = x_ref[...]
    s = jnp.cumsum(x, dtype=jnp.int32)
    scan_ref[...] = s
    tot_ref[...] = s[-1:]


def _block_add_kernel(scan_ref, off_ref, o_ref):
    """Add the per-block exclusive offset to a scanned block."""
    o_ref[...] = scan_ref[...] + off_ref[0]


@functools.partial(jax.jit, static_argnames=("block",))
def block_scan(x, *, block=DEFAULT_BLOCK):
    """Step 1: per-block inclusive scans and block totals.

    Args:
      x: ``[n]`` int32, n a multiple of ``block``.

    Returns:
      ``(scans [n] int32, totals [n // block] int32)``.
    """
    (n,) = x.shape
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    nblocks = n // block
    return pl.pallas_call(
        _block_scan_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((nblocks,), jnp.int32),
        ],
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("block",))
def block_add(scans, offsets, *, block=DEFAULT_BLOCK):
    """Step 3: apply per-block exclusive offsets to the local scans."""
    (n,) = scans.shape
    nblocks = n // block
    if offsets.shape != (nblocks,):
        raise ValueError(f"offsets {offsets.shape} != ({nblocks},)")
    return pl.pallas_call(
        _block_add_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(scans, offsets)

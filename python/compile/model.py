"""L2: the JAX compute graph for DDM matching, built on the L1 kernels.

Three exported computations (all AOT-lowered by ``compile.aot`` to HLO
text and executed from the Rust coordinator through PJRT):

* ``match_mask``   — dense [n, m] uint8 intersection mask (tiled Pallas
  kernel). The Rust backend enumerates (i, j) pairs from the mask; this
  is the data-parallel BFM of paper Algorithm 2.
* ``match_counts`` — per-subscription counts [n] plus the scalar total
  K, fused count+reduce (the benches only need K, exactly like the
  paper's experiments, which count intersections without storing them).
* ``parallel_prefix_sum`` / ``sbm_active_counts`` — the paper Fig. 7
  three-step scan composed from the Pallas scan kernels; the "GPU SBM"
  building block discussed in §4's closing remarks.

Everything here is shape-polymorphic at trace time but fixed at AOT
time; the Rust side pads with the kernels' PAD sentinel to the compiled
shape (see ``runtime::xla_backend``).
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import overlap, scan


def match_mask(s_lo, s_hi, u_lo, u_hi, *, ts=None, tu=None):
    """Dense intersection mask [n, m] (uint8)."""
    kw = {}
    if ts is not None:
        kw["ts"] = ts
    if tu is not None:
        kw["tu"] = tu
    return overlap.overlap_mask(s_lo, s_hi, u_lo, u_hi, **kw)


def match_counts(s_lo, s_hi, u_lo, u_hi, *, ts=None, tu=None):
    """Per-subscription counts [n] and total K (the paper's metric)."""
    kw = {}
    if ts is not None:
        kw["ts"] = ts
    if tu is not None:
        kw["tu"] = tu
    counts = overlap.overlap_counts(s_lo, s_hi, u_lo, u_hi, **kw)
    # int32 is safe for every compiled artifact shape (K <= n*m <= 2^22).
    return counts, counts.sum(dtype=jnp.int32)


def parallel_prefix_sum(x, *, block=scan.DEFAULT_BLOCK):
    """Paper Fig. 7: block scans -> master combine -> offset apply.

    The middle step runs on the [nblocks] totals vector — the "executed
    by the master" step of Algorithm 7 — and is negligible by design
    (O(P) in the paper, O(nblocks) here).
    """
    scans, totals = scan.block_scan(x, block=block)
    # Exclusive scan of block totals: offsets[i] = sum(totals[:i]).
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(totals, dtype=jnp.int32)[:-1]]
    )
    return scan.block_add(scans, offsets, block=block)


def sbm_active_counts(markers, *, block=scan.DEFAULT_BLOCK):
    """Number of active regions after each sorted endpoint (§4).

    ``markers`` is +1 for lower endpoints, -1 for upper endpoints, in
    sweep order. The result after the endpoint closing region x equals
    |SubSet| + |UpdSet| as maintained by Algorithm 4.
    """
    return parallel_prefix_sum(markers, block=block)

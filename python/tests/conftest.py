"""Shared fixtures for the L1/L2 test suite."""

import os
import sys

import numpy as np
import pytest

# Make `compile` importable when pytest is launched from anywhere.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture
def rng():
    return np.random.default_rng(0xDD77)


def random_regions(rng, k, d, space=1000.0, max_len=20.0, dtype=np.float32):
    """Random half-open d-rectangles: lo uniform, extent uniform > 0."""
    lo = rng.uniform(0.0, space, (k, d)).astype(dtype)
    hi = lo + rng.uniform(0.0, max_len, (k, d)).astype(dtype)
    return lo, hi

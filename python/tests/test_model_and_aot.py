"""L2 model graphs + AOT pipeline checks (shapes, manifest, HLO text)."""

import os

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from tests.conftest import random_regions

ARTIFACT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "artifacts",
)


def test_match_counts_total(rng):
    n, m, d = 32, 32, 2
    slo, shi = random_regions(rng, n, d)
    ulo, uhi = random_regions(rng, m, d)
    counts, total = model.match_counts(slo, shi, ulo, uhi, ts=8, tu=8)
    want_mask = np.asarray(ref.intersect_mask(slo, shi, ulo, uhi))
    assert int(total) == want_mask.sum()
    np.testing.assert_array_equal(np.asarray(counts), want_mask.sum(axis=1))


def test_match_mask_dtype_uint8(rng):
    slo, shi = random_regions(rng, 8, 1)
    ulo, uhi = random_regions(rng, 8, 1)
    mask = model.match_mask(slo, shi, ulo, uhi, ts=8, tu=8)
    assert np.asarray(mask).dtype == np.uint8
    assert set(np.unique(np.asarray(mask))) <= {0, 1}


def test_hlo_text_is_parsable_hlo():
    """The interchange text must be classic HLO (HloModule header) and
    must not be StableHLO/MHLO (which the Rust-side parser rejects)."""
    lowered = aot._lower_prefix_sum(n=64, block=16)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "stablehlo" not in text
    assert "ENTRY" in text


def test_artifact_registry_is_consistent():
    names = [name for name, _, _ in aot.ARTIFACTS]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for name, kind, p in aot.ARTIFACTS:
        assert kind in ("mask", "counts", "scan")
        if kind in ("mask", "counts"):
            assert p["n"] % p["ts"] == 0 and p["m"] % p["tu"] == 0
            assert str(p["d"]) in name
        else:
            assert p["n"] % p["block"] == 0


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_files_on_disk():
    with open(os.path.join(ARTIFACT_DIR, "manifest.txt")) as f:
        lines = [l.strip() for l in f if l.strip()]
    assert len(lines) == len(aot.ARTIFACTS)
    for line in lines:
        fields = dict(
            kv.split("=", 1) for kv in line.split()[1:] if "=" in kv
        )
        path = os.path.join(ARTIFACT_DIR, fields["file"])
        assert os.path.exists(path), f"missing artifact {path}"
        with open(path) as f:
            text = f.read()
        assert text.startswith("HloModule")
        import hashlib

        assert hashlib.sha256(text.encode()).hexdigest()[:16] == fields["sha256"]

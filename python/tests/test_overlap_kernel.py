"""L1 overlap kernel vs pure-jnp oracle — the core correctness signal.

The Pallas kernels must agree bit-for-bit with ``ref.py`` (boolean
output, so exact equality — no allclose tolerance games) on random,
adversarial, and hypothesis-generated inputs.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import overlap, ref
from tests.conftest import random_regions

# Small tiles so tests exercise multi-tile grids cheaply.
TS = TU = 8


def run_both(slo, shi, ulo, uhi, ts=TS, tu=TU):
    got = np.asarray(overlap.overlap_mask(slo, shi, ulo, uhi, ts=ts, tu=tu))
    want = np.asarray(ref.intersect_mask(slo, shi, ulo, uhi))
    return got.astype(bool), want


@pytest.mark.parametrize("d", [1, 2, 3])
@pytest.mark.parametrize("n,m", [(8, 8), (32, 16), (64, 64)])
def test_mask_matches_ref_random(rng, n, m, d):
    slo, shi = random_regions(rng, n, d)
    ulo, uhi = random_regions(rng, m, d)
    got, want = run_both(slo, shi, ulo, uhi)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("d", [1, 2])
def test_counts_match_mask_rowsums(rng, d):
    n, m = 32, 48
    slo, shi = random_regions(rng, n, d)
    ulo, uhi = random_regions(rng, m, d)
    counts = np.asarray(overlap.overlap_counts(slo, shi, ulo, uhi, ts=TS, tu=TU))
    want = np.asarray(ref.intersect_mask(slo, shi, ulo, uhi)).sum(axis=1)
    np.testing.assert_array_equal(counts, want)


def test_half_open_touching_endpoints_do_not_intersect():
    # [0, 1) and [1, 2) share only the endpoint 1 -> no intersection.
    slo = np.array([[0.0]], np.float32)
    shi = np.array([[1.0]], np.float32)
    ulo = np.array([[1.0]], np.float32)
    uhi = np.array([[2.0]], np.float32)
    got, want = run_both(slo, shi, ulo, uhi, ts=1, tu=1)
    assert not got.any()
    assert not want.any()


def test_identical_intervals_intersect():
    lo = np.full((4, 1), 5.0, np.float32)
    hi = np.full((4, 1), 7.0, np.float32)
    got, want = run_both(lo, hi, lo, hi, ts=4, tu=4)
    assert got.all() and want.all()


def test_nested_intervals_intersect():
    slo = np.array([[0.0]], np.float32)
    shi = np.array([[100.0]], np.float32)
    ulo = np.array([[40.0]], np.float32)
    uhi = np.array([[41.0]], np.float32)
    got, _ = run_both(slo, shi, ulo, uhi, ts=1, tu=1)
    assert got.all()


def test_empty_interval_follows_alg1():
    """Paper Algorithm 1 assumes non-empty intervals: for an empty
    interval [5,5) strictly inside [0,10) the formula
    ``x.lo < y.hi and y.lo < x.hi`` still reports an intersection.
    Kernel and oracle must agree on this (documented) behavior; the PAD
    sentinel relies on PAD exceeding every real coordinate, not on
    emptiness (see test_pad_sentinel_rows_never_match)."""
    slo = np.array([[5.0]], np.float32)
    shi = np.array([[5.0]], np.float32)
    ulo = np.array([[0.0]], np.float32)
    uhi = np.array([[10.0]], np.float32)
    got, want = run_both(slo, shi, ulo, uhi, ts=1, tu=1)
    np.testing.assert_array_equal(got, want)
    assert got.all()  # Alg-1 semantics
    # Outside one another, empty intervals do not intersect.
    got2, want2 = run_both(slo, shi, np.array([[6.0]], np.float32),
                           np.array([[10.0]], np.float32), ts=1, tu=1)
    np.testing.assert_array_equal(got2, want2)
    assert not got2.any()


def test_pad_sentinel_rows_never_match(rng):
    n, m, d = 5, 7, 2
    slo, shi = random_regions(rng, n, d)
    ulo, uhi = random_regions(rng, m, d)
    slo_p, shi_p = overlap.pad_regions(slo, shi, 8)
    ulo_p, uhi_p = overlap.pad_regions(ulo, uhi, 8)
    assert slo_p.shape == (8, d) and ulo_p.shape == (8, d)
    got, _ = run_both(np.asarray(slo_p), np.asarray(shi_p),
                      np.asarray(ulo_p), np.asarray(uhi_p))
    # Padded rows/cols are all-false.
    assert not got[n:, :].any()
    assert not got[:, m:].any()
    # Live corner equals the unpadded reference.
    want = np.asarray(ref.intersect_mask(slo, shi, ulo, uhi))
    np.testing.assert_array_equal(got[:n, :m], want)


def test_d2_requires_overlap_on_both_dims():
    # Overlap on dim 0 only -> no intersection.
    slo = np.array([[0.0, 0.0]], np.float32)
    shi = np.array([[10.0, 1.0]], np.float32)
    ulo = np.array([[5.0, 2.0]], np.float32)
    uhi = np.array([[6.0, 3.0]], np.float32)
    got, _ = run_both(slo, shi, ulo, uhi, ts=1, tu=1)
    assert not got.any()


def test_tile_shape_mismatch_raises(rng):
    slo, shi = random_regions(rng, 10, 1)
    ulo, uhi = random_regions(rng, 8, 1)
    with pytest.raises(ValueError, match="multiple"):
        overlap.overlap_mask(slo, shi, ulo, uhi, ts=8, tu=8)


def test_inconsistent_bounds_shape_raises(rng):
    slo, shi = random_regions(rng, 8, 1)
    ulo, uhi = random_regions(rng, 8, 2)
    with pytest.raises(ValueError, match="inconsistent"):
        overlap.overlap_mask(slo, shi, ulo, uhi, ts=8, tu=8)


@pytest.mark.parametrize("ts,tu", [(4, 8), (8, 4), (16, 16)])
def test_tiling_is_invisible(rng, ts, tu):
    """Result must not depend on the VMEM tiling (pure schedule change)."""
    n, m = 16, 16
    slo, shi = random_regions(rng, n, 1)
    ulo, uhi = random_regions(rng, m, 1)
    base = np.asarray(overlap.overlap_mask(slo, shi, ulo, uhi, ts=16, tu=16))
    tiled = np.asarray(overlap.overlap_mask(slo, shi, ulo, uhi, ts=ts, tu=tu))
    np.testing.assert_array_equal(base, tiled)


finite_coord = st.floats(
    min_value=-1e6, max_value=1e6, allow_nan=False, width=32
)


@settings(max_examples=40, deadline=None)
@given(
    data=st.data(),
    n=st.integers(1, 8),
    m=st.integers(1, 8),
    d=st.integers(1, 3),
)
def test_hypothesis_mask_matches_ref(data, n, m, d):
    """Hypothesis sweep: arbitrary (possibly degenerate) f32 rectangles."""
    def rects(k):
        lo = np.array(
            data.draw(st.lists(st.lists(finite_coord, min_size=d, max_size=d),
                               min_size=k, max_size=k)),
            np.float32,
        ).reshape(k, d)
        ext = np.array(
            data.draw(st.lists(st.lists(
                st.floats(min_value=0, max_value=1e5, allow_nan=False,
                          width=32), min_size=d, max_size=d),
                min_size=k, max_size=k)),
            np.float32,
        ).reshape(k, d)
        return lo, lo + ext

    slo, shi = rects(n)
    ulo, uhi = rects(m)
    got = np.asarray(
        overlap.overlap_mask(slo, shi, ulo, uhi, ts=n, tu=m)
    ).astype(bool)
    want = np.asarray(ref.intersect_mask(slo, shi, ulo, uhi))
    np.testing.assert_array_equal(got, want)

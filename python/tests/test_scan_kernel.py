"""Scan kernels (paper Fig. 7 three-step prefix sum) vs jnp.cumsum."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, scan


@pytest.mark.parametrize("n,block", [(16, 4), (64, 8), (4096, 256)])
def test_block_scan_blocks_and_totals(rng, n, block):
    x = rng.integers(-5, 6, n).astype(np.int32)
    scans, totals = scan.block_scan(x, block=block)
    scans, totals = np.asarray(scans), np.asarray(totals)
    for b in range(n // block):
        seg = x[b * block : (b + 1) * block]
        np.testing.assert_array_equal(scans[b * block : (b + 1) * block],
                                      np.cumsum(seg))
        assert totals[b] == seg.sum()


@pytest.mark.parametrize("n,block", [(16, 4), (4096, 512), (65536, 4096)])
def test_parallel_prefix_sum_matches_cumsum(rng, n, block):
    x = rng.integers(-100, 101, n).astype(np.int32)
    got = np.asarray(model.parallel_prefix_sum(x, block=block))
    np.testing.assert_array_equal(got, np.cumsum(x))


def test_non_multiple_block_raises(rng):
    x = rng.integers(0, 2, 10).astype(np.int32)
    with pytest.raises(ValueError, match="multiple"):
        scan.block_scan(x, block=4)


def test_sbm_active_counts_semantics(rng):
    """Markers from a valid sweep: counts never negative, end at zero."""
    k = 128
    lo = rng.uniform(0, 100, k)
    hi = lo + rng.uniform(0.1, 10, k)
    # endpoints sorted by position, +1 lower / -1 upper
    pts = sorted([(p, +1) for p in lo] + [(p, -1) for p in hi])
    markers = np.array([s for _, s in pts], np.int32)
    active = np.asarray(model.sbm_active_counts(markers, block=32))
    assert (active >= 0).all()
    assert active[-1] == 0
    assert active.max() <= k


def test_active_counts_oracle_agreement(rng):
    markers = rng.integers(-1, 2, 256).astype(np.int32)
    got = np.asarray(model.sbm_active_counts(markers, block=64))
    want = np.asarray(ref.active_counts(markers))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(
    vals=st.lists(st.integers(-1000, 1000), min_size=1, max_size=64),
    block_pow=st.integers(0, 4),
)
def test_hypothesis_prefix_sum(vals, block_pow):
    block = 2 ** block_pow
    n = ((len(vals) + block - 1) // block) * block
    x = np.zeros(n, np.int32)
    x[: len(vals)] = vals
    got = np.asarray(model.parallel_prefix_sum(x, block=block))
    np.testing.assert_array_equal(got, np.cumsum(x))

//! Ablation A4 (paper §3 + conclusions): dynamic interval management —
//! the two-tree incremental scheme vs full re-matching after each
//! region move.
//!
//! The paper motivates ITM by this exact trade-off: interval trees
//! support O(lg n) updates and output-sensitive re-queries, while SBM
//! must re-run from scratch ("a dynamic parallel SBM is ongoing
//! research"). This bench measures the crossover: how many moves per
//! full re-match amortize each approach.
//!
//!   cargo bench --bench abl_dynamic -- [--n 1e5] [--quick]

use ddm::algos::dynamic::{DynamicDdm, Side};
use ddm::algos::{Algo, MatchParams};
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::core::interval::Interval;
use ddm::prng::Rng;
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let ctx = FigCtx::new(4);
    let n_total = ctx.args.size("n", if ctx.quick { 20_000 } else { 100_000 });
    let n_moves = ctx.args.size("moves", if ctx.quick { 500 } else { 5_000 });
    let alpha = ctx.args.opt("alpha", 1.0);
    let wp = AlphaParams {
        n_total,
        alpha,
        space: 1e6,
    };
    banner(
        "A4",
        "dynamic regions: incremental two-tree vs full re-match",
        &format!("N={n_total} α={alpha} moves={n_moves}"),
    );
    let (subs, upds) = alpha_workload(24, &wp);
    let l = wp.region_len();

    // Incremental path.
    let t0 = std::time::Instant::now();
    let mut ddm_state = DynamicDdm::new(subs.clone(), upds.clone());
    let t_build = t0.elapsed().as_secs_f64();
    let mut rng = Rng::new(25);
    let t1 = std::time::Instant::now();
    let mut churn = 0usize;
    for _ in 0..n_moves {
        let side = if rng.chance(0.5) {
            Side::Subscription
        } else {
            Side::Update
        };
        let count = match side {
            Side::Subscription => ddm_state.n_subs(),
            Side::Update => ddm_state.n_upds(),
        };
        let idx = rng.below(count as u64) as u32;
        let lo = rng.uniform(0.0, wp.space - l);
        let diff = ddm_state.move_region(side, idx, Interval::new(lo, lo + l));
        churn += diff.added.len() + diff.removed.len();
    }
    let t_inc = t1.elapsed().as_secs_f64();

    // Full re-match path (parallel SBM per move, measured once).
    let matcher = ctx.matcher(Algo::Psbm, &MatchParams::default());
    let point = ctx.measure_matcher(matcher.as_ref(), 4, &subs, &upds);
    let t_full = point.modeled.mean;

    let mut table = Table::new(vec!["metric", "value"]);
    table.row(vec!["tree build (two trees)".to_string(), fmt_secs(t_build)]);
    table.row(vec![
        "incremental, per move".to_string(),
        fmt_secs(t_inc / n_moves as f64),
    ]);
    table.row(vec![
        "overlap churn (pairs +/-)".to_string(),
        churn.to_string(),
    ]);
    table.row(vec!["full PSBM re-match".to_string(), fmt_secs(t_full)]);
    let crossover = t_full / (t_inc / n_moves as f64);
    table.row(vec![
        "moves per re-match at parity".to_string(),
        format!("{crossover:.0}"),
    ]);
    table.print();
    ctx.emit("abl_dynamic", &table);
    println!(
        "\nreading: below ~{crossover:.0} moves per epoch the incremental tree wins — \
         the paper's argument for ITM in dynamic scenarios."
    );
}

//! Ablation A2 (paper §5): GBM phase-1 cell-list synchronization —
//! the per-worker fan-in merge (which replaced the per-cell mutexes /
//! the paper's `omp critical`) vs the ad-hoc lock-free append list —
//! plus the dedup strategy (paper's `res` set vs the
//! first-shared-cell rule).
//!
//! The paper found the lock-free list "did not perform significantly
//! better" and kept std::list + critical; this bench re-tests that
//! call under Rust's cost model, with the lock-free fan-in standing in
//! for the now-removed mutex strawman.
//!
//!   cargo bench --bench abl_gbm_list -- [--n 2e5] [--quick]

use ddm::algos::gbm::{CellList, Dedup};
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let ctx = FigCtx::new(32);
    let n_total = ctx.args.size("n", if ctx.quick { 40_000 } else { 200_000 });
    let ncells = ctx.args.opt("ncells", 3000usize);
    let wp = AlphaParams {
        n_total,
        alpha: ctx.args.opt("alpha", 100.0),
        space: 1e6,
    };
    banner(
        "A2",
        "GBM cell-list synchronization + dedup strategy",
        &format!("N={n_total} ncells={ncells} α={}", wp.alpha),
    );
    let (subs, upds) = alpha_workload(22, &wp);

    let threads: Vec<usize> = ctx.args.list("threads", &[1, 4, 16, 32]);
    let mut table = Table::new(vec!["P", "cell-list", "dedup", "WCT(model)", "K"]);
    for &p in &threads {
        for cell_list in [CellList::FanIn, CellList::LockFree] {
            for dedup in [Dedup::FirstCell, Dedup::ResSet] {
                // The strategy knobs ride the engine's parameter
                // block, so ablations and production share one path.
                let params = ddm::algos::MatchParams {
                    ncells,
                    cell_list,
                    dedup,
                    ..Default::default()
                };
                let engine = ctx.engine(ddm::algos::Algo::Gbm, p, &params);
                let point = ctx.measure(p, |_pool, _p| engine.count_1d(&subs, &upds));
                table.row(vec![
                    p.to_string(),
                    format!("{cell_list:?}"),
                    format!("{dedup:?}"),
                    fmt_secs(point.modeled.mean),
                    point.value.to_string(),
                ]);
            }
        }
    }
    table.print();
    ctx.emit("abl_gbm_list", &table);
    println!(
        "\npaper check: lock-free vs mutex should be close (the paper kept the \
         mutex); the res-set dedup pays a hash cost the first-cell rule avoids."
    );
}

//! Ablation A7: the N-D pipeline — native sweep-and-verify
//! (`core::ddim::sweep_and_verify` behind the matchers' `match_nd`
//! overrides) vs the paper's per-dimension reduction
//! (`core::ddim::ReductionNd`), across d ∈ {2, 3, 5} and per-dimension
//! selectivity skews.
//!
//! Three workload families per d (the anisotropic ones are where the
//! reduction's O(ΣK_k) combine blows up):
//!
//! * `iso`     — same α on every dimension;
//! * `skew0`   — dimension 0 barely discriminates (α₀ ≫ α_rest): the
//!               reduction must materialize the huge K₀ pair set, the
//!               native path sweeps a selective dimension instead;
//! * `corr`    — correlated placement (centers track dimension 0):
//!               every projection is dense, the joint result is not.
//!
//! Both paths are asserted to produce the identical K. The acceptance
//! row (d=3, skew0) additionally asserts native < reduction on the
//! modeled WCT.
//!
//!   cargo bench --bench abl_nd -- [--n 20k] [--dims 2,3,5] [--quick]

use ddm::algos::Algo;
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::core::ddim;
use ddm::engine::{DdmEngine, NdMode};
use ddm::workload::{nd_alpha_workload, nd_correlated_workload, NdAlphaParams};

const THREADS: usize = 4;
const SPACE: f64 = 1e6;

fn main() {
    let ctx = FigCtx::new(THREADS);
    let n_total = ctx.args.size("n", if ctx.quick { 6_000 } else { 20_000 });
    let default_dims: &[usize] = if ctx.quick { &[3] } else { &[2, 3, 5] };
    let dims: Vec<usize> = ctx.args.list("dims", default_dims);
    let alpha = ctx.args.opt("alpha", 3.0);
    let skew = ctx.args.opt("skew", 500.0);
    banner(
        "A7",
        "N-D matching: native sweep-and-verify vs per-dimension reduction",
        &format!("N={n_total} α={alpha} skewed α₀={skew} P={THREADS}"),
    );

    let engine = |mode: NdMode| -> DdmEngine {
        DdmEngine::builder()
            .algo(Algo::Psbm)
            .threads(THREADS)
            .nd_mode(mode)
            .pool(std::sync::Arc::clone(&ctx.pool))
            .build()
    };
    let native = engine(NdMode::Native);
    let reduce = engine(NdMode::Reduction);

    let mut table = Table::new(vec![
        "d",
        "workload",
        "sweep",
        "K",
        "native(model)",
        "reduce(model)",
        "speedup",
    ]);
    let mut accept_checked = false;
    for &d in &dims {
        let mut alphas = vec![alpha; d];
        alphas[0] = skew;
        let families: Vec<(&str, _)> = vec![
            (
                "iso",
                nd_alpha_workload(101, &NdAlphaParams::iso(d, n_total, alpha, SPACE)),
            ),
            (
                "skew0",
                nd_alpha_workload(102, &NdAlphaParams::skewed(n_total, &alphas, SPACE)),
            ),
            (
                "corr",
                nd_correlated_workload(
                    103,
                    &NdAlphaParams::iso(d, n_total, alpha, SPACE),
                    0.995,
                ),
            ),
        ];
        for (name, (subs, upds)) in families {
            let sweep = ddim::select_sweep_dim(&ctx.pool, THREADS, &subs, &upds);
            let pn = ctx.measure(THREADS, |_pool, _p| native.count_nd(&subs, &upds));
            let pr = ctx.measure(THREADS, |_pool, _p| reduce.count_nd(&subs, &upds));
            assert_eq!(pn.value, pr.value, "native vs reduction K diverged ({name} d={d})");
            let speedup = pr.modeled.mean / pn.modeled.mean.max(1e-12);
            if d == 3 && name == "skew0" {
                // The acceptance row: a low-selectivity dimension 0
                // must not cost the native path anything.
                assert!(
                    speedup > 1.0,
                    "native ({}) must beat reduction ({}) on d=3 skew0",
                    fmt_secs(pn.modeled.mean),
                    fmt_secs(pr.modeled.mean)
                );
                accept_checked = true;
            }
            table.row(vec![
                d.to_string(),
                name.to_string(),
                sweep.to_string(),
                pn.value.to_string(),
                fmt_secs(pn.modeled.mean),
                fmt_secs(pr.modeled.mean),
                format!("{speedup:.1}x"),
            ]);
        }
    }
    table.print();
    ctx.emit("abl_nd", &table);
    if !accept_checked {
        eprintln!("(note: d=3 not in --dims; the skew0 acceptance assert did not run)");
    }
    println!(
        "\nreading: on skew0 the reduction materializes dimension 0's full 1-D pair \
         set (K₀ ≈ N·α₀/2) before any filtering, while the native path sweeps the \
         most selective dimension and verifies the rest inline — identical K is \
         asserted on every row, not assumed."
    );
}

//! Ablation A8: the network-facing DDM service.
//!
//! Table 1 (loopback staging): one worker server on an ephemeral
//! loopback port, driven over 1..k connections with disjoint key
//! ranges. Reports staging throughput and commit→diff round-trip
//! latency. Every row is also an end-to-end equivalence witness:
//! `bench_loopback` asserts the diff stream observed over the wire
//! equal — epoch numbers included — to an in-process session
//! replaying the identical ops.
//!
//! Table 2 (federation): a router plus two workers, each owning a
//! contiguous stripe-range of the same global partition, driven
//! through [`FederationClient`] and compared epoch-by-epoch against a
//! flat in-process [`ShardedSession`](ddm::shard::ShardedSession)
//! over all four stripes. The refcount-merged diff stream and the
//! final pair sets must be byte-equal — the paper's matching result,
//! reproduced across process-style boundaries.
//!
//!   cargo bench --bench abl_net -- [--n 2000] [--epochs 4] [--conns 1,2,4] [--quick]

use std::time::Instant;

use ddm::bench::harness::FigCtx;
use ddm::bench::netbench::{bench_loopback, conn_script};
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::core::Interval;
use ddm::engine::DdmEngine;
use ddm::net::{
    assign_stripes, serve, FederationClient, RegionOp, RouterService, ServerConfig,
    TopologySnapshot, WorkerService,
};
use ddm::shard::{AnySession, SpacePartitioner};

const SEED: u64 = 42;
const D: usize = 2;
const SPACE: f64 = 1e6;

fn apply_flat(sess: &mut AnySession, ops: &[RegionOp]) {
    for op in ops {
        match op {
            RegionOp::UpsertSub { key, rect } => sess.upsert_subscription(*key, rect),
            RegionOp::UpsertUpd { key, rect } => sess.upsert_update(*key, rect),
            RegionOp::RemoveSub { key } => sess.remove_subscription(*key),
            RegionOp::RemoveUpd { key } => sess.remove_update(*key),
        }
    }
}

fn apply_fed(fed: &mut FederationClient, ops: &[RegionOp]) -> ddm::Result<()> {
    for op in ops {
        match op {
            RegionOp::UpsertSub { key, rect } => fed.upsert_subscription(*key, rect)?,
            RegionOp::UpsertUpd { key, rect } => fed.upsert_update(*key, rect)?,
            RegionOp::RemoveSub { key } => fed.remove_subscription(*key)?,
            RegionOp::RemoveUpd { key } => fed.remove_update(*key)?,
        }
    }
    Ok(())
}

fn main() {
    let ctx = FigCtx::new(4);
    let n: usize = ctx.args.opt("n", if ctx.quick { 800 } else { 2000 });
    let epochs: usize = ctx.args.opt("epochs", if ctx.quick { 3 } else { 4 });
    let default_conns: &[usize] = if ctx.quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let conns_sweep: Vec<usize> = ctx.args.list("conns", default_conns);
    banner(
        "A8",
        "network service: loopback staging throughput and router/worker federation",
        &format!("n={n} epochs={epochs} conns={conns_sweep:?}"),
    );

    // ---- Table 1: single worker over loopback --------------------------
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        io_threads: 2,
    };
    let mut t1 = Table::new(vec![
        "conns", "ops", "ops/s", "commit", "p50", "p99", "+pairs", "-pairs", "diff==local",
    ]);
    for &conns in &conns_sweep {
        let engine = DdmEngine::builder().threads(2).build();
        let handle = serve(&cfg, WorkerService::new(AnySession::Single(engine.session(D))))
            .expect("serve worker");
        let addr = handle.addr().to_string();
        let res = bench_loopback(&addr, conns, n, epochs, SEED, D).expect("loopback run");
        let metrics = handle.shutdown();
        assert!(
            metrics.counter("commits") >= epochs as u64,
            "server saw {} commits, expected >= {epochs}",
            metrics.counter("commits")
        );
        assert!(
            metrics.hist("commit_ns").is_some_and(|h| !h.is_empty()),
            "server-side commit_ns histogram missing from final metrics"
        );
        assert!(
            res.commit_p50_s <= res.commit_p99_s,
            "quantile ordering violated: p50 {} > p99 {}",
            res.commit_p50_s,
            res.commit_p99_s
        );
        t1.row(vec![
            conns.to_string(),
            res.ops.to_string(),
            format!("{:.0}", res.ops_per_s),
            fmt_secs(res.commit_latency_s),
            fmt_secs(res.commit_p50_s),
            fmt_secs(res.commit_p99_s),
            res.added.to_string(),
            res.removed.to_string(),
            "yes".into(),
        ]);
    }
    t1.print();
    // Schema guard for the machine-readable mirror: downstream tooling
    // (xtask bench-snapshot, CI) keys on these columns by name.
    let t1_json = t1.to_json(&[("fig", "abl_net")]);
    for col in ["\"conns\"", "\"ops/s\"", "\"commit\"", "\"p50\"", "\"p99\""] {
        assert!(t1_json.contains(col), "BENCH_abl_net.json lost column {col}: {t1_json}");
    }
    assert!(t1_json.contains("\"header\"") && t1_json.contains("\"rows\""), "{t1_json}");
    ctx.emit("abl_net", &t1);

    // ---- Table 2: router + 2 workers vs flat ShardedSession -------------
    let shards = 4;
    let part = SpacePartitioner::uniform(shards, 0, Interval::new(0.0, SPACE));
    let cuts = part.cuts().to_vec();

    let mut entries = assign_stripes(shards, &vec![String::new(); 2]);
    let mut worker_handles = Vec::new();
    for e in &mut entries {
        let local = SpacePartitioner::from_cuts(0, cuts[e.first as usize..e.last as usize].to_vec());
        let engine = DdmEngine::builder().threads(2).build();
        let sess = AnySession::Sharded(engine.sharded_session_with(D, local));
        let h = serve(&cfg, WorkerService::new(sess)).expect("serve federated worker");
        e.addr = h.addr().to_string();
        worker_handles.push(h);
    }
    let topo = TopologySnapshot {
        d: D as u32,
        split_dim: 0,
        cuts: cuts.clone(),
        workers: entries,
    };
    let router = serve(&cfg, RouterService::new(topo)).expect("serve router");
    let mut fed = FederationClient::connect(&router.addr().to_string()).expect("federation client");

    let engine = DdmEngine::builder().threads(2).build();
    let mut flat = AnySession::Sharded(
        engine.sharded_session_with(D, SpacePartitioner::from_cuts(0, cuts.clone())),
    );

    let script = conn_script(SEED ^ 0xFED, 0, 1, n, epochs, D);
    let mut t2 = Table::new(vec![
        "epoch", "ops", "stage", "commit", "+pairs", "-pairs", "diff==flat",
    ]);
    for (e, ops) in script.iter().enumerate() {
        let t0 = Instant::now();
        apply_fed(&mut fed, ops).expect("stage over federation");
        let stage = t0.elapsed().as_secs_f64();
        let t1c = Instant::now();
        let diff = fed.commit().expect("federated commit");
        let commit = t1c.elapsed().as_secs_f64();

        apply_flat(&mut flat, ops);
        let want = flat.commit();
        assert_eq!(
            diff, want,
            "epoch {e}: federated diff diverged from flat ShardedSession"
        );
        t2.row(vec![
            e.to_string(),
            ops.len().to_string(),
            fmt_secs(stage),
            fmt_secs(commit),
            diff.added.len().to_string(),
            diff.removed.len().to_string(),
            "yes".into(),
        ]);
    }
    let fed_pairs = fed.pairs().expect("federated pairs");
    assert_eq!(fed_pairs, flat.pairs(), "final pair sets diverged");
    assert_eq!(fed.n_pairs(), fed_pairs.len(), "client refcount table out of sync");
    fed.shutdown_workers().expect("worker shutdown");
    for h in worker_handles {
        h.join();
    }
    router.shutdown();
    t2.print();
    ctx.emit("abl_net_fed", &t2);
    println!(
        "\nreading: table 1's throughput rows double as correctness witnesses — each \
         run's wire-observed diff stream is asserted byte-equal (epochs included) to \
         an in-process replay. Table 2 federates the same workload across a router \
         and two stripe-owning workers: per-worker refcounted diffs merge at the \
         client into exactly the flat sharded session's diff, so a pair straddling a \
         worker boundary is reported exactly once."
    );
}

//! Ablation RW: reader tail latency under concurrent churn — locking
//! the session around every read vs wait-free [`EpochSnapshot`] reads.
//!
//! The MVCC claim: once commits publish an immutable refcounted
//! snapshot, a pure reader pays an `Arc` bump instead of waiting out a
//! whole stage-and-commit critical section. This bench pins that down:
//! P reader threads hammer point queries while a churn writer commits
//! at increasing rates (smaller batches, more commits per second). The
//! baseline shares one `Mutex<DdmSession>` between readers and writer
//! — the pre-snapshot architecture — so every commit stalls every
//! reader. The snapshot path publishes the post-commit
//! [`EpochSnapshot`] into a cell readers clone in O(1); the writer
//! runs the pipelined commit path fed with the next epoch's
//! already-coalesced batch. Snapshot-vs-live equality is asserted
//! after every epoch in both modes, the two modes must end in the
//! identical pair set, and at full size (N ≥ 1e5, readers ≥ 4) the
//! bench asserts outright that snapshot reads improve reader p99.
//!
//!   cargo bench --bench abl_rw -- [--n 100k] [--epochs 6] [--readers 4] [--quick]

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use ddm::algos::Algo;
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::core::{Interval, PairVec, Regions1D};
use ddm::engine::DdmEngine;
use ddm::obs::Histogram;
use ddm::session::EpochSnapshot;
use ddm::workload::churn::{relocate, MoveScript};
use ddm::workload::{alpha_workload, AlphaParams};

const THREADS: usize = 4;
const SPACE: f64 = 1e6;
const SCRIPT_SEED: u64 = 0xA5B1;

/// One epoch's moves, coalesced LWW per key — the shape
/// `commit_pipelined` prewrites and the locked path stages op-by-op.
type Batch = BTreeMap<u32, Option<Vec<Interval>>>;

fn build_batch(
    script: &mut MoveScript,
    subs: &mut Regions1D,
    upds: &mut Regions1D,
    n_moves: usize,
) -> (Batch, Batch) {
    let (mut bs, mut bu) = (Batch::new(), Batch::new());
    for _ in 0..n_moves {
        let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
        if sub_side {
            let iv = relocate(subs, idx, frac, SPACE);
            bs.insert(idx as u32, Some(vec![iv]));
        } else {
            let iv = relocate(upds, idx, frac, SPACE);
            bu.insert(idx as u32, Some(vec![iv]));
        }
    }
    (bs, bu)
}

/// Merged reader histogram (per-read latency), total reads, wall
/// seconds, commits closed, and the final pair set of one mode run.
struct ModeRun {
    hist: Histogram,
    reads: u64,
    elapsed: f64,
    commits: u64,
    pairs: PairVec,
}

/// Baseline: readers and the churn writer share one mutex — each
/// epoch's stage + commit holds the lock, so reads queue behind it.
fn run_locked(
    engine: &DdmEngine,
    subs0: &Regions1D,
    upds0: &Regions1D,
    epochs: usize,
    n_moves: usize,
    readers: usize,
) -> ModeRun {
    let (mut subs, mut upds) = (subs0.clone(), upds0.clone());
    let mut sess = engine.session(1);
    sess.load_dense_1d(&subs, &upds);
    let _ = sess.commit();
    let probe = subs.len() as u32;
    let sess = Mutex::new(sess);
    let stop = AtomicBool::new(false);
    let mut hist = Histogram::default();
    let mut reads = 0u64;
    let mut commits = 0u64;
    let t_run = Instant::now();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..readers)
            .map(|r| {
                let (sess, stop) = (&sess, &stop);
                scope.spawn(move || {
                    let mut h = Histogram::default();
                    let mut n = 0u64;
                    let mut key = r as u32;
                    while !stop.load(Ordering::Relaxed) {
                        let t0 = Instant::now();
                        {
                            let g = sess.lock().unwrap();
                            std::hint::black_box(g.n_pairs());
                            std::hint::black_box(g.updates_of(key % probe));
                        }
                        h.record_duration(t0.elapsed());
                        n += 1;
                        key = key.wrapping_add(1);
                    }
                    (h, n)
                })
            })
            .collect();
        let mut script = MoveScript::new(SCRIPT_SEED);
        for _ in 0..epochs {
            let (bs, bu) = build_batch(&mut script, &mut subs, &mut upds, n_moves);
            let mut g = sess.lock().unwrap();
            for (key, rect) in &bs {
                g.upsert_subscription(*key, rect.as_deref().unwrap());
            }
            for (key, rect) in &bu {
                g.upsert_update(*key, rect.as_deref().unwrap());
            }
            let _ = g.commit();
            commits += 1;
            // Honesty check: the published snapshot is the live state.
            assert_eq!(g.snapshot().pairs(), g.pairs(), "snapshot != live (locked)");
        }
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            let (hh, n) = h.join().unwrap();
            hist.merge(&hh);
            reads += n;
        }
    });
    let elapsed = t_run.elapsed().as_secs_f64();
    let pairs = sess.into_inner().unwrap().pairs();
    ModeRun {
        hist,
        reads,
        elapsed,
        commits,
        pairs,
    }
}

/// Snapshot path: the writer owns the session outright and publishes
/// each post-commit [`EpochSnapshot`] into a cell; readers clone it
/// (an `Arc` bump) and query without ever touching the session. The
/// writer runs `commit_pipelined`, overlapping the next batch's tree
/// writes with the current epoch's diff + snapshot build.
fn run_snapshot(
    engine: &DdmEngine,
    subs0: &Regions1D,
    upds0: &Regions1D,
    epochs: usize,
    n_moves: usize,
    readers: usize,
) -> ModeRun {
    let (mut subs, mut upds) = (subs0.clone(), upds0.clone());
    let mut sess = engine.session(1);
    sess.load_dense_1d(&subs, &upds);
    let _ = sess.commit();
    let probe = subs.len() as u32;
    let cell = Mutex::new(sess.snapshot());
    let stop = AtomicBool::new(false);
    let mut hist = Histogram::default();
    let mut reads = 0u64;
    let mut commits = 0u64;
    let t_run = Instant::now();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..readers)
            .map(|r| {
                let (cell, stop) = (&cell, &stop);
                scope.spawn(move || {
                    let mut h = Histogram::default();
                    let mut n = 0u64;
                    let mut key = r as u32;
                    while !stop.load(Ordering::Relaxed) {
                        let t0 = Instant::now();
                        let snap: EpochSnapshot = cell.lock().unwrap().clone();
                        std::hint::black_box(snap.n_pairs());
                        std::hint::black_box(snap.updates_of(key % probe));
                        h.record_duration(t0.elapsed());
                        n += 1;
                        key = key.wrapping_add(1);
                    }
                    (h, n)
                })
            })
            .collect();
        let mut script = MoveScript::new(SCRIPT_SEED);
        for _ in 0..epochs {
            // Batch e prewrites during the commit that closes epoch
            // e-1's churn; a trailing plain commit applies the last.
            let (bs, bu) = build_batch(&mut script, &mut subs, &mut upds, n_moves);
            let _ = sess.commit_pipelined(bs, bu);
            commits += 1;
            let snap = sess.snapshot();
            assert_eq!(snap.epoch(), sess.epoch(), "snapshot lags the session");
            assert_eq!(snap.pairs(), sess.pairs(), "snapshot != live (pipelined)");
            *cell.lock().unwrap() = snap;
        }
        let _ = sess.commit();
        commits += 1;
        *cell.lock().unwrap() = sess.snapshot();
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            let (hh, n) = h.join().unwrap();
            hist.merge(&hh);
            reads += n;
        }
    });
    let elapsed = t_run.elapsed().as_secs_f64();
    let pairs = sess.pairs();
    ModeRun {
        hist,
        reads,
        elapsed,
        commits,
        pairs,
    }
}

fn main() {
    let ctx = FigCtx::new(THREADS);
    let n_total = ctx.args.size("n", if ctx.quick { 10_000 } else { 100_000 });
    let epochs = ctx.args.size("epochs", if ctx.quick { 3 } else { 6 });
    let readers = ctx.args.size("readers", 4);
    let alpha = ctx.args.opt("alpha", 10.0);
    // Descending batch sizes: commits get smaller and faster down the
    // table, i.e. the commit *rate* readers endure goes up.
    let default_churns: &[f64] = if ctx.quick {
        &[0.02]
    } else {
        &[0.10, 0.02, 0.005]
    };
    let churns: Vec<f64> = ctx.args.list("churns", default_churns);
    let wp = AlphaParams {
        n_total,
        alpha,
        space: SPACE,
    };
    banner(
        "RW",
        "reader tail latency under churn: locked session vs wait-free snapshots",
        &format!("N={n_total} α={alpha} epochs={epochs} readers={readers} P={THREADS}"),
    );

    let engine = DdmEngine::builder()
        .algo(Algo::Psbm)
        .threads(THREADS)
        .pool(std::sync::Arc::clone(&ctx.pool))
        .build();
    let (subs0, upds0) = alpha_workload(77, &wp);

    let mut table = Table::new(vec![
        "churn",
        "moves/epoch",
        "commits/s",
        "reads/s locked",
        "reads/s snap",
        "locked p50",
        "locked p99",
        "snap p50",
        "snap p99",
        "p99 gain",
    ]);
    for &churn in &churns {
        let n_moves = ((n_total as f64) * churn).ceil().max(1.0) as usize;
        let locked = run_locked(&engine, &subs0, &upds0, epochs, n_moves, readers);
        let snap = run_snapshot(&engine, &subs0, &upds0, epochs, n_moves, readers);

        // Both modes ran the identical move script; they must agree.
        assert_eq!(
            locked.pairs, snap.pairs,
            "locked and snapshot modes diverged at churn {churn}"
        );

        let (p50_l, p99_l) = (locked.hist.p50(), locked.hist.p99());
        let (p50_s, p99_s) = (snap.hist.p50(), snap.hist.p99());
        if n_total >= 100_000 && readers >= 4 {
            // The tentpole's headline: wait-free reads cut tail latency
            // under concurrent churn. Asserted, not eyeballed.
            assert!(
                p99_s < p99_l,
                "snapshot reads did not improve reader p99 at churn {churn}: \
                 snap {p99_s}ns vs locked {p99_l}ns"
            );
        }
        table.row(vec![
            format!("{:.1}%", churn * 100.0),
            n_moves.to_string(),
            format!("{:.1}", snap.commits as f64 / snap.elapsed),
            format!("{:.0}", locked.reads as f64 / locked.elapsed),
            format!("{:.0}", snap.reads as f64 / snap.elapsed),
            fmt_secs(p50_l as f64 * 1e-9),
            fmt_secs(p99_l as f64 * 1e-9),
            fmt_secs(p50_s as f64 * 1e-9),
            fmt_secs(p99_s as f64 * 1e-9),
            format!("{:.1}x", p99_l as f64 / (p99_s.max(1)) as f64),
        ]);
    }
    table.print();
    ctx.emit("abl_rw", &table);
    println!(
        "\nreading: the locked columns are the pre-snapshot architecture — every \
         read waits out any in-flight stage+commit, so reader p99 tracks the epoch \
         length. The snap columns clone the published EpochSnapshot (an Arc bump) \
         and never touch the session, so p99 stays flat as the commit rate climbs. \
         Equality is asserted every epoch: each published snapshot matches a live \
         read, and both modes end in the identical pair set."
    );
}

//! Ablation A5: epoch-based incremental sessions — `MatchDiff` per
//! epoch vs full rebuild-and-rediff per epoch, across churn rates.
//!
//! The `DdmSession` tentpole claim: when a minority of regions moves
//! per epoch, applying the batch to the per-dimension interval trees
//! and recomputing only the touched regions' overlaps beats re-running
//! the static matcher (and re-deriving the diff) from scratch. This
//! bench sweeps the churn rate on a ≥10k-region workload and reports
//! the per-epoch wall-clock of both paths plus their crossover. Both
//! paths run the identical deterministic move script and are asserted
//! to end in the same pair set.
//!
//!   cargo bench --bench abl_session -- [--n 50k] [--epochs 8] [--quick]

use std::time::Instant;

use ddm::algos::Algo;
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::engine::DdmEngine;
use ddm::workload::churn::{diff_pair_counts, relocate, MoveScript};
use ddm::workload::{alpha_workload, AlphaParams};

const THREADS: usize = 4;
const SPACE: f64 = 1e6;

fn main() {
    let ctx = FigCtx::new(THREADS);
    let n_total = ctx.args.size("n", if ctx.quick { 10_000 } else { 50_000 });
    let epochs = ctx.args.size("epochs", if ctx.quick { 3 } else { 8 });
    let alpha = ctx.args.opt("alpha", 10.0);
    let churns: Vec<f64> = ctx.args.list("churns", &[0.01, 0.05, 0.10, 0.25, 0.50]);
    let wp = AlphaParams {
        n_total,
        alpha,
        space: SPACE,
    };
    banner(
        "A5",
        "incremental sessions: MatchDiff per epoch vs rebuild per epoch",
        &format!("N={n_total} α={alpha} epochs={epochs} P={THREADS}"),
    );

    let engine = DdmEngine::builder()
        .algo(Algo::Psbm)
        .threads(THREADS)
        .pool(std::sync::Arc::clone(&ctx.pool))
        .build();
    let (subs0, upds0) = alpha_workload(77, &wp);

    // Cold baseline: identical sessions with scratch reuse disabled
    // (per-epoch allocation), isolating the warm-scratch win.
    let cold_engine = DdmEngine::builder()
        .algo(Algo::Psbm)
        .threads(THREADS)
        .session_scratch_reuse(false)
        .pool(std::sync::Arc::clone(&ctx.pool))
        .build();

    let mut table = Table::new(vec![
        "churn",
        "moves/epoch",
        "session/epoch",
        "cold-scratch/epoch",
        "rebuild/epoch",
        "speedup",
        "pair churn/epoch",
    ]);
    for &churn in &churns {
        let n_moves = ((n_total as f64) * churn).ceil().max(1.0) as usize;

        // --- session path: staged batch + MatchDiff per epoch --------------
        // (warm: the session's scratch buffers are reused across epochs)
        let (mut subs, mut upds) = (subs0.clone(), upds0.clone());
        let mut sess = engine.session(1);
        sess.load_dense_1d(&subs, &upds);
        let init = sess.commit();
        let mut script = MoveScript::new(0xAB5);
        let mut pair_churn = 0usize;
        let t0 = Instant::now();
        for _ in 0..epochs {
            for _ in 0..n_moves {
                let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
                if sub_side {
                    let iv = relocate(&mut subs, idx, frac, SPACE);
                    sess.upsert_subscription(idx as u32, &[iv]);
                } else {
                    let iv = relocate(&mut upds, idx, frac, SPACE);
                    sess.upsert_update(idx as u32, &[iv]);
                }
            }
            pair_churn += sess.commit().churn();
        }
        let t_session = t0.elapsed().as_secs_f64() / epochs as f64;

        // --- cold-scratch session: same script, buffers dropped per epoch --
        let (mut subs_c, mut upds_c) = (subs0.clone(), upds0.clone());
        let mut cold = cold_engine.session(1);
        cold.load_dense_1d(&subs_c, &upds_c);
        let cold_init = cold.commit();
        assert_eq!(cold_init.added.len(), init.added.len(), "cold epoch 0 differs");
        let mut script = MoveScript::new(0xAB5);
        let t_cold = Instant::now();
        for _ in 0..epochs {
            for _ in 0..n_moves {
                let (sub_side, idx, frac) = script.next(subs_c.len(), upds_c.len());
                if sub_side {
                    let iv = relocate(&mut subs_c, idx, frac, SPACE);
                    cold.upsert_subscription(idx as u32, &[iv]);
                } else {
                    let iv = relocate(&mut upds_c, idx, frac, SPACE);
                    cold.upsert_update(idx as u32, &[iv]);
                }
            }
            let _ = cold.commit();
        }
        let t_cold = t_cold.elapsed().as_secs_f64() / epochs as f64;
        assert_eq!(cold.pairs(), sess.pairs(), "cold/warm sessions diverged");

        // --- rebuild path: full re-match + re-diff per epoch ---------------
        let (mut subs, mut upds) = (subs0.clone(), upds0.clone());
        let mut script = MoveScript::new(0xAB5);
        let mut prev = engine.pairs_1d(&subs, &upds);
        assert_eq!(prev.len(), init.added.len(), "paths disagree at epoch 0");
        let t1 = Instant::now();
        for _ in 0..epochs {
            for _ in 0..n_moves {
                let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
                if sub_side {
                    relocate(&mut subs, idx, frac, SPACE);
                } else {
                    relocate(&mut upds, idx, frac, SPACE);
                }
            }
            let cur = engine.pairs_1d(&subs, &upds);
            // The rebuild path must also pay for deriving the delta —
            // that is what the notification layer consumes.
            std::hint::black_box(diff_pair_counts(&prev, &cur));
            prev = cur;
        }
        let t_rebuild = t1.elapsed().as_secs_f64() / epochs as f64;

        // Honesty check: both paths end in the identical pair set.
        assert_eq!(
            sess.pairs(),
            prev,
            "session diverged from rebuild at churn {churn}"
        );

        table.row(vec![
            format!("{:.0}%", churn * 100.0),
            n_moves.to_string(),
            fmt_secs(t_session),
            fmt_secs(t_cold),
            fmt_secs(t_rebuild),
            format!("{:.1}x", t_rebuild / t_session),
            (pair_churn / epochs).to_string(),
        ]);
    }
    table.print();
    ctx.emit("abl_session", &table);
    println!(
        "\nreading: at low churn (≤10% of regions touched per epoch) diff-per-epoch \
         beats rebuild-per-epoch outright; the crossover marks where whole-set \
         re-matching starts to amortize — the session API makes that a knob, not \
         a rewrite. The cold-scratch column re-runs the session with per-epoch \
         allocation (no buffer reuse); the gap to session/epoch is what the \
         MatchScratch pool buys every commit."
    );
}

//! Ablation A1 (paper §5's data-structure study): active-set
//! implementation for SBM/Parallel SBM.
//!
//! The paper compared std::vector<bool>, raw bit vectors, std::set,
//! std::unordered_set and boost::dynamic_bitset, finding std::set
//! fastest in C++. We re-run the study in Rust (bitvec / hash / btree /
//! sortedvec) across the paper's three α regimes — the winner flips
//! with the active-set density, which is the insight behind making the
//! set pluggable.
//!
//!   cargo bench --bench abl_sets -- [--n 2e5] [--quick]

use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::sets::SetImpl;
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let ctx = FigCtx::new(8);
    let n_total = ctx.args.size("n", if ctx.quick { 40_000 } else { 200_000 });
    let p = ctx.args.opt("p", 4usize);
    let alphas: Vec<f64> = ctx.args.list("alphas", &[0.01, 1.0, 100.0]);
    banner(
        "A1",
        "Parallel SBM active-set implementation study",
        &format!("N={n_total} P={p} α ∈ {alphas:?} (paper picked std::set)"),
    );
    let mut table = Table::new(vec!["alpha", "set", "WCT(model)", "K"]);
    for &alpha in &alphas {
        let wp = AlphaParams {
            n_total,
            alpha,
            space: 1e6,
        };
        let (subs, upds) = alpha_workload(21, &wp);
        let mut best: Option<(f64, SetImpl)> = None;
        for set_impl in SetImpl::ALL {
            // Set implementations are an `EngineBuilder` knob.
            let matcher = ddm::engine::algo_matcher(
                ddm::algos::Algo::Psbm,
                &ddm::algos::MatchParams {
                    set_impl,
                    ..Default::default()
                },
            );
            let point = ctx.measure_matcher(matcher.as_ref(), p, &subs, &upds);
            let wct = point.modeled.mean;
            if best.map_or(true, |(b, _)| wct < b) {
                best = Some((wct, set_impl));
            }
            table.row(vec![
                format!("{alpha}"),
                set_impl.name().to_string(),
                fmt_secs(wct),
                point.value.to_string(),
            ]);
        }
        if let Some((_, w)) = best {
            println!("α={alpha}: fastest = {}", w.name());
        }
    }
    table.print();
    ctx.emit("abl_sets", &table);
}

//! Ablation A6: sharded sessions — per-epoch commit cost of a
//! `ShardedSession` (spatial stripes, shard-parallel commits, merged
//! deduplicated diffs) vs the unsharded `DdmSession`, swept over shard
//! counts × churn rates on a **skewed** churn workload
//! (`MoveScript::with_hotspot` drifts most moves into one corner, so
//! shard imbalance is actually exercised and reported).
//!
//! Both paths replay the identical deterministic move script and are
//! asserted to produce identical per-epoch diff sizes and end in the
//! identical pair set. Two cost columns per row:
//!
//! * `commit/ep` — raw wall-clock on this host (oversubscribed when
//!   P > cores);
//! * `modeled/ep` — the work-span modeled wall-clock of the pooled
//!   phases for a P-core machine (DESIGN.md §3; routing/merge work
//!   outside pool regions is not charged, on either path).
//!
//!   cargo bench --bench abl_shard -- [--n 40k] [--epochs 6] \
//!       [--shards 1,2,4,8] [--churns 0.05,0.2] [--hotspot 0.75] [--quick]

use std::time::Instant;

use ddm::algos::Algo;
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::core::Interval;
use ddm::engine::DdmEngine;
use ddm::shard::{AnySession, SpacePartitioner};
use ddm::workload::churn::{relocate, MoveScript};
use ddm::workload::{alpha_workload, AlphaParams};

const THREADS: usize = 4;
const SPACE: f64 = 1e6;
const SCRIPT_SEED: u64 = 0xAB6;

/// One replay's outcome: per-epoch costs, per-epoch diff sizes, the
/// final pair set, total pair churn, and the final shard imbalance.
struct Run {
    meas_per_epoch: f64,
    model_per_epoch: f64,
    diffs: Vec<(usize, usize)>,
    pairs: Vec<(u32, u32)>,
    pair_churn: usize,
    imbalance: Option<f64>,
}

/// One replay: load, epoch-0 commit, then `epochs` staged-move epochs.
fn run(
    ctx: &FigCtx,
    mut sess: AnySession,
    subs0: &ddm::core::Regions1D,
    upds0: &ddm::core::Regions1D,
    epochs: usize,
    n_moves: usize,
    hotspot: f64,
) -> Run {
    let (mut subs, mut upds) = (subs0.clone(), upds0.clone());
    sess.load_dense_1d(&subs, &upds);
    sess.commit();
    let mut script = MoveScript::with_hotspot(SCRIPT_SEED, hotspot);
    let (mut measured, mut modeled) = (0.0f64, 0.0f64);
    let mut diffs = Vec::with_capacity(epochs);
    let mut pair_churn = 0usize;
    for _ in 0..epochs {
        for _ in 0..n_moves {
            let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
            if sub_side {
                let iv = relocate(&mut subs, idx, frac, SPACE);
                sess.upsert_subscription(idx as u32, &[iv]);
            } else {
                let iv = relocate(&mut upds, idx, frac, SPACE);
                sess.upsert_update(idx as u32, &[iv]);
            }
        }
        ctx.pool.start_log();
        let t0 = Instant::now();
        let d = sess.commit();
        measured += t0.elapsed().as_secs_f64();
        modeled += ctx.model.modeled_wct(&ctx.pool.take_log(), THREADS);
        diffs.push((d.added.len(), d.removed.len()));
        pair_churn += d.churn();
    }
    let e = epochs.max(1) as f64;
    Run {
        meas_per_epoch: measured / e,
        model_per_epoch: modeled / e,
        diffs,
        pairs: sess.pairs(),
        pair_churn,
        imbalance: sess.imbalance(),
    }
}

fn main() {
    let ctx = FigCtx::new(THREADS);
    let n_total = ctx.args.size("n", if ctx.quick { 8_000 } else { 40_000 });
    let epochs = ctx.args.size("epochs", if ctx.quick { 2 } else { 6 });
    let alpha = ctx.args.opt("alpha", 10.0);
    let hotspot = ctx.args.opt("hotspot", 0.75);
    let default_shards: &[usize] = if ctx.quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let shard_counts: Vec<usize> = ctx.args.list("shards", default_shards);
    let default_churns: &[f64] = if ctx.quick { &[0.10] } else { &[0.05, 0.20] };
    let churns: Vec<f64> = ctx.args.list("churns", default_churns);
    banner(
        "A6",
        "sharded vs unsharded sessions: per-epoch commit cost under skewed churn",
        &format!("N={n_total} α={alpha} epochs={epochs} hotspot={hotspot} P={THREADS}"),
    );

    let engine = DdmEngine::builder()
        .algo(Algo::Psbm)
        .threads(THREADS)
        .pool(std::sync::Arc::clone(&ctx.pool))
        .build();
    let wp = AlphaParams {
        n_total,
        alpha,
        space: SPACE,
    };
    let (subs0, upds0) = alpha_workload(77, &wp);
    let span = Interval::new(0.0, SPACE);

    let mut table = Table::new(vec![
        "churn",
        "path",
        "shards",
        "commit/ep",
        "modeled/ep",
        "speedup",
        "imbalance",
        "pair churn/ep",
    ]);
    for &churn in &churns {
        let n_moves = ((n_total as f64) * churn).ceil().max(1.0) as usize;

        // Unsharded baseline.
        let base = run(
            &ctx,
            AnySession::Single(engine.session(1)),
            &subs0,
            &upds0,
            epochs,
            n_moves,
            hotspot,
        );
        table.row(vec![
            format!("{:.0}%", churn * 100.0),
            "session".to_string(),
            "-".to_string(),
            fmt_secs(base.meas_per_epoch),
            fmt_secs(base.model_per_epoch),
            "1.0x".to_string(),
            "-".to_string(),
            (base.pair_churn / epochs.max(1)).to_string(),
        ]);

        // Sharded sweep on the identical script.
        for &shards in &shard_counts {
            let sess = AnySession::Sharded(
                engine.sharded_session_with(1, SpacePartitioner::uniform(shards, 0, span)),
            );
            let r = run(&ctx, sess, &subs0, &upds0, epochs, n_moves, hotspot);
            // Honesty checks: identical per-epoch diff sizes and end state.
            assert_eq!(
                r.diffs, base.diffs,
                "sharded({shards}) per-epoch diffs diverged at churn {churn}"
            );
            assert_eq!(
                r.pairs, base.pairs,
                "sharded({shards}) end state diverged at churn {churn}"
            );
            table.row(vec![
                format!("{:.0}%", churn * 100.0),
                "sharded".to_string(),
                shards.to_string(),
                fmt_secs(r.meas_per_epoch),
                fmt_secs(r.model_per_epoch),
                format!("{:.1}x", base.model_per_epoch / r.model_per_epoch.max(1e-12)),
                format!("{:.2}", r.imbalance.unwrap_or(1.0)),
                (r.pair_churn / epochs.max(1)).to_string(),
            ]);
        }
    }
    table.print();
    ctx.emit("abl_shard", &table);
    println!(
        "\nreading: the hotspot drives most churn into one stripe, so uniform stripes \
         report imbalance well above 1.0 while the modeled per-epoch commit cost drops \
         as shards (and with them the parallel fan-out) increase; the measured column \
         is this host's oversubscribed wall-clock. Equal per-epoch diffs and end \
         states vs the unsharded session are asserted, not assumed."
    );
}

//! Ablation A7: the compact-key radix sort and the reusable match
//! scratch — the two halves of the zero-allocation SBM/PSBM hot path.
//!
//! Table 1 (sort phase): sorting the same 2(n+m) endpoint array with
//! the parallel LSD radix sort (compact `u64` key), the merge-path
//! parallel mergesort (`u128` comparison key) and serial `std`
//! `sort_unstable`, across N and thread counts. Every row's output
//! array is asserted bit-identical (checksum over the sorted order),
//! and on the N≥1e6 multi-thread rows radix is asserted strictly
//! faster than merge-path (modeled WCT — the quantity a P-core
//! machine's wall clock tracks).
//!
//! Table 2 (scratch reuse): cold vs warm `count_nd` calls on one
//! engine. The first call fills the engine's `MatchScratch`
//! (endpoints, radix aux + histograms, sinks); warm calls must not
//! grow any of it — asserted via `ScratchStats` equality — and radix
//! and merge engines must agree on K on every row.
//!
//!   cargo bench --bench abl_sort -- [--sizes 100000,1000000] [--quick]

use std::time::Instant;

use ddm::algos::sbm::build_endpoints;
use ddm::algos::Algo;
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::engine::DdmEngine;
use ddm::exec::psort::par_sort_by_key;
use ddm::exec::radix::{par_radix_sort_by_key, RadixScratch};
use ddm::exec::SortAlgo;
use ddm::workload::{alpha_workload, nd_alpha_workload, AlphaParams, NdAlphaParams};

const SPACE: f64 = 1e6;

/// Order-sensitive digest of a sorted endpoint array: all three sort
/// implementations must produce it bit-identically.
fn checksum(endpoints: &[ddm::algos::sbm::Endpoint]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let stride = (endpoints.len() / 4096).max(1);
    let mut i = 0;
    while i < endpoints.len() {
        let e = endpoints[i];
        h = (h ^ e.hi).wrapping_mul(0x100000001b3);
        h = (h ^ e.lo).wrapping_mul(0x100000001b3);
        i += stride;
    }
    h ^ endpoints.len() as u64
}

fn main() {
    let ctx = FigCtx::new(8);
    let sizes: Vec<usize> = ctx.args.list("sizes", &[100_000, 1_000_000]);
    let default_threads: &[usize] = if ctx.quick { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let threads: Vec<usize> = ctx.args.list("threads", default_threads);
    banner(
        "A7",
        "compact-key radix sort vs merge-path, and cold vs warm scratch-reused matching",
        &format!("sizes={sizes:?} threads={threads:?}"),
    );

    // ---- Table 1: the sort phase alone ---------------------------------
    let mut t1 = Table::new(vec![
        "N", "P", "radix", "merge", "std", "merge/radix", "identical",
    ]);
    for &n in &sizes {
        let wp = AlphaParams {
            n_total: n,
            alpha: 100.0,
            space: SPACE,
        };
        let (subs, upds) = alpha_workload(42, &wp);
        let endpoints = build_endpoints(&subs, &upds);
        // Reused across reps: each timed run pays one memcpy (same for
        // every algorithm) plus the sort itself.
        let mut buf = endpoints.clone();
        let mut aux = Vec::new();
        let mut rscratch = RadixScratch::new();
        for &p in &threads {
            // Serial sorts never enter a pool region, so their cost
            // must be charged to the log's serial term or the modeled
            // WCT reads zero (std column, and the P=1 fallbacks).
            let radix = ctx.measure(p, |pool, nthreads| {
                buf.copy_from_slice(&endpoints);
                let sort = || {
                    par_radix_sort_by_key(pool, nthreads, &mut buf, &mut aux, &mut rscratch, |e| {
                        e.radix_key()
                    })
                };
                if nthreads <= 1 {
                    pool.serial_section(sort);
                } else {
                    sort();
                }
                checksum(&buf)
            });
            let merge = ctx.measure(p, |pool, nthreads| {
                buf.copy_from_slice(&endpoints);
                let sort = || par_sort_by_key(pool, nthreads, &mut buf, |e| e.sort_key());
                if nthreads <= 1 {
                    pool.serial_section(sort);
                } else {
                    sort();
                }
                checksum(&buf)
            });
            let std_sort = ctx.measure(p, |pool, _nthreads| {
                buf.copy_from_slice(&endpoints);
                pool.serial_section(|| buf.sort_unstable_by_key(|e| e.sort_key()));
                checksum(&buf)
            });
            assert_eq!(radix.value, merge.value, "radix != merge order (N={n} P={p})");
            assert_eq!(radix.value, std_sort.value, "radix != std order (N={n} P={p})");
            if n >= 1_000_000 && p >= 2 {
                // Min-of-reps of the busy-time-modeled WCT: robust to
                // scheduler noise on shared/oversubscribed CI hosts.
                assert!(
                    radix.modeled.min < merge.modeled.min,
                    "radix ({}) must beat merge-path ({}) at N={n} P={p}",
                    fmt_secs(radix.modeled.min),
                    fmt_secs(merge.modeled.min),
                );
            }
            t1.row(vec![
                n.to_string(),
                p.to_string(),
                fmt_secs(radix.modeled.mean),
                fmt_secs(merge.modeled.mean),
                fmt_secs(std_sort.modeled.mean),
                format!("{:.2}x", merge.modeled.mean / radix.modeled.mean),
                "yes".into(),
            ]);
        }
    }
    t1.print();
    ctx.emit("abl_sort", &t1);

    // ---- Table 2: cold vs warm scratch-reused match_nd ------------------
    let warm_runs = if ctx.quick { 2 } else { 3 };
    // The cold/warm story needs the thread extremes, not the full sweep.
    let t2_threads: Vec<usize> = if threads.len() > 2 {
        vec![threads[0], *threads.last().unwrap()]
    } else {
        threads.clone()
    };
    // "scratch-stable" = ScratchStats unchanged across warm calls. For
    // the radix rows that means truly allocation-free; the merge rows
    // still heap-allocate psort's internal O(n) aux buffer per call
    // (invisible to ScratchStats) — that allocation is part of what
    // the radix path eliminates.
    let mut t2 = Table::new(vec![
        "N", "P", "sort", "cold", "warm", "cold/warm", "scratch-stable", "K",
    ]);
    for &n in &sizes {
        let np = NdAlphaParams::skewed(n, &[100.0, 100.0], SPACE);
        let (subs, upds) = nd_alpha_workload(42, &np);
        for &p in &t2_threads {
            let mut k_by_sort = Vec::new();
            for sort in [SortAlgo::Radix, SortAlgo::Merge] {
                let engine = DdmEngine::builder()
                    .algo(Algo::Psbm)
                    .threads(p)
                    .sort_algo(sort)
                    .pool(std::sync::Arc::clone(&ctx.pool))
                    .build();
                let t0 = Instant::now();
                let k = engine.count_nd(&subs, &upds);
                let cold = t0.elapsed().as_secs_f64();
                // After the first call the scratch is at steady-state
                // capacity; warm calls must not grow it.
                let stats = engine.scratch_stats();
                let mut warm_best = f64::INFINITY;
                let mut alloc_free = true;
                for _ in 0..warm_runs {
                    let t = Instant::now();
                    let kw = engine.count_nd(&subs, &upds);
                    warm_best = warm_best.min(t.elapsed().as_secs_f64());
                    assert_eq!(kw, k, "warm K diverged (N={n} P={p} {sort:?})");
                    alloc_free &= engine.scratch_stats() == stats;
                }
                assert!(
                    alloc_free,
                    "scratch grew on a warm call (N={n} P={p} {sort:?}): {:?} -> {:?}",
                    stats,
                    engine.scratch_stats()
                );
                k_by_sort.push(k);
                t2.row(vec![
                    n.to_string(),
                    p.to_string(),
                    sort.name().into(),
                    fmt_secs(cold),
                    fmt_secs(warm_best),
                    format!("{:.2}x", cold / warm_best),
                    "yes".into(),
                    k.to_string(),
                ]);
            }
            assert_eq!(
                k_by_sort[0], k_by_sort[1],
                "K-identity broken between sorts (N={n} P={p})"
            );
        }
    }
    t2.print();
    ctx.emit("abl_sort_warm", &t2);
    println!(
        "\nreading: the radix path sorts one u64 word in ≤8 stable counting passes \
         where merge-path pays a u128 comparison per element per level — and with \
         the engine's MatchScratch, every warm row above ran without growing a \
         single pooled buffer. Only the radix rows are truly allocation-free: the \
         merge rows still pay psort's internal O(n) aux allocation each call, \
         which ScratchStats cannot see. Table 1 is the sort phase alone; Table 2 \
         is end-to-end count_nd on the PSBM native pipeline."
    );
}

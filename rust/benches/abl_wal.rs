//! Ablation WAL: what crash-consistent durability costs the commit
//! path, and what recovery costs as the log grows.
//!
//! Three modes run the identical churn script on a [`DdmSession`]:
//! durability off (the in-memory baseline), WAL (every staged op and
//! commit marker appended + flushed to the op log), and WAL+fsync
//! (`fsync` after every commit marker — crash-through-power
//! durability). Per-commit latency is recorded into a histogram, so
//! the table reports the p50/p99 cost of each policy across churn
//! rates. Periodic checkpoints are disabled for the run, so the log
//! holds the entire history; the `recover_ms` column then times
//! [`DdmEngine::recover_session`] over that log, and the scaling rows
//! (`wal xE`) grow the epoch count to show recovery time tracking log
//! length. Every WAL run is recovered and asserted bit-equal to the
//! live session it logged (epoch and pair set), and all three modes
//! must end in the identical pair set.
//!
//!   cargo bench --bench abl_wal -- [--n 50k] [--epochs 8] [--quick]

use std::time::Instant;

use ddm::algos::Algo;
use ddm::bench::harness::FigCtx;
use ddm::bench::table::{banner, Table};
use ddm::core::PairVec;
use ddm::engine::DdmEngine;
use ddm::obs::Histogram;
use ddm::workload::churn::{relocate, MoveScript};
use ddm::workload::{alpha_workload, AlphaParams};

const THREADS: usize = 4;
const SPACE: f64 = 1e6;
const SCRIPT_SEED: u64 = 0x3A17;

/// Durability policy under test.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    Off,
    Wal,
    WalFsync,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Off => "off",
            Mode::Wal => "wal",
            Mode::WalFsync => "wal+fsync",
        }
    }
}

/// What one mode run measured.
struct ModeRun {
    hist: Histogram,
    commits: u64,
    elapsed: f64,
    pairs: PairVec,
    epoch: u64,
    log_bytes: u64,
    recover_s: Option<f64>,
}

/// Run `epochs` of churn at `n_moves` moves/epoch under one durability
/// policy; for WAL modes, recover from the directory afterwards and
/// assert the rebuilt session matches the live one exactly.
fn run_mode(
    ctx: &FigCtx,
    mode: Mode,
    wp: &AlphaParams,
    epochs: usize,
    n_moves: usize,
    dir: &std::path::Path,
) -> ModeRun {
    let mut builder = DdmEngine::builder()
        .algo(Algo::Psbm)
        .threads(THREADS)
        .pool(std::sync::Arc::clone(&ctx.pool));
    if mode != Mode::Off {
        let _ = std::fs::remove_dir_all(dir);
        builder = builder
            .durability(dir)
            .durability_fsync(mode == Mode::WalFsync)
            // No periodic checkpoints: the log keeps the whole history,
            // so recover_ms measures replay over `epochs` batches.
            .durability_snapshot_every(u64::MAX);
    }
    let engine = builder.build();
    let (mut subs, mut upds) = alpha_workload(77, wp);
    let mut sess = engine.session(1);
    sess.load_dense_1d(&subs, &upds);
    let mut hist = Histogram::default();
    let t_run = Instant::now();
    let t0 = Instant::now();
    let _ = sess.commit();
    hist.record_duration(t0.elapsed());
    let mut commits = 1u64;
    let mut script = MoveScript::new(SCRIPT_SEED);
    for _ in 0..epochs {
        for _ in 0..n_moves {
            let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
            if sub_side {
                let iv = relocate(&mut subs, idx, frac, SPACE);
                sess.upsert_subscription(idx as u32, &[iv]);
            } else {
                let iv = relocate(&mut upds, idx, frac, SPACE);
                sess.upsert_update(idx as u32, &[iv]);
            }
        }
        let t0 = Instant::now();
        let _ = sess.commit();
        hist.record_duration(t0.elapsed());
        commits += 1;
    }
    let elapsed = t_run.elapsed().as_secs_f64();
    let stats = sess.wal_stats();
    if let Some(err) = sess.wal_error() {
        panic!("{} run degraded its WAL: {err}", mode.name());
    }
    let recover_s = (mode != Mode::Off).then(|| {
        let t0 = Instant::now();
        let (rec, report) = engine
            .recover_session(1)
            .unwrap_or_else(|e| panic!("recover after {} run: {e}", mode.name()));
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(report.epoch, sess.epoch(), "recovered epoch != live epoch");
        assert_eq!(rec.pairs(), sess.pairs(), "recovered pair set != live pair set");
        dt
    });
    ModeRun {
        hist,
        commits,
        elapsed,
        pairs: sess.pairs(),
        epoch: sess.epoch(),
        log_bytes: stats.map_or(0, |s| s.bytes),
        recover_s,
    }
}

fn main() {
    let ctx = FigCtx::new(THREADS);
    let n_total = ctx.args.size("n", if ctx.quick { 5_000 } else { 50_000 });
    let epochs = ctx.args.size("epochs", if ctx.quick { 4 } else { 8 });
    let alpha = ctx.args.opt("alpha", 10.0);
    let default_churns: &[f64] = if ctx.quick { &[0.02] } else { &[0.10, 0.02] };
    let churns: Vec<f64> = ctx.args.list("churns", default_churns);
    let scale_factors: &[usize] = if ctx.quick { &[2] } else { &[2, 4] };
    let wp = AlphaParams {
        n_total,
        alpha,
        space: SPACE,
    };
    banner(
        "WAL",
        "commit latency off / WAL / WAL+fsync, and recovery time vs log length",
        &format!("N={n_total} α={alpha} epochs={epochs} P={THREADS}"),
    );

    let base = std::env::temp_dir().join(format!("ddm-abl-wal-{}", std::process::id()));
    let mut table = Table::new(vec![
        "mode",
        "churn",
        "epochs",
        "commits/s",
        "p50_ms",
        "p99_ms",
        "log_MB",
        "recover_ms",
    ]);
    fn row_of(mode: &str, churn: f64, epochs: usize, r: &ModeRun) -> Vec<String> {
        vec![
            mode.to_string(),
            format!("{:.1}%", churn * 100.0),
            epochs.to_string(),
            format!("{:.1}", r.commits as f64 / r.elapsed.max(1e-9)),
            format!("{:.3}", r.hist.p50() as f64 * 1e-6),
            format!("{:.3}", r.hist.p99() as f64 * 1e-6),
            if r.log_bytes == 0 {
                "-".to_string()
            } else {
                format!("{:.2}", r.log_bytes as f64 / 1e6)
            },
            r.recover_s
                .map_or_else(|| "-".to_string(), |s| format!("{:.2}", s * 1e3)),
        ]
    }

    for &churn in &churns {
        let n_moves = ((n_total as f64) * churn).ceil().max(1.0) as usize;
        let off = run_mode(&ctx, Mode::Off, &wp, epochs, n_moves, &base);
        let wal = run_mode(&ctx, Mode::Wal, &wp, epochs, n_moves, &base);
        let fsync = run_mode(&ctx, Mode::WalFsync, &wp, epochs, n_moves, &base);
        // Identical script ⇒ identical end state, durable or not.
        assert_eq!(off.pairs, wal.pairs, "off vs wal diverged at churn {churn}");
        assert_eq!(off.pairs, fsync.pairs, "off vs fsync diverged at churn {churn}");
        assert_eq!(off.epoch, wal.epoch, "epoch counters diverged at churn {churn}");
        table.row(row_of("off", churn, epochs, &off));
        table.row(row_of("wal", churn, epochs, &wal));
        table.row(row_of("wal+fsync", churn, epochs, &fsync));
    }

    // Recovery time vs log length: same churn rate, growing epoch
    // counts — the log (and so replay work) scales with epochs.
    let churn = *churns.last().unwrap_or(&0.02);
    let n_moves = ((n_total as f64) * churn).ceil().max(1.0) as usize;
    for &factor in scale_factors {
        let e = epochs * factor;
        let r = run_mode(&ctx, Mode::Wal, &wp, e, n_moves, &base);
        table.row(row_of(&format!("wal x{factor}"), churn, e, &r));
    }
    let _ = std::fs::remove_dir_all(&base);

    table.print();
    ctx.emit("abl_wal", &table);
    println!(
        "\nreading: the off row is the in-memory baseline; wal adds op records plus \
         a commit marker per epoch (buffered writes, flushed at the marker), and \
         wal+fsync adds an fsync per commit — that gap is the price of \
         crash-through-power durability. log_MB is the op log the run left behind \
         (checkpoints disabled), and recover_ms is a full scan-and-replay of it, \
         asserted to rebuild the exact live epoch and pair set. The wal xE rows \
         grow the history to show recovery time tracking log length."
    );
}

//! Ablation A3 (ours): the XLA/Pallas tiled matcher vs the native
//! algorithms — where does the dense data-parallel formulation win?
//!
//! The paper's §4 GPU remarks argue SBM/ITM are SIMD-hostile while the
//! brute-force formulation vectorizes. This bench quantifies that
//! trade-off on the CPU PJRT backend (interpret-lowered Pallas): the
//! dense kernel pays Θ(n·m) work for perfect regularity; SBM pays
//! Θ(N lg N + K) with branches. Crossover depends on α and N.
//!
//! Requires `make artifacts`.
//!
//!   cargo bench --bench abl_xla_backend -- [--quick]

use ddm::algos::{Algo, MatchParams};
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::runtime::{backend::quantize_f32, XlaMatchBackend};
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let ctx = FigCtx::new(4);
    let dir = std::path::Path::new(ddm::runtime::DEFAULT_ARTIFACT_DIR);
    if !ddm::runtime::artifacts_available(dir) {
        println!("A3 skipped: artifacts missing (run `make artifacts`)");
        return;
    }
    banner(
        "A3",
        "XLA tiled kernel vs native matchers",
        "counts kernel, f32-quantized inputs",
    );
    let t0 = std::time::Instant::now();
    let be = XlaMatchBackend::load(dir).expect("backend");
    println!("backend compile time: {}", fmt_secs(t0.elapsed().as_secs_f64()));

    let sizes: Vec<usize> = ctx.args.list(
        "sizes",
        if ctx.quick {
            &[2_048, 8_192]
        } else {
            &[2_048, 8_192, 32_768]
        },
    );
    let params = MatchParams::default();
    let mut table = Table::new(vec![
        "N", "alpha", "xla", "bfm(1t)", "psbm(4t)", "K",
    ]);
    for &n in &sizes {
        for alpha in [1.0, 100.0] {
            let wp = AlphaParams {
                n_total: n,
                alpha,
                space: 1e5,
            };
            let (subs, upds) = alpha_workload(23, &wp);
            let (subs, upds) = (quantize_f32(&subs), quantize_f32(&upds));

            let t = std::time::Instant::now();
            let k_xla = be.match_counts_1d(&subs, &upds).expect("xla");
            let t_xla = t.elapsed().as_secs_f64();

            let bfm_matcher = ctx.matcher(Algo::Bfm, &params);
            let bfm = ctx.measure_matcher(bfm_matcher.as_ref(), 1, &subs, &upds);
            let psbm_matcher = ctx.matcher(Algo::Psbm, &params);
            let psbm = ctx.measure_matcher(psbm_matcher.as_ref(), 4, &subs, &upds);
            assert_eq!(k_xla, bfm.value, "XLA vs BFM disagree");
            assert_eq!(k_xla, psbm.value, "XLA vs PSBM disagree");
            table.row(vec![
                n.to_string(),
                format!("{alpha}"),
                fmt_secs(t_xla),
                fmt_secs(bfm.modeled.mean),
                fmt_secs(psbm.modeled.mean),
                k_xla.to_string(),
            ]);
        }
    }
    table.print();
    ctx.emit("abl_xla", &table);
    println!(
        "\nreading: the dense kernel beats quadratic native BFM through \
         vectorized regularity but cannot beat O(N lg N) SBM asymptotically — \
         exactly the paper's GPU-suitability argument."
    );
}

//! Figure 9 (paper §5): wall-clock time and relative speedup of
//! parallel BFM, GBM, ITM and SBM vs the number of threads P.
//!
//! Paper parameters: N = 10⁶, α = 100, GBM with 3000 cells, P = 1..32
//! on a 16-core/32-thread Xeon. Default here is N = 10⁵ (BFM is Θ(N²);
//! the full N is a `--n 1e6` flag away — shapes are N-invariant).
//! WCT(P) is the work-span model over measured per-worker CPU time
//! (DESIGN.md §3); the raw (oversubscribed) wall-clock is also shown
//! for P = 1.
//!
//!   cargo bench --bench fig09_wct_speedup -- --n 1e5 [--quick] [--csv]

use ddm::algos::{Algo, MatchParams};
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::workload::{alpha_workload, AlphaParams};

// Algorithms are driven through the engine API (`FigCtx::matcher` +
// `FigCtx::measure_matcher`), so any `Matcher` — including out-of-tree
// backends — can be added to the sweep.

fn main() {
    let ctx = FigCtx::new(32);
    let n_total = ctx.args.size("n", if ctx.quick { 20_000 } else { 100_000 });
    let alpha = ctx.args.opt("alpha", 100.0);
    let ncells = ctx.args.opt("ncells", 3000usize);
    let wp = AlphaParams {
        n_total,
        alpha,
        space: 1e6,
    };
    banner(
        "Fig. 9",
        "WCT and speedup of parallel {BFM, GBM, ITM, SBM}",
        &format!(
            "N={n_total} α={alpha} ncells={ncells} (paper: N=1e6 α=100, 3000 cells)"
        ),
    );
    let (subs, upds) = alpha_workload(ctx.args.opt("seed", 42u64), &wp);
    let params = MatchParams {
        ncells,
        ..Default::default()
    };

    // BFM is quadratic; keep its sweep affordable by subsampling when
    // the workload is large, and report the scale honestly.
    let bfm_cap = ctx.args.size("bfm-cap", 40_000);
    let bfm_scale = (n_total as f64 / bfm_cap as f64).max(1.0);
    let (bfm_subs, bfm_upds) = if n_total > bfm_cap {
        let p2 = AlphaParams {
            n_total: bfm_cap,
            alpha,
            space: 1e6,
        };
        alpha_workload(7, &p2)
    } else {
        (subs.clone(), upds.clone())
    };
    if bfm_scale > 1.0 {
        println!(
            "(BFM measured at N={bfm_cap} and scaled ×{:.1} = (N/Nbfm)² in the table)",
            bfm_scale * bfm_scale
        );
    }

    let algos = [Algo::Bfm, Algo::Gbm, Algo::Itm, Algo::Psbm];
    let mut table = Table::new(vec![
        "P", "algo", "WCT(model)", "speedup", "WCT(raw)", "K",
    ]);
    let mut t1: Vec<f64> = vec![0.0; algos.len()];
    for &p in &ctx.thread_counts() {
        for (ai, &algo) in algos.iter().enumerate() {
            let (s, u, scale) = if algo == Algo::Bfm {
                (&bfm_subs, &bfm_upds, bfm_scale * bfm_scale)
            } else {
                (&subs, &upds, 1.0)
            };
            let matcher = ctx.matcher(algo, &params);
            let point = ctx.measure_matcher(matcher.as_ref(), p, s, u);
            let wct = point.modeled.mean * scale;
            if p == 1 {
                t1[ai] = wct;
            }
            let speedup = t1[ai] / wct;
            table.row(vec![
                p.to_string(),
                algo.name().to_string(),
                fmt_secs(wct),
                format!("{speedup:.2}"),
                fmt_secs(point.measured.mean * scale),
                point.value.to_string(),
            ]);
        }
    }
    table.print();
    ctx.emit("fig09", &table);
    println!(
        "\npaper shape check: BFM most scalable (embarrassingly parallel), \
         SBM fastest but least scalable; HT region (P>16) bends every curve."
    );
}

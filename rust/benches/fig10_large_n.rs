//! Figure 10 (paper §5): WCT and speedup of parallel ITM and SBM with
//! a large region count (paper: N = 10⁸, α = 100 — BFM/GBM omitted as
//! "taking orders of magnitude longer").
//!
//! Default here is N = 2×10⁶ (the full 10⁸ needs ~7 GB and hours of
//! single-core time; pass `--n 1e8` on a bigger box). The paper's
//! observation — SBM's speedup *improves* at large N because per-worker
//! work dwarfs synchronization overhead — is the shape to check.
//!
//!   cargo bench --bench fig10_large_n -- [--n 2e6] [--quick]

use ddm::algos::{Algo, MatchParams};
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let ctx = FigCtx::new(32);
    let n_total = ctx.args.size("n", if ctx.quick { 200_000 } else { 1_000_000 });
    let alpha = ctx.args.opt("alpha", 100.0);
    let wp = AlphaParams {
        n_total,
        alpha,
        space: 1e6,
    };
    banner(
        "Fig. 10",
        "WCT and speedup of parallel ITM and SBM, large N",
        &format!("N={n_total} α={alpha} (paper: N=1e8 α=100)"),
    );
    let (subs, upds) = alpha_workload(ctx.args.opt("seed", 10u64), &wp);
    let params = MatchParams::default();

    let algos = [Algo::Itm, Algo::Psbm];
    let mut table = Table::new(vec!["P", "algo", "WCT(model)", "speedup", "K"]);
    let mut t1 = [0.0f64; 2];
    for &p in &ctx.thread_counts() {
        for (ai, &algo) in algos.iter().enumerate() {
            let matcher = ctx.matcher(algo, &params);
            let point = ctx.measure_matcher(matcher.as_ref(), p, &subs, &upds);
            let wct = point.modeled.mean;
            if p == 1 {
                t1[ai] = wct;
            }
            table.row(vec![
                p.to_string(),
                algo.name().to_string(),
                fmt_secs(wct),
                format!("{:.2}", t1[ai] / wct),
                point.value.to_string(),
            ]);
        }
    }
    table.print();
    ctx.emit("fig10", &table);
    println!(
        "\npaper shape check: SBM reaches ~7x at P=32 at N=1e8 (vs ~3.6x at N=1e6) — \
         larger per-worker work amortizes synchronization; ITM stays tree-build-bound."
    );
}

//! Figure 11 (paper §5): GBM wall-clock as a function of (P, ncells),
//! with the per-P optimum marked (the paper's red dots).
//!
//! The paper's point: the optimal cell count depends on P (many cells
//! at low P, fewer at high P) and shifts erratically — GBM needs
//! workload- and machine-specific tuning, unlike ITM/SBM.
//!
//!   cargo bench --bench fig11_gbm_cells -- [--n 1e5] [--quick]

use ddm::algos::gbm::{self, GbmParams};
use ddm::bench::harness::FigCtx;
use ddm::bench::table::{banner, Table};
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let ctx = FigCtx::new(32);
    let n_total = ctx.args.size("n", if ctx.quick { 20_000 } else { 100_000 });
    let alpha = ctx.args.opt("alpha", 100.0);
    let wp = AlphaParams {
        n_total,
        alpha,
        space: 1e6,
    };
    banner(
        "Fig. 11",
        "GBM WCT vs (P, number of grid cells); * marks the per-P optimum",
        &format!("N={n_total} α={alpha} (paper: N=1e6 α=100)"),
    );
    let (subs, upds) = alpha_workload(ctx.args.opt("seed", 11u64), &wp);

    let cell_counts: Vec<usize> = ctx.args.list(
        "cells",
        if ctx.quick {
            &[30, 300, 3000, 30_000]
        } else {
            &[10, 30, 100, 300, 1000, 3000, 10_000, 30_000, 100_000]
        },
    );
    let threads: Vec<usize> = ctx.args.list("threads", &[1, 4, 16, 32]);

    let mut header: Vec<String> = vec!["ncells".into()];
    header.extend(threads.iter().map(|p| format!("P={p}")));
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for &nc in &cell_counts {
        let mut row = Vec::new();
        for &p in &threads {
            let matcher = gbm::GbmMatcher::new(GbmParams {
                ncells: nc,
                ..Default::default()
            });
            let point = ctx.measure_matcher(&matcher, p, &subs, &upds);
            row.push(point.modeled.mean);
        }
        rows.push(row);
    }
    // Column minima = the paper's red dots.
    let mins: Vec<usize> = (0..threads.len())
        .map(|c| {
            (0..rows.len())
                .min_by(|&a, &b| rows[a][c].total_cmp(&rows[b][c]))
                .unwrap()
        })
        .collect();

    let mut table = Table::new(header);
    for (ri, row) in rows.iter().enumerate() {
        let mut cells: Vec<String> = vec![cell_counts[ri].to_string()];
        for (ci, &v) in row.iter().enumerate() {
            let mark = if mins[ci] == ri { " *" } else { "" };
            cells.push(format!("{}{mark}", ddm::bench::stats::fmt_secs(v)));
        }
        table.row(cells);
    }
    table.print();
    ctx.emit("fig11", &table);
    println!(
        "\npaper shape check: optimum ncells drifts with P (larger grids pay off \
         at low P; coarser grids win as P grows and per-cell lists shrink)."
    );
}

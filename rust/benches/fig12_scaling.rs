//! Figure 12 (paper §5): parallel ITM and SBM wall-clock (a) as a
//! function of N at α = 100, and (b) as a function of α at fixed N —
//! both at P = 32 threads.
//!
//! Shapes to check: both grow polylog-linearly in N; SBM is flat in α
//! (its cost does not depend on the number of intersections) while ITM
//! grows with α (its query cost is output-sensitive, O(K lg n)).
//!
//!   cargo bench --bench fig12_scaling -- [--quick]

use ddm::algos::{Algo, MatchParams};
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::workload::{alpha_workload, AlphaParams};

fn main() {
    let ctx = FigCtx::new(32);
    let p = ctx.args.opt("p", 32usize);
    let params = MatchParams::default();
    let algos = [Algo::Itm, Algo::Psbm];

    // ---- (a) WCT vs N at α = 100 ----------------------------------------
    let ns: Vec<usize> = ctx.args.list(
        "ns",
        if ctx.quick {
            &[50_000, 100_000, 200_000]
        } else {
            &[100_000, 200_000, 400_000, 800_000, 1_600_000]
        },
    );
    banner(
        "Fig. 12(a)",
        "WCT vs number of regions N (P = 32, α = 100)",
        &format!("N ∈ {ns:?} (paper: 1e7..1e8)"),
    );
    let mut ta = Table::new(vec!["N", "algo", "WCT(model)", "K"]);
    for &n in &ns {
        let wp = AlphaParams {
            n_total: n,
            alpha: 100.0,
            space: 1e6,
        };
        let (subs, upds) = alpha_workload(12, &wp);
        for &algo in &algos {
            let matcher = ctx.matcher(algo, &params);
            let point = ctx.measure_matcher(matcher.as_ref(), p, &subs, &upds);
            ta.row(vec![
                n.to_string(),
                algo.name().to_string(),
                fmt_secs(point.modeled.mean),
                point.value.to_string(),
            ]);
        }
    }
    ta.print();
    ctx.emit("fig12a", &ta);

    // ---- (b) WCT vs α at fixed N -----------------------------------------
    let n_total = ctx.args.size("n", if ctx.quick { 100_000 } else { 800_000 });
    let alphas: Vec<f64> = ctx.args.list("alphas", &[0.01, 1.0, 100.0]);
    banner(
        "Fig. 12(b)",
        "WCT vs overlapping degree α (P = 32)",
        &format!("N={n_total}, α ∈ {alphas:?} (paper: N=1e8)"),
    );
    let mut tb = Table::new(vec!["alpha", "algo", "WCT(model)", "K"]);
    for &alpha in &alphas {
        let wp = AlphaParams {
            n_total,
            alpha,
            space: 1e6,
        };
        let (subs, upds) = alpha_workload(13, &wp);
        for &algo in &algos {
            let matcher = ctx.matcher(algo, &params);
            let point = ctx.measure_matcher(matcher.as_ref(), p, &subs, &upds);
            tb.row(vec![
                format!("{alpha}"),
                algo.name().to_string(),
                fmt_secs(point.modeled.mean),
                point.value.to_string(),
            ]);
        }
    }
    tb.print();
    ctx.emit("fig12b", &tb);
    println!(
        "\npaper shape check: (a) polylog growth in N for both; \
         (b) SBM ~flat in α, ITM grows with α (output-sensitive queries)."
    );
}

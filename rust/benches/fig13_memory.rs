//! Figure 13 (paper §5): peak resident set size (VmHWM) of BFM, GBM,
//! ITM and SBM (a) vs the number of regions N and (b) vs threads P.
//!
//! VmHWM is a per-process high-water mark, so each (algo, N, P) point
//! runs in a fresh child process (this binary re-execs itself with
//! `--child`). Shapes to check: linear growth in N for all; BFM
//! smallest, SBM largest (endpoint array + per-worker sets ≈ 3× BFM);
//! RSS flat in P.
//!
//!   cargo bench --bench fig13_memory -- [--quick]

use ddm::algos::Algo;
use ddm::bench::rss;
use ddm::bench::table::{banner, Table};
use ddm::cli::Args;
use ddm::engine::DdmEngine;
use ddm::workload::{alpha_workload, AlphaParams};

fn child(args: &Args) {
    let algo: Algo = args.get("algo").unwrap().parse().unwrap();
    let n_total = args.size("n", 100_000);
    let threads = args.opt("threads", 4usize);
    let wp = AlphaParams {
        n_total,
        alpha: args.opt("alpha", 100.0),
        space: 1e6,
    };
    let (subs, upds) = alpha_workload(13, &wp);
    let baseline = rss::peak_rss_bytes().unwrap_or(0);
    let engine = DdmEngine::builder().algo(algo).threads(threads).build();
    // BFM's peak RSS is input-dominated (O(1) extra memory) but its
    // runtime is Θ(N²); cap the *compute* on a subscription prefix so
    // the measurement stays affordable — the full arrays stay
    // allocated, which is what VmHWM sees.
    let k = if algo == Algo::Bfm && subs.len() > 20_000 {
        let head = ddm::core::Regions1D {
            lo: subs.lo[..20_000].to_vec(),
            hi: subs.hi[..20_000].to_vec(),
        };
        let k = engine.count_1d(&head, &upds);
        std::hint::black_box(&subs);
        k
    } else {
        engine.count_1d(&subs, &upds)
    };
    let peak = rss::peak_rss_bytes().unwrap_or(0);
    // Parent parses this exact line.
    println!("CHILD_RESULT algo={} peak={peak} base={baseline} k={k}", algo.name());
}

fn run_child(algo: Algo, n: usize, threads: usize, alpha: f64) -> Option<(u64, u64)> {
    let exe = std::env::current_exe().ok()?;
    let out = std::process::Command::new(exe)
        .args([
            "--child",
            "--algo",
            algo.name(),
            "--n",
            &n.to_string(),
            "--threads",
            &threads.to_string(),
            "--alpha",
            &alpha.to_string(),
        ])
        .output()
        .ok()?;
    let text = String::from_utf8_lossy(&out.stdout);
    let line = text.lines().find(|l| l.starts_with("CHILD_RESULT"))?;
    let field = |k: &str| -> Option<u64> {
        line.split_whitespace()
            .find_map(|t| t.strip_prefix(&format!("{k}=")))
            .and_then(|v| v.parse().ok())
    };
    Some((field("peak")?, field("k")?))
}

fn main() {
    let args = Args::from_env();
    if args.flag("child") {
        child(&args);
        return;
    }
    let quick = args.flag("quick");
    let algos = [Algo::Bfm, Algo::Gbm, Algo::Itm, Algo::Psbm];

    // ---- (a) RSS vs N ----------------------------------------------------
    let ns: Vec<usize> = args.list(
        "ns",
        if quick {
            &[50_000, 200_000]
        } else {
            &[100_000, 200_000, 400_000, 800_000, 1_600_000]
        },
    );
    banner(
        "Fig. 13(a)",
        "peak RSS (VmHWM) vs number of regions N (α=100, P=4)",
        &format!("N ∈ {ns:?} (paper: 2.5e7..1e8; BFM lowest, SBM ≈3× BFM)"),
    );
    let mut ta = Table::new(vec!["N", "bfm", "gbm", "itm", "psbm"]);
    for &n in &ns {
        let mut row = vec![n.to_string()];
        for &algo in &algos {
            match run_child(algo, n, 4, 100.0) {
                Some((peak, _)) => row.push(rss::fmt_bytes(peak)),
                None => row.push("?".into()),
            }
        }
        ta.row(row);
    }
    ta.print();
    ddm::bench::harness::json_with_args(&args, quick, "fig13a", &ta);

    // ---- (b) RSS vs P ------------------------------------------------------
    let n_fixed = args.size("n", if quick { 100_000 } else { 400_000 });
    let threads: Vec<usize> = args.list("threads", &[1, 2, 4, 8, 16, 32]);
    banner(
        "Fig. 13(b)",
        "peak RSS (VmHWM) vs threads P",
        &format!("N={n_fixed} α=100 (paper: flat in P)"),
    );
    let mut tb = Table::new(vec!["P", "bfm", "gbm", "itm", "psbm"]);
    for &p in &threads {
        let mut row = vec![p.to_string()];
        for &algo in &algos {
            match run_child(algo, n_fixed, p, 100.0) {
                Some((peak, _)) => row.push(rss::fmt_bytes(peak)),
                None => row.push("?".into()),
            }
        }
        tb.row(row);
    }
    tb.print();
    ddm::bench::harness::json_with_args(&args, quick, "fig13b", &tb);
    println!(
        "\npaper shape check: RSS linear in N; BFM smallest, SBM largest; flat in P."
    );
}

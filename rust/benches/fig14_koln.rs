//! Figure 14 (paper §5): WCT and speedup of parallel GBM, ITM and SBM
//! on the Cologne vehicular trace (here: the Köln-like synthetic trace,
//! DESIGN.md §3 substitution 2 — the real trace is not downloadable
//! offline).
//!
//! Paper: 541,222 positions → ~10⁶ regions of width 100 m and
//! ≈3.9×10⁹ intersections; GBM slowest, parallel SBM fastest by a wide
//! margin, SBM speedup limited by its tiny absolute runtime.
//!
//!   cargo bench --bench fig14_koln -- [--scale 0.25] [--quick]

use ddm::algos::{Algo, MatchParams};
use ddm::bench::harness::FigCtx;
use ddm::bench::stats::fmt_secs;
use ddm::bench::table::{banner, Table};
use ddm::workload::koln::{koln_workload, KolnParams};

fn main() {
    let ctx = FigCtx::new(32);
    // Default 10% of the full trace: the arterial clustering makes K
    // grow quadratically with scale, and GBM must do Ω(K) work per
    // rep; 10% keeps the full P-sweep affordable on one core. Use
    // `--scale 1.0` for the paper-size run on a real multicore box.
    let scale = ctx.args.opt("scale", if ctx.quick { 0.02 } else { 0.1 });
    let kp = KolnParams::default().scaled(scale);
    banner(
        "Fig. 14",
        "WCT and speedup on the Köln-like trace",
        &format!(
            "positions={} width={} m extent={} m (paper: 541222 / 100 m; K≈3.9e9 — \
             scaled target K≈{:.3e})",
            kp.positions,
            kp.width,
            kp.extent,
            3.9e9 * scale * scale
        ),
    );
    let (subs, upds) = koln_workload(ctx.args.opt("seed", 62u64), &kp);
    let params = MatchParams {
        ncells: ctx.args.opt("ncells", 3000usize),
        ..Default::default()
    };

    let algos = [Algo::Gbm, Algo::Itm, Algo::Psbm];
    let mut table = Table::new(vec!["P", "algo", "WCT(model)", "speedup", "K"]);
    let mut t1 = [0.0f64; 3];
    for &p in &ctx.thread_counts() {
        for (ai, &algo) in algos.iter().enumerate() {
            let matcher = ctx.matcher(algo, &params);
            let point = ctx.measure_matcher(matcher.as_ref(), p, &subs, &upds);
            let wct = point.modeled.mean;
            if p == 1 {
                t1[ai] = wct;
            }
            table.row(vec![
                p.to_string(),
                algo.name().to_string(),
                fmt_secs(wct),
                format!("{:.2}", t1[ai] / wct),
                point.value.to_string(),
            ]);
        }
    }
    table.print();
    ctx.emit("fig14", &table);
    println!(
        "\npaper shape check: GBM slowest, parallel SBM fastest by a wide margin; \
         SBM's speedup stays low because its absolute runtime is tiny."
    );
}

//! Brute-Force Matching (paper Algorithm 2, "region-based" matching).
//!
//! Θ(n·m) pair tests; optimal only in the worst case but — as the paper
//! stresses — *embarrassingly parallel*: the outer loop carries no
//! dependencies, so the parallel version simply splits the subscription
//! set across workers (`#pragma omp parallel for` in the paper's code,
//! [`crate::exec::pfor::parallel_for_static`] here).

use crate::core::sink::MatchSink;
use crate::core::Regions1D;
use crate::exec::pfor::chunks;
use crate::exec::ThreadPool;

/// Serial BFM (Algorithm 2 verbatim).
pub fn match_seq(subs: &Regions1D, upds: &Regions1D, sink: &mut dyn MatchSink) {
    match_range(subs, upds, 0..subs.len(), sink);
}

/// BFM over a subscription index sub-range (the parallel work unit).
#[inline]
pub fn match_range(
    subs: &Regions1D,
    upds: &Regions1D,
    range: std::ops::Range<usize>,
    sink: &mut dyn MatchSink,
) {
    let (ulo, uhi) = (&upds.lo[..], &upds.hi[..]);
    for i in range {
        let (slo, shi) = (subs.lo[i], subs.hi[i]);
        // Hot loop: branch-light Intersect-1D over SoA arrays.
        for j in 0..ulo.len() {
            if slo < uhi[j] && ulo[j] < shi {
                sink.report(i as u32, j as u32);
            }
        }
    }
}

/// Parallel BFM: static split of the subscription loop (paper §5).
pub fn match_par<S>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
) -> Vec<S>
where
    S: MatchSink + Default,
{
    let ranges = chunks(subs.len(), nthreads);
    super::par_collect(pool, nthreads, |p, sink| {
        match_range(subs, upds, ranges[p].clone(), sink);
    })
}

/// [`Matcher`](crate::engine::Matcher) backend for brute-force
/// matching.
pub struct BfmMatcher;

impl crate::engine::Matcher for BfmMatcher {
    fn name(&self) -> &str {
        "bfm"
    }

    fn match_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    ) {
        let sinks: Vec<crate::core::sink::VecSink> =
            match_par(ctx.pool, ctx.nthreads, subs, upds);
        crate::core::sink::replay(sinks, sink);
    }

    fn count_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
    ) -> u64 {
        let sinks: Vec<crate::core::sink::CountSink> =
            match_par(ctx.pool, ctx.nthreads, subs, upds);
        crate::core::sink::total_count(&sinks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::interval::Interval;
    use crate::core::sink::{canonical_pairs, canonicalize, VecSink};
    use crate::core::region::random_regions_1d;

    #[test]
    fn simple_known_case() {
        let subs = Regions1D::from_intervals(&[
            Interval::new(0.0, 2.0),
            Interval::new(5.0, 6.0),
        ]);
        let upds = Regions1D::from_intervals(&[
            Interval::new(1.0, 3.0),
            Interval::new(2.0, 5.0),
            Interval::new(5.5, 7.0),
        ]);
        let mut sink = VecSink::default();
        match_seq(&subs, &upds, &mut sink);
        assert_eq!(canonicalize(sink.pairs), vec![(0, 0), (1, 2)]);
    }

    #[test]
    fn parallel_equals_serial_for_all_p() {
        let pool = ThreadPool::new(7);
        let mut rng = crate::prng::Rng::new(0xBF);
        let subs = random_regions_1d(&mut rng, 500, 1000.0, 4.0);
        let upds = random_regions_1d(&mut rng, 400, 1000.0, 4.0);
        let mut want = VecSink::default();
        match_seq(&subs, &upds, &mut want);
        let want = canonicalize(want.pairs);
        for p in 1..=8 {
            let got = canonical_pairs(match_par::<VecSink>(&pool, p, &subs, &upds));
            assert_eq!(got, want, "p={p}");
        }
    }

    #[test]
    fn empty_sets() {
        let mut sink = VecSink::default();
        match_seq(&Regions1D::default(), &Regions1D::default(), &mut sink);
        assert!(sink.pairs.is_empty());
        let pool = ThreadPool::new(1);
        let sinks = match_par::<VecSink>(&pool, 2, &Regions1D::default(), &Regions1D::default());
        assert!(canonical_pairs(sinks).is_empty());
    }

    #[test]
    fn exactly_once_property() {
        crate::bench::prop::prop_check("bfm-exactly-once", 0xB1, |rng| {
            let n = rng.below(100) as usize;
            let m = rng.below(100) as usize;
            let subs = random_regions_1d(rng, n.max(1), 100.0, 10.0);
            let upds = random_regions_1d(rng, m.max(1), 100.0, 10.0);
            let mut sink = VecSink::default();
            match_seq(&subs, &upds, &mut sink);
            crate::core::sink::assert_exactly_once(&canonicalize(sink.pairs))
        });
    }
}

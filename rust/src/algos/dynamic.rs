//! Dynamic interval management (paper §3, "Dynamic interval
//! management") — the ITM feature the paper highlights against SBM.
//!
//! [`TreeIndex`] is the per-side building block: an interval tree plus
//! a key → interval map, implementing the engine's
//! [`DynamicMatcher`](crate::engine::DynamicMatcher) extension trait
//! (insert/modify/remove in O(lg n), output-sensitive queries).
//!
//! [`DynamicDdm`] composes two of them — the paper's two-tree scheme —
//! to index the subscription and update sets. When a region moves or
//! resizes, the affected overlaps are recomputed in O(min{n, K lg n})
//! by querying the *opposite* tree, and the region's own tree is
//! updated with one delete + one insert (O(lg n) each) — no full
//! re-match. [`MoveDiff`] reports which pairs appeared and
//! disappeared, which is exactly what the HLA notification layer needs.
//!
//! [`crate::session::DdmSession`] generalizes this scheme to batched,
//! N-dimensional, epoch-committed churn: one [`TreeIndex`] per
//! dimension per side, with whole-epoch
//! [`MatchDiff`](crate::session::MatchDiff)s instead of per-move
//! diffs.

use std::collections::BTreeMap;

use crate::core::interval::Interval;
use crate::core::Regions1D;

use super::interval_tree::IntervalTree;

/// A keyed incremental 1-D interval index: the native
/// [`DynamicMatcher`](crate::engine::DynamicMatcher) of the
/// interval-tree family (one side of the two-tree scheme).
pub struct TreeIndex {
    tree: IntervalTree,
    ivs: BTreeMap<u32, Interval>,
}

impl TreeIndex {
    pub fn new() -> Self {
        Self {
            tree: IntervalTree::new(),
            ivs: BTreeMap::new(),
        }
    }

    /// Bulk build keyed by dense index (O(n) tree construction).
    pub fn from_regions(regions: &Regions1D) -> Self {
        let tree = IntervalTree::from_regions(regions);
        let ivs = (0..regions.len())
            .map(|i| (i as u32, regions.get(i)))
            .collect();
        Self { tree, ivs }
    }

    /// Store `iv` under `key`, replacing any previous interval.
    pub fn put(&mut self, key: u32, iv: Interval) {
        if let Some(old) = self.ivs.insert(key, iv) {
            let removed = self.tree.remove(old, key);
            debug_assert!(removed);
        }
        self.tree.insert(iv, key);
    }

    /// Drop `key` (no-op if absent).
    pub fn delete(&mut self, key: u32) {
        if let Some(old) = self.ivs.remove(&key) {
            let removed = self.tree.remove(old, key);
            debug_assert!(removed);
        }
    }

    /// The interval stored under `key`.
    pub fn get(&self, key: u32) -> Option<Interval> {
        self.ivs.get(&key).copied()
    }

    /// Keys of stored intervals overlapping `q`, ascending.
    pub fn query_sorted(&self, q: Interval) -> Vec<u32> {
        let mut out = Vec::new();
        self.query_into(q, &mut out);
        out
    }

    /// [`query_sorted`](Self::query_sorted) into a reusable buffer
    /// (cleared first) — the allocation-free form the session's
    /// per-epoch recompute runs on.
    pub fn query_into(&self, q: Interval, out: &mut Vec<u32>) {
        out.clear();
        self.tree.query(q, &mut |i| out.push(i));
        out.sort_unstable();
    }

    pub fn len(&self) -> usize {
        self.ivs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ivs.is_empty()
    }

    /// Iterate `(key, interval)` in ascending key order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, Interval)> + '_ {
        self.ivs.iter().map(|(&k, &iv)| (k, iv))
    }

    /// Structural self-check (tests).
    pub fn check_invariants(&self) {
        self.tree.check_invariants();
        assert_eq!(self.tree.len(), self.ivs.len());
    }
}

impl Default for TreeIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl crate::engine::DynamicMatcher for TreeIndex {
    fn insert(&mut self, key: u32, iv: Interval) {
        self.put(key, iv);
    }

    fn modify(&mut self, key: u32, iv: Interval) {
        self.put(key, iv);
    }

    fn remove(&mut self, key: u32) {
        self.delete(key);
    }

    fn query(&mut self, _ctx: &crate::engine::ExecCtx<'_>, q: Interval, out: &mut Vec<u32>) {
        out.clear();
        out.extend(self.query_sorted(q));
    }

    fn len(&self) -> usize {
        self.ivs.len()
    }
}

/// Which side a region belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Subscription,
    Update,
}

/// Overlap changes caused by one region move.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MoveDiff {
    /// Pairs that stopped overlapping (sorted opposite-side indices).
    pub removed: Vec<u32>,
    /// Pairs that started overlapping (sorted opposite-side indices).
    pub added: Vec<u32>,
}

/// The two-tree dynamic DDM state of §3: one [`TreeIndex`] per side.
pub struct DynamicDdm {
    tree_s: TreeIndex,
    tree_u: TreeIndex,
}

impl DynamicDdm {
    pub fn new(subs: Regions1D, upds: Regions1D) -> Self {
        Self {
            tree_s: TreeIndex::from_regions(&subs),
            tree_u: TreeIndex::from_regions(&upds),
        }
    }

    pub fn n_subs(&self) -> usize {
        self.tree_s.len()
    }

    pub fn n_upds(&self) -> usize {
        self.tree_u.len()
    }

    pub fn interval(&self, side: Side, idx: u32) -> Interval {
        let index = match side {
            Side::Subscription => &self.tree_s,
            Side::Update => &self.tree_u,
        };
        // xlint: allow(hot-panic): caller contract — a stale handle is
        // a caller bug and must fail loudly, not silently mis-match.
        index.get(idx).expect("region index in range")
    }

    /// Current overlaps of one region (opposite-side indices, sorted).
    pub fn overlaps(&self, side: Side, idx: u32) -> Vec<u32> {
        let q = self.interval(side, idx);
        match side {
            Side::Subscription => self.tree_u.query_sorted(q),
            Side::Update => self.tree_s.query_sorted(q),
        }
    }

    /// Move/resize a region; returns the overlap diff.
    ///
    /// Cost: two opposite-tree queries (O(min{n, K lg n})) plus one
    /// delete + insert in the region's own tree (O(lg n)).
    pub fn move_region(&mut self, side: Side, idx: u32, new_iv: Interval) -> MoveDiff {
        let old_iv = self.interval(side, idx);
        let (old, new) = match side {
            Side::Subscription => {
                let old = self.tree_u.query_sorted(old_iv);
                let new = self.tree_u.query_sorted(new_iv);
                self.tree_s.put(idx, new_iv);
                (old, new)
            }
            Side::Update => {
                let old = self.tree_s.query_sorted(old_iv);
                let new = self.tree_s.query_sorted(new_iv);
                self.tree_u.put(idx, new_iv);
                (old, new)
            }
        };
        diff_sorted(&old, &new)
    }

    /// Full current pair set (for validation): query every update
    /// against the subscription tree.
    pub fn all_pairs(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for (j, q) in self.tree_u.iter() {
            out.extend(self.tree_s.query_sorted(q).into_iter().map(|s| (s, j)));
        }
        out.sort_unstable();
        out
    }

    /// Structural self-check (tests).
    pub fn check(&self) {
        self.tree_s.check_invariants();
        self.tree_u.check_invariants();
    }
}

/// Set difference of two sorted vectors: (old \ new, new \ old).
fn diff_sorted(old: &[u32], new: &[u32]) -> MoveDiff {
    let mut removed = Vec::new();
    let mut added = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < old.len() && j < new.len() {
        match old[i].cmp(&new[j]) {
            std::cmp::Ordering::Less => {
                removed.push(old[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                added.push(new[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
    }
    removed.extend_from_slice(&old[i..]);
    added.extend_from_slice(&new[j..]);
    MoveDiff { removed, added }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::bfm;
    use crate::core::region::random_regions_1d;
    use crate::core::sink::{canonicalize, VecSink};
    use crate::prng::Rng;

    fn bfm_pairs(subs: &Regions1D, upds: &Regions1D) -> Vec<(u32, u32)> {
        let mut sink = VecSink::default();
        bfm::match_seq(subs, upds, &mut sink);
        canonicalize(sink.pairs)
    }

    #[test]
    fn diff_sorted_basics() {
        let d = diff_sorted(&[1, 2, 3], &[2, 3, 4]);
        assert_eq!(d.removed, vec![1]);
        assert_eq!(d.added, vec![4]);
        let d2 = diff_sorted(&[], &[7]);
        assert_eq!((d2.removed.len(), d2.added), (0, vec![7]));
    }

    #[test]
    fn initial_state_matches_bfm() {
        let mut rng = Rng::new(0xD0);
        let subs = random_regions_1d(&mut rng, 150, 300.0, 8.0);
        let upds = random_regions_1d(&mut rng, 150, 300.0, 8.0);
        let ddm = DynamicDdm::new(subs.clone(), upds.clone());
        ddm.check();
        assert_eq!(ddm.all_pairs(), bfm_pairs(&subs, &upds));
    }

    #[test]
    fn moves_track_bfm_property() {
        crate::bench::prop::prop_check("dynamic-moves-vs-bfm", 0xD1, |rng| {
            let n = 5 + rng.below(60) as usize;
            let subs = random_regions_1d(rng, n, 100.0, 6.0);
            let upds = random_regions_1d(rng, n, 100.0, 6.0);
            let mut ddm = DynamicDdm::new(subs.clone(), upds.clone());
            let (mut subs, mut upds) = (subs, upds);
            for _ in 0..30 {
                let side = if rng.chance(0.5) {
                    Side::Subscription
                } else {
                    Side::Update
                };
                let idx = rng.below(n as u64) as u32;
                let lo = rng.uniform(0.0, 94.0);
                let new_iv = Interval::new(lo, lo + rng.uniform(0.0, 8.0));
                let before = ddm.overlaps(side, idx);
                let diff = ddm.move_region(side, idx, new_iv);
                let after = ddm.overlaps(side, idx);
                // Diff consistency: before - removed + added == after.
                let mut expect: Vec<u32> = before
                    .iter()
                    .filter(|x| !diff.removed.contains(x))
                    .cloned()
                    .collect();
                expect.extend(diff.added.iter().cloned());
                expect.sort_unstable();
                if expect != after {
                    return Err(format!("diff inconsistent: {expect:?} vs {after:?}"));
                }
                match side {
                    Side::Subscription => subs.set(idx as usize, new_iv),
                    Side::Update => upds.set(idx as usize, new_iv),
                }
            }
            ddm.check();
            crate::bench::prop::expect_eq(
                &ddm.all_pairs(),
                &bfm_pairs(&subs, &upds),
                "pair set after moves",
            )
        });
    }

    #[test]
    fn move_to_same_place_is_noop_diff() {
        let subs = Regions1D::from_intervals(&[Interval::new(0.0, 10.0)]);
        let upds = Regions1D::from_intervals(&[Interval::new(5.0, 15.0)]);
        let mut ddm = DynamicDdm::new(subs, upds);
        let d = ddm.move_region(Side::Subscription, 0, Interval::new(0.0, 10.0));
        assert_eq!(d, MoveDiff::default());
    }

    /// Property: TreeIndex and the engine's rebuild-on-write adapter
    /// are interchangeable implementations of the DynamicMatcher
    /// contract — identical query results and lengths under randomized
    /// insert/modify/remove/query sequences, whatever static matcher
    /// backs the adapter.
    #[test]
    fn rebuild_adapter_agrees_with_tree_index_property() {
        use crate::algos::{Algo, MatchParams};
        use crate::engine::{algo_matcher, DynamicMatcher, ExecCtx, RebuildDynamic};
        let pool = crate::exec::ThreadPool::new(1);
        crate::bench::prop::prop_check("rebuild-vs-tree-index", 0xD7, |rng| {
            let ctx = ExecCtx::new(&pool, 2);
            let backing = match rng.below(3) {
                0 => Algo::Psbm,
                1 => Algo::Itm,
                _ => Algo::Sbm,
            };
            let mut tree: Box<dyn DynamicMatcher> = Box::new(TreeIndex::new());
            let mut rebuild: Box<dyn DynamicMatcher> = Box::new(RebuildDynamic::new(
                algo_matcher(backing, &MatchParams::default()),
            ));
            let nops = 30 + rng.below(80);
            for step in 0..nops {
                let key = rng.below(24) as u32;
                let lo = rng.uniform(0.0, 90.0);
                let iv = Interval::new(lo, lo + rng.uniform(0.0, 10.0));
                match rng.below(4) {
                    0 => {
                        tree.insert(key, iv);
                        rebuild.insert(key, iv);
                    }
                    1 => {
                        tree.modify(key, iv);
                        rebuild.modify(key, iv);
                    }
                    2 => {
                        tree.remove(key);
                        rebuild.remove(key);
                    }
                    _ => {} // query-only step
                }
                let qlo = rng.uniform(0.0, 95.0);
                let q = Interval::new(qlo, qlo + rng.uniform(0.5, 8.0));
                let (mut a, mut b) = (Vec::new(), Vec::new());
                tree.query(&ctx, q, &mut a);
                rebuild.query(&ctx, q, &mut b);
                crate::bench::prop::expect_eq(
                    &a,
                    &b,
                    &format!("query at step {step} ({} backing)", backing.name()),
                )?;
                if tree.len() != rebuild.len() {
                    return Err(format!(
                        "len diverged at step {step}: tree {} vs rebuild {}",
                        tree.len(),
                        rebuild.len()
                    ));
                }
            }
            Ok(())
        });
    }
}

//! Dynamic interval management (paper §3, "Dynamic interval
//! management") — the ITM feature the paper highlights against SBM.
//!
//! Two interval trees index the subscription and update sets. When a
//! region moves or resizes, the affected overlaps are recomputed in
//! O(min{n, K lg n}) by querying the *opposite* tree, and the region's
//! own tree is updated with one delete + one insert (O(lg n) each) —
//! no full re-match. [`MoveDiff`] reports which pairs appeared and
//! disappeared, which is exactly what the HLA notification layer needs.

use crate::core::interval::Interval;
use crate::core::Regions1D;

use super::interval_tree::IntervalTree;

/// Which side a region belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Subscription,
    Update,
}

/// Overlap changes caused by one region move.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MoveDiff {
    /// Pairs that stopped overlapping (sorted opposite-side indices).
    pub removed: Vec<u32>,
    /// Pairs that started overlapping (sorted opposite-side indices).
    pub added: Vec<u32>,
}

/// The two-tree dynamic DDM state of §3.
pub struct DynamicDdm {
    subs: Regions1D,
    upds: Regions1D,
    tree_s: IntervalTree,
    tree_u: IntervalTree,
}

impl DynamicDdm {
    pub fn new(subs: Regions1D, upds: Regions1D) -> Self {
        let tree_s = IntervalTree::from_regions(&subs);
        let tree_u = IntervalTree::from_regions(&upds);
        Self {
            subs,
            upds,
            tree_s,
            tree_u,
        }
    }

    pub fn n_subs(&self) -> usize {
        self.subs.len()
    }

    pub fn n_upds(&self) -> usize {
        self.upds.len()
    }

    pub fn interval(&self, side: Side, idx: u32) -> Interval {
        match side {
            Side::Subscription => self.subs.get(idx as usize),
            Side::Update => self.upds.get(idx as usize),
        }
    }

    /// Current overlaps of one region (opposite-side indices, sorted).
    pub fn overlaps(&self, side: Side, idx: u32) -> Vec<u32> {
        let q = self.interval(side, idx);
        match side {
            Side::Subscription => self.tree_u.query_vec(q),
            Side::Update => self.tree_s.query_vec(q),
        }
    }

    /// Move/resize a region; returns the overlap diff.
    ///
    /// Cost: two opposite-tree queries (O(min{n, K lg n})) plus one
    /// delete + insert in the region's own tree (O(lg n)).
    pub fn move_region(&mut self, side: Side, idx: u32, new_iv: Interval) -> MoveDiff {
        let old_iv = self.interval(side, idx);
        let (old, new) = match side {
            Side::Subscription => {
                let old = self.tree_u.query_vec(old_iv);
                let new = self.tree_u.query_vec(new_iv);
                let ok = self.tree_s.remove(old_iv, idx);
                debug_assert!(ok);
                self.tree_s.insert(new_iv, idx);
                self.subs.set(idx as usize, new_iv);
                (old, new)
            }
            Side::Update => {
                let old = self.tree_s.query_vec(old_iv);
                let new = self.tree_s.query_vec(new_iv);
                let ok = self.tree_u.remove(old_iv, idx);
                debug_assert!(ok);
                self.tree_u.insert(new_iv, idx);
                self.upds.set(idx as usize, new_iv);
                (old, new)
            }
        };
        diff_sorted(&old, &new)
    }

    /// Full current pair set (for validation): query every update
    /// against the subscription tree.
    pub fn all_pairs(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for j in 0..self.upds.len() {
            let q = self.upds.get(j);
            self.tree_s.query(q, &mut |s| out.push((s, j as u32)));
        }
        out.sort_unstable();
        out
    }

    /// Structural self-check (tests).
    pub fn check(&self) {
        self.tree_s.check_invariants();
        self.tree_u.check_invariants();
        assert_eq!(self.tree_s.len(), self.subs.len());
        assert_eq!(self.tree_u.len(), self.upds.len());
    }
}

/// Set difference of two sorted vectors: (old \ new, new \ old).
fn diff_sorted(old: &[u32], new: &[u32]) -> MoveDiff {
    let mut removed = Vec::new();
    let mut added = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < old.len() && j < new.len() {
        match old[i].cmp(&new[j]) {
            std::cmp::Ordering::Less => {
                removed.push(old[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                added.push(new[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
    }
    removed.extend_from_slice(&old[i..]);
    added.extend_from_slice(&new[j..]);
    MoveDiff { removed, added }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::bfm;
    use crate::core::region::random_regions_1d;
    use crate::core::sink::{canonicalize, VecSink};
    use crate::prng::Rng;

    fn bfm_pairs(subs: &Regions1D, upds: &Regions1D) -> Vec<(u32, u32)> {
        let mut sink = VecSink::default();
        bfm::match_seq(subs, upds, &mut sink);
        canonicalize(sink.pairs)
    }

    #[test]
    fn diff_sorted_basics() {
        let d = diff_sorted(&[1, 2, 3], &[2, 3, 4]);
        assert_eq!(d.removed, vec![1]);
        assert_eq!(d.added, vec![4]);
        let d2 = diff_sorted(&[], &[7]);
        assert_eq!((d2.removed.len(), d2.added), (0, vec![7]));
    }

    #[test]
    fn initial_state_matches_bfm() {
        let mut rng = Rng::new(0xD0);
        let subs = random_regions_1d(&mut rng, 150, 300.0, 8.0);
        let upds = random_regions_1d(&mut rng, 150, 300.0, 8.0);
        let ddm = DynamicDdm::new(subs.clone(), upds.clone());
        ddm.check();
        assert_eq!(ddm.all_pairs(), bfm_pairs(&subs, &upds));
    }

    #[test]
    fn moves_track_bfm_property() {
        crate::bench::prop::prop_check("dynamic-moves-vs-bfm", 0xD1, |rng| {
            let n = 5 + rng.below(60) as usize;
            let subs = random_regions_1d(rng, n, 100.0, 6.0);
            let upds = random_regions_1d(rng, n, 100.0, 6.0);
            let mut ddm = DynamicDdm::new(subs.clone(), upds.clone());
            let (mut subs, mut upds) = (subs, upds);
            for _ in 0..30 {
                let side = if rng.chance(0.5) {
                    Side::Subscription
                } else {
                    Side::Update
                };
                let idx = rng.below(n as u64) as u32;
                let lo = rng.uniform(0.0, 94.0);
                let new_iv = Interval::new(lo, lo + rng.uniform(0.0, 8.0));
                let before = ddm.overlaps(side, idx);
                let diff = ddm.move_region(side, idx, new_iv);
                let after = ddm.overlaps(side, idx);
                // Diff consistency: before - removed + added == after.
                let mut expect: Vec<u32> = before
                    .iter()
                    .filter(|x| !diff.removed.contains(x))
                    .cloned()
                    .collect();
                expect.extend(diff.added.iter().cloned());
                expect.sort_unstable();
                if expect != after {
                    return Err(format!("diff inconsistent: {expect:?} vs {after:?}"));
                }
                match side {
                    Side::Subscription => subs.set(idx as usize, new_iv),
                    Side::Update => upds.set(idx as usize, new_iv),
                }
            }
            ddm.check();
            crate::bench::prop::expect_eq(
                &ddm.all_pairs(),
                &bfm_pairs(&subs, &upds),
                "pair set after moves",
            )
        });
    }

    #[test]
    fn move_to_same_place_is_noop_diff() {
        let subs = Regions1D::from_intervals(&[Interval::new(0.0, 10.0)]);
        let upds = Regions1D::from_intervals(&[Interval::new(5.0, 15.0)]);
        let mut ddm = DynamicDdm::new(subs, upds);
        let d = ddm.move_region(Side::Subscription, 0, Interval::new(0.0, 10.0));
        assert_eq!(d, MoveDiff::default());
    }
}

//! Grid-Based Matching (paper Algorithm 3, [16, 63]).
//!
//! The routing space is split into `ncells` equal cells; update regions
//! are binned into the cells they overlap (phase 1), then every
//! subscription is tested against the update lists of its cells
//! (phase 2). Two concurrency strategies for the phase-1 data race on
//! the cell lists (the lock-free fan-in that replaced the per-cell
//! mutexes vs the paper's ad-hoc lock-free append list) and two
//! duplicate-suppression strategies (the paper's `res` set vs the
//! standard first-shared-cell rule) are selectable —
//! `benches/abl_gbm_list.rs` re-runs the comparison.

use crate::core::ddim::{self, NdMode, NdPolicy};
use crate::core::scratch::MatchScratch;
use crate::core::sink::MatchSink;
use crate::core::{Regions1D, RegionsNd};
use crate::exec::lflist::LfList;
use crate::exec::pfor::chunks;
use crate::exec::ThreadPool;

/// Phase-1 cell-list synchronization strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CellList {
    /// Counting-sort scatter over the radix machinery's histogram
    /// layout: pass 1 counts each worker's entries per cell, a master
    /// prefix sum turns the counts into disjoint offsets (cell-major,
    /// worker-minor), pass 2 scatters update indices straight into one
    /// flat CSR array — no locks, no per-cell `Vec`s, and each cell's
    /// list comes out in ascending update order deterministically.
    /// (Replaces the per-worker-`Vec` fan-in, itself the replacement
    /// for per-cell mutexes and the paper's one-global-lock
    /// `#pragma omp critical`.)
    #[default]
    FanIn,
    /// The ad-hoc lock-free append list (paper §5).
    LockFree,
}

/// Phase-1 output: per-cell update lists, either as one flat CSR block
/// (the counting-sort scatter) or per-cell vectors (lock-free lists).
/// The CSR variant keeps its (spent) count block alive so all three
/// pooled buffers can be returned together in take order — that keeps
/// each buffer in the same role on the next call, so warm capacities
/// are exactly stable.
enum Bins {
    Csr {
        flat: Vec<u32>,
        starts: Vec<u32>,
        counts: Vec<u32>,
    },
    Lists(Vec<Vec<u32>>),
}

impl Bins {
    #[inline]
    fn cell(&self, c: usize) -> &[u32] {
        match self {
            Bins::Csr { flat, starts, .. } => &flat[starts[c] as usize..starts[c + 1] as usize],
            Bins::Lists(lists) => &lists[c],
        }
    }
}

/// Duplicate-suppression strategy for phase 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dedup {
    /// Report (s,u) only in the first cell both share — no `res` set,
    /// no extra memory (the standard grid dedup rule).
    #[default]
    FirstCell,
    /// The paper's Algorithm 3 `res` set (per subscription, which is
    /// equivalent to the paper's global set: duplicates only arise
    /// among the cells of one subscription).
    ResSet,
}

#[derive(Debug, Clone, Copy)]
pub struct GbmParams {
    pub ncells: usize,
    pub cell_list: CellList,
    pub dedup: Dedup,
}

impl Default for GbmParams {
    fn default() -> Self {
        Self {
            ncells: 3000,
            cell_list: CellList::FanIn,
            dedup: Dedup::FirstCell,
        }
    }
}

struct Grid {
    lb: f64,
    width: f64,
    ncells: usize,
}

impl Grid {
    fn new(subs: &Regions1D, upds: &Regions1D, ncells: usize) -> Option<Grid> {
        let b = match (subs.bounds(), upds.bounds()) {
            (Some(a), Some(b)) => a.hull(&b),
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (None, None) => return None,
        };
        let span = (b.hi - b.lo).max(f64::MIN_POSITIVE);
        Some(Grid {
            lb: b.lo,
            width: span / ncells as f64,
            ncells,
        })
    }

    /// Cell containing point `x` (clamped).
    #[inline]
    fn cell_of(&self, x: f64) -> usize {
        (((x - self.lb) / self.width) as usize).min(self.ncells - 1)
    }

    /// Iterate the cells interval `[lo, hi)` overlaps (Algorithm 3's
    /// `while (i < ncells) && (i*width < upper)` loop).
    #[inline]
    fn cells(&self, lo: f64, hi: f64) -> std::ops::RangeInclusive<usize> {
        let first = self.cell_of(lo);
        // last cell whose start is < hi
        let mut last = self.cell_of(hi);
        if last > first && self.lb + last as f64 * self.width >= hi {
            last -= 1;
        }
        first..=last
    }
}

/// Serial GBM (Algorithm 3).
pub fn match_seq(
    subs: &Regions1D,
    upds: &Regions1D,
    params: &GbmParams,
    sink: &mut dyn MatchSink,
) {
    let Some(grid) = Grid::new(subs, upds, params.ncells) else {
        return;
    };
    // Phase 1: bin updates.
    let mut cells: Vec<Vec<u32>> = vec![Vec::new(); grid.ncells];
    for j in 0..upds.len() {
        for c in grid.cells(upds.lo[j], upds.hi[j]) {
            cells[c].push(j as u32);
        }
    }
    // Phase 2: scan subscriptions.
    let mut res = std::collections::HashSet::new();
    for i in 0..subs.len() {
        let (slo, shi) = (subs.lo[i], subs.hi[i]);
        if params.dedup == Dedup::ResSet {
            res.clear();
        }
        for c in grid.cells(slo, shi) {
            for &j in &cells[c] {
                let (ulo, uhi) = (upds.lo[j as usize], upds.hi[j as usize]);
                if slo < uhi && ulo < shi {
                    match params.dedup {
                        Dedup::FirstCell => {
                            if c == grid.cell_of(slo.max(ulo)) {
                                sink.report(i as u32, j);
                            }
                        }
                        Dedup::ResSet => {
                            if res.insert(j) {
                                sink.report(i as u32, j);
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Parallel GBM (both phases parallel; phase 1 races on the cell lists
/// and uses the selected synchronization strategy).
pub fn match_par<S>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    params: &GbmParams,
) -> Vec<S>
where
    S: MatchSink + Default,
{
    match_par_sinks(pool, nthreads, subs, upds, params, |_p| S::default())
}

/// [`match_par`] with a per-worker sink factory (worker `p` reports
/// into `mk(p)`) — how the native N-D path wraps every worker's sink
/// in a [`FilterSink`](crate::core::sink::FilterSink).
pub fn match_par_sinks<S, M>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    params: &GbmParams,
    mk: M,
) -> Vec<S>
where
    S: MatchSink,
    M: Fn(usize) -> S + Sync,
{
    match_par_sinks_scratch(pool, nthreads, subs, upds, params, &mut MatchScratch::new(), mk)
}

/// [`match_par_sinks`] over a caller-owned
/// [`MatchScratch`](crate::core::scratch::MatchScratch): the binning
/// count block, the cell-start array and the flat CSR cell list are
/// all pooled, so a warm call's phase 1 allocates nothing.
pub fn match_par_sinks_scratch<S, M>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    params: &GbmParams,
    scratch: &mut MatchScratch,
    mk: M,
) -> Vec<S>
where
    S: MatchSink,
    M: Fn(usize) -> S + Sync,
{
    let Some(grid) = Grid::new(subs, upds, params.ncells) else {
        return (0..nthreads).map(&mk).collect();
    };
    let grid = &grid;

    use crate::exec::DisjointWriter;

    // ---- Phase 1 (parallel over updates) --------------------------------
    let t_bin = scratch.span_log.start();
    let bins: Bins = match params.cell_list {
        CellList::FanIn => {
            // Counting-sort scatter (see [`CellList::FanIn`]): count,
            // prefix-sum into disjoint offsets, scatter — same
            // histogram machinery as the radix sort, no per-cell Vecs.
            let ncells = grid.ncells;
            let ranges = chunks(upds.len(), nthreads);
            let ranges = &ranges;
            let mut counts = scratch.take_u32();
            counts.resize(nthreads * ncells, 0);
            {
                let cw = DisjointWriter::new(&mut counts[..], "gbm::bin counts");
                let cw = &cw;
                pool.run(nthreads, |p| {
                    // SAFETY: worker p claims exactly counts segment p;
                    // the segments are disjoint by construction.
                    let mut seg = unsafe { cw.claim(p * ncells..(p + 1) * ncells) };
                    for j in ranges[p].clone() {
                        for c in grid.cells(upds.lo[j], upds.hi[j]) {
                            seg[c] += 1;
                        }
                    }
                });
            }
            // Master: per-cell starts + in-place (cell, worker) offsets,
            // cell-major worker-minor — each (cell, worker) pair gets a
            // disjoint slice of the flat array, in ascending update
            // order (workers hold ascending contiguous update ranges).
            let mut starts = scratch.take_u32();
            starts.resize(ncells + 1, 0);
            let mut total = 0u64;
            for c in 0..ncells {
                starts[c] = total as u32;
                for p in 0..nthreads {
                    let cnt = counts[p * ncells + c];
                    counts[p * ncells + c] = total as u32;
                    total += cnt as u64;
                }
            }
            assert!(total <= u32::MAX as u64, "cell-list entries exceed u32 offsets");
            starts[ncells] = total as u32;
            let mut flat = scratch.take_u32();
            flat.resize(total as usize, 0);
            {
                let cw = DisjointWriter::new(&mut counts[..], "gbm::scatter counts");
                let fw = DisjointWriter::new(&mut flat[..], "gbm::scatter flat");
                let (cw, fw) = (&cw, &fw);
                pool.run(nthreads, |p| {
                    // SAFETY: worker p claims exactly counts segment p.
                    let mut seg = unsafe { cw.claim(p * ncells..(p + 1) * ncells) };
                    for j in ranges[p].clone() {
                        for c in grid.cells(upds.lo[j], upds.hi[j]) {
                            // SAFETY: the (cell, worker) offsets
                            // partition 0..total, so every flat slot is
                            // written exactly once.
                            unsafe { fw.write(seg[c] as usize, j as u32) };
                            seg[c] += 1;
                        }
                    }
                });
            }
            Bins::Csr {
                flat,
                starts,
                counts,
            }
        }
        CellList::LockFree => {
            let lists: Vec<LfList<u32>> =
                (0..grid.ncells).map(|_| LfList::new()).collect();
            let ranges = chunks(upds.len(), nthreads);
            pool.run(nthreads, |p| {
                for j in ranges[p].clone() {
                    for c in grid.cells(upds.lo[j], upds.hi[j]) {
                        lists[c].push(j as u32);
                    }
                }
            });
            Bins::Lists(
                lists
                    .iter()
                    .map(|l| l.iter().copied().collect())
                    .collect(),
            )
        }
    };

    scratch.span_log.record(
        crate::obs::Phase::GbmBin,
        crate::obs::trace::MASTER_WORKER,
        t_bin,
        upds.len() as u64,
    );
    let t_scan = scratch.span_log.start();

    // ---- Phase 2 (parallel over subscriptions, independent) -------------
    let ranges = chunks(subs.len(), nthreads);
    let bins_ref = &bins;
    let collected = super::par_collect_with(pool, nthreads, mk, |p, sink: &mut S| {
        let mut res = std::collections::HashSet::new();
        for i in ranges[p].clone() {
            let (slo, shi) = (subs.lo[i], subs.hi[i]);
            if params.dedup == Dedup::ResSet {
                res.clear();
            }
            for c in grid.cells(slo, shi) {
                for &j in bins_ref.cell(c) {
                    let (ulo, uhi) = (upds.lo[j as usize], upds.hi[j as usize]);
                    if slo < uhi && ulo < shi {
                        match params.dedup {
                            Dedup::FirstCell => {
                                if c == grid.cell_of(slo.max(ulo)) {
                                    sink.report(i as u32, j);
                                }
                            }
                            Dedup::ResSet => {
                                if res.insert(j) {
                                    sink.report(i as u32, j);
                                }
                            }
                        }
                    }
                }
            }
        }
    });
    if let Bins::Csr {
        flat,
        starts,
        counts,
    } = bins
    {
        // Take order was counts, starts, flat; the pool is a stack, so
        // giving flat, starts, counts keeps every buffer in the same
        // role next call (stable warm capacities).
        scratch.give_u32_bufs([flat, starts, counts]);
    }
    scratch.span_log.record(
        crate::obs::Phase::GbmScan,
        crate::obs::trace::MASTER_WORKER,
        t_scan,
        subs.len() as u64,
    );
    collected
}

/// [`Matcher`](crate::engine::Matcher) backend for grid-based
/// matching, carrying its grid parameters.
pub struct GbmMatcher {
    params: GbmParams,
    nd: NdPolicy,
}

impl GbmMatcher {
    pub fn new(params: GbmParams) -> Self {
        Self {
            params,
            nd: NdPolicy::default(),
        }
    }

    /// Set the N-D pipeline policy (engine-injected).
    pub fn with_nd(mut self, nd: NdPolicy) -> Self {
        self.nd = nd;
        self
    }

    pub fn params(&self) -> &GbmParams {
        &self.params
    }
}

impl crate::engine::Matcher for GbmMatcher {
    fn name(&self) -> &str {
        "gbm"
    }

    fn match_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    ) {
        let mut guard = ctx.scratch();
        let scratch = &mut *guard;
        let disp =
            crate::core::scratch::SinkDispenser::new(scratch.take_pair_sinks(ctx.nthreads));
        let sinks: Vec<crate::core::sink::VecSink> = match_par_sinks_scratch(
            ctx.pool,
            ctx.nthreads,
            subs,
            upds,
            &self.params,
            scratch,
            |p| disp.take(p),
        );
        scratch.drain_pair_sinks(sinks, disp.into_remaining(), sink);
    }

    fn count_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
    ) -> u64 {
        let mut guard = ctx.scratch();
        let sinks: Vec<crate::core::sink::CountSink> = match_par_sinks_scratch(
            ctx.pool,
            ctx.nthreads,
            subs,
            upds,
            &self.params,
            &mut guard,
            |_p| crate::core::sink::CountSink::default(),
        );
        crate::core::sink::total_count(&sinks)
    }

    fn match_nd(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &RegionsNd,
        upds: &RegionsNd,
        sink: &mut dyn MatchSink,
    ) {
        match self.nd.mode {
            NdMode::Reduction => ddim::ReductionNd::match_nd_with(
                Some(ctx.pool),
                subs,
                upds,
                |s1, u1, out| self.match_1d(ctx, s1, u1, out),
                sink,
            ),
            NdMode::Native => {
                let mut guard = ctx.scratch();
                ddim::native_match(
                    self.nd.sweep,
                    ctx.pool,
                    ctx.nthreads,
                    subs,
                    upds,
                    &mut guard,
                    |s1, u1, scratch, mk| {
                        match_par_sinks_scratch(
                            ctx.pool,
                            ctx.nthreads,
                            s1,
                            u1,
                            &self.params,
                            scratch,
                            mk,
                        )
                    },
                    sink,
                )
            }
        }
    }

    fn count_nd(&self, ctx: &crate::engine::ExecCtx<'_>, subs: &RegionsNd, upds: &RegionsNd) -> u64 {
        match self.nd.mode {
            NdMode::Reduction => {
                let mut sink = crate::core::sink::CountSink::default();
                self.match_nd(ctx, subs, upds, &mut sink);
                sink.count
            }
            NdMode::Native => {
                let mut guard = ctx.scratch();
                ddim::native_count(
                    self.nd.sweep,
                    ctx.pool,
                    ctx.nthreads,
                    subs,
                    upds,
                    &mut guard,
                    |s1, u1, scratch, mk| {
                        match_par_sinks_scratch(
                            ctx.pool,
                            ctx.nthreads,
                            s1,
                            u1,
                            &self.params,
                            scratch,
                            mk,
                        )
                    },
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::bfm;
    use crate::core::interval::Interval;
    use crate::core::region::random_regions_1d;
    use crate::core::sink::{canonical_pairs, canonicalize, VecSink};

    fn bfm_pairs(subs: &Regions1D, upds: &Regions1D) -> Vec<(u32, u32)> {
        let mut want = VecSink::default();
        bfm::match_seq(subs, upds, &mut want);
        canonicalize(want.pairs)
    }

    #[test]
    fn serial_matches_bfm_both_dedups() {
        let mut rng = crate::prng::Rng::new(0x6B);
        let subs = random_regions_1d(&mut rng, 400, 1000.0, 15.0);
        let upds = random_regions_1d(&mut rng, 350, 1000.0, 15.0);
        let want = bfm_pairs(&subs, &upds);
        for dedup in [Dedup::FirstCell, Dedup::ResSet] {
            let params = GbmParams {
                ncells: 37,
                dedup,
                ..Default::default()
            };
            let mut sink = VecSink::default();
            match_seq(&subs, &upds, &params, &mut sink);
            assert_eq!(canonicalize(sink.pairs), want, "{dedup:?}");
        }
    }

    #[test]
    fn parallel_matches_bfm_all_strategies() {
        let pool = ThreadPool::new(3);
        let mut rng = crate::prng::Rng::new(0x6C);
        let subs = random_regions_1d(&mut rng, 300, 500.0, 8.0);
        let upds = random_regions_1d(&mut rng, 300, 500.0, 8.0);
        let want = bfm_pairs(&subs, &upds);
        for cell_list in [CellList::FanIn, CellList::LockFree] {
            for dedup in [Dedup::FirstCell, Dedup::ResSet] {
                let params = GbmParams {
                    ncells: 50,
                    cell_list,
                    dedup,
                };
                let got =
                    canonical_pairs(match_par::<VecSink>(&pool, 4, &subs, &upds, &params));
                assert_eq!(got, want, "{cell_list:?}/{dedup:?}");
            }
        }
    }

    #[test]
    fn ncells_does_not_change_result_property() {
        crate::bench::prop::prop_check("gbm-ncells-invariance", 0x6D, |rng| {
            let n = 1 + rng.below(120) as usize;
            let subs = { let l = rng.uniform(0.5, 50.0); random_regions_1d(rng, n, 200.0, l) };
            let upds = { let l = rng.uniform(0.5, 50.0); random_regions_1d(rng, n, 200.0, l) };
            let want = bfm_pairs(&subs, &upds);
            let ncells = 1 + rng.below(300) as usize;
            let params = GbmParams {
                ncells,
                ..Default::default()
            };
            let mut sink = VecSink::default();
            match_seq(&subs, &upds, &params, &mut sink);
            crate::bench::prop::expect_eq(
                &canonicalize(sink.pairs),
                &want,
                &format!("ncells={ncells}"),
            )
        });
    }

    #[test]
    fn regions_spanning_many_cells() {
        let subs = Regions1D::from_intervals(&[Interval::new(0.0, 100.0)]);
        let upds = Regions1D::from_intervals(&[
            Interval::new(50.0, 51.0),
            Interval::new(0.0, 100.0),
        ]);
        let params = GbmParams {
            ncells: 10,
            ..Default::default()
        };
        let mut sink = VecSink::default();
        match_seq(&subs, &upds, &params, &mut sink);
        assert_eq!(canonicalize(sink.pairs), vec![(0, 0), (0, 1)]);
    }

    #[test]
    fn empty_inputs() {
        let params = GbmParams::default();
        let mut sink = VecSink::default();
        match_seq(&Regions1D::default(), &Regions1D::default(), &params, &mut sink);
        assert!(sink.pairs.is_empty());
        let pool = ThreadPool::new(1);
        let sinks =
            match_par::<VecSink>(&pool, 2, &Regions1D::default(), &Regions1D::default(), &params);
        assert!(canonical_pairs(sinks).is_empty());
    }

    #[test]
    fn single_cell_degenerates_to_bfm() {
        let mut rng = crate::prng::Rng::new(0x6E);
        let subs = random_regions_1d(&mut rng, 50, 100.0, 10.0);
        let upds = random_regions_1d(&mut rng, 50, 100.0, 10.0);
        let params = GbmParams {
            ncells: 1,
            ..Default::default()
        };
        let mut sink = VecSink::default();
        match_seq(&subs, &upds, &params, &mut sink);
        assert_eq!(canonicalize(sink.pairs), bfm_pairs(&subs, &upds));
    }
}

//! Augmented AVL interval tree (paper §3, Fig. 6).
//!
//! Each node stores an interval plus `minlower`/`maxupper` over its
//! subtree — the two fields Algorithm 5's Interval-Query uses to prune
//! irrelevant subtrees. Nodes are kept in an arena (`Vec`) with index
//! links: no per-node allocation on the hot path, cache-friendly
//! traversal, and a free list so deletions recycle slots (dynamic
//! interval management, §3).
//!
//! Ordering key is `(lo, region idx)` so duplicate lower bounds are
//! totally ordered and every region is individually addressable for
//! deletion.

use crate::core::interval::Interval;
use crate::core::Regions1D;

const NIL: i32 = -1;

/// Recursively build the subtree for `range` (sorted-order indices)
/// into implicit slots (`slot = mid`) through the claims layer.
/// Returns the subtree root.
///
/// # Safety
/// No other thread may touch slots inside `range` for the writer's
/// lifetime (each slot of the arena is written exactly once across the
/// whole build — checked under `race-check`).
unsafe fn fill_subtree(
    nodes: &crate::exec::DisjointWriter<'_, Node>,
    regions: &Regions1D,
    order: &[u32],
    range: std::ops::Range<usize>,
) -> i32 {
    if range.is_empty() {
        return NIL;
    }
    let mid = (range.start + range.end) / 2;
    // SAFETY: sub-ranges of an exclusively owned range stay exclusive.
    let (left, right) = unsafe {
        (
            fill_subtree(nodes, regions, order, range.start..mid),
            fill_subtree(nodes, regions, order, mid + 1..range.end),
        )
    };
    // SAFETY: `mid` is inside this thread's range; both children were
    // written by the recursion above.
    unsafe { write_node(nodes, regions, order, mid, left, right) };
    mid as i32
}

/// Write slot `mid` from its (already written) children.
///
/// # Safety
/// Both child slots must already be written through `nodes` (with a
/// happens-before edge to this call) and slot `mid` must be owned by
/// the caller — `race-check` enforces both.
unsafe fn write_node(
    nodes: &crate::exec::DisjointWriter<'_, Node>,
    regions: &Regions1D,
    order: &[u32],
    mid: usize,
    left: i32,
    right: i32,
) {
    let idx = order[mid];
    let (lo, hi) = (regions.lo[idx as usize], regions.hi[idx as usize]);
    let mut height = 0;
    let mut minlower = lo;
    let mut maxupper = hi;
    for c in [left, right] {
        if c != NIL {
            // SAFETY: child slots are written per the caller's
            // contract (read-before-write panics under race-check).
            let cn = unsafe { nodes.read(c as usize) };
            height = height.max(cn.height + 1);
            minlower = minlower.min(cn.minlower);
            maxupper = maxupper.max(cn.maxupper);
        }
    }
    // SAFETY: slot `mid` belongs to this caller alone.
    unsafe {
        nodes.write(
            mid,
            Node {
                lo,
                hi,
                idx,
                left,
                right,
                height,
                minlower,
                maxupper,
            },
        );
    }
}

#[derive(Debug, Clone)]
struct Node {
    lo: f64,
    hi: f64,
    idx: u32,
    left: i32,
    right: i32,
    height: i32,
    minlower: f64,
    maxupper: f64,
}

/// The interval tree.
#[derive(Debug, Clone)]
pub struct IntervalTree {
    nodes: Vec<Node>,
    root: i32,
    free: Vec<u32>,
    len: usize,
}

impl Default for IntervalTree {
    fn default() -> Self {
        Self {
            nodes: Vec::new(),
            root: NIL, // NB: derived Default would yield root = 0
            free: Vec::new(),
            len: 0,
        }
    }
}

impl IntervalTree {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bulk build from a region set: O(n) balanced construction from
    /// the sorted (lo, idx) order. (The paper builds by repeated
    /// insertion in O(n lg n); see `new_by_insertion` for that path —
    /// the bulk build is our perf-pass replacement, same structure
    /// invariants.)
    pub fn from_regions(regions: &Regions1D) -> Self {
        let n = regions.len();
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            let ka = (crate::exec::f64_key(regions.lo[a as usize]), a);
            let kb = (crate::exec::f64_key(regions.lo[b as usize]), b);
            ka.cmp(&kb)
        });
        let mut tree = Self {
            nodes: Vec::with_capacity(n),
            root: NIL,
            free: Vec::new(),
            len: n,
        };
        tree.root = tree.build_balanced(regions, &order);
        tree
    }

    /// Parallel bulk build (perf pass): nodes live at *implicit* slots
    /// (`slot = mid of the node's sorted-order range`), so P workers
    /// can fill disjoint subtrees of a preallocated arena without
    /// synchronization; the master stitches the top ⌈lg P⌉ levels.
    /// Produces the same query semantics as [`Self::from_regions`]
    /// (checked by `builders_agree`); used by parallel ITM, where the
    /// serial build otherwise bounds speedup (EXPERIMENTS.md §Perf).
    pub fn from_regions_par(
        pool: &crate::exec::ThreadPool,
        nthreads: usize,
        regions: &Regions1D,
    ) -> Self {
        let n = regions.len();
        if nthreads <= 1 || n < 4 * nthreads {
            return Self::from_regions(regions);
        }
        let mut order: Vec<u32> = (0..n as u32).collect();
        crate::exec::psort::par_sort_by_key(pool, nthreads, &mut order, |&i| {
            ((crate::exec::f64_key(regions.lo[i as usize]) as u128) << 32) | i as u128
        });

        // Split the order range until we have >= nthreads segments.
        let mut segments: Vec<std::ops::Range<usize>> = vec![0..n];
        while segments.len() < nthreads {
            let mut next = Vec::with_capacity(segments.len() * 2);
            for r in &segments {
                let mid = (r.start + r.end) / 2;
                next.push(r.start..mid);
                next.push(mid + 1..r.end);
            }
            if next.iter().any(|r| r.is_empty()) && next.len() >= nthreads {
                break;
            }
            segments = next;
        }

        let mut nodes: Vec<Node> = vec![
            Node {
                lo: 0.0,
                hi: 0.0,
                idx: 0,
                left: NIL,
                right: NIL,
                height: 0,
                minlower: 0.0,
                maxupper: 0.0,
            };
            n
        ];
        let root;
        {
            let w = crate::exec::DisjointWriter::new(&mut nodes[..], "itree::par build");
            let w = &w;
            let order_ref = &order;
            let segs = &segments;
            pool.run(nthreads.min(segments.len()), |p| {
                let workers = nthreads.min(segs.len());
                let mut s = p;
                while s < segs.len() {
                    // SAFETY: segments are disjoint order-ranges; each
                    // node slot (= an index inside the range) is
                    // written by exactly one worker.
                    unsafe { fill_subtree(w, regions, order_ref, segs[s].clone()) };
                    s += workers;
                }
            });

            // Master: stitch the levels above the segments (the
            // recursion below segment granularity was done by workers).
            fn stitch(
                nodes: &crate::exec::DisjointWriter<'_, Node>,
                regions: &Regions1D,
                order: &[u32],
                range: std::ops::Range<usize>,
                segments: &[std::ops::Range<usize>],
            ) -> i32 {
                if range.is_empty() {
                    return NIL;
                }
                if segments.iter().any(|s| *s == range) {
                    return ((range.start + range.end) / 2) as i32;
                }
                let mid = (range.start + range.end) / 2;
                let left = stitch(nodes, regions, order, range.start..mid, segments);
                let right = stitch(nodes, regions, order, mid + 1..range.end, segments);
                // SAFETY: slot `mid` belongs to no worker segment at
                // this level, and both children were written (by a
                // worker past the join barrier, or by this recursion).
                unsafe { write_node(nodes, regions, order, mid, left, right) };
                mid as i32
            }
            root = pool.serial_section(|| stitch(w, regions, &order, 0..n, &segments));
        }
        Self {
            nodes,
            root,
            free: Vec::new(),
            len: n,
        }
    }

    /// Paper-faithful O(n lg n) build by repeated insertion.
    pub fn new_by_insertion(regions: &Regions1D) -> Self {
        let mut tree = Self::new();
        for i in 0..regions.len() {
            tree.insert(regions.get(i), i as u32);
        }
        tree
    }

    fn build_balanced(&mut self, regions: &Regions1D, order: &[u32]) -> i32 {
        if order.is_empty() {
            return NIL;
        }
        let mid = order.len() / 2;
        let idx = order[mid];
        let iv = regions.get(idx as usize);
        let left = self.build_balanced(regions, &order[..mid]);
        let right = self.build_balanced(regions, &order[mid + 1..]);
        let id = self.nodes.len() as i32;
        self.nodes.push(Node {
            lo: iv.lo,
            hi: iv.hi,
            idx,
            left,
            right,
            height: 0,
            minlower: iv.lo,
            maxupper: iv.hi,
        });
        self.pull(id);
        id
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    // ---- node helpers ---------------------------------------------------

    #[inline]
    fn h(&self, id: i32) -> i32 {
        if id == NIL {
            -1
        } else {
            self.nodes[id as usize].height
        }
    }

    /// Recompute height / minlower / maxupper from children.
    #[inline]
    fn pull(&mut self, id: i32) {
        let (l, r) = {
            let n = &self.nodes[id as usize];
            (n.left, n.right)
        };
        let mut height = 0;
        let n_lo = self.nodes[id as usize].lo;
        let n_hi = self.nodes[id as usize].hi;
        let mut minlower = n_lo;
        let mut maxupper = n_hi;
        for c in [l, r] {
            if c != NIL {
                let cn = &self.nodes[c as usize];
                height = height.max(cn.height + 1);
                minlower = minlower.min(cn.minlower);
                maxupper = maxupper.max(cn.maxupper);
            }
        }
        let n = &mut self.nodes[id as usize];
        n.height = height;
        n.minlower = minlower;
        n.maxupper = maxupper;
    }

    fn rotate_right(&mut self, y: i32) -> i32 {
        let x = self.nodes[y as usize].left;
        let t2 = self.nodes[x as usize].right;
        self.nodes[x as usize].right = y;
        self.nodes[y as usize].left = t2;
        self.pull(y);
        self.pull(x);
        x
    }

    fn rotate_left(&mut self, x: i32) -> i32 {
        let y = self.nodes[x as usize].right;
        let t2 = self.nodes[y as usize].left;
        self.nodes[y as usize].left = x;
        self.nodes[x as usize].right = t2;
        self.pull(x);
        self.pull(y);
        y
    }

    fn rebalance(&mut self, id: i32) -> i32 {
        self.pull(id);
        let bf = self.h(self.nodes[id as usize].left) - self.h(self.nodes[id as usize].right);
        if bf > 1 {
            let l = self.nodes[id as usize].left;
            if self.h(self.nodes[l as usize].left) < self.h(self.nodes[l as usize].right) {
                let nl = self.rotate_left(l);
                self.nodes[id as usize].left = nl;
                self.pull(id);
            }
            self.rotate_right(id)
        } else if bf < -1 {
            let r = self.nodes[id as usize].right;
            if self.h(self.nodes[r as usize].right) < self.h(self.nodes[r as usize].left) {
                let nr = self.rotate_right(r);
                self.nodes[id as usize].right = nr;
                self.pull(id);
            }
            self.rotate_left(id)
        } else {
            id
        }
    }

    #[inline]
    fn key(&self, id: i32) -> (u64, u32) {
        let n = &self.nodes[id as usize];
        (crate::exec::f64_key(n.lo), n.idx)
    }

    fn alloc(&mut self, iv: Interval, idx: u32) -> i32 {
        let node = Node {
            lo: iv.lo,
            hi: iv.hi,
            idx,
            left: NIL,
            right: NIL,
            height: 0,
            minlower: iv.lo,
            maxupper: iv.hi,
        };
        if let Some(slot) = self.free.pop() {
            self.nodes[slot as usize] = node;
            slot as i32
        } else {
            self.nodes.push(node);
            self.nodes.len() as i32 - 1
        }
    }

    // ---- public ops -----------------------------------------------------

    /// Insert region `idx` with interval `iv` — O(lg n).
    pub fn insert(&mut self, iv: Interval, idx: u32) {
        let key = (crate::exec::f64_key(iv.lo), idx);
        let node = self.alloc(iv, idx);
        self.root = self.insert_at(self.root, node, key);
        self.len += 1;
    }

    fn insert_at(&mut self, id: i32, node: i32, key: (u64, u32)) -> i32 {
        if id == NIL {
            return node;
        }
        if key < self.key(id) {
            let nl = self.insert_at(self.nodes[id as usize].left, node, key);
            self.nodes[id as usize].left = nl;
        } else {
            let nr = self.insert_at(self.nodes[id as usize].right, node, key);
            self.nodes[id as usize].right = nr;
        }
        self.rebalance(id)
    }

    /// Remove region `idx` whose current interval is `iv` — O(lg n).
    /// Returns true if found and removed.
    pub fn remove(&mut self, iv: Interval, idx: u32) -> bool {
        let key = (crate::exec::f64_key(iv.lo), idx);
        let mut removed = false;
        self.root = self.remove_at(self.root, key, idx, &mut removed);
        if removed {
            self.len -= 1;
        }
        removed
    }

    fn remove_at(&mut self, id: i32, key: (u64, u32), idx: u32, removed: &mut bool) -> i32 {
        if id == NIL {
            return NIL;
        }
        let nkey = self.key(id);
        if key < nkey {
            let nl = self.remove_at(self.nodes[id as usize].left, key, idx, removed);
            self.nodes[id as usize].left = nl;
        } else if key > nkey {
            let nr = self.remove_at(self.nodes[id as usize].right, key, idx, removed);
            self.nodes[id as usize].right = nr;
        } else {
            debug_assert_eq!(self.nodes[id as usize].idx, idx);
            *removed = true;
            let (l, r) = (self.nodes[id as usize].left, self.nodes[id as usize].right);
            if l == NIL || r == NIL {
                self.free.push(id as u32);
                return if l == NIL { r } else { l };
            }
            // Two children: replace payload with in-order successor,
            // then delete the successor from the right subtree.
            let mut s = r;
            while self.nodes[s as usize].left != NIL {
                s = self.nodes[s as usize].left;
            }
            let (slo, shi, sidx) = {
                let sn = &self.nodes[s as usize];
                (sn.lo, sn.hi, sn.idx)
            };
            let skey = (crate::exec::f64_key(slo), sidx);
            let mut dummy = false;
            let nr = self.remove_at(r, skey, sidx, &mut dummy);
            debug_assert!(dummy);
            let n = &mut self.nodes[id as usize];
            n.lo = slo;
            n.hi = shi;
            n.idx = sidx;
            n.right = nr;
        }
        self.rebalance(id)
    }

    /// Paper Algorithm 5: report every stored interval intersecting
    /// `q` (half-open semantics) exactly once.
    pub fn query(&self, q: Interval, f: &mut dyn FnMut(u32)) {
        self.query_at(self.root, q, f);
    }

    fn query_at(&self, id: i32, q: Interval, f: &mut dyn FnMut(u32)) {
        if id == NIL {
            return;
        }
        let n = &self.nodes[id as usize];
        // Prune: subtree's [minlower, maxupper) cannot touch q.
        if n.maxupper <= q.lo || n.minlower >= q.hi {
            return;
        }
        self.query_at(n.left, q, f);
        if n.lo < q.hi && q.lo < n.hi {
            f(n.idx);
        }
        // Right subtree has lowers >= n.lo; descend only if q extends
        // past this node's lower bound.
        if q.hi > n.lo {
            self.query_at(n.right, q, f);
        }
    }

    /// Collect intersections into a sorted Vec (test convenience).
    pub fn query_vec(&self, q: Interval) -> Vec<u32> {
        let mut out = Vec::new();
        self.query(q, &mut |i| out.push(i));
        out.sort_unstable();
        out
    }

    /// Tree height (root = 0; empty = -1).
    pub fn height(&self) -> i32 {
        self.h(self.root)
    }

    // ---- invariants (tests / property checks) ---------------------------

    /// Validate AVL balance, BST order and augmentation; returns node
    /// count. Panics with a description on violation.
    pub fn check_invariants(&self) -> usize {
        let mut count = 0;
        self.check_at(self.root, None, None, &mut count);
        assert_eq!(count, self.len, "len bookkeeping");
        count
    }

    fn check_at(
        &self,
        id: i32,
        min: Option<(u64, u32)>,
        max: Option<(u64, u32)>,
        count: &mut usize,
    ) -> (i32, f64, f64) {
        if id == NIL {
            return (-1, f64::INFINITY, f64::NEG_INFINITY);
        }
        *count += 1;
        let n = &self.nodes[id as usize];
        let key = self.key(id);
        if let Some(mn) = min {
            assert!(key > mn, "BST order violated");
        }
        if let Some(mx) = max {
            assert!(key < mx, "BST order violated");
        }
        let (hl, minl, maxl) = self.check_at(n.left, min, Some(key), count);
        let (hr, minr, maxr) = self.check_at(n.right, Some(key), max, count);
        assert!((hl - hr).abs() <= 1, "AVL balance violated");
        let h = 1 + hl.max(hr);
        assert_eq!(n.height, h, "height field stale");
        let minlower = n.lo.min(minl).min(minr);
        let maxupper = n.hi.max(maxl).max(maxr);
        assert_eq!(n.minlower, minlower, "minlower stale");
        assert_eq!(n.maxupper, maxupper, "maxupper stale");
        (h, minlower, maxupper)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::region::random_regions_1d;
    use crate::prng::Rng;

    fn brute_query(regions: &Regions1D, q: Interval) -> Vec<u32> {
        (0..regions.len() as u32)
            .filter(|&i| regions.get(i as usize).intersects(&q))
            .collect()
    }

    #[test]
    fn figure6_style_queries() {
        // A handful of intervals with nesting and duplicates.
        let regions = Regions1D::from_intervals(&[
            Interval::new(0.0, 10.0),
            Interval::new(2.0, 3.0),
            Interval::new(2.0, 8.0),
            Interval::new(5.0, 6.0),
            Interval::new(9.0, 12.0),
        ]);
        let t = IntervalTree::from_regions(&regions);
        t.check_invariants();
        assert_eq!(t.query_vec(Interval::new(2.5, 5.5)), vec![0, 1, 2, 3]);
        assert_eq!(t.query_vec(Interval::new(10.0, 11.0)), vec![4]);
        assert_eq!(t.query_vec(Interval::new(100.0, 101.0)), Vec::<u32>::new());
    }

    #[test]
    fn parallel_build_agrees_with_serial() {
        let pool = crate::exec::ThreadPool::new(7);
        let mut rng = Rng::new(0x9A12);
        for n in [1usize, 2, 7, 100, 1000, 4096] {
            let regions = random_regions_1d(&mut rng, n, 1000.0, 10.0);
            let serial = IntervalTree::from_regions(&regions);
            for p in [2usize, 3, 8] {
                let par = IntervalTree::from_regions_par(&pool, p, &regions);
                par.check_invariants();
                for _ in 0..10 {
                    let lo = rng.uniform(0.0, 990.0);
                    let q = Interval::new(lo, lo + rng.uniform(0.0, 20.0));
                    assert_eq!(par.query_vec(q), serial.query_vec(q), "n={n} p={p}");
                }
            }
        }
    }

    #[test]
    fn builders_agree() {
        let mut rng = Rng::new(0x17EE);
        let regions = random_regions_1d(&mut rng, 500, 100.0, 8.0);
        let bulk = IntervalTree::from_regions(&regions);
        let ins = IntervalTree::new_by_insertion(&regions);
        bulk.check_invariants();
        ins.check_invariants();
        for _ in 0..50 {
            let lo = rng.uniform(0.0, 95.0);
            let q = Interval::new(lo, lo + rng.uniform(0.0, 10.0));
            assert_eq!(bulk.query_vec(q), ins.query_vec(q));
        }
    }

    #[test]
    fn query_matches_brute_force_property() {
        crate::bench::prop::prop_check("itree-query-vs-brute", 0x7E, |rng| {
            let n = 1 + rng.below(200) as usize;
            let regions = random_regions_1d(rng, n, 50.0, 6.0);
            let t = IntervalTree::from_regions(&regions);
            t.check_invariants();
            for _ in 0..10 {
                let lo = rng.uniform(0.0, 48.0);
                let q = Interval::new(lo, lo + rng.uniform(0.0, 8.0));
                let got = t.query_vec(q);
                let want = brute_query(&regions, q);
                if got != want {
                    return Err(format!("q={q:?}: got {got:?}, want {want:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn insert_delete_random_sequence_keeps_invariants() {
        crate::bench::prop::prop_check("itree-insert-delete", 0xDE1, |rng| {
            let mut t = IntervalTree::new();
            let mut live: Vec<(Interval, u32)> = Vec::new();
            let mut next_idx = 0u32;
            for _ in 0..300 {
                if live.is_empty() || rng.chance(0.6) {
                    let lo = rng.uniform(0.0, 100.0);
                    let iv = Interval::new(lo, lo + rng.uniform(0.0, 10.0));
                    t.insert(iv, next_idx);
                    live.push((iv, next_idx));
                    next_idx += 1;
                } else {
                    let k = rng.below(live.len() as u64) as usize;
                    let (iv, idx) = live.swap_remove(k);
                    if !t.remove(iv, idx) {
                        return Err(format!("failed to remove idx {idx}"));
                    }
                }
                t.check_invariants();
            }
            // Final query cross-check against the live list.
            let q = Interval::new(20.0, 40.0);
            let mut want: Vec<u32> = live
                .iter()
                .filter(|(iv, _)| iv.intersects(&q))
                .map(|&(_, i)| i)
                .collect();
            want.sort_unstable();
            crate::bench::prop::expect_eq(&t.query_vec(q), &want, "query after churn")
        });
    }

    #[test]
    fn removing_absent_returns_false() {
        let mut t = IntervalTree::new();
        t.insert(Interval::new(0.0, 1.0), 0);
        assert!(!t.remove(Interval::new(0.0, 1.0), 99));
        assert!(t.remove(Interval::new(0.0, 1.0), 0));
        assert!(t.is_empty());
    }

    #[test]
    fn height_is_logarithmic() {
        let mut rng = Rng::new(1);
        let regions = random_regions_1d(&mut rng, 10_000, 1e6, 10.0);
        let t = IntervalTree::from_regions(&regions);
        // AVL height bound: 1.44 lg(n+2); bulk build is near-perfect.
        assert!(t.height() <= 20, "height {} too large", t.height());
    }

    #[test]
    fn touching_intervals_not_reported() {
        let regions = Regions1D::from_intervals(&[Interval::new(0.0, 5.0)]);
        let t = IntervalTree::from_regions(&regions);
        assert!(t.query_vec(Interval::new(5.0, 6.0)).is_empty());
        assert_eq!(t.query_vec(Interval::new(4.999, 6.0)), vec![0]);
    }
}

//! Interval Tree Matching (paper Algorithm 5, §3).
//!
//! Build an interval tree over the subscription set, then query it with
//! every update region. Queries are read-only, so the loop over update
//! regions parallelizes freely; per-query work varies with K_u, so we
//! use dynamic scheduling (the OpenMP runtime does the same with its
//! default chunking when the static schedule is imbalanced).
//!
//! The role swap the paper describes (build the tree on the *smaller*
//! set) is implemented in [`match_par`].

use crate::core::ddim::{self, NdMode, NdPolicy};
use crate::core::sink::MatchSink;
use crate::core::{Regions1D, RegionsNd};
use crate::exec::ThreadPool;

use super::interval_tree::IntervalTree;
use super::{par_collect, par_collect_with};

/// Dynamic-schedule chunk: big enough to amortize the cursor CAS,
/// small enough to balance skewed K_u.
const QUERY_CHUNK: usize = 64;

/// Serial ITM (tree on S, query with every u).
pub fn match_seq(subs: &Regions1D, upds: &Regions1D, sink: &mut dyn MatchSink) {
    let tree = IntervalTree::from_regions(subs);
    for j in 0..upds.len() {
        let q = upds.get(j);
        tree.query(q, &mut |i| sink.report(i, j as u32));
    }
}

/// Parallel ITM (Algorithm 5's `for all u in parallel`), with the
/// smaller-set build optimization.
pub fn match_par<S>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
) -> Vec<S>
where
    S: MatchSink + Default,
{
    match_par_sinks(pool, nthreads, subs, upds, |_p| S::default())
}

/// [`match_par`] with a per-worker sink factory (worker `p` reports
/// into `mk(p)`) — how the native N-D path wraps every worker's sink
/// in a [`FilterSink`](crate::core::sink::FilterSink).
pub fn match_par_sinks<S, M>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    mk: M,
) -> Vec<S>
where
    S: MatchSink,
    M: Fn(usize) -> S + Sync,
{
    // Build on the smaller side: tree height and build time drop, the
    // parallel query loop grows — strictly more parallel work.
    let swap = upds.len() < subs.len();
    let (tree_side, query_side) = if swap { (upds, subs) } else { (subs, upds) };
    let tree = IntervalTree::from_regions_par(pool, nthreads, tree_side);

    // One sink per worker; queries pulled via a shared dynamic cursor
    // (per-query work K_u is skewed, so static chunks would imbalance).
    let cursor = crate::exec::pool::WorkCounter::new();
    let collected = par_collect_with(pool, nthreads, mk, |_p, sink: &mut S| {
        while let Some(r) = cursor.next_chunk(QUERY_CHUNK, query_side.len()) {
            for j in r {
                let q = query_side.get(j);
                if swap {
                    // tree holds updates; j indexes subscriptions
                    tree.query(q, &mut |u| sink.report(j as u32, u));
                } else {
                    tree.query(q, &mut |s| sink.report(s, j as u32));
                }
            }
        }
    });
    collected
}

/// Parallel ITM with a *static* schedule (no role swap) — the
/// scheduling ablation's comparison point against the dynamic default.
pub fn match_par_static<S>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
) -> Vec<S>
where
    S: MatchSink + Default,
{
    let tree = IntervalTree::from_regions(subs);
    let tree = &tree;
    let ranges = crate::exec::pfor::chunks(upds.len(), nthreads);
    par_collect(pool, nthreads, |p, sink: &mut S| {
        for j in ranges[p].clone() {
            let q = upds.get(j);
            tree.query(q, &mut |s| sink.report(s, j as u32));
        }
    })
}

/// [`Matcher`](crate::engine::Matcher) backend for interval-tree
/// matching. The ITM family is the one with a native incremental
/// index, so [`make_dynamic`](crate::engine::Matcher::make_dynamic)
/// returns the interval-tree index instead of the rebuild adapter.
#[derive(Default)]
pub struct ItmMatcher {
    nd: NdPolicy,
}

impl ItmMatcher {
    /// Set the N-D pipeline policy (engine-injected).
    pub fn with_nd(mut self, nd: NdPolicy) -> Self {
        self.nd = nd;
        self
    }
}

impl crate::engine::Matcher for ItmMatcher {
    fn name(&self) -> &str {
        "itm"
    }

    fn match_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    ) {
        let sinks: Vec<crate::core::sink::VecSink> =
            match_par(ctx.pool, ctx.nthreads, subs, upds);
        crate::core::sink::replay(sinks, sink);
    }

    fn count_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
    ) -> u64 {
        let sinks: Vec<crate::core::sink::CountSink> =
            match_par(ctx.pool, ctx.nthreads, subs, upds);
        crate::core::sink::total_count(&sinks)
    }

    fn match_nd(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &RegionsNd,
        upds: &RegionsNd,
        sink: &mut dyn MatchSink,
    ) {
        match self.nd.mode {
            NdMode::Reduction => ddim::ReductionNd::match_nd_with(
                Some(ctx.pool),
                subs,
                upds,
                |s1, u1, out| self.match_1d(ctx, s1, u1, out),
                sink,
            ),
            NdMode::Native => {
                let mut guard = ctx.scratch();
                ddim::native_match(
                    self.nd.sweep,
                    ctx.pool,
                    ctx.nthreads,
                    subs,
                    upds,
                    &mut guard,
                    // ITM has no sort/binning buffers; only the pooled
                    // per-worker pair sinks ride the scratch.
                    |s1, u1, _scratch, mk| match_par_sinks(ctx.pool, ctx.nthreads, s1, u1, mk),
                    sink,
                )
            }
        }
    }

    fn count_nd(&self, ctx: &crate::engine::ExecCtx<'_>, subs: &RegionsNd, upds: &RegionsNd) -> u64 {
        match self.nd.mode {
            NdMode::Reduction => {
                let mut sink = crate::core::sink::CountSink::default();
                self.match_nd(ctx, subs, upds, &mut sink);
                sink.count
            }
            NdMode::Native => {
                let mut guard = ctx.scratch();
                ddim::native_count(
                    self.nd.sweep,
                    ctx.pool,
                    ctx.nthreads,
                    subs,
                    upds,
                    &mut guard,
                    |s1, u1, _scratch, mk| match_par_sinks(ctx.pool, ctx.nthreads, s1, u1, mk),
                )
            }
        }
    }

    fn make_dynamic(&self) -> Option<Box<dyn crate::engine::DynamicMatcher>> {
        Some(Box::new(super::dynamic::TreeIndex::new()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::bfm;
    use crate::core::region::random_regions_1d;
    use crate::core::sink::{canonical_pairs, canonicalize, VecSink};

    #[test]
    fn matches_bfm_serial() {
        let mut rng = crate::prng::Rng::new(0x11);
        let subs = random_regions_1d(&mut rng, 300, 500.0, 5.0);
        let upds = random_regions_1d(&mut rng, 200, 500.0, 5.0);
        let mut want = VecSink::default();
        bfm::match_seq(&subs, &upds, &mut want);
        let mut got = VecSink::default();
        match_seq(&subs, &upds, &mut got);
        assert_eq!(canonicalize(got.pairs), canonicalize(want.pairs));
    }

    #[test]
    fn parallel_matches_serial_all_p_and_swaps() {
        let pool = ThreadPool::new(7);
        let mut rng = crate::prng::Rng::new(0x12);
        // m << n triggers the role swap.
        let subs = random_regions_1d(&mut rng, 600, 500.0, 5.0);
        let upds = random_regions_1d(&mut rng, 50, 500.0, 5.0);
        let mut want = VecSink::default();
        bfm::match_seq(&subs, &upds, &mut want);
        let want = canonicalize(want.pairs);
        for p in 1..=8 {
            let got = canonical_pairs(match_par::<VecSink>(&pool, p, &subs, &upds));
            assert_eq!(got, want, "p={p}");
        }
        // n << m: no swap.
        let subs2 = random_regions_1d(&mut rng, 50, 500.0, 5.0);
        let upds2 = random_regions_1d(&mut rng, 600, 500.0, 5.0);
        let mut want2 = VecSink::default();
        bfm::match_seq(&subs2, &upds2, &mut want2);
        let got2 = canonical_pairs(match_par::<VecSink>(&pool, 4, &subs2, &upds2));
        assert_eq!(got2, canonicalize(want2.pairs));
    }

    #[test]
    fn static_variant_agrees() {
        let pool = ThreadPool::new(3);
        let mut rng = crate::prng::Rng::new(0x13);
        let subs = random_regions_1d(&mut rng, 200, 100.0, 3.0);
        let upds = random_regions_1d(&mut rng, 150, 100.0, 3.0);
        let a = canonical_pairs(match_par::<VecSink>(&pool, 4, &subs, &upds));
        let b = canonical_pairs(match_par_static::<VecSink>(&pool, 4, &subs, &upds));
        assert_eq!(a, b);
    }

    #[test]
    fn empty_inputs() {
        let pool = ThreadPool::new(1);
        let got = canonical_pairs(match_par::<VecSink>(
            &pool,
            2,
            &Regions1D::default(),
            &Regions1D::default(),
        ));
        assert!(got.is_empty());
    }
}

//! The matching algorithms (paper §2–§4).
//!
//! * [`bfm`] — Brute-Force Matching (Algorithm 2), serial + parallel.
//! * [`gbm`] — Grid-Based Matching (Algorithm 3), serial + parallel,
//!   with selectable cell-list synchronization and dedup strategies.
//! * [`interval_tree`] — the augmented AVL interval tree of §3.
//! * [`itm`] — Interval Tree Matching (Algorithm 5), parallel queries.
//! * [`sbm`] — Sort-Based Matching (Algorithm 4), the sequential
//!   state of the art the paper starts from.
//! * [`psbm`] — **Parallel SBM** (Algorithms 6+7), the paper's main
//!   contribution.
//! * [`sbm_binary`] — the binary-search-enhanced SBM baseline in the
//!   spirit of Li et al. [38].
//! * [`dynamic`] — dynamic interval management (§3's two-tree scheme).

pub mod bfm;
pub mod dynamic;
pub mod gbm;
pub mod interval_tree;
pub mod itm;
pub mod psbm;
pub mod sbm;
pub mod sbm_binary;

use crate::core::ddim::NdPolicy;
use crate::core::sink::{CountSink, MatchSink, VecSink};
use crate::core::Regions1D;
use crate::exec::ThreadPool;
use crate::sets::SetImpl;

/// Run `f(p, &mut sink)` on `nthreads` workers, each with a sink built
/// by `mk(p)`, and return the sinks in worker order. Built on
/// [`ThreadPool::fan_map`]: indexed slots, no locks, deterministic
/// order by construction. The factory form lets the native N-D path
/// hand every worker a [`FilterSink`](crate::core::sink::FilterSink)
/// wrapping its private collection sink, so residual-dimension
/// verification runs *inside* the parallel region.
pub fn par_collect_with<S, M, F>(pool: &ThreadPool, nthreads: usize, mk: M, f: F) -> Vec<S>
where
    S: MatchSink,
    M: Fn(usize) -> S + Sync,
    F: Fn(usize, &mut S) + Sync,
{
    pool.fan_map(nthreads, nthreads, |p| {
        let mut sink = mk(p);
        f(p, &mut sink);
        sink
    })
}

/// [`par_collect_with`] over default-constructed sinks — the common
/// per-worker collection helper of the parallel matchers.
pub fn par_collect<S, F>(pool: &ThreadPool, nthreads: usize, f: F) -> Vec<S>
where
    S: MatchSink + Default,
    F: Fn(usize, &mut S) + Sync,
{
    par_collect_with(pool, nthreads, |_p| S::default(), f)
}

/// Algorithm selector used by the CLI, coordinator and benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    Bfm,
    Gbm,
    Itm,
    Sbm,
    Psbm,
    SbmBinary,
}

impl Algo {
    pub const ALL: [Algo; 6] = [
        Algo::Bfm,
        Algo::Gbm,
        Algo::Itm,
        Algo::Sbm,
        Algo::Psbm,
        Algo::SbmBinary,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Algo::Bfm => "bfm",
            Algo::Gbm => "gbm",
            Algo::Itm => "itm",
            Algo::Sbm => "sbm",
            Algo::Psbm => "psbm",
            Algo::SbmBinary => "sbm-binary",
        }
    }

    /// Every accepted spelling (lower-case canonical form; parsing is
    /// ASCII-case-insensitive) — the single source of truth for
    /// [`FromStr`](std::str::FromStr), error messages and tests.
    pub const ALIASES: [(&'static str, Algo); 18] = [
        ("bfm", Algo::Bfm),
        ("brute", Algo::Bfm),
        ("bruteforce", Algo::Bfm),
        ("brute-force", Algo::Bfm),
        ("gbm", Algo::Gbm),
        ("grid", Algo::Gbm),
        ("grid-based", Algo::Gbm),
        ("itm", Algo::Itm),
        ("tree", Algo::Itm),
        ("interval-tree", Algo::Itm),
        ("sbm", Algo::Sbm),
        ("sort", Algo::Sbm),
        ("sort-based", Algo::Sbm),
        ("psbm", Algo::Psbm),
        ("parallel-sbm", Algo::Psbm),
        ("sbm-par", Algo::Psbm),
        ("sbm-binary", Algo::SbmBinary),
        ("binary", Algo::SbmBinary),
    ];
}

impl std::str::FromStr for Algo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let t = s.trim();
        for (name, algo) in Algo::ALIASES {
            if t.eq_ignore_ascii_case(name) {
                return Ok(algo);
            }
        }
        let valid: Vec<&str> = Algo::ALL.iter().map(|a| a.name()).collect();
        let aliases: Vec<&str> = Algo::ALIASES
            .iter()
            .map(|&(n, _)| n)
            .filter(|n| !valid.contains(n))
            .collect();
        Err(format!(
            "unknown algorithm '{t}' (valid: {}; aliases: {})",
            valid.join(", "),
            aliases.join(", ")
        ))
    }
}

/// Knobs shared by the 1-D matchers (everything the
/// [`EngineBuilder`](crate::engine::EngineBuilder) tunes).
#[derive(Debug, Clone, Copy)]
pub struct MatchParams {
    /// GBM: number of grid cells (paper: user-provided, e.g. 3000).
    pub ncells: usize,
    /// SBM/PSBM active-set implementation (paper §5 study).
    pub set_impl: SetImpl,
    /// GBM phase-1 cell-list synchronization strategy.
    pub cell_list: gbm::CellList,
    /// GBM phase-2 duplicate-suppression strategy.
    pub dedup: gbm::Dedup,
    /// N-D pipeline policy: native sweep-and-verify vs per-dimension
    /// reduction, and the sweep-dimension choice
    /// ([`crate::core::ddim`]).
    pub nd: NdPolicy,
    /// SBM/PSBM endpoint sort: compact-key radix (default) or the
    /// merge-path comparison fallback ([`crate::exec::radix`]; CLI
    /// `--sort radix|merge`).
    pub sort: crate::exec::SortAlgo,
    /// Capture phase spans ([`crate::obs`]) during matching. Off by
    /// default: the disabled path is a branch per phase — no clock
    /// read, no write, no allocation.
    pub trace: bool,
}

impl MatchParams {
    /// The GBM parameter block this configuration implies.
    pub fn gbm(&self) -> gbm::GbmParams {
        gbm::GbmParams {
            ncells: self.ncells,
            cell_list: self.cell_list,
            dedup: self.dedup,
        }
    }
}

impl Default for MatchParams {
    fn default() -> Self {
        Self {
            ncells: 3000,
            set_impl: SetImpl::Sparse,
            cell_list: gbm::CellList::default(),
            dedup: gbm::Dedup::default(),
            nd: NdPolicy::default(),
            sort: crate::exec::SortAlgo::default(),
            trace: false,
        }
    }
}

/// Count intersections with `algo` using `nthreads` workers.
#[deprecated(
    since = "0.2.0",
    note = "use `DdmEngine::builder().algo(..).build().count_1d(..)` (crate::engine)"
)]
pub fn run_count(
    algo: Algo,
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    params: &MatchParams,
) -> u64 {
    #[allow(deprecated)]
    let sinks: Vec<CountSink> = run_collect(algo, pool, nthreads, subs, upds, params);
    crate::core::sink::total_count(&sinks)
}

/// Run `algo` collecting per-worker sinks of type `S`.
#[deprecated(
    since = "0.2.0",
    note = "use `DdmEngine::match_1d` with a sink, or the module-level match functions"
)]
pub fn run_collect<S>(
    algo: Algo,
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    params: &MatchParams,
) -> Vec<S>
where
    S: MatchSink + Default,
{
    match algo {
        Algo::Bfm => bfm::match_par(pool, nthreads, subs, upds),
        Algo::Gbm => gbm::match_par(pool, nthreads, subs, upds, &params.gbm()),
        Algo::Itm => itm::match_par(pool, nthreads, subs, upds),
        Algo::Sbm => {
            // Intrinsically serial baseline (the paper's Algorithm 4);
            // runs on one thread regardless of nthreads.
            vec![sbm::match_seq_with(params.set_impl, subs, upds)]
        }
        Algo::Psbm => psbm::match_par_with(params.set_impl, pool, nthreads, subs, upds),
        Algo::SbmBinary => sbm_binary::match_par(pool, nthreads, subs, upds),
    }
}

/// Canonical pair list for `algo` (test helper).
#[deprecated(
    since = "0.2.0",
    note = "use `DdmEngine::builder().algo(..).build().pairs_1d(..)` (crate::engine)"
)]
pub fn run_pairs(
    algo: Algo,
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    params: &MatchParams,
) -> crate::core::sink::PairVec {
    #[allow(deprecated)]
    let sinks: Vec<VecSink> = run_collect(algo, pool, nthreads, subs, upds, params);
    crate::core::sink::canonical_pairs(sinks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_parse_roundtrip() {
        for a in Algo::ALL {
            assert_eq!(a.name().parse::<Algo>().unwrap(), a);
        }
        assert!("nope".parse::<Algo>().is_err());
    }

    #[test]
    fn algo_parse_long_aliases() {
        assert_eq!("interval-tree".parse::<Algo>().unwrap(), Algo::Itm);
        assert_eq!("grid-based".parse::<Algo>().unwrap(), Algo::Gbm);
        assert_eq!("sort-based".parse::<Algo>().unwrap(), Algo::Sbm);
        assert_eq!("brute-force".parse::<Algo>().unwrap(), Algo::Bfm);
        assert_eq!("Interval-Tree".parse::<Algo>().unwrap(), Algo::Itm);
    }

    #[test]
    fn every_listed_alias_parses_case_insensitively() {
        for (name, want) in Algo::ALIASES {
            assert_eq!(name.parse::<Algo>().unwrap(), want, "{name}");
            let upper = name.to_ascii_uppercase();
            assert_eq!(upper.parse::<Algo>().unwrap(), want, "{upper}");
            let mut mixed = name.to_string();
            mixed[..1].make_ascii_uppercase();
            assert_eq!(mixed.parse::<Algo>().unwrap(), want, "{mixed}");
            // Surrounding whitespace is tolerated (CLI/config input).
            assert_eq!(format!(" {name} ").parse::<Algo>().unwrap(), want);
        }
        // Canonical names are themselves aliases.
        for a in Algo::ALL {
            assert!(Algo::ALIASES.iter().any(|&(n, b)| n == a.name() && b == a));
        }
    }

    #[test]
    fn algo_parse_error_lists_every_spelling() {
        let err = "frobnicate".parse::<Algo>().unwrap_err();
        for (alias, _) in Algo::ALIASES {
            assert!(err.contains(alias), "error should list {alias}: {err}");
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_work() {
        use crate::core::region::random_regions_1d;
        let pool = ThreadPool::new(1);
        let mut rng = crate::prng::Rng::new(0xA0);
        let subs = random_regions_1d(&mut rng, 80, 100.0, 5.0);
        let upds = random_regions_1d(&mut rng, 80, 100.0, 5.0);
        let params = MatchParams::default();
        let want = crate::engine::DdmEngine::builder()
            .algo(Algo::Psbm)
            .threads(2)
            .build()
            .pairs_1d(&subs, &upds);
        assert_eq!(run_pairs(Algo::Psbm, &pool, 2, &subs, &upds, &params), want);
        assert_eq!(
            run_count(Algo::Psbm, &pool, 2, &subs, &upds, &params),
            want.len() as u64
        );
    }

    #[test]
    fn par_collect_orders_by_worker() {
        let pool = ThreadPool::new(3);
        let sinks: Vec<VecSink> = par_collect(&pool, 4, |p, sink: &mut VecSink| {
            sink.report(p as u32, 0);
        });
        let firsts: Vec<u32> = sinks.iter().map(|s| s.pairs[0].0).collect();
        assert_eq!(firsts, vec![0, 1, 2, 3]);
    }
}

//! **Parallel Sort-Based Matching** (paper §4, Algorithms 6 + 7) — the
//! paper's main contribution.
//!
//! Three phases:
//!
//! 1. Build and sort the endpoint array in parallel — built in
//!    canonical order into a reusable scratch buffer
//!    ([`crate::core::endpoint`], [`crate::core::scratch`]), then
//!    sorted by the compact `u64` key with the parallel LSD radix sort
//!    ([`crate::exec::radix`]; `--sort merge` selects the
//!    [`crate::exec::psort`] comparison fallback).
//! 2. Initialize per-segment active sets with a prefix computation:
//!    every worker scans its segment recording the *delta* it would
//!    apply to SubSet/UpdSet (`Sadd/Sdel/Uadd/Udel`, Algorithm 7
//!    lines 1–17, invariants (1)–(2) of §4), then the master combines
//!    the deltas serially (lines 18–21; the O(N/P + P) two-level scan
//!    of Fig. 7). Deltas are collected through
//!    [`ThreadPool::fan_map`] — indexed slots, no locks, segment order
//!    by construction.
//! 3. Every worker sweeps its segment with its private, correctly
//!    initialized SubSet/UpdSet (Algorithm 6), reporting into a
//!    per-worker sink — zero synchronization on the hot path (the
//!    init sets are *moved* to their segment's worker via
//!    [`ThreadPool::fan_map_take`]).
//!
//! The result is bit-identical to serial SBM for every thread count
//! (property-tested below, including the half-open tie-breaking).
//!
//! For d dimensions `PsbmMatcher` overrides
//! [`match_nd`](crate::engine::Matcher::match_nd) with the native
//! sweep-and-verify pipeline ([`crate::core::ddim`]): only the chosen
//! sweep dimension is swept, and each worker's sink is wrapped in a
//! [`FilterSink`](crate::core::sink::FilterSink) that verifies the residual dimensions inline.

use crate::core::ddim::{self, NdMode, NdPolicy};
use crate::core::endpoint::{endpoint_slot, sort_endpoints};
use crate::core::scratch::{MatchScratch, SinkDispenser};
use crate::core::sink::MatchSink;
use crate::core::{Regions1D, RegionsNd};
use crate::exec::pfor::chunks;
use crate::exec::{SortAlgo, ThreadPool};
use crate::sets::{
    ActiveSet, BTreeActiveSet, BitSet, HashActiveSet, SetImpl, SortedVecSet, SparseSet,
};

use super::sbm::{sweep, Endpoint};

/// Per-segment delta (Algorithm 7 invariants):
/// * `sadd`/`uadd` — regions whose lower endpoint is in the segment but
///   whose upper endpoint is not (they *stay* active);
/// * `sdel`/`udel` — regions whose upper endpoint is in the segment but
///   whose lower endpoint is not (they *cease* to be active).
struct Delta<Set> {
    sadd: Set,
    sdel: Set,
    uadd: Set,
    udel: Set,
}

/// Scan one segment computing its delta (Algorithm 7 lines 2–17).
fn segment_delta<Set: ActiveSet>(
    endpoints: &[Endpoint],
    n_subs: usize,
    n_upds: usize,
) -> Delta<Set> {
    let mut d = Delta {
        sadd: Set::with_universe(n_subs),
        sdel: Set::with_universe(n_subs),
        uadd: Set::with_universe(n_upds),
        udel: Set::with_universe(n_upds),
    };
    for &e in endpoints {
        let idx = e.idx();
        let (add, del) = if e.is_update() {
            (&mut d.uadd, &mut d.udel)
        } else {
            (&mut d.sadd, &mut d.sdel)
        };
        if !e.is_upper() {
            add.insert(idx);
        } else if add.contains(idx) {
            add.remove(idx);
        } else {
            del.insert(idx);
        }
    }
    d
}

/// Parallel SBM, generic over the active-set implementation.
pub fn match_par<Set, S>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
) -> Vec<S>
where
    Set: ActiveSet,
    S: MatchSink + Default,
{
    match_par_sinks::<Set, S, _>(pool, nthreads, subs, upds, |_p| S::default())
}

/// [`match_par`] with a per-worker sink factory: worker `p` reports
/// into `mk(p)`. The native N-D path hands every worker a
/// [`FilterSink`](crate::core::sink::FilterSink) here, so residual-dimension verification happens
/// inside the parallel sweep.
pub fn match_par_sinks<Set, S, M>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    mk: M,
) -> Vec<S>
where
    Set: ActiveSet,
    S: MatchSink,
    M: Fn(usize) -> S + Sync,
{
    match_par_sinks_scratch::<Set, S, M>(
        pool,
        nthreads,
        SortAlgo::default(),
        subs,
        upds,
        &mut MatchScratch::new(),
        mk,
    )
}

/// [`match_par_sinks`] over a caller-owned [`MatchScratch`] and an
/// explicit sort selection: the endpoint array, the radix ping-pong
/// buffer and the histogram block are all reused across calls, so the
/// warm path allocates nothing in phases 1a/1b.
pub fn match_par_sinks_scratch<Set, S, M>(
    pool: &ThreadPool,
    nthreads: usize,
    sort: SortAlgo,
    subs: &Regions1D,
    upds: &Regions1D,
    scratch: &mut MatchScratch,
    mk: M,
) -> Vec<S>
where
    Set: ActiveSet,
    S: MatchSink,
    M: Fn(usize) -> S + Sync,
{
    let (n, m) = (subs.len(), upds.len());
    let total = 2 * (n + m);

    let MatchScratch {
        endpoints,
        aux,
        radix,
        span_log,
        ..
    } = scratch;
    let t_sort = span_log.start();

    // ---- Phase 1a: build the endpoint array in parallel -----------------
    // Canonical build order (uppers before lowers, subscriptions
    // before updates, ascending idx — `endpoint_slot`): the stable
    // radix sort's tie-break is exactly this input order. No clear()
    // first: a warm same-size call makes resize a no-op (every slot is
    // overwritten below), so the buffer is not redundantly memset.
    endpoints.resize(total, Endpoint::default());
    {
        let dw = crate::exec::DisjointWriter::new(&mut endpoints[..], "psbm::endpoint build");
        let dw = &dw;
        let sub_ranges = chunks(n, nthreads);
        let upd_ranges = chunks(m, nthreads);
        let (sub_ranges, upd_ranges) = (&sub_ranges, &upd_ranges);
        pool.run(nthreads, |p| {
            for i in sub_ranges[p].clone() {
                // SAFETY: `endpoint_slot` maps each (region, side,
                // kind) to a distinct slot and each region belongs to
                // exactly one worker, so all writes are disjoint.
                unsafe {
                    dw.write(
                        endpoint_slot(n, m, i, true, false),
                        Endpoint::new(subs.hi[i], i as u32, true, false),
                    );
                    dw.write(
                        endpoint_slot(n, m, i, false, false),
                        Endpoint::new(subs.lo[i], i as u32, false, false),
                    );
                }
            }
            for j in upd_ranges[p].clone() {
                // SAFETY: as above — slots are distinct per (region,
                // side, kind) and regions are partitioned by worker.
                unsafe {
                    dw.write(
                        endpoint_slot(n, m, j, true, true),
                        Endpoint::new(upds.hi[j], j as u32, true, true),
                    );
                    dw.write(
                        endpoint_slot(n, m, j, false, true),
                        Endpoint::new(upds.lo[j], j as u32, false, true),
                    );
                }
            }
        });
    }

    // ---- Phase 1b: parallel sort (Algorithm 6 line 4) -------------------
    sort_endpoints(Some((pool, nthreads)), endpoints, aux, radix, sort);
    // The Sort span covers build + sort: the fork-join region timed
    // from the master lane, items = endpoints sorted.
    span_log.record(crate::obs::Phase::Sort, crate::obs::trace::MASTER_WORKER, t_sort, total as u64);
    let t_sweep = span_log.start();

    // ---- Phase 2: per-segment deltas + master combine (Algorithm 7) -----
    let segments = chunks(total, nthreads);
    let endpoints_ref: &[Endpoint] = endpoints;
    let segments_ref = &segments;
    let deltas: Vec<Delta<Set>> = pool.fan_map(nthreads, nthreads, |p| {
        segment_delta::<Set>(&endpoints_ref[segments_ref[p].clone()], n, m)
    });

    // Master-only combine (Algorithm 7 lines 18–21): SubSet[p] =
    // SubSet[p-1] ∪ Sadd[p-1] \ Sdel[p-1], likewise UpdSet.
    let init_sets: Vec<(Set, Set)> = pool.serial_section(|| {
        let mut out = Vec::with_capacity(nthreads);
        let mut sub = Set::with_universe(n);
        let mut upd = Set::with_universe(m);
        for d in &deltas {
            out.push((sub.clone(), upd.clone()));
            sub.union_with(&d.sadd);
            sub.subtract(&d.sdel);
            upd.union_with(&d.uadd);
            upd.subtract(&d.udel);
        }
        out
    });

    // ---- Phase 3: per-segment sweeps (Algorithm 6 lines 7–20) -----------
    // Each segment's init sets are moved into the worker that claims
    // it — no locks, no clones, slot order by construction.
    let sinks = pool.fan_map_take(nthreads, init_sets, |p, (mut sub_set, mut upd_set)| {
        let mut sink = mk(p);
        sweep(
            &endpoints_ref[segments_ref[p].clone()],
            &mut sub_set,
            &mut upd_set,
            &mut sink,
        );
        sink
    });
    // Sweep span = phases 2+3 (delta init + per-segment sweeps), the
    // whole fork-join region timed from the master lane.
    span_log.record(crate::obs::Phase::Sweep, crate::obs::trace::MASTER_WORKER, t_sweep, total as u64);
    sinks
}

/// Runtime-dispatched Parallel SBM.
pub fn match_par_with<S>(
    set_impl: SetImpl,
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
) -> Vec<S>
where
    S: MatchSink + Default,
{
    match_par_sinks_with(set_impl, pool, nthreads, subs, upds, |_p| S::default())
}

/// Runtime-dispatched [`match_par_sinks`].
pub fn match_par_sinks_with<S, M>(
    set_impl: SetImpl,
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    mk: M,
) -> Vec<S>
where
    S: MatchSink,
    M: Fn(usize) -> S + Sync,
{
    match_par_sinks_scratch_with(
        set_impl,
        SortAlgo::default(),
        pool,
        nthreads,
        subs,
        upds,
        &mut MatchScratch::new(),
        mk,
    )
}

/// Runtime-dispatched [`match_par_sinks_scratch`].
#[allow(clippy::too_many_arguments)]
pub fn match_par_sinks_scratch_with<S, M>(
    set_impl: SetImpl,
    sort: SortAlgo,
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
    scratch: &mut MatchScratch,
    mk: M,
) -> Vec<S>
where
    S: MatchSink,
    M: Fn(usize) -> S + Sync,
{
    match set_impl {
        SetImpl::Bit => {
            match_par_sinks_scratch::<BitSet, S, M>(pool, nthreads, sort, subs, upds, scratch, mk)
        }
        SetImpl::Hash => match_par_sinks_scratch::<HashActiveSet, S, M>(
            pool, nthreads, sort, subs, upds, scratch, mk,
        ),
        SetImpl::BTree => match_par_sinks_scratch::<BTreeActiveSet, S, M>(
            pool, nthreads, sort, subs, upds, scratch, mk,
        ),
        SetImpl::SortedVec => match_par_sinks_scratch::<SortedVecSet, S, M>(
            pool, nthreads, sort, subs, upds, scratch, mk,
        ),
        SetImpl::Sparse => match_par_sinks_scratch::<SparseSet, S, M>(
            pool, nthreads, sort, subs, upds, scratch, mk,
        ),
    }
}

/// [`Matcher`](crate::engine::Matcher) backend for Parallel SBM (the
/// paper's main contribution).
pub struct PsbmMatcher {
    set_impl: SetImpl,
    sort: SortAlgo,
    nd: NdPolicy,
}

impl PsbmMatcher {
    pub fn new(set_impl: SetImpl) -> Self {
        Self {
            set_impl,
            sort: SortAlgo::default(),
            nd: NdPolicy::default(),
        }
    }

    /// Set the N-D pipeline policy (engine-injected).
    pub fn with_nd(mut self, nd: NdPolicy) -> Self {
        self.nd = nd;
        self
    }

    /// Set the endpoint sort implementation (engine-injected; CLI
    /// `--sort radix|merge`).
    pub fn with_sort(mut self, sort: SortAlgo) -> Self {
        self.sort = sort;
        self
    }
}

impl crate::engine::Matcher for PsbmMatcher {
    fn name(&self) -> &str {
        "psbm"
    }

    fn match_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    ) {
        let mut guard = ctx.scratch();
        let scratch = &mut *guard;
        // Per-worker collection sinks come from (and return to) the
        // scratch pool, so warm calls reuse their pair buffers too.
        let disp = SinkDispenser::new(scratch.take_pair_sinks(ctx.nthreads));
        let sinks: Vec<crate::core::sink::VecSink> = match_par_sinks_scratch_with(
            self.set_impl,
            self.sort,
            ctx.pool,
            ctx.nthreads,
            subs,
            upds,
            scratch,
            |p| disp.take(p),
        );
        scratch.drain_pair_sinks(sinks, disp.into_remaining(), sink);
    }

    fn count_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
    ) -> u64 {
        let mut guard = ctx.scratch();
        let sinks: Vec<crate::core::sink::CountSink> = match_par_sinks_scratch_with(
            self.set_impl,
            self.sort,
            ctx.pool,
            ctx.nthreads,
            subs,
            upds,
            &mut guard,
            |_p| crate::core::sink::CountSink::default(),
        );
        crate::core::sink::total_count(&sinks)
    }

    fn match_nd(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &RegionsNd,
        upds: &RegionsNd,
        sink: &mut dyn MatchSink,
    ) {
        match self.nd.mode {
            NdMode::Reduction => ddim::ReductionNd::match_nd_with(
                Some(ctx.pool),
                subs,
                upds,
                |s1, u1, out| self.match_1d(ctx, s1, u1, out),
                sink,
            ),
            NdMode::Native => {
                let mut guard = ctx.scratch();
                ddim::native_match(
                    self.nd.sweep,
                    ctx.pool,
                    ctx.nthreads,
                    subs,
                    upds,
                    &mut guard,
                    |s1, u1, scratch, mk| {
                        match_par_sinks_scratch_with(
                            self.set_impl,
                            self.sort,
                            ctx.pool,
                            ctx.nthreads,
                            s1,
                            u1,
                            scratch,
                            mk,
                        )
                    },
                    sink,
                )
            }
        }
    }

    fn count_nd(&self, ctx: &crate::engine::ExecCtx<'_>, subs: &RegionsNd, upds: &RegionsNd) -> u64 {
        match self.nd.mode {
            NdMode::Reduction => {
                let mut sink = crate::core::sink::CountSink::default();
                self.match_nd(ctx, subs, upds, &mut sink);
                sink.count
            }
            NdMode::Native => {
                let mut guard = ctx.scratch();
                ddim::native_count(
                    self.nd.sweep,
                    ctx.pool,
                    ctx.nthreads,
                    subs,
                    upds,
                    &mut guard,
                    |s1, u1, scratch, mk| {
                        match_par_sinks_scratch_with(
                            self.set_impl,
                            self.sort,
                            ctx.pool,
                            ctx.nthreads,
                            s1,
                            u1,
                            scratch,
                            mk,
                        )
                    },
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{bfm, sbm};
    use crate::core::interval::Interval;
    use crate::core::region::random_regions_1d;
    use crate::core::sink::{canonical_pairs, canonicalize, VecSink};

    fn bfm_pairs(subs: &Regions1D, upds: &Regions1D) -> Vec<(u32, u32)> {
        let mut want = VecSink::default();
        bfm::match_seq(subs, upds, &mut want);
        canonicalize(want.pairs)
    }

    #[test]
    fn equals_serial_sbm_and_bfm_for_all_thread_counts() {
        let pool = ThreadPool::new(7);
        let mut rng = crate::prng::Rng::new(0x95B);
        let subs = random_regions_1d(&mut rng, 700, 1000.0, 12.0);
        let upds = random_regions_1d(&mut rng, 600, 1000.0, 12.0);
        let want = bfm_pairs(&subs, &upds);
        let serial: VecSink = sbm::match_seq_with(SetImpl::Bit, &subs, &upds);
        assert_eq!(canonicalize(serial.pairs), want);
        for p in 1..=8 {
            let got =
                canonical_pairs(match_par::<BitSet, VecSink>(&pool, p, &subs, &upds));
            assert_eq!(got, want, "p={p}");
        }
    }

    #[test]
    fn all_set_impls_agree_in_parallel() {
        let pool = ThreadPool::new(3);
        let mut rng = crate::prng::Rng::new(0x95C);
        let subs = random_regions_1d(&mut rng, 300, 500.0, 25.0);
        let upds = random_regions_1d(&mut rng, 300, 500.0, 25.0);
        let want = bfm_pairs(&subs, &upds);
        for set_impl in SetImpl::ALL {
            let got: Vec<VecSink> = match_par_with(set_impl, &pool, 4, &subs, &upds);
            assert_eq!(canonical_pairs(got), want, "{}", set_impl.name());
        }
    }

    #[test]
    fn segment_boundary_straddling_regions() {
        // One long region spanning every segment, many short ones.
        let pool = ThreadPool::new(7);
        let mut intervals = vec![Interval::new(0.0, 1000.0)];
        for i in 0..100 {
            let lo = i as f64 * 10.0;
            intervals.push(Interval::new(lo, lo + 5.0));
        }
        let subs = Regions1D::from_intervals(&intervals);
        let upds = Regions1D::from_intervals(&intervals);
        let want = bfm_pairs(&subs, &upds);
        for p in [2, 3, 5, 8] {
            let got =
                canonical_pairs(match_par::<BitSet, VecSink>(&pool, p, &subs, &upds));
            assert_eq!(got, want, "p={p}");
        }
    }

    #[test]
    fn property_p_invariance_random_workloads() {
        let pool = ThreadPool::new(5);
        crate::bench::prop::prop_check("psbm-p-invariance", 0x95D, |rng| {
            let n = 1 + rng.below(200) as usize;
            let m = 1 + rng.below(200) as usize;
            let l = rng.uniform(0.1, 40.0);
            let subs = random_regions_1d(rng, n, 100.0, l);
            let upds = random_regions_1d(rng, m, 100.0, l);
            let want = bfm_pairs(&subs, &upds);
            let p = 1 + rng.below(6) as usize;
            let got =
                canonical_pairs(match_par::<BitSet, VecSink>(&pool, p, &subs, &upds));
            crate::bench::prop::expect_eq(&got, &want, "pairs")
        });
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let pool = ThreadPool::new(3);
        let empty = Regions1D::default();
        let got = canonical_pairs(match_par::<BitSet, VecSink>(&pool, 4, &empty, &empty));
        assert!(got.is_empty());
        let one = Regions1D::from_intervals(&[Interval::new(0.0, 1.0)]);
        let got = canonical_pairs(match_par::<BitSet, VecSink>(&pool, 4, &one, &one));
        assert_eq!(got, vec![(0, 0)]);
    }

    #[test]
    fn duplicate_endpoints_across_segments() {
        // All endpoints identical: worst case for tie-breaking + segmenting.
        let pool = ThreadPool::new(7);
        let iv = Interval::new(5.0, 6.0);
        let subs = Regions1D::from_intervals(&[iv; 20]);
        let upds = Regions1D::from_intervals(&[iv; 20]);
        let want = bfm_pairs(&subs, &upds);
        assert_eq!(want.len(), 400);
        for p in [1, 2, 4, 8] {
            let got =
                canonical_pairs(match_par::<BitSet, VecSink>(&pool, p, &subs, &upds));
            assert_eq!(got, want, "p={p}");
        }
    }
}

//! Sort-Based Matching (paper Algorithm 4; Raczy, Tan & Yu [52]).
//!
//! Endpoints of all regions are sorted and swept in non-decreasing
//! order while two active sets track the open subscription and update
//! regions. When a region's upper endpoint is encountered it is
//! reported against every active region of the opposite kind — no
//! Intersect-1D calls at all.
//!
//! **Tie-breaking.** Positions can collide; intervals are half-open, so
//! at equal position the *upper* endpoints must be processed before the
//! lower ones — `[a, b)` and `[b, c)` must not match. The endpoint sort
//! key encodes this (see [`Endpoint::sort_key`]); the choice is
//! property-tested against BFM, which never looks at ordering.
//!
//! The module also exports the endpoint encoding and the sweep core so
//! Parallel SBM ([`super::psbm`]) reuses the exact same semantics.

use crate::core::scratch::MatchScratch;
use crate::core::sink::MatchSink;
use crate::core::Regions1D;
use crate::exec::SortAlgo;
use crate::sets::{
    ActiveSet, BTreeActiveSet, BitSet, HashActiveSet, SetImpl, SortedVecSet, SparseSet,
};

// The endpoint record (compact `u64` radix key + tie-break payload)
// and its builders live in the core layer so the scratch buffers and
// the sort machinery share one layout; re-exported here because SBM is
// their natural home in the paper.
pub use crate::core::endpoint::{build_endpoints, build_endpoints_into, Endpoint};

/// The sweep core (Algorithm 4 lines 6–18 / Algorithm 6 lines 8–20):
/// process `endpoints` in order against the given active sets.
#[inline]
pub fn sweep<Set: ActiveSet>(
    endpoints: &[Endpoint],
    sub_set: &mut Set,
    upd_set: &mut Set,
    sink: &mut dyn MatchSink,
) {
    for &e in endpoints {
        let idx = e.idx();
        if e.is_update() {
            if !e.is_upper() {
                upd_set.insert(idx);
            } else {
                upd_set.remove(idx);
                sub_set.for_each(&mut |s| sink.report(s, idx));
            }
        } else if !e.is_upper() {
            sub_set.insert(idx);
        } else {
            sub_set.remove(idx);
            upd_set.for_each(&mut |u| sink.report(idx, u));
        }
    }
}

/// Serial SBM (Algorithm 4) with a chosen active-set implementation.
pub fn match_seq<Set: ActiveSet>(
    subs: &Regions1D,
    upds: &Regions1D,
    sink: &mut dyn MatchSink,
) {
    match_seq_scratch_generic::<Set>(
        SortAlgo::default(),
        subs,
        upds,
        &mut MatchScratch::new(),
        sink,
    );
}

/// Serial SBM over a caller-owned [`MatchScratch`]: the endpoint
/// array, the radix aux buffer and the histogram block are all reused
/// across calls, so the warm path allocates nothing.
pub fn match_seq_scratch_generic<Set: ActiveSet>(
    sort: SortAlgo,
    subs: &Regions1D,
    upds: &Regions1D,
    scratch: &mut MatchScratch,
    sink: &mut dyn MatchSink,
) {
    let MatchScratch {
        endpoints,
        aux,
        radix,
        span_log,
        ..
    } = scratch;
    let t_sort = span_log.start();
    build_endpoints_into(subs, upds, endpoints);
    crate::core::endpoint::sort_endpoints(None, endpoints, aux, radix, sort);
    let total = endpoints.len() as u64;
    span_log.record(crate::obs::Phase::Sort, crate::obs::trace::MASTER_WORKER, t_sort, total);
    let t_sweep = span_log.start();
    let mut sub_set = Set::with_universe(subs.len());
    let mut upd_set = Set::with_universe(upds.len());
    sweep(endpoints, &mut sub_set, &mut upd_set, sink);
    span_log.record(crate::obs::Phase::Sweep, crate::obs::trace::MASTER_WORKER, t_sweep, total);
}

/// Runtime-dispatched serial SBM over a caller-owned scratch.
pub fn match_seq_scratch(
    set_impl: SetImpl,
    sort: SortAlgo,
    subs: &Regions1D,
    upds: &Regions1D,
    scratch: &mut MatchScratch,
    sink: &mut dyn MatchSink,
) {
    match set_impl {
        SetImpl::Bit => match_seq_scratch_generic::<BitSet>(sort, subs, upds, scratch, sink),
        SetImpl::Hash => {
            match_seq_scratch_generic::<HashActiveSet>(sort, subs, upds, scratch, sink)
        }
        SetImpl::BTree => {
            match_seq_scratch_generic::<BTreeActiveSet>(sort, subs, upds, scratch, sink)
        }
        SetImpl::SortedVec => {
            match_seq_scratch_generic::<SortedVecSet>(sort, subs, upds, scratch, sink)
        }
        SetImpl::Sparse => match_seq_scratch_generic::<SparseSet>(sort, subs, upds, scratch, sink),
    }
}

/// Runtime-dispatched serial SBM returning a fresh sink.
pub fn match_seq_with<S>(set_impl: SetImpl, subs: &Regions1D, upds: &Regions1D) -> S
where
    S: MatchSink + Default,
{
    let mut sink = S::default();
    match_seq_scratch(
        set_impl,
        SortAlgo::default(),
        subs,
        upds,
        &mut MatchScratch::new(),
        &mut sink,
    );
    sink
}

/// [`Matcher`](crate::engine::Matcher) backend for **serial** SBM
/// (the paper's Algorithm 4, the sequential state of the art). Runs on
/// one thread regardless of the context's thread count.
pub struct SbmMatcher {
    set_impl: SetImpl,
    sort: SortAlgo,
    nd: crate::core::ddim::NdPolicy,
}

impl SbmMatcher {
    pub fn new(set_impl: SetImpl) -> Self {
        Self {
            set_impl,
            sort: SortAlgo::default(),
            nd: crate::core::ddim::NdPolicy::default(),
        }
    }

    /// Set the N-D pipeline policy (engine-injected).
    pub fn with_nd(mut self, nd: crate::core::ddim::NdPolicy) -> Self {
        self.nd = nd;
        self
    }

    /// Set the endpoint sort implementation (engine-injected; CLI
    /// `--sort radix|merge`).
    pub fn with_sort(mut self, sort: SortAlgo) -> Self {
        self.sort = sort;
        self
    }
}

impl crate::engine::Matcher for SbmMatcher {
    fn name(&self) -> &str {
        "sbm"
    }

    fn match_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    ) {
        let mut scratch = ctx.scratch();
        match_seq_scratch(self.set_impl, self.sort, subs, upds, &mut scratch, sink);
    }

    fn count_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
    ) -> u64 {
        let mut counted = crate::core::sink::CountSink::default();
        self.match_1d(ctx, subs, upds, &mut counted);
        counted.count
    }

    fn match_nd(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &crate::core::RegionsNd,
        upds: &crate::core::RegionsNd,
        sink: &mut dyn MatchSink,
    ) {
        use crate::core::ddim::{self, NdMode};
        match self.nd.mode {
            NdMode::Reduction => ddim::ReductionNd::match_nd_with(
                Some(ctx.pool),
                subs,
                upds,
                |s1, u1, out| self.match_1d(ctx, s1, u1, out),
                sink,
            ),
            NdMode::Native => {
                // Serial backend: one FilterSink straight over the
                // caller's sink; the sweep is a single pass anyway.
                let k = ddim::resolve_sweep_dim(self.nd.sweep, ctx.pool, 1, subs, upds);
                let mut scratch = ctx.scratch();
                let scratch = &mut *scratch;
                ddim::sweep_and_verify(
                    subs,
                    upds,
                    k,
                    |s1, u1, out| {
                        match_seq_scratch(self.set_impl, self.sort, s1, u1, scratch, out)
                    },
                    sink,
                );
            }
        }
    }

    fn count_nd(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &crate::core::RegionsNd,
        upds: &crate::core::RegionsNd,
    ) -> u64 {
        let mut sink = crate::core::sink::CountSink::default();
        self.match_nd(ctx, subs, upds, &mut sink);
        sink.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::bfm;
    use crate::core::interval::Interval;
    use crate::core::region::random_regions_1d;
    use crate::core::sink::{canonicalize, VecSink};

    /// The satellite tie-break oracle test: sweeps over equal
    /// positions, -0.0 vs 0.0, subnormals and ±inf must match the 1-D
    /// brute-force oracle (which only uses Intersect-1D) under BOTH
    /// sort implementations.
    #[test]
    fn pathological_positions_match_bfm_under_both_sorts() {
        use crate::core::scratch::MatchScratch;
        use crate::exec::SortAlgo;

        let specials = [
            0.0,
            -0.0,
            5e-324,
            -5e-324,
            f64::MIN_POSITIVE,
            f64::INFINITY,
            f64::NEG_INFINITY,
            1.0,
            -1.0,
        ];
        let mut rng = crate::prng::Rng::new(0x71E5);
        for case in 0..40 {
            let mut mk = |n: usize| {
                let mut r = Regions1D::default();
                for _ in 0..n {
                    let pick = |rng: &mut crate::prng::Rng| -> f64 {
                        if rng.chance(0.8) {
                            specials[rng.below(specials.len() as u64) as usize]
                        } else {
                            rng.uniform(-1.0, 1.0)
                        }
                    };
                    let (a, b) = (pick(&mut rng), pick(&mut rng));
                    let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                    r.push(Interval::new(lo, hi));
                }
                r
            };
            let subs = mk(12);
            let upds = mk(12);
            let mut want = VecSink::default();
            bfm::match_seq(&subs, &upds, &mut want);
            let want = canonicalize(want.pairs);
            for sort in [SortAlgo::Radix, SortAlgo::Merge] {
                let mut got = VecSink::default();
                match_seq_scratch(
                    SetImpl::Hash,
                    sort,
                    &subs,
                    &upds,
                    &mut MatchScratch::new(),
                    &mut got,
                );
                assert_eq!(
                    canonicalize(got.pairs),
                    want,
                    "case {case} sort {sort:?} diverged from Intersect-1D"
                );
            }
        }
    }

    /// A reused scratch yields bit-identical results to fresh
    /// allocation, and its buffers stop growing after the first call.
    #[test]
    fn scratch_reuse_is_identical_and_allocation_free() {
        use crate::core::scratch::MatchScratch;
        use crate::exec::SortAlgo;

        let mut rng = crate::prng::Rng::new(0x5C4A);
        let subs = random_regions_1d(&mut rng, 500, 800.0, 10.0);
        let upds = random_regions_1d(&mut rng, 450, 800.0, 10.0);
        let mut scratch = MatchScratch::new();
        let mut first: Option<Vec<(u32, u32)>> = None;
        let mut stats = None;
        for call in 0..4 {
            let mut got = VecSink::default();
            match_seq_scratch(
                SetImpl::Sparse,
                SortAlgo::Radix,
                &subs,
                &upds,
                &mut scratch,
                &mut got,
            );
            let got = canonicalize(got.pairs);
            match &first {
                None => {
                    // Fresh-allocation reference.
                    let fresh: VecSink = match_seq_with(SetImpl::Sparse, &subs, &upds);
                    assert_eq!(got, canonicalize(fresh.pairs));
                    first = Some(got);
                }
                Some(want) => assert_eq!(&got, want, "warm call {call} diverged"),
            }
            match stats {
                None => stats = Some(scratch.stats()),
                Some(s) => assert_eq!(scratch.stats(), s, "scratch grew on warm call {call}"),
            }
        }
    }

    #[test]
    fn touching_intervals_do_not_match() {
        let subs = Regions1D::from_intervals(&[Interval::new(0.0, 5.0)]);
        let upds = Regions1D::from_intervals(&[Interval::new(5.0, 9.0)]);
        let mut sink = VecSink::default();
        match_seq::<BitSet>(&subs, &upds, &mut sink);
        assert!(sink.pairs.is_empty());
    }

    #[test]
    fn figure5_style_sweep() {
        // Overlapping chain: s0=[0,4), s1=[2,6); u0=[3,5).
        let subs = Regions1D::from_intervals(&[
            Interval::new(0.0, 4.0),
            Interval::new(2.0, 6.0),
        ]);
        let upds = Regions1D::from_intervals(&[Interval::new(3.0, 5.0)]);
        let mut sink = VecSink::default();
        match_seq::<BitSet>(&subs, &upds, &mut sink);
        assert_eq!(canonicalize(sink.pairs), vec![(0, 0), (1, 0)]);
    }

    #[test]
    fn all_set_impls_match_bfm_property() {
        crate::bench::prop::prop_check("sbm-vs-bfm", 0x5B, |rng| {
            let n = 1 + rng.below(150) as usize;
            let m = 1 + rng.below(150) as usize;
            // Mix of long and short intervals; occasional duplicates.
            let space = 100.0;
            let subs = { let l = rng.uniform(0.5, 30.0); random_regions_1d(rng, n, space, l) };
            let upds = { let l = rng.uniform(0.5, 30.0); random_regions_1d(rng, m, space, l) };
            let mut want = VecSink::default();
            bfm::match_seq(&subs, &upds, &mut want);
            let want = canonicalize(want.pairs);
            for set_impl in SetImpl::ALL {
                let got: VecSink = match_seq_with(set_impl, &subs, &upds);
                let got = canonicalize(got.pairs);
                if got != want {
                    return Err(format!(
                        "{}: {} pairs vs bfm {}",
                        set_impl.name(),
                        got.len(),
                        want.len()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn identical_endpoints_stress() {
        // Many regions sharing exact endpoints.
        let iv = Interval::new(1.0, 2.0);
        let subs = Regions1D::from_intervals(&[iv; 8]);
        let upds = Regions1D::from_intervals(&[iv; 8]);
        let mut sink = VecSink::default();
        match_seq::<BitSet>(&subs, &upds, &mut sink);
        assert_eq!(sink.pairs.len(), 64);
        crate::core::sink::assert_exactly_once(&canonicalize(sink.pairs)).unwrap();
    }
}

//! Sort-Based Matching (paper Algorithm 4; Raczy, Tan & Yu [52]).
//!
//! Endpoints of all regions are sorted and swept in non-decreasing
//! order while two active sets track the open subscription and update
//! regions. When a region's upper endpoint is encountered it is
//! reported against every active region of the opposite kind — no
//! Intersect-1D calls at all.
//!
//! **Tie-breaking.** Positions can collide; intervals are half-open, so
//! at equal position the *upper* endpoints must be processed before the
//! lower ones — `[a, b)` and `[b, c)` must not match. The endpoint sort
//! key encodes this (see [`Endpoint::sort_key`]); the choice is
//! property-tested against BFM, which never looks at ordering.
//!
//! The module also exports the endpoint encoding and the sweep core so
//! Parallel SBM ([`super::psbm`]) reuses the exact same semantics.

use crate::core::sink::MatchSink;
use crate::core::Regions1D;
use crate::exec::f64_key;
use crate::sets::{
    ActiveSet, BTreeActiveSet, BitSet, HashActiveSet, SetImpl, SortedVecSet, SparseSet,
};

/// One interval endpoint, stored **sort-ready**: the position is kept
/// as its order-preserving bit pattern (`f64_key`) and the tie-break
/// bits are pre-composed, so sorting compares two plain u64 words with
/// no per-comparison key recomputation (a measured win on the sort
/// phase — EXPERIMENTS.md §Perf).
///
/// `lo` layout: bit 63 = side-first flag (0 for *upper* endpoints so
/// they sort before lowers at equal positions — half-open semantics);
/// bits 2.. = region idx; bit 1 = is_upper; bit 0 = is_update.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Endpoint {
    /// `f64_key(pos)` — order-preserving position bits.
    pub hi: u64,
    /// Tie-break + payload bits (see layout above).
    pub lo: u64,
}

const LOWER_SORTS_LAST: u64 = 1 << 63;

impl Endpoint {
    #[inline]
    pub fn new(pos: f64, idx: u32, is_upper: bool, is_update: bool) -> Self {
        let side = if is_upper { 0 } else { LOWER_SORTS_LAST };
        Self {
            hi: f64_key(pos),
            lo: side | (idx as u64) << 2 | (is_upper as u64) << 1 | is_update as u64,
        }
    }

    #[inline]
    pub fn idx(self) -> u32 {
        ((self.lo & !LOWER_SORTS_LAST) >> 2) as u32
    }

    #[inline]
    pub fn is_upper(self) -> bool {
        self.lo & 2 != 0
    }

    #[inline]
    pub fn is_update(self) -> bool {
        self.lo & 1 != 0
    }

    /// Position (decoded from the order-preserving bits; debug use).
    pub fn pos(self) -> f64 {
        let bits = if self.hi & (1 << 63) != 0 {
            self.hi & !(1 << 63)
        } else {
            !self.hi
        };
        f64::from_bits(bits)
    }

    /// Total sort key: position, then side (uppers first), then
    /// kind/idx for determinism — a pure bit concatenation of the
    /// stored words, no recomputation.
    #[inline]
    pub fn sort_key(self) -> u128 {
        (self.hi as u128) << 64 | self.lo as u128
    }
}

/// Build the 2(n+m) endpoint array (Algorithm 4 lines 1–3).
pub fn build_endpoints(subs: &Regions1D, upds: &Regions1D) -> Vec<Endpoint> {
    let mut t = Vec::with_capacity(2 * (subs.len() + upds.len()));
    for i in 0..subs.len() {
        t.push(Endpoint::new(subs.lo[i], i as u32, false, false));
        t.push(Endpoint::new(subs.hi[i], i as u32, true, false));
    }
    for j in 0..upds.len() {
        t.push(Endpoint::new(upds.lo[j], j as u32, false, true));
        t.push(Endpoint::new(upds.hi[j], j as u32, true, true));
    }
    t
}

/// The sweep core (Algorithm 4 lines 6–18 / Algorithm 6 lines 8–20):
/// process `endpoints` in order against the given active sets.
#[inline]
pub fn sweep<Set: ActiveSet>(
    endpoints: &[Endpoint],
    sub_set: &mut Set,
    upd_set: &mut Set,
    sink: &mut dyn MatchSink,
) {
    for &e in endpoints {
        let idx = e.idx();
        if e.is_update() {
            if !e.is_upper() {
                upd_set.insert(idx);
            } else {
                upd_set.remove(idx);
                sub_set.for_each(&mut |s| sink.report(s, idx));
            }
        } else if !e.is_upper() {
            sub_set.insert(idx);
        } else {
            sub_set.remove(idx);
            upd_set.for_each(&mut |u| sink.report(idx, u));
        }
    }
}

/// Serial SBM (Algorithm 4) with a chosen active-set implementation.
pub fn match_seq<Set: ActiveSet>(
    subs: &Regions1D,
    upds: &Regions1D,
    sink: &mut dyn MatchSink,
) {
    let mut t = build_endpoints(subs, upds);
    t.sort_unstable_by_key(|e| e.sort_key());
    let mut sub_set = Set::with_universe(subs.len());
    let mut upd_set = Set::with_universe(upds.len());
    sweep(&t, &mut sub_set, &mut upd_set, sink);
}

/// Runtime-dispatched serial SBM returning a fresh sink.
pub fn match_seq_with<S>(set_impl: SetImpl, subs: &Regions1D, upds: &Regions1D) -> S
where
    S: MatchSink + Default,
{
    let mut sink = S::default();
    match set_impl {
        SetImpl::Bit => match_seq::<BitSet>(subs, upds, &mut sink),
        SetImpl::Hash => match_seq::<HashActiveSet>(subs, upds, &mut sink),
        SetImpl::BTree => match_seq::<BTreeActiveSet>(subs, upds, &mut sink),
        SetImpl::SortedVec => match_seq::<SortedVecSet>(subs, upds, &mut sink),
        SetImpl::Sparse => match_seq::<SparseSet>(subs, upds, &mut sink),
    }
    sink
}

/// [`Matcher`](crate::engine::Matcher) backend for **serial** SBM
/// (the paper's Algorithm 4, the sequential state of the art). Runs on
/// one thread regardless of the context's thread count.
pub struct SbmMatcher {
    set_impl: SetImpl,
    nd: crate::core::ddim::NdPolicy,
}

impl SbmMatcher {
    pub fn new(set_impl: SetImpl) -> Self {
        Self {
            set_impl,
            nd: crate::core::ddim::NdPolicy::default(),
        }
    }

    /// Set the N-D pipeline policy (engine-injected).
    pub fn with_nd(mut self, nd: crate::core::ddim::NdPolicy) -> Self {
        self.nd = nd;
        self
    }

    /// Serial sweep of one dimension's projections into `sink`
    /// (runtime set dispatch).
    fn sweep_into(&self, subs: &Regions1D, upds: &Regions1D, sink: &mut dyn MatchSink) {
        match self.set_impl {
            SetImpl::Bit => match_seq::<BitSet>(subs, upds, sink),
            SetImpl::Hash => match_seq::<HashActiveSet>(subs, upds, sink),
            SetImpl::BTree => match_seq::<BTreeActiveSet>(subs, upds, sink),
            SetImpl::SortedVec => match_seq::<SortedVecSet>(subs, upds, sink),
            SetImpl::Sparse => match_seq::<SparseSet>(subs, upds, sink),
        }
    }
}

impl crate::engine::Matcher for SbmMatcher {
    fn name(&self) -> &str {
        "sbm"
    }

    fn match_1d(
        &self,
        _ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    ) {
        let collected: crate::core::sink::VecSink =
            match_seq_with(self.set_impl, subs, upds);
        crate::core::sink::replay(vec![collected], sink);
    }

    fn count_1d(
        &self,
        _ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
    ) -> u64 {
        let counted: crate::core::sink::CountSink = match_seq_with(self.set_impl, subs, upds);
        counted.count
    }

    fn match_nd(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &crate::core::RegionsNd,
        upds: &crate::core::RegionsNd,
        sink: &mut dyn MatchSink,
    ) {
        use crate::core::ddim::{self, NdMode};
        match self.nd.mode {
            NdMode::Reduction => ddim::ReductionNd::match_nd_with(
                Some(ctx.pool),
                subs,
                upds,
                |s1, u1, out| self.match_1d(ctx, s1, u1, out),
                sink,
            ),
            NdMode::Native => {
                // Serial backend: one FilterSink straight over the
                // caller's sink; the sweep is a single pass anyway.
                let k = ddim::resolve_sweep_dim(self.nd.sweep, ctx.pool, 1, subs, upds);
                ddim::sweep_and_verify(
                    subs,
                    upds,
                    k,
                    |s1, u1, out| self.sweep_into(s1, u1, out),
                    sink,
                );
            }
        }
    }

    fn count_nd(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &crate::core::RegionsNd,
        upds: &crate::core::RegionsNd,
    ) -> u64 {
        let mut sink = crate::core::sink::CountSink::default();
        self.match_nd(ctx, subs, upds, &mut sink);
        sink.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::bfm;
    use crate::core::interval::Interval;
    use crate::core::region::random_regions_1d;
    use crate::core::sink::{canonicalize, VecSink};

    #[test]
    fn endpoint_encoding_roundtrip() {
        let e = Endpoint::new(3.5, 1234, true, false);
        assert_eq!(e.idx(), 1234);
        assert!(e.is_upper());
        assert!(!e.is_update());
        let e2 = Endpoint::new(-1.0, 0, false, true);
        assert!(!e2.is_upper());
        assert!(e2.is_update());
    }

    #[test]
    fn uppers_sort_before_lowers_at_equal_pos() {
        let upper = Endpoint::new(5.0, 7, true, false);
        let lower = Endpoint::new(5.0, 3, false, true);
        assert!(upper.sort_key() < lower.sort_key());
        // and position dominates
        let earlier = Endpoint::new(4.9, 9, false, false);
        assert!(earlier.sort_key() < upper.sort_key());
    }

    #[test]
    fn touching_intervals_do_not_match() {
        let subs = Regions1D::from_intervals(&[Interval::new(0.0, 5.0)]);
        let upds = Regions1D::from_intervals(&[Interval::new(5.0, 9.0)]);
        let mut sink = VecSink::default();
        match_seq::<BitSet>(&subs, &upds, &mut sink);
        assert!(sink.pairs.is_empty());
    }

    #[test]
    fn figure5_style_sweep() {
        // Overlapping chain: s0=[0,4), s1=[2,6); u0=[3,5).
        let subs = Regions1D::from_intervals(&[
            Interval::new(0.0, 4.0),
            Interval::new(2.0, 6.0),
        ]);
        let upds = Regions1D::from_intervals(&[Interval::new(3.0, 5.0)]);
        let mut sink = VecSink::default();
        match_seq::<BitSet>(&subs, &upds, &mut sink);
        assert_eq!(canonicalize(sink.pairs), vec![(0, 0), (1, 0)]);
    }

    #[test]
    fn all_set_impls_match_bfm_property() {
        crate::bench::prop::prop_check("sbm-vs-bfm", 0x5B, |rng| {
            let n = 1 + rng.below(150) as usize;
            let m = 1 + rng.below(150) as usize;
            // Mix of long and short intervals; occasional duplicates.
            let space = 100.0;
            let subs = { let l = rng.uniform(0.5, 30.0); random_regions_1d(rng, n, space, l) };
            let upds = { let l = rng.uniform(0.5, 30.0); random_regions_1d(rng, m, space, l) };
            let mut want = VecSink::default();
            bfm::match_seq(&subs, &upds, &mut want);
            let want = canonicalize(want.pairs);
            for set_impl in SetImpl::ALL {
                let got: VecSink = match_seq_with(set_impl, &subs, &upds);
                let got = canonicalize(got.pairs);
                if got != want {
                    return Err(format!(
                        "{}: {} pairs vs bfm {}",
                        set_impl.name(),
                        got.len(),
                        want.len()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn identical_endpoints_stress() {
        // Many regions sharing exact endpoints.
        let iv = Interval::new(1.0, 2.0);
        let subs = Regions1D::from_intervals(&[iv; 8]);
        let upds = Regions1D::from_intervals(&[iv; 8]);
        let mut sink = VecSink::default();
        match_seq::<BitSet>(&subs, &upds, &mut sink);
        assert_eq!(sink.pairs.len(), 64);
        crate::core::sink::assert_exactly_once(&canonicalize(sink.pairs)).unwrap();
    }
}

//! Binary-search-enhanced sort matching, in the spirit of Li, Tang,
//! Yao & Zhu [38] (paper §2 related work).
//!
//! Li et al. speed SBM up by sorting *smaller* vectors (the region
//! bounds rather than all endpoints) and binary-searching them. We
//! implement the natural enumeration variant: updates are sorted by
//! lower bound; for each subscription `s` a binary search finds the
//! prefix of updates with `u.lo < s.hi`, which is then filtered by
//! `u.hi > s.lo`. Worst case O(n·m) like BFM, but with tight constants
//! and the same trivially parallel outer loop; fast when the overlap
//! degree is small. (The exact algorithm of [38] interleaves counting
//! bounds; we document this as an *inspired-by* baseline, not a
//! faithful reproduction — it plays that role in the benches.)

use crate::core::sink::MatchSink;
use crate::core::Regions1D;
use crate::exec::pfor::chunks;
use crate::exec::ThreadPool;

struct SortedUpdates {
    /// (lo, hi, original index), sorted by lo.
    by_lo: Vec<(f64, f64, u32)>,
}

fn prepare(upds: &Regions1D) -> SortedUpdates {
    let mut by_lo: Vec<(f64, f64, u32)> = (0..upds.len())
        .map(|j| (upds.lo[j], upds.hi[j], j as u32))
        .collect();
    by_lo.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    SortedUpdates { by_lo }
}

#[inline]
fn match_one(s_idx: u32, slo: f64, shi: f64, upd: &SortedUpdates, sink: &mut dyn MatchSink) {
    // Binary search: first index with u.lo >= s.hi; candidates are [0, end).
    let end = upd.by_lo.partition_point(|&(lo, _, _)| lo < shi);
    for &(_, uhi, j) in &upd.by_lo[..end] {
        if uhi > slo {
            sink.report(s_idx, j);
        }
    }
}

/// Serial binary-search matching.
pub fn match_seq(subs: &Regions1D, upds: &Regions1D, sink: &mut dyn MatchSink) {
    let upd = prepare(upds);
    for i in 0..subs.len() {
        match_one(i as u32, subs.lo[i], subs.hi[i], &upd, sink);
    }
}

/// Parallel variant: subscriptions split statically across workers.
pub fn match_par<S>(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &Regions1D,
    upds: &Regions1D,
) -> Vec<S>
where
    S: MatchSink + Default,
{
    let upd = pool.serial_section(|| prepare(upds));
    let upd = &upd;
    let ranges = chunks(subs.len(), nthreads);
    super::par_collect(pool, nthreads, |p, sink: &mut S| {
        for i in ranges[p].clone() {
            match_one(i as u32, subs.lo[i], subs.hi[i], upd, sink);
        }
    })
}

/// [`Matcher`](crate::engine::Matcher) backend for the
/// binary-search-enhanced sort matching baseline.
pub struct SbmBinaryMatcher;

impl crate::engine::Matcher for SbmBinaryMatcher {
    fn name(&self) -> &str {
        "sbm-binary"
    }

    fn match_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    ) {
        let sinks: Vec<crate::core::sink::VecSink> =
            match_par(ctx.pool, ctx.nthreads, subs, upds);
        crate::core::sink::replay(sinks, sink);
    }

    fn count_1d(
        &self,
        ctx: &crate::engine::ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
    ) -> u64 {
        let sinks: Vec<crate::core::sink::CountSink> =
            match_par(ctx.pool, ctx.nthreads, subs, upds);
        crate::core::sink::total_count(&sinks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::bfm;
    use crate::core::region::random_regions_1d;
    use crate::core::sink::{canonical_pairs, canonicalize, VecSink};

    #[test]
    fn matches_bfm_property() {
        crate::bench::prop::prop_check("sbm-binary-vs-bfm", 0xB5, |rng| {
            let n = 1 + rng.below(120) as usize;
            let m = 1 + rng.below(120) as usize;
            let subs = { let l = rng.uniform(0.5, 20.0); random_regions_1d(rng, n, 100.0, l) };
            let upds = { let l = rng.uniform(0.5, 20.0); random_regions_1d(rng, m, 100.0, l) };
            let mut want = VecSink::default();
            bfm::match_seq(&subs, &upds, &mut want);
            let mut got = VecSink::default();
            match_seq(&subs, &upds, &mut got);
            crate::bench::prop::expect_eq(
                &canonicalize(got.pairs),
                &canonicalize(want.pairs),
                "pairs",
            )
        });
    }

    #[test]
    fn parallel_equals_serial() {
        let pool = ThreadPool::new(3);
        let mut rng = crate::prng::Rng::new(0xB6);
        let subs = random_regions_1d(&mut rng, 200, 100.0, 5.0);
        let upds = random_regions_1d(&mut rng, 300, 100.0, 5.0);
        let mut want = VecSink::default();
        match_seq(&subs, &upds, &mut want);
        let got = canonical_pairs(match_par::<VecSink>(&pool, 4, &subs, &upds));
        assert_eq!(got, canonicalize(want.pairs));
    }
}

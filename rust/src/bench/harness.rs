//! Shared bench-binary plumbing: context construction, modeled-WCT
//! measurement, and CSV output paths.
//!
//! Every figure bench follows the same protocol (DESIGN.md §3):
//! run the parallel algorithm with P workers under cost logging
//! (per-worker CPU busy times + serial sections), then convert the log
//! to the wall-clock a P-core machine would see via
//! [`super::speedup::ModelOpts::modeled_wct`]. Raw oversubscribed
//! wall-clock is also recorded for transparency.

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Instant;

use super::speedup::ModelOpts;
use super::stats::{summarize, Summary};
use super::Meter;
use crate::algos::{Algo, MatchParams};
use crate::cli::Args;
use crate::coordinator::metrics::Metrics;
use crate::core::Regions1D;
use crate::engine::{algo_matcher, DdmEngine, ExecCtx, Matcher};
use crate::exec::ThreadPool;

/// Everything a figure bench needs.
pub struct FigCtx {
    pub args: Args,
    pub meter: Meter,
    pub model: ModelOpts,
    /// Shared worker pool (engines built via [`FigCtx::engine`] reuse
    /// it so the cost log captures their regions).
    pub pool: Arc<ThreadPool>,
    pub quick: bool,
    pub csv_dir: Option<std::path::PathBuf>,
    /// The same counters/gauges/histograms registry the coordinator
    /// and net services report through: every [`measure`](Self::measure)
    /// rep lands in the `rep_ns` (measured) and `modeled_ns` (modeled
    /// WCT) histograms, so bench-side tail latency renders via the one
    /// shared [`Metrics::table`] path.
    pub registry: RefCell<Metrics>,
}

impl FigCtx {
    /// Parse argv; create a pool able to serve the largest P requested.
    pub fn new(max_threads: usize) -> Self {
        let args = Args::from_env();
        let quick = args.flag("quick");
        let meter = Meter::from_args(&args);
        let pool = Arc::new(ThreadPool::new(max_threads.saturating_sub(1)));
        // Fork-join term: the modeled testbed's OpenMP-style barrier
        // (~10 µs, ModelOpts::default). Calibrating it from this host's
        // wall-clock would charge the 1-core scheduler's wakeup latency
        // (~1 ms under oversubscription) to a 16-core machine and
        // unfairly penalize region-rich algorithms like Parallel SBM;
        // the measured value is printed for transparency.
        let model = ModelOpts::default();
        let calibrated = calibrate_fork_join(&pool);
        if !args.flag("quick") {
            eprintln!(
                "(this host's region dispatch latency: {:?}; model charges {:?})",
                calibrated, model.fork_join
            );
        }
        let csv_dir = args
            .get("csv")
            .map(std::path::PathBuf::from)
            .or_else(|| args.flag("csv").then(|| "bench_results".into()));
        Self {
            args,
            meter,
            model,
            pool,
            quick,
            csv_dir,
            registry: RefCell::new(Metrics::default()),
        }
    }

    /// Thread counts to sweep (paper Figs. 9/10/14 use 1..32).
    pub fn thread_counts(&self) -> Vec<usize> {
        let default: &[usize] = if self.quick {
            &[1, 2, 4, 8, 16, 32]
        } else {
            &[1, 2, 4, 6, 8, 12, 16, 20, 24, 28, 32]
        };
        self.args.list("threads", default)
    }

    /// Measure one (algo, P) point: returns measured wall-clock summary,
    /// modeled WCT (mean over reps), and the result value of `f`.
    pub fn measure<F>(&self, p: usize, mut f: F) -> Point
    where
        F: FnMut(&ThreadPool, usize) -> u64,
    {
        let mut measured = Vec::with_capacity(self.meter.reps);
        let mut modeled = Vec::with_capacity(self.meter.reps);
        let mut value = 0u64;
        for _ in 0..self.meter.warmup {
            std::hint::black_box(f(&self.pool, p));
        }
        for _ in 0..self.meter.reps.max(1) {
            self.pool.start_log();
            let t0 = Instant::now();
            value = std::hint::black_box(f(&self.pool, p));
            measured.push(t0.elapsed().as_secs_f64());
            let log = self.pool.take_log();
            modeled.push(self.model.modeled_wct(&log, p));
        }
        {
            let mut reg = self.registry.borrow_mut();
            for &s in &measured {
                reg.observe_ns("rep_ns", (s * 1e9) as u64);
            }
            for &s in &modeled {
                reg.observe_ns("modeled_ns", (s * 1e9) as u64);
            }
        }
        Point {
            measured: summarize(&measured),
            modeled: summarize(&modeled),
            value,
        }
    }

    /// An engine for one in-tree algorithm, sharing this harness's
    /// pool (so region costs land in the harness's log) and running
    /// `p` workers per call.
    pub fn engine(&self, algo: Algo, p: usize, params: &MatchParams) -> DdmEngine {
        DdmEngine::builder()
            .algo(algo)
            .threads(p)
            .params(*params)
            .pool(Arc::clone(&self.pool))
            .build()
    }

    /// The bare matcher for one in-tree algorithm (drive it through
    /// [`Self::measure_matcher`]).
    pub fn matcher(&self, algo: Algo, params: &MatchParams) -> Arc<dyn Matcher> {
        algo_matcher(algo, params)
    }

    /// Measure the counting path of **any** [`Matcher`] — in-tree or
    /// out-of-tree — at `p` workers, under the same cost-log protocol
    /// as [`Self::measure`]. This is how custom backends get
    /// benchmarked without touching the `Algo` enum.
    pub fn measure_matcher(
        &self,
        matcher: &dyn Matcher,
        p: usize,
        subs: &Regions1D,
        upds: &Regions1D,
    ) -> Point {
        self.measure(p, |pool, nthreads| {
            let ctx = ExecCtx::new(pool, nthreads);
            matcher.count_1d(&ctx, subs, upds)
        })
    }

    /// Write a table to `<csv_dir>/<name>.csv` when CSV output is on.
    pub fn maybe_csv(&self, name: &str, table: &super::table::Table) {
        if let Some(dir) = &self.csv_dir {
            let path = dir.join(format!("{name}.csv"));
            match table.write_csv(&path) {
                Ok(()) => println!("(csv written to {})", path.display()),
                Err(e) => eprintln!("csv write failed: {e}"),
            }
        }
    }

    /// Write the machine-readable `BENCH_<name>.json` result file.
    pub fn maybe_json(&self, name: &str, table: &super::table::Table) {
        json_with_args(&self.args, self.quick, name, table);
    }

    /// Emit one bench table everywhere it is tracked: stdout already
    /// printed by the caller, CSV when `--csv` is on, and the
    /// `BENCH_<name>.json` trajectory file.
    pub fn emit(&self, name: &str, table: &super::table::Table) {
        self.maybe_csv(name, table);
        self.maybe_json(name, table);
    }
}

/// The machine-readable `BENCH_<name>.json` trajectory file (how the
/// perf trajectory is tracked across PRs), from raw [`Args`] — for
/// bench binaries that never build a [`FigCtx`] (fig13's re-exec'ing
/// memory bench); everything else goes through [`FigCtx::emit`]. On by
/// default into `bench_results/`; redirect with `--json <dir>`,
/// disable with `--no-json`.
pub fn json_with_args(args: &Args, quick: bool, name: &str, table: &super::table::Table) {
    if args.flag("no-json") {
        return;
    }
    let dir = args
        .get("json")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("bench_results"));
    let path = dir.join(format!("BENCH_{name}.json"));
    let host = super::sysinfo::summary_line();
    let quick = if quick { "true" } else { "false" };
    let meta = [("fig", name), ("host", host.as_str()), ("quick", quick)];
    match table.write_json(&path, &meta) {
        Ok(()) => println!("(json written to {})", path.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}

/// One measured (algo, P) point.
#[derive(Debug, Clone, Copy)]
pub struct Point {
    /// Raw wall-clock on this host (oversubscribed for P > cores).
    pub measured: Summary,
    /// Work-span modeled wall-clock for the paper's 16c/32t testbed.
    pub modeled: Summary,
    /// The algorithm's output (K) — keeps work observable & checked.
    pub value: u64,
}

/// Calibrate the fork-join cost: mean wall time of an empty 1-thread
/// region (channel send + condvar join), the per-region overhead term.
pub fn calibrate_fork_join(pool: &ThreadPool) -> std::time::Duration {
    // warmup
    for _ in 0..16 {
        pool.run(1, |_| {});
    }
    let reps = 256;
    let t0 = Instant::now();
    for _ in 0..reps {
        pool.run(2.min(pool.max_threads()), |_| {});
    }
    t0.elapsed() / reps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_is_small_but_positive() {
        let pool = ThreadPool::new(1);
        let fj = calibrate_fork_join(&pool);
        assert!(fj > std::time::Duration::ZERO);
        assert!(fj < std::time::Duration::from_millis(60), "{fj:?}");
    }

    /// The harness drives any `&dyn Matcher` — including one that is
    /// not in the `Algo` enum.
    #[test]
    fn measure_matcher_accepts_custom_backend() {
        use crate::core::sink::MatchSink;

        struct CountEverything;
        impl Matcher for CountEverything {
            fn name(&self) -> &str {
                "count-everything"
            }
            fn match_1d(
                &self,
                _ctx: &ExecCtx<'_>,
                subs: &Regions1D,
                upds: &Regions1D,
                sink: &mut dyn crate::core::sink::MatchSink,
            ) {
                for i in 0..subs.len() as u32 {
                    for j in 0..upds.len() as u32 {
                        sink.report(i, j);
                    }
                }
            }
        }

        let ctx = FigCtx {
            args: Args::from_iter(Vec::<String>::new()),
            meter: Meter { warmup: 0, reps: 1 },
            model: ModelOpts::default(),
            pool: Arc::new(ThreadPool::new(1)),
            quick: true,
            csv_dir: None,
            registry: RefCell::new(Metrics::default()),
        };
        let regions = Regions1D {
            lo: vec![0.0; 5],
            hi: vec![1.0; 5],
        };
        let point = ctx.measure_matcher(&CountEverything, 2, &regions, &regions);
        assert_eq!(point.value, 25);
        // Reps land in the shared registry's histograms.
        let reg = ctx.registry.borrow();
        assert!(reg.hist("rep_ns").is_some_and(|h| h.count() == 1));
        assert!(reg.hist("modeled_ns").is_some_and(|h| h.count() == 1));
        drop(reg);

        // In-tree matchers ride the same path.
        let psbm = ctx.matcher(Algo::Psbm, &MatchParams::default());
        let point = ctx.measure_matcher(psbm.as_ref(), 2, &regions, &regions);
        assert_eq!(point.value, 25);
    }
}

//! Measurement harness (offline replacement for `criterion`), plus the
//! paper-specific speedup model and system reporting.
//!
//! Every bench binary in `rust/benches/` uses this module to produce
//! the rows of the corresponding paper figure: repeated measurements
//! with warmup, mean ± sd, peak-RSS readings, paper-style aligned
//! tables and optional CSV output.

pub mod harness;
pub mod netbench;
pub mod prop;
pub mod rss;
pub mod speedup;
pub mod stats;
pub mod sysinfo;
pub mod table;

use std::time::{Duration, Instant};

/// Measurement configuration shared by all bench binaries.
#[derive(Debug, Clone, Copy)]
pub struct Meter {
    pub warmup: usize,
    pub reps: usize,
}

impl Meter {
    /// Paper methodology: averages of 50 independent runs. That is not
    /// affordable for every point on this single-core testbed; benches
    /// default to 3 reps (2 in `--quick` mode) and report dispersion
    /// via the CI columns so noise is visible rather than hidden.
    /// `--reps 50` restores the paper's protocol.
    pub fn from_args(args: &crate::cli::Args) -> Self {
        let quick = args.flag("quick");
        Meter {
            warmup: args.opt("warmup", if quick { 0 } else { 1 }),
            reps: args.opt("reps", if quick { 2 } else { 3 }),
        }
    }

    /// Measure `f`, returning per-rep wall-clock durations.
    pub fn time<F: FnMut()>(&self, mut f: F) -> Vec<Duration> {
        for _ in 0..self.warmup {
            f();
        }
        (0..self.reps.max(1))
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed()
            })
            .collect()
    }

    /// Measure `f` which returns a value; the last value is returned
    /// alongside the timings (used to keep results observable and to
    /// carry per-run metadata like busy times).
    pub fn time_with<T, F: FnMut() -> T>(&self, mut f: F) -> (Vec<Duration>, T) {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut out = None;
        let times = (0..self.reps.max(1))
            .map(|_| {
                let t0 = Instant::now();
                out = Some(std::hint::black_box(f()));
                t0.elapsed()
            })
            .collect();
        (times, out.unwrap())
    }
}

/// Seconds as f64 (plotting-friendly).
pub fn secs(d: Duration) -> f64 {
    d.as_secs_f64()
}

/// Mean seconds of a run vector.
pub fn mean_secs(ds: &[Duration]) -> f64 {
    if ds.is_empty() {
        return 0.0;
    }
    ds.iter().map(|d| d.as_secs_f64()).sum::<f64>() / ds.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_runs_expected_reps() {
        let m = Meter { warmup: 2, reps: 3 };
        let mut count = 0;
        let times = m.time(|| count += 1);
        assert_eq!(count, 5);
        assert_eq!(times.len(), 3);
    }

    #[test]
    fn time_with_returns_value() {
        let m = Meter { warmup: 0, reps: 2 };
        let (times, v) = m.time_with(|| 41 + 1);
        assert_eq!(times.len(), 2);
        assert_eq!(v, 42);
    }

    #[test]
    fn mean_secs_sane() {
        let ds = vec![Duration::from_millis(10), Duration::from_millis(20)];
        let m = mean_secs(&ds);
        assert!((m - 0.015).abs() < 1e-9);
        assert_eq!(mean_secs(&[]), 0.0);
    }
}

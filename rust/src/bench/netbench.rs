//! Loopback measurement helpers for the network service.
//!
//! Lives in `bench/` (not `net/`) because it times things: the hot
//! `net/` tree is wallclock-free by lint, while this module drives a
//! running server over real sockets with `Instant` in hand. Used by
//! `ddm bench-net` and `benches/abl_net.rs`.
//!
//! Every run doubles as a correctness check: the diff stream observed
//! over the wire is asserted equal — epoch numbers included — to an
//! in-process session replaying the identical op script, so a
//! throughput number from this module is also an end-to-end
//! equivalence witness.

use std::time::Instant;

use crate::core::interval::Interval;
use crate::engine::DdmEngine;
use crate::net::{NetClient, RegionOp};
use crate::obs::Histogram;
use crate::prng::Rng;
use crate::shard::AnySession;

/// One loopback run's outcome.
#[derive(Debug, Clone, Copy)]
pub struct LoopbackResult {
    /// Region ops staged over the wire (all connections, all epochs).
    pub ops: usize,
    /// Staging throughput: ops sent / wall-clock of send+sync phases.
    pub ops_per_s: f64,
    /// Mean commit→diff round-trip per epoch, seconds.
    pub commit_latency_s: f64,
    /// Median commit→diff round-trip, seconds (log-bucketed histogram
    /// quantile, so p50 ≤ p99 holds by construction).
    pub commit_p50_s: f64,
    /// 99th-percentile commit→diff round-trip, seconds.
    pub commit_p99_s: f64,
    /// Total pairs added across all epoch diffs.
    pub added: usize,
    /// Total pairs removed across all epoch diffs.
    pub removed: usize,
}

/// The per-connection churn script: connection `c` of `conns` owns the
/// keys `k ≡ c (mod conns)` below `n` — disjoint ranges, so the LWW
/// batch semantics make the multi-connection interleaving
/// deterministic. Epoch 0 upserts a subscription + update region per
/// owned key; later epochs move ~20% of them.
pub fn conn_script(
    seed: u64,
    conn: usize,
    conns: usize,
    n: usize,
    epochs: usize,
    d: usize,
) -> Vec<Vec<RegionOp>> {
    let mut rng = Rng::new(seed ^ (conn as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let space = 1e6;
    let mut rect = |rng: &mut Rng| -> Vec<Interval> {
        (0..d)
            .map(|_| {
                let lo = rng.uniform(0.0, space);
                Interval::new(lo, lo + rng.uniform(space * 1e-4, space * 1e-2))
            })
            .collect()
    };
    let keys: Vec<u32> = (0..n as u32).filter(|k| *k as usize % conns == conn).collect();
    let mut out = Vec::with_capacity(epochs.max(1));
    let mut first = Vec::with_capacity(2 * keys.len());
    for &key in &keys {
        first.push(RegionOp::UpsertSub { key, rect: rect(&mut rng) });
        first.push(RegionOp::UpsertUpd { key, rect: rect(&mut rng) });
    }
    out.push(first);
    if keys.is_empty() {
        out.resize(epochs.max(1), Vec::new());
        return out;
    }
    let moves = (keys.len() / 5).max(1);
    for _ in 1..epochs.max(1) {
        let mut ops = Vec::with_capacity(moves);
        for _ in 0..moves {
            let key = keys[rng.below(keys.len() as u64) as usize];
            let r = rect(&mut rng);
            ops.push(if rng.chance(0.5) {
                RegionOp::UpsertSub { key, rect: r }
            } else {
                RegionOp::UpsertUpd { key, rect: r }
            });
        }
        out.push(ops);
    }
    out
}

fn apply_local(sess: &mut AnySession, ops: &[RegionOp]) {
    for op in ops {
        match op {
            RegionOp::UpsertSub { key, rect } => sess.upsert_subscription(*key, rect),
            RegionOp::UpsertUpd { key, rect } => sess.upsert_update(*key, rect),
            RegionOp::RemoveSub { key } => sess.remove_subscription(*key),
            RegionOp::RemoveUpd { key } => sess.remove_update(*key),
        }
    }
}

/// Drive the churn script against a worker at `addr` over `conns`
/// connections with disjoint key ranges; per epoch, every connection
/// stages its ops and `Sync`-barriers, then connection 0 commits.
/// The observed diff stream is asserted equal (epochs included) to an
/// in-process single-session replay of the same ops.
pub fn bench_loopback(
    addr: &str,
    conns: usize,
    n: usize,
    epochs: usize,
    seed: u64,
    d: usize,
) -> crate::Result<LoopbackResult> {
    let conns = conns.max(1);
    let mut clients = Vec::with_capacity(conns);
    for _ in 0..conns {
        clients.push(NetClient::connect(addr)?);
    }
    let scripts: Vec<Vec<Vec<RegionOp>>> = (0..conns)
        .map(|c| conn_script(seed, c, conns, n, epochs, d))
        .collect();

    let engine = DdmEngine::builder().threads(2).build();
    let mut local = AnySession::Single(engine.session(d));

    let mut total_ops = 0usize;
    let mut stage_s = 0.0f64;
    let mut commit_s = 0.0f64;
    let mut commit_hist = Histogram::default();
    let (mut added, mut removed) = (0usize, 0usize);
    let epochs = epochs.max(1);
    for e in 0..epochs {
        let t0 = Instant::now();
        for (c, client) in clients.iter_mut().enumerate() {
            let ops = &scripts[c][e];
            total_ops += ops.len();
            client.batch(ops.clone())?;
        }
        // Barrier: a SyncAck proves the server consumed everything this
        // connection sent before it.
        for (c, client) in clients.iter_mut().enumerate() {
            client.sync((e * conns + c) as u64)?;
        }
        stage_s += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let diff = clients[0].commit()?;
        let rt = t1.elapsed();
        commit_s += rt.as_secs_f64();
        commit_hist.record_duration(rt);

        for script in &scripts {
            apply_local(&mut local, &script[e]);
        }
        let want = local.commit();
        if want != diff {
            crate::bail!(
                "epoch {e}: wire diff (epoch {}, +{} -{}) != local replay (epoch {}, +{} -{})",
                diff.epoch,
                diff.added.len(),
                diff.removed.len(),
                want.epoch,
                want.added.len(),
                want.removed.len()
            );
        }
        added += diff.added.len();
        removed += diff.removed.len();
    }
    Ok(LoopbackResult {
        ops: total_ops,
        ops_per_s: total_ops as f64 / stage_s.max(1e-9),
        commit_latency_s: commit_s / epochs as f64,
        commit_p50_s: commit_hist.p50() as f64 / 1e9,
        commit_p99_s: commit_hist.p99() as f64 / 1e9,
        added,
        removed,
    })
}

//! Mini property-testing harness (offline replacement for `proptest`).
//!
//! Runs a seeded generator/check loop; on failure it reports the exact
//! case seed so the case can be replayed with
//! `DDM_PROP_SEED=<seed> cargo test <name>`. Case count scales with
//! `DDM_PROP_CASES` (default 64).

use crate::prng::Rng;

/// Number of cases to run (env-overridable).
pub fn cases() -> u64 {
    std::env::var("DDM_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `check(rng)` for `cases()` seeds derived from `base_seed`.
///
/// `check` returns `Err(description)` to fail the property; the failure
/// message includes the replay seed.
pub fn prop_check<F>(name: &str, base_seed: u64, check: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    // Replay mode: a single explicit seed.
    if let Ok(seed) = std::env::var("DDM_PROP_SEED") {
        let seed: u64 = seed.parse().expect("DDM_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = check(&mut rng) {
            panic!("property '{name}' failed under replay seed {seed}: {msg}");
        }
        return;
    }
    let mut seeder = Rng::new(base_seed);
    for case in 0..cases() {
        let seed = seeder.next_u64();
        let mut rng = Rng::new(seed);
        if let Err(msg) = check(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} \
                 (replay: DDM_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert-eq helper producing a `Result` for use inside properties.
pub fn expect_eq<T: PartialEq + std::fmt::Debug>(
    got: &T,
    want: &T,
    what: &str,
) -> Result<(), String> {
    if got == want {
        Ok(())
    } else {
        Err(format!("{what}: got {got:?}, want {want:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The engine-API agreement property: for random 1-D **and** 3-D
    /// workloads, every `Matcher` implementation produces the
    /// identical canonical pair set through `DdmEngine`.
    #[test]
    fn engine_matchers_agree_on_random_1d_and_3d_workloads() {
        use crate::algos::Algo;
        use crate::core::interval::Interval;
        use crate::core::region::{random_regions_1d, RegionsNd};
        use crate::engine::DdmEngine;
        use crate::exec::ThreadPool;
        use std::sync::Arc;

        let pool = Arc::new(ThreadPool::new(3));
        let engines: Vec<DdmEngine> = Algo::ALL
            .iter()
            .map(|&algo| {
                DdmEngine::builder()
                    .algo(algo)
                    .threads(3)
                    .ncells(48)
                    .pool(Arc::clone(&pool))
                    .build()
            })
            .collect();

        prop_check("engine-matchers-agree", 0xE16E, |rng| {
            // ---- 1-D ----------------------------------------------------
            let n = 1 + rng.below(120) as usize;
            let m = 1 + rng.below(120) as usize;
            let l = rng.uniform(0.5, 25.0);
            let subs = random_regions_1d(rng, n, 200.0, l);
            let upds = random_regions_1d(rng, m, 200.0, l);
            let want = engines[0].pairs_1d(&subs, &upds);
            for e in &engines[1..] {
                let got = e.pairs_1d(&subs, &upds);
                expect_eq(&got, &want, e.algo_name())?;
                if e.count_1d(&subs, &upds) != want.len() as u64 {
                    return Err(format!("{}: count != pair-set size", e.algo_name()));
                }
            }

            // ---- 3-D ----------------------------------------------------
            let d = 3;
            let mut subs3 = RegionsNd::new(d);
            let mut upds3 = RegionsNd::new(d);
            for _ in 0..1 + rng.below(40) {
                let rect: Vec<Interval> = (0..d)
                    .map(|_| {
                        let lo = rng.uniform(0.0, 60.0);
                        Interval::new(lo, lo + rng.uniform(0.0, 15.0))
                    })
                    .collect();
                subs3.push(&rect);
            }
            for _ in 0..1 + rng.below(40) {
                let rect: Vec<Interval> = (0..d)
                    .map(|_| {
                        let lo = rng.uniform(0.0, 60.0);
                        Interval::new(lo, lo + rng.uniform(0.0, 15.0))
                    })
                    .collect();
                upds3.push(&rect);
            }
            let want3 = engines[0].pairs_nd(&subs3, &upds3);
            for e in &engines[1..] {
                expect_eq(&e.pairs_nd(&subs3, &upds3), &want3, e.algo_name())?;
            }
            Ok(())
        });
    }

    #[test]
    fn passing_property_passes() {
        prop_check("tautology", 1, |rng| {
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay: DDM_PROP_SEED=")]
    fn failing_property_reports_seed() {
        prop_check("always-fails", 2, |_| Err("nope".into()));
    }

    #[test]
    fn expect_eq_formats() {
        assert!(expect_eq(&1, &1, "x").is_ok());
        let e = expect_eq(&1, &2, "x").unwrap_err();
        assert!(e.contains("got 1"));
    }
}

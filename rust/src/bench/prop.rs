//! Mini property-testing harness (offline replacement for `proptest`).
//!
//! Runs a seeded generator/check loop; on failure it reports the exact
//! case seed so the case can be replayed with
//! `DDM_PROP_SEED=<seed> cargo test <name>`. Case count scales with
//! `DDM_PROP_CASES` (default 64).

use crate::prng::Rng;

/// Number of cases to run (env-overridable).
pub fn cases() -> u64 {
    std::env::var("DDM_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `check(rng)` for `cases()` seeds derived from `base_seed`.
///
/// `check` returns `Err(description)` to fail the property; the failure
/// message includes the replay seed.
pub fn prop_check<F>(name: &str, base_seed: u64, check: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    // Replay mode: a single explicit seed.
    if let Ok(seed) = std::env::var("DDM_PROP_SEED") {
        let seed: u64 = seed.parse().expect("DDM_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = check(&mut rng) {
            panic!("property '{name}' failed under replay seed {seed}: {msg}");
        }
        return;
    }
    let mut seeder = Rng::new(base_seed);
    for case in 0..cases() {
        let seed = seeder.next_u64();
        let mut rng = Rng::new(seed);
        if let Err(msg) = check(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} \
                 (replay: DDM_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert-eq helper producing a `Result` for use inside properties.
pub fn expect_eq<T: PartialEq + std::fmt::Debug>(
    got: &T,
    want: &T,
    what: &str,
) -> Result<(), String> {
    if got == want {
        Ok(())
    } else {
        Err(format!("{what}: got {got:?}, want {want:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check("tautology", 1, |rng| {
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay: DDM_PROP_SEED=")]
    fn failing_property_reports_seed() {
        prop_check("always-fails", 2, |_| Err("nope".into()));
    }

    #[test]
    fn expect_eq_formats() {
        assert!(expect_eq(&1, &1, "x").is_ok());
        let e = expect_eq(&1, &2, "x").unwrap_err();
        assert!(e.contains("got 1"));
    }
}

//! Memory metrics (paper Fig. 13: peak resident set size, VmHWM).

/// Read a field (kB) from /proc/self/status.
fn proc_status_kb(field: &str) -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(field) {
            let rest = rest.trim_start_matches(':').trim();
            let kb: u64 = rest.split_whitespace().next()?.parse().ok()?;
            return Some(kb);
        }
    }
    None
}

/// Peak resident set size in bytes (VmHWM — what the paper reports).
pub fn peak_rss_bytes() -> Option<u64> {
    proc_status_kb("VmHWM").map(|kb| kb * 1024)
}

/// Current resident set size in bytes (VmRSS).
pub fn current_rss_bytes() -> Option<u64> {
    proc_status_kb("VmRSS").map(|kb| kb * 1024)
}

/// Human-readable bytes.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_readable_on_linux() {
        let peak = peak_rss_bytes().expect("VmHWM readable");
        let cur = current_rss_bytes().expect("VmRSS readable");
        assert!(peak > 0 && cur > 0);
        assert!(peak >= cur / 2, "peak {peak} vs current {cur}");
    }

    #[test]
    fn peak_grows_with_allocation() {
        let before = peak_rss_bytes().unwrap();
        let v: Vec<u8> = vec![1; 64 << 20]; // 64 MiB touched
        std::hint::black_box(&v);
        let after = peak_rss_bytes().unwrap();
        assert!(
            after >= before + (32 << 20),
            "peak rss did not grow: {before} -> {after}"
        );
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(7 * 1024 * 1024 * 1024), "7.00 GiB");
    }
}

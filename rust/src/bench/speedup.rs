//! Work-span speedup model (DESIGN.md §3, substitution 1).
//!
//! The paper measures wall-clock on a dual-socket 16-core (32 HT
//! threads) Xeon. This reproduction testbed has **one** physical core,
//! so wall-clock under P-thread oversubscription measures the OS
//! scheduler, not the algorithm. Instead the [`crate::exec::ThreadPool`]
//! measures each worker's **CPU time** (immune to preemption), and this
//! module converts a logged run into the wall-clock a P-core machine
//! would see:
//!
//! ```text
//! WCT(P) = Σ_regions max( max_p busy_p , Σ_p busy_p / eff(P) )
//!        + serial + fork_join_cost · #regions
//! ```
//!
//! `eff(P)` models the paper's Hyper-Threading knee: beyond the
//! physical core count C the extra "virtual" cores only add ~22%
//! throughput (the 16–28% band the paper cites from Intel [44]).
//!
//! The model intentionally preserves the *shapes* of Figs. 9/10/14 —
//! embarrassingly-parallel BFM scales ~linearly; SBM saturates because
//! of its serial master step and sort span; the HT region bends — while
//! absolute numbers are tied to this host's single-core throughput.

use std::time::Duration;

/// A logged parallel execution (filled by `ThreadPool` logging).
#[derive(Debug, Clone, Default)]
pub struct CostLog {
    /// Per-region, per-worker CPU busy times.
    pub regions: Vec<Vec<Duration>>,
    /// CPU time spent in master-only (serial) sections.
    pub serial: Duration,
}

impl CostLog {
    pub fn total_work(&self) -> Duration {
        let par: Duration = self
            .regions
            .iter()
            .flat_map(|r| r.iter())
            .sum();
        par + self.serial
    }
}

/// Model parameters.
#[derive(Debug, Clone, Copy)]
pub struct ModelOpts {
    /// Physical cores of the modeled machine (paper Table 1: 16).
    pub physical_cores: usize,
    /// Max logical CPUs (paper: 32). P beyond this is not modeled.
    pub logical_cpus: usize,
    /// Relative throughput of one HT sibling pair vs one core (~1.22).
    pub ht_throughput: f64,
    /// Fork-join cost per parallel region (calibrated or default 10 µs).
    pub fork_join: Duration,
}

impl Default for ModelOpts {
    /// Mirror of the paper's testbed (Table 1).
    fn default() -> Self {
        ModelOpts {
            physical_cores: 16,
            logical_cpus: 32,
            ht_throughput: 1.22,
            fork_join: Duration::from_micros(10),
        }
    }
}

impl ModelOpts {
    /// Effective core count available to a P-thread region.
    pub fn effective_cores(&self, p: usize) -> f64 {
        let c = self.physical_cores as f64;
        let p = p.min(self.logical_cpus) as f64;
        if p <= c {
            p
        } else {
            // c cores fully used; (p - c) of them run a second HT
            // thread, each such pair delivering ht_throughput total.
            let paired = p - c;
            (c - paired) + paired * self.ht_throughput
        }
    }

    /// Modeled wall-clock for a logged run at `p` threads.
    pub fn modeled_wct(&self, log: &CostLog, p: usize) -> f64 {
        let mut total = log.serial.as_secs_f64();
        let eff = self.effective_cores(p);
        for region in &log.regions {
            let max_busy = region
                .iter()
                .map(|d| d.as_secs_f64())
                .fold(0.0f64, f64::max);
            let sum_busy: f64 = region.iter().map(|d| d.as_secs_f64()).sum();
            // A region cannot finish before its critical path (max) nor
            // before the machine has executed all its work (sum/eff).
            total += max_busy.max(sum_busy / eff) + self.fork_join.as_secs_f64();
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn secs(s: f64) -> Duration {
        Duration::from_secs_f64(s)
    }

    fn balanced_log(p: usize, work: f64) -> CostLog {
        CostLog {
            regions: vec![(0..p).map(|_| secs(work / p as f64)).collect()],
            serial: Duration::ZERO,
        }
    }

    #[test]
    fn perfectly_balanced_scales_linearly() {
        let m = ModelOpts {
            fork_join: Duration::ZERO,
            ..ModelOpts::default()
        };
        let t1 = m.modeled_wct(&balanced_log(1, 16.0), 1);
        let t16 = m.modeled_wct(&balanced_log(16, 16.0), 16);
        assert!((t1 / t16 - 16.0).abs() < 1e-9);
    }

    #[test]
    fn ht_region_bends() {
        let m = ModelOpts {
            fork_join: Duration::ZERO,
            ..ModelOpts::default()
        };
        let t16 = m.modeled_wct(&balanced_log(16, 32.0), 16);
        let t32 = m.modeled_wct(&balanced_log(32, 32.0), 32);
        let s = t16 / t32;
        // 32 threads on 16 HT cores: eff = 16 * 1.22 = 19.52 -> speedup
        // over 16 threads is 1.22, far from 2.0.
        assert!((s - 1.22).abs() < 1e-6, "got {s}");
    }

    #[test]
    fn serial_fraction_limits_speedup() {
        let m = ModelOpts {
            fork_join: Duration::ZERO,
            ..ModelOpts::default()
        };
        let mk = |p: usize| CostLog {
            serial: secs(1.0),
            regions: vec![(0..p).map(|_| secs(1.0 / p as f64)).collect()],
        };
        let t1 = m.modeled_wct(&mk(1), 1);
        let t16 = m.modeled_wct(&mk(16), 16);
        // Amdahl: 2.0 / (1 + 1/16) ≈ 1.88
        assert!((t1 / t16 - 2.0 / (1.0 + 1.0 / 16.0)).abs() < 1e-9);
    }

    #[test]
    fn imbalance_limits_speedup() {
        let m = ModelOpts {
            fork_join: Duration::ZERO,
            ..ModelOpts::default()
        };
        // One worker got all the work.
        let log = CostLog {
            regions: vec![vec![secs(1.0), secs(0.0), secs(0.0), secs(0.0)]],
            serial: Duration::ZERO,
        };
        assert!((m.modeled_wct(&log, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fork_join_counts_per_region() {
        let m = ModelOpts {
            fork_join: Duration::from_millis(1),
            ..ModelOpts::default()
        };
        let log = CostLog {
            regions: vec![vec![secs(0.0)]; 5],
            serial: Duration::ZERO,
        };
        assert!((m.modeled_wct(&log, 1) - 0.005).abs() < 1e-9);
    }

    #[test]
    fn effective_cores_monotone() {
        let m = ModelOpts::default();
        let mut prev = 0.0;
        for p in 1..=32 {
            let e = m.effective_cores(p);
            assert!(e >= prev);
            prev = e;
        }
        assert_eq!(m.effective_cores(16), 16.0);
        assert!((m.effective_cores(32) - 16.0 * 1.22).abs() < 1e-9);
    }
}

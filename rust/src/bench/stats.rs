//! Summary statistics for repeated measurements.

/// Mean, standard deviation, min/max and a 95% confidence half-width
/// (Student t for small samples, the paper's "50 independent runs"
/// methodology scaled down).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
    pub ci95: f64,
}

/// Two-sided 95% Student-t quantiles for df = 1..=30 (df > 30 ≈ 1.96).
const T95: [f64; 30] = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
    2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
    2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
];

pub fn summarize(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary {
            n: 0,
            mean: 0.0,
            sd: 0.0,
            min: 0.0,
            max: 0.0,
            ci95: 0.0,
        };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let sd = var.sqrt();
    let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        min = min.min(x);
        max = max.max(x);
    }
    let ci95 = if n > 1 {
        let t = T95.get(n - 2).copied().unwrap_or(1.96);
        t * sd / (n as f64).sqrt()
    } else {
        0.0
    };
    Summary {
        n,
        mean,
        sd,
        min,
        max,
        ci95,
    }
}

/// Relative speedup S(P) = T(1)/T(P) (paper §5).
pub fn speedup(t1: f64, tp: f64) -> f64 {
    if tp > 0.0 {
        t1 / tp
    } else {
        f64::NAN
    }
}

/// Format seconds with an adaptive unit (s / ms / µs).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constants() {
        let s = summarize(&[2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.sd, 0.0);
        assert_eq!(s.ci95, 0.0);
        assert_eq!((s.min, s.max), (2.0, 2.0));
    }

    #[test]
    fn summary_known_values() {
        // sample sd of [1,2,3,4] = sqrt(5/3)
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.sd - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        // t(df=3) = 3.182
        assert!((s.ci95 - 3.182 * s.sd / 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(summarize(&[]).n, 0);
        let s = summarize(&[5.0]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.ci95, 0.0);
    }

    #[test]
    fn speedup_basic() {
        assert_eq!(speedup(10.0, 2.0), 5.0);
        assert!(speedup(1.0, 0.0).is_nan());
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(0.0000025), "2.5 µs");
    }
}

//! Host description (the paper's Table 1 analog, printed by benches).

use std::sync::OnceLock;

#[derive(Debug, Clone)]
pub struct SysInfo {
    pub cpu_model: String,
    pub physical_cores: usize,
    pub logical_cpus: usize,
    pub ram_gb: f64,
    pub os: String,
}

fn read_cpuinfo() -> (String, usize, usize) {
    let text = std::fs::read_to_string("/proc/cpuinfo").unwrap_or_default();
    let mut model = String::from("unknown");
    let mut logical = 0usize;
    let mut cores_per_socket = 0usize;
    let mut sockets = std::collections::HashSet::new();
    for line in text.lines() {
        let mut kv = line.splitn(2, ':');
        let k = kv.next().unwrap_or("").trim();
        let v = kv.next().unwrap_or("").trim();
        match k {
            "model name" => {
                if model == "unknown" {
                    model = v.to_string();
                }
                logical += 1;
            }
            "cpu cores" => cores_per_socket = v.parse().unwrap_or(0),
            "physical id" => {
                sockets.insert(v.to_string());
            }
            _ => {}
        }
    }
    let physical = if cores_per_socket > 0 {
        cores_per_socket * sockets.len().max(1)
    } else {
        logical.max(1)
    };
    (model, physical.max(1), logical.max(1))
}

fn read_ram_gb() -> f64 {
    let text = std::fs::read_to_string("/proc/meminfo").unwrap_or_default();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("MemTotal:") {
            if let Some(kb) = rest.trim().split_whitespace().next() {
                if let Ok(kb) = kb.parse::<f64>() {
                    return kb / (1024.0 * 1024.0);
                }
            }
        }
    }
    0.0
}

static SYSINFO: OnceLock<SysInfo> = OnceLock::new();

pub fn get() -> &'static SysInfo {
    SYSINFO.get_or_init(|| {
        let (cpu_model, physical_cores, logical_cpus) = read_cpuinfo();
        SysInfo {
            cpu_model,
            physical_cores,
            logical_cpus,
            ram_gb: read_ram_gb(),
            os: std::fs::read_to_string("/etc/os-release")
                .ok()
                .and_then(|t| {
                    t.lines()
                        .find(|l| l.starts_with("PRETTY_NAME="))
                        .map(|l| l.trim_start_matches("PRETTY_NAME=").trim_matches('"').to_string())
                })
                .unwrap_or_else(|| "linux".to_string()),
        }
    })
}

/// One-line host summary for bench banners.
pub fn summary_line() -> String {
    let s = get();
    format!(
        "{} | {} physical / {} logical cpus | {:.1} GB RAM | {}",
        s.cpu_model, s.physical_cores, s.logical_cpus, s.ram_gb, s.os
    )
}

/// The paper's Table 1 as a rendered table for EXPERIMENTS.md.
pub fn table1() -> super::table::Table {
    let s = get();
    let mut t = super::table::Table::new(vec!["field", "paper (Table 1)", "this host"]);
    t.row(vec!["CPU", "Intel Xeon E5-2640", s.cpu_model.as_str()]);
    t.row(vec!["Processors", "2", "1"]);
    let pc = s.physical_cores.to_string();
    t.row(vec!["Total cores", "16", pc.as_str()]);
    let lc = s.logical_cpus.to_string();
    t.row(vec!["Logical CPUs", "32 (HT)", lc.as_str()]);
    let ram = format!("{:.0} GB", s.ram_gb);
    t.row(vec!["RAM", "128 GB", ram.as_str()]);
    t.row(vec!["OS", "Ubuntu 16.04.3 LTS", s.os.as_str()]);
    t
}

#[cfg(test)]
mod tests {
    #[test]
    fn sysinfo_is_populated() {
        let s = super::get();
        assert!(s.logical_cpus >= 1);
        assert!(s.physical_cores >= 1);
        assert!(s.ram_gb > 0.0);
        assert!(!super::summary_line().is_empty());
    }

    #[test]
    fn table1_renders() {
        let t = super::table1();
        let s = t.render();
        assert!(s.contains("Xeon E5-2640"));
    }
}

//! Paper-style aligned table output + CSV emission for every bench.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Column-aligned text table with an optional CSV mirror.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let _ = write!(out, "{:>width$}", c, width = widths[i]);
            }
            out.push('\n');
        };
        fmt_row(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(&mut out, row);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    /// Machine-readable mirror for cross-PR perf tracking:
    /// `{<meta fields>, "header": [...], "rows": [[...], ...]}`.
    /// Cells that parse as finite numbers are emitted as JSON numbers,
    /// everything else as strings.
    pub fn to_json(&self, meta: &[(&str, &str)]) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len() + 2);
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
            out
        }
        fn cell(s: &str) -> String {
            // Bare only for strings that are themselves valid JSON
            // numbers (leading digit or minus-digit, no trailing dot —
            // rules out Rust-parseable non-JSON like ".5"/"5."/"nan").
            let mut chars = s.chars();
            let leading = match (chars.next(), chars.next()) {
                (Some(c0), _) if c0.is_ascii_digit() => true,
                (Some('-'), Some(c1)) if c1.is_ascii_digit() => true,
                _ => false,
            };
            let numeric_shape = leading && !s.ends_with('.');
            if numeric_shape && s.parse::<f64>().map(|v| v.is_finite()).unwrap_or(false) {
                s.to_string()
            } else {
                esc(s)
            }
        }
        let mut out = String::from("{\n");
        for (k, v) in meta {
            let _ = writeln!(out, "  {}: {},", esc(k), esc(v));
        }
        let header: Vec<String> = self.header.iter().map(|h| esc(h)).collect();
        let _ = writeln!(out, "  \"header\": [{}],", header.join(", "));
        out.push_str("  \"rows\": [\n");
        for (i, row) in self.rows.iter().enumerate() {
            let cells: Vec<String> = row.iter().map(|c| cell(c)).collect();
            let comma = if i + 1 < self.rows.len() { "," } else { "" };
            let _ = writeln!(out, "    [{}]{comma}", cells.join(", "));
        }
        out.push_str("  ]\n}\n");
        out
    }

    pub fn write_json(&self, path: &Path, meta: &[(&str, &str)]) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json(meta).as_bytes())
    }
}

/// Standard bench banner: figure id, title, parameters.
pub fn banner(fig: &str, title: &str, params: &str) {
    println!("\n=== {fig}: {title} ===");
    if !params.is_empty() {
        println!("    {params}");
    }
    println!("    host: {}", super::sysinfo::summary_line());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_render() {
        let mut t = Table::new(vec!["P", "WCT"]);
        t.row(vec!["1", "10.0"]);
        t.row(vec!["16", "1.25"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains('P') && lines[0].contains("WCT"));
        assert!(lines[3].starts_with("16"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn wrong_width_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(vec!["name", "v"]);
        t.row(vec!["a,b", "1"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
    }

    #[test]
    fn json_types_and_escaping() {
        let mut t = Table::new(vec!["algo", "wct", "note"]);
        t.row(vec!["psbm", "1.25", "he said \"hi\""]);
        t.row(vec!["gbm", "2e-3", "nan"]);
        let j = t.to_json(&[("fig", "t1")]);
        assert!(j.contains("\"fig\": \"t1\""));
        assert!(j.contains("\"header\": [\"algo\", \"wct\", \"note\"]"));
        // Numeric cells stay bare; strings (incl. "nan") are quoted.
        assert!(j.contains("[\"psbm\", 1.25, \"he said \\\"hi\\\"\"]"));
        assert!(j.contains("[\"gbm\", 2e-3, \"nan\"]"));
        // Structure is balanced (cheap well-formedness check).
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }
}

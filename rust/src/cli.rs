//! Minimal command-line parsing (offline replacement for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals, and
//! typed getters with defaults. Used by the `ddm` binary, the examples
//! and every bench harness.

use std::collections::BTreeMap;
use std::str::FromStr;

#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — does NOT include argv[0].
    pub fn from_iter<I, S>(iter: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut it = iter.into_iter().map(Into::into).peekable();
        let mut out = Args::default();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` end-of-options marker
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else {
                    // Lookahead: `--key value` unless next looks like an option.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.opts.insert(body.to_string(), v);
                        }
                        _ => out.flags.push(body.to_string()),
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process arguments (skips argv[0]).
    pub fn from_env() -> Self {
        Self::from_iter(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).is_some_and(|v| v == "true")
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    /// Typed option: `Ok(None)` when absent, `Err(one-line message)`
    /// when present but malformed.
    pub fn try_opt<T>(&self, name: &str) -> Result<Option<T>, String>
    where
        T: FromStr,
        T::Err: std::fmt::Display,
    {
        match self.opts.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse()
                .map(Some)
                .map_err(|e| format!("--{name}={raw}: {e}")),
        }
    }

    /// Typed option with default; a malformed value prints a one-line
    /// error to stderr and exits nonzero (CLI surface: user input is
    /// not a bug, so no panic, no backtrace).
    pub fn opt<T>(&self, name: &str, default: T) -> T
    where
        T: FromStr,
        T::Err: std::fmt::Display,
    {
        match self.try_opt(name) {
            Ok(Some(v)) => v,
            Ok(None) => default,
            Err(e) => die(&e),
        }
    }

    /// Scientific-notation-friendly usize: `Ok(None)` when absent,
    /// `Err` on a malformed value.
    pub fn try_size(&self, name: &str) -> Result<Option<usize>, String> {
        match self.opts.get(name) {
            None => Ok(None),
            Some(raw) => parse_size(raw)
                .map(Some)
                .ok_or_else(|| format!("--{name}={raw}: bad size")),
        }
    }

    /// Scientific-notation-friendly usize (`--n 1e6`); malformed
    /// values exit with a one-line error.
    pub fn size(&self, name: &str, default: usize) -> usize {
        match self.try_size(name) {
            Ok(Some(v)) => v,
            Ok(None) => default,
            Err(e) => die(&e),
        }
    }

    /// Comma-separated typed list: `Ok(None)` when absent, `Err` on
    /// the first malformed element.
    pub fn try_list<T>(&self, name: &str) -> Result<Option<Vec<T>>, String>
    where
        T: FromStr,
        T::Err: std::fmt::Display,
    {
        match self.opts.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|e| format!("--{name}={raw}: {e}"))
                })
                .collect::<Result<Vec<T>, String>>()
                .map(Some),
        }
    }

    /// Comma-separated typed list (`--threads 1,2,4,8`); malformed
    /// values exit with a one-line error.
    pub fn list<T>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: FromStr + Clone,
        T::Err: std::fmt::Display,
    {
        match self.try_list(name) {
            Ok(Some(v)) => v,
            Ok(None) => default.to_vec(),
            Err(e) => die(&e),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// One-line CLI failure: print to stderr and exit nonzero. Bad flags
/// are user input, not bugs — no panic, no backtrace.
pub fn die(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2)
}

/// Parse "1000", "1e6", "2.5e3", "10k", "3M" into usize.
pub fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    if let Ok(v) = s.parse::<usize>() {
        return Some(v);
    }
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1_000.0),
        'm' | 'M' => (&s[..s.len() - 1], 1_000_000.0),
        'g' | 'G' => (&s[..s.len() - 1], 1_000_000_000.0),
        _ => (s, 1.0),
    };
    let v: f64 = num.parse().ok()?;
    if v < 0.0 || !v.is_finite() {
        return None;
    }
    Some((v * mult).round() as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_styles() {
        // NOTE: a bare `--flag` followed by a non-option token is
        // parsed as `--flag <value>` (documented lookahead rule); put
        // flags last or use `--flag=true` when mixing with positionals.
        let a = Args::from_iter([
            "pos1", "--n", "1e6", "--alpha=100", "--threads", "1,2,4", "--verbose",
        ]);
        assert_eq!(a.size("n", 0), 1_000_000);
        assert_eq!(a.opt::<f64>("alpha", 0.0), 100.0);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
        assert_eq!(a.list::<usize>("threads", &[]), vec![1, 2, 4]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::from_iter(["--x=1"]);
        assert_eq!(a.opt::<u32>("missing", 7), 7);
        assert!(!a.flag("quick"));
        assert_eq!(a.list::<u32>("l", &[3, 4]), vec![3, 4]);
    }

    #[test]
    fn flag_followed_by_option() {
        let a = Args::from_iter(["--quick", "--n", "10"]);
        assert!(a.flag("quick"));
        assert_eq!(a.size("n", 0), 10);
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = Args::from_iter(["--a=1", "--", "--not-an-opt"]);
        assert_eq!(a.positional(), &["--not-an-opt".to_string()]);
    }

    /// Malformed values surface as one-line `Err`s through the `try_*`
    /// API (the panicking/aborting behavior is confined to the exiting
    /// wrappers, which benches and the `ddm` binary use).
    #[test]
    fn malformed_values_are_errors_not_panics() {
        let a = Args::from_iter(["--n", "abc", "--x", "1.5", "--l", "1,two,3"]);
        let e = a.try_opt::<u32>("x").unwrap_err();
        assert!(e.starts_with("--x=1.5:"), "{e}");
        let e = a.try_size("n").unwrap_err();
        assert_eq!(e, "--n=abc: bad size");
        let e = a.try_list::<u32>("l").unwrap_err();
        assert!(e.starts_with("--l=1,two,3:"), "{e}");
        // One line each — these go straight to stderr.
        for msg in [
            a.try_opt::<u32>("x").unwrap_err(),
            a.try_size("n").unwrap_err(),
            a.try_list::<u32>("l").unwrap_err(),
        ] {
            assert!(!msg.contains('\n'), "{msg}");
        }
        // Well-formed and absent values still parse through try_*.
        assert_eq!(a.try_opt::<f64>("x").unwrap(), Some(1.5));
        assert_eq!(a.try_opt::<u32>("missing").unwrap(), None);
        assert_eq!(a.try_size("missing").unwrap(), None);
        assert_eq!(a.try_list::<u32>("missing").unwrap(), None);
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_size("10k"), Some(10_000));
        assert_eq!(parse_size("2M"), Some(2_000_000));
        assert_eq!(parse_size("1e8"), Some(100_000_000));
        assert_eq!(parse_size("2.5e3"), Some(2_500));
        assert_eq!(parse_size("abc"), None);
        assert_eq!(parse_size("-5"), None);
    }
}

//! Configuration files for the coordinator/launcher (serde-free).
//!
//! A pragmatic TOML subset: `[section]` headers, `key = value` pairs,
//! `#` comments, strings (quoted or bare), integers, floats, booleans,
//! and flat arrays `[a, b, c]`. This covers the launcher configs in
//! `examples/` and the `ddm serve --config` path.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::List(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// A parsed config: `section.key -> Value` (top-level keys live in the
/// "" section).
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<(String, String), Value>,
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn parse_scalar(raw: &str) -> Value {
    let raw = raw.trim();
    if let Some(stripped) = raw.strip_prefix('"').and_then(|r| r.strip_suffix('"')) {
        return Value::Str(stripped.to_string());
    }
    match raw {
        "true" => return Value::Bool(true),
        "false" => return Value::Bool(false),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Value::Int(i);
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Value::Float(f);
    }
    Value::Str(raw.to_string())
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (idx, raw_line) in text.lines().enumerate() {
            let line = raw_line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| ParseError {
                line: idx + 1,
                msg: format!("expected `key = value`, got `{line}`"),
            })?;
            let key = key.trim().to_string();
            let val = val.trim();
            let value = if let Some(body) =
                val.strip_prefix('[').and_then(|v| v.strip_suffix(']'))
            {
                Value::List(
                    body.split(',')
                        .map(str::trim)
                        .filter(|s| !s.is_empty())
                        .map(parse_scalar)
                        .collect(),
                )
            } else {
                parse_scalar(val)
            };
            cfg.values.insert((section.clone(), key), value);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> crate::error::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .and_then(Value::as_float)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn sections(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.values.keys().map(|(s, _)| s.as_str()).collect();
        v.dedup();
        v
    }
}

/// Minimal JSON writer for machine-readable bench results (serde-free).
pub mod json {
    use std::fmt::Write;

    pub fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out
    }

    /// Render an object from key/raw-value pairs (values pre-rendered).
    pub fn object(fields: &[(&str, String)]) -> String {
        let body: Vec<String> = fields
            .iter()
            .map(|(k, v)| format!("\"{}\":{}", escape(k), v))
            .collect();
        format!("{{{}}}", body.join(","))
    }

    pub fn string(s: &str) -> String {
        format!("\"{}\"", escape(s))
    }

    pub fn num(x: f64) -> String {
        if x.is_finite() {
            format!("{x}")
        } else {
            "null".to_string()
        }
    }

    pub fn array(items: &[String]) -> String {
        format!("[{}]", items.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let text = r#"
# top comment
name = "ddm-service"
threads = 8
[match]
algo = psbm        # bare string
alpha = 100.5
verbose = true
cells = [10, 20, 30]
"#;
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.str_or("", "name", ""), "ddm-service");
        assert_eq!(cfg.int_or("", "threads", 0), 8);
        assert_eq!(cfg.str_or("match", "algo", ""), "psbm");
        assert_eq!(cfg.float_or("match", "alpha", 0.0), 100.5);
        assert!(cfg.bool_or("match", "verbose", false));
        let cells = cfg.get("match", "cells").unwrap().as_list().unwrap();
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[1].as_int(), Some(20));
    }

    #[test]
    fn defaults_and_missing() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.int_or("x", "y", 42), 42);
        assert!(cfg.get("x", "y").is_none());
    }

    #[test]
    fn bad_line_is_an_error() {
        let err = Config::parse("not a kv line").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn int_promotes_to_float() {
        let cfg = Config::parse("x = 3").unwrap();
        assert_eq!(cfg.float_or("", "x", 0.0), 3.0);
    }

    #[test]
    fn json_writer_escapes() {
        let s = json::object(&[
            ("name", json::string("a\"b")),
            ("v", json::num(1.5)),
            ("xs", json::array(&[json::num(1.0), json::num(2.0)])),
        ]);
        assert_eq!(s, r#"{"name":"a\"b","v":1.5,"xs":[1,2]}"#);
    }
}

//! Lightweight service metrics (counters, latency accumulators,
//! gauges, and log-bucketed [`Histogram`]s for quantile-readable
//! distributions).

use std::collections::BTreeMap;
use std::time::Duration;

use crate::obs::Histogram;

/// Latency accumulator: count, total, max (enough for service tables
/// without a full histogram; use [`Metrics::observe_ns`] when
/// quantiles matter).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyStat {
    pub count: u64,
    pub total: Duration,
    pub max: Duration,
}

impl LatencyStat {
    pub fn record(&mut self, d: Duration) {
        self.count += 1;
        self.total += d;
        self.max = self.max.max(d);
    }

    /// Arithmetic mean, computed in the u128 nanosecond domain and
    /// rounded to nearest. (The old `total / count as u32` both
    /// truncated sub-divisor remainders and wrapped the divisor at
    /// 2^32 samples — dividing by a *truncated count*, a panic at
    /// exact multiples.)
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let n = self.count as u128;
        let ns = (self.total.as_nanos() + n / 2) / n;
        Duration::from_nanos(ns.min(u64::MAX as u128) as u64)
    }
}

/// Named counters + latencies + gauges + histograms.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub counters: BTreeMap<&'static str, u64>,
    pub latencies: BTreeMap<&'static str, LatencyStat>,
    /// Last-write-wins instantaneous values (e.g. the shard imbalance
    /// gauge) — unlike counters they describe *current* state, not
    /// accumulation.
    pub gauges: BTreeMap<&'static str, f64>,
    /// Log-bucketed nanosecond distributions ([`Metrics::observe_ns`])
    /// — quantile-readable and mergeable, so the wire snapshot can
    /// carry them and the client can render p50/p99 without raw
    /// samples.
    pub hists: BTreeMap<&'static str, Histogram>,
}

impl Metrics {
    pub fn inc(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_default() += by;
    }

    pub fn time(&mut self, name: &'static str, d: Duration) {
        self.latencies.entry(name).or_default().record(d);
    }

    /// Set an instantaneous gauge (overwrites the previous value).
    pub fn gauge(&mut self, name: &'static str, value: f64) {
        self.gauges.insert(name, value);
    }

    /// Record one nanosecond sample into the named histogram.
    pub fn observe_ns(&mut self, name: &'static str, ns: u64) {
        self.hists.entry(name).or_default().record(ns);
    }

    /// Record a `Duration` sample into the named histogram.
    pub fn observe(&mut self, name: &'static str, d: Duration) {
        self.hists.entry(name).or_default().record_duration(d);
    }

    /// Fold a ready-made histogram (an [`AtomicHist`](crate::obs::AtomicHist)
    /// snapshot from an IO thread, a remote worker's wire copy) into
    /// the named one.
    pub fn merge_hist(&mut self, name: &'static str, h: &Histogram) {
        self.hists.entry(name).or_default().merge(h);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// The named histogram, if any samples were observed under it.
    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// Render as an aligned table (histogram rows carry the quantile
    /// columns; plain latencies show `-` there).
    pub fn table(&self) -> crate::bench::table::Table {
        use crate::bench::stats::fmt_secs;
        let ns = |v: u64| fmt_secs(v as f64 / 1e9);
        let mut t = crate::bench::table::Table::new(vec![
            "metric", "count", "mean", "p50", "p99", "max",
        ]);
        for (name, v) in &self.counters {
            t.row(vec![
                name.to_string(),
                v.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
        }
        for (name, v) in &self.gauges {
            t.row(vec![
                name.to_string(),
                format!("{v:.3}"),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
        }
        for (name, l) in &self.latencies {
            t.row(vec![
                name.to_string(),
                l.count.to_string(),
                fmt_secs(l.mean().as_secs_f64()),
                "-".into(),
                "-".into(),
                fmt_secs(l.max.as_secs_f64()),
            ]);
        }
        for (name, h) in &self.hists {
            t.row(vec![
                name.to_string(),
                h.count().to_string(),
                ns(h.mean_ns()),
                ns(h.p50()),
                ns(h.p99()),
                ns(h.max_ns()),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latencies() {
        let mut m = Metrics::default();
        m.inc("publishes", 1);
        m.inc("publishes", 2);
        assert_eq!(m.counter("publishes"), 3);
        m.time("publish", Duration::from_millis(2));
        m.time("publish", Duration::from_millis(4));
        let l = m.latencies["publish"];
        assert_eq!(l.count, 2);
        assert_eq!(l.mean(), Duration::from_millis(3));
        assert_eq!(l.max, Duration::from_millis(4));
        assert!(m.table().render().contains("publishes"));
    }

    /// Satellite regression: `mean` computes in u128 nanoseconds. The
    /// old `total / count as u32` (a) truncated — 3ns over 2 samples
    /// reported 1ns, not the rounded 2ns — and (b) wrapped the divisor
    /// at 2^32 samples, panicking on division by a zero-truncated
    /// count.
    #[test]
    fn latency_mean_rounds_and_survives_u32_overflow_counts() {
        let mut l = LatencyStat::default();
        l.record(Duration::from_nanos(1));
        l.record(Duration::from_nanos(2));
        assert_eq!(l.mean(), Duration::from_nanos(2), "1.5ns rounds to 2ns");

        let big = LatencyStat {
            count: 1u64 << 33, // `as u32` would truncate this to 0
            total: Duration::from_nanos(100 * (1u64 << 33)),
            max: Duration::from_nanos(100),
        };
        assert_eq!(big.mean(), Duration::from_nanos(100));

        assert_eq!(LatencyStat::default().mean(), Duration::ZERO);
    }

    #[test]
    fn histograms_record_merge_and_render_quantiles() {
        let mut m = Metrics::default();
        assert!(m.hist("commit").is_none());
        for ns in [100u64, 200, 400, 100_000] {
            m.observe_ns("commit", ns);
        }
        m.observe("commit", Duration::from_micros(2));
        let h = m.hist("commit").unwrap();
        assert_eq!(h.count(), 5);
        assert!(h.p99() >= h.p50());

        let mut other = Histogram::default();
        other.record(7);
        m.merge_hist("commit", &other);
        assert_eq!(m.hist("commit").unwrap().count(), 6);

        let r = m.table().render();
        assert!(r.contains("commit"), "{r}");
        assert!(r.contains("p50") && r.contains("p99"), "{r}");
    }

    #[test]
    fn gauges_are_last_write_wins() {
        let mut m = Metrics::default();
        assert_eq!(m.gauge_value("shard_imbalance"), None);
        m.gauge("shard_imbalance", 2.5);
        m.gauge("shard_imbalance", 1.25);
        assert_eq!(m.gauge_value("shard_imbalance"), Some(1.25));
        assert!(m.table().render().contains("shard_imbalance"));
        assert!(m.table().render().contains("1.250"));
    }
}

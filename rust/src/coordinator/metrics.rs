//! Lightweight service metrics (counters + latency accumulators).

use std::collections::BTreeMap;
use std::time::Duration;

/// Latency accumulator: count, total, max (enough for service tables
/// without a full histogram dependency).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyStat {
    pub count: u64,
    pub total: Duration,
    pub max: Duration,
}

impl LatencyStat {
    pub fn record(&mut self, d: Duration) {
        self.count += 1;
        self.total += d;
        self.max = self.max.max(d);
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / self.count as u32
        }
    }
}

/// Named counters + latencies + gauges.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub counters: BTreeMap<&'static str, u64>,
    pub latencies: BTreeMap<&'static str, LatencyStat>,
    /// Last-write-wins instantaneous values (e.g. the shard imbalance
    /// gauge) — unlike counters they describe *current* state, not
    /// accumulation.
    pub gauges: BTreeMap<&'static str, f64>,
}

impl Metrics {
    pub fn inc(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_default() += by;
    }

    pub fn time(&mut self, name: &'static str, d: Duration) {
        self.latencies.entry(name).or_default().record(d);
    }

    /// Set an instantaneous gauge (overwrites the previous value).
    pub fn gauge(&mut self, name: &'static str, value: f64) {
        self.gauges.insert(name, value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Render as an aligned table.
    pub fn table(&self) -> crate::bench::table::Table {
        let mut t = crate::bench::table::Table::new(vec![
            "metric", "count", "mean", "max",
        ]);
        for (name, v) in &self.counters {
            t.row(vec![name.to_string(), v.to_string(), "-".into(), "-".into()]);
        }
        for (name, v) in &self.gauges {
            t.row(vec![name.to_string(), format!("{v:.3}"), "-".into(), "-".into()]);
        }
        for (name, l) in &self.latencies {
            t.row(vec![
                name.to_string(),
                l.count.to_string(),
                crate::bench::stats::fmt_secs(l.mean().as_secs_f64()),
                crate::bench::stats::fmt_secs(l.max.as_secs_f64()),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latencies() {
        let mut m = Metrics::default();
        m.inc("publishes", 1);
        m.inc("publishes", 2);
        assert_eq!(m.counter("publishes"), 3);
        m.time("publish", Duration::from_millis(2));
        m.time("publish", Duration::from_millis(4));
        let l = m.latencies["publish"];
        assert_eq!(l.count, 2);
        assert_eq!(l.mean(), Duration::from_millis(3));
        assert_eq!(l.max, Duration::from_millis(4));
        assert!(m.table().render().contains("publishes"));
    }

    #[test]
    fn gauges_are_last_write_wins() {
        let mut m = Metrics::default();
        assert_eq!(m.gauge_value("shard_imbalance"), None);
        m.gauge("shard_imbalance", 2.5);
        m.gauge("shard_imbalance", 1.25);
        assert_eq!(m.gauge_value("shard_imbalance"), Some(1.25));
        assert!(m.table().render().contains("shard_imbalance"));
        assert!(m.table().render().contains("1.250"));
    }
}

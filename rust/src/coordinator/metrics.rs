//! Lightweight service metrics (counters + latency accumulators).

use std::collections::BTreeMap;
use std::time::Duration;

/// Latency accumulator: count, total, max (enough for service tables
/// without a full histogram dependency).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyStat {
    pub count: u64,
    pub total: Duration,
    pub max: Duration,
}

impl LatencyStat {
    pub fn record(&mut self, d: Duration) {
        self.count += 1;
        self.total += d;
        self.max = self.max.max(d);
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / self.count as u32
        }
    }
}

/// Named counters + latencies.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub counters: BTreeMap<&'static str, u64>,
    pub latencies: BTreeMap<&'static str, LatencyStat>,
}

impl Metrics {
    pub fn inc(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_default() += by;
    }

    pub fn time(&mut self, name: &'static str, d: Duration) {
        self.latencies.entry(name).or_default().record(d);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Render as an aligned table.
    pub fn table(&self) -> crate::bench::table::Table {
        let mut t = crate::bench::table::Table::new(vec![
            "metric", "count", "mean", "max",
        ]);
        for (name, v) in &self.counters {
            t.row(vec![name.to_string(), v.to_string(), "-".into(), "-".into()]);
        }
        for (name, l) in &self.latencies {
            t.row(vec![
                name.to_string(),
                l.count.to_string(),
                crate::bench::stats::fmt_secs(l.mean().as_secs_f64()),
                crate::bench::stats::fmt_secs(l.max.as_secs_f64()),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latencies() {
        let mut m = Metrics::default();
        m.inc("publishes", 1);
        m.inc("publishes", 2);
        assert_eq!(m.counter("publishes"), 3);
        m.time("publish", Duration::from_millis(2));
        m.time("publish", Duration::from_millis(4));
        let l = m.latencies["publish"];
        assert_eq!(l.count, 2);
        assert_eq!(l.mean(), Duration::from_millis(3));
        assert_eq!(l.max, Duration::from_millis(4));
        assert!(m.table().render().contains("publishes"));
    }
}

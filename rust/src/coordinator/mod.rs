//! The L3 coordinator: the service loop that owns the DDM state and
//! its matching engine, and serves commands from clients over a
//! channel — the "router/batcher" shape of the three-layer
//! architecture with Python nowhere on the request path.
//!
//! The coordinator is **algorithm-agnostic**: it is configured with a
//! [`DdmEngine`](crate::engine::DdmEngine) and never names a concrete
//! matcher — swapping algorithms is an
//! [`EngineBuilder`](crate::engine::EngineBuilder) change at spawn
//! time.
//!
//! The service runs on an incremental
//! [`DdmSession`](crate::session::DdmSession): mutating commands
//! (register/modify/publish) stage batched ops, and the session epoch
//! commits lazily — a burst of region modifications becomes ONE
//! parallel batch apply at the next read (or explicit
//! [`Client::commit`]), with the epoch's
//! [`MatchDiff`](crate::session::MatchDiff) counted in the metrics
//! (`commits`, `diff_added`, `diff_removed`). The command loop's
//! `batch_max` bound drains queued commands before answering queries,
//! so synchronous bursts coalesce into large staged batches.

pub mod metrics;

use std::sync::mpsc;
use std::time::Instant;

use crate::engine::DdmEngine;
use crate::error::Result;
use crate::hla::{
    DdmService, FederateId, Notification, RegionHandle, RegionKind, RegionSpec, RoutingSpace,
};
use metrics::Metrics;

/// Commands a client can send to the coordinator.
pub enum Command {
    Join {
        name: String,
        reply: mpsc::Sender<FederateId>,
    },
    Register {
        fed: FederateId,
        kind: RegionKind,
        spec: RegionSpec,
        reply: mpsc::Sender<Result<RegionHandle>>,
    },
    Modify {
        handle: RegionHandle,
        spec: RegionSpec,
        reply: mpsc::Sender<Result<()>>,
    },
    Publish {
        handle: RegionHandle,
        payload: u64,
        reply: mpsc::Sender<Result<usize>>,
    },
    Poll {
        fed: FederateId,
        reply: mpsc::Sender<Vec<Notification>>,
    },
    MatchAll {
        reply: mpsc::Sender<usize>,
    },
    /// Commit the staged session epoch; replies with
    /// `(epoch, pairs added, pairs removed)`.
    Commit {
        reply: mpsc::Sender<(u64, usize, usize)>,
    },
    Metrics {
        reply: mpsc::Sender<Metrics>,
    },
    Shutdown,
}

/// Coordinator configuration: the routing space, the matching engine
/// (algorithm, threads, parameters — see
/// [`EngineBuilder`](crate::engine::EngineBuilder)) and the batching
/// bound.
pub struct CoordinatorConfig {
    pub space: RoutingSpace,
    pub engine: DdmEngine,
    /// Max commands drained per loop iteration (batching bound).
    pub batch_max: usize,
}

impl CoordinatorConfig {
    /// Config with the default batching bound. Prefer this over
    /// `..Default::default()` when supplying an engine —
    /// [`Default`] constructs (and would immediately discard) a full
    /// engine with its worker pool.
    pub fn new(space: RoutingSpace, engine: DdmEngine) -> Self {
        Self {
            space,
            engine,
            batch_max: 256,
        }
    }
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self::new(RoutingSpace::uniform(1, 1_000_000), DdmEngine::default())
    }
}

/// Client handle: typed wrappers over the command channel.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Command>,
}

impl Client {
    fn call<T>(&self, build: impl FnOnce(mpsc::Sender<T>) -> Command) -> T {
        let (tx, rx) = mpsc::channel();
        self.tx.send(build(tx)).expect("coordinator alive");
        rx.recv().expect("coordinator replies")
    }

    pub fn join(&self, name: &str) -> FederateId {
        self.call(|reply| Command::Join {
            name: name.to_string(),
            reply,
        })
    }

    pub fn register(
        &self,
        fed: FederateId,
        kind: RegionKind,
        spec: RegionSpec,
    ) -> Result<RegionHandle> {
        self.call(|reply| Command::Register {
            fed,
            kind,
            spec,
            reply,
        })
    }

    pub fn modify(&self, handle: RegionHandle, spec: RegionSpec) -> Result<()> {
        self.call(|reply| Command::Modify {
            handle,
            spec,
            reply,
        })
    }

    pub fn publish(&self, handle: RegionHandle, payload: u64) -> Result<usize> {
        self.call(|reply| Command::Publish {
            handle,
            payload,
            reply,
        })
    }

    pub fn poll(&self, fed: FederateId) -> Vec<Notification> {
        self.call(|reply| Command::Poll { fed, reply })
    }

    pub fn match_all(&self) -> usize {
        self.call(|reply| Command::MatchAll { reply })
    }

    /// Commit the staged epoch: returns `(epoch, added, removed)` — the
    /// size of the intersection diff produced by the batched region ops
    /// since the previous epoch.
    pub fn commit(&self) -> (u64, usize, usize) {
        self.call(|reply| Command::Commit { reply })
    }

    pub fn metrics(&self) -> Metrics {
        self.call(|reply| Command::Metrics { reply })
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Command::Shutdown);
    }
}

/// The running coordinator (owns the service thread).
pub struct Coordinator {
    client: Client,
    handle: Option<std::thread::JoinHandle<Metrics>>,
}

impl Coordinator {
    /// Spawn the service loop on its own thread.
    pub fn spawn(cfg: CoordinatorConfig) -> Self {
        let (tx, rx) = mpsc::channel::<Command>();
        let handle = std::thread::Builder::new()
            .name("ddm-coordinator".into())
            .spawn(move || service_loop(cfg, rx))
            .expect("spawn coordinator");
        Self {
            client: Client { tx },
            handle: Some(handle),
        }
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Shut down and return final metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.client.shutdown();
        self.handle
            .take()
            .expect("not yet joined")
            .join()
            .expect("coordinator thread")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = self.client.tx.send(Command::Shutdown);
            let _ = h.join();
        }
    }
}

fn service_loop(cfg: CoordinatorConfig, rx: mpsc::Receiver<Command>) -> Metrics {
    let mut svc = DdmService::with_engine(cfg.space.clone(), cfg.engine);
    let mut metrics = Metrics::default();
    let mut batch: Vec<Command> = Vec::with_capacity(cfg.batch_max);

    'outer: loop {
        // Block for the first command, then drain the queue (batching).
        match rx.recv() {
            Ok(cmd) => batch.push(cmd),
            Err(_) => break,
        }
        while batch.len() < cfg.batch_max {
            match rx.try_recv() {
                Ok(cmd) => batch.push(cmd),
                Err(_) => break,
            }
        }
        metrics.inc("batches", 1);
        metrics.inc("commands", batch.len() as u64);

        for cmd in batch.drain(..) {
            let t0 = Instant::now();
            match cmd {
                Command::Join { name, reply } => {
                    let id = svc.join(name);
                    metrics.inc("joins", 1);
                    let _ = reply.send(id);
                }
                Command::Register {
                    fed,
                    kind,
                    spec,
                    reply,
                } => {
                    metrics.inc("registers", 1);
                    let r = svc.register(fed, kind, &spec);
                    metrics.time("register", t0.elapsed());
                    let _ = reply.send(r);
                }
                Command::Modify {
                    handle,
                    spec,
                    reply,
                } => {
                    metrics.inc("modifies", 1);
                    let r = svc.modify(handle, &spec);
                    metrics.time("modify", t0.elapsed());
                    let _ = reply.send(r);
                }
                Command::Publish {
                    handle,
                    payload,
                    reply,
                } => {
                    metrics.inc("publishes", 1);
                    let r = svc.publish(handle, payload);
                    if let Ok(n) = &r {
                        metrics.inc("notifications", *n as u64);
                    }
                    metrics.time("publish", t0.elapsed());
                    let _ = reply.send(r);
                }
                Command::Poll { fed, reply } => {
                    let _ = reply.send(svc.poll(fed));
                }
                Command::MatchAll { reply } => {
                    let pairs = svc.match_all();
                    metrics.inc("match_all", 1);
                    metrics.time("match_all", t0.elapsed());
                    let _ = reply.send(pairs.len());
                }
                Command::Commit { reply } => {
                    let diff = svc.commit();
                    metrics.inc("commits", 1);
                    metrics.inc("diff_added", diff.added.len() as u64);
                    metrics.inc("diff_removed", diff.removed.len() as u64);
                    // Sharded engines: per-shard op/diff totals plus the
                    // instantaneous load-imbalance gauge (1.0 = even,
                    // `shards` = everything on one stripe).
                    if let Some(stats) = svc.shard_stats() {
                        metrics.inc(
                            "shard_ops",
                            stats.iter().map(|s| s.last_ops as u64).sum(),
                        );
                        metrics.inc(
                            "shard_ops_max",
                            stats.iter().map(|s| s.last_ops as u64).max().unwrap_or(0),
                        );
                        metrics.inc(
                            "shard_diff_churn",
                            stats.iter().map(|s| s.last_churn as u64).sum(),
                        );
                        metrics.gauge("shards", stats.len() as f64);
                        // Derived from the snapshot in hand — no second
                        // sweep of the shard locks.
                        metrics.gauge(
                            "shard_imbalance",
                            crate::shard::ShardedSession::imbalance_of(&stats),
                        );
                        // The measured counterpart: max/mean of the
                        // shards' actual inner-commit wall times.
                        if let Some(ti) =
                            crate::shard::ShardedSession::commit_time_imbalance_of(&stats)
                        {
                            metrics.gauge("shard_time_imbalance", ti);
                        }
                    }
                    metrics.time("commit", t0.elapsed());
                    metrics.observe("commit_ns", t0.elapsed());
                    let _ = reply.send((diff.epoch, diff.added.len(), diff.removed.len()));
                }
                Command::Metrics { reply } => {
                    let _ = reply.send(metrics.clone());
                }
                Command::Shutdown => break 'outer,
            }
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_service_roundtrip() {
        let coord = Coordinator::spawn(CoordinatorConfig::new(
            RoutingSpace::uniform(1, 1000),
            DdmEngine::builder().threads(2).build(),
        ));
        let c = coord.client();
        let veh = c.join("vehicles");
        let lights = c.join("lights");
        let s = c
            .register(veh, RegionKind::Subscription, RegionSpec::interval(0, 100))
            .unwrap();
        let u = c
            .register(lights, RegionKind::Update, RegionSpec::interval(50, 150))
            .unwrap();
        assert_eq!(c.match_all(), 1);
        assert_eq!(c.publish(u, 99).unwrap(), 1);
        let mail = c.poll(veh);
        assert_eq!(mail.len(), 1);
        assert_eq!(mail[0].payload, 99);
        assert_eq!(mail[0].subscription, s);

        // Move the subscription away; no more routing.
        c.modify(s, RegionSpec::interval(500, 600)).unwrap();
        assert_eq!(c.publish(u, 1).unwrap(), 0);

        let m = coord.shutdown();
        assert_eq!(m.counter("publishes"), 2);
        assert_eq!(m.counter("notifications"), 1);
        assert!(m.counter("batches") >= 1);
    }

    #[test]
    fn burst_of_commands_is_batched() {
        let coord = Coordinator::spawn(CoordinatorConfig::new(
            RoutingSpace::uniform(1, 10_000),
            DdmEngine::builder().threads(1).build(),
        ));
        let c = coord.client();
        let f = c.join("f");
        for i in 0..100u64 {
            c.register(
                f,
                RegionKind::Subscription,
                RegionSpec::interval(i * 10, i * 10 + 20),
            )
            .unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.counter("registers"), 100);
        // Synchronous client ⇒ batches ≈ commands; the assertion is on
        // plumbing, not the batching win (async clients get that).
        assert!(m.counter("batches") <= m.counter("commands"));
        coord.shutdown();
    }

    /// Swapping the coordinator's algorithm is a spawn-time engine
    /// change only; behavior (match counts, routing) is identical.
    #[test]
    fn coordinator_is_engine_agnostic() {
        use crate::algos::Algo;
        let mut counts = Vec::new();
        for algo in [Algo::Itm, Algo::Psbm, Algo::Gbm] {
            let coord = Coordinator::spawn(CoordinatorConfig::new(
                RoutingSpace::uniform(1, 100_000),
                DdmEngine::builder().algo(algo).threads(2).ncells(128).build(),
            ));
            let c = coord.client();
            let f = c.join("f");
            let mut rng = crate::prng::Rng::new(9);
            for _ in 0..100 {
                let x = rng.below(99_000);
                c.register(f, RegionKind::Subscription, RegionSpec::interval(x, x + 800))
                    .unwrap();
            }
            for _ in 0..50 {
                let x = rng.below(99_000);
                c.register(f, RegionKind::Update, RegionSpec::interval(x, x + 500))
                    .unwrap();
            }
            counts.push(c.match_all());
            coord.shutdown();
        }
        assert!(counts[0] > 0);
        assert!(counts.windows(2).all(|w| w[0] == w[1]));
    }

    /// A burst of staged region ops commits as ONE epoch whose diff
    /// reports exactly the new pairs; a second commit is empty.
    #[test]
    fn staged_epoch_commit_returns_diff() {
        let coord = Coordinator::spawn(CoordinatorConfig::new(
            RoutingSpace::uniform(1, 10_000),
            DdmEngine::builder().threads(2).build(),
        ));
        let c = coord.client();
        let f = c.join("f");
        for i in 0..20u64 {
            c.register(
                f,
                RegionKind::Subscription,
                RegionSpec::interval(i * 100, i * 100 + 150),
            )
            .unwrap();
        }
        let u = c
            .register(f, RegionKind::Update, RegionSpec::interval(0, 250))
            .unwrap();
        let (epoch, added, removed) = c.commit();
        assert_eq!(epoch, 1);
        assert_eq!((added, removed), (3, 0)); // subs at 0, 100, 200 overlap [0, 250)
        let (epoch, added, removed) = c.commit();
        assert_eq!(epoch, 2);
        assert_eq!((added, removed), (0, 0));
        // Moving the update region flips the pair set; the diff says so.
        c.modify(u, RegionSpec::interval(1800, 1950)).unwrap();
        let (_, added, removed) = c.commit();
        assert_eq!((added, removed), (3, 3)); // now overlaps subs at 1700, 1800, 1900
        let m = coord.shutdown();
        assert_eq!(m.counter("commits"), 3);
        assert_eq!(m.counter("diff_added"), 6);
        assert_eq!(m.counter("diff_removed"), 3);
    }

    /// A sharded coordinator serves the same workload and reports
    /// per-shard op/diff metrics plus the imbalance gauge on commit.
    #[test]
    fn sharded_coordinator_reports_shard_metrics() {
        let coord = Coordinator::spawn(CoordinatorConfig::new(
            RoutingSpace::uniform(1, 10_000),
            DdmEngine::builder().threads(2).shards(4).build(),
        ));
        let c = coord.client();
        let f = c.join("f");
        for i in 0..20u64 {
            c.register(
                f,
                RegionKind::Subscription,
                RegionSpec::interval(i * 100, i * 100 + 150),
            )
            .unwrap();
        }
        c.register(f, RegionKind::Update, RegionSpec::interval(0, 250))
            .unwrap();
        let (epoch, added, removed) = c.commit();
        assert_eq!(epoch, 1);
        assert_eq!((added, removed), (3, 0), "same diff as the unsharded path");
        let m = c.metrics();
        assert_eq!(m.counter("shard_ops"), 21, "20 subs + 1 update routed");
        assert!(m.counter("shard_ops_max") <= m.counter("shard_ops"));
        assert_eq!(m.gauge_value("shards"), Some(4.0));
        // Every region lands in stripe 0 of [0, 10k): maximal skew.
        assert_eq!(m.gauge_value("shard_imbalance"), Some(4.0));
        // The measured counterpart exists and is a valid ratio.
        let ti = m
            .gauge_value("shard_time_imbalance")
            .expect("commit ran, so shard timings are real");
        assert!((1.0..=4.0).contains(&ti), "{ti}");
        // Commit latency lands in the quantile-readable histogram too.
        assert!(m.hist("commit_ns").is_some_and(|h| h.count() == 1));
        coord.shutdown();
    }

    #[test]
    fn errors_propagate_to_client() {
        let coord = Coordinator::spawn(CoordinatorConfig::default());
        let c = coord.client();
        let f = c.join("f");
        // Out-of-space region is rejected.
        let err = c.register(
            f,
            RegionKind::Subscription,
            RegionSpec::interval(0, 10_000_000),
        );
        assert!(err.is_err());
    }
}

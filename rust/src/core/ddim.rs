//! d-dimensional reduction (paper §2, footnote 1).
//!
//! Two d-rectangles intersect iff their projections intersect on every
//! dimension, so any 1-D matcher extends to d dimensions by running it
//! once per dimension and intersecting the partial result sets. The
//! paper notes the combination step must be O(f(n, m)) with hash-based
//! sets — we intersect via a `HashSet<u64>` of packed pairs, giving
//! O(K₀ + K₁ + … + K_{d-1}) expected combine time.

use std::collections::HashSet;

use super::region::{Regions1D, RegionsNd};
use super::sink::{pack_pair, unpack_pair, MatchSink, VecSink};

/// Extend a 1-D matcher to d dimensions.
///
/// `match1d(s_proj, u_proj, sink)` must report every intersecting pair
/// of the 1-D projections exactly once.
pub fn match_nd<F>(
    subs: &RegionsNd,
    upds: &RegionsNd,
    match1d: F,
    sink: &mut dyn MatchSink,
) where
    F: Fn(&Regions1D, &Regions1D, &mut VecSink),
{
    assert_eq!(subs.d(), upds.d(), "dimension mismatch");
    let d = subs.d();
    if d == 1 {
        let mut v = VecSink::default();
        match1d(subs.project(0), upds.project(0), &mut v);
        for (s, u) in v.pairs {
            sink.report(s, u);
        }
        return;
    }

    // Dimension 0 seeds the candidate set…
    let mut v = VecSink::default();
    match1d(subs.project(0), upds.project(0), &mut v);
    let mut candidates: HashSet<u64> =
        v.pairs.iter().map(|&(s, u)| pack_pair(s, u)).collect();

    // …and each further dimension filters it.
    for k in 1..d {
        if candidates.is_empty() {
            return;
        }
        let mut vk = VecSink::default();
        match1d(subs.project(k), upds.project(k), &mut vk);
        let dim_pairs: HashSet<u64> =
            vk.pairs.iter().map(|&(s, u)| pack_pair(s, u)).collect();
        candidates.retain(|p| dim_pairs.contains(p));
    }

    let mut out: Vec<u64> = candidates.into_iter().collect();
    out.sort_unstable(); // deterministic report order
    for p in out {
        let (s, u) = unpack_pair(p);
        sink.report(s, u);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::interval::Interval;
    use crate::core::sink::{canonicalize, VecSink};

    /// Trivial 1-D matcher oracle (BFM is defined in algos; core tests
    /// stay dependency-free with a local quadratic loop).
    fn bf1d(s: &Regions1D, u: &Regions1D, sink: &mut VecSink) {
        for i in 0..s.len() {
            for j in 0..u.len() {
                if s.get(i).intersects(&u.get(j)) {
                    sink.report(i as u32, j as u32);
                }
            }
        }
    }

    fn direct_nd(subs: &RegionsNd, upds: &RegionsNd) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for i in 0..subs.len() {
            for j in 0..upds.len() {
                if subs.rects_intersect(i, upds, j) {
                    out.push((i as u32, j as u32));
                }
            }
        }
        out
    }

    #[test]
    fn matches_direct_nd_on_random_rects() {
        crate::bench::prop::prop_check("ddim-vs-direct", 0xD1, |rng| {
            let d = 1 + rng.below(3) as usize;
            let n = 1 + rng.below(30) as usize;
            let m = 1 + rng.below(30) as usize;
            let mut subs = RegionsNd::new(d);
            let mut upds = RegionsNd::new(d);
            for _ in 0..n {
                let rect: Vec<Interval> = (0..d)
                    .map(|_| {
                        let lo = rng.uniform(0.0, 50.0);
                        Interval::new(lo, lo + rng.uniform(0.0, 20.0))
                    })
                    .collect();
                subs.push(&rect);
            }
            for _ in 0..m {
                let rect: Vec<Interval> = (0..d)
                    .map(|_| {
                        let lo = rng.uniform(0.0, 50.0);
                        Interval::new(lo, lo + rng.uniform(0.0, 20.0))
                    })
                    .collect();
                upds.push(&rect);
            }
            let mut sink = VecSink::default();
            match_nd(&subs, &upds, bf1d, &mut sink);
            let got = canonicalize(sink.pairs);
            let want = canonicalize(direct_nd(&subs, &upds));
            crate::bench::prop::expect_eq(&got, &want, "pair sets")
        });
    }

    #[test]
    fn figure3_example() {
        // Paper Fig. 3: S1..S3, U1..U2 in d=2; expected overlaps
        // {(S1,U1),(S2,U2),(S3,U1),(S3,U2)}. Coordinates chosen to
        // reproduce the figure's topology.
        let mut subs = RegionsNd::new(2);
        subs.push(&[Interval::new(0.0, 4.0), Interval::new(4.0, 9.0)]); // S1
        subs.push(&[Interval::new(7.0, 12.0), Interval::new(0.0, 3.0)]); // S2
        subs.push(&[Interval::new(2.0, 10.0), Interval::new(1.0, 6.0)]); // S3
        let mut upds = RegionsNd::new(2);
        upds.push(&[Interval::new(1.0, 5.0), Interval::new(2.0, 7.0)]); // U1
        upds.push(&[Interval::new(6.0, 11.0), Interval::new(2.0, 5.0)]); // U2
        let mut sink = VecSink::default();
        match_nd(&subs, &upds, bf1d, &mut sink);
        assert_eq!(
            canonicalize(sink.pairs),
            vec![(0, 0), (1, 1), (2, 0), (2, 1)]
        );
    }

    #[test]
    fn empty_inputs() {
        let subs = RegionsNd::new(2);
        let upds = RegionsNd::new(2);
        let mut sink = VecSink::default();
        match_nd(&subs, &upds, bf1d, &mut sink);
        assert!(sink.pairs.is_empty());
    }
}

//! d-dimensional matching: the native **sweep-and-verify** pipeline
//! and the per-dimension reduction fallback (paper §2, footnote 1).
//!
//! The paper extends 1-D matchers to d dimensions by matching every
//! dimension independently and intersecting the d partial pair sets
//! ([`ReductionNd`]). That combine is O(K₀ + K₁ + … + K_{d-1}) — and on
//! anisotropic workloads (one dimension barely discriminates, e.g. the
//! time axis of a vehicular trace) the largest K_k can dwarf the true
//! N-D result, making the reduction the dominant cost.
//!
//! The native pipeline ([`sweep_and_verify`]) instead sweeps **one**
//! dimension — chosen by a cheap sampled selectivity estimate
//! ([`select_sweep_dim`]) — and verifies the residual d−1 dimensions
//! inline at report time through a
//! [`FilterSink`](crate::core::sink::FilterSink): total cost is the
//! best single-dimension 1-D match plus O(d) float compares per
//! swept pair, and **no per-dimension pair set is ever materialized**.
//! `benches/abl_nd.rs` measures both paths against each other.
//!
//! Which path runs is an engine policy ([`NdPolicy`], set through
//! [`EngineBuilder::nd_mode`](crate::engine::EngineBuilder::nd_mode) /
//! [`EngineBuilder::sweep_dim`](crate::engine::EngineBuilder::sweep_dim)
//! and the CLI's `--nd-mode` / `--sweep-dim`).

use std::collections::HashSet;

use super::region::{Regions1D, RegionsNd};
use super::sink::{pack_pair, unpack_pair, CountSink, FilterSink, MatchSink, VecSink};
use crate::exec::ThreadPool;

/// N-D combination strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NdMode {
    /// Sweep one dimension, verify the rest inline ([`sweep_and_verify`]).
    #[default]
    Native,
    /// Match every dimension, intersect the pair sets ([`ReductionNd`]).
    Reduction,
}

impl std::str::FromStr for NdMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let t = s.trim();
        if t.eq_ignore_ascii_case("native") {
            Ok(NdMode::Native)
        } else if t.eq_ignore_ascii_case("reduce") || t.eq_ignore_ascii_case("reduction") {
            Ok(NdMode::Reduction)
        } else {
            Err(format!("unknown N-D mode '{t}' (valid: native, reduce)"))
        }
    }
}

/// Sweep-dimension choice for [`NdMode::Native`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SweepDim {
    /// Pick per call via [`select_sweep_dim`].
    #[default]
    Auto,
    /// Always sweep dimension `k` (clamped to `d - 1`).
    Fixed(usize),
}

impl std::str::FromStr for SweepDim {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let t = s.trim();
        if t.eq_ignore_ascii_case("auto") {
            return Ok(SweepDim::Auto);
        }
        t.parse::<usize>()
            .map(SweepDim::Fixed)
            .map_err(|_| format!("unknown sweep dimension '{t}' (valid: auto, or an index)"))
    }
}

/// The engine's N-D matching policy (mode + sweep-dimension choice),
/// carried by [`MatchParams`](crate::algos::MatchParams) into every
/// natively-N-D matcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct NdPolicy {
    pub mode: NdMode,
    pub sweep: SweepDim,
}

/// Regions sampled per side and dimension by [`select_sweep_dim`].
const SELECTIVITY_SAMPLE: usize = 64;

/// Per-dimension selectivity score from a strided sample: the expected
/// fraction of (s, u) pairs whose dimension-`k` projections intersect,
/// estimated as `(E[l_s] + E[l_u]) / span_k` — the α-model's pair
/// density from sampled endpoint statistics. Lower = more selective.
fn dim_score(subs: &Regions1D, upds: &Regions1D, k_sample: usize) -> f64 {
    let sample = |r: &Regions1D| -> (f64, f64, f64, usize) {
        let n = r.len();
        let stride = (n / k_sample).max(1);
        let (mut len_sum, mut lo_min, mut hi_max, mut count) =
            (0.0f64, f64::INFINITY, f64::NEG_INFINITY, 0usize);
        let mut i = 0;
        while i < n {
            len_sum += r.hi[i] - r.lo[i];
            lo_min = lo_min.min(r.lo[i]);
            hi_max = hi_max.max(r.hi[i]);
            count += 1;
            i += stride;
        }
        (len_sum, lo_min, hi_max, count)
    };
    let (sl, slo, shi, sc) = sample(subs);
    let (ul, ulo, uhi, uc) = sample(upds);
    if sc == 0 || uc == 0 {
        return 0.0;
    }
    let mean_len = sl / sc as f64 + ul / uc as f64;
    if mean_len <= 0.0 {
        // Zero-width sample: nothing can intersect on this dimension.
        return 0.0;
    }
    let span = shi.max(uhi) - slo.min(ulo);
    (mean_len / span.max(f64::MIN_POSITIVE)).min(1.0)
}

/// Pick the sweep dimension for the native pipeline: the dimension
/// whose sampled endpoint density predicts the fewest 1-D pairs
/// (strided sample of [`SELECTIVITY_SAMPLE`] regions per side per
/// dimension; the per-dimension scores are evaluated in parallel on
/// `pool` when it has workers to spare). Ties break to the lowest
/// dimension; d = 1 (and empty inputs) return 0.
pub fn select_sweep_dim(
    pool: &ThreadPool,
    nthreads: usize,
    subs: &RegionsNd,
    upds: &RegionsNd,
) -> usize {
    let d = subs.d().min(upds.d());
    if d <= 1 || subs.is_empty() || upds.is_empty() {
        return 0;
    }
    let score = |k: usize| dim_score(subs.project(k), upds.project(k), SELECTIVITY_SAMPLE);
    let scores: Vec<f64> = if nthreads > 1 {
        pool.fan_map(nthreads.min(d), d, score)
    } else {
        (0..d).map(score).collect()
    };
    let mut best = 0;
    for (k, &s) in scores.iter().enumerate() {
        if s < scores[best] {
            best = k;
        }
    }
    best
}

/// Resolve a [`SweepDim`] policy to a concrete dimension for this call.
pub fn resolve_sweep_dim(
    sweep: SweepDim,
    pool: &ThreadPool,
    nthreads: usize,
    subs: &RegionsNd,
    upds: &RegionsNd,
) -> usize {
    match sweep {
        SweepDim::Fixed(k) => k.min(subs.d().saturating_sub(1)),
        SweepDim::Auto => select_sweep_dim(pool, nthreads, subs, upds),
    }
}

/// Native sweep-and-verify N-D matching: run the 1-D matcher on the
/// `sweep` projections only, verifying the residual dimensions of every
/// reported pair inline through a [`FilterSink`] wrapped around `sink`.
///
/// Exactly-once follows from the 1-D matcher's exactly-once contract
/// (the filter is deterministic and stateless per pair). Parallel
/// matchers that want the verification inside their workers construct
/// per-worker `FilterSink`s instead (see the `match_nd` overrides in
/// [`crate::algos`]); this entry point is the serial/generic form.
pub fn sweep_and_verify<F>(
    subs: &RegionsNd,
    upds: &RegionsNd,
    sweep: usize,
    match1d: F,
    sink: &mut dyn MatchSink,
) where
    F: FnOnce(&Regions1D, &Regions1D, &mut dyn MatchSink),
{
    assert_eq!(subs.d(), upds.d(), "dimension mismatch");
    if subs.d() == 1 {
        match1d(subs.project(0), upds.project(0), sink);
        return;
    }
    let mut f = FilterSink::new(subs, upds, sweep, sink);
    match1d(subs.project(sweep), upds.project(sweep), &mut f);
}

/// The paper's per-dimension reduction (§2, footnote 1), kept as the
/// fallback N-D combiner (`--nd-mode reduce`): run the 1-D matcher once
/// per dimension and intersect the partial pair sets via a
/// `HashSet<u64>` of packed pairs — O(K₀ + K₁ + … + K_{d-1}) expected
/// combine time, which is exactly what the native pipeline avoids.
pub struct ReductionNd;

impl ReductionNd {
    /// Extend a 1-D matcher to d dimensions by reduction.
    ///
    /// `match1d(s_proj, u_proj, sink)` must report every intersecting
    /// pair of the 1-D projections exactly once.
    pub fn match_nd<F>(subs: &RegionsNd, upds: &RegionsNd, match1d: F, sink: &mut dyn MatchSink)
    where
        F: Fn(&Regions1D, &Regions1D, &mut VecSink),
    {
        Self::match_nd_with(None, subs, upds, match1d, sink);
    }

    /// [`match_nd`](Self::match_nd) charging the hash-set combine to
    /// `pool`'s cost-log **serial** term (it is master-only work,
    /// exactly like PSBM's Algorithm-7 combine) — so the work-span
    /// model sees the reduction's dominant cost. The engine's matchers
    /// route their `NdMode::Reduction` arms through this.
    pub fn match_nd_with<F>(
        pool: Option<&ThreadPool>,
        subs: &RegionsNd,
        upds: &RegionsNd,
        match1d: F,
        sink: &mut dyn MatchSink,
    ) where
        F: Fn(&Regions1D, &Regions1D, &mut VecSink),
    {
        let serial = |f: &mut dyn FnMut()| match pool {
            Some(p) => p.serial_section(f),
            None => f(),
        };
        assert_eq!(subs.d(), upds.d(), "dimension mismatch");
        let d = subs.d();
        if d == 1 {
            let mut v = VecSink::default();
            match1d(subs.project(0), upds.project(0), &mut v);
            for (s, u) in v.pairs {
                sink.report(s, u);
            }
            return;
        }

        // Dimension 0 seeds the candidate set…
        let mut v = VecSink::default();
        match1d(subs.project(0), upds.project(0), &mut v);
        let mut candidates: HashSet<u64> = HashSet::new();
        serial(&mut || {
            candidates = v.pairs.iter().map(|&(s, u)| pack_pair(s, u)).collect();
        });

        // …and each further dimension filters it.
        for k in 1..d {
            if candidates.is_empty() {
                return;
            }
            let mut vk = VecSink::default();
            match1d(subs.project(k), upds.project(k), &mut vk);
            serial(&mut || {
                let dim_pairs: HashSet<u64> =
                    vk.pairs.iter().map(|&(s, u)| pack_pair(s, u)).collect();
                candidates.retain(|p| dim_pairs.contains(p));
            });
        }

        let mut out: Vec<u64> = Vec::new();
        serial(&mut || {
            out = candidates.drain().collect();
            out.sort_unstable(); // deterministic report order
        });
        for p in out {
            let (s, u) = unpack_pair(p);
            sink.report(s, u);
        }
    }
}

/// Drive the native pipeline over a parallel 1-D matcher that accepts
/// a per-worker sink factory, collecting pairs into `sink`: resolve
/// the sweep dimension, project it, hand `run1d` a factory producing
/// per-worker [`FilterSink`]`<VecSink>`s, and drain the returned
/// sinks. The shared body of the PSBM/ITM/GBM `match_nd` overrides.
///
/// The per-worker pair buffers come from (and return to) `scratch`,
/// and `run1d` receives the same scratch for its own buffers (the
/// endpoint array and radix block on the PSBM path, the binning block
/// on the GBM path), so a warm `match_nd` call allocates nothing.
pub fn native_match<'a, R>(
    sweep: SweepDim,
    pool: &ThreadPool,
    nthreads: usize,
    subs: &'a RegionsNd,
    upds: &'a RegionsNd,
    scratch: &mut crate::core::scratch::MatchScratch,
    run1d: R,
    sink: &mut dyn MatchSink,
) where
    R: FnOnce(
        &'a Regions1D,
        &'a Regions1D,
        &mut crate::core::scratch::MatchScratch,
        &(dyn Fn(usize) -> FilterSink<'a, VecSink> + Sync),
    ) -> Vec<FilterSink<'a, VecSink>>,
{
    use crate::core::scratch::SinkDispenser;
    let k = resolve_sweep_dim(sweep, pool, nthreads, subs, upds);
    let disp = SinkDispenser::new(
        scratch
            .take_pair_sinks(nthreads)
            .into_iter()
            .map(|v| FilterSink::new(subs, upds, k, v))
            .collect(),
    );
    let mk = |p: usize| disp.take(p);
    let t_res = scratch.span_log.start();
    let out = run1d(subs.project(k), upds.project(k), &mut *scratch, &mk);
    let mut checked = 0u64;
    let collected: Vec<VecSink> = out
        .into_iter()
        .map(|fs| {
            let (v, c) = fs.into_parts();
            checked += c;
            v
        })
        .collect();
    // The Residual span brackets the sweep that drove the inline
    // checks; items = candidate pairs residual-verified.
    scratch.span_log.record(
        crate::obs::Phase::Residual,
        crate::obs::trace::MASTER_WORKER,
        t_res,
        checked,
    );
    scratch.drain_pair_sinks(
        collected,
        disp.into_remaining().map(FilterSink::into_inner),
        sink,
    );
}

/// Counting twin of [`native_match`]: per-worker
/// [`FilterSink`]`<CountSink>`s, summed — verification inside the
/// workers, no pair ever collected (the
/// [`MatchScratch`](crate::core::scratch::MatchScratch) still feeds
/// `run1d`'s endpoint/binning buffers).
pub fn native_count<'a, R>(
    sweep: SweepDim,
    pool: &ThreadPool,
    nthreads: usize,
    subs: &'a RegionsNd,
    upds: &'a RegionsNd,
    scratch: &mut crate::core::scratch::MatchScratch,
    run1d: R,
) -> u64
where
    R: FnOnce(
        &'a Regions1D,
        &'a Regions1D,
        &mut crate::core::scratch::MatchScratch,
        &(dyn Fn(usize) -> FilterSink<'a, CountSink> + Sync),
    ) -> Vec<FilterSink<'a, CountSink>>,
{
    let k = resolve_sweep_dim(sweep, pool, nthreads, subs, upds);
    let mk = move |_p: usize| FilterSink::new(subs, upds, k, CountSink::default());
    let t_res = scratch.span_log.start();
    let out = run1d(subs.project(k), upds.project(k), scratch, &mk);
    let (mut total, mut checked) = (0u64, 0u64);
    for fs in out {
        let (c, n) = fs.into_parts();
        total += c.count;
        checked += n;
    }
    scratch.span_log.record(
        crate::obs::Phase::Residual,
        crate::obs::trace::MASTER_WORKER,
        t_res,
        checked,
    );
    total
}

/// Back-compat spelling of [`ReductionNd::match_nd`] (the default
/// [`Matcher::match_nd`](crate::engine::Matcher::match_nd) for
/// backends without a native N-D override).
pub fn match_nd<F>(subs: &RegionsNd, upds: &RegionsNd, match1d: F, sink: &mut dyn MatchSink)
where
    F: Fn(&Regions1D, &Regions1D, &mut VecSink),
{
    ReductionNd::match_nd(subs, upds, match1d, sink);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::interval::Interval;
    use crate::core::sink::{canonicalize, VecSink};

    /// Trivial 1-D matcher oracle (BFM is defined in algos; core tests
    /// stay dependency-free with a local quadratic loop).
    fn bf1d(s: &Regions1D, u: &Regions1D, sink: &mut VecSink) {
        for i in 0..s.len() {
            for j in 0..u.len() {
                if s.get(i).intersects(&u.get(j)) {
                    sink.report(i as u32, j as u32);
                }
            }
        }
    }

    fn bf1d_dyn(s: &Regions1D, u: &Regions1D, sink: &mut dyn MatchSink) {
        for i in 0..s.len() {
            for j in 0..u.len() {
                if s.get(i).intersects(&u.get(j)) {
                    sink.report(i as u32, j as u32);
                }
            }
        }
    }

    fn direct_nd(subs: &RegionsNd, upds: &RegionsNd) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for i in 0..subs.len() {
            for j in 0..upds.len() {
                if subs.rects_intersect(i, upds, j) {
                    out.push((i as u32, j as u32));
                }
            }
        }
        out
    }

    fn random_rects(rng: &mut crate::prng::Rng, d: usize, count: usize) -> RegionsNd {
        let mut out = RegionsNd::new(d);
        for _ in 0..count {
            let rect: Vec<Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 50.0);
                    Interval::new(lo, lo + rng.uniform(0.0, 20.0))
                })
                .collect();
            out.push(&rect);
        }
        out
    }

    #[test]
    fn matches_direct_nd_on_random_rects() {
        crate::bench::prop::prop_check("ddim-vs-direct", 0xD1, |rng| {
            let d = 1 + rng.below(3) as usize;
            let n = 1 + rng.below(30) as usize;
            let m = 1 + rng.below(30) as usize;
            let subs = random_rects(rng, d, n);
            let upds = random_rects(rng, d, m);
            let mut sink = VecSink::default();
            ReductionNd::match_nd(&subs, &upds, bf1d, &mut sink);
            let got = canonicalize(sink.pairs);
            let want = canonicalize(direct_nd(&subs, &upds));
            crate::bench::prop::expect_eq(&got, &want, "pair sets")
        });
    }

    /// Native sweep-and-verify equals the reduction and the direct
    /// check for every possible sweep dimension.
    #[test]
    fn sweep_and_verify_equals_reduction_every_sweep_dim() {
        crate::bench::prop::prop_check("sweep-verify-vs-direct", 0xD2, |rng| {
            let d = 1 + rng.below(4) as usize;
            let n = 1 + rng.below(30) as usize;
            let m = 1 + rng.below(30) as usize;
            let subs = random_rects(rng, d, n);
            let upds = random_rects(rng, d, m);
            let want = canonicalize(direct_nd(&subs, &upds));
            for sweep in 0..d {
                let mut sink = VecSink::default();
                sweep_and_verify(&subs, &upds, sweep, bf1d_dyn, &mut sink);
                crate::bench::prop::expect_eq(
                    &canonicalize(sink.pairs),
                    &want,
                    &format!("sweep dim {sweep} of {d}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn select_sweep_dim_prefers_the_selective_dimension() {
        // Dimension 0 barely discriminates (regions half the space);
        // dimension 1 is sharp (0.1% of the space). The estimator must
        // pick dimension 1.
        let mut rng = crate::prng::Rng::new(0xD3);
        let mut subs = RegionsNd::new(2);
        let mut upds = RegionsNd::new(2);
        for _ in 0..200 {
            let wide = rng.uniform(0.0, 50.0);
            let sharp = rng.uniform(0.0, 99.9);
            subs.push(&[
                Interval::new(wide, wide + 50.0),
                Interval::new(sharp, sharp + 0.1),
            ]);
            let wide = rng.uniform(0.0, 50.0);
            let sharp = rng.uniform(0.0, 99.9);
            upds.push(&[
                Interval::new(wide, wide + 50.0),
                Interval::new(sharp, sharp + 0.1),
            ]);
        }
        let pool = ThreadPool::new(1);
        assert_eq!(select_sweep_dim(&pool, 1, &subs, &upds), 1);
        assert_eq!(select_sweep_dim(&pool, 2, &subs, &upds), 1, "parallel estimate");
        // Fixed policy clamps out-of-range dimensions.
        assert_eq!(
            resolve_sweep_dim(SweepDim::Fixed(9), &pool, 1, &subs, &upds),
            1
        );
        assert_eq!(
            resolve_sweep_dim(SweepDim::Auto, &pool, 1, &subs, &upds),
            1
        );
    }

    #[test]
    fn nd_mode_and_sweep_dim_parse() {
        assert_eq!("native".parse::<NdMode>().unwrap(), NdMode::Native);
        assert_eq!("Reduce".parse::<NdMode>().unwrap(), NdMode::Reduction);
        assert_eq!("reduction".parse::<NdMode>().unwrap(), NdMode::Reduction);
        assert!("frob".parse::<NdMode>().is_err());
        assert_eq!("auto".parse::<SweepDim>().unwrap(), SweepDim::Auto);
        assert_eq!("2".parse::<SweepDim>().unwrap(), SweepDim::Fixed(2));
        assert!("minus-one".parse::<SweepDim>().is_err());
    }

    #[test]
    fn figure3_example() {
        // Paper Fig. 3: S1..S3, U1..U2 in d=2; expected overlaps
        // {(S1,U1),(S2,U2),(S3,U1),(S3,U2)}. Coordinates chosen to
        // reproduce the figure's topology.
        let mut subs = RegionsNd::new(2);
        subs.push(&[Interval::new(0.0, 4.0), Interval::new(4.0, 9.0)]); // S1
        subs.push(&[Interval::new(7.0, 12.0), Interval::new(0.0, 3.0)]); // S2
        subs.push(&[Interval::new(2.0, 10.0), Interval::new(1.0, 6.0)]); // S3
        let mut upds = RegionsNd::new(2);
        upds.push(&[Interval::new(1.0, 5.0), Interval::new(2.0, 7.0)]); // U1
        upds.push(&[Interval::new(6.0, 11.0), Interval::new(2.0, 5.0)]); // U2
        let want = vec![(0, 0), (1, 1), (2, 0), (2, 1)];
        let mut sink = VecSink::default();
        ReductionNd::match_nd(&subs, &upds, bf1d, &mut sink);
        assert_eq!(canonicalize(sink.pairs), want);
        for sweep in 0..2 {
            let mut sink = VecSink::default();
            sweep_and_verify(&subs, &upds, sweep, bf1d_dyn, &mut sink);
            assert_eq!(canonicalize(sink.pairs), want, "sweep {sweep}");
        }
    }

    #[test]
    fn empty_inputs() {
        let subs = RegionsNd::new(2);
        let upds = RegionsNd::new(2);
        let mut sink = VecSink::default();
        ReductionNd::match_nd(&subs, &upds, bf1d, &mut sink);
        assert!(sink.pairs.is_empty());
        let mut sink = VecSink::default();
        sweep_and_verify(&subs, &upds, 0, bf1d_dyn, &mut sink);
        assert!(sink.pairs.is_empty());
        let pool = ThreadPool::new(0);
        assert_eq!(select_sweep_dim(&pool, 1, &subs, &upds), 0);
    }
}

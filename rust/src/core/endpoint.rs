//! The sweep endpoint record and its **compact `u64` sort key**.
//!
//! SBM/PSBM sort the `2(n+m)` interval endpoints and sweep them in
//! order (paper Algorithms 4/6). This module owns the endpoint
//! encoding so the sort hot path, the scratch buffers
//! ([`super::scratch`]) and the algorithms all agree on one layout:
//!
//! * `hi` — the **sort key**: the position mapped through the
//!   order-preserving IEEE-754 sign-magnitude flip
//!   ([`crate::exec::f64_key`]), one `u64` word. `-0.0` is normalized
//!   to `+0.0` first, because the sweep must agree with Intersect-1D,
//!   where `-0.0 == 0.0` (a raw `f64_key` orders them strictly and
//!   would let `[a, -0.0)` match `[0.0, b)`).
//! * `lo` — payload plus comparison tie-break bits (see below).
//!
//! **Tie-breaking.** Positions collide; intervals are half-open, so at
//! equal position *upper* endpoints must be processed before *lower*
//! ones (`[a, b)` and `[b, c)` must not match). The radix path
//! ([`crate::exec::radix`]) sorts by `hi` alone and gets the tie-break
//! from **stability + build order**: [`build_endpoints`] emits all
//! uppers before all lowers (subscriptions before updates, ascending
//! index), and a stable sort keeps that order within equal keys. The
//! comparison fallback sorts by the full [`Endpoint::sort_key`]
//! (`u128`), whose `lo` bit layout encodes the *same* order —
//! property-tested to produce bit-identical arrays.
//!
//! `lo` layout: bit 63 = side (0 for uppers, so they sort first at
//! equal positions); bit 62 = update-group (subscriptions first);
//! bits 2..=33 = region idx; bit 1 = is_upper; bit 0 = is_update.

use super::region::Regions1D;
use crate::exec::psort::par_sort_by_key;
use crate::exec::radix::{par_radix_sort_by_key, radix_sort_by_key, RadixScratch, SortAlgo};
use crate::exec::{f64_key, ThreadPool};

/// One interval endpoint, stored **sort-ready**: the position is kept
/// as its order-preserving bit pattern and the tie-break bits are
/// pre-composed, so the radix path sorts one `u64` word and the
/// comparison fallback compares two with no per-comparison key
/// recomputation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Endpoint {
    /// The compact sort key: `f64_key(pos)` (with `-0.0` → `+0.0`).
    pub hi: u64,
    /// Tie-break + payload bits (see module docs).
    pub lo: u64,
}

const LOWER_SORTS_LAST: u64 = 1 << 63;
const UPDATE_SORTS_LAST: u64 = 1 << 62;
const IDX_MASK: u64 = (1 << 62) - 1;

impl Endpoint {
    #[inline]
    pub fn new(pos: f64, idx: u32, is_upper: bool, is_update: bool) -> Self {
        let side = if is_upper { 0 } else { LOWER_SORTS_LAST };
        let group = if is_update { UPDATE_SORTS_LAST } else { 0 };
        // `+ 0.0` collapses -0.0 onto +0.0 (every other value,
        // including NaN payloads, is unchanged): the sweep's order must
        // match Intersect-1D, which compares positions with IEEE `<`.
        Self {
            hi: f64_key(pos + 0.0),
            lo: side | group | (idx as u64) << 2 | (is_upper as u64) << 1 | is_update as u64,
        }
    }

    #[inline]
    pub fn idx(self) -> u32 {
        ((self.lo & IDX_MASK) >> 2) as u32
    }

    #[inline]
    pub fn is_upper(self) -> bool {
        self.lo & 2 != 0
    }

    #[inline]
    pub fn is_update(self) -> bool {
        self.lo & 1 != 0
    }

    /// Position (decoded from the order-preserving bits; debug use).
    pub fn pos(self) -> f64 {
        let bits = if self.hi & (1 << 63) != 0 {
            self.hi & !(1 << 63)
        } else {
            !self.hi
        };
        f64::from_bits(bits)
    }

    /// The compact radix key: position order, one `u64` word. Ties are
    /// broken by stable-sort input order (see module docs).
    #[inline]
    pub fn radix_key(self) -> u64 {
        self.hi
    }

    /// Total comparison key: position, then side (uppers first), then
    /// update-group, then idx — a pure bit concatenation of the stored
    /// words. Encodes exactly the order the stable radix path produces
    /// from [`build_endpoints`] input order.
    #[inline]
    pub fn sort_key(self) -> u128 {
        (self.hi as u128) << 64 | self.lo as u128
    }
}

/// Slot of one endpoint in the canonical build order (uppers before
/// lowers, subscriptions before updates, ascending index) — the order
/// whose stable sort implements the tie-break. Shared by the serial
/// builder below and PSBM's parallel builder.
#[inline]
pub fn endpoint_slot(
    n_subs: usize,
    n_upds: usize,
    idx: usize,
    is_upper: bool,
    is_update: bool,
) -> usize {
    let base = match (is_upper, is_update) {
        (true, false) => 0,
        (true, true) => n_subs,
        (false, false) => n_subs + n_upds,
        (false, true) => 2 * n_subs + n_upds,
    };
    base + idx
}

/// Build the 2(n+m) endpoint array (Algorithm 4 lines 1–3) into a
/// reusable buffer, in canonical build order.
pub fn build_endpoints_into(subs: &Regions1D, upds: &Regions1D, out: &mut Vec<Endpoint>) {
    out.clear();
    out.reserve(2 * (subs.len() + upds.len()));
    for i in 0..subs.len() {
        out.push(Endpoint::new(subs.hi[i], i as u32, true, false));
    }
    for j in 0..upds.len() {
        out.push(Endpoint::new(upds.hi[j], j as u32, true, true));
    }
    for i in 0..subs.len() {
        out.push(Endpoint::new(subs.lo[i], i as u32, false, false));
    }
    for j in 0..upds.len() {
        out.push(Endpoint::new(upds.lo[j], j as u32, false, true));
    }
}

/// Build the 2(n+m) endpoint array into a fresh vector.
pub fn build_endpoints(subs: &Regions1D, upds: &Regions1D) -> Vec<Endpoint> {
    let mut t = Vec::new();
    build_endpoints_into(subs, upds, &mut t);
    t
}

/// Sort an endpoint array with the selected algorithm. The radix path
/// sorts by the compact `u64` key, relying on stability + canonical
/// build order for the tie-break, so **`endpoints` must still be in
/// [`build_endpoints`] order** (every in-tree builder emits it). The
/// merge path sorts by the full `u128` comparison key, which encodes
/// the same total order — both paths yield bit-identical arrays.
/// `pool: None` runs serially.
pub fn sort_endpoints(
    pool: Option<(&ThreadPool, usize)>,
    endpoints: &mut [Endpoint],
    aux: &mut Vec<Endpoint>,
    radix: &mut RadixScratch,
    sort: SortAlgo,
) {
    match (sort, pool) {
        (SortAlgo::Radix, Some((pool, nthreads))) => {
            par_radix_sort_by_key(pool, nthreads, endpoints, aux, radix, |e| e.radix_key());
        }
        (SortAlgo::Radix, None) => {
            radix_sort_by_key(endpoints, aux, radix, |e| e.radix_key());
        }
        (SortAlgo::Merge, Some((pool, nthreads))) => {
            par_sort_by_key(pool, nthreads, endpoints, |e| e.sort_key());
        }
        (SortAlgo::Merge, None) => {
            // u128 keys are distinct (idx/side/kind bits), so an
            // unstable sort yields the same unique order.
            endpoints.sort_unstable_by_key(|e| e.sort_key());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::interval::Interval;
    use crate::prng::Rng;

    #[test]
    fn endpoint_encoding_roundtrip() {
        let e = Endpoint::new(3.5, 1234, true, false);
        assert_eq!(e.idx(), 1234);
        assert!(e.is_upper());
        assert!(!e.is_update());
        assert_eq!(e.pos(), 3.5);
        let e2 = Endpoint::new(-1.0, 0, false, true);
        assert!(!e2.is_upper());
        assert!(e2.is_update());
        assert_eq!(e2.pos(), -1.0);
        // Large indices survive the group bits.
        let e3 = Endpoint::new(0.0, u32::MAX, false, false);
        assert_eq!(e3.idx(), u32::MAX);
    }

    #[test]
    fn uppers_sort_before_lowers_at_equal_pos() {
        let upper = Endpoint::new(5.0, 7, true, false);
        let lower = Endpoint::new(5.0, 3, false, true);
        assert!(upper.sort_key() < lower.sort_key());
        assert_eq!(upper.radix_key(), lower.radix_key(), "compact keys tie");
        // and position dominates
        let earlier = Endpoint::new(4.9, 9, false, false);
        assert!(earlier.sort_key() < upper.sort_key());
        assert!(earlier.radix_key() < upper.radix_key());
    }

    #[test]
    fn negative_zero_ties_with_positive_zero() {
        // -0.0 == 0.0 under Intersect-1D, so their keys must be equal
        // and the side bit must decide: an upper at 0.0 precedes a
        // lower at -0.0 (touching intervals stay non-matching).
        let upper = Endpoint::new(0.0, 0, true, false);
        let lower = Endpoint::new(-0.0, 1, false, true);
        assert_eq!(upper.radix_key(), lower.radix_key());
        assert!(upper.sort_key() < lower.sort_key());
    }

    #[test]
    fn build_order_is_the_comparison_tie_order() {
        // With ALL positions equal, the canonical build order must
        // already be sorted by the comparison key — that equivalence is
        // what lets the stable radix path skip the tie bits entirely.
        let iv = Interval::new(2.0, 2.0); // zero-width: all 4 kinds at one pos
        let subs = Regions1D::from_intervals(&[iv; 3]);
        let upds = Regions1D::from_intervals(&[iv; 2]);
        let built = build_endpoints(&subs, &upds);
        assert_eq!(built.len(), 10);
        let mut sorted = built.clone();
        sorted.sort_unstable_by_key(|e| e.sort_key());
        assert_eq!(built, sorted, "build order must equal comparison order at ties");
        // Slots agree with the builder.
        for (slot, e) in built.iter().enumerate() {
            assert_eq!(
                endpoint_slot(3, 2, e.idx() as usize, e.is_upper(), e.is_update()),
                slot
            );
        }
    }

    /// The satellite stability test: equal positions, -0.0 vs 0.0,
    /// subnormals, ±inf — radix (serial and parallel) and comparison
    /// sorts must produce bit-identical arrays.
    #[test]
    fn radix_and_comparison_sorts_agree_on_pathological_positions() {
        let pool = ThreadPool::new(3);
        let specials = [
            0.0,
            -0.0,
            f64::MIN_POSITIVE,          // smallest normal
            5e-324,                     // subnormal
            -5e-324,
            f64::INFINITY,
            f64::NEG_INFINITY,
            1.0,
            -1.0,
            f64::MAX,
            -f64::MAX,
        ];
        let mut rng = Rng::new(0xE9D);
        let mut subs = Regions1D::default();
        let mut upds = Regions1D::default();
        for i in 0..600 {
            let pick = |rng: &mut Rng| -> f64 {
                if rng.chance(0.7) {
                    specials[rng.below(specials.len() as u64) as usize]
                } else {
                    rng.uniform(-2.0, 2.0)
                }
            };
            let (a, b) = (pick(&mut rng), pick(&mut rng));
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            if i % 2 == 0 {
                subs.push(Interval::new(lo, hi));
            } else {
                upds.push(Interval::new(lo, hi));
            }
        }
        let built = build_endpoints(&subs, &upds);

        let mut merge = built.clone();
        sort_endpoints(None, &mut merge, &mut Vec::new(), &mut RadixScratch::new(), SortAlgo::Merge);
        let mut radix = built.clone();
        sort_endpoints(None, &mut radix, &mut Vec::new(), &mut RadixScratch::new(), SortAlgo::Radix);
        assert_eq!(radix, merge, "serial radix != comparison order");
        for p in [1usize, 2, 4] {
            let mut par = built.clone();
            sort_endpoints(
                Some((&pool, p)),
                &mut par,
                &mut Vec::new(),
                &mut RadixScratch::new(),
                SortAlgo::Radix,
            );
            assert_eq!(par, merge, "parallel radix (p={p}) != comparison order");
            let mut pm = built.clone();
            sort_endpoints(
                Some((&pool, p)),
                &mut pm,
                &mut Vec::new(),
                &mut RadixScratch::new(),
                SortAlgo::Merge,
            );
            assert_eq!(pm, merge, "parallel merge (p={p}) != comparison order");
        }
    }

    #[test]
    fn sort_paths_agree_on_random_workloads_property() {
        let pool = ThreadPool::new(5);
        crate::bench::prop::prop_check("endpoint-radix-vs-merge", 0xE9E, |rng| {
            let n = 1 + rng.below(400) as usize;
            let m = 1 + rng.below(400) as usize;
            let space = rng.uniform(1.0, 1e6);
            let subs = crate::core::region::random_regions_1d(rng, n, space, space / 20.0);
            let upds = crate::core::region::random_regions_1d(rng, m, space, space / 20.0);
            let built = build_endpoints(&subs, &upds);
            let mut want = built.clone();
            want.sort_unstable_by_key(|e| e.sort_key());
            let p = 1 + rng.below(6) as usize;
            let mut radix = built.clone();
            sort_endpoints(
                Some((&pool, p)),
                &mut radix,
                &mut Vec::new(),
                &mut RadixScratch::new(),
                SortAlgo::Radix,
            );
            crate::bench::prop::expect_eq(&radix, &want, "radix vs comparison")
        });
    }
}

//! Half-open 1-D intervals and the paper's Intersect-1D (Algorithm 1).

/// A half-open interval `[lo, hi)` on one dimension.
///
/// The paper's Algorithm 1 tests `x.lo < y.hi && y.lo < x.hi`
/// (non-empty intervals assumed); HLA ranges are half-open
/// `[lower bound, upper bound)`, which is what the strict comparisons
/// implement: touching intervals do **not** intersect.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interval {
    pub lo: f64,
    pub hi: f64,
}

impl Interval {
    #[inline]
    pub fn new(lo: f64, hi: f64) -> Self {
        debug_assert!(lo <= hi, "interval [{lo}, {hi}) has lo > hi");
        Self { lo, hi }
    }

    /// Paper Algorithm 1: Intersect-1D.
    #[inline]
    pub fn intersects(&self, other: &Interval) -> bool {
        self.lo < other.hi && other.lo < self.hi
    }

    #[inline]
    pub fn len(&self) -> f64 {
        self.hi - self.lo
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.lo >= self.hi
    }

    #[inline]
    pub fn contains_point(&self, x: f64) -> bool {
        self.lo <= x && x < self.hi
    }

    /// Smallest interval covering both (used by GBM's bounding box).
    #[inline]
    pub fn hull(&self, other: &Interval) -> Interval {
        Interval {
            lo: self.lo.min(other.lo),
            hi: self.hi.max(other.hi),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlapping() {
        assert!(Interval::new(0.0, 2.0).intersects(&Interval::new(1.0, 3.0)));
        assert!(Interval::new(1.0, 3.0).intersects(&Interval::new(0.0, 2.0)));
    }

    #[test]
    fn touching_half_open_do_not_intersect() {
        assert!(!Interval::new(0.0, 1.0).intersects(&Interval::new(1.0, 2.0)));
        assert!(!Interval::new(1.0, 2.0).intersects(&Interval::new(0.0, 1.0)));
    }

    #[test]
    fn nested_intersect() {
        assert!(Interval::new(0.0, 10.0).intersects(&Interval::new(4.0, 5.0)));
        assert!(Interval::new(4.0, 5.0).intersects(&Interval::new(0.0, 10.0)));
    }

    #[test]
    fn identical_intersect() {
        let a = Interval::new(2.0, 4.0);
        assert!(a.intersects(&a));
    }

    #[test]
    fn disjoint_do_not_intersect() {
        assert!(!Interval::new(0.0, 1.0).intersects(&Interval::new(5.0, 6.0)));
    }

    #[test]
    fn intersects_is_symmetric_property() {
        crate::bench::prop::prop_check("intersect-symmetry", 0xA11CE, |rng| {
            let mk = |rng: &mut crate::prng::Rng| {
                let lo = rng.uniform(0.0, 100.0);
                Interval::new(lo, lo + rng.uniform(0.0, 10.0))
            };
            let (a, b) = (mk(rng), mk(rng));
            if a.intersects(&b) == b.intersects(&a) {
                Ok(())
            } else {
                Err(format!("{a:?} vs {b:?}"))
            }
        });
    }

    #[test]
    fn point_containment_half_open() {
        let i = Interval::new(1.0, 2.0);
        assert!(i.contains_point(1.0));
        assert!(!i.contains_point(2.0));
    }

    #[test]
    fn hull_covers_both() {
        let h = Interval::new(0.0, 1.0).hull(&Interval::new(5.0, 6.0));
        assert_eq!(h, Interval::new(0.0, 6.0));
    }
}

//! Problem-domain types: intervals, d-rectangles, region sets, match
//! sinks, the sweep endpoint encoding with its compact `u64` sort key
//! ([`endpoint`]), the reusable match scratch ([`scratch`]), and the
//! d-dimensional pipeline (native sweep-and-verify plus the paper-§2
//! reduction fallback, [`ddim`]).

pub mod ddim;
pub mod endpoint;
pub mod interval;
pub mod region;
pub mod scratch;
pub mod sink;

pub use interval::Interval;
pub use region::{Regions1D, RegionsNd};
pub use scratch::{MatchScratch, ScratchStats};
pub use sink::{CountSink, MatchSink, PairVec, VecSink};

/// Index of a region inside its set (regions are dense arrays).
pub type RegionIdx = u32;

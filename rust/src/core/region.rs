//! Region storage: structure-of-arrays interval sets.
//!
//! The matching algorithms operate on dense arrays of intervals (the
//! paper's S and U). SoA layout (`lo[]`, `hi[]`) keeps the hot loops
//! vectorizable and mirrors the L1 kernel's input layout.

use super::interval::Interval;
use crate::prng::Rng;

/// A set of 1-D regions in SoA layout.
#[derive(Debug, Clone, Default)]
pub struct Regions1D {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl Regions1D {
    pub fn with_capacity(n: usize) -> Self {
        Self {
            lo: Vec::with_capacity(n),
            hi: Vec::with_capacity(n),
        }
    }

    pub fn from_intervals(intervals: &[Interval]) -> Self {
        Self {
            lo: intervals.iter().map(|i| i.lo).collect(),
            hi: intervals.iter().map(|i| i.hi).collect(),
        }
    }

    #[inline]
    pub fn push(&mut self, iv: Interval) {
        self.lo.push(iv.lo);
        self.hi.push(iv.hi);
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.lo.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.lo.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> Interval {
        Interval {
            lo: self.lo[i],
            hi: self.hi[i],
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize, iv: Interval) {
        self.lo[i] = iv.lo;
        self.hi[i] = iv.hi;
    }

    pub fn iter(&self) -> impl Iterator<Item = Interval> + '_ {
        self.lo
            .iter()
            .zip(&self.hi)
            .map(|(&lo, &hi)| Interval { lo, hi })
    }

    /// Bounding interval of the whole set (GBM's `[lb, ub)`).
    pub fn bounds(&self) -> Option<Interval> {
        if self.is_empty() {
            return None;
        }
        let lo = self.lo.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = self.hi.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Some(Interval { lo, hi })
    }
}

/// A set of d-dimensional axis-parallel rectangles, stored per
/// dimension (paper §2's d-rectangles).
#[derive(Debug, Clone)]
pub struct RegionsNd {
    /// One Regions1D per dimension; all have the same length.
    pub dims: Vec<Regions1D>,
}

impl RegionsNd {
    pub fn new(d: usize) -> Self {
        assert!(d >= 1);
        Self {
            dims: (0..d).map(|_| Regions1D::default()).collect(),
        }
    }

    pub fn d(&self) -> usize {
        self.dims.len()
    }

    pub fn len(&self) -> usize {
        self.dims[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append a rectangle given as per-dimension intervals.
    pub fn push(&mut self, rect: &[Interval]) {
        assert_eq!(rect.len(), self.d());
        for (dim, iv) in self.dims.iter_mut().zip(rect) {
            dim.push(*iv);
        }
    }

    pub fn get(&self, i: usize) -> Vec<Interval> {
        self.dims.iter().map(|d| d.get(i)).collect()
    }

    /// Two rectangles intersect iff all their projections intersect.
    pub fn rects_intersect(&self, i: usize, other: &RegionsNd, j: usize) -> bool {
        debug_assert_eq!(self.d(), other.d());
        self.dims
            .iter()
            .zip(&other.dims)
            .all(|(a, b)| a.get(i).intersects(&b.get(j)))
    }

    /// [`rects_intersect`](Self::rects_intersect) skipping dimension
    /// `skip` — the native N-D pipeline's residual verification (the
    /// swept dimension is already known to intersect). Half-open
    /// Intersect-1D on the SoA arrays, no `Interval` construction.
    #[inline]
    pub fn rects_intersect_except(&self, i: usize, other: &RegionsNd, j: usize, skip: usize) -> bool {
        debug_assert_eq!(self.d(), other.d());
        for (k, (a, b)) in self.dims.iter().zip(&other.dims).enumerate() {
            if k == skip {
                continue;
            }
            if !(a.lo[i] < b.hi[j] && b.lo[j] < a.hi[i]) {
                return false;
            }
        }
        true
    }

    /// The 1-D projection onto dimension `k`.
    pub fn project(&self, k: usize) -> &Regions1D {
        &self.dims[k]
    }
}

/// Generate `count` random 1-D regions of fixed length `l` on
/// `[0, space)` — the paper §5 synthetic workload building block.
///
/// `l` is clamped to `space`: a region longer than the routing space
/// degenerates to the whole space instead of producing an inverted
/// placement range (`lo` drawn from a negative interval) and regions
/// sticking out below zero. For `l < space` the produced stream is
/// bit-identical to the historical one.
pub fn random_regions_1d(rng: &mut Rng, count: usize, space: f64, l: f64) -> Regions1D {
    assert!(
        space > 0.0 && l >= 0.0 && space.is_finite() && l.is_finite(),
        "invalid workload geometry: space={space} l={l}"
    );
    let l = l.min(space);
    let max_lo = space - l;
    let mut out = Regions1D::with_capacity(count);
    for _ in 0..count {
        let lo = rng.uniform(0.0, max_lo);
        out.push(Interval::new(lo, lo + l));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soa_roundtrip() {
        let mut r = Regions1D::default();
        r.push(Interval::new(1.0, 2.0));
        r.push(Interval::new(3.0, 5.0));
        assert_eq!(r.len(), 2);
        assert_eq!(r.get(1), Interval::new(3.0, 5.0));
        r.set(0, Interval::new(0.0, 9.0));
        assert_eq!(r.get(0), Interval::new(0.0, 9.0));
        assert_eq!(r.bounds(), Some(Interval::new(0.0, 9.0)));
    }

    #[test]
    fn bounds_of_empty_is_none() {
        assert!(Regions1D::default().bounds().is_none());
    }

    #[test]
    fn nd_projection_intersection() {
        let mut a = RegionsNd::new(2);
        a.push(&[Interval::new(0.0, 2.0), Interval::new(0.0, 2.0)]);
        let mut b = RegionsNd::new(2);
        b.push(&[Interval::new(1.0, 3.0), Interval::new(5.0, 6.0)]);
        b.push(&[Interval::new(1.0, 3.0), Interval::new(1.0, 3.0)]);
        assert!(!a.rects_intersect(0, &b, 0)); // dim 1 disjoint
        assert!(a.rects_intersect(0, &b, 1));
    }

    /// Regression: `l ≥ space` used to draw `lo` from an inverted
    /// `uniform(0, negative)` range, yielding regions with negative
    /// lower bounds; it now clamps to the whole space.
    #[test]
    fn oversized_region_length_clamps_to_space() {
        let mut rng = Rng::new(11);
        for l in [5.0, 12.5, 1e9] {
            let r = random_regions_1d(&mut rng, 20, 5.0, l);
            assert_eq!(r.len(), 20);
            for iv in r.iter() {
                assert_eq!(iv, Interval::new(0.0, 5.0), "l={l}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "invalid workload geometry")]
    fn nonpositive_space_is_rejected() {
        let mut rng = Rng::new(12);
        let _ = random_regions_1d(&mut rng, 1, 0.0, 1.0);
    }

    #[test]
    fn random_regions_have_length_l() {
        let mut rng = Rng::new(3);
        let r = random_regions_1d(&mut rng, 100, 1000.0, 5.0);
        assert_eq!(r.len(), 100);
        for iv in r.iter() {
            assert!((iv.len() - 5.0).abs() < 1e-9);
            assert!(iv.lo >= 0.0 && iv.hi <= 1000.0);
        }
    }
}

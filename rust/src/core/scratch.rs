//! Reusable match scratch: the buffers behind the zero-allocation
//! steady state of repeated match calls and session commits.
//!
//! A cold match call allocates the endpoint array, the radix ping-pong
//! buffer, the histogram block and one pair buffer per worker; a warm
//! call should allocate **nothing**. [`MatchScratch`] owns all of
//! those and hands them out by capacity-preserving take/give pairs:
//!
//! * the [`DdmEngine`](crate::engine::DdmEngine) owns one behind a
//!   `Mutex`, attached to every [`ExecCtx`](crate::engine::ExecCtx) it
//!   creates, so back-to-back `match_nd`/`count_nd` calls reuse the
//!   previous call's buffers (`try_lock`: a contended or absent
//!   scratch degrades to per-call allocation, never blocks);
//! * every [`DdmSession`](crate::session::DdmSession) owns one
//!   directly and reuses its per-region query and diff buffers across
//!   epochs (a [`ShardedSession`](crate::shard::ShardedSession) gets
//!   per-shard scratch for free — each inner session owns its own);
//! * [`ScratchStats`] snapshots every capacity, so benches and tests
//!   can assert the steady state really stops growing
//!   (`benches/abl_sort.rs`).

use crate::core::endpoint::Endpoint;
use crate::core::sink::VecSink;
use crate::core::RegionIdx;
use crate::exec::radix::RadixScratch;

/// Reusable buffers for the matching hot paths. See the module docs
/// for ownership; `Default`/[`new`](Self::new) is an empty scratch
/// that fills lazily on first use.
#[derive(Debug, Default)]
pub struct MatchScratch {
    /// The endpoint build buffer (SBM/PSBM phase 1a).
    pub endpoints: Vec<Endpoint>,
    /// The radix sort's ping-pong buffer.
    pub aux: Vec<Endpoint>,
    /// The radix sort's per-worker histogram block.
    pub radix: RadixScratch,
    /// Pooled per-worker pair buffers (cleared, capacity kept).
    pairs_pool: Vec<Vec<(RegionIdx, RegionIdx)>>,
    /// Pooled `u32` work buffers (session recompute/diff scratch, GBM
    /// binning offsets; cleared, capacity kept).
    u32_pool: Vec<Vec<u32>>,
    /// Phase-span capture for the match call running over this scratch
    /// ([`crate::obs`]). Defaults to the disabled sink (a branch per
    /// phase, no allocation); the engine/session enable it when their
    /// `trace` knob is on and absorb it after each call/epoch.
    /// Deliberately **not** part of [`stats`](Self::stats): the
    /// zero-alloc steady-state assertions measure the match buffers,
    /// and span capture is an opt-in observer with its own fixed-size
    /// buffer.
    pub span_log: crate::obs::SpanSink,
}

impl MatchScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take `n` empty per-worker collection sinks, reusing pooled pair
    /// buffers (most-recently-returned first).
    pub fn take_pair_sinks(&mut self, n: usize) -> Vec<VecSink> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(VecSink {
                pairs: self.pairs_pool.pop().unwrap_or_default(),
            });
        }
        out
    }

    /// Return collection sinks to the pool (cleared, capacity kept).
    pub fn give_pair_sinks(&mut self, sinks: impl IntoIterator<Item = VecSink>) {
        for mut s in sinks {
            s.pairs.clear();
            self.pairs_pool.push(s.pairs);
        }
    }

    /// Replay every pair from per-worker `sinks` (worker order) into
    /// `sink`, then return all buffers — including any unclaimed
    /// `leftovers` — to the pool in **reverse** order. The pool is a
    /// stack, so the reversal hands worker p the same buffer (and its
    /// grown capacity) on the next call: per-worker capacities are
    /// exactly stable on warm paths. The one home of that invariant,
    /// shared by the PSBM/GBM `match_1d` overrides and
    /// [`ddim::native_match`](crate::core::ddim::native_match).
    pub fn drain_pair_sinks(
        &mut self,
        sinks: Vec<VecSink>,
        leftovers: impl IntoIterator<Item = VecSink>,
        sink: &mut dyn crate::core::sink::MatchSink,
    ) {
        let mut back = sinks;
        for s in &back {
            for &(a, b) in &s.pairs {
                sink.report(a, b);
            }
        }
        back.extend(leftovers);
        self.give_pair_sinks(back.into_iter().rev());
    }

    /// Take `n` empty `u32` buffers from the pool.
    pub fn take_u32_bufs(&mut self, n: usize) -> Vec<Vec<u32>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32_pool.pop().unwrap_or_default());
        }
        out
    }

    /// Take one empty `u32` buffer from the pool.
    pub fn take_u32(&mut self) -> Vec<u32> {
        self.u32_pool.pop().unwrap_or_default()
    }

    /// Return `u32` buffers to the pool (cleared, capacity kept).
    pub fn give_u32_bufs(&mut self, bufs: impl IntoIterator<Item = Vec<u32>>) {
        for mut b in bufs {
            b.clear();
            self.u32_pool.push(b);
        }
    }

    /// Return one `u32` buffer to the pool.
    pub fn give_u32(&mut self, buf: Vec<u32>) {
        self.give_u32_bufs([buf]);
    }

    /// Capacity snapshot for allocation-free assertions.
    pub fn stats(&self) -> ScratchStats {
        ScratchStats {
            endpoints_cap: self.endpoints.capacity(),
            aux_cap: self.aux.capacity(),
            radix_counts_cap: self.radix.counts_capacity(),
            pooled_pair_bufs: self.pairs_pool.len(),
            pooled_pair_cap: self.pairs_pool.iter().map(Vec::capacity).sum(),
            pooled_u32_bufs: self.u32_pool.len(),
            pooled_u32_cap: self.u32_pool.iter().map(Vec::capacity).sum(),
        }
    }
}

/// Capacity snapshot of a [`MatchScratch`]: two equal snapshots around
/// a warm call mean the call allocated nothing from the scratch's
/// buffers (the steady-state acceptance check of `abl_sort`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ScratchStats {
    pub endpoints_cap: usize,
    pub aux_cap: usize,
    pub radix_counts_cap: usize,
    pub pooled_pair_bufs: usize,
    pub pooled_pair_cap: usize,
    pub pooled_u32_bufs: usize,
    pub pooled_u32_cap: usize,
}

/// Hands pre-built per-worker sinks out by worker index, across the
/// `Fn(usize) -> S` factory seam the parallel matchers share — so
/// pooled sinks flow into parallel regions without locks. A thin
/// domain wrapper over the claims layer's take-once cells
/// ([`TakeCells`](crate::exec::claims::TakeCells)).
///
/// # Safety contract
/// `take(p)` must be called **at most once per distinct `p`** (the
/// matchers call their factory exactly once per worker index, each
/// from the worker that owns it). A sequential double take panics in
/// every build; under `--features race-check` a *concurrent* double
/// take also panics deterministically with site/thread diagnostics
/// instead of racing. Sinks never claimed can be recovered with
/// [`into_remaining`](Self::into_remaining).
pub struct SinkDispenser<S> {
    cells: crate::exec::claims::TakeCells<S>,
}

impl<S> SinkDispenser<S> {
    /// Wrap per-worker `sinks`; worker `p` claims index `p`.
    pub fn new(sinks: Vec<S>) -> Self {
        Self {
            cells: crate::exec::claims::TakeCells::new(sinks, "scratch::SinkDispenser"),
        }
    }

    /// Claim the sink for worker `p`. Panics if `p` is out of range or
    /// already claimed (both indicate a broken factory contract).
    pub fn take(&self, p: usize) -> S {
        // SAFETY: per the documented contract each worker index is
        // claimed at most once, from one thread; violations panic
        // (always when sequential, under race-check also concurrent).
        unsafe { self.cells.take(p) }
    }

    /// Recover every unclaimed sink (for returning them to the pool).
    pub fn into_remaining(self) -> impl Iterator<Item = S> {
        self.cells.into_remaining()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pools_preserve_capacity_across_take_give() {
        let mut scratch = MatchScratch::new();
        let mut sinks = scratch.take_pair_sinks(3);
        for s in &mut sinks {
            for i in 0..100u32 {
                s.pairs.push((i, i));
            }
        }
        scratch.give_pair_sinks(sinks);
        let stats = scratch.stats();
        assert_eq!(stats.pooled_pair_bufs, 3);
        assert!(stats.pooled_pair_cap >= 300);

        // A warm take/give cycle neither grows nor shrinks the pool.
        let sinks = scratch.take_pair_sinks(3);
        assert!(sinks.iter().all(|s| s.pairs.is_empty()), "sinks come back cleared");
        assert!(sinks.iter().all(|s| s.pairs.capacity() >= 100), "capacity survives");
        scratch.give_pair_sinks(sinks);
        assert_eq!(scratch.stats(), stats, "warm cycle must not change capacities");
    }

    #[test]
    fn u32_pool_round_trips() {
        let mut scratch = MatchScratch::new();
        let mut bufs = scratch.take_u32_bufs(2);
        bufs[0].extend(0..50);
        bufs[1].extend(0..10);
        scratch.give_u32_bufs(bufs);
        let one = scratch.take_u32();
        assert!(one.is_empty() && one.capacity() > 0);
        scratch.give_u32(one);
        assert_eq!(scratch.stats().pooled_u32_bufs, 2);
    }

    #[test]
    fn dispenser_hands_each_slot_once_and_recovers_leftovers() {
        let disp = SinkDispenser::new(vec![VecSink::default(), VecSink::default(), VecSink::default()]);
        let _a = disp.take(0);
        let _b = disp.take(2);
        let rest: Vec<VecSink> = disp.into_remaining().collect();
        assert_eq!(rest.len(), 1);
    }

    // "take" matches both the release backstop ("cell 0 taken twice")
    // and the race-check diagnostic ("double take at ...").
    #[test]
    #[should_panic(expected = "take")]
    fn dispenser_rejects_double_take() {
        let disp = SinkDispenser::new(vec![VecSink::default()]);
        let _a = disp.take(0);
        let _b = disp.take(0);
    }
}

//! Match reporting (the paper's `Report(s, u)` callback).
//!
//! Every matcher reports each intersecting (subscription, update) pair
//! exactly once through a [`MatchSink`]. Benches count (like the
//! paper's evaluation, which counts intersections without storing
//! them); tests collect and compare pair sets; the coordinator routes
//! notifications. Parallel matchers use one sink per worker and merge
//! afterwards, keeping the hot loop lock-free.

use super::region::RegionsNd;
use super::RegionIdx;

/// Receiver for reported (subscription, update) intersections.
pub trait MatchSink: Send {
    fn report(&mut self, s: RegionIdx, u: RegionIdx);
}

/// Mutable references forward, so adapters like [`FilterSink`] can
/// wrap either an owned sink or a caller's `&mut dyn MatchSink`.
impl<T: MatchSink + ?Sized> MatchSink for &mut T {
    #[inline]
    fn report(&mut self, s: RegionIdx, u: RegionIdx) {
        (**self).report(s, u);
    }
}

/// The native N-D pipeline's verification stage (see
/// [`crate::core::ddim`]): wraps an inner sink and forwards a reported
/// pair only if the **residual** dimensions — every dimension except
/// the swept one — also intersect, checked inline with the paper's
/// Intersect-1D on the SoA arrays. No per-dimension pair set is ever
/// materialized; a pair that fails any residual dimension costs a few
/// float compares and is dropped on the spot.
///
/// Parallel matchers construct one `FilterSink` per worker (wrapping
/// the worker's private sink), so verification runs inside the
/// parallel sweep; serial callers wrap the caller's sink directly.
pub struct FilterSink<'a, S: MatchSink> {
    subs: &'a RegionsNd,
    upds: &'a RegionsNd,
    /// The swept dimension (already matched; skipped here).
    sweep: usize,
    /// Pairs residual-checked so far (passed or dropped) — the `items`
    /// count of the [`Residual`](crate::obs::Phase::Residual) span.
    checked: u64,
    inner: S,
}

impl<'a, S: MatchSink> FilterSink<'a, S> {
    pub fn new(subs: &'a RegionsNd, upds: &'a RegionsNd, sweep: usize, inner: S) -> Self {
        debug_assert_eq!(subs.d(), upds.d(), "dimension mismatch");
        debug_assert!(sweep < subs.d(), "sweep dimension out of range");
        Self {
            subs,
            upds,
            sweep,
            checked: 0,
            inner,
        }
    }

    /// Candidate pairs residual-verified so far.
    pub fn checked(&self) -> u64 {
        self.checked
    }

    /// Unwrap the inner sink (per-worker collection fan-in).
    pub fn into_inner(self) -> S {
        self.inner
    }

    /// Unwrap, also yielding the residual-check count.
    pub fn into_parts(self) -> (S, u64) {
        (self.inner, self.checked)
    }
}

impl<S: MatchSink> MatchSink for FilterSink<'_, S> {
    #[inline]
    fn report(&mut self, s: RegionIdx, u: RegionIdx) {
        self.checked += 1;
        if self
            .subs
            .rects_intersect_except(s as usize, self.upds, u as usize, self.sweep)
        {
            self.inner.report(s, u);
        }
    }
}

/// Counts intersections (the paper's evaluation sink).
#[derive(Debug, Default, Clone)]
pub struct CountSink {
    pub count: u64,
}

impl MatchSink for CountSink {
    #[inline]
    fn report(&mut self, _s: RegionIdx, _u: RegionIdx) {
        self.count += 1;
    }
}

/// Collects pairs (test/routing sink).
#[derive(Debug, Default, Clone)]
pub struct VecSink {
    pub pairs: Vec<(RegionIdx, RegionIdx)>,
}

impl MatchSink for VecSink {
    #[inline]
    fn report(&mut self, s: RegionIdx, u: RegionIdx) {
        self.pairs.push((s, u));
    }
}

/// Closure adapter.
pub struct FnSink<F: FnMut(RegionIdx, RegionIdx) + Send>(pub F);

impl<F: FnMut(RegionIdx, RegionIdx) + Send> MatchSink for FnSink<F> {
    #[inline]
    fn report(&mut self, s: RegionIdx, u: RegionIdx) {
        (self.0)(s, u);
    }
}

/// A sorted, deduplicated pair list — canonical form for comparisons.
pub type PairVec = Vec<(RegionIdx, RegionIdx)>;

/// Pack a (subscription, update) pair into one `u64` key, subscription
/// in the high half — the canonical pair-set element shared by the N-D
/// reduction ([`crate::core::ddim`]) and the session diff store
/// ([`crate::session`]). Packed keys sort in the same order as the
/// `(s, u)` tuples.
#[inline]
pub fn pack_pair(s: RegionIdx, u: RegionIdx) -> u64 {
    (s as u64) << 32 | u as u64
}

/// Inverse of [`pack_pair`].
#[inline]
pub fn unpack_pair(p: u64) -> (RegionIdx, RegionIdx) {
    ((p >> 32) as u32, p as u32)
}

/// Merge per-worker VecSinks into canonical form.
pub fn canonical_pairs(sinks: Vec<VecSink>) -> PairVec {
    let mut all: PairVec = sinks.into_iter().flat_map(|s| s.pairs).collect();
    all.sort_unstable();
    all
}

/// Canonicalize a single pair list (sort; callers assert no dups).
pub fn canonicalize(mut pairs: PairVec) -> PairVec {
    pairs.sort_unstable();
    pairs
}

/// Assert that a canonical pair list contains no duplicates — the
/// paper's "each pair reported exactly once" contract.
pub fn assert_exactly_once(pairs: &PairVec) -> Result<(), String> {
    for w in pairs.windows(2) {
        if w[0] == w[1] {
            return Err(format!("pair {:?} reported more than once", w[0]));
        }
    }
    Ok(())
}

/// Total count across per-worker CountSinks.
pub fn total_count(sinks: &[CountSink]) -> u64 {
    sinks.iter().map(|s| s.count).sum()
}

/// Replay per-worker VecSinks into one downstream sink (how the
/// parallel matchers adapt their per-worker collection to the
/// object-safe `&mut dyn MatchSink` engine API).
pub fn replay(sinks: Vec<VecSink>, sink: &mut dyn MatchSink) {
    for s in sinks {
        for (a, b) in s.pairs {
            sink.report(a, b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_sink_counts() {
        let mut s = CountSink::default();
        s.report(1, 2);
        s.report(3, 4);
        assert_eq!(s.count, 2);
    }

    #[test]
    fn canonical_merge() {
        let a = VecSink {
            pairs: vec![(2, 1), (0, 0)],
        };
        let b = VecSink {
            pairs: vec![(1, 5)],
        };
        assert_eq!(canonical_pairs(vec![a, b]), vec![(0, 0), (1, 5), (2, 1)]);
    }

    #[test]
    fn exactly_once_detects_duplicates() {
        let ok = vec![(0, 1), (0, 2)];
        assert!(assert_exactly_once(&ok).is_ok());
        let bad = vec![(0, 1), (0, 1)];
        assert!(assert_exactly_once(&bad).is_err());
    }

    #[test]
    fn pack_pair_roundtrips_and_orders() {
        for &(s, u) in &[(0u32, 0u32), (1, 2), (u32::MAX, 7), (3, u32::MAX)] {
            assert_eq!(unpack_pair(pack_pair(s, u)), (s, u));
        }
        // Packed order == tuple order.
        assert!(pack_pair(1, 9) < pack_pair(2, 0));
        assert!(pack_pair(2, 0) < pack_pair(2, 1));
    }

    #[test]
    fn filter_sink_verifies_residual_dimensions() {
        use crate::core::interval::Interval;
        use crate::core::region::RegionsNd;

        let mut subs = RegionsNd::new(3);
        subs.push(&[
            Interval::new(0.0, 10.0),
            Interval::new(0.0, 2.0),
            Interval::new(5.0, 6.0),
        ]);
        let mut upds = RegionsNd::new(3);
        // Intersects in every dimension.
        upds.push(&[
            Interval::new(1.0, 2.0),
            Interval::new(1.0, 3.0),
            Interval::new(5.5, 7.0),
        ]);
        // Fails residual dim 1 (touching is not intersecting).
        upds.push(&[
            Interval::new(1.0, 2.0),
            Interval::new(2.0, 3.0),
            Interval::new(5.5, 7.0),
        ]);
        // Fails residual dim 2.
        upds.push(&[
            Interval::new(1.0, 2.0),
            Interval::new(1.0, 3.0),
            Interval::new(9.0, 11.0),
        ]);
        let mut out = VecSink::default();
        {
            // Sweep dim 0: the filter checks dims 1 and 2 only.
            let mut f = FilterSink::new(&subs, &upds, 0, &mut out as &mut dyn MatchSink);
            f.report(0, 0);
            f.report(0, 1);
            f.report(0, 2);
        }
        assert_eq!(out.pairs, vec![(0, 0)]);
        // Sweeping dim 1 instead: dim 1 is NOT checked, dim 0/2 are.
        let mut f = FilterSink::new(&subs, &upds, 1, VecSink::default());
        f.report(0, 1);
        f.report(0, 2);
        assert_eq!(f.into_inner().pairs, vec![(0, 1)]);
    }

    #[test]
    fn fn_sink_invokes_closure() {
        let mut hits = Vec::new();
        {
            let mut s = FnSink(|a, b| hits.push((a, b)));
            s.report(7, 9);
        }
        assert_eq!(hits, vec![(7, 9)]);
    }
}

//! Match reporting (the paper's `Report(s, u)` callback).
//!
//! Every matcher reports each intersecting (subscription, update) pair
//! exactly once through a [`MatchSink`]. Benches count (like the
//! paper's evaluation, which counts intersections without storing
//! them); tests collect and compare pair sets; the coordinator routes
//! notifications. Parallel matchers use one sink per worker and merge
//! afterwards, keeping the hot loop lock-free.

use super::RegionIdx;

/// Receiver for reported (subscription, update) intersections.
pub trait MatchSink: Send {
    fn report(&mut self, s: RegionIdx, u: RegionIdx);
}

/// Counts intersections (the paper's evaluation sink).
#[derive(Debug, Default, Clone)]
pub struct CountSink {
    pub count: u64,
}

impl MatchSink for CountSink {
    #[inline]
    fn report(&mut self, _s: RegionIdx, _u: RegionIdx) {
        self.count += 1;
    }
}

/// Collects pairs (test/routing sink).
#[derive(Debug, Default, Clone)]
pub struct VecSink {
    pub pairs: Vec<(RegionIdx, RegionIdx)>,
}

impl MatchSink for VecSink {
    #[inline]
    fn report(&mut self, s: RegionIdx, u: RegionIdx) {
        self.pairs.push((s, u));
    }
}

/// Closure adapter.
pub struct FnSink<F: FnMut(RegionIdx, RegionIdx) + Send>(pub F);

impl<F: FnMut(RegionIdx, RegionIdx) + Send> MatchSink for FnSink<F> {
    #[inline]
    fn report(&mut self, s: RegionIdx, u: RegionIdx) {
        (self.0)(s, u);
    }
}

/// A sorted, deduplicated pair list — canonical form for comparisons.
pub type PairVec = Vec<(RegionIdx, RegionIdx)>;

/// Pack a (subscription, update) pair into one `u64` key, subscription
/// in the high half — the canonical pair-set element shared by the N-D
/// reduction ([`crate::core::ddim`]) and the session diff store
/// ([`crate::session`]). Packed keys sort in the same order as the
/// `(s, u)` tuples.
#[inline]
pub fn pack_pair(s: RegionIdx, u: RegionIdx) -> u64 {
    (s as u64) << 32 | u as u64
}

/// Inverse of [`pack_pair`].
#[inline]
pub fn unpack_pair(p: u64) -> (RegionIdx, RegionIdx) {
    ((p >> 32) as u32, p as u32)
}

/// Merge per-worker VecSinks into canonical form.
pub fn canonical_pairs(sinks: Vec<VecSink>) -> PairVec {
    let mut all: PairVec = sinks.into_iter().flat_map(|s| s.pairs).collect();
    all.sort_unstable();
    all
}

/// Canonicalize a single pair list (sort; callers assert no dups).
pub fn canonicalize(mut pairs: PairVec) -> PairVec {
    pairs.sort_unstable();
    pairs
}

/// Assert that a canonical pair list contains no duplicates — the
/// paper's "each pair reported exactly once" contract.
pub fn assert_exactly_once(pairs: &PairVec) -> Result<(), String> {
    for w in pairs.windows(2) {
        if w[0] == w[1] {
            return Err(format!("pair {:?} reported more than once", w[0]));
        }
    }
    Ok(())
}

/// Total count across per-worker CountSinks.
pub fn total_count(sinks: &[CountSink]) -> u64 {
    sinks.iter().map(|s| s.count).sum()
}

/// Replay per-worker VecSinks into one downstream sink (how the
/// parallel matchers adapt their per-worker collection to the
/// object-safe `&mut dyn MatchSink` engine API).
pub fn replay(sinks: Vec<VecSink>, sink: &mut dyn MatchSink) {
    for s in sinks {
        for (a, b) in s.pairs {
            sink.report(a, b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_sink_counts() {
        let mut s = CountSink::default();
        s.report(1, 2);
        s.report(3, 4);
        assert_eq!(s.count, 2);
    }

    #[test]
    fn canonical_merge() {
        let a = VecSink {
            pairs: vec![(2, 1), (0, 0)],
        };
        let b = VecSink {
            pairs: vec![(1, 5)],
        };
        assert_eq!(canonical_pairs(vec![a, b]), vec![(0, 0), (1, 5), (2, 1)]);
    }

    #[test]
    fn exactly_once_detects_duplicates() {
        let ok = vec![(0, 1), (0, 2)];
        assert!(assert_exactly_once(&ok).is_ok());
        let bad = vec![(0, 1), (0, 1)];
        assert!(assert_exactly_once(&bad).is_err());
    }

    #[test]
    fn pack_pair_roundtrips_and_orders() {
        for &(s, u) in &[(0u32, 0u32), (1, 2), (u32::MAX, 7), (3, u32::MAX)] {
            assert_eq!(unpack_pair(pack_pair(s, u)), (s, u));
        }
        // Packed order == tuple order.
        assert!(pack_pair(1, 9) < pack_pair(2, 0));
        assert!(pack_pair(2, 0) < pack_pair(2, 1));
    }

    #[test]
    fn fn_sink_invokes_closure() {
        let mut hits = Vec::new();
        {
            let mut s = FnSink(|a, b| hits.push((a, b)));
            s.report(7, 9);
        }
        assert_eq!(hits, vec![(7, 9)]);
    }
}

//! CRC32 (IEEE 802.3, reflected, polynomial `0xEDB88320`) — the
//! per-record checksum of the write-ahead log and the whole-payload
//! checksum of epoch-snapshot files.
//!
//! Implemented in-tree (the crate is dependency-free by design) as the
//! classic byte-at-a-time table walk; the 1 KiB table is built in a
//! `const fn` so it lives in rodata. Throughput is irrelevant here —
//! WAL records are small and snapshot files are written once per
//! checkpoint — but the exact polynomial matters: it is the same CRC32
//! `gzip`/`zlib`/Ethernet use, so `crc32(b"123456789") ==
//! 0xCBF4_3926` is checkable against any external tool.

/// Reflected CRC32 lookup table for polynomial `0xEDB88320`.
const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Incremental CRC32 state (for callers hashing in pieces, e.g. the
/// pair-set fingerprint folding one `u64` at a time).
#[derive(Debug, Clone, Copy)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// Fresh state (equivalent to having hashed zero bytes).
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = TABLE[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// Finish: the CRC32 of everything updated so far.
    pub fn finish(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The check value every CRC32 catalogue lists for this
        // polynomial/reflection/init combination.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_equals_one_shot() {
        let data = b"incremental hashing must match one-shot hashing";
        for split in [0, 1, 7, data.len() / 2, data.len()] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), crc32(data), "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_change_the_checksum() {
        let base = b"some wal record payload".to_vec();
        let want = crc32(&base);
        for byte in 0..base.len() {
            for bit in 0..8 {
                let mut flipped = base.clone();
                flipped[byte] ^= 1 << bit;
                assert_ne!(crc32(&flipped), want, "flip at {byte}:{bit} undetected");
            }
        }
    }
}

//! Fault injection for the durability layer: a [`WalSink`] wrapper
//! that kills the log at a chosen point — truncating, tearing, or
//! erroring the Nth write — so the recovery suite can prove that
//! *every* crash point recovers to a committed-epoch prefix.
//!
//! Gated on `cfg(any(test, feature = "failpoints"))`: production
//! builds never link it, the unit/property suites always can.
//!
//! The plan vocabulary mirrors how real storage fails:
//!
//! * [`FailPlan::TruncateAt`] — the process dies before write N hits
//!   the file at all (power loss with an empty page cache).
//! * [`FailPlan::TearAt`] — write N lands partially (a sector-straddling
//!   append torn mid-record).
//! * [`FailPlan::ErrorAt`] — write N fails with an IO error but the
//!   process lives (ENOSPC, EIO): the log must degrade, not panic.
//! * [`FailPlan::FlipBit`] — a byte in an otherwise-complete write is
//!   corrupted (bit rot; caught later by the per-record CRC).

use std::io;
use std::sync::{Arc, Mutex};

use super::wal::WalSink;

/// What to do to the Nth write (0-based) through a [`FaultSink`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailPlan {
    /// Drop write N and every later write/sync entirely.
    TruncateAt { nth: usize },
    /// Write only `keep` bytes of write N, then drop everything later.
    TearAt { nth: usize, keep: usize },
    /// Fail write N with an IO error (later writes proceed — the WAL
    /// is expected to have degraded and stopped calling us).
    ErrorAt { nth: usize },
    /// XOR one byte of write N with `mask`, then keep going normally.
    FlipBit { nth: usize, byte: usize, mask: u8 },
}

/// Shared observation handle: how many writes/syncs the sink saw and
/// whether the plan fired.
#[derive(Debug, Default)]
pub struct FaultLog {
    inner: Mutex<FaultLogInner>,
}

#[derive(Debug, Default, Clone, Copy)]
struct FaultLogInner {
    writes: usize,
    syncs: usize,
    fired: bool,
}

impl FaultLog {
    /// Writes attempted through the sink so far.
    pub fn writes(&self) -> usize {
        match self.inner.lock() {
            Ok(g) => g.writes,
            Err(_) => 0,
        }
    }

    /// Syncs attempted through the sink so far.
    pub fn syncs(&self) -> usize {
        match self.inner.lock() {
            Ok(g) => g.syncs,
            Err(_) => 0,
        }
    }

    /// Whether the failure plan has triggered.
    pub fn fired(&self) -> bool {
        match self.inner.lock() {
            Ok(g) => g.fired,
            Err(_) => false,
        }
    }
}

/// A [`WalSink`] that forwards to an inner sink until its [`FailPlan`]
/// triggers.
pub struct FaultSink<S: WalSink> {
    inner: S,
    plan: FailPlan,
    log: Arc<FaultLog>,
    /// After a truncate/tear fired, all subsequent IO is swallowed
    /// (the "process" is dead as far as the file is concerned).
    dead: bool,
}

impl<S: WalSink> FaultSink<S> {
    /// Wrap `inner`, applying `plan`; returns the sink and its
    /// observation handle.
    pub fn new(inner: S, plan: FailPlan) -> (Self, Arc<FaultLog>) {
        let log = Arc::new(FaultLog::default());
        (
            Self {
                inner,
                plan,
                log: Arc::clone(&log),
                dead: false,
            },
            log,
        )
    }
}

impl<S: WalSink> WalSink for FaultSink<S> {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        let nth = {
            let Ok(mut g) = self.log.inner.lock() else {
                return Err(io::Error::other("fault log poisoned"));
            };
            let nth = g.writes;
            g.writes += 1;
            nth
        };
        if self.dead {
            return Ok(());
        }
        let fire = |log: &FaultLog| {
            if let Ok(mut g) = log.inner.lock() {
                g.fired = true;
            }
        };
        match self.plan {
            FailPlan::TruncateAt { nth: n } if nth >= n => {
                fire(&self.log);
                self.dead = true;
                Ok(())
            }
            FailPlan::TearAt { nth: n, keep } if nth == n => {
                fire(&self.log);
                self.dead = true;
                self.inner.write_all(&buf[..keep.min(buf.len())])
            }
            FailPlan::ErrorAt { nth: n } if nth == n => {
                fire(&self.log);
                Err(io::Error::other("injected wal write failure"))
            }
            FailPlan::FlipBit { nth: n, byte, mask } if nth == n => {
                fire(&self.log);
                let mut corrupted = buf.to_vec();
                if let Some(b) = corrupted.get_mut(byte.min(buf.len().saturating_sub(1))) {
                    *b ^= mask;
                }
                self.inner.write_all(&corrupted)
            }
            _ => self.inner.write_all(buf),
        }
    }

    fn sync(&mut self) -> io::Result<()> {
        if let Ok(mut g) = self.log.inner.lock() {
            g.syncs += 1;
        }
        if self.dead {
            return Ok(());
        }
        self.inner.sync()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// In-memory sink so the unit tests need no filesystem.
    #[derive(Default)]
    pub(crate) struct MemSink {
        pub data: Arc<Mutex<Vec<u8>>>,
    }

    impl WalSink for MemSink {
        fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
            if let Ok(mut g) = self.data.lock() {
                g.extend_from_slice(buf);
            }
            Ok(())
        }
        fn sync(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn truncate_drops_everything_from_nth_write() {
        let mem = MemSink::default();
        let data = Arc::clone(&mem.data);
        let (mut sink, log) = FaultSink::new(mem, FailPlan::TruncateAt { nth: 1 });
        sink.write_all(b"aaaa").expect("write 0");
        sink.write_all(b"bbbb").expect("write 1 swallowed");
        sink.write_all(b"cccc").expect("write 2 swallowed");
        assert_eq!(data.lock().expect("lock").as_slice(), b"aaaa");
        assert!(log.fired());
        assert_eq!(log.writes(), 3);
    }

    #[test]
    fn tear_keeps_a_prefix_of_the_nth_write() {
        let mem = MemSink::default();
        let data = Arc::clone(&mem.data);
        let (mut sink, log) = FaultSink::new(mem, FailPlan::TearAt { nth: 1, keep: 2 });
        sink.write_all(b"aaaa").expect("write 0");
        sink.write_all(b"bbbb").expect("write 1 torn");
        sink.write_all(b"cccc").expect("write 2 swallowed");
        assert_eq!(data.lock().expect("lock").as_slice(), b"aaaabb");
        assert!(log.fired());
    }

    #[test]
    fn error_fails_exactly_the_nth_write() {
        let mem = MemSink::default();
        let (mut sink, log) = FaultSink::new(mem, FailPlan::ErrorAt { nth: 1 });
        sink.write_all(b"aaaa").expect("write 0");
        assert!(sink.write_all(b"bbbb").is_err());
        assert!(log.fired());
        // The WAL degrades after an error; if someone keeps writing
        // anyway the sink behaves normally again.
        sink.write_all(b"cccc").expect("write 2");
    }

    #[test]
    fn flip_bit_corrupts_in_flight_bytes() {
        let mem = MemSink::default();
        let data = Arc::clone(&mem.data);
        let (mut sink, _log) = FaultSink::new(mem, FailPlan::FlipBit { nth: 0, byte: 1, mask: 0x40 });
        sink.write_all(b"aaaa").expect("write 0");
        sink.write_all(b"bbbb").expect("write 1");
        assert_eq!(data.lock().expect("lock").as_slice(), b"a!aabbbb");
    }
}

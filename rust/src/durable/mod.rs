//! Crash-consistent durability: write-ahead op log, epoch-snapshot
//! files, and recovery back to the exact last durable epoch.
//!
//! The paper's DDM service is in-memory; this module is what makes the
//! session layer survive a `kill -9`. The design is the classic
//! WAL + checkpoint pair, specialised to the session's epoch model:
//!
//! * [`wal`] — a length-prefixed record log reusing the
//!   [`net::wire`](crate::net::wire) codecs: every staged op becomes an
//!   *op record*, every `commit()` closes with a *commit marker*
//!   carrying the epoch and a CRC32 **fingerprint** of the post-commit
//!   packed pair set. Each record carries its own CRC32, so torn,
//!   truncated, or bit-flipped tails are detected and discarded back to
//!   the last valid marker — never replayed as a partial epoch.
//! * [`snapfile`] — a compact checkpoint file: the serialized
//!   [`EpochSnapshot`](crate::session::EpochSnapshot) packed pair
//!   array plus the live region tables (key → rectangle, both sides).
//!   Written atomically (tmp + rename) every
//!   [`snapshot_every`](crate::engine::EngineBuilder::durability_snapshot_every)
//!   commits, after which the log is truncated.
//! * [`recover`] — scan the directory, decode snapshot + committed log
//!   tail, rebuild a live session by replaying the batches, and force
//!   the epoch counter to the last durable epoch. The rebuilt pair set
//!   is verified against the stored fingerprint before the session is
//!   handed back.
//! * [`faultfs`] — a failpoint [`WalSink`](wal::WalSink) that can
//!   truncate, tear, or error the Nth write (test/`failpoints`-gated),
//!   driving the recovery property suite.
//!
//! Wiring: `DdmEngine::builder().durability(dir)` attaches a WAL to
//! every session the engine creates;
//! [`DdmEngine::recover_session`](crate::engine::DdmEngine::recover_session)
//! / [`recover_any_session`](crate::engine::DdmEngine::recover_any_session)
//! resume one. On the CLI: `ddm serve --wal DIR [--resume]`, `ddm
//! replay --record DIR` / `--resume DIR`, and `ddm wal-info --dir DIR`
//! for offline inspection. Commit-path WAL work is traced as the
//! [`WalAppend`](crate::obs::Phase::WalAppend) /
//! [`WalFsync`](crate::obs::Phase::WalFsync) phases; recovery records
//! [`RecoverScan`](crate::obs::Phase::RecoverScan).
//!
//! ## Failure policy
//!
//! Commits never fail because a disk does: a WAL write error flips the
//! log into a *degraded* state (the error is kept, counted in
//! [`WalStats::errors`](wal::WalStats), and surfaced through
//! `wal_stats()` / the `wal_errors` gauge) while the in-memory session
//! keeps serving. Recovery, by contrast, is strict: a corrupt
//! *snapshot* file is a hard error, and a rebuilt state whose
//! fingerprint disagrees with the last durable marker refuses to come
//! up rather than serve silently wrong matches.

pub mod crc;
#[cfg(any(test, feature = "failpoints"))]
pub mod faultfs;
pub mod recover;
pub mod snapfile;
pub mod wal;

pub use crc::{crc32, Crc32};
pub use recover::{DurableState, RecoverReport};
pub use snapfile::SnapshotFile;
pub use wal::{CommittedBatch, SessionWal, Wal, WalOptions, WalScan, WalStats};

use std::path::PathBuf;

/// Engine-level durability configuration
/// ([`EngineBuilder::durability`](crate::engine::EngineBuilder::durability)
/// and friends). One directory holds one session's history: the op log
/// ([`wal::LOG_FILE`]) and the latest checkpoint
/// ([`snapfile::SNAP_FILE`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DurabilityCfg {
    /// Directory holding the log + snapshot files (created on demand).
    pub dir: PathBuf,
    /// `fsync` the log after every commit marker (crash-through-power
    /// durability) instead of trusting the OS page cache.
    pub fsync_commits: bool,
    /// Checkpoint (snapshot file + log truncation) every this many
    /// commits; `u64::MAX` disables periodic checkpoints.
    pub snapshot_every: u64,
}

impl DurabilityCfg {
    /// Default knobs for `dir`: no per-commit fsync, checkpoint every
    /// 64 commits.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            fsync_commits: false,
            snapshot_every: 64,
        }
    }
}

/// The pair-set fingerprint commit markers and snapshot files carry:
/// CRC32 over the ascending packed pair array's little-endian bytes.
/// Two session states fingerprint equal iff their retained pair sets
/// are identical (up to CRC collision), which is what `--resume`
/// verification and the recovery suite key on.
pub fn fingerprint_packed(packed: &[u64]) -> u32 {
    let mut c = Crc32::new();
    for &p in packed {
        c.update(&p.to_le_bytes());
    }
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_order_and_content_sensitive() {
        assert_eq!(fingerprint_packed(&[]), 0);
        let a = fingerprint_packed(&[1, 2, 3]);
        let b = fingerprint_packed(&[1, 2, 4]);
        let c = fingerprint_packed(&[1, 3, 2]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, fingerprint_packed(&[1, 2, 3]));
    }

    #[test]
    fn cfg_defaults() {
        let cfg = DurabilityCfg::new("/tmp/x");
        assert!(!cfg.fsync_commits);
        assert_eq!(cfg.snapshot_every, 64);
        assert_eq!(cfg.dir, PathBuf::from("/tmp/x"));
    }
}

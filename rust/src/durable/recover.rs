//! Recovery: scan a durability directory, keep exactly the committed
//! epoch history, and replay it into a fresh session.
//!
//! The recovery state machine:
//!
//! ```text
//!   scan_dir ──► decode snap.bin (strict: corrupt ⇒ hard error)
//!        │
//!        ├────► scan wal.log (tolerant: torn/flipped tail ⇒ discard
//!        │      back to the last valid commit marker)
//!        │
//!        └────► drop batches ≤ snapshot epoch (the crash window
//!               between checkpoint rename and log truncation), then
//!               require the rest to be epoch-contiguous
//!
//!   replay_into ──► stage snapshot regions, commit, pin the epoch
//!        │          counter to the checkpoint epoch
//!        │
//!        ├──────► per batch: stage ops in log order, commit, check
//!        │        the rebuilt pair-set fingerprint + count against
//!        │        the batch's marker (mismatch ⇒ refuse to come up)
//!        │
//!        └──────► final state: exact last durable epoch, traced as
//!                 one `recover_scan` span
//! ```
//!
//! Replay re-runs the real matcher over the logged ops, so a recovered
//! session is not a deserialized facsimile but the same state the
//! original session computed — which is exactly what the per-epoch
//! fingerprint check proves.

use std::path::Path;

use crate::net::proto::RegionOp;
use crate::obs::Phase;
use crate::shard::AnySession;

use super::snapfile::{self, SnapshotFile};
use super::wal::{self, CommittedBatch};
use super::fingerprint_packed;

/// Everything durable a directory held: the decoded checkpoint plus
/// the committed log tail, already filtered down to the batches replay
/// must apply.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DurableState {
    /// The checkpoint, if one was installed.
    pub snapshot: Option<SnapshotFile>,
    /// Committed batches past the checkpoint, epoch-contiguous.
    pub batches: Vec<CommittedBatch>,
    /// The last durable epoch (0: empty history).
    pub last_epoch: u64,
    /// Pair count at `last_epoch`, per the last marker / checkpoint.
    pub last_n_pairs: u64,
    /// Pair-set fingerprint at `last_epoch`.
    pub last_fingerprint: u32,
    /// Log bytes past the durable prefix that the scan discarded.
    pub tail_bytes: usize,
    /// Op records after the last marker (a batch that never committed).
    pub open_ops: usize,
    /// Structurally valid log records scanned.
    pub log_records: u64,
    /// Total log file size scanned.
    pub log_bytes: u64,
}

/// What a completed recovery did — surfaced by
/// [`DdmEngine::recover_session`](crate::engine::DdmEngine::recover_session)
/// and printed by `ddm serve --resume` / `ddm wal-info`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoverReport {
    /// Epoch the session came back at.
    pub epoch: u64,
    /// Regions restored from the checkpoint.
    pub snapshot_regions: usize,
    /// Committed batches replayed from the log tail.
    pub batches: usize,
    /// Ops replayed from those batches.
    pub ops: usize,
    /// Pairs in the recovered match set.
    pub n_pairs: usize,
    /// Fingerprint of the recovered pair set.
    pub fingerprint: u32,
    /// Discarded log tail bytes (0 for a clean shutdown).
    pub tail_bytes: usize,
    /// Discarded uncommitted trailing ops.
    pub open_ops: usize,
}

impl DurableState {
    /// The live region tables at `last_epoch`: checkpoint regions with
    /// the committed log tail applied last-writer-wins on top. This is
    /// what a freshly recovered session re-seeds its WAL shadow tables
    /// from, so the next checkpoint serializes exactly this state.
    pub fn final_regions(
        &self,
    ) -> (
        std::collections::HashMap<u32, Vec<crate::core::interval::Interval>>,
        std::collections::HashMap<u32, Vec<crate::core::interval::Interval>>,
    ) {
        let mut subs = std::collections::HashMap::new();
        let mut upds = std::collections::HashMap::new();
        if let Some(snap) = &self.snapshot {
            for (key, rect) in &snap.subs {
                subs.insert(*key, rect.clone());
            }
            for (key, rect) in &snap.upds {
                upds.insert(*key, rect.clone());
            }
        }
        for batch in &self.batches {
            for op in &batch.ops {
                match op {
                    RegionOp::UpsertSub { key, rect } => {
                        subs.insert(*key, rect.clone());
                    }
                    RegionOp::UpsertUpd { key, rect } => {
                        upds.insert(*key, rect.clone());
                    }
                    RegionOp::RemoveSub { key } => {
                        subs.remove(key);
                    }
                    RegionOp::RemoveUpd { key } => {
                        upds.remove(key);
                    }
                }
            }
        }
        (subs, upds)
    }
}

/// Read and validate a durability directory without touching any
/// session: strict on the snapshot, tolerant on the log tail, strict
/// on epoch continuity between the two.
pub fn scan_dir(dir: &Path) -> crate::Result<DurableState> {
    let log_path = dir.join(wal::LOG_FILE);
    let snap_path = dir.join(snapfile::SNAP_FILE);
    if !log_path.exists() && !snap_path.exists() {
        crate::bail!(
            "nothing to recover in {dir:?}: no {} or {}",
            wal::LOG_FILE,
            snapfile::SNAP_FILE
        );
    }
    let mut st = DurableState::default();
    if snap_path.exists() {
        let bytes = std::fs::read(&snap_path)
            .map_err(|e| crate::error::Error::msg(format!("read {snap_path:?}: {e}")))?;
        st.snapshot = Some(SnapshotFile::decode(&bytes)?);
    }
    let base = st.snapshot.as_ref().map_or(0, |s| s.epoch);
    if log_path.exists() {
        let bytes = std::fs::read(&log_path)
            .map_err(|e| crate::error::Error::msg(format!("read {log_path:?}: {e}")))?;
        let scan = wal::scan_log(&bytes);
        st.log_records = scan.records;
        st.log_bytes = bytes.len().try_into().unwrap_or(u64::MAX);
        st.tail_bytes = scan.tail_bytes;
        st.open_ops = scan.open_ops;
        let mut expect = base.saturating_add(1);
        for b in scan.batches {
            if b.epoch <= base {
                // The crash window between checkpoint rename and log
                // truncation: the old log still holds batches the
                // snapshot already covers.
                continue;
            }
            if b.epoch != expect {
                crate::bail!(
                    "log holds epoch {} where {expect} was expected — \
                     mixed or inconsistent durability history in {dir:?}",
                    b.epoch
                );
            }
            expect = expect.saturating_add(1);
            st.batches.push(b);
        }
    }
    if let Some(last) = st.batches.last() {
        st.last_epoch = last.epoch;
        st.last_n_pairs = last.n_pairs;
        st.last_fingerprint = last.fingerprint;
    } else if let Some(snap) = &st.snapshot {
        st.last_epoch = snap.epoch;
        st.last_n_pairs = snap.pairs.len().try_into().unwrap_or(u64::MAX);
        st.last_fingerprint = snap.fingerprint();
    }
    Ok(st)
}

/// Replay a scanned history into a fresh session (epoch 0, no WAL
/// attached), leaving it at the exact last durable epoch. Every commit
/// boundary is verified against its marker's fingerprint and pair
/// count; any disagreement aborts recovery with the session discarded.
pub fn replay_into(session: &mut AnySession, st: &DurableState) -> crate::Result<RecoverReport> {
    if session.epoch() != 0 {
        crate::bail!("recovery needs a fresh session, got one at epoch {}", session.epoch());
    }
    let t0 = session.trace_start();
    let mut report = RecoverReport {
        tail_bytes: st.tail_bytes,
        open_ops: st.open_ops,
        ..RecoverReport::default()
    };
    if let Some(snap) = &st.snapshot {
        if snap.d != session.d() {
            crate::bail!("snapshot is {}-d but the session is {}-d", snap.d, session.d());
        }
        for (key, rect) in &snap.subs {
            session.upsert_subscription(*key, rect);
        }
        for (key, rect) in &snap.upds {
            session.upsert_update(*key, rect);
        }
        report.snapshot_regions = snap.subs.len() + snap.upds.len();
        session.commit();
        session.force_epoch(snap.epoch);
        let got = fingerprint_packed(session.snapshot().packed_pairs());
        let want = snap.fingerprint();
        if got != want {
            crate::bail!(
                "checkpoint replay diverged at epoch {}: fingerprint {got:#010x} != stored {want:#010x}",
                snap.epoch
            );
        }
    }
    for batch in &st.batches {
        for op in &batch.ops {
            apply_op(session, op);
        }
        report.ops += batch.ops.len();
        report.batches += 1;
        let diff = session.commit();
        if diff.epoch != batch.epoch {
            crate::bail!("replay reached epoch {} where the log says {}", diff.epoch, batch.epoch);
        }
        let snap = session.snapshot();
        let got = fingerprint_packed(snap.packed_pairs());
        let got_n = u64::try_from(snap.n_pairs()).unwrap_or(u64::MAX);
        if got != batch.fingerprint || got_n != batch.n_pairs {
            crate::bail!(
                "replay diverged at epoch {}: {} pairs fingerprint {got:#010x}, \
                 marker says {} pairs fingerprint {:#010x}",
                batch.epoch,
                got_n,
                batch.n_pairs,
                batch.fingerprint
            );
        }
    }
    let snap = session.snapshot();
    report.epoch = snap.epoch();
    report.n_pairs = snap.n_pairs();
    report.fingerprint = fingerprint_packed(snap.packed_pairs());
    if report.epoch != st.last_epoch {
        crate::bail!("recovered epoch {} != last durable epoch {}", report.epoch, st.last_epoch);
    }
    let items = u64::try_from(report.ops + report.snapshot_regions).unwrap_or(u64::MAX);
    session.trace_span(Phase::RecoverScan, t0, items);
    Ok(report)
}

fn apply_op(session: &mut AnySession, op: &RegionOp) {
    match op {
        RegionOp::UpsertSub { key, rect } => session.upsert_subscription(*key, rect),
        RegionOp::UpsertUpd { key, rect } => session.upsert_update(*key, rect),
        RegionOp::RemoveSub { key } => session.remove_subscription(*key),
        RegionOp::RemoveUpd { key } => session.remove_update(*key),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::interval::Interval;
    use crate::engine::DdmEngine;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ddm-recover-{tag}-{}", std::process::id()))
    }

    #[test]
    fn scan_dir_on_missing_dir_is_an_error() {
        assert!(scan_dir(Path::new("/nonexistent/ddm-recover-test")).is_err());
    }

    #[test]
    fn empty_log_recovers_to_epoch_zero() {
        let dir = tmp("empty");
        std::fs::create_dir_all(&dir).expect("mkdir");
        std::fs::write(dir.join(wal::LOG_FILE), wal::WAL_MAGIC).expect("write log");
        let st = scan_dir(&dir).expect("scan");
        assert_eq!(st.last_epoch, 0);
        assert!(st.batches.is_empty());
        let engine = DdmEngine::builder().threads(1).build();
        let mut session = engine.any_session(1, Interval::new(0.0, 100.0));
        let report = replay_into(&mut session, &st).expect("replay");
        assert_eq!(report.epoch, 0);
        assert_eq!(session.epoch(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn epoch_gap_in_log_is_a_hard_error() {
        let dir = tmp("gap");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let mut log = wal::WAL_MAGIC.to_vec();
        wal::encode_commit_record(&mut log, 1, 0, 0);
        wal::encode_commit_record(&mut log, 3, 0, 0);
        std::fs::write(dir.join(wal::LOG_FILE), &log).expect("write log");
        let err = scan_dir(&dir).expect_err("gap must fail");
        assert!(err.to_string().contains("epoch 3"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batches_at_or_below_snapshot_epoch_are_skipped() {
        let dir = tmp("overlap");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let snap = SnapshotFile { epoch: 2, d: 1, ..SnapshotFile::default() };
        std::fs::write(dir.join(snapfile::SNAP_FILE), snap.encode()).expect("write snap");
        // Old log from before the (crash-interrupted) truncation:
        // epochs 1 and 2 are covered by the snapshot, 3 is new.
        let mut log = wal::WAL_MAGIC.to_vec();
        wal::encode_commit_record(&mut log, 1, 0, 0);
        wal::encode_commit_record(&mut log, 2, 0, 0);
        wal::encode_commit_record(&mut log, 3, 0, 0);
        std::fs::write(dir.join(wal::LOG_FILE), &log).expect("write log");
        let st = scan_dir(&dir).expect("scan");
        assert_eq!(st.batches.len(), 1);
        assert_eq!(st.batches[0].epoch, 3);
        assert_eq!(st.last_epoch, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_snapshot_is_a_hard_error_even_with_a_good_log() {
        let dir = tmp("badsnap");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let mut bytes = SnapshotFile { epoch: 1, d: 1, ..SnapshotFile::default() }.encode();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(dir.join(snapfile::SNAP_FILE), &bytes).expect("write snap");
        std::fs::write(dir.join(wal::LOG_FILE), wal::WAL_MAGIC).expect("write log");
        assert!(scan_dir(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_mismatch_refuses_to_come_up() {
        let dir = tmp("badfp");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let mut log = wal::WAL_MAGIC.to_vec();
        let op = crate::net::proto::RegionOp::UpsertSub {
            key: 1,
            rect: vec![Interval::new(0.0, 1.0)],
        };
        wal::encode_op_record(&mut log, &op);
        // Marker lies about the fingerprint.
        wal::encode_commit_record(&mut log, 1, 5, 0xBAD0_F00D);
        std::fs::write(dir.join(wal::LOG_FILE), &log).expect("write log");
        let st = scan_dir(&dir).expect("scan is tolerant; replay is not");
        let engine = DdmEngine::builder().threads(1).build();
        let mut session = engine.any_session(1, Interval::new(0.0, 100.0));
        let err = replay_into(&mut session, &st).expect_err("must refuse");
        assert!(err.to_string().contains("diverged"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! The epoch-snapshot (checkpoint) file: a compact, single-read image
//! of one session's durable state at a commit boundary.
//!
//! ## File format
//!
//! ```text
//! [magic: 8 bytes "DDMSNAP1"][payload][crc32(payload): u32 LE]
//! ```
//!
//! with `payload` encoded by the [`net::wire`](crate::net::wire)
//! primitives:
//!
//! | field | encoding |
//! |-------|----------|
//! | epoch | varint |
//! | d | varint (1..=`MAX_DIMS`) |
//! | subscriptions | varint count, then per region: varint key + rect (varint d + 2·d bit-exact f64) |
//! | updates | same |
//! | packed pairs | varint count, then one varint per packed `sub<<32|upd` key, ascending |
//!
//! The pair array is the [`EpochSnapshot`](crate::session::EpochSnapshot)
//! packed form verbatim; the region tables are what replay needs to
//! rebuild the trees. Unlike the tolerant WAL scan, decoding is
//! **strict**: any truncation, checksum mismatch, or malformed field is
//! a hard error — a checkpoint is written atomically (tmp + rename by
//! [`Wal::install_checkpoint`](super::wal::Wal::install_checkpoint)),
//! so a bad one means real corruption, and recovery must refuse to
//! come up rather than guess.

use crate::core::interval::Interval;
use crate::net::proto::{put_rect, read_rect};
use crate::net::wire::{self, Reader};

use super::crc::crc32;
use super::fingerprint_packed;

/// Snapshot file name inside a durability directory.
pub const SNAP_FILE: &str = "snap.bin";

/// Magic + version prefix of the snapshot file.
pub const SNAP_MAGIC: [u8; 8] = *b"DDMSNAP1";

/// Decoded checkpoint: everything needed to rebuild a session at
/// `epoch` before replaying the log tail.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SnapshotFile {
    /// Epoch the checkpoint was taken at.
    pub epoch: u64,
    /// Space dimensionality of every rectangle below.
    pub d: usize,
    /// Live subscription regions (key → rectangle), ascending by key.
    pub subs: Vec<(u32, Vec<Interval>)>,
    /// Live update regions, ascending by key.
    pub upds: Vec<(u32, Vec<Interval>)>,
    /// The packed matched-pair array (`sub<<32|upd`, ascending) — the
    /// `EpochSnapshot` payload verbatim.
    pub pairs: Vec<u64>,
}

impl SnapshotFile {
    /// CRC32 fingerprint of the pair set (what commit markers carry).
    pub fn fingerprint(&self) -> u32 {
        fingerprint_packed(&self.pairs)
    }

    /// Serialize to a complete file image (magic + payload + CRC).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::with_capacity(64 + 24 * (self.subs.len() + self.upds.len()));
        wire::put_varint(&mut payload, self.epoch);
        wire::put_varint(&mut payload, self.d as u64);
        put_regions(&mut payload, &self.subs);
        put_regions(&mut payload, &self.upds);
        wire::put_varint(&mut payload, self.pairs.len() as u64);
        for &p in &self.pairs {
            wire::put_varint(&mut payload, p);
        }
        let mut out = Vec::with_capacity(SNAP_MAGIC.len() + payload.len() + 4);
        out.extend_from_slice(&SNAP_MAGIC);
        out.extend_from_slice(&payload);
        wire::put_u32(&mut out, crc32(&payload));
        out
    }

    /// Strictly decode a file image. Every failure mode (short file,
    /// foreign magic, checksum mismatch, malformed or trailing bytes,
    /// rect dimensionality disagreeing with the header) is an error.
    pub fn decode(bytes: &[u8]) -> crate::Result<Self> {
        let magic_len = SNAP_MAGIC.len();
        if bytes.len() < magic_len + 4 {
            crate::bail!("snapshot file too short ({} bytes)", bytes.len());
        }
        if bytes[..magic_len] != SNAP_MAGIC {
            crate::bail!("snapshot file has foreign magic");
        }
        let crc_at = bytes.len() - 4;
        let payload = &bytes[magic_len..crc_at];
        let Ok(crc_bytes) = <[u8; 4]>::try_from(&bytes[crc_at..]) else {
            crate::bail!("snapshot checksum unreadable");
        };
        let want = u32::from_le_bytes(crc_bytes);
        let got = crc32(payload);
        if got != want {
            crate::bail!("snapshot checksum mismatch: stored {want:#010x}, computed {got:#010x}");
        }
        let mut r = Reader::new(payload);
        let epoch = r.varint().map_err(snap_err)?;
        let d_raw = r.varint().map_err(snap_err)?;
        let Ok(d) = usize::try_from(d_raw) else {
            crate::bail!("snapshot dimension {d_raw} out of range");
        };
        if d == 0 || d > crate::net::proto::MAX_DIMS {
            crate::bail!("snapshot dimension {d} out of range");
        }
        let subs = read_regions(&mut r, d)?;
        let upds = read_regions(&mut r, d)?;
        let n_pairs = r.count(1).map_err(snap_err)?;
        let mut pairs = Vec::with_capacity(n_pairs);
        let mut prev: Option<u64> = None;
        for _ in 0..n_pairs {
            let p = r.varint().map_err(snap_err)?;
            if prev.is_some_and(|q| q >= p) {
                crate::bail!("snapshot pair array not strictly ascending");
            }
            prev = Some(p);
            pairs.push(p);
        }
        r.finish().map_err(snap_err)?;
        Ok(Self { epoch, d, subs, upds, pairs })
    }
}

fn snap_err(e: crate::net::wire::WireError) -> crate::error::Error {
    crate::error::Error::msg(format!("snapshot payload malformed: {e}"))
}

fn put_regions(out: &mut Vec<u8>, regions: &[(u32, Vec<Interval>)]) {
    wire::put_varint(out, regions.len() as u64);
    for (key, rect) in regions {
        wire::put_varint(out, u64::from(*key));
        put_rect(out, rect);
    }
}

fn read_regions(r: &mut Reader<'_>, d: usize) -> crate::Result<Vec<(u32, Vec<Interval>)>> {
    // Each region is at least 1 byte of key + d * 16 bytes of rect.
    let n = r.count(1 + d * 16).map_err(snap_err)?;
    let mut regions = Vec::with_capacity(n);
    let mut prev: Option<u32> = None;
    for _ in 0..n {
        let key_raw = r.varint().map_err(snap_err)?;
        let Ok(key) = u32::try_from(key_raw) else {
            crate::bail!("snapshot region key {key_raw} exceeds u32");
        };
        if prev.is_some_and(|q| q >= key) {
            crate::bail!("snapshot region keys not strictly ascending");
        }
        prev = Some(key);
        let rect = read_rect(r).map_err(snap_err)?;
        if rect.len() != d {
            crate::bail!("snapshot rect is {}-d in a {d}-d file", rect.len());
        }
        regions.push((key, rect));
    }
    Ok(regions)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SnapshotFile {
        SnapshotFile {
            epoch: 42,
            d: 2,
            subs: vec![
                (1, vec![Interval::new(0.0, 1.0), Interval::new(2.0, 3.0)]),
                (7, vec![Interval::new(-1.5, 0.5), Interval::new(0.0, 0.25)]),
            ],
            upds: vec![(3, vec![Interval::new(0.5, 0.75), Interval::new(2.5, 2.75)])],
            pairs: vec![(1 << 32) | 3, (7 << 32) | 3],
        }
    }

    #[test]
    fn encode_decode_round_trips() {
        let snap = sample();
        let bytes = snap.encode();
        assert_eq!(SnapshotFile::decode(&bytes).expect("decode"), snap);
    }

    #[test]
    fn empty_snapshot_round_trips() {
        let snap = SnapshotFile { epoch: 0, d: 1, ..SnapshotFile::default() };
        assert_eq!(SnapshotFile::decode(&snap.encode()).expect("decode"), snap);
        assert_eq!(snap.fingerprint(), 0);
    }

    #[test]
    fn every_truncation_is_a_hard_error() {
        let bytes = sample().encode();
        for cut in 0..bytes.len() {
            assert!(
                SnapshotFile::decode(&bytes[..cut]).is_err(),
                "truncation to {cut} bytes decoded"
            );
        }
    }

    #[test]
    fn every_single_bit_flip_is_a_hard_error() {
        let bytes = sample().encode();
        for byte in 0..bytes.len() {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    SnapshotFile::decode(&bad).is_err(),
                    "flip at {byte}:{bit} decoded"
                );
            }
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let snap = sample();
        let mut bytes = snap.encode();
        // Valid payload + CRC, then garbage after: the CRC no longer
        // covers the right span, so this must fail.
        bytes.push(0);
        assert!(SnapshotFile::decode(&bytes).is_err());
    }

    #[test]
    fn fingerprint_matches_module_fingerprint() {
        let snap = sample();
        assert_eq!(snap.fingerprint(), fingerprint_packed(&snap.pairs));
    }
}

//! The write-ahead op log: length-prefixed, CRC-checked records the
//! session appends before a commit publishes its snapshot.
//!
//! ## Record format
//!
//! The log starts with the 8-byte magic [`WAL_MAGIC`]; every record is
//!
//! ```text
//! [len: u32 LE][payload: len bytes][crc32(payload): u32 LE]
//! ```
//!
//! with `payload = [kind: u8][body]` encoded with the
//! [`net::wire`](crate::net::wire) primitives:
//!
//! | kind | record | body |
//! |------|--------|------|
//! | [`REC_OP`] | one staged op | the [`RegionOp`] wire encoding (op tag, varint key, rect as varint d + 2·d bit-exact f64) |
//! | [`REC_COMMIT`] | commit marker | varint epoch, varint pair count, varint CRC32 pair-set fingerprint |
//!
//! A commit is durable iff its marker record is intact: recovery
//! ([`scan_log`]) walks records until the first length/CRC/decode
//! failure and discards everything after the last valid marker, so a
//! torn or bit-flipped tail can lose at most the epochs that never
//! finished writing — never produce a partial one.
//!
//! ## Write path
//!
//! Ops are encoded into an in-memory buffer at stage time (no
//! syscalls on the staging path); `commit()` flushes the buffer
//! (`wal_append` phase), publishes its snapshot, then appends the
//! marker and optionally fsyncs (`wal_fsync`). A buffer past
//! [`BUF_HIWAT`] flushes early so bulk loads don't accumulate
//! unbounded. IO errors degrade the log (sticky
//! [`WalStats::errors`] + [`last_error`](Wal::last_error)) instead of
//! failing the commit — see the [module docs](super) failure policy.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::core::interval::Interval;
use crate::net::proto::{put_op, read_op, RegionOp};
use crate::net::wire::{self, Reader};
use crate::obs::Tracer;

use super::crc::crc32;
use super::DurabilityCfg;

/// Log file name inside a durability directory.
pub const LOG_FILE: &str = "wal.log";

/// Magic + version prefix of the log file.
pub const WAL_MAGIC: [u8; 8] = *b"DDMWAL01";

/// Record kind: one staged region op.
pub const REC_OP: u8 = 1;

/// Record kind: a commit marker (epoch + pair-set fingerprint).
pub const REC_COMMIT: u8 = 2;

/// Upper bound on one record's payload; scan treats larger declared
/// lengths as corruption. Generous: the largest op (a 64-d upsert) is
/// ~1 KiB.
pub const MAX_RECORD: usize = 1 << 20;

/// Buffered op bytes past this flush to the file outside the commit
/// path (bounds staging-path memory during bulk loads).
const BUF_HIWAT: usize = 1 << 20;

/// Destination of log writes — a seam so the fault-injection harness
/// ([`faultfs`](super::faultfs)) can truncate, tear, or error the Nth
/// write.
pub trait WalSink: Send {
    /// Write the whole buffer or fail.
    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()>;
    /// Flush to stable storage (`fsync`).
    fn sync(&mut self) -> std::io::Result<()>;
}

impl WalSink for std::fs::File {
    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        Write::write_all(self, buf)
    }
    fn sync(&mut self) -> std::io::Result<()> {
        self.sync_data()
    }
}

/// Monotonic log-side counters, surfaced as `wal_*` metrics gauges and
/// asserted by the durability tests/benches.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WalStats {
    /// Bytes handed to the sink (magic + records).
    pub bytes: u64,
    /// Records encoded (op + commit), including still-buffered ones.
    pub records: u64,
    /// Commit markers appended.
    pub commits: u64,
    /// `fsync`s issued on the log.
    pub fsyncs: u64,
    /// Checkpoints installed (snapshot written + log truncated).
    pub checkpoints: u64,
    /// Failed writes/syncs — nonzero means the log is degraded.
    pub errors: u64,
}

/// Log behaviour knobs (the session-facing subset of
/// [`DurabilityCfg`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalOptions {
    /// `fsync` after every commit marker.
    pub fsync_commits: bool,
    /// Checkpoint every this many commits (`u64::MAX`: never).
    pub snapshot_every: u64,
}

impl Default for WalOptions {
    fn default() -> Self {
        Self {
            fsync_commits: false,
            snapshot_every: 64,
        }
    }
}

/// One durable epoch recovered from the log: the staged ops between
/// the previous marker and this one, plus the marker's own metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct CommittedBatch {
    /// Epoch the marker closed.
    pub epoch: u64,
    /// Retained pair count the marker recorded.
    pub n_pairs: u64,
    /// CRC32 fingerprint of the post-commit packed pair set.
    pub fingerprint: u32,
    /// The batch's op records, in append (stage) order.
    pub ops: Vec<RegionOp>,
}

/// Result of walking a log image ([`scan_log`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WalScan {
    /// Fully committed batches, in log order.
    pub batches: Vec<CommittedBatch>,
    /// Byte length of the durable prefix: everything up to and
    /// including the last valid commit marker. Appends after recovery
    /// resume here.
    pub valid_len: usize,
    /// End offset of every structurally valid record (the crash-point
    /// menu the property suite truncates at).
    pub record_ends: Vec<usize>,
    /// Structurally valid records decoded.
    pub records: u64,
    /// Bytes past `valid_len` (uncommitted tail ops + any corruption)
    /// that recovery discards.
    pub tail_bytes: usize,
    /// Op records after the last marker (the discarded open batch).
    pub open_ops: usize,
}

/// Append one op record (framing + CRC) to `out`.
pub fn encode_op_record(out: &mut Vec<u8>, op: &RegionOp) {
    let mut payload = Vec::with_capacity(64);
    wire::put_u8(&mut payload, REC_OP);
    put_op(&mut payload, op);
    put_record(out, &payload);
}

/// Append one commit-marker record to `out`.
pub fn encode_commit_record(out: &mut Vec<u8>, epoch: u64, n_pairs: u64, fingerprint: u32) {
    let mut payload = Vec::with_capacity(24);
    wire::put_u8(&mut payload, REC_COMMIT);
    wire::put_varint(&mut payload, epoch);
    wire::put_varint(&mut payload, n_pairs);
    wire::put_varint(&mut payload, u64::from(fingerprint));
    put_record(out, &payload);
}

fn put_record(out: &mut Vec<u8>, payload: &[u8]) {
    debug_assert!(payload.len() <= MAX_RECORD, "record payload over MAX_RECORD");
    wire::put_u32(out, payload.len() as u32);
    out.extend_from_slice(payload);
    wire::put_u32(out, crc32(payload));
}

/// Decode one record payload into the scan state. `None` = the
/// payload is malformed (scan stops there).
fn decode_payload(payload: &[u8], open: &mut Vec<RegionOp>) -> Option<Result<CommittedBatch, ()>> {
    let mut r = Reader::new(payload);
    let kind = r.u8().ok()?;
    match kind {
        REC_OP => {
            let op = read_op(&mut r).ok()?;
            r.finish().ok()?;
            open.push(op);
            Some(Err(()))
        }
        REC_COMMIT => {
            let epoch = r.varint().ok()?;
            let n_pairs = r.varint().ok()?;
            let fingerprint = u32::try_from(r.varint().ok()?).ok()?;
            r.finish().ok()?;
            Some(Ok(CommittedBatch {
                epoch,
                n_pairs,
                fingerprint,
                ops: std::mem::take(open),
            }))
        }
        _ => None,
    }
}

/// Walk a log image record by record, stopping at the first
/// length/CRC/decode failure, and return every fully committed batch.
/// Never errors and never panics: a missing/foreign magic, a torn
/// record, or a bit-flipped byte all just shorten the durable prefix.
pub fn scan_log(bytes: &[u8]) -> WalScan {
    let mut scan = WalScan::default();
    if bytes.len() < WAL_MAGIC.len() || bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
        scan.tail_bytes = bytes.len();
        return scan;
    }
    let mut at = WAL_MAGIC.len();
    scan.valid_len = at;
    let mut open: Vec<RegionOp> = Vec::new();
    loop {
        let Some(head) = bytes.get(at..at.checked_add(4).unwrap_or(usize::MAX)) else {
            break;
        };
        let Ok(len_bytes) = <[u8; 4]>::try_from(head) else {
            break;
        };
        let Ok(len) = usize::try_from(u32::from_le_bytes(len_bytes)) else {
            break;
        };
        if len > MAX_RECORD {
            break;
        }
        let Some(body_end) = at.checked_add(4).and_then(|v| v.checked_add(len)) else {
            break;
        };
        let Some(rec_end) = body_end.checked_add(4) else {
            break;
        };
        let (Some(payload), Some(crc_slice)) = (bytes.get(at + 4..body_end), bytes.get(body_end..rec_end))
        else {
            break;
        };
        let Ok(crc_bytes) = <[u8; 4]>::try_from(crc_slice) else {
            break;
        };
        if crc32(payload) != u32::from_le_bytes(crc_bytes) {
            break;
        }
        let Some(decoded) = decode_payload(payload, &mut open) else {
            break;
        };
        at = rec_end;
        scan.records += 1;
        scan.record_ends.push(at);
        if let Ok(batch) = decoded {
            scan.batches.push(batch);
            scan.valid_len = at;
        }
    }
    scan.open_ops = open.len();
    scan.tail_bytes = bytes.len().saturating_sub(scan.valid_len);
    scan
}

/// The session-attached write-ahead log: an op buffer, a sink, and the
/// checkpoint cadence. Constructed by the engine
/// ([`durability`](crate::engine::EngineBuilder::durability)) and
/// driven from the session commit path.
pub struct Wal {
    dir: PathBuf,
    sink: Option<Box<dyn WalSink>>,
    /// Encoded op records staged since the last file write.
    buf: Vec<u8>,
    buf_records: u64,
    opts: WalOptions,
    commits_since_checkpoint: u64,
    stats: WalStats,
    last_error: Option<String>,
}

impl Wal {
    /// Open a log handle on `cfg.dir` (creating the directory). No log
    /// file is touched yet — follow with [`start_fresh`](Self::start_fresh)
    /// (new history) or [`install_checkpoint`](Self::install_checkpoint)
    /// (resume after recovery).
    pub fn open(cfg: &DurabilityCfg) -> crate::Result<Self> {
        std::fs::create_dir_all(&cfg.dir)
            .map_err(|e| crate::error::Error::msg(format!("durability dir {:?}: {e}", cfg.dir)))?;
        Ok(Self {
            dir: cfg.dir.clone(),
            sink: None,
            buf: Vec::new(),
            buf_records: 0,
            opts: WalOptions {
                fsync_commits: cfg.fsync_commits,
                snapshot_every: cfg.snapshot_every.max(1),
            },
            commits_since_checkpoint: 0,
            stats: WalStats::default(),
            last_error: None,
        })
    }

    /// [`open`](Self::open) + [`start_fresh`](Self::start_fresh): a new
    /// empty history at `cfg.dir`.
    pub fn create_fresh(cfg: &DurabilityCfg) -> crate::Result<Self> {
        let mut wal = Self::open(cfg)?;
        wal.start_fresh()?;
        Ok(wal)
    }

    /// Begin a new history: delete any previous snapshot file and
    /// truncate the log to its magic. Destroys whatever the directory
    /// held — resuming callers go through
    /// [`DdmEngine::recover_session`](crate::engine::DdmEngine::recover_session)
    /// instead.
    pub fn start_fresh(&mut self) -> crate::Result<()> {
        let snap = self.dir.join(super::snapfile::SNAP_FILE);
        if snap.exists() {
            std::fs::remove_file(&snap)
                .map_err(|e| crate::error::Error::msg(format!("remove {snap:?}: {e}")))?;
        }
        self.new_log()
            .map_err(|e| crate::error::Error::msg(format!("create log in {:?}: {e}", self.dir)))
    }

    fn new_log(&mut self) -> std::io::Result<()> {
        let mut f = std::fs::File::create(self.dir.join(LOG_FILE))?;
        Write::write_all(&mut f, &WAL_MAGIC)?;
        f.sync_data()?;
        self.sink = Some(Box::new(f));
        self.stats.bytes += WAL_MAGIC.len() as u64;
        Ok(())
    }

    /// Directory this log lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Counters since construction.
    pub fn stats(&self) -> WalStats {
        self.stats
    }

    /// The error that degraded the log, if any write/sync has failed.
    pub fn last_error(&self) -> Option<&str> {
        self.last_error.as_deref()
    }

    /// Replace the sink — the fault-injection seam.
    #[cfg(any(test, feature = "failpoints"))]
    pub fn set_sink(&mut self, sink: Box<dyn WalSink>) {
        self.sink = Some(sink);
    }

    /// Buffer one staged op (no IO unless the buffer passed
    /// [`BUF_HIWAT`]). Called from the session staging path.
    pub(crate) fn log_op(&mut self, sub: bool, key: u32, rect: Option<&[Interval]>) {
        let op = match (sub, rect) {
            (true, Some(r)) => RegionOp::UpsertSub { key, rect: r.to_vec() },
            (false, Some(r)) => RegionOp::UpsertUpd { key, rect: r.to_vec() },
            (true, None) => RegionOp::RemoveSub { key },
            (false, None) => RegionOp::RemoveUpd { key },
        };
        encode_op_record(&mut self.buf, &op);
        self.stats.records += 1;
        self.buf_records += 1;
        if self.buf.len() >= BUF_HIWAT {
            self.write_buffered();
        }
    }

    /// Flush buffered op records to the file — the write-ahead point a
    /// commit runs before publishing its snapshot (`wal_append`).
    pub(crate) fn flush_ops(&mut self, tracer: &mut Tracer) {
        if self.buf.is_empty() {
            return;
        }
        let t0 = tracer.start();
        let n = self.buf_records;
        self.write_buffered();
        tracer.span(crate::obs::Phase::WalAppend, t0, n);
    }

    /// Append the commit marker for `epoch` (and fsync per policy) —
    /// the point after which the epoch is durable.
    pub(crate) fn append_commit(
        &mut self,
        epoch: u64,
        n_pairs: u64,
        fingerprint: u32,
        tracer: &mut Tracer,
    ) {
        let t0 = tracer.start();
        let mut rec = Vec::with_capacity(24);
        encode_commit_record(&mut rec, epoch, n_pairs, fingerprint);
        self.stats.records += 1;
        self.stats.commits += 1;
        self.commits_since_checkpoint += 1;
        self.write(&rec);
        tracer.span(crate::obs::Phase::WalAppend, t0, 1);
        if self.opts.fsync_commits {
            let t1 = tracer.start();
            self.sync();
            tracer.span(crate::obs::Phase::WalFsync, t1, 1);
        }
    }

    /// Whether the checkpoint cadence says this commit should install a
    /// snapshot and truncate the log.
    pub(crate) fn should_checkpoint(&self) -> bool {
        self.sink.is_some() && self.commits_since_checkpoint >= self.opts.snapshot_every
    }

    /// Install a checkpoint: atomically replace the snapshot file with
    /// `snapshot_payload` (tmp + rename, both synced) and truncate the
    /// log back to its magic. Buffered-but-unflushed op records are
    /// kept — they belong to the next, not-yet-durable epoch and will
    /// land in the fresh log.
    pub(crate) fn install_checkpoint(&mut self, snapshot_payload: &[u8]) {
        let snap = self.dir.join(super::snapfile::SNAP_FILE);
        let tmp = self.dir.join(format!("{}.tmp", super::snapfile::SNAP_FILE));
        let res = (|| -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            Write::write_all(&mut f, snapshot_payload)?;
            f.sync_data()?;
            drop(f);
            std::fs::rename(&tmp, &snap)?;
            self.new_log()
        })();
        match res {
            Ok(()) => {
                self.commits_since_checkpoint = 0;
                self.stats.checkpoints += 1;
                self.stats.bytes += snapshot_payload.len() as u64;
            }
            Err(e) => self.degrade(&format!("checkpoint: {e}")),
        }
    }

    fn write_buffered(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        self.buf_records = 0;
        self.write(&buf);
        self.buf = buf;
        self.buf.clear();
    }

    fn write(&mut self, bytes: &[u8]) {
        if bytes.is_empty() {
            return;
        }
        let Some(sink) = self.sink.as_mut() else {
            return;
        };
        match sink.write_all(bytes) {
            Ok(()) => self.stats.bytes += bytes.len() as u64,
            Err(e) => self.degrade(&format!("write: {e}")),
        }
    }

    fn sync(&mut self) {
        let Some(sink) = self.sink.as_mut() else {
            return;
        };
        match sink.sync() {
            Ok(()) => self.stats.fsyncs += 1,
            Err(e) => self.degrade(&format!("fsync: {e}")),
        }
    }

    /// Record the error, count it, and stop writing: the in-memory
    /// session keeps serving while the log is degraded.
    fn degrade(&mut self, msg: &str) {
        self.stats.errors += 1;
        self.last_error = Some(msg.to_string());
        self.sink = None;
    }
}

/// The WAL as a session holds it: the log itself plus shadow tables of
/// the *committed* region state (key → rectangle, both sides), which
/// is what checkpoints serialize.
///
/// The shadow exists because the session's trees are not a safe
/// checkpoint source: a pipelined commit
/// ([`commit_pipelined`](crate::session::DdmSession::commit_pipelined))
/// writes the *next* epoch's rectangles into the trees while this
/// epoch's marker is being appended, so at checkpoint time the trees
/// can be one batch ahead of the durable epoch. The shadow is updated
/// only from the merged batch an apply actually commits, so it always
/// equals the marker's epoch exactly.
pub struct SessionWal {
    wal: Wal,
    d: usize,
    subs: std::collections::HashMap<u32, Vec<Interval>>,
    upds: std::collections::HashMap<u32, Vec<Interval>>,
}

impl SessionWal {
    /// Wrap `wal` for a `d`-dimensional session with no prior state.
    pub fn new(wal: Wal, d: usize) -> Self {
        assert!(
            d >= 1 && d <= crate::net::proto::MAX_DIMS,
            "durability supports 1..={} dimensions, got {d}",
            crate::net::proto::MAX_DIMS
        );
        Self {
            wal,
            d,
            subs: std::collections::HashMap::new(),
            upds: std::collections::HashMap::new(),
        }
    }

    /// Wrap `wal` with the shadow tables pre-seeded — the resume path,
    /// where the session already holds recovered regions.
    pub fn with_regions(
        wal: Wal,
        d: usize,
        subs: std::collections::HashMap<u32, Vec<Interval>>,
        upds: std::collections::HashMap<u32, Vec<Interval>>,
    ) -> Self {
        let mut sw = Self::new(wal, d);
        sw.subs = subs;
        sw.upds = upds;
        sw
    }

    /// Buffer one staged op (see [`Wal::log_op`]).
    pub(crate) fn log_op(&mut self, sub: bool, key: u32, rect: Option<&[Interval]>) {
        self.wal.log_op(sub, key, rect);
    }

    /// Fold one *applied* (merged, coalesced) batch into the shadow
    /// tables — called where the session actually writes its indexes.
    pub(crate) fn apply_committed(
        &mut self,
        subs: &std::collections::BTreeMap<u32, Option<Vec<Interval>>>,
        upds: &std::collections::BTreeMap<u32, Option<Vec<Interval>>>,
    ) {
        for (key, op) in subs {
            match op {
                Some(rect) => {
                    self.subs.insert(*key, rect.clone());
                }
                None => {
                    self.subs.remove(key);
                }
            }
        }
        for (key, op) in upds {
            match op {
                Some(rect) => {
                    self.upds.insert(*key, rect.clone());
                }
                None => {
                    self.upds.remove(key);
                }
            }
        }
    }

    /// Write-ahead flush of the buffered op records (see
    /// [`Wal::flush_ops`]).
    pub(crate) fn flush_ops(&mut self, tracer: &mut Tracer) {
        self.wal.flush_ops(tracer);
    }

    /// Close the epoch durably: append the marker for the
    /// just-published `snap` (epoch, pair count, fingerprint), fsync
    /// per policy, and install a checkpoint when the cadence says so.
    pub(crate) fn on_commit(&mut self, snap: &crate::session::EpochSnapshot, tracer: &mut Tracer) {
        let fingerprint = super::fingerprint_packed(snap.packed_pairs());
        let n_pairs = u64::try_from(snap.n_pairs()).unwrap_or(u64::MAX);
        self.wal.append_commit(snap.epoch(), n_pairs, fingerprint, tracer);
        if self.wal.should_checkpoint() {
            self.checkpoint(snap);
        }
    }

    /// Unconditionally install a checkpoint of `snap` + the shadow
    /// region tables (the resume path calls this right after recovery
    /// so the torn tail is physically gone).
    pub(crate) fn checkpoint(&mut self, snap: &crate::session::EpochSnapshot) {
        let mut subs: Vec<(u32, Vec<Interval>)> =
            self.subs.iter().map(|(k, r)| (*k, r.clone())).collect();
        subs.sort_unstable_by_key(|(k, _)| *k);
        let mut upds: Vec<(u32, Vec<Interval>)> =
            self.upds.iter().map(|(k, r)| (*k, r.clone())).collect();
        upds.sort_unstable_by_key(|(k, _)| *k);
        let file = super::snapfile::SnapshotFile {
            epoch: snap.epoch(),
            d: self.d,
            subs,
            upds,
            pairs: snap.packed_pairs().to_vec(),
        };
        self.wal.install_checkpoint(&file.encode());
    }

    /// Counters since construction (see [`Wal::stats`]).
    pub fn stats(&self) -> WalStats {
        self.wal.stats()
    }

    /// The error that degraded the log, if any (see
    /// [`Wal::last_error`]).
    pub fn last_error(&self) -> Option<&str> {
        self.wal.last_error()
    }

    /// Directory the log lives in.
    pub fn dir(&self) -> &Path {
        self.wal.dir()
    }

    /// Replace the sink — the fault-injection seam.
    #[cfg(any(test, feature = "failpoints"))]
    pub fn set_sink(&mut self, sink: Box<dyn WalSink>) {
        self.wal.set_sink(sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(key: u32) -> RegionOp {
        RegionOp::UpsertSub {
            key,
            rect: vec![Interval::new(f64::from(key), f64::from(key) + 1.0)],
        }
    }

    fn sample_log(epochs: u64, ops_per: u32) -> Vec<u8> {
        let mut log = WAL_MAGIC.to_vec();
        for e in 1..=epochs {
            for k in 0..ops_per {
                encode_op_record(&mut log, &op(k));
            }
            encode_commit_record(&mut log, e, u64::from(ops_per), 0xDEAD_0000 + e as u32);
        }
        log
    }

    #[test]
    fn scan_round_trips_committed_batches() {
        let log = sample_log(3, 4);
        let scan = scan_log(&log);
        assert_eq!(scan.batches.len(), 3);
        assert_eq!(scan.records, 15);
        assert_eq!(scan.valid_len, log.len());
        assert_eq!(scan.tail_bytes, 0);
        assert_eq!(scan.open_ops, 0);
        for (i, b) in scan.batches.iter().enumerate() {
            assert_eq!(b.epoch, i as u64 + 1);
            assert_eq!(b.n_pairs, 4);
            assert_eq!(b.fingerprint, 0xDEAD_0000 + i as u32 + 1);
            assert_eq!(b.ops.len(), 4);
            assert_eq!(b.ops[2], op(2));
        }
    }

    #[test]
    fn uncommitted_tail_ops_are_discarded() {
        let mut log = sample_log(2, 3);
        let durable = log.len();
        encode_op_record(&mut log, &op(9));
        encode_op_record(&mut log, &op(10));
        let scan = scan_log(&log);
        assert_eq!(scan.batches.len(), 2);
        assert_eq!(scan.valid_len, durable);
        assert_eq!(scan.open_ops, 2);
        assert_eq!(scan.tail_bytes, log.len() - durable);
    }

    #[test]
    fn truncation_at_every_byte_never_panics_and_keeps_a_prefix() {
        let log = sample_log(3, 2);
        let full = scan_log(&log);
        for cut in 0..=log.len() {
            let scan = scan_log(&log[..cut]);
            assert!(scan.batches.len() <= full.batches.len());
            // Whatever survives is an exact prefix of the full history.
            assert_eq!(
                scan.batches[..],
                full.batches[..scan.batches.len()],
                "cut at {cut} is not a committed prefix"
            );
            assert!(scan.valid_len <= cut.max(WAL_MAGIC.len()));
        }
    }

    #[test]
    fn single_bit_flips_truncate_to_a_committed_prefix() {
        let log = sample_log(3, 2);
        let full = scan_log(&log);
        assert_eq!(full.batches.len(), 3);
        for byte in 0..log.len() {
            let mut bad = log.clone();
            bad[byte] ^= 0x10;
            let scan = scan_log(&bad);
            // The flip may kill the whole log (magic), a middle record
            // (everything after discards), or a tail record — but the
            // result is always a prefix of the real history.
            assert!(
                scan.batches.len() <= full.batches.len(),
                "flip at {byte} grew the history"
            );
            assert_eq!(
                scan.batches[..],
                full.batches[..scan.batches.len()],
                "flip at {byte} yielded a non-prefix"
            );
        }
    }

    #[test]
    fn foreign_or_missing_magic_discards_everything() {
        assert_eq!(scan_log(b""), WalScan { tail_bytes: 0, ..WalScan::default() });
        let scan = scan_log(b"NOTAWAL0rest");
        assert!(scan.batches.is_empty());
        assert_eq!(scan.valid_len, 0);
        assert_eq!(scan.tail_bytes, 12);
    }

    #[test]
    fn oversized_declared_length_stops_the_scan() {
        let mut log = sample_log(1, 1);
        let durable = scan_log(&log).valid_len;
        wire::put_u32(&mut log, (MAX_RECORD + 1) as u32);
        log.extend_from_slice(&[0u8; 16]);
        let scan = scan_log(&log);
        assert_eq!(scan.batches.len(), 1);
        assert_eq!(scan.valid_len, durable);
    }

    #[test]
    fn wal_degrades_on_error_instead_of_panicking() {
        let dir = std::env::temp_dir().join(format!("ddm-wal-degrade-{}", std::process::id()));
        let mut wal = Wal::create_fresh(&DurabilityCfg::new(&dir)).expect("create");
        struct Boom;
        impl WalSink for Boom {
            fn write_all(&mut self, _b: &[u8]) -> std::io::Result<()> {
                Err(std::io::Error::other("disk on fire"))
            }
            fn sync(&mut self) -> std::io::Result<()> {
                Err(std::io::Error::other("disk on fire"))
            }
        }
        wal.set_sink(Box::new(Boom));
        let mut tracer = Tracer::new(false);
        wal.log_op(true, 1, Some(&[Interval::new(0.0, 1.0)]));
        wal.flush_ops(&mut tracer);
        wal.append_commit(1, 0, 0, &mut tracer);
        assert!(wal.stats().errors >= 1);
        assert!(wal.last_error().is_some());
        // Degraded log swallows later writes silently.
        wal.log_op(true, 2, None);
        wal.flush_ops(&mut tracer);
        std::fs::remove_dir_all(&dir).ok();
    }
}

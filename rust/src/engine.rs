//! The unified matching API: the [`Matcher`] trait, the
//! [`DynamicMatcher`] incremental extension, and the [`DdmEngine`] /
//! [`EngineBuilder`] entry points.
//!
//! The paper evaluates six interchangeable matching algorithms over
//! the same subscription/update workload; its predecessors (parallel
//! SBM, parallel GBM) make the same architectural point: the DDM
//! *service* should be algorithm-agnostic so backends can be swapped
//! and compared. This module is that seam:
//!
//! * [`Matcher`] — object-safe 1-D matching plus a provided N-D path
//!   via the dimension reduction of paper §2 ([`crate::core::ddim`]).
//!   All six in-tree algorithms implement it; out-of-tree backends
//!   (e.g. the XLA runtime, see `examples/xla_backend.rs`) implement
//!   the same trait and plug into the same engine.
//! * [`DynamicMatcher`] — the incremental insert/delete/modify
//!   extension (paper §3's dynamic interval management). Implemented
//!   natively by the interval-tree index
//!   ([`crate::algos::dynamic::TreeIndex`], the two-tree scheme's
//!   per-side building block) and generically by [`RebuildDynamic`],
//!   a rebuild-on-write adapter that makes *any* static matcher
//!   dynamic.
//! * [`DdmEngine`] — the entry point: owns the worker pool, the match
//!   parameters and the selected matcher. Built via [`EngineBuilder`]
//!   (algorithm, thread count, [`MatchParams`], set implementation,
//!   GBM dedup strategy, or adaptive auto-selection by workload size).
//!   [`DdmEngine::session`] hands out epoch-based incremental matching
//!   sessions ([`crate::session::DdmSession`]) configured by the
//!   builder's session knobs
//!   ([`session_set_impl`](EngineBuilder::session_set_impl),
//!   [`batch_threshold`](EngineBuilder::batch_threshold),
//!   [`parallel_cutoff`](EngineBuilder::parallel_cutoff)).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::algos::{bfm, gbm, itm, psbm, sbm, sbm_binary};
use crate::algos::{Algo, MatchParams};
use crate::core::ddim;
pub use crate::core::ddim::{NdMode, NdPolicy, SweepDim};
use crate::core::interval::Interval;
use crate::core::scratch::{MatchScratch, ScratchStats};
use crate::core::sink::{canonicalize, CountSink, FnSink, MatchSink, PairVec, VecSink};
use crate::core::{Regions1D, RegionsNd};
use crate::exec::{SortAlgo, ThreadPool};
use crate::session::{DdmSession, SessionParams};
use crate::sets::SetImpl;
use crate::shard::{AnySession, ShardStrategy, ShardedMatcher, ShardedSession, SpacePartitioner};

/// Execution context handed to every [`Matcher`] call: the worker
/// pool, the number of workers the matcher may use for this call, and
/// (optionally) the engine's reusable [`MatchScratch`].
pub struct ExecCtx<'a> {
    pub pool: &'a ThreadPool,
    pub nthreads: usize,
    /// The engine's shared scratch, if any. Matchers access it through
    /// [`scratch`](Self::scratch); contexts built with
    /// [`new`](Self::new) (benches, custom drivers, per-stripe serial
    /// calls) have none and degrade to per-call allocation.
    scratch: Option<&'a Mutex<MatchScratch>>,
}

impl<'a> ExecCtx<'a> {
    pub fn new(pool: &'a ThreadPool, nthreads: usize) -> Self {
        assert!(nthreads >= 1, "ExecCtx needs at least one thread");
        Self {
            pool,
            nthreads,
            scratch: None,
        }
    }

    /// A context that hands matchers the given scratch (what
    /// [`DdmEngine::ctx`] builds).
    pub fn with_scratch(
        pool: &'a ThreadPool,
        nthreads: usize,
        scratch: &'a Mutex<MatchScratch>,
    ) -> Self {
        let mut ctx = Self::new(pool, nthreads);
        ctx.scratch = Some(scratch);
        ctx
    }

    /// Borrow the context's scratch for the duration of one match
    /// call. Never blocks: without an attached scratch — or when it is
    /// already held (another thread matching on the same engine, or a
    /// reentrant native pipeline) — a fresh owned scratch is returned
    /// instead, which simply restores per-call allocation.
    pub fn scratch(&self) -> ScratchGuard<'a> {
        match self.scratch.and_then(|m| m.try_lock().ok()) {
            Some(guard) => ScratchGuard::Pooled(guard),
            None => ScratchGuard::Owned(Box::new(MatchScratch::new())),
        }
    }
}

/// A borrowed-or-owned [`MatchScratch`] (see [`ExecCtx::scratch`]).
pub enum ScratchGuard<'a> {
    Pooled(std::sync::MutexGuard<'a, MatchScratch>),
    Owned(Box<MatchScratch>),
}

impl std::ops::Deref for ScratchGuard<'_> {
    type Target = MatchScratch;
    fn deref(&self) -> &MatchScratch {
        match self {
            ScratchGuard::Pooled(g) => g,
            ScratchGuard::Owned(s) => s,
        }
    }
}

impl std::ops::DerefMut for ScratchGuard<'_> {
    fn deref_mut(&mut self) -> &mut MatchScratch {
        match self {
            ScratchGuard::Pooled(g) => g,
            ScratchGuard::Owned(s) => s,
        }
    }
}

/// An interchangeable region-matching backend (the paper's six
/// algorithms, plus anything out-of-tree).
///
/// Object-safe by design: services hold `Arc<dyn Matcher>` / take
/// `&dyn Matcher`, so swapping the algorithm is a value change, not a
/// type change. Implementations must report every intersecting
/// (subscription, update) pair exactly once per call.
pub trait Matcher: Send + Sync {
    /// Short name for tables, logs and CLI round-trips.
    fn name(&self) -> &str;

    /// Match two 1-D region sets, reporting every intersecting pair
    /// `(s, u)` of dense indices into `subs`/`upds` exactly once.
    fn match_1d(
        &self,
        ctx: &ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    );

    /// Count intersections without retaining them (the paper's
    /// evaluation protocol). Implementations override this with
    /// per-worker counting sinks so the hot path stays allocation-free.
    fn count_1d(&self, ctx: &ExecCtx<'_>, subs: &Regions1D, upds: &Regions1D) -> u64 {
        let mut sink = CountSink::default();
        self.match_1d(ctx, subs, upds, &mut sink);
        sink.count
    }

    /// Match d-dimensional region sets. The provided implementation is
    /// the per-dimension reduction of paper §2
    /// ([`ddim::ReductionNd`]); the in-tree SBM/PSBM/ITM/GBM backends
    /// override it with the native sweep-and-verify pipeline
    /// ([`ddim::sweep_and_verify`]) under the engine's
    /// [`NdPolicy`](ddim::NdPolicy), and natively d-dimensional
    /// backends (e.g. the dense XLA kernels) override it outright.
    fn match_nd(
        &self,
        ctx: &ExecCtx<'_>,
        subs: &RegionsNd,
        upds: &RegionsNd,
        sink: &mut dyn MatchSink,
    ) {
        ddim::ReductionNd::match_nd_with(
            Some(ctx.pool),
            subs,
            upds,
            |s1, u1, out| self.match_1d(ctx, s1, u1, out),
            sink,
        );
    }

    /// Count d-dimensional intersections without retaining them
    /// (provided: a counting sink over [`match_nd`](Self::match_nd);
    /// the native-pipeline backends override it so the count runs
    /// through per-worker filtered counting sinks with no pair
    /// collection at all).
    fn count_nd(&self, ctx: &ExecCtx<'_>, subs: &RegionsNd, upds: &RegionsNd) -> u64 {
        let mut sink = CountSink::default();
        self.match_nd(ctx, subs, upds, &mut sink);
        sink.count
    }

    /// A dynamic (incremental) index natively maintained by this
    /// matcher family, if it has one. `None` (the default) makes the
    /// engine fall back to a generic index — the interval tree for
    /// in-tree algorithms, the [`RebuildDynamic`] adapter for custom
    /// backends (see [`DdmEngine::dynamic`]).
    fn make_dynamic(&self) -> Option<Box<dyn DynamicMatcher>> {
        None
    }
}

/// Extension of the matcher family for incremental workloads (paper
/// §3, dynamic interval management): a keyed 1-D interval index that
/// stays queryable across insert/delete/modify without a full
/// re-match.
///
/// Keys are caller-chosen `u32`s (the HLA service uses region handle
/// ids, which — unlike dense indices — survive swap-removal).
/// [`query`](Self::query) returns the keys of all stored intervals
/// overlapping `q`, ascending.
pub trait DynamicMatcher: Send {
    /// Add an interval under `key` (keys are unique; inserting an
    /// existing key replaces its interval).
    fn insert(&mut self, key: u32, iv: Interval);

    /// Replace the interval stored under `key`.
    fn modify(&mut self, key: u32, iv: Interval);

    /// Remove `key` (no-op if absent).
    fn remove(&mut self, key: u32);

    /// Clear `out` and fill it with the keys of stored intervals
    /// overlapping `q`, ascending (`out` is a reusable scratch buffer,
    /// not an accumulator). `&mut self` so rebuild-on-write adapters
    /// can rebuild lazily.
    fn query(&mut self, ctx: &ExecCtx<'_>, q: Interval, out: &mut Vec<u32>);

    /// Number of stored intervals.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Rebuild-on-write [`DynamicMatcher`] adapter for static matchers:
/// writes invalidate a cached dense snapshot; the next query rebuilds
/// it and runs the wrapped matcher against the query interval.
///
/// This is the trade-off the paper highlights against the interval
/// tree: O(1) writes, O(rebuild + match) reads — the right choice when
/// writes vastly outnumber queries, or when the wrapped backend's
/// matching semantics differ from exact interval overlap (a custom
/// backend computing in f32, say) and queries must reproduce them.
pub struct RebuildDynamic {
    matcher: Arc<dyn Matcher>,
    ivs: BTreeMap<u32, Interval>,
    /// Dense snapshot (regions, key per row); `None` after a write.
    dense: Option<(Regions1D, Vec<u32>)>,
}

impl RebuildDynamic {
    pub fn new(matcher: Arc<dyn Matcher>) -> Self {
        Self {
            matcher,
            ivs: BTreeMap::new(),
            dense: None,
        }
    }
}

impl DynamicMatcher for RebuildDynamic {
    fn insert(&mut self, key: u32, iv: Interval) {
        self.ivs.insert(key, iv);
        self.dense = None;
    }

    fn modify(&mut self, key: u32, iv: Interval) {
        self.ivs.insert(key, iv);
        self.dense = None;
    }

    fn remove(&mut self, key: u32) {
        self.ivs.remove(&key);
        self.dense = None;
    }

    fn query(&mut self, ctx: &ExecCtx<'_>, q: Interval, out: &mut Vec<u32>) {
        out.clear();
        if self.dense.is_none() {
            let mut regions = Regions1D::with_capacity(self.ivs.len());
            let mut keys = Vec::with_capacity(self.ivs.len());
            for (&k, &iv) in &self.ivs {
                regions.push(iv);
                keys.push(k);
            }
            self.dense = Some((regions, keys));
        }
        let (regions, keys) = self.dense.as_ref().expect("just built");
        let upd = Regions1D::from_intervals(&[q]);
        let mut sink = FnSink(|s: u32, _u: u32| out.push(keys[s as usize]));
        self.matcher.match_1d(ctx, regions, &upd, &mut sink);
        out.sort_unstable();
    }

    fn len(&self) -> usize {
        self.ivs.len()
    }
}

/// Spatial sharding configuration (see [`crate::shard`]): how many
/// stripes, which dimension to split, and how cuts are derived.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardParams {
    /// Number of spatial shards; `1` (the default) disables sharding
    /// everywhere — sessions are plain [`DdmSession`]s and the static
    /// matcher is not wrapped.
    pub shards: usize,
    /// Dimension whose extent is striped (clamped to `d - 1` at
    /// session construction).
    pub split_dim: usize,
    /// Uniform cuts or sample-balanced quantile cuts.
    pub strategy: ShardStrategy,
}

impl Default for ShardParams {
    fn default() -> Self {
        Self {
            shards: 1,
            split_dim: 0,
            strategy: ShardStrategy::Uniform,
        }
    }
}

/// How the engine picks its matcher.
#[derive(Clone)]
enum Selection {
    /// One fixed in-tree algorithm.
    Fixed(Algo),
    /// Adaptive: pick per call by workload size and thread count.
    Auto,
    /// A caller-supplied backend.
    Custom(Arc<dyn Matcher>),
}

/// Construct the [`Matcher`] for one in-tree algorithm. SBM, PSBM,
/// ITM and GBM carry the parameter block's [`NdPolicy`](ddim::NdPolicy)
/// into their native N-D overrides; BFM and binary-SBM keep the
/// provided reduction path.
pub fn algo_matcher(algo: Algo, params: &MatchParams) -> Arc<dyn Matcher> {
    match algo {
        Algo::Bfm => Arc::new(bfm::BfmMatcher),
        Algo::Gbm => Arc::new(gbm::GbmMatcher::new(params.gbm()).with_nd(params.nd)),
        Algo::Itm => Arc::new(itm::ItmMatcher::default().with_nd(params.nd)),
        Algo::Sbm => Arc::new(
            sbm::SbmMatcher::new(params.set_impl)
                .with_nd(params.nd)
                .with_sort(params.sort),
        ),
        Algo::Psbm => Arc::new(
            psbm::PsbmMatcher::new(params.set_impl)
                .with_nd(params.nd)
                .with_sort(params.sort),
        ),
        Algo::SbmBinary => Arc::new(sbm_binary::SbmBinaryMatcher),
    }
}

/// Auto-selection heuristic (paper §5's summary findings): brute force
/// for workloads too small to amortize a sort, serial SBM on one
/// worker (the sequential state of the art), Parallel SBM otherwise
/// (the paper's winner across every large workload).
fn auto_algo(n: usize, m: usize, nthreads: usize) -> Algo {
    if n + m <= 256 {
        Algo::Bfm
    } else if nthreads == 1 {
        Algo::Sbm
    } else {
        Algo::Psbm
    }
}

/// Builder for [`DdmEngine`].
///
/// ```
/// use ddm::algos::Algo;
/// use ddm::engine::DdmEngine;
/// use ddm::sets::SetImpl;
///
/// let engine = DdmEngine::builder()
///     .algo(Algo::Psbm)
///     .threads(4)
///     .set_impl(SetImpl::Bit)
///     .build();
/// assert_eq!(engine.algo_name(), "psbm");
/// ```
pub struct EngineBuilder {
    selection: Selection,
    nthreads: usize,
    params: MatchParams,
    session: SessionParams,
    shard: ShardParams,
    pool: Option<Arc<ThreadPool>>,
    durability: Option<crate::durable::DurabilityCfg>,
}

impl EngineBuilder {
    pub fn new() -> Self {
        Self {
            selection: Selection::Fixed(Algo::Psbm),
            nthreads: 4,
            params: MatchParams::default(),
            session: SessionParams::default(),
            shard: ShardParams::default(),
            pool: None,
            durability: None,
        }
    }

    /// Use one fixed in-tree algorithm.
    pub fn algo(mut self, algo: Algo) -> Self {
        self.selection = Selection::Fixed(algo);
        self
    }

    /// Adaptive algorithm selection by workload size (see
    /// [`DdmEngine::algo_name`] for what gets picked).
    pub fn auto(mut self) -> Self {
        self.selection = Selection::Auto;
        self
    }

    /// Use a caller-supplied (possibly out-of-tree) backend.
    pub fn matcher(mut self, matcher: Arc<dyn Matcher>) -> Self {
        self.selection = Selection::Custom(matcher);
        self
    }

    /// Parse an algorithm name: every [`Algo`] alias plus `"auto"`.
    pub fn algo_str(self, s: &str) -> Result<Self, String> {
        if s.eq_ignore_ascii_case("auto") {
            return Ok(self.auto());
        }
        Ok(self.algo(s.parse::<Algo>()?))
    }

    /// Number of workers per match call (≥ 1; serial algorithms
    /// ignore it).
    pub fn threads(mut self, nthreads: usize) -> Self {
        self.nthreads = nthreads.max(1);
        self
    }

    /// Replace the whole parameter block.
    pub fn params(mut self, params: MatchParams) -> Self {
        self.params = params;
        self
    }

    /// SBM/PSBM active-set implementation (paper §5 study).
    pub fn set_impl(mut self, set_impl: SetImpl) -> Self {
        self.params.set_impl = set_impl;
        self
    }

    /// GBM grid-cell count.
    pub fn ncells(mut self, ncells: usize) -> Self {
        self.params.ncells = ncells;
        self
    }

    /// GBM phase-2 duplicate-suppression strategy.
    pub fn dedup(mut self, dedup: gbm::Dedup) -> Self {
        self.params.dedup = dedup;
        self
    }

    /// GBM phase-1 cell-list synchronization strategy.
    pub fn cell_list(mut self, cell_list: gbm::CellList) -> Self {
        self.params.cell_list = cell_list;
        self
    }

    /// N-D pipeline: native sweep-and-verify (default) or the paper's
    /// per-dimension reduction (see [`crate::core::ddim`]; CLI
    /// `--nd-mode native|reduce`).
    pub fn nd_mode(mut self, mode: ddim::NdMode) -> Self {
        self.params.nd.mode = mode;
        self
    }

    /// Sweep dimension for the native N-D pipeline: auto-selected by
    /// sampled selectivity (default) or pinned to one dimension (CLI
    /// `--sweep-dim auto|k`).
    pub fn sweep_dim(mut self, sweep: ddim::SweepDim) -> Self {
        self.params.nd.sweep = sweep;
        self
    }

    /// SBM/PSBM endpoint sort: compact-key radix (default) or the
    /// merge-path comparison fallback (CLI `--sort radix|merge`;
    /// `benches/abl_sort.rs` measures the two against each other).
    pub fn sort_algo(mut self, sort: SortAlgo) -> Self {
        self.params.sort = sort;
        self
    }

    /// Capture phase spans ([`crate::obs`]) in every match call and
    /// every session this engine creates (CLI `--trace`). Off by
    /// default; the disabled path is a branch per phase. Read the
    /// timeline back with [`DdmEngine::drain_trace`] /
    /// [`DdmSession::drain_trace`](crate::session::DdmSession::drain_trace).
    pub fn trace(mut self, on: bool) -> Self {
        self.params.trace = on;
        self.session.trace = on;
        self
    }

    // ---- session knobs (see crate::session) --------------------------------

    /// Backing store of session diff retention sets
    /// ([`SessionParams::set_impl`]).
    pub fn session_set_impl(mut self, set_impl: SetImpl) -> Self {
        self.session.set_impl = set_impl;
        self
    }

    /// Epoch batching threshold: sessions auto-apply staged ops to
    /// their indexes once this many are pending (`0` = only at
    /// `commit`). See [`SessionParams::batch_threshold`].
    pub fn batch_threshold(mut self, ops: usize) -> Self {
        self.session.batch_threshold = ops;
        self
    }

    /// Minimum touched regions per session batch before apply and
    /// recompute run on the worker pool. See
    /// [`SessionParams::parallel_cutoff`].
    pub fn parallel_cutoff(mut self, regions: usize) -> Self {
        self.session.parallel_cutoff = regions;
        self
    }

    /// Reuse each session's per-epoch scratch buffers across commits
    /// (default `true`; `false` restores per-epoch allocation — the
    /// cold baseline `benches/abl_session.rs` measures against). See
    /// [`SessionParams::reuse_scratch`].
    pub fn session_scratch_reuse(mut self, reuse: bool) -> Self {
        self.session.reuse_scratch = reuse;
        self
    }

    /// Admission bound of the async ingestion front-end built for the
    /// engine's sessions: staged-op queues
    /// ([`ingest_queue`](crate::session::ingest_queue)) sized from the
    /// session params admit this many in-flight ops before producers
    /// get a typed `Busy` (the net worker surfaces it on the wire).
    /// See [`SessionParams::ingest_backlog`].
    pub fn ingest_backlog(mut self, ops: usize) -> Self {
        self.session.ingest_backlog = ops;
        self
    }

    /// Replace the whole session parameter block.
    pub fn session_params(mut self, session: SessionParams) -> Self {
        self.session = session;
        self
    }

    // ---- shard knobs (see crate::shard) -------------------------------------

    /// Number of spatial shards (stripes of the split dimension).
    /// With `n > 1` the static matcher is wrapped in a
    /// [`ShardedMatcher`] and
    /// [`any_session`](DdmEngine::any_session) /
    /// [`sharded_session`](DdmEngine::sharded_session) hand out
    /// [`ShardedSession`]s. `1` (default) disables sharding.
    pub fn shards(mut self, n: usize) -> Self {
        self.shard.shards = n.max(1);
        self
    }

    /// Which dimension to stripe (default 0; clamped to the session's
    /// dimensionality at construction).
    pub fn split_dim(mut self, k: usize) -> Self {
        self.shard.split_dim = k;
        self
    }

    /// Derive stripe cuts from a sample of the first staged batch
    /// (quantile-balanced) instead of uniform widths — see
    /// [`ShardStrategy::Balanced`].
    pub fn balanced_shards(mut self) -> Self {
        self.shard.strategy = ShardStrategy::Balanced;
        self
    }

    /// Replace the whole shard parameter block.
    pub fn shard_params(mut self, shard: ShardParams) -> Self {
        self.shard = shard;
        self.shard.shards = self.shard.shards.max(1);
        self
    }

    /// Share an existing pool (e.g. the bench harness pool) instead of
    /// spawning one. The pool must be able to serve `threads` workers.
    pub fn pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    // ---- durability knobs (see crate::durable) ------------------------------

    /// Make every session this engine creates crash-consistent: staged
    /// ops are written ahead to `dir`'s op log before each commit
    /// publishes, commit markers carry the epoch's pair-set
    /// fingerprint, and checkpoints truncate the log on a cadence. A
    /// *new* session truncates whatever history `dir` held; to come
    /// back from an earlier run use
    /// [`DdmEngine::recover_session`] / [`DdmEngine::recover_any_session`]
    /// instead. One directory belongs to one live session at a time.
    ///
    /// CLI: `ddm serve --wal DIR`, `ddm replay --record DIR`.
    pub fn durability(mut self, dir: impl AsRef<std::path::Path>) -> Self {
        let dir = dir.as_ref().to_path_buf();
        match self.durability.as_mut() {
            Some(cfg) => cfg.dir = dir,
            None => self.durability = Some(crate::durable::DurabilityCfg::new(dir)),
        }
        self
    }

    /// `fsync` the op log after every commit marker (crash-through-power
    /// durability; default `false` trusts the OS page cache). Call
    /// after [`durability`](Self::durability).
    ///
    /// # Panics
    /// If no durability directory has been configured yet.
    pub fn durability_fsync(mut self, on: bool) -> Self {
        self.durability
            .as_mut()
            .expect("durability_fsync needs durability(dir) first")
            .fsync_commits = on;
        self
    }

    /// Checkpoint (snapshot file + log truncation) every `commits`
    /// commits (default 64; `u64::MAX` disables the cadence). Call
    /// after [`durability`](Self::durability).
    ///
    /// # Panics
    /// If no durability directory has been configured yet.
    pub fn durability_snapshot_every(mut self, commits: u64) -> Self {
        self.durability
            .as_mut()
            .expect("durability_snapshot_every needs durability(dir) first")
            .snapshot_every = commits.max(1);
        self
    }

    pub fn build(self) -> DdmEngine {
        let pool = self
            .pool
            .unwrap_or_else(|| Arc::new(ThreadPool::new(self.nthreads.saturating_sub(1))));
        assert!(
            self.nthreads <= pool.max_threads(),
            "engine wants {} threads but the pool serves at most {}",
            self.nthreads,
            pool.max_threads()
        );
        // With shards > 1 every static backend is striped behind a
        // ShardedMatcher (dedup'd by the owner-stripe rule); the
        // unwrapped selection is kept for `dynamic()`.
        let wrap = |m: Arc<dyn Matcher>| -> Arc<dyn Matcher> {
            if self.shard.shards > 1 {
                Arc::new(ShardedMatcher::new(m, self.shard.shards).with_nd(self.params.nd))
            } else {
                m
            }
        };
        let matcher = wrap(match &self.selection {
            Selection::Fixed(algo) => algo_matcher(*algo, &self.params),
            // Auto resolves per call; keep the paper's overall winner
            // as the representative (dynamic-index donor, name).
            Selection::Auto => algo_matcher(Algo::Psbm, &self.params),
            Selection::Custom(m) => Arc::clone(m),
        });
        let auto_set = match self.selection {
            Selection::Auto => Some(AutoSet {
                bfm: wrap(algo_matcher(Algo::Bfm, &self.params)),
                sbm: wrap(algo_matcher(Algo::Sbm, &self.params)),
                psbm: wrap(algo_matcher(Algo::Psbm, &self.params)),
            }),
            _ => None,
        };
        let mut scratch = MatchScratch::new();
        if self.params.trace {
            scratch.span_log =
                crate::obs::SpanSink::with_capacity(crate::obs::trace::DEFAULT_SINK_CAP);
        }
        DdmEngine {
            selection: self.selection,
            matcher,
            auto_set,
            pool,
            nthreads: self.nthreads,
            params: self.params,
            session: self.session,
            shard: self.shard,
            durability: self.durability,
            scratch: Arc::new(Mutex::new(scratch)),
        }
    }
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// Pre-built candidates for adaptive selection.
#[derive(Clone)]
struct AutoSet {
    bfm: Arc<dyn Matcher>,
    sbm: Arc<dyn Matcher>,
    psbm: Arc<dyn Matcher>,
}

/// The algorithm-agnostic matching engine: worker pool + parameters +
/// a [`Matcher`] behind one object-safe seam.
///
/// Cheap to clone (the pool and matcher are shared); use
/// [`with_threads`](Self::with_threads) to sweep thread counts over
/// one pool.
#[derive(Clone)]
pub struct DdmEngine {
    selection: Selection,
    matcher: Arc<dyn Matcher>,
    auto_set: Option<AutoSet>,
    pool: Arc<ThreadPool>,
    nthreads: usize,
    params: MatchParams,
    session: SessionParams,
    shard: ShardParams,
    durability: Option<crate::durable::DurabilityCfg>,
    /// Reusable match scratch attached to every [`ExecCtx`] this
    /// engine creates: back-to-back match calls reuse the endpoint
    /// array, radix buffers, GBM binning block and per-worker pair
    /// sinks (shared across clones, like the pool; concurrent calls
    /// degrade to per-call allocation via `try_lock`, never block).
    scratch: Arc<Mutex<MatchScratch>>,
}

impl DdmEngine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// The execution context handed to matcher calls (carries the
    /// engine's reusable scratch).
    pub fn ctx(&self) -> ExecCtx<'_> {
        ExecCtx::with_scratch(self.pool.as_ref(), self.nthreads, &self.scratch)
    }

    /// Capacity snapshot of the engine's match scratch — equal
    /// snapshots around a warm call mean the call allocated nothing
    /// from the reusable buffers (asserted by `benches/abl_sort.rs`).
    pub fn scratch_stats(&self) -> ScratchStats {
        self.scratch.lock().map(|s| s.stats()).unwrap_or_default()
    }

    /// Take the phase spans recorded by match calls since the last
    /// drain (empty unless built with
    /// [`trace(true)`](EngineBuilder::trace)). Spans recorded through
    /// a contended scratch (concurrent calls degrade to per-call
    /// scratch) are lost — tracing follows the same try-lock policy as
    /// the buffers themselves.
    pub fn drain_trace(&self) -> Vec<crate::obs::SpanRecord> {
        let mut out = Vec::new();
        if let Ok(mut s) = self.scratch.lock() {
            s.span_log.drain_into(&mut out);
        }
        out
    }

    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    pub fn params(&self) -> &MatchParams {
        &self.params
    }

    /// The engine's matcher for a workload of the given size (adaptive
    /// engines pick here; fixed/custom engines always return the same
    /// backend).
    pub fn matcher_for(&self, n: usize, m: usize) -> &Arc<dyn Matcher> {
        match (&self.selection, &self.auto_set) {
            (Selection::Auto, Some(set)) => match auto_algo(n, m, self.nthreads) {
                Algo::Bfm => &set.bfm,
                Algo::Sbm => &set.sbm,
                _ => &set.psbm,
            },
            _ => &self.matcher,
        }
    }

    /// The configured matcher (adaptive engines: the representative).
    pub fn matcher(&self) -> &Arc<dyn Matcher> {
        &self.matcher
    }

    /// `"auto"`, the fixed algorithm's name, or the custom backend's.
    pub fn algo_name(&self) -> &str {
        match &self.selection {
            Selection::Auto => "auto",
            _ => self.matcher.name(),
        }
    }

    /// Clone sharing the pool but running `nthreads` workers per call
    /// (bench sweeps). Panics at call time if `nthreads` exceeds the
    /// shared pool's capacity.
    pub fn with_threads(&self, nthreads: usize) -> DdmEngine {
        let mut e = self.clone();
        e.nthreads = nthreads.max(1);
        e
    }

    // ---- matching ---------------------------------------------------------

    /// Match 1-D region sets into `sink` (exactly-once per pair).
    pub fn match_1d(&self, subs: &Regions1D, upds: &Regions1D, sink: &mut dyn MatchSink) {
        let ctx = self.ctx();
        self.matcher_for(subs.len(), upds.len())
            .match_1d(&ctx, subs, upds, sink);
    }

    /// Count 1-D intersections (the paper's evaluation protocol).
    pub fn count_1d(&self, subs: &Regions1D, upds: &Regions1D) -> u64 {
        let ctx = self.ctx();
        self.matcher_for(subs.len(), upds.len())
            .count_1d(&ctx, subs, upds)
    }

    /// Canonical (sorted) 1-D pair list.
    pub fn pairs_1d(&self, subs: &Regions1D, upds: &Regions1D) -> PairVec {
        let mut sink = VecSink::default();
        self.match_1d(subs, upds, &mut sink);
        canonicalize(sink.pairs)
    }

    /// Match d-dimensional region sets into `sink`.
    pub fn match_nd(&self, subs: &RegionsNd, upds: &RegionsNd, sink: &mut dyn MatchSink) {
        let ctx = self.ctx();
        self.matcher_for(subs.len(), upds.len())
            .match_nd(&ctx, subs, upds, sink);
    }

    /// Count d-dimensional intersections (the native pipeline counts
    /// through per-worker filtered sinks without collecting pairs).
    pub fn count_nd(&self, subs: &RegionsNd, upds: &RegionsNd) -> u64 {
        let ctx = self.ctx();
        self.matcher_for(subs.len(), upds.len())
            .count_nd(&ctx, subs, upds)
    }

    /// Canonical (sorted) d-dimensional pair list.
    pub fn pairs_nd(&self, subs: &RegionsNd, upds: &RegionsNd) -> PairVec {
        let mut sink = VecSink::default();
        self.match_nd(subs, upds, &mut sink);
        canonicalize(sink.pairs)
    }

    // ---- dynamic ----------------------------------------------------------

    /// A fresh incremental index for this engine's matcher family:
    ///
    /// * the matcher's native index when it has one (ITM's interval
    ///   tree);
    /// * for the other **in-tree** algorithms, the interval-tree index
    ///   too — all six share exact half-open overlap semantics, and
    ///   the tree keeps queries O(lg n + k) where rebuild-on-write
    ///   would re-run a full match per query (the publish hot path);
    /// * for **custom** backends, the [`RebuildDynamic`] adapter, so
    ///   queries reproduce the backend's own matching semantics
    ///   (e.g. the XLA backend's f32 comparisons) instead of assuming
    ///   exact f64 overlap.
    pub fn dynamic(&self) -> Box<dyn DynamicMatcher> {
        if let Some(native) = self.matcher.make_dynamic() {
            return native;
        }
        match &self.selection {
            Selection::Custom(m) => Box::new(RebuildDynamic::new(Arc::clone(m))),
            _ => Box::new(crate::algos::dynamic::TreeIndex::new()),
        }
    }

    // ---- sessions ----------------------------------------------------------

    /// A fresh `d`-dimensional incremental matching session sharing
    /// this engine's worker pool, thread count and session knobs: stage
    /// batched region churn, `commit()` an epoch, get back only the
    /// [`MatchDiff`](crate::session::MatchDiff) of intersections. See
    /// [`crate::session`] for the full model.
    pub fn session(&self, d: usize) -> DdmSession {
        let mut s = DdmSession::new(d, Arc::clone(&self.pool), self.nthreads, self.session);
        if let Some(wal) = self.fresh_wal(d) {
            s.attach_wal(wal);
        }
        s
    }

    /// The session knobs new sessions are created with.
    pub fn session_params(&self) -> &SessionParams {
        &self.session
    }

    /// The durability configuration sessions are created with, if any
    /// (see [`EngineBuilder::durability`]).
    pub fn durability_cfg(&self) -> Option<&crate::durable::DurabilityCfg> {
        self.durability.as_ref()
    }

    /// A fresh-history [`SessionWal`](crate::durable::SessionWal) per
    /// the builder's durability knobs; `None` without them.
    ///
    /// # Panics
    /// On an unwritable durability directory — a misconfiguration, not
    /// a runtime fault (runtime IO errors degrade the log instead; see
    /// [`crate::durable`]).
    fn fresh_wal(&self, d: usize) -> Option<crate::durable::SessionWal> {
        self.durability.as_ref().map(|cfg| {
            let wal = crate::durable::Wal::create_fresh(cfg)
                .unwrap_or_else(|e| panic!("durability setup failed: {e}"));
            crate::durable::SessionWal::new(wal, d)
        })
    }

    // ---- sharding ----------------------------------------------------------

    /// The shard configuration engines and services read.
    pub fn shard_params(&self) -> &ShardParams {
        &self.shard
    }

    /// A fresh `d`-dimensional [`ShardedSession`] striping the builder's
    /// [`shards`](EngineBuilder::shards) over `span` on the (clamped)
    /// [`split_dim`](EngineBuilder::split_dim). With the
    /// [`balanced_shards`](EngineBuilder::balanced_shards) strategy the
    /// uniform cuts over `span` are only the fallback until the first
    /// batch is sampled.
    pub fn sharded_session(&self, d: usize, span: crate::core::Interval) -> ShardedSession {
        assert!(d >= 1, "sessions need at least one dimension");
        let split = self.shard.split_dim.min(d - 1);
        let part = SpacePartitioner::uniform(self.shard.shards, split, span);
        self.sharded_session_with_strategy(d, part, self.shard.strategy)
    }

    /// A sharded session over an explicit partitioner (uniform-cut
    /// semantics: the given cuts are used as-is).
    pub fn sharded_session_with(&self, d: usize, part: SpacePartitioner) -> ShardedSession {
        self.sharded_session_with_strategy(d, part, ShardStrategy::Uniform)
    }

    /// A sharded session over an explicit partitioner and cut strategy
    /// ([`ShardStrategy::Balanced`] re-derives the cuts from the first
    /// staged batch; `part` is the fallback until then).
    pub fn sharded_session_with_strategy(
        &self,
        d: usize,
        part: SpacePartitioner,
        strategy: ShardStrategy,
    ) -> ShardedSession {
        let mut s = ShardedSession::new(
            d,
            part,
            strategy,
            Arc::clone(&self.pool),
            self.nthreads,
            self.session,
        );
        if let Some(wal) = self.fresh_wal(d) {
            s.attach_wal(wal);
        }
        s
    }

    /// A session dispatched by the builder's shard count: a plain
    /// [`DdmSession`] for `shards == 1`, a [`ShardedSession`] striping
    /// `span` otherwise. This is what the HLA service and the CLI use,
    /// so turning sharding on is purely a builder change.
    pub fn any_session(&self, d: usize, span: crate::core::Interval) -> AnySession {
        if self.shard.shards > 1 {
            AnySession::Sharded(self.sharded_session(d, span))
        } else {
            AnySession::Single(self.session(d))
        }
    }

    // ---- recovery ----------------------------------------------------------

    /// Rebuild a plain [`DdmSession`] to the exact last durable epoch
    /// in the builder's durability directory: decode the checkpoint,
    /// replay the committed log tail through the real matcher, verify
    /// every epoch's pair-set fingerprint, then resume logging into the
    /// same directory (installing a fresh checkpoint so any torn tail
    /// is physically discarded). See [`crate::durable::recover`] for
    /// the state machine; `ddm replay --resume` / `ddm serve --resume`
    /// are this, on the CLI.
    ///
    /// Errors: no durability configured, nothing to recover, corrupt
    /// checkpoint, inconsistent epoch history, or a replay that does
    /// not reproduce the logged fingerprints.
    pub fn recover_session(&self, d: usize) -> crate::Result<(DdmSession, crate::durable::RecoverReport)> {
        let (any, report) = self.recover_impl(d, |bare| AnySession::Single(bare.session(d)))?;
        match any {
            AnySession::Single(s) => Ok((s, report)),
            AnySession::Sharded(_) => unreachable!("recover_impl preserves the session shape"),
        }
    }

    /// [`recover_session`](Self::recover_session) through the
    /// [`any_session`](Self::any_session) dispatch: recovers into a
    /// sharded session when the builder says `shards > 1`, a plain one
    /// otherwise. The WAL is shape-agnostic (one op log per session
    /// either way), so a history recorded unsharded can be recovered
    /// sharded and vice versa.
    pub fn recover_any_session(
        &self,
        d: usize,
        span: crate::core::Interval,
    ) -> crate::Result<(AnySession, crate::durable::RecoverReport)> {
        self.recover_impl(d, |bare| bare.any_session(d, span))
    }

    fn recover_impl(
        &self,
        d: usize,
        make: impl FnOnce(&DdmEngine) -> AnySession,
    ) -> crate::Result<(AnySession, crate::durable::RecoverReport)> {
        let Some(cfg) = self.durability.clone() else {
            crate::bail!("recover needs an engine built with durability(dir)");
        };
        let st = crate::durable::recover::scan_dir(&cfg.dir)?;
        // Replay into a WAL-less session so recovery writes nothing,
        // then attach a resumed log seeded with the recovered regions.
        let mut bare = self.clone();
        bare.durability = None;
        let mut session = make(&bare);
        let report = crate::durable::recover::replay_into(&mut session, &st)?;
        let (subs, upds) = st.final_regions();
        let wal = crate::durable::Wal::open(&cfg)?;
        session.attach_wal(crate::durable::SessionWal::with_regions(wal, d, subs, upds));
        session.checkpoint_now();
        Ok((session, report))
    }
}

impl Default for DdmEngine {
    fn default() -> Self {
        EngineBuilder::new().build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::region::random_regions_1d;
    use crate::prng::Rng;

    /// A deliberately naive out-of-tree backend: quadratic loop.
    struct LoopMatcher;

    impl Matcher for LoopMatcher {
        fn name(&self) -> &str {
            "loop"
        }

        fn match_1d(
            &self,
            _ctx: &ExecCtx<'_>,
            subs: &Regions1D,
            upds: &Regions1D,
            sink: &mut dyn MatchSink,
        ) {
            for i in 0..subs.len() {
                for j in 0..upds.len() {
                    if subs.get(i).intersects(&upds.get(j)) {
                        sink.report(i as u32, j as u32);
                    }
                }
            }
        }
    }

    fn workload(seed: u64, n: usize, m: usize) -> (Regions1D, Regions1D) {
        let mut rng = Rng::new(seed);
        let subs = random_regions_1d(&mut rng, n, 500.0, 8.0);
        let upds = random_regions_1d(&mut rng, m, 500.0, 8.0);
        (subs, upds)
    }

    #[test]
    fn every_algo_engine_agrees_with_custom_backend() {
        let (subs, upds) = workload(0xE1, 300, 250);
        let reference = DdmEngine::builder()
            .matcher(Arc::new(LoopMatcher))
            .threads(1)
            .build()
            .pairs_1d(&subs, &upds);
        assert!(!reference.is_empty());
        for algo in Algo::ALL {
            let engine = DdmEngine::builder().algo(algo).threads(3).ncells(64).build();
            assert_eq!(engine.pairs_1d(&subs, &upds), reference, "{}", algo.name());
            assert_eq!(
                engine.count_1d(&subs, &upds),
                reference.len() as u64,
                "{}",
                algo.name()
            );
        }
    }

    #[test]
    fn durable_sessions_recover_to_the_last_committed_epoch() {
        for shards in [1usize, 3] {
            let dir = std::env::temp_dir()
                .join(format!("ddm-engine-wal-{shards}-{}", std::process::id()));
            std::fs::remove_dir_all(&dir).ok();
            let engine = DdmEngine::builder()
                .threads(2)
                .shards(shards)
                .durability(&dir)
                .build();
            let span = Interval::new(0.0, 100.0);
            {
                let mut s = engine.any_session(1, span);
                s.upsert_subscription(0, &[Interval::new(0.0, 10.0)]);
                s.upsert_update(1, &[Interval::new(5.0, 15.0)]);
                s.commit();
                s.upsert_update(2, &[Interval::new(50.0, 60.0)]);
                s.commit();
                assert!(s.wal_stats().is_some());
                assert_eq!(s.wal_error(), None);
            }
            let (mut s, report) = engine.recover_any_session(1, span).expect("recover");
            assert_eq!(report.epoch, 2, "shards={shards}");
            assert_eq!(s.epoch(), 2, "shards={shards}");
            assert_eq!(report.batches, 2);
            assert!(s.contains_pair(0, 1));
            assert!(!s.contains_pair(0, 2));
            // The recovered session keeps logging: one more commit
            // lands at epoch 3 and is itself recoverable.
            s.remove_subscription(0);
            s.commit();
            drop(s);
            let (s2, r2) = engine.recover_any_session(1, span).expect("re-recover");
            assert_eq!((r2.epoch, s2.epoch()), (3, 3));
            assert!(!s2.contains_pair(0, 1));
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn recover_without_durability_is_an_error() {
        let engine = DdmEngine::builder().threads(1).build();
        assert!(engine.recover_session(1).is_err());
    }

    #[test]
    fn auto_engine_matches_fixed() {
        let engine = DdmEngine::builder().auto().threads(2).build();
        assert_eq!(engine.algo_name(), "auto");

        // Tiny workload: auto picks BFM.
        let (s_small, u_small) = workload(0xE2, 20, 20);
        assert_eq!(engine.matcher_for(20, 20).name(), "bfm");

        // Large workload on 2 threads: auto picks Parallel SBM.
        let (s_big, u_big) = workload(0xE3, 600, 600);
        assert_eq!(engine.matcher_for(600, 600).name(), "psbm");
        // And one worker falls back to serial SBM.
        assert_eq!(engine.with_threads(1).matcher_for(600, 600).name(), "sbm");

        let fixed = DdmEngine::builder().algo(Algo::Bfm).threads(1).build();
        assert_eq!(engine.pairs_1d(&s_small, &u_small), fixed.pairs_1d(&s_small, &u_small));
        assert_eq!(engine.pairs_1d(&s_big, &u_big), fixed.pairs_1d(&s_big, &u_big));
    }

    #[test]
    fn nd_paths_agree_with_direct_check() {
        let mut rng = Rng::new(0xE4);
        let d = 3;
        let mut subs = RegionsNd::new(d);
        let mut upds = RegionsNd::new(d);
        for _ in 0..120 {
            let rect: Vec<Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 80.0);
                    Interval::new(lo, lo + rng.uniform(0.0, 12.0))
                })
                .collect();
            subs.push(&rect);
        }
        for _ in 0..100 {
            let rect: Vec<Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 80.0);
                    Interval::new(lo, lo + rng.uniform(0.0, 12.0))
                })
                .collect();
            upds.push(&rect);
        }
        let mut want = Vec::new();
        for i in 0..subs.len() {
            for j in 0..upds.len() {
                if subs.rects_intersect(i, &upds, j) {
                    want.push((i as u32, j as u32));
                }
            }
        }
        for algo in [Algo::Psbm, Algo::Itm, Algo::Gbm] {
            let engine = DdmEngine::builder().algo(algo).threads(2).ncells(32).build();
            assert_eq!(engine.pairs_nd(&subs, &upds), want, "{}", algo.name());
            assert_eq!(engine.count_nd(&subs, &upds), want.len() as u64);
        }
    }

    #[test]
    fn shared_pool_and_thread_sweep() {
        let pool = Arc::new(ThreadPool::new(7));
        let base = DdmEngine::builder()
            .algo(Algo::Psbm)
            .threads(1)
            .pool(Arc::clone(&pool))
            .build();
        let (subs, upds) = workload(0xE5, 400, 400);
        let want = base.pairs_1d(&subs, &upds);
        for p in 2..=8 {
            assert_eq!(base.with_threads(p).pairs_1d(&subs, &upds), want, "p={p}");
        }
    }

    #[test]
    #[should_panic(expected = "engine wants")]
    fn oversubscribed_shared_pool_panics_at_build() {
        let pool = Arc::new(ThreadPool::new(0));
        let _ = DdmEngine::builder().threads(4).pool(pool).build();
    }

    #[test]
    fn rebuild_dynamic_tracks_brute_force() {
        // A custom backend gets the rebuild-on-write adapter from
        // `dynamic()` (in-tree algorithms get the interval tree).
        let engine = DdmEngine::builder()
            .matcher(Arc::new(LoopMatcher))
            .threads(2)
            .build();
        let mut index = engine.dynamic();
        assert!(index.is_empty());
        let mut rng = Rng::new(0xE6);
        let mut model: BTreeMap<u32, Interval> = BTreeMap::new();
        for step in 0..200u32 {
            let key = rng.below(40) as u32;
            match rng.below(3) {
                0 => {
                    let lo = rng.uniform(0.0, 90.0);
                    let iv = Interval::new(lo, lo + rng.uniform(0.0, 10.0));
                    index.insert(key, iv);
                    model.insert(key, iv);
                }
                1 => {
                    if model.contains_key(&key) {
                        let lo = rng.uniform(0.0, 90.0);
                        let iv = Interval::new(lo, lo + rng.uniform(0.0, 10.0));
                        index.modify(key, iv);
                        model.insert(key, iv);
                    }
                }
                _ => {
                    index.remove(key);
                    model.remove(&key);
                }
            }
            let lo = rng.uniform(0.0, 95.0);
            let q = Interval::new(lo, lo + 5.0);
            let mut got = Vec::new();
            index.query(&engine.ctx(), q, &mut got);
            let want: Vec<u32> = model
                .iter()
                .filter(|(_, iv)| iv.intersects(&q))
                .map(|(&k, _)| k)
                .collect();
            assert_eq!(got, want, "step {step}");
            assert_eq!(index.len(), model.len());
        }
    }

    #[test]
    fn builder_session_knobs_flow_through() {
        use crate::sets::SetImpl;
        let e = DdmEngine::builder()
            .threads(2)
            .session_set_impl(SetImpl::Bit)
            .batch_threshold(7)
            .parallel_cutoff(3)
            .ingest_backlog(128)
            .build();
        let p = e.session_params();
        assert_eq!(p.set_impl, SetImpl::Bit);
        assert_eq!(p.batch_threshold, 7);
        assert_eq!(p.parallel_cutoff, 3);
        assert_eq!(p.ingest_backlog, 128);
        let s = e.session(3);
        assert_eq!(s.d(), 3);
        assert_eq!(s.params().ingest_backlog, 128);
        assert_eq!(s.epoch(), 0);
        assert_eq!(s.pending_ops(), 0);
    }

    #[test]
    fn builder_shard_knobs_flow_through() {
        use crate::shard::ShardStrategy;
        let e = DdmEngine::builder()
            .threads(2)
            .shards(6)
            .split_dim(1)
            .balanced_shards()
            .build();
        let p = e.shard_params();
        assert_eq!(p.shards, 6);
        assert_eq!(p.split_dim, 1);
        assert_eq!(p.strategy, ShardStrategy::Balanced);
        assert!(e.algo_name().starts_with("sharded("), "{}", e.algo_name());
        // split_dim clamps to d - 1 for a 1-d session.
        let s = e.sharded_session(1, Interval::new(0.0, 10.0));
        assert_eq!(s.partitioner().split_dim(), 0);
        assert_eq!(s.shards(), 6);
        // shards(1) leaves the matcher unwrapped and sessions plain.
        let plain = DdmEngine::builder().algo(Algo::Itm).threads(1).shards(1).build();
        assert_eq!(plain.algo_name(), "itm");
        assert_eq!(plain.shard_params(), &ShardParams::default());
    }

    #[test]
    fn builder_nd_knobs_flow_through_and_modes_agree() {
        let native = DdmEngine::builder().algo(Algo::Psbm).threads(2).build();
        assert_eq!(native.params().nd.mode, NdMode::Native);
        assert_eq!(native.params().nd.sweep, SweepDim::Auto);
        let reduce = DdmEngine::builder()
            .algo(Algo::Psbm)
            .threads(2)
            .nd_mode(NdMode::Reduction)
            .build();
        assert_eq!(reduce.params().nd.mode, NdMode::Reduction);
        let pinned = DdmEngine::builder()
            .algo(Algo::Psbm)
            .threads(2)
            .sweep_dim(SweepDim::Fixed(1))
            .build();
        assert_eq!(pinned.params().nd.sweep, SweepDim::Fixed(1));

        let mut rng = Rng::new(0xE7);
        let d = 3;
        let mut subs = RegionsNd::new(d);
        let mut upds = RegionsNd::new(d);
        for _ in 0..150 {
            let rect: Vec<Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 60.0);
                    Interval::new(lo, lo + rng.uniform(0.0, 10.0))
                })
                .collect();
            subs.push(&rect);
            let rect: Vec<Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 60.0);
                    Interval::new(lo, lo + rng.uniform(0.0, 10.0))
                })
                .collect();
            upds.push(&rect);
        }
        let want = reduce.pairs_nd(&subs, &upds);
        assert!(!want.is_empty());
        assert_eq!(native.pairs_nd(&subs, &upds), want);
        assert_eq!(native.count_nd(&subs, &upds), want.len() as u64);
        assert_eq!(pinned.pairs_nd(&subs, &upds), want);
        assert_eq!(pinned.count_nd(&subs, &upds), want.len() as u64);
    }

    #[test]
    fn builder_algo_str_parses_auto_and_aliases() {
        let e = DdmEngine::builder().algo_str("interval-tree").unwrap().build();
        assert_eq!(e.algo_name(), "itm");
        let e = DdmEngine::builder().algo_str("AUTO").unwrap().build();
        assert_eq!(e.algo_name(), "auto");
        assert!(EngineBuilder::new().algo_str("frobnicate").is_err());
    }
}

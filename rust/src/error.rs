//! Minimal error type (offline replacement for `anyhow`).
//!
//! Mirrors the subset of `anyhow` this crate uses: a string-backed
//! [`Error`], the crate-wide [`Result`] alias, the [`bail!`] macro and
//! the [`Context`] extension trait for `Result`/`Option`. Like
//! `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion below coherent.
//!
//! [`bail!`]: crate::bail

use std::fmt;

/// A string-backed error with `?`-conversion from any std error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from a message (what [`crate::bail!`] expands to).
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e.to_string())
    }
}

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

/// `anyhow::Context`-style annotation for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn may_bail(fail: bool) -> Result<u32> {
        if fail {
            bail!("failed with code {}", 7);
        }
        Ok(1)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(may_bail(false).unwrap(), 1);
        let e = may_bail(true).unwrap_err();
        assert_eq!(e.to_string(), "failed with code 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while rendering").unwrap_err();
        assert!(e.to_string().starts_with("while rendering:"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }
}

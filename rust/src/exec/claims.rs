//! Claim-checked disjoint writes — the one audited home of the
//! crate's shared-memory write discipline.
//!
//! Every lock-free hot path in this crate (the pool's fan-in slots,
//! the radix/counting-sort scatters, the merge-round outputs of
//! [`super::psort`], PSBM's endpoint build, GBM's CSR cell lists, the
//! interval tree's parallel arena build, the pooled-sink dispenser)
//! rests on the same invariant: **a set of workers writes a shared
//! buffer through disjoint indices, with a fork-join barrier between
//! the writes and any read**. This module packages that invariant
//! behind four small types so the `unsafe` lives in one place:
//!
//! * [`DisjointWriter`] — exclusive-borrow a slice, then let many
//!   workers write disjoint indices ([`write`](DisjointWriter::write))
//!   or claim disjoint subranges ([`claim`](DisjointWriter::claim) →
//!   [`ClaimedSlice`]) concurrently.
//! * [`FanSlots`] — write-once result slots (the fan-in destination).
//! * [`TakeCells`] — take-once input cells (the fan-out source).
//!
//! In a normal build these compile to exactly the raw-pointer stores
//! they replaced: no atomics, no bookkeeping, `#[inline]` wrappers
//! around `ptr::add` (the `abl_sort` radix-vs-merge assert in CI is
//! the regression guard on that). Under `--features race-check` every
//! index additionally carries an atomic **claim word**, and any
//! overlapping write, overlapping range claim, double take, or
//! read-before-write panics with the construction site, the index and
//! the offending thread — turning a silent data race into a
//! deterministic diagnostic. The randomized stress suite
//! (`tests/race_stress.rs`) drives all of the refactored call sites
//! across worker counts and adversarial sizes under that feature.

use std::cell::UnsafeCell;
use std::marker::PhantomData;

#[cfg(feature = "race-check")]
use std::sync::atomic::{AtomicU8, Ordering};

/// Claim-word states (race-check builds only): a slot is free until
/// somebody claims a range over it or writes it.
#[cfg(feature = "race-check")]
const FREE: u8 = 0;
#[cfg(feature = "race-check")]
const CLAIMED: u8 = 1;
#[cfg(feature = "race-check")]
const WRITTEN: u8 = 2;

#[cfg(feature = "race-check")]
fn state_name(s: u8) -> &'static str {
    match s {
        FREE => "free",
        CLAIMED => "claimed by another worker",
        _ => "already written",
    }
}

#[cfg(feature = "race-check")]
fn current_thread() -> String {
    std::thread::current()
        .name()
        .unwrap_or("<unnamed>")
        .to_string()
}

/// Per-index claim table shared by the three wrappers (compiled out
/// entirely in normal builds).
#[cfg(feature = "race-check")]
#[derive(Debug)]
struct Claims {
    site: &'static str,
    words: Vec<AtomicU8>,
}

#[cfg(feature = "race-check")]
impl Claims {
    fn new(site: &'static str, n: usize) -> Self {
        Self {
            site,
            words: (0..n).map(|_| AtomicU8::new(FREE)).collect(),
        }
    }

    /// Transition index `i` from `from` to `to` or panic with a
    /// site/index/thread diagnostic.
    fn transition(&self, i: usize, from: u8, to: u8, action: &str) {
        if let Err(prev) =
            self.words[i].compare_exchange(from, to, Ordering::AcqRel, Ordering::Acquire)
        {
            panic!(
                "race-check: {action} at {}[{i}] by thread '{}' but the slot is {}",
                self.site,
                current_thread(),
                state_name(prev),
            );
        }
    }

    /// Require index `i` to be in state `want` (no transition).
    fn require(&self, i: usize, want: u8, action: &str) {
        let s = self.words[i].load(Ordering::Acquire);
        if s != want {
            panic!(
                "race-check: {action} at {}[{i}] by thread '{}' but the slot is {}",
                self.site,
                current_thread(),
                state_name(s),
            );
        }
    }
}

/// Exclusive borrow of a slice that hands out **disjoint** write
/// access to many workers at once.
///
/// Construction takes `&mut [T]`, so the borrow checker guarantees
/// nobody else can touch the buffer for the writer's lifetime; the
/// caller's obligation (checked under `race-check`) is only that the
/// *workers* stay disjoint: no index is [`write`](Self::write)-ten
/// twice, no [`claim`](Self::claim)-ed ranges overlap, and
/// [`read`](Self::read) only touches indices already written through
/// this writer.
///
/// The fork-join barrier of [`ThreadPool::run`](super::ThreadPool::run)
/// provides the happens-before edge between the parallel writes and
/// the master's subsequent reads, exactly as before this abstraction
/// existed — the writer checks disjointness, not ordering.
#[derive(Debug)]
pub struct DisjointWriter<'a, T> {
    ptr: *mut T,
    len: usize,
    #[cfg(feature = "race-check")]
    claims: Claims,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: the writer only allows writes to disjoint indices (the
// caller's contract, enforced under race-check) with a fork-join
// barrier before reads, so sharing it across workers is sound
// whenever T itself can move between threads.
unsafe impl<T: Send> Sync for DisjointWriter<'_, T> {}
// SAFETY: same argument; the writer is just a pointer + length (+
// atomics under race-check) over data borrowed for 'a.
unsafe impl<T: Send> Send for DisjointWriter<'_, T> {}

impl<'a, T> DisjointWriter<'a, T> {
    /// Wrap `data` for disjoint parallel writing. `site` names the
    /// call site in race-check diagnostics (and costs nothing in
    /// normal builds).
    pub fn new(data: &'a mut [T], site: &'static str) -> Self {
        let ptr = data.as_mut_ptr();
        let len = data.len();
        #[cfg(not(feature = "race-check"))]
        let _ = site;
        Self {
            ptr,
            len,
            #[cfg(feature = "race-check")]
            claims: Claims::new(site, len),
            _marker: PhantomData,
        }
    }

    /// Length of the wrapped slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the wrapped slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `value` into slot `i`.
    ///
    /// # Safety
    /// No other write or claim may touch index `i` for this writer's
    /// lifetime, and `read(i)` may only happen after this write (on
    /// the same thread, or across the region's join barrier). Under
    /// `race-check` a violation panics instead of racing.
    #[inline]
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len, "DisjointWriter::write out of bounds");
        #[cfg(feature = "race-check")]
        self.claims.transition(i, FREE, WRITTEN, "overlapping write");
        // SAFETY: i < len (checked in debug; offsets at every call
        // site partition the buffer), the slot is initialized memory
        // (constructed from &mut [T]) and per the caller's contract no
        // other thread accesses it concurrently.
        unsafe { *self.ptr.add(i) = value };
    }

    /// Read back slot `i` (interval-tree builders read child nodes
    /// their own recursion just wrote).
    ///
    /// # Safety
    /// Index `i` must have been written through this writer, with a
    /// happens-before edge to this read (same thread or past a join
    /// barrier), and no claim may cover it. Under `race-check`,
    /// reading a never-written or currently-claimed slot panics
    /// (read-before-write detection).
    #[inline]
    pub unsafe fn read(&self, i: usize) -> &T {
        debug_assert!(i < self.len, "DisjointWriter::read out of bounds");
        #[cfg(feature = "race-check")]
        self.claims.require(i, WRITTEN, "read-before-write");
        // SAFETY: i < len and the slot was written per the caller's
        // contract; shared reads of a written slot are fine.
        unsafe { &*self.ptr.add(i) }
    }

    /// Claim `range` as an exclusive sub-slice (a worker's private
    /// segment: radix histogram segments, psort chunk sorts and
    /// sub-merge output ranges, scan chunks).
    ///
    /// # Safety
    /// `range` must be in bounds and disjoint from every other claim
    /// and `write` on this writer for the claim's lifetime. Under
    /// `race-check`, overlapping claims panic index-by-index.
    #[inline]
    pub unsafe fn claim(&self, range: std::ops::Range<usize>) -> ClaimedSlice<'_, T> {
        debug_assert!(
            range.start <= range.end && range.end <= self.len,
            "DisjointWriter::claim out of bounds"
        );
        #[cfg(feature = "race-check")]
        for i in range.clone() {
            self.claims.transition(i, FREE, CLAIMED, "overlapping claim");
        }
        ClaimedSlice {
            // SAFETY: in-bounds range (asserted above) of a live
            // buffer; exclusivity is the caller's contract, enforced
            // by the claim words under race-check.
            slice: unsafe {
                std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.len())
            },
            #[cfg(feature = "race-check")]
            claims: &self.claims,
            #[cfg(feature = "race-check")]
            range,
        }
    }
}

/// An exclusively claimed subrange of a [`DisjointWriter`], usable as
/// a plain `&mut [T]`. Dropping it (race-check builds) marks the
/// range written, so post-barrier [`DisjointWriter::read`]s of it are
/// legal.
#[derive(Debug)]
pub struct ClaimedSlice<'w, T> {
    slice: &'w mut [T],
    #[cfg(feature = "race-check")]
    claims: &'w Claims,
    #[cfg(feature = "race-check")]
    range: std::ops::Range<usize>,
}

impl<T> std::ops::Deref for ClaimedSlice<'_, T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        self.slice
    }
}

impl<T> std::ops::DerefMut for ClaimedSlice<'_, T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut [T] {
        self.slice
    }
}

#[cfg(feature = "race-check")]
impl<T> Drop for ClaimedSlice<'_, T> {
    fn drop(&mut self) {
        for i in self.range.clone() {
            self.claims.transition(i, CLAIMED, WRITTEN, "claim release");
        }
    }
}

/// Write-once result slots: the fan-in destination of
/// [`ThreadPool::fan_map`](super::ThreadPool::fan_map). Slot `i` is
/// written by exactly the worker the work cursor handed index `i`;
/// the pool reads everything back after the join barrier.
#[derive(Debug)]
pub struct FanSlots<T> {
    slots: Vec<UnsafeCell<Option<T>>>,
    #[cfg(feature = "race-check")]
    claims: Claims,
}

// SAFETY: each slot is written by exactly one worker (the one that
// claimed its index — the documented contract of `put`, enforced
// under race-check) and only read after the region's join barrier.
unsafe impl<T: Send> Sync for FanSlots<T> {}

impl<T> FanSlots<T> {
    /// `n` empty slots; `site` names race-check diagnostics.
    pub fn new(n: usize, site: &'static str) -> Self {
        #[cfg(not(feature = "race-check"))]
        let _ = site;
        Self {
            slots: (0..n).map(|_| UnsafeCell::new(None)).collect(),
            #[cfg(feature = "race-check")]
            claims: Claims::new(site, n),
        }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether there are no slots.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Fill slot `i`.
    ///
    /// # Safety
    /// Each index must be filled at most once, by one thread, with no
    /// concurrent `put` on the same index (disjoint-index fan-in);
    /// race-check builds panic on a double put.
    #[inline]
    pub unsafe fn put(&self, i: usize, value: T) {
        #[cfg(feature = "race-check")]
        self.claims.transition(i, FREE, WRITTEN, "overlapping put");
        // SAFETY: slot i belongs to this caller alone per the
        // contract; the UnsafeCell write is unaliased.
        unsafe { *self.slots[i].get() = Some(value) };
    }

    /// Consume the slots in index order (after the join barrier).
    /// Unfilled slots yield `None`.
    pub fn into_values(self) -> impl Iterator<Item = Option<T>> {
        self.slots.into_iter().map(|c| c.into_inner())
    }
}

/// Take-once input cells: the fan-out source of
/// [`ThreadPool::fan_map_take`](super::ThreadPool::fan_map_take) and
/// the pooled-sink dispenser
/// ([`SinkDispenser`](crate::core::scratch::SinkDispenser)). Item `i`
/// is moved out by exactly one caller.
#[derive(Debug)]
pub struct TakeCells<T> {
    cells: Vec<UnsafeCell<Option<T>>>,
    #[cfg(feature = "race-check")]
    claims: Claims,
}

// SAFETY: each cell is taken by exactly one caller (the contract of
// `take`, enforced under race-check), so the cells never see
// concurrent access.
unsafe impl<T: Send> Sync for TakeCells<T> {}

impl<T> TakeCells<T> {
    /// Wrap `items` as take-once cells; `site` names race-check
    /// diagnostics.
    pub fn new(items: Vec<T>, site: &'static str) -> Self {
        #[cfg(not(feature = "race-check"))]
        let _ = site;
        #[cfg(feature = "race-check")]
        let claims = Claims::new(site, items.len());
        Self {
            cells: items.into_iter().map(|i| UnsafeCell::new(Some(i))).collect(),
            #[cfg(feature = "race-check")]
            claims,
        }
    }

    /// Number of cells (taken or not).
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// Whether there are no cells.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Move item `i` out. Panics on a double take (always — the
    /// `Option` is the release-mode backstop; race-check builds panic
    /// with the site/thread diagnostic even when the double take is
    /// concurrent rather than sequential).
    ///
    /// # Safety
    /// Each index must be taken at most once, by one thread; no
    /// concurrent `take` on the same index.
    #[inline]
    pub unsafe fn take(&self, i: usize) -> T {
        #[cfg(feature = "race-check")]
        self.claims.transition(i, FREE, WRITTEN, "double take");
        // SAFETY: cell i belongs to this caller alone per the
        // contract; the UnsafeCell access is unaliased.
        let v = unsafe { (*self.cells[i].get()).take() };
        match v {
            Some(v) => v,
            None => panic!("claims::TakeCells: cell {i} taken twice"),
        }
    }

    /// Recover every untaken item (after the join barrier) — the
    /// dispenser returns unclaimed pooled sinks this way.
    pub fn into_remaining(self) -> impl Iterator<Item = T> {
        self.cells.into_iter().filter_map(|c| c.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::pool::scoped_region;

    #[test]
    fn disjoint_writes_land_in_order() {
        let mut buf = vec![0u32; 1000];
        {
            let w = DisjointWriter::new(&mut buf, "test::writes");
            scoped_region(4, |p| {
                for i in (p..1000).step_by(4) {
                    // SAFETY: indices are partitioned by residue class.
                    unsafe { w.write(i, i as u32) };
                }
            });
        }
        assert!(buf.iter().enumerate().all(|(i, &v)| v == i as u32));
    }

    #[test]
    fn claimed_ranges_act_as_slices() {
        let mut buf = vec![0u8; 97];
        {
            let w = DisjointWriter::new(&mut buf, "test::claims");
            let bounds = crate::exec::pfor::chunks(97, 5);
            let bounds = &bounds;
            scoped_region(5, |p| {
                // SAFETY: chunks partition 0..97 disjointly.
                let mut seg = unsafe { w.claim(bounds[p].clone()) };
                for x in seg.iter_mut() {
                    *x = p as u8 + 1;
                }
            });
        }
        assert!(buf.iter().all(|&v| v != 0));
    }

    #[test]
    fn read_after_write_sees_the_value() {
        let mut buf = vec![0u64; 8];
        let w = DisjointWriter::new(&mut buf, "test::read");
        // SAFETY: single-threaded write-then-read of one index.
        unsafe {
            w.write(3, 42);
            assert_eq!(*w.read(3), 42);
        }
    }

    #[test]
    fn fan_slots_round_trip() {
        let slots = FanSlots::new(10, "test::fan");
        scoped_region(3, |p| {
            for i in (p..10).step_by(3) {
                // SAFETY: indices partitioned by residue class.
                unsafe { slots.put(i, i * 2) };
            }
        });
        let got: Vec<usize> = slots.into_values().map(|v| v.expect("filled")).collect();
        assert_eq!(got, (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn take_cells_move_each_item_once() {
        let cells = TakeCells::new((0..20).map(|i| format!("item-{i}")).collect(), "test::take");
        let taken = std::sync::Mutex::new(Vec::new());
        scoped_region(4, |p| {
            for i in (p..16).step_by(4) {
                // SAFETY: indices partitioned by residue class.
                let v = unsafe { cells.take(i) };
                taken.lock().unwrap().push(v);
            }
        });
        assert_eq!(taken.lock().unwrap().len(), 16);
        let rest: Vec<String> = cells.into_remaining().collect();
        assert_eq!(rest.len(), 4, "untaken items recovered");
    }

    /// The `Option` backstop catches a *sequential* double take even
    /// without the race-check claim words (which would panic first,
    /// with a different message — hence the cfg).
    #[test]
    #[cfg(not(feature = "race-check"))]
    #[should_panic(expected = "taken twice")]
    fn sequential_double_take_panics_even_in_release() {
        let cells = TakeCells::new(vec![1u8], "test::double");
        // SAFETY: single-threaded; the second take is the deliberate
        // contract violation under test.
        unsafe {
            let _a = cells.take(0);
            let _b = cells.take(0);
        }
    }

    /// The claim checker itself: these contract violations are
    /// deterministic panics under `--features race-check` (and UB-free
    /// only because the checked build never performs the second
    /// access).
    #[cfg(feature = "race-check")]
    mod race_check {
        use super::super::*;

        #[test]
        #[should_panic(expected = "overlapping write")]
        fn overlapping_write_is_caught() {
            let mut buf = vec![0u32; 4];
            let w = DisjointWriter::new(&mut buf, "race::write");
            // SAFETY: the second write is the violation under test;
            // race-check panics before any aliased store happens.
            unsafe {
                w.write(2, 7);
                w.write(2, 8);
            }
        }

        #[test]
        #[should_panic(expected = "overlapping claim")]
        fn overlapping_claim_is_caught() {
            let mut buf = vec![0u32; 10];
            let w = DisjointWriter::new(&mut buf, "race::claim");
            // SAFETY: overlap is the violation under test; race-check
            // panics before the second slice exists.
            unsafe {
                let _a = w.claim(0..6);
                let _b = w.claim(5..10);
            }
        }

        #[test]
        #[should_panic(expected = "read-before-write")]
        fn read_before_write_is_caught() {
            let mut buf = vec![0u32; 4];
            let w = DisjointWriter::new(&mut buf, "race::read");
            // SAFETY: reading an unwritten slot is the violation under
            // test; race-check panics before the read.
            unsafe {
                let _ = w.read(1);
            }
        }

        #[test]
        #[should_panic(expected = "overlapping write")]
        fn write_into_claimed_range_is_caught() {
            let mut buf = vec![0u32; 8];
            let w = DisjointWriter::new(&mut buf, "race::mixed");
            // SAFETY: the write under an active claim is the violation
            // under test; race-check panics before the store.
            unsafe {
                let _seg = w.claim(2..6);
                w.write(3, 1);
            }
        }

        #[test]
        fn released_claim_allows_reads() {
            let mut buf = vec![0u32; 8];
            let w = DisjointWriter::new(&mut buf, "race::release");
            // SAFETY: claim, fill, drop, then read — the legal order.
            unsafe {
                {
                    let mut seg = w.claim(0..8);
                    for (i, x) in seg.iter_mut().enumerate() {
                        *x = i as u32;
                    }
                }
                assert_eq!(*w.read(5), 5);
            }
        }

        #[test]
        #[should_panic(expected = "overlapping put")]
        fn fan_slot_double_put_is_caught() {
            let slots = FanSlots::new(3, "race::put");
            // SAFETY: the double put is the violation under test.
            unsafe {
                slots.put(1, 10);
                slots.put(1, 11);
            }
        }

        #[test]
        #[should_panic(expected = "double take")]
        fn cell_double_take_is_caught() {
            let cells = TakeCells::new(vec![5u8, 6], "race::take");
            // SAFETY: the double take is the violation under test.
            unsafe {
                let _a = cells.take(1);
                let _b = cells.take(1);
            }
        }
    }
}

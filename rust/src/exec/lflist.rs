//! Lock-free append-only list (paper §5's "ad-hoc linked list").
//!
//! The paper's GBM phase 1 has a data race on the per-cell region
//! lists; the authors compared an OpenMP `critical` section against an
//! ad-hoc lock-free list and found no significant difference. We keep
//! both options in Rust (`Mutex<Vec>` vs this Treiber-style list) and
//! re-run that experiment in `benches/abl_gbm_list.rs`.

use std::ptr;
use std::sync::atomic::{AtomicPtr, Ordering};

struct Node<T> {
    value: T,
    next: *mut Node<T>,
}

/// A concurrent append-only singly-linked list. `push` is lock-free;
/// iteration requires external quiescence (all pushes completed), which
/// GBM guarantees with the barrier between its two phases.
pub struct LfList<T> {
    head: AtomicPtr<Node<T>>,
}

impl<T> LfList<T> {
    /// Empty list; allocation happens per-push.
    pub fn new() -> Self {
        Self {
            head: AtomicPtr::new(ptr::null_mut()),
        }
    }

    /// Lock-free prepend (LIFO order; order is irrelevant for GBM cells).
    pub fn push(&self, value: T) {
        let node = Box::into_raw(Box::new(Node {
            value,
            next: ptr::null_mut(),
        }));
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            // SAFETY: `node` is fresh and owned until the CAS succeeds.
            unsafe { (*node).next = head };
            match self.head.compare_exchange_weak(
                head,
                node,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(h) => head = h,
            }
        }
    }

    /// Iterate the list. Callers must ensure no concurrent `push`
    /// (quiescent point), which the GBM phase barrier provides.
    pub fn iter(&self) -> Iter<'_, T> {
        Iter {
            cur: self.head.load(Ordering::Acquire),
            _marker: std::marker::PhantomData,
        }
    }

    /// True if nothing has been pushed (quiescent callers only).
    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Acquire).is_null()
    }

    /// Number of elements — O(n) walk; quiescent callers only.
    pub fn len(&self) -> usize {
        self.iter().count()
    }
}

impl<T> Default for LfList<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Drop for LfList<T> {
    fn drop(&mut self) {
        let mut cur = *self.head.get_mut();
        while !cur.is_null() {
            // SAFETY: exclusive access in Drop; nodes were Box-allocated.
            let boxed = unsafe { Box::from_raw(cur) };
            cur = boxed.next;
        }
    }
}

/// Borrowing iterator over an [`LfList`] at a quiescent point (see
/// [`LfList::iter`]).
pub struct Iter<'a, T> {
    cur: *const Node<T>,
    _marker: std::marker::PhantomData<&'a T>,
}

impl<'a, T> Iterator for Iter<'a, T> {
    type Item = &'a T;
    fn next(&mut self) -> Option<&'a T> {
        if self.cur.is_null() {
            None
        } else {
            // SAFETY: nodes are immutable after insertion and live as
            // long as the list; quiescence guaranteed by caller.
            let node = unsafe { &*self.cur };
            self.cur = node.next;
            Some(&node.value)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::pool::scoped_region;

    #[test]
    fn single_thread_push_iter() {
        let l = LfList::new();
        assert!(l.is_empty());
        for i in 0..100 {
            l.push(i);
        }
        let mut got: Vec<i32> = l.iter().copied().collect();
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
        assert_eq!(l.len(), 100);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn concurrent_pushes_lose_nothing() {
        let l = LfList::new();
        let per = 10_000u32;
        let threads = 8u32;
        scoped_region(threads as usize, |p| {
            for i in 0..per {
                l.push(p as u32 * per + i);
            }
        });
        let mut got: Vec<u32> = l.iter().copied().collect();
        assert_eq!(got.len(), (per * threads) as usize);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), (per * threads) as usize, "duplicates or loss");
    }

    #[test]
    fn drop_releases_all_nodes() {
        // Mostly a miri/asan-style check; here it just must not crash.
        let l = LfList::new();
        for i in 0..10_000 {
            l.push(vec![i; 4]);
        }
        drop(l);
    }
}

//! The shared-memory parallel runtime (the paper's OpenMP substrate).
//!
//! The paper parallelizes its algorithms with OpenMP `parallel for`
//! regions, parallel STL sorts and a hand-rolled two-level prefix scan
//! (Fig. 7). This module provides the equivalent building blocks in
//! std-only Rust:
//!
//! * [`pool::ThreadPool`] — persistent worker pool with fork-join
//!   parallel regions (`#pragma omp parallel`), including per-worker
//!   busy-time measurement used by the speedup model.
//! * [`pfor`] — static and dynamic loop scheduling
//!   (`#pragma omp for schedule(static|dynamic)`).
//! * [`psort`] — parallel merge sort (the `-D_GLIBCXX_PARALLEL`
//!   `std::sort` replacement).
//! * [`radix`] — parallel LSD radix sort on compact `u64` keys (the
//!   sort-phase hot path of SBM/PSBM; `psort` stays as the
//!   property-tested comparison fallback).
//! * [`scan`] — sequential and two-level parallel prefix scans
//!   (paper Fig. 7 / Algorithm 7 master step).
//! * [`lflist`] — a lock-free append-only list (the paper's §5 ad-hoc
//!   GBM cell list experiment).
//! * [`claims`] — claim-checked disjoint writes: the audited wrappers
//!   every lock-free fan-in/scatter seam above writes through, with a
//!   `race-check` feature that turns contract violations into
//!   deterministic panics. All raw-pointer sharing across parallel
//!   regions goes through this module — there is no bare `SendPtr`
//!   escape hatch anymore.

pub mod claims;
pub mod lflist;
pub mod pfor;
pub mod pool;
pub mod psort;
pub mod radix;
pub mod scan;

pub use claims::{ClaimedSlice, DisjointWriter, FanSlots, TakeCells};
pub use pool::ThreadPool;
pub use radix::{RadixScratch, SortAlgo};

/// Total order for `f64` keys (sign-magnitude flip). NaNs sort above
/// +inf; workload code never produces them, but the order stays total.
#[inline]
pub fn f64_key(x: f64) -> u64 {
    let bits = x.to_bits();
    if bits & 0x8000_0000_0000_0000 != 0 {
        !bits
    } else {
        bits | 0x8000_0000_0000_0000
    }
}

#[cfg(test)]
mod tests {
    use super::f64_key;

    #[test]
    fn f64_key_is_monotone() {
        let xs = [
            f64::NEG_INFINITY,
            -1.0e30,
            -2.5,
            -0.0,
            0.0,
            1.0e-300,
            1.0,
            3.5,
            f64::INFINITY,
        ];
        for w in xs.windows(2) {
            assert!(f64_key(w[0]) <= f64_key(w[1]), "{} vs {}", w[0], w[1]);
        }
        // -0.0 and 0.0 compare equal in IEEE; keys may differ but must
        // preserve <= ordering, checked above. Distinct values strict:
        assert!(f64_key(-2.5) < f64_key(-1.0));
        assert!(f64_key(1.0) < f64_key(2.0));
    }
}

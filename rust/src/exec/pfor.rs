//! Loop scheduling on top of [`super::pool::ThreadPool`] — the
//! `#pragma omp for` replacements.
//!
//! * [`chunks`] — static schedule: `0..n` is split into `P` contiguous
//!   chunks (what the paper's BFM/ITM/GBM parallelizations use).
//! * [`parallel_for_static`] — static schedule driving a per-index body.
//! * [`parallel_for_dynamic`] — dynamic schedule with a shared atomic
//!   cursor (`schedule(dynamic, chunk)`), useful when per-item work is
//!   skewed (e.g. ITM queries with different K_u).

use std::ops::Range;
use std::time::Duration;

use super::pool::{ThreadPool, WorkCounter};

/// Split `0..n` into `p` near-equal contiguous chunks.
/// The first `n % p` chunks get one extra element (OpenMP static).
pub fn chunks(n: usize, p: usize) -> Vec<Range<usize>> {
    assert!(p >= 1);
    let base = n / p;
    let extra = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Static-schedule parallel for: `body(p, range_p)` once per worker.
/// Returns per-worker busy times.
pub fn parallel_for_static<F>(
    pool: &ThreadPool,
    nthreads: usize,
    n: usize,
    body: F,
) -> Vec<Duration>
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let ranges = chunks(n, nthreads);
    pool.run(nthreads, |p| body(p, ranges[p].clone()))
}

/// Dynamic-schedule parallel for: workers repeatedly grab `chunk`-sized
/// ranges from a shared cursor and call `body(p, range)`.
pub fn parallel_for_dynamic<F>(
    pool: &ThreadPool,
    nthreads: usize,
    n: usize,
    chunk: usize,
    body: F,
) -> Vec<Duration>
where
    F: Fn(usize, Range<usize>) + Sync,
{
    assert!(chunk >= 1);
    let cursor = WorkCounter::new();
    pool.run(nthreads, |p| {
        while let Some(r) = cursor.next_chunk(chunk, n) {
            body(p, r);
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn chunks_cover_and_partition() {
        for n in [0usize, 1, 7, 100, 101, 1023] {
            for p in [1usize, 2, 3, 8, 32] {
                let cs = chunks(n, p);
                assert_eq!(cs.len(), p);
                let mut next = 0;
                for c in &cs {
                    assert_eq!(c.start, next);
                    next = c.end;
                }
                assert_eq!(next, n);
                let lens: Vec<usize> = cs.iter().map(|c| c.len()).collect();
                let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(mx - mn <= 1, "balanced chunks: {lens:?}");
            }
        }
    }

    #[test]
    fn static_for_touches_each_index_once() {
        let pool = ThreadPool::new(3);
        let n = 1000;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        parallel_for_static(&pool, 4, n, |_, r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_for_touches_each_index_once() {
        let pool = ThreadPool::new(3);
        let n = 1003;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        parallel_for_dynamic(&pool, 4, n, 17, |_, r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_loop_is_fine() {
        let pool = ThreadPool::new(2);
        parallel_for_static(&pool, 3, 0, |_, r| assert!(r.is_empty()));
        parallel_for_dynamic(&pool, 3, 0, 8, |_, _| panic!("no work expected"));
    }
}

//! Persistent worker pool with OpenMP-style fork-join parallel regions.
//!
//! `ThreadPool::run(P, |p| ...)` executes the closure on `P` logical
//! workers (worker 0 runs on the calling thread, like an OpenMP master)
//! and blocks until all complete — the moral equivalent of
//! `#pragma omp parallel num_threads(P)`.
//!
//! The pool also measures each worker's busy time. On this single-core
//! reproduction testbed the busy times feed the work-span speedup model
//! (DESIGN.md §3.1): wall-clock under oversubscription is meaningless,
//! but `max_p busy_p` is exactly the quantity a P-core machine's
//! wall-clock would track.

// xlint: allow-file(hot-lock): the pool's Mutex/Condvar are its
// control plane (join barrier, cost log) — taken once per region or
// per bench, never inside a parallel region's per-element work. The
// per-worker busy-time slots that used to be Mutexes now go through
// the claims layer.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use super::claims::{DisjointWriter, FanSlots, TakeCells};
use crate::bench::speedup::CostLog;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Lock a control-plane mutex, recovering from poisoning: a worker
/// that panicked mid-region must not wedge every later region (the
/// guarded state — join counters, cost logs, busy times — stays
/// internally consistent even across a poisoned panic).
fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// CPU time consumed by the calling thread (CLOCK_THREAD_CPUTIME_ID).
///
/// Unlike `Instant`, this is immune to preemption: on an oversubscribed
/// host a P-thread region still reports each worker's true compute
/// cost, which is what the speedup model needs (DESIGN.md §3).
///
/// The `clock_gettime` symbol is declared locally (std already links
/// libc) so the crate stays dependency-free. The hand-rolled timespec
/// layout matches 64-bit Linux only, so other targets (including
/// 32-bit Linux, whose timespec is two 32-bit words) fall back to
/// zero. On those hosts the work-span *modeled* WCT collapses to the
/// fork-join term and is meaningless — read the measured wall-clock
/// column of bench output instead.
#[cfg(all(target_os = "linux", target_pointer_width = "64", not(miri)))]
pub fn thread_cpu_time() -> Duration {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    extern "C" {
        fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    let mut ts = Timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    // SAFETY: ts is a valid out-pointer; the clock id is a constant.
    let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    if rc == 0 {
        Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
    } else {
        Duration::ZERO
    }
}

/// Fallback for non-Linux targets and Miri (whose interpreter has no
/// foreign-function `clock_gettime`): busy times collapse to zero and
/// the modeled WCT is meaningless, but everything still runs.
#[cfg(not(all(target_os = "linux", target_pointer_width = "64", not(miri))))]
pub fn thread_cpu_time() -> Duration {
    Duration::ZERO
}

struct Shared {
    pending: Mutex<usize>,
    all_done: Condvar,
}

/// A persistent pool of `capacity` background workers (plus the caller,
/// which acts as worker 0 of every region).
pub struct ThreadPool {
    senders: Vec<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    shared: Arc<Shared>,
    /// Optional cost log (per-region busy times + serial CPU time)
    /// consumed by the work-span speedup model.
    log: Mutex<Option<CostLog>>,
}

impl ThreadPool {
    /// Pool able to serve regions with up to `capacity + 1` workers.
    pub fn new(capacity: usize) -> Self {
        let shared = Arc::new(Shared {
            pending: Mutex::new(0),
            all_done: Condvar::new(),
        });
        let mut senders = Vec::with_capacity(capacity);
        let mut handles = Vec::with_capacity(capacity);
        for i in 0..capacity {
            let (tx, rx) = mpsc::channel::<Job>();
            let shared2 = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("ddm-worker-{}", i + 1))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        job();
                        let mut pending = lock_ok(&shared2.pending);
                        *pending -= 1;
                        if *pending == 0 {
                            shared2.all_done.notify_all();
                        }
                    }
                })
                // xlint: allow(hot-panic): construction-time resource
                // exhaustion, not a per-element hot path.
                .expect("spawn pool worker");
            senders.push(tx);
            handles.push(h);
        }
        Self {
            senders,
            handles,
            shared,
            log: Mutex::new(None),
        }
    }

    /// Number of workers a region can use (background + caller).
    pub fn max_threads(&self) -> usize {
        self.senders.len() + 1
    }

    /// Start recording region costs (resets any previous log).
    pub fn start_log(&self) {
        *lock_ok(&self.log) = Some(CostLog::default());
    }

    /// Stop recording and return the accumulated log.
    pub fn take_log(&self) -> CostLog {
        lock_ok(&self.log).take().unwrap_or_default()
    }

    /// Record master-only (serial) CPU time; algorithms call this
    /// around their sequential sections (e.g. Algorithm 7 lines 18–21).
    pub fn log_serial(&self, d: Duration) {
        if let Some(log) = lock_ok(&self.log).as_mut() {
            log.serial += d;
        }
    }

    /// Run a master-only section, logging its CPU time when enabled.
    pub fn serial_section<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = thread_cpu_time();
        let out = f();
        self.log_serial(thread_cpu_time().saturating_sub(t0));
        out
    }

    /// Work-stealing fork-join map: evaluate `f(i)` for `i in 0..n`
    /// across up to `workers` pool workers (atomic-cursor dynamic
    /// scheduling) and collect the results in index order. The shared
    /// helper behind the shard fan-outs
    /// ([`crate::shard::ShardedSession`], [`crate::shard::ShardedMatcher`]),
    /// the per-worker sink collection of the parallel matchers
    /// ([`crate::algos::par_collect`]) and the session recompute phase.
    ///
    /// The result slots are write-once [`FanSlots`] (the claims
    /// layer), not locks: the cursor hands each index to exactly one
    /// worker, so slot writes never alias and the hot path carries no
    /// lock at all. Slot order is deterministic by construction
    /// regardless of which worker claims which index — and under
    /// `--features race-check` an aliased write panics instead of
    /// racing.
    pub fn fan_map<T, F>(&self, workers: usize, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let slots: FanSlots<T> = FanSlots::new(n, "pool::fan_map");
        let cursor = AtomicUsize::new(0);
        self.run(workers.min(n.max(1)).max(1), |_p| loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let out = f(i);
            // SAFETY: index i is claimed exactly once (fetch_add), and
            // `run` joins every worker before the slots are read back,
            // so this put is unaliased and happens-before the reads.
            unsafe { slots.put(i, out) };
        });
        slots
            .into_values()
            // xlint: allow(hot-panic): post-join invariant — the
            // cursor covered 0..n, so every slot is filled.
            .map(|c| c.expect("fan_map slot filled"))
            .collect()
    }

    /// [`fan_map`](Self::fan_map) over **owned** inputs: item `i` is
    /// moved into the worker that claims index `i` (take-once
    /// [`TakeCells`] — no clone, no `Mutex<Option<_>>::take`
    /// hand-off). Used by Parallel SBM to move each segment's
    /// initialized active sets into its phase-3 sweep.
    pub fn fan_map_take<I, T, F>(&self, workers: usize, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send,
        T: Send,
        F: Fn(usize, I) -> T + Sync,
    {
        let n = items.len();
        let cells: TakeCells<I> = TakeCells::new(items, "pool::fan_map_take");
        let cells = &cells;
        self.fan_map(workers, n, |i| {
            // SAFETY: index i is claimed exactly once by the fan_map
            // cursor; no other worker touches this cell.
            let item = unsafe { cells.take(i) };
            f(i, item)
        })
    }

    /// Fork-join parallel region: run `f(p)` for `p in 0..nthreads`,
    /// caller executes `p = 0`. Returns per-worker busy times.
    ///
    /// # Panics
    /// If `nthreads` exceeds [`Self::max_threads`] or is zero.
    pub fn run<F>(&self, nthreads: usize, f: F) -> Vec<Duration>
    where
        F: Fn(usize) + Sync,
    {
        assert!(nthreads >= 1, "need at least one thread");
        assert!(
            nthreads <= self.max_threads(),
            "region of {} threads on a pool of {}",
            nthreads,
            self.max_threads()
        );
        let mut busy: Vec<Duration> = vec![Duration::ZERO; nthreads];

        {
            let mut pending = lock_ok(&self.shared.pending);
            *pending = nthreads - 1;
        }

        // SAFETY: the closures borrow `f` and the busy-time writer,
        // which outlive the region because we block on `all_done`
        // before returning (and before the borrows go out of scope).
        // This is the standard scoped-execution pattern (what
        // rayon/crossbeam do internally); the 'static bound on Job is
        // satisfied by transmuting the borrow lifetime, never observed
        // beyond the join below.
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: lifetime laundering per the block comment above; the
        // reference never survives the join barrier below.
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        // Busy times go through the claims layer: worker p owns slot p
        // of one region, so no lock is needed even for the metrics.
        let busy_writer = DisjointWriter::new(&mut busy, "pool::run busy");
        let busy_ref: &DisjointWriter<'_, Duration> = &busy_writer;
        // SAFETY: lifetime laundering only, as for `f_static` above —
        // the reference never survives the join barrier below.
        let busy_static: &'static DisjointWriter<'static, Duration> =
            unsafe { std::mem::transmute(busy_ref) };

        for p in 1..nthreads {
            let job: Job = Box::new(move || {
                let t0 = thread_cpu_time();
                f_static(p);
                // SAFETY: worker p writes only busy slot p, once; the
                // join barrier below happens-before the read-back.
                unsafe { busy_static.write(p, thread_cpu_time().saturating_sub(t0)) };
            });
            // xlint: allow(hot-panic): a hung-up worker channel means
            // the pool is torn down — unrecoverable by design.
            self.senders[p - 1].send(job).expect("worker hung up");
        }

        let t0 = thread_cpu_time();
        f(0);
        // SAFETY: the master alone writes busy slot 0, once.
        unsafe { busy_writer.write(0, thread_cpu_time().saturating_sub(t0)) };

        // Join: wait until every background worker of this region is done.
        let mut pending = lock_ok(&self.shared.pending);
        while *pending != 0 {
            pending = self
                .shared
                .all_done
                .wait(pending)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        drop(pending);
        drop(busy_writer);

        if let Some(log) = lock_ok(&self.log).as_mut() {
            log.regions.push(busy.clone());
        }
        busy
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.senders.clear(); // close channels; workers exit their loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One-shot scoped parallel region without a persistent pool
/// (convenience for tests and cold paths).
pub fn scoped_region<F>(nthreads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    std::thread::scope(|s| {
        for p in 1..nthreads {
            let f = &f;
            s.spawn(move || f(p));
        }
        f(0);
    });
}

/// Shared atomic work counter for dynamic scheduling experiments.
pub struct WorkCounter(AtomicUsize);

impl WorkCounter {
    /// Fresh counter starting at index 0.
    pub fn new() -> Self {
        Self(AtomicUsize::new(0))
    }
    /// Atomically grab the next `chunk`-sized range below `limit`, or
    /// `None` when the work is exhausted.
    #[inline]
    pub fn next_chunk(&self, chunk: usize, limit: usize) -> Option<std::ops::Range<usize>> {
        let start = self.0.fetch_add(chunk, Ordering::Relaxed);
        if start >= limit {
            None
        } else {
            Some(start..(start + chunk).min(limit))
        }
    }
}

impl Default for WorkCounter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn region_runs_every_worker_exactly_once() {
        let pool = ThreadPool::new(7);
        let hits: Vec<AtomicU64> = (0..8).map(|_| AtomicU64::new(0)).collect();
        pool.run(8, |p| {
            hits[p].fetch_add(1, Ordering::Relaxed);
        });
        for (p, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "worker {p}");
        }
    }

    #[test]
    fn pool_is_reusable_across_regions() {
        let pool = ThreadPool::new(3);
        let total = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run(4, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn region_smaller_than_pool() {
        let pool = ThreadPool::new(8);
        let total = AtomicU64::new(0);
        pool.run(2, |_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn busy_times_reported_for_all_workers() {
        let pool = ThreadPool::new(3);
        let busy = pool.run(4, |p| {
            // Unequal work so at least some busy times are non-trivial.
            let mut x = 0u64;
            for i in 0..(p as u64 + 1) * 100_000 {
                x = x.wrapping_add(i * i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(busy.len(), 4);
    }

    #[test]
    fn single_thread_region_runs_on_caller() {
        let pool = ThreadPool::new(0);
        let id = std::thread::current().id();
        let same = Mutex::new(false);
        pool.run(1, |p| {
            assert_eq!(p, 0);
            *same.lock().unwrap() = std::thread::current().id() == id;
        });
        assert!(*same.lock().unwrap());
    }

    #[test]
    #[should_panic(expected = "region of")]
    fn oversubscribed_region_panics() {
        let pool = ThreadPool::new(1);
        pool.run(3, |_| {});
    }

    #[test]
    fn scoped_region_covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..5).map(|_| AtomicU64::new(0)).collect();
        scoped_region(5, |p| {
            hits[p].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn fan_map_collects_in_index_order() {
        let pool = ThreadPool::new(3);
        let got = pool.fan_map(4, 100, |i| i * i);
        assert_eq!(got, (0..100).map(|i| i * i).collect::<Vec<_>>());
        assert!(pool.fan_map(4, 0, |i| i).is_empty());
        // Fewer items than workers still covers everything once.
        assert_eq!(pool.fan_map(4, 2, |i| i + 1), vec![1, 2]);
    }

    #[test]
    fn fan_map_take_moves_each_item_once() {
        let pool = ThreadPool::new(3);
        // Non-Clone, non-Default items: ownership must transfer.
        struct Owned(String);
        let items: Vec<Owned> = (0..50).map(|i| Owned(format!("item-{i}"))).collect();
        let got = pool.fan_map_take(4, items, |i, item: Owned| {
            assert_eq!(item.0, format!("item-{i}"));
            i
        });
        assert_eq!(got, (0..50).collect::<Vec<_>>());
        assert!(pool.fan_map_take(4, Vec::<Owned>::new(), |i, _| i).is_empty());
    }

    #[test]
    fn work_counter_covers_range_without_overlap() {
        let wc = WorkCounter::new();
        let seen = Mutex::new(vec![0u8; 1000]);
        scoped_region(4, |_| {
            while let Some(r) = wc.next_chunk(7, 1000) {
                let mut s = seen.lock().unwrap();
                for i in r {
                    s[i] += 1;
                }
            }
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }
}

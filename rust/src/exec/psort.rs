//! Parallel merge sort — the replacement for the parallel `std::sort`
//! the paper gets from `-D_GLIBCXX_PARALLEL` (multiway mergesort).
//!
//! Phase 1: the input is split into `P` chunks, each sorted locally
//! (`slice::sort_unstable_by_key`). Phase 2: ⌈log₂ P⌉ rounds of
//! pairwise merges between an input and an output buffer; each merge is
//! itself split across workers with **merge-path partitioning** (binary
//! search for the (i, j) split at a given output rank), so the span of
//! every round is O(N/P + lg N) — without the split, the final round
//! is a serial O(N) merge that caps SBM's speedup (this showed up
//! directly in the Fig. 10 reproduction; EXPERIMENTS.md §Perf step 5).

use super::claims::DisjointWriter;
use super::pfor::chunks;
use super::pool::ThreadPool;

/// Sort `data` by `key` using up to `nthreads` workers of `pool`.
pub fn par_sort_by_key<T, K, F>(
    pool: &ThreadPool,
    nthreads: usize,
    data: &mut [T],
    key: F,
) where
    T: Copy + Send + Sync,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    let n = data.len();
    if nthreads <= 1 || n < 4 * nthreads {
        data.sort_unstable_by_key(|x| key(x));
        return;
    }

    // Phase 1: sort P disjoint chunks in parallel.
    let bounds = chunks(n, nthreads);
    {
        let dw = DisjointWriter::new(&mut *data, "psort::chunk_sort");
        let (dw, bounds, key) = (&dw, &bounds, &key);
        pool.run(nthreads, |p| {
            // SAFETY: the chunks partition 0..n, so every worker claims
            // a disjoint range.
            let mut chunk = unsafe { dw.claim(bounds[p].clone()) };
            chunk.sort_unstable_by_key(|x| key(x));
        });
    }

    // Phase 2: pairwise merge rounds, ping-ponging with an aux buffer.
    let mut aux: Vec<T> = data.to_vec();
    let mut runs: Vec<std::ops::Range<usize>> = bounds;
    let mut src_is_data = true;
    while runs.len() > 1 {
        let pairs: Vec<(std::ops::Range<usize>, std::ops::Range<usize>)> = runs
            .chunks(2)
            .map(|c| {
                if c.len() == 2 {
                    (c[0].clone(), c[1].clone())
                } else {
                    (c[0].clone(), c[0].end..c[0].end)
                }
            })
            .collect();

        // Merge-path task decomposition, split by TOTAL element count:
        // worker w's share of this round is the global output ranks
        // [n·w/W, n·(w+1)/W), and every pair is cut exactly at the
        // worker boundaries that fall inside it. Each worker therefore
        // copies a contiguous ≈n/W elements even in the last round
        // (1 pair) and when the sub-merge count is not a multiple of
        // the worker count — the old round-robin-by-task-index
        // distribution gave some workers a whole extra sub-merge
        // there, capping the round at ~2x the ideal span.
        let total_all: usize = pairs.iter().map(|(a, b)| a.len() + b.len()).sum();
        let workers = nthreads.min(total_all).max(1);
        let mut tasks: Vec<(std::ops::Range<usize>, std::ops::Range<usize>, usize)> =
            Vec::with_capacity(pairs.len() + workers);
        // Owner worker per task (non-decreasing: tasks are generated in
        // global output-rank order and never straddle a boundary).
        let mut owners: Vec<usize> = Vec::with_capacity(pairs.len() + workers);
        {
            let src: &[T] = if src_is_data { &*data } else { &aux };
            let mut pair_start = 0usize; // global rank of this pair's first output
            // Owner of the task starting at global rank s: the worker
            // whose range [n·w/W, n·(w+1)/W) contains s. Task starts
            // are non-decreasing, so a monotone cursor resolves it
            // exactly (a floor(s·W/n) re-derivation is NOT the inverse
            // of the boundary formula and hands boundary-started tasks
            // to the previous worker).
            let mut ow = 0usize;
            let mut owner_of = |s: usize| {
                while ow + 1 < workers && total_all * (ow + 1) / workers <= s {
                    ow += 1;
                }
                ow
            };
            for (a, b) in &pairs {
                let len = a.len() + b.len();
                if len == 0 {
                    continue;
                }
                let mut prev = (0usize, 0usize); // (i into a, j into b)
                let mut prev_rank = 0usize;
                for w in 1..workers {
                    let r = total_all * w / workers;
                    if r <= pair_start || r >= pair_start + len {
                        continue; // boundary not inside this pair
                    }
                    let cut =
                        merge_path_split(&src[a.clone()], &src[b.clone()], r - pair_start, &key);
                    if cut != prev {
                        owners.push(owner_of(pair_start + prev_rank));
                        tasks.push((
                            a.start + prev.0..a.start + cut.0,
                            b.start + prev.1..b.start + cut.1,
                            a.start + prev.0 + prev.1,
                        ));
                        prev = cut;
                        prev_rank = r - pair_start;
                    }
                }
                let end = (a.len(), b.len());
                if end != prev {
                    owners.push(owner_of(pair_start + prev_rank));
                    tasks.push((
                        a.start + prev.0..a.start + end.0,
                        b.start + prev.1..b.start + end.1,
                        a.start + prev.0 + prev.1,
                    ));
                }
                pair_start += len;
            }
        }

        // Boundary claim check: the generated tasks must cover every
        // output rank of this round exactly once (their claimed output
        // ranges tile; race-check verifies disjointness index-wise).
        debug_assert_eq!(
            tasks.iter().map(|(a, b, _)| a.len() + b.len()).sum::<usize>(),
            total_all,
            "psort sub-merge tasks must cover the whole round"
        );
        // The branch gives each round a clean (shared src, exclusive
        // dst) borrow pair over the two distinct ping-pong buffers.
        if src_is_data {
            merge_round(pool, workers, &*data, &mut aux, &tasks, &owners, &key);
        } else {
            merge_round(pool, workers, &aux, data, &tasks, &owners, &key);
        }
        runs = pairs.iter().map(|(a, b)| a.start..b.end).collect();
        src_is_data = !src_is_data;
    }

    if !src_is_data {
        data.copy_from_slice(&aux);
    }
}

/// Find the (i, j) with i + j = r such that merging `a[..i]` and
/// `b[..j]` yields the first `r` elements of the stable merge of a, b
/// (the "merge path" split; a-elements win ties, preserving stability).
fn merge_path_split<T, K, F>(a: &[T], b: &[T], r: usize, key: &F) -> (usize, usize)
where
    K: Ord,
    F: Fn(&T) -> K,
{
    let (mut lo, mut hi) = (r.saturating_sub(b.len()), r.min(a.len()));
    while lo < hi {
        let i = (lo + hi) / 2;
        let j = r - i;
        // Too few a-elements taken: a[i] belongs before b[j-1].
        if j > 0 && i < a.len() && key(&a[i]) < key(&b[j - 1]) {
            lo = i + 1;
        } else if i > 0 && j < b.len() && key(&b[j]) < key(&a[i - 1]) {
            // Too many a-elements taken: b[j] belongs before a[i-1].
            hi = i - 1;
        } else {
            return (i, r - i);
        }
    }
    (lo, r - lo)
}

/// One parallel merge round: every worker walks its contiguous task
/// group (owners are sorted), claims each task's output range through
/// the claims layer and runs the safe two-way merge into it. The task
/// output ranges tile the round's outputs disjointly — checked
/// index-by-index under `race-check`.
fn merge_round<T, K, F>(
    pool: &ThreadPool,
    workers: usize,
    src: &[T],
    dst: &mut [T],
    tasks: &[(std::ops::Range<usize>, std::ops::Range<usize>, usize)],
    owners: &[usize],
    key: &F,
) where
    T: Copy + Send + Sync,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    let dw = DisjointWriter::new(dst, "psort::merge dst");
    let dw = &dw;
    pool.run(workers, |p| {
        // This worker's contiguous task group (owners sorted).
        let s = owners.partition_point(|&o| o < p);
        let e = owners.partition_point(|&o| o <= p);
        for i in s..e {
            let (a, b, out) = tasks[i].clone();
            let len = a.len() + b.len();
            // SAFETY: the merge-path cuts assign every task a disjoint
            // output range (together they tile the round's outputs).
            let mut seg = unsafe { dw.claim(out..out + len) };
            merge_into(&src[a], &src[b], &mut seg, key);
        }
    });
}

/// Merge sorted `a` and `b` into `dst` (stable: a-elements win ties).
/// `dst.len()` must equal `a.len() + b.len()`; plain safe slice code —
/// the claims layer hands each sub-merge its exclusive output slice.
fn merge_into<T, K, F>(a: &[T], b: &[T], dst: &mut [T], key: &F)
where
    T: Copy,
    K: Ord,
    F: Fn(&T) -> K,
{
    debug_assert_eq!(dst.len(), a.len() + b.len(), "merge output must fit exactly");
    let (mut i, mut j) = (0, 0);
    for slot in dst.iter_mut() {
        if j >= b.len() || (i < a.len() && key(&a[i]) <= key(&b[j])) {
            *slot = a[i];
            i += 1;
        } else {
            *slot = b[j];
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn check_sorted(pool: &ThreadPool, nthreads: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut data: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1000).collect();
        let mut want = data.clone();
        want.sort_unstable();
        par_sort_by_key(pool, nthreads, &mut data, |&x| x);
        assert_eq!(data, want, "n={n} p={nthreads}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn sorts_like_std_across_thread_counts() {
        let pool = ThreadPool::new(7);
        for &p in &[1usize, 2, 3, 4, 8] {
            for &n in &[0usize, 1, 2, 17, 100, 1000, 10_000] {
                check_sorted(&pool, p, n, 42 + n as u64 + p as u64);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn sorts_already_sorted_and_reversed() {
        let pool = ThreadPool::new(3);
        let mut asc: Vec<u64> = (0..5000).collect();
        let want = asc.clone();
        par_sort_by_key(&pool, 4, &mut asc, |&x| x);
        assert_eq!(asc, want);
        let mut desc: Vec<u64> = (0..5000).rev().collect();
        par_sort_by_key(&pool, 4, &mut desc, |&x| x);
        assert_eq!(desc, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn sorts_with_many_duplicates() {
        let pool = ThreadPool::new(3);
        let mut rng = Rng::new(9);
        let mut data: Vec<u64> = (0..20_000).map(|_| rng.below(4)).collect();
        let mut want = data.clone();
        want.sort_unstable();
        par_sort_by_key(&pool, 4, &mut data, |&x| x);
        assert_eq!(data, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn all_equal_keys() {
        let pool = ThreadPool::new(7);
        let mut data: Vec<u64> = vec![7; 10_000];
        par_sort_by_key(&pool, 8, &mut data, |&x| x);
        assert!(data.iter().all(|&x| x == 7));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn composite_keys_via_f64_key() {
        use crate::exec::f64_key;
        let pool = ThreadPool::new(3);
        let mut rng = Rng::new(31);
        let mut data: Vec<(f64, u32)> = (0..10_000)
            .map(|i| (rng.uniform(-100.0, 100.0), i as u32))
            .collect();
        par_sort_by_key(&pool, 4, &mut data, |&(pos, id)| {
            ((f64_key(pos) as u128) << 32) | id as u128
        });
        for w in data.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn thread_count_does_not_change_result() {
        let pool = ThreadPool::new(7);
        let mut rng = Rng::new(77);
        let base: Vec<u64> = (0..9999).map(|_| rng.next_u64()).collect();
        let mut one = base.clone();
        par_sort_by_key(&pool, 1, &mut one, |&x| x);
        for p in 2..=8 {
            let mut v = base.clone();
            par_sort_by_key(&pool, p, &mut v, |&x| x);
            assert_eq!(v, one, "p={p}");
        }
    }

    /// Adversarial run/worker shapes for the element-count sub-merge
    /// split: odd chunk counts leave a lone run in the pairing, and
    /// worker counts that don't divide the sub-merge count used to
    /// idle workers under the old round-robin-by-task distribution.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn last_round_uneven_worker_counts() {
        let pool = ThreadPool::new(7);
        let mut rng = Rng::new(0xBA1A);
        for &p in &[3usize, 5, 6, 7] {
            for &n in &[4 * p, 4 * p + 1, 997, 10_001, 32 * 1024 + 17] {
                let mut data: Vec<u64> = (0..n).map(|_| rng.next_u64() % 512).collect();
                let mut want = data.clone();
                want.sort_unstable();
                par_sort_by_key(&pool, p, &mut data, |&x| x);
                assert_eq!(data, want, "n={n} p={p}");
            }
        }
    }

    #[test]
    fn merge_path_split_properties() {
        let a = [1u64, 3, 5, 7, 9];
        let b = [2u64, 4, 6, 8];
        for r in 0..=a.len() + b.len() {
            let (i, j) = merge_path_split(&a, &b, r, &|&x| x);
            assert_eq!(i + j, r);
            // Everything taken is <= everything not yet taken.
            if i > 0 && j < b.len() {
                assert!(a[i - 1] <= b[j], "r={r}");
            }
            if j > 0 && i < a.len() {
                assert!(b[j - 1] <= a[i], "r={r}");
            }
        }
    }

    #[test]
    fn merge_path_split_with_duplicates() {
        let a = [5u64; 6];
        let b = [5u64; 6];
        for r in 0..=12 {
            let (i, j) = merge_path_split(&a, &b, r, &|&x| x);
            assert_eq!(i + j, r);
        }
    }
}

//! Parallel LSD radix sort on compact `u64` keys — the sort-phase
//! replacement for the comparison-based merge path of
//! [`super::psort`].
//!
//! The SBM/PSBM pipeline is dominated by sorting the `2(n+m)` endpoint
//! array (the companion paper "Parallel Sort-Based Matching for DDM"
//! measures the sort phase capping SBM speedup). The merge path pays a
//! `u128` comparison per element per merge level; this module sorts by
//! a single `u64` word in at most eight 256-bucket passes, each pass a
//! per-worker histogram, an `O(buckets)` master prefix sum
//! ([`crate::exec::scan::seq_exclusive_scan_in_place`]) and a stable
//! scatter into a ping-pong buffer. Passes whose digit is constant
//! across the whole array (the common case for the high bytes of
//! bounded coordinates) are skipped after the histogram alone.
//!
//! **Stability is the tie-break.** LSD radix is stable by
//! construction: per pass, bucket offsets are laid out bucket-major in
//! worker order and every worker scatters its contiguous chunk in
//! order, so equal keys keep their input order — independent of the
//! worker count. Callers that need a secondary ordering (the endpoint
//! array's upper-before-lower rule, [`crate::core::endpoint`]) encode
//! it in the *input order* instead of widening the key.
//!
//! Buffers (`aux` ping-pong and the histogram block) are caller-owned
//! ([`RadixScratch`] usually lives in a
//! [`MatchScratch`](crate::core::scratch::MatchScratch)), so repeated
//! sorts of same-sized arrays allocate nothing.

use super::claims::DisjointWriter;
use super::pfor::chunks;
use super::pool::ThreadPool;
use super::scan::seq_exclusive_scan_in_place;

/// Buckets per pass (8-bit digits).
pub const RADIX_BUCKETS: usize = 256;

/// Below this length a stable insertion sort beats any radix pass.
const INSERTION_CUTOFF: usize = 64;

/// Serial cutoff: below this length the parallel entry point runs the
/// whole sort on the calling worker (histogram + scatter regions would
/// cost more in fork-join than they save).
const PAR_CUTOFF: usize = 8 * 1024;

/// Which endpoint-sort implementation a matcher runs — the radix path
/// of this module (default) or the comparison merge path of
/// [`super::psort`], kept as the property-tested fallback and the
/// `--sort merge` A/B arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SortAlgo {
    /// Compact-key LSD radix sort ([`par_radix_sort_by_key`]).
    #[default]
    Radix,
    /// Merge-path parallel mergesort ([`super::psort::par_sort_by_key`]).
    Merge,
}

impl SortAlgo {
    /// Stable identifier used in CLI flags and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            SortAlgo::Radix => "radix",
            SortAlgo::Merge => "merge",
        }
    }
}

impl std::str::FromStr for SortAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let t = s.trim();
        if t.eq_ignore_ascii_case("radix") {
            Ok(SortAlgo::Radix)
        } else if t.eq_ignore_ascii_case("merge") || t.eq_ignore_ascii_case("mergesort") {
            Ok(SortAlgo::Merge)
        } else {
            Err(format!("unknown sort algorithm '{t}' (valid: radix, merge)"))
        }
    }
}

/// Reusable histogram/offset block for the radix passes: one
/// 256-counter segment per worker, transformed in place into scatter
/// offsets each pass. Owned by the caller so steady-state sorts
/// allocate nothing.
#[derive(Debug, Default)]
pub struct RadixScratch {
    counts: Vec<u32>,
}

impl RadixScratch {
    /// Empty scratch; buffers grow on first use and are then reused.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current capacity of the counter block (allocation-free warm
    /// paths assert this stops growing after the first call).
    pub fn counts_capacity(&self) -> usize {
        self.counts.capacity()
    }
}

/// Stable insertion sort by key (the small-array cutoff shared by the
/// serial and parallel entry points, so every path yields the
/// identical order).
fn insertion_sort_by_key<T, F>(data: &mut [T], key: &F)
where
    T: Copy,
    F: Fn(&T) -> u64,
{
    for i in 1..data.len() {
        let x = data[i];
        let k = key(&x);
        let mut j = i;
        while j > 0 && key(&data[j - 1]) > k {
            data[j] = data[j - 1];
            j -= 1;
        }
        data[j] = x;
    }
}

/// Serial stable LSD radix sort by a `u64` key. Exactly the order
/// [`par_radix_sort_by_key`] produces (the parallel form is
/// worker-count-invariant), with `aux`/`scratch` reused across calls.
pub fn radix_sort_by_key<T, F>(
    data: &mut [T],
    aux: &mut Vec<T>,
    scratch: &mut RadixScratch,
    key: F,
) where
    T: Copy + Default,
    F: Fn(&T) -> u64,
{
    let n = data.len();
    if n < INSERTION_CUTOFF {
        insertion_sort_by_key(data, &key);
        return;
    }
    assert!(n <= u32::MAX as usize, "radix sort offsets are u32");
    if aux.len() < n {
        aux.resize(n, T::default());
    }
    if scratch.counts.len() < RADIX_BUCKETS {
        scratch.counts.resize(RADIX_BUCKETS, 0);
    }
    let counts = &mut scratch.counts[..RADIX_BUCKETS];
    let mut src_is_data = true;
    for pass in 0..8 {
        let shift = pass * 8;
        counts.fill(0);
        {
            let src: &[T] = if src_is_data { &*data } else { &aux[..n] };
            for x in src {
                counts[(key(x) >> shift) as usize & 0xFF] += 1;
            }
        }
        if counts.iter().filter(|&&c| c != 0).count() <= 1 {
            continue; // constant digit: nothing to move
        }
        let grand = seq_exclusive_scan_in_place(counts);
        debug_assert_eq!(grand as usize, n, "radix histogram must count every element");
        // The branch gives each pass a clean (shared src, exclusive
        // dst) borrow pair over the two distinct ping-pong buffers —
        // the serial scatter needs no unsafe at all.
        if src_is_data {
            scatter_serial(&*data, &mut aux[..n], counts, shift, &key);
        } else {
            scatter_serial(&aux[..n], data, counts, shift, &key);
        }
        src_is_data = !src_is_data;
    }
    if !src_is_data {
        data.copy_from_slice(&aux[..n]);
    }
}

/// One serial counting-sort scatter pass: move every `src` element to
/// `dst[counts[digit]]`, bumping the running offsets. `counts` must
/// hold the exclusive bucket starts for this digit (they partition
/// `0..src.len()`, so every `dst` slot is written exactly once —
/// safe-code bounds checks enforce it).
fn scatter_serial<T, F>(src: &[T], dst: &mut [T], counts: &mut [u32], shift: usize, key: &F)
where
    T: Copy,
    F: Fn(&T) -> u64,
{
    for x in src {
        let v = (key(x) >> shift) as usize & 0xFF;
        dst[counts[v] as usize] = *x;
        counts[v] += 1;
    }
}

/// Parallel stable LSD radix sort by a `u64` key on up to `nthreads`
/// workers of `pool`. Per pass: per-worker 256-bucket histograms over
/// contiguous chunks, a master prefix sum laying the offsets out
/// bucket-major in worker order, and a parallel stable scatter into
/// the ping-pong buffer. Output order is identical for every
/// `nthreads` (including 1) and identical to [`radix_sort_by_key`].
pub fn par_radix_sort_by_key<T, F>(
    pool: &ThreadPool,
    nthreads: usize,
    data: &mut [T],
    aux: &mut Vec<T>,
    scratch: &mut RadixScratch,
    key: F,
) where
    T: Copy + Default + Send + Sync,
    F: Fn(&T) -> u64 + Sync,
{
    let n = data.len();
    if nthreads <= 1 || n < PAR_CUTOFF {
        radix_sort_by_key(data, aux, scratch, key);
        return;
    }
    let workers = nthreads;
    assert!(n <= u32::MAX as usize, "radix sort offsets are u32");
    if aux.len() < n {
        aux.resize(n, T::default());
    }
    if scratch.counts.len() < workers * RADIX_BUCKETS {
        scratch.counts.resize(workers * RADIX_BUCKETS, 0);
    }
    let counts: &mut [u32] = &mut scratch.counts[..workers * RADIX_BUCKETS];
    let bounds = chunks(n, workers);

    let mut src_is_data = true;
    for pass in 0..8 {
        let shift = pass * 8;

        // ---- per-worker histograms (each worker owns one segment) ----
        if src_is_data {
            histogram_pass(pool, workers, &*data, counts, &bounds, shift, &key);
        } else {
            histogram_pass(pool, workers, &aux[..n], counts, &bounds, shift, &key);
        }

        // ---- master: bucket totals, skip check, offsets ---------------
        let mut totals = [0u32; RADIX_BUCKETS];
        for p in 0..workers {
            let seg = &counts[p * RADIX_BUCKETS..(p + 1) * RADIX_BUCKETS];
            for (t, &c) in totals.iter_mut().zip(seg) {
                *t += c;
            }
        }
        if totals.iter().filter(|&&c| c != 0).count() <= 1 {
            continue; // constant digit: nothing to move
        }
        let grand = seq_exclusive_scan_in_place(&mut totals);
        debug_assert_eq!(grand as usize, n, "radix histograms must count every element");
        // Offsets bucket-major, worker-minor: worker p's slice of
        // bucket v starts after every lower bucket and after workers
        // 0..p of bucket v — the layout that makes the scatter stable.
        for v in 0..RADIX_BUCKETS {
            let mut at = totals[v];
            for p in 0..workers {
                let c = counts[p * RADIX_BUCKETS + v];
                counts[p * RADIX_BUCKETS + v] = at;
                at += c;
            }
            // Boundary claim check: bucket v's last worker slice must
            // end exactly where bucket v+1 starts (or at n) — i.e. the
            // (bucket, worker) offset table tiles 0..n with no gap or
            // overlap. Compiled out in release.
            debug_assert_eq!(
                at as usize,
                if v + 1 < RADIX_BUCKETS {
                    totals[v + 1] as usize
                } else {
                    n
                },
                "radix offsets must tile 0..n (bucket {v})"
            );
        }

        // ---- parallel stable scatter ----------------------------------
        if src_is_data {
            scatter_pass(pool, workers, &*data, &mut aux[..n], counts, &bounds, shift, &key);
        } else {
            scatter_pass(pool, workers, &aux[..n], data, counts, &bounds, shift, &key);
        }
        src_is_data = !src_is_data;
    }

    if !src_is_data {
        // Result landed in aux: parallel copy back.
        let dst = DisjointWriter::new(data, "radix::copy_back");
        let (dst, src, bounds) = (&dst, &aux[..n], &bounds);
        pool.run(workers, |p| {
            let r = bounds[p].clone();
            // SAFETY: the chunks partition 0..n, so each worker claims
            // a disjoint range of dst (and reads the same range of the
            // distinct src buffer).
            let mut seg = unsafe { dst.claim(r.clone()) };
            seg.copy_from_slice(&src[r]);
        });
    }
}

/// One parallel histogram pass: worker `p` claims counts segment `p`
/// (through the claims layer) and counts digit occurrences over its
/// contiguous chunk of `src`.
fn histogram_pass<T, F>(
    pool: &ThreadPool,
    workers: usize,
    src: &[T],
    counts: &mut [u32],
    bounds: &[std::ops::Range<usize>],
    shift: usize,
    key: &F,
) where
    T: Sync,
    F: Fn(&T) -> u64 + Sync,
{
    let cw = DisjointWriter::new(counts, "radix::histogram counts");
    let cw = &cw;
    pool.run(workers, |p| {
        // SAFETY: worker p claims exactly counts segment p; the
        // segments are disjoint by construction.
        let mut seg = unsafe { cw.claim(p * RADIX_BUCKETS..(p + 1) * RADIX_BUCKETS) };
        seg.fill(0);
        for x in &src[bounds[p].clone()] {
            seg[(key(x) >> shift) as usize & 0xFF] += 1;
        }
    });
}

/// One parallel stable scatter pass: worker `p` claims counts segment
/// `p` (its private running offsets) and moves its chunk of `src`
/// into `dst` through the claims layer — the offset table assigns
/// every `(bucket, worker)` pair a disjoint `dst` range, so each slot
/// is written exactly once (checked under `race-check`).
fn scatter_pass<T, F>(
    pool: &ThreadPool,
    workers: usize,
    src: &[T],
    dst: &mut [T],
    counts: &mut [u32],
    bounds: &[std::ops::Range<usize>],
    shift: usize,
    key: &F,
) where
    T: Copy + Send + Sync,
    F: Fn(&T) -> u64 + Sync,
{
    let dw = DisjointWriter::new(dst, "radix::scatter dst");
    let cw = DisjointWriter::new(counts, "radix::scatter counts");
    let (dw, cw) = (&dw, &cw);
    pool.run(workers, |p| {
        // SAFETY: worker p claims exactly counts segment p.
        let mut seg = unsafe { cw.claim(p * RADIX_BUCKETS..(p + 1) * RADIX_BUCKETS) };
        for x in &src[bounds[p].clone()] {
            let v = (key(x) >> shift) as usize & 0xFF;
            // SAFETY: seg[v] walks worker p's disjoint slice of bucket
            // v's output range; no other worker writes these slots.
            unsafe { dw.write(seg[v] as usize, *x) };
            seg[v] += 1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn sort_both(n: usize, seed: u64, nthreads: usize, pool: &ThreadPool) {
        let mut rng = Rng::new(seed);
        // (key, payload): payload records input order so stability is
        // observable.
        let base: Vec<(u64, u32)> = (0..n)
            .map(|i| (rng.next_u64() % 97, i as u32))
            .collect();
        let mut want = base.clone();
        want.sort_by_key(|&(k, _)| k); // std stable sort = the oracle
        let mut got = base.clone();
        let mut aux = Vec::new();
        let mut scratch = RadixScratch::new();
        par_radix_sort_by_key(pool, nthreads, &mut got, &mut aux, &mut scratch, |&(k, _)| k);
        assert_eq!(got, want, "n={n} p={nthreads}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn stable_and_sorted_across_sizes_and_thread_counts() {
        let pool = ThreadPool::new(7);
        for &p in &[1usize, 2, 3, 4, 8] {
            for &n in &[0usize, 1, 2, 63, 64, 100, 1000, 9000, 40_000] {
                sort_both(n, 0x0AD ^ (n as u64) ^ ((p as u64) << 32), p, &pool);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn serial_and_parallel_orders_are_identical() {
        let pool = ThreadPool::new(7);
        let mut rng = Rng::new(0x5EED);
        let base: Vec<(u64, u32)> = (0..30_011)
            .map(|i| (rng.next_u64() % 13, i as u32))
            .collect();
        let mut serial = base.clone();
        let mut aux = Vec::new();
        let mut scratch = RadixScratch::new();
        radix_sort_by_key(&mut serial, &mut aux, &mut scratch, |&(k, _)| k);
        for p in [2, 4, 8] {
            let mut par = base.clone();
            let mut aux = Vec::new();
            let mut scratch = RadixScratch::new();
            par_radix_sort_by_key(&pool, p, &mut par, &mut aux, &mut scratch, |&(k, _)| k);
            assert_eq!(par, serial, "p={p}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn full_width_keys_and_extremes() {
        let pool = ThreadPool::new(3);
        let mut rng = Rng::new(0xF00D);
        let mut data: Vec<u64> = (0..20_000).map(|_| rng.next_u64()).collect();
        data.extend([0, u64::MAX, 1, u64::MAX - 1, 1 << 63]);
        let mut want = data.clone();
        want.sort_unstable();
        let mut aux = Vec::new();
        let mut scratch = RadixScratch::new();
        par_radix_sort_by_key(&pool, 4, &mut data, &mut aux, &mut scratch, |&x| x);
        assert_eq!(data, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn all_equal_keys_keep_input_order() {
        let pool = ThreadPool::new(3);
        let base: Vec<(u64, u32)> = (0..10_000).map(|i| (7, i as u32)).collect();
        let mut data = base.clone();
        let mut aux = Vec::new();
        let mut scratch = RadixScratch::new();
        par_radix_sort_by_key(&pool, 4, &mut data, &mut aux, &mut scratch, |&(k, _)| k);
        assert_eq!(data, base, "constant keys must not move");
    }

    /// Property-tested fallback agreement: where keys are distinct the
    /// comparison merge path (`psort`) must produce the identical
    /// array; where they collide, radix keeps input order (stability).
    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn agrees_with_psort_fallback_property() {
        let pool = ThreadPool::new(5);
        crate::bench::prop::prop_check("radix-vs-psort", 0x5087, |rng| {
            let n = rng.below(5000) as usize;
            let spread = 1 + rng.below(1 << 40);
            // Distinct composite: (key, unique id) — both sorts agree
            // on the total order.
            let base: Vec<(u64, u32)> = (0..n)
                .map(|i| (rng.next_u64() % spread, i as u32))
                .collect();
            let p = 1 + rng.below(6) as usize;
            let mut radix = base.clone();
            let mut aux = Vec::new();
            let mut scratch = RadixScratch::new();
            // Radix on the key alone: ties broken by input order, which
            // here equals ascending id.
            par_radix_sort_by_key(&pool, p, &mut radix, &mut aux, &mut scratch, |&(k, _)| k);
            let mut merge = base.clone();
            crate::exec::psort::par_sort_by_key(&pool, p, &mut merge, |&(k, id)| {
                ((k as u128) << 32) | id as u128
            });
            crate::bench::prop::expect_eq(&radix, &merge, "radix vs merge order")
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy workload; CI runs the small exec tests under Miri
    fn scratch_buffers_stop_growing_after_first_call() {
        let pool = ThreadPool::new(3);
        let mut rng = Rng::new(0xCAFE);
        let base: Vec<u64> = (0..50_000).map(|_| rng.next_u64()).collect();
        let mut aux = Vec::new();
        let mut scratch = RadixScratch::new();
        let mut data = base.clone();
        par_radix_sort_by_key(&pool, 4, &mut data, &mut aux, &mut scratch, |&x| x);
        let (aux_cap, counts_cap) = (aux.capacity(), scratch.counts_capacity());
        for _ in 0..3 {
            let mut data = base.clone();
            par_radix_sort_by_key(&pool, 4, &mut data, &mut aux, &mut scratch, |&x| x);
            assert_eq!(aux.capacity(), aux_cap, "aux must not grow on warm calls");
            assert_eq!(scratch.counts_capacity(), counts_cap, "counts must not grow");
        }
    }

    #[test]
    fn sort_algo_parses() {
        assert_eq!("radix".parse::<SortAlgo>().unwrap(), SortAlgo::Radix);
        assert_eq!("Merge".parse::<SortAlgo>().unwrap(), SortAlgo::Merge);
        assert_eq!("mergesort".parse::<SortAlgo>().unwrap(), SortAlgo::Merge);
        assert!("quick".parse::<SortAlgo>().is_err());
        assert_eq!(SortAlgo::default(), SortAlgo::Radix);
        assert_eq!(SortAlgo::Radix.name(), "radix");
        assert_eq!(SortAlgo::Merge.name(), "merge");
    }
}

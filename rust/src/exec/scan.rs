//! Prefix computations (paper §4, Fig. 7).
//!
//! Parallel SBM needs an exclusive scan over *per-segment set deltas*
//! with a non-commutative (but associative) combine operator; the
//! paper's two-level scheme is: ① per-worker local scans, ② a serial
//! master combine over `P` partial results, ③ per-worker offset apply.
//! Because `P ≪ N`, step ② is O(P) and the whole scan is O(N/P + P).
//!
//! [`seq_exclusive_scan`] is the master-step building block (also used
//! directly by Algorithm 7 lines 18–21); [`par_inclusive_scan`] is the
//! full three-step pipeline for plain `Copy` elements, mirroring the L1
//! Pallas scan kernels (`python/compile/kernels/scan.py`) layer by
//! layer.

use super::claims::DisjointWriter;
use super::pfor::chunks;
use super::pool::ThreadPool;

/// Exclusive scan: `out[i] = identity ⊕ x₀ ⊕ … ⊕ xᵢ₋₁`.
pub fn seq_exclusive_scan<T, F>(items: &[T], identity: T, op: F) -> Vec<T>
where
    T: Clone,
    F: Fn(&T, &T) -> T,
{
    let mut out = Vec::with_capacity(items.len());
    let mut acc = identity;
    for x in items {
        out.push(acc.clone());
        acc = op(&acc, x);
    }
    out
}

/// In-place exclusive prefix sum over counters, returning the total:
/// `data[i] ← data[0] + … + data[i-1]`. The allocation-free master
/// step of the counting-sort machinery — [`crate::exec::radix`]'s
/// bucket starts and GBM's cell starts both run through it, so the
/// scatter hot paths never build a fresh offsets vector.
pub fn seq_exclusive_scan_in_place(data: &mut [u32]) -> u32 {
    let mut acc = 0u32;
    for x in data.iter_mut() {
        let c = *x;
        *x = acc;
        acc += c;
    }
    acc
}

/// Inclusive scan: `out[i] = x₀ ⊕ … ⊕ xᵢ`.
pub fn seq_inclusive_scan<T, F>(items: &[T], op: F) -> Vec<T>
where
    T: Clone,
    F: Fn(&T, &T) -> T,
{
    let mut out = Vec::with_capacity(items.len());
    for x in items {
        let next = match out.last() {
            Some(prev) => op(prev, x),
            None => x.clone(),
        };
        out.push(next);
    }
    out
}

/// In-place parallel inclusive scan (paper Fig. 7, steps ①–③).
///
/// `op` must be associative; `identity` its neutral element.
pub fn par_inclusive_scan<T, F>(
    pool: &ThreadPool,
    nthreads: usize,
    data: &mut [T],
    identity: T,
    op: F,
) where
    T: Copy + Send + Sync,
    F: Fn(T, T) -> T + Sync,
{
    let n = data.len();
    if nthreads <= 1 || n < 2 * nthreads {
        let mut acc = identity;
        for x in data.iter_mut() {
            acc = op(acc, *x);
            *x = acc;
        }
        return;
    }

    let bounds = chunks(n, nthreads);

    // Step ①: local inclusive scans (each worker claims its chunk).
    {
        let dw = DisjointWriter::new(&mut *data, "scan::local");
        let (dw, bounds, op) = (&dw, &bounds, &op);
        pool.run(nthreads, |p| {
            // SAFETY: the chunks partition 0..n disjointly.
            let mut s = unsafe { dw.claim(bounds[p].clone()) };
            let mut acc = identity;
            for x in s.iter_mut() {
                acc = op(acc, *x);
                *x = acc;
            }
        });
    }

    // Step ②: master — exclusive scan of the per-chunk totals.
    let totals: Vec<T> = bounds
        .iter()
        .map(|r| {
            if r.is_empty() {
                identity
            } else {
                data[r.end - 1]
            }
        })
        .collect();
    let offsets = seq_exclusive_scan(&totals, identity, |a, b| op(*a, *b));

    // Step ③: apply offsets (worker 0's offset is the identity).
    {
        let dw = DisjointWriter::new(&mut *data, "scan::apply");
        let (dw, bounds, offsets, op) = (&dw, &bounds, &offsets, &op);
        pool.run(nthreads, |p| {
            if p == 0 {
                return;
            }
            let off = offsets[p];
            // SAFETY: the chunks partition 0..n disjointly (worker 0
            // claims nothing; its chunk keeps its local scan).
            let mut s = unsafe { dw.claim(bounds[p].clone()) };
            for x in s.iter_mut() {
                *x = op(off, *x);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn seq_exclusive_matches_definition() {
        let xs = [1i64, 2, 3, 4];
        assert_eq!(seq_exclusive_scan(&xs, 0, |a, b| a + b), vec![0, 1, 3, 6]);
        let empty: [i64; 0] = [];
        assert!(seq_exclusive_scan(&empty, 0, |a, b| a + b).is_empty());
    }

    #[test]
    fn in_place_exclusive_scan_matches_definition() {
        let mut xs = [1u32, 2, 3, 4];
        assert_eq!(seq_exclusive_scan_in_place(&mut xs), 10);
        assert_eq!(xs, [0, 1, 3, 6]);
        let mut empty: [u32; 0] = [];
        assert_eq!(seq_exclusive_scan_in_place(&mut empty), 0);
    }

    #[test]
    fn seq_inclusive_matches_definition() {
        let xs = [1i64, 2, 3, 4];
        assert_eq!(seq_inclusive_scan(&xs, |a, b| a + b), vec![1, 3, 6, 10]);
    }

    #[test]
    fn par_scan_matches_seq_for_all_thread_counts() {
        let pool = ThreadPool::new(7);
        let mut rng = Rng::new(5);
        let base: Vec<i64> = (0..10_001).map(|_| rng.range(-50, 51)).collect();
        let want = seq_inclusive_scan(&base, |a, b| a + b);
        for p in 1..=8 {
            let mut v = base.clone();
            par_inclusive_scan(&pool, p, &mut v, 0, |a, b| a + b);
            assert_eq!(v, want, "p={p}");
        }
    }

    #[test]
    fn par_scan_with_non_commutative_op() {
        // 2x2 integer matrix multiply: associative, NOT commutative —
        // exactly the class of operator the set-delta combine is in.
        // Wrapping arithmetic keeps associativity exact mod 2^64.
        type M = [i64; 4];
        const I: M = [1, 0, 0, 1];
        fn mul(a: M, b: M) -> M {
            let e = |x: i64, y: i64, z: i64, w: i64| {
                x.wrapping_mul(y).wrapping_add(z.wrapping_mul(w))
            };
            [
                e(a[0], b[0], a[1], b[2]),
                e(a[0], b[1], a[1], b[3]),
                e(a[2], b[0], a[3], b[2]),
                e(a[2], b[1], a[3], b[3]),
            ]
        }
        let pool = ThreadPool::new(3);
        let mut rng = Rng::new(8);
        let base: Vec<M> = (0..257)
            .map(|_| {
                [
                    rng.range(-2, 3),
                    rng.range(-2, 3),
                    rng.range(-2, 3),
                    rng.range(-2, 3),
                ]
            })
            .collect();
        let want = seq_inclusive_scan(&base, |a, b| mul(*a, *b));
        let mut got = base.clone();
        par_inclusive_scan(&pool, 4, &mut got, I, mul);
        assert_eq!(got, want);
    }

    #[test]
    fn par_scan_tiny_inputs() {
        let pool = ThreadPool::new(7);
        for n in 0..8usize {
            let base: Vec<i64> = (0..n as i64).collect();
            let want = seq_inclusive_scan(&base, |a, b| a + b);
            let mut got = base.clone();
            par_inclusive_scan(&pool, 8, &mut got, 0, |a, b| a + b);
            assert_eq!(got, want, "n={n}");
        }
    }
}

//! A miniature HLA/RTI **Data Distribution Management** service — the
//! system the paper's matchers exist to serve (paper §1).
//!
//! The HLA model (IEEE 1516): a simulation declares *dimensions* (integer
//! ranges `0..upper`); federates register *region specifications* (one
//! range per dimension) as subscription or update regions; the DDM
//! service computes subscription/update overlaps and routes each update
//! notification to the federates whose subscriptions intersect the
//! update region (the paper's Fig. 1 traffic example).
//!
//! * [`space`] — dimensions and the routing space.
//! * [`region`] — region specifications and validation.
//! * [`service`] — federate management, region registration,
//!   matching, notification routing, and dynamic region modification.

pub mod region;
pub mod service;
pub mod space;

pub use region::{RegionHandle, RegionKind, RegionSpec};
pub use service::{DdmService, FederateId, Notification};
pub use space::{Dimension, RoutingSpace};

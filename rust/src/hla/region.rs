//! Region specifications (HLA OMT "region specification": one range
//! per dimension).

use crate::core::interval::Interval;

/// Subscription or update side.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RegionKind {
    Subscription,
    Update,
}

/// Stable external handle for a registered region.
///
/// Handles survive internal compaction (the service maintains a
/// handle → dense-index map); `kind` is encoded so misuse is caught.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RegionHandle {
    pub kind: RegionKind,
    pub id: u32,
}

/// A region specification: one half-open integer range per dimension.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegionSpec {
    pub ranges: Vec<(u64, u64)>,
}

impl RegionSpec {
    pub fn new(ranges: Vec<(u64, u64)>) -> Self {
        Self { ranges }
    }

    /// 1-D helper.
    pub fn interval(lo: u64, hi: u64) -> Self {
        Self {
            ranges: vec![(lo, hi)],
        }
    }

    /// 2-D helper.
    pub fn rect(x: (u64, u64), y: (u64, u64)) -> Self {
        Self {
            ranges: vec![x, y],
        }
    }

    pub fn d(&self) -> usize {
        self.ranges.len()
    }

    /// Convert to per-dimension f64 intervals (matching layer input).
    pub fn to_intervals(&self) -> Vec<Interval> {
        self.ranges
            .iter()
            .map(|&(lo, hi)| Interval::new(lo as f64, hi as f64))
            .collect()
    }

    /// HLA-semantics overlap (projection test on every dimension).
    pub fn overlaps(&self, other: &RegionSpec) -> bool {
        debug_assert_eq!(self.d(), other.d());
        self.ranges
            .iter()
            .zip(&other.ranges)
            .all(|(&(alo, ahi), &(blo, bhi))| alo < bhi && blo < ahi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        let spec = RegionSpec::rect((0, 10), (5, 8));
        let ivs = spec.to_intervals();
        assert_eq!(ivs[0], Interval::new(0.0, 10.0));
        assert_eq!(ivs[1], Interval::new(5.0, 8.0));
    }

    #[test]
    fn overlap_semantics() {
        let a = RegionSpec::rect((0, 10), (0, 10));
        let b = RegionSpec::rect((5, 15), (9, 20));
        let c = RegionSpec::rect((10, 15), (0, 10)); // touches a on x
        assert!(a.overlaps(&b));
        assert!(!a.overlaps(&c));
    }
}

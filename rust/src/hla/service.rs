//! The DDM service: federates, region registration, session-driven
//! matching and notification routing (the paper's Fig. 1 scenario, as
//! a library).
//!
//! The service runs **entirely on an incremental
//! [`DdmSession`](crate::session::DdmSession)**: register, modify and
//! delete stage batched ops keyed by region handle id; every read path
//! ([`publish`](DdmService::publish), [`match_all`](DdmService::match_all),
//! [`overlapping_subscriptions`](DdmService::overlapping_subscriptions))
//! first flushes the staged batch (epoch stays open, so interleaved
//! reads never swallow a diff) and answers from the session's
//! wait-free [`EpochSnapshot`](crate::session::EpochSnapshot) — no
//! full re-match anywhere, and federate
//! notifications are driven by the
//! [`MatchDiff`](crate::session::MatchDiff)-maintained state (see
//! [`notify_new_matches`](DdmService::notify_new_matches) for the
//! literal diff-to-mailbox path).
//!
//! The service stays **algorithm- and configuration-agnostic**: the
//! injected [`DdmEngine`](crate::engine::DdmEngine) supplies the worker
//! pool and the session knobs (diff retention set, epoch batching
//! threshold, parallel-apply cutoff — see the
//! [`EngineBuilder`](crate::engine::EngineBuilder) session methods).
//! Swapping any of that is a builder change; the service code does not
//! move.

use std::collections::VecDeque;

use crate::bail;
use crate::core::Interval;
use crate::engine::DdmEngine;
use crate::error::{Context, Result};
use crate::session::MatchDiff;
use crate::shard::{AnySession, ShardStats};

use super::region::{RegionHandle, RegionKind, RegionSpec};
use super::space::RoutingSpace;

/// Identifies a joined federate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FederateId(pub u32);

/// An update notification delivered to a subscribing federate.
#[derive(Debug, Clone, PartialEq)]
pub struct Notification {
    pub from: FederateId,
    pub update: RegionHandle,
    pub subscription: RegionHandle,
    pub payload: u64,
}

struct Federate {
    name: String,
    mailbox: VecDeque<Notification>,
}

/// One side's registered regions, keyed by handle id (the same key
/// space the session indexes use — handles never need translation).
struct RegionTable {
    records: Vec<Option<(RegionSpec, FederateId)>>,
    live: usize,
}

impl RegionTable {
    fn new() -> Self {
        Self {
            records: Vec::new(),
            live: 0,
        }
    }

    fn insert(&mut self, spec: RegionSpec, owner: FederateId) -> u32 {
        let id = self.records.len() as u32;
        self.records.push(Some((spec, owner)));
        self.live += 1;
        id
    }

    fn get(&self, id: u32) -> Result<&(RegionSpec, FederateId)> {
        self.records
            .get(id as usize)
            .and_then(|r| r.as_ref())
            .with_context(|| format!("region handle {id} is not registered"))
    }

    fn set_spec(&mut self, id: u32, spec: RegionSpec) -> Result<()> {
        match self.records.get_mut(id as usize).and_then(|r| r.as_mut()) {
            Some(rec) => {
                rec.0 = spec;
                Ok(())
            }
            None => bail!("region handle {id} is not registered"),
        }
    }

    fn remove(&mut self, id: u32) -> Result<()> {
        let taken = self.records.get_mut(id as usize).and_then(|slot| slot.take());
        if taken.is_none() {
            bail!("region handle {id} is not registered");
        }
        self.live -= 1;
        Ok(())
    }
}

/// The Data Distribution Management service.
pub struct DdmService {
    space: RoutingSpace,
    engine: DdmEngine,
    federates: Vec<Federate>,
    subs: RegionTable,
    upds: RegionTable,
    /// The epoch-based incremental matching state — a plain session,
    /// or a sharded one when the engine was built with
    /// [`shards`](crate::engine::EngineBuilder::shards) > 1 (the
    /// stripes span the routing space's split dimension). Every region
    /// op is staged here (keyed by handle id); reads flush first.
    session: AnySession,
    /// Counters.
    pub notifications_routed: u64,
    pub matches_run: u64,
    pub epochs_committed: u64,
}

impl DdmService {
    /// Service with the default engine (the builder's defaults).
    pub fn new(space: RoutingSpace) -> Self {
        Self::with_engine(space, DdmEngine::default())
    }

    /// Service running on the given engine's pool and session knobs.
    /// An engine built with `shards(n > 1)` gives the service a
    /// [`ShardedSession`](crate::shard::ShardedSession) striping the
    /// routing space's split-dimension extent.
    pub fn with_engine(space: RoutingSpace, engine: DdmEngine) -> Self {
        let d = space.d().max(1);
        let split = engine.shard_params().split_dim.min(d - 1);
        let upper = space
            .dimensions
            .get(split)
            .map(|dim| dim.upper as f64)
            .unwrap_or(1.0)
            .max(1.0);
        let session = engine.any_session(d, Interval::new(0.0, upper));
        Self {
            space,
            engine,
            federates: Vec::new(),
            subs: RegionTable::new(),
            upds: RegionTable::new(),
            session,
            notifications_routed: 0,
            matches_run: 0,
            epochs_committed: 0,
        }
    }

    pub fn space(&self) -> &RoutingSpace {
        &self.space
    }

    pub fn engine(&self) -> &DdmEngine {
        &self.engine
    }

    /// The underlying incremental session (epoch counter, retained
    /// pair set, staged-op count, shard count).
    pub fn session(&self) -> &AnySession {
        &self.session
    }

    /// Per-shard load snapshot (`None` when the engine is unsharded) —
    /// the coordinator's per-shard metrics and imbalance gauge read
    /// this after each commit.
    pub fn shard_stats(&self) -> Option<Vec<ShardStats>> {
        self.session.shard_stats()
    }

    pub fn n_subscriptions(&self) -> usize {
        self.subs.live
    }

    pub fn n_updates(&self) -> usize {
        self.upds.live
    }

    // ---- federates -------------------------------------------------------

    pub fn join(&mut self, name: impl Into<String>) -> FederateId {
        let id = FederateId(self.federates.len() as u32);
        self.federates.push(Federate {
            name: name.into(),
            mailbox: VecDeque::new(),
        });
        id
    }

    pub fn federate_name(&self, id: FederateId) -> Option<&str> {
        self.federates.get(id.0 as usize).map(|f| f.name.as_str())
    }

    /// Drain a federate's mailbox.
    pub fn poll(&mut self, id: FederateId) -> Vec<Notification> {
        match self.federates.get_mut(id.0 as usize) {
            Some(f) => f.mailbox.drain(..).collect(),
            None => Vec::new(),
        }
    }

    pub fn mailbox_len(&self, id: FederateId) -> usize {
        self.federates
            .get(id.0 as usize)
            .map_or(0, |f| f.mailbox.len())
    }

    // ---- region registration (staged ops) ----------------------------------

    pub fn register(
        &mut self,
        fed: FederateId,
        kind: RegionKind,
        spec: &RegionSpec,
    ) -> Result<RegionHandle> {
        self.space.validate_ranges(&spec.ranges)?;
        if fed.0 as usize >= self.federates.len() {
            bail!("federate {} has not joined", fed.0);
        }
        let ivs = spec.to_intervals();
        let id = match kind {
            RegionKind::Subscription => {
                let id = self.subs.insert(spec.clone(), fed);
                self.session.upsert_subscription(id, &ivs);
                id
            }
            RegionKind::Update => {
                let id = self.upds.insert(spec.clone(), fed);
                self.session.upsert_update(id, &ivs);
                id
            }
        };
        Ok(RegionHandle { kind, id })
    }

    pub fn modify(&mut self, handle: RegionHandle, spec: &RegionSpec) -> Result<()> {
        self.space.validate_ranges(&spec.ranges)?;
        let ivs = spec.to_intervals();
        match handle.kind {
            RegionKind::Subscription => {
                self.subs.set_spec(handle.id, spec.clone())?;
                self.session.upsert_subscription(handle.id, &ivs);
            }
            RegionKind::Update => {
                self.upds.set_spec(handle.id, spec.clone())?;
                self.session.upsert_update(handle.id, &ivs);
            }
        }
        Ok(())
    }

    pub fn delete(&mut self, handle: RegionHandle) -> Result<()> {
        match handle.kind {
            RegionKind::Subscription => {
                self.subs.remove(handle.id)?;
                self.session.remove_subscription(handle.id);
            }
            RegionKind::Update => {
                self.upds.remove(handle.id)?;
                self.session.remove_update(handle.id);
            }
        }
        Ok(())
    }

    // ---- epochs and matching ------------------------------------------------

    /// Commit the staged epoch: apply all batched region ops and return
    /// the intersection delta. The diff's keys ARE region handle ids
    /// (subscription id, update id).
    pub fn commit(&mut self) -> MatchDiff {
        self.epochs_committed += 1;
        self.session.commit()
    }

    /// Apply staged ops so reads see current state — WITHOUT closing
    /// the epoch: the accumulated churn stays queued, so an interleaved
    /// read never swallows the diff a later [`commit`](Self::commit) /
    /// [`notify_new_matches`](Self::notify_new_matches) reports.
    fn sync(&mut self) {
        self.session.flush();
    }

    /// Every overlapping (subscription, update) handle pair — answered
    /// from the session's wait-free
    /// [`EpochSnapshot`](crate::session::EpochSnapshot) in O(K), never
    /// re-matched (the preceding sync republishes, so the snapshot is
    /// current).
    pub fn match_all(&mut self) -> Vec<(RegionHandle, RegionHandle)> {
        self.sync();
        self.matches_run += 1;
        self.session
            .snapshot()
            .pairs()
            .into_iter()
            .map(|(s, u)| {
                (
                    RegionHandle {
                        kind: RegionKind::Subscription,
                        id: s,
                    },
                    RegionHandle {
                        kind: RegionKind::Update,
                        id: u,
                    },
                )
            })
            .collect()
    }

    /// Subscriptions overlapping one update region (the publish path):
    /// an O(K_u) read of the session's wait-free snapshot.
    pub fn overlapping_subscriptions(&mut self, update: RegionHandle) -> Result<Vec<RegionHandle>> {
        if update.kind != RegionKind::Update {
            bail!("overlapping_subscriptions takes an update handle");
        }
        self.sync();
        self.upds.get(update.id)?;
        Ok(self
            .session
            .snapshot()
            .subscriptions_of(update.id)
            .into_iter()
            .map(|id| RegionHandle {
                kind: RegionKind::Subscription,
                id,
            })
            .collect())
    }

    /// Publish an update: route `payload` to every federate owning an
    /// overlapping subscription (at-most-once per overlapping region).
    pub fn publish(&mut self, update: RegionHandle, payload: u64) -> Result<usize> {
        let targets = self.overlapping_subscriptions(update)?;
        let from = self.upds.get(update.id)?.1;
        let mut delivered = 0;
        for sub in targets {
            let owner = self.subs.get(sub.id)?.1;
            self.federates[owner.0 as usize]
                .mailbox
                .push_back(Notification {
                    from,
                    update,
                    subscription: sub,
                    payload,
                });
            delivered += 1;
        }
        self.notifications_routed += delivered as u64;
        Ok(delivered)
    }

    /// Commit the epoch and deliver one notification per **newly
    /// appeared** pair to the subscription's owner — match discovery
    /// driven literally by the epoch's [`MatchDiff`], instead of
    /// re-matching and re-notifying the whole pair set.
    pub fn notify_new_matches(&mut self, payload: u64) -> Result<usize> {
        let diff = self.commit();
        let mut delivered = 0usize;
        for &(s, u) in &diff.added {
            let owner = self.subs.get(s)?.1;
            let from = self.upds.get(u)?.1;
            self.federates[owner.0 as usize]
                .mailbox
                .push_back(Notification {
                    from,
                    update: RegionHandle {
                        kind: RegionKind::Update,
                        id: u,
                    },
                    subscription: RegionHandle {
                        kind: RegionKind::Subscription,
                        id: s,
                    },
                    payload,
                });
            delivered += 1;
        }
        self.notifications_routed += delivered as u64;
        Ok(delivered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::Algo;

    fn engine(algo: Algo) -> DdmEngine {
        DdmEngine::builder().algo(algo).threads(2).ncells(64).build()
    }

    fn two_fed_service() -> (DdmService, FederateId, FederateId) {
        let mut svc = DdmService::with_engine(
            RoutingSpace::uniform(2, 1000),
            engine(Algo::Psbm),
        );
        let a = svc.join("vehicles");
        let b = svc.join("lights");
        (svc, a, b)
    }

    #[test]
    fn register_match_publish_roundtrip() {
        let (mut svc, veh, lights) = two_fed_service();
        let s1 = svc
            .register(veh, RegionKind::Subscription, &RegionSpec::rect((0, 100), (0, 100)))
            .unwrap();
        let _s2 = svc
            .register(veh, RegionKind::Subscription, &RegionSpec::rect((500, 600), (0, 100)))
            .unwrap();
        let u1 = svc
            .register(lights, RegionKind::Update, &RegionSpec::rect((50, 150), (50, 150)))
            .unwrap();

        // match_all sees exactly (s1, u1).
        let pairs = svc.match_all();
        assert_eq!(pairs, vec![(s1, u1)]);

        // publish routes one notification to the vehicles federate.
        let delivered = svc.publish(u1, 42).unwrap();
        assert_eq!(delivered, 1);
        let mail = svc.poll(veh);
        assert_eq!(mail.len(), 1);
        assert_eq!(mail[0].payload, 42);
        assert_eq!(mail[0].subscription, s1);
        assert!(svc.poll(veh).is_empty(), "mailbox drained");
    }

    #[test]
    fn validation_rejects_out_of_space() {
        let (mut svc, veh, _) = two_fed_service();
        let err = svc.register(
            veh,
            RegionKind::Subscription,
            &RegionSpec::rect((0, 100), (0, 2000)),
        );
        assert!(err.is_err());
    }

    #[test]
    fn modify_moves_matches() {
        let (mut svc, veh, lights) = two_fed_service();
        let s = svc
            .register(veh, RegionKind::Subscription, &RegionSpec::rect((0, 10), (0, 10)))
            .unwrap();
        let u = svc
            .register(lights, RegionKind::Update, &RegionSpec::rect((50, 60), (0, 10)))
            .unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![]);
        svc.modify(s, &RegionSpec::rect((55, 65), (0, 10))).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![s]);
        svc.modify(u, &RegionSpec::rect((100, 110), (0, 10))).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![]);
    }

    #[test]
    fn delete_keeps_other_handles_stable() {
        let (mut svc, veh, lights) = two_fed_service();
        let spec = |x: u64| RegionSpec::rect((x, x + 10), (0, 10));
        let s0 = svc.register(veh, RegionKind::Subscription, &spec(0)).unwrap();
        let s1 = svc.register(veh, RegionKind::Subscription, &spec(100)).unwrap();
        let s2 = svc.register(veh, RegionKind::Subscription, &spec(200)).unwrap();
        let u = svc
            .register(lights, RegionKind::Update, &RegionSpec::rect((205, 215), (0, 10)))
            .unwrap();
        svc.delete(s0).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![s2]);
        assert_eq!(svc.n_subscriptions(), 2);
        svc.delete(s2).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![]);
        // s1 still valid.
        svc.modify(s1, &spec(210)).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![s1]);
        // deleted handles error.
        assert!(svc.modify(s0, &spec(0)).is_err());
        assert!(svc.delete(s0).is_err());
    }

    #[test]
    fn publish_fans_out_to_multiple_federates() {
        let mut svc =
            DdmService::with_engine(RoutingSpace::uniform(1, 1000), engine(Algo::Itm));
        let feds: Vec<FederateId> = (0..4).map(|i| svc.join(format!("f{i}"))).collect();
        for &f in &feds {
            svc.register(f, RegionKind::Subscription, &RegionSpec::interval(0, 500))
                .unwrap();
        }
        let pub_fed = svc.join("publisher");
        let u = svc
            .register(pub_fed, RegionKind::Update, &RegionSpec::interval(100, 200))
            .unwrap();
        let delivered = svc.publish(u, 7).unwrap();
        assert_eq!(delivered, 4);
        for &f in &feds {
            assert_eq!(svc.mailbox_len(f), 1);
        }
        assert_eq!(svc.notifications_routed, 4);
    }

    /// The service's epoch commit reports exactly the pair delta, and
    /// repeated commits of an untouched service are empty.
    #[test]
    fn epoch_commit_reports_match_diffs() {
        let (mut svc, veh, lights) = two_fed_service();
        let s = svc
            .register(veh, RegionKind::Subscription, &RegionSpec::rect((0, 100), (0, 100)))
            .unwrap();
        let u = svc
            .register(lights, RegionKind::Update, &RegionSpec::rect((50, 150), (50, 150)))
            .unwrap();
        let d1 = svc.commit();
        assert_eq!(d1.added, vec![(s.id, u.id)]);
        assert!(d1.removed.is_empty());

        svc.modify(s, &RegionSpec::rect((500, 600), (0, 100))).unwrap();
        let d2 = svc.commit();
        assert_eq!(d2.removed, vec![(s.id, u.id)]);
        assert!(d2.added.is_empty());

        let d3 = svc.commit();
        assert!(d3.is_empty());
        assert_eq!(svc.session().epoch(), 3);
        assert_eq!(svc.epochs_committed, 3);
    }

    /// Reads interleaved between staging and commit must NOT swallow
    /// the epoch diff (sync flushes, it does not commit).
    #[test]
    fn reads_do_not_swallow_epoch_diffs() {
        let (mut svc, veh, lights) = two_fed_service();
        let s = svc
            .register(veh, RegionKind::Subscription, &RegionSpec::rect((0, 100), (0, 100)))
            .unwrap();
        let u = svc
            .register(lights, RegionKind::Update, &RegionSpec::rect((50, 150), (50, 150)))
            .unwrap();
        // A full read between staging and commit…
        assert_eq!(svc.match_all().len(), 1);
        // …must leave the diff intact.
        let d = svc.commit();
        assert_eq!(d.added, vec![(s.id, u.id)]);

        // Same through the diff-driven notification path, with a
        // publish-path read interleaved.
        svc.modify(u, &RegionSpec::rect((500, 600), (500, 600))).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![]);
        assert_eq!(svc.notify_new_matches(8).unwrap(), 0); // removal only
        svc.modify(u, &RegionSpec::rect((50, 150), (50, 150))).unwrap();
        assert_eq!(svc.match_all().len(), 1); // read interleaves again
        assert_eq!(svc.notify_new_matches(9).unwrap(), 1, "still delivered");
        assert_eq!(svc.poll(veh).len(), 1);
    }

    /// Diff-driven match notifications: only newly appeared pairs hit
    /// the mailboxes — repeats and removals deliver nothing.
    #[test]
    fn notify_new_matches_is_diff_driven() {
        let (mut svc, veh, lights) = two_fed_service();
        let s = svc
            .register(veh, RegionKind::Subscription, &RegionSpec::rect((0, 100), (0, 100)))
            .unwrap();
        let u = svc
            .register(lights, RegionKind::Update, &RegionSpec::rect((50, 150), (50, 150)))
            .unwrap();
        assert_eq!(svc.notify_new_matches(1).unwrap(), 1);
        let mail = svc.poll(veh);
        assert_eq!(mail.len(), 1);
        assert_eq!(mail[0].subscription, s);
        assert_eq!(mail[0].update, u);

        // Nothing changed: nothing delivered.
        assert_eq!(svc.notify_new_matches(2).unwrap(), 0);

        // Pair removed: still nothing delivered (only additions notify).
        svc.modify(u, &RegionSpec::rect((500, 600), (500, 600))).unwrap();
        assert_eq!(svc.notify_new_matches(3).unwrap(), 0);

        // Pair re-appears: one delivery again.
        svc.modify(u, &RegionSpec::rect((50, 150), (50, 150))).unwrap();
        assert_eq!(svc.notify_new_matches(4).unwrap(), 1);
        assert_eq!(svc.poll(veh).len(), 1);
    }

    /// The acceptance scenario: the same HLA notification workload runs
    /// under engines with different matchers and produces identical
    /// notifications. Swapping the algorithm (or session knobs) is
    /// purely an `EngineBuilder` change.
    #[test]
    fn notification_scenario_is_engine_invariant() {
        fn run_scenario(
            engine: DdmEngine,
        ) -> (Vec<(RegionHandle, RegionHandle)>, Vec<Notification>) {
            let mut svc = DdmService::with_engine(RoutingSpace::uniform(2, 10_000), engine);
            let watchers = svc.join("watchers");
            let movers = svc.join("movers");
            let mut rng = crate::prng::Rng::new(0x5CEA);
            let mut subs = Vec::new();
            for _ in 0..60 {
                let x = rng.below(9000);
                let y = rng.below(9000);
                subs.push(
                    svc.register(
                        watchers,
                        RegionKind::Subscription,
                        &RegionSpec::rect((x, x + 600), (y, y + 600)),
                    )
                    .unwrap(),
                );
            }
            let mut upds = Vec::new();
            for _ in 0..40 {
                let x = rng.below(9000);
                let y = rng.below(9000);
                upds.push(
                    svc.register(
                        movers,
                        RegionKind::Update,
                        &RegionSpec::rect((x, x + 400), (y, y + 400)),
                    )
                    .unwrap(),
                );
            }
            // Churn: move a third of the subscriptions, delete a few.
            for (i, &s) in subs.iter().enumerate().take(20) {
                let x = rng.below(9000);
                svc.modify(s, &RegionSpec::rect((x, x + 600), (0, 600))).unwrap();
                if i % 5 == 0 {
                    svc.delete(s).unwrap();
                }
            }
            let mut pairs = svc.match_all();
            pairs.sort_by_key(|(a, b)| (a.id, b.id));
            let mut mail = Vec::new();
            for (step, &u) in upds.iter().enumerate() {
                svc.publish(u, step as u64).unwrap();
            }
            mail.extend(svc.poll(watchers));
            (pairs, mail)
        }

        let algos = [Algo::Itm, Algo::Psbm, Algo::Gbm, Algo::SbmBinary];
        let (ref_pairs, ref_mail) = run_scenario(engine(algos[0]));
        assert!(!ref_mail.is_empty());
        for &algo in &algos[1..] {
            let (pairs, mail) = run_scenario(engine(algo));
            assert_eq!(pairs, ref_pairs, "{}", algo.name());
            assert_eq!(mail, ref_mail, "{}", algo.name());
        }
        // The adaptive engine routes the same notifications…
        let auto = DdmEngine::builder().auto().threads(3).build();
        let (pairs, mail) = run_scenario(auto);
        assert_eq!(pairs, ref_pairs);
        assert_eq!(mail, ref_mail);
        // …and so do different session configurations (eager batching,
        // forced parallel apply, different retention set).
        let tuned = DdmEngine::builder()
            .threads(3)
            .batch_threshold(8)
            .parallel_cutoff(1)
            .session_set_impl(crate::sets::SetImpl::Bit)
            .build();
        let (pairs, mail) = run_scenario(tuned);
        assert_eq!(pairs, ref_pairs);
        assert_eq!(mail, ref_mail);
        // …and spatially sharded services (uniform and balanced cuts)
        // route the identical notifications — sharding is invisible at
        // the service surface.
        let sharded = DdmEngine::builder().threads(3).shards(4).parallel_cutoff(1).build();
        let (pairs, mail) = run_scenario(sharded);
        assert_eq!(pairs, ref_pairs, "sharded");
        assert_eq!(mail, ref_mail, "sharded");
        let balanced = DdmEngine::builder().threads(2).shards(3).balanced_shards().build();
        let (pairs, mail) = run_scenario(balanced);
        assert_eq!(pairs, ref_pairs, "balanced-sharded");
        assert_eq!(mail, ref_mail, "balanced-sharded");
    }

    /// A sharded service exposes per-shard stats, and regions land in
    /// the stripes of the routing space's split dimension.
    #[test]
    fn sharded_service_exposes_shard_stats() {
        let mut svc = DdmService::with_engine(
            RoutingSpace::uniform(2, 1000),
            DdmEngine::builder().threads(2).shards(4).build(),
        );
        let f = svc.join("f");
        // One subscription per stripe of dim 0 (stripe width 250).
        for i in 0..4u64 {
            let x = i * 250 + 10;
            svc.register(f, RegionKind::Subscription, &RegionSpec::rect((x, x + 50), (0, 100)))
                .unwrap();
        }
        // One wide update crossing all stripes.
        let u = svc
            .register(f, RegionKind::Update, &RegionSpec::rect((0, 1000), (0, 100)))
            .unwrap();
        let diff = svc.commit();
        assert_eq!(diff.added.len(), 4, "one pair per stripe, each dedup'd");
        let stats = svc.shard_stats().expect("sharded engine exposes stats");
        assert_eq!(stats.len(), 4);
        assert!(stats.iter().all(|s| s.subscriptions == 1 && s.updates == 1), "{stats:?}");
        assert_eq!(svc.session().shards(), 4);
        assert_eq!(svc.session().imbalance(), Some(1.0));
        // Publish still routes exactly once per overlapping pair.
        assert_eq!(svc.publish(u, 5).unwrap(), 4);
        assert_eq!(svc.poll(f).len(), 4);
    }

    #[test]
    fn match_all_engines_agree_on_service_state() {
        let mut handles: Vec<Vec<(RegionHandle, RegionHandle)>> = Vec::new();
        for algo in Algo::ALL {
            let mut svc = DdmService::with_engine(
                RoutingSpace::uniform(2, 10_000),
                engine(algo),
            );
            let f = svc.join("f");
            let mut rng = crate::prng::Rng::new(0x44A);
            for _ in 0..80 {
                let x = rng.below(9000);
                let y = rng.below(9000);
                svc.register(
                    f,
                    RegionKind::Subscription,
                    &RegionSpec::rect((x, x + 500), (y, y + 500)),
                )
                .unwrap();
            }
            for _ in 0..60 {
                let x = rng.below(9000);
                let y = rng.below(9000);
                svc.register(
                    f,
                    RegionKind::Update,
                    &RegionSpec::rect((x, x + 400), (y, y + 400)),
                )
                .unwrap();
            }
            let mut pairs = svc.match_all();
            pairs.sort_by_key(|(a, b)| (a.id, b.id));
            handles.push(pairs);
        }
        for w in handles.windows(2) {
            assert_eq!(w[0], w[1]);
        }
        assert!(!handles[0].is_empty());
    }

    /// match_all answers from the retained pair set and agrees with a
    /// fresh static match over the same live regions.
    #[test]
    fn match_all_agrees_with_static_rematch() {
        let (mut svc, veh, _) = two_fed_service();
        let mut rng = crate::prng::Rng::new(0x117);
        let mut handles = Vec::new();
        for _ in 0..50 {
            let x = rng.below(900);
            let y = rng.below(900);
            let spec = RegionSpec::rect((x, x + 80), (y, y + 80));
            handles.push(svc.register(veh, RegionKind::Subscription, &spec).unwrap());
        }
        for _ in 0..40 {
            let x = rng.below(900);
            let y = rng.below(900);
            svc.register(veh, RegionKind::Update, &RegionSpec::rect((x, x + 60), (y, y + 60)))
                .unwrap();
        }
        for &h in handles.iter().take(10) {
            svc.delete(h).unwrap();
        }
        let pairs = svc.match_all();
        // Static reference: match the live specs directly.
        let mut want = Vec::new();
        for (si, srec) in svc.subs.records.iter().enumerate() {
            let Some((sspec, _)) = srec else { continue };
            for (ui, urec) in svc.upds.records.iter().enumerate() {
                let Some((uspec, _)) = urec else { continue };
                if sspec.overlaps(uspec) {
                    want.push((
                        RegionHandle {
                            kind: RegionKind::Subscription,
                            id: si as u32,
                        },
                        RegionHandle {
                            kind: RegionKind::Update,
                            id: ui as u32,
                        },
                    ));
                }
            }
        }
        assert_eq!(pairs, want);
        assert!(!pairs.is_empty());
    }
}

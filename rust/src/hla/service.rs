//! The DDM service: federates, region registration, matching and
//! notification routing (the paper's Fig. 1 scenario, as a library).
//!
//! The service is **algorithm-agnostic**: it never names a concrete
//! matcher. All matching goes through the injected
//! [`DdmEngine`](crate::engine::DdmEngine) — full matches via the
//! engine's N-D path, the publish hot path via the engine's
//! [`DynamicMatcher`](crate::engine::DynamicMatcher) index over
//! dimension 0 of the subscription set (an incremental interval tree
//! for every in-tree algorithm family, rebuild-on-write for custom
//! backends with their own matching semantics). Swapping the
//! algorithm is an [`EngineBuilder`](crate::engine::EngineBuilder)
//! change; the service code does not move.

use std::collections::VecDeque;

use crate::bail;
use crate::engine::{DdmEngine, DynamicMatcher};
use crate::error::{Context, Result};

use super::region::{RegionHandle, RegionKind, RegionSpec};
use super::space::RoutingSpace;
use crate::core::interval::Interval;
use crate::core::RegionsNd;

/// Identifies a joined federate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FederateId(pub u32);

/// An update notification delivered to a subscribing federate.
#[derive(Debug, Clone, PartialEq)]
pub struct Notification {
    pub from: FederateId,
    pub update: RegionHandle,
    pub subscription: RegionHandle,
    pub payload: u64,
}

struct Federate {
    name: String,
    mailbox: VecDeque<Notification>,
}

/// Dense storage of one side's regions with stable handles.
struct SideStore {
    regions: RegionsNd,
    owner: Vec<FederateId>,
    /// dense index -> handle id
    handle_of: Vec<u32>,
    /// handle id -> dense index (None = deleted)
    index_of: Vec<Option<u32>>,
}

impl SideStore {
    fn new(d: usize) -> Self {
        Self {
            regions: RegionsNd::new(d),
            owner: Vec::new(),
            handle_of: Vec::new(),
            index_of: Vec::new(),
        }
    }

    fn len(&self) -> usize {
        self.regions.len()
    }

    fn insert(&mut self, spec: &RegionSpec, owner: FederateId) -> u32 {
        let handle_id = self.index_of.len() as u32;
        let dense = self.regions.len() as u32;
        self.regions.push(&spec.to_intervals());
        self.owner.push(owner);
        self.handle_of.push(handle_id);
        self.index_of.push(Some(dense));
        handle_id
    }

    fn dense(&self, handle_id: u32) -> Result<usize> {
        self.index_of
            .get(handle_id as usize)
            .copied()
            .flatten()
            .map(|i| i as usize)
            .with_context(|| format!("region handle {handle_id} is not registered"))
    }

    /// Swap-remove, fixing up the displaced region's handle mapping.
    fn delete(&mut self, handle_id: u32) -> Result<()> {
        let i = self.dense(handle_id)?;
        let last = self.regions.len() - 1;
        for dim in self.regions.dims.iter_mut() {
            dim.lo.swap_remove(i);
            dim.hi.swap_remove(i);
        }
        self.owner.swap_remove(i);
        let moved_handle = self.handle_of[last];
        self.handle_of.swap_remove(i);
        if i <= last && i < self.handle_of.len() {
            self.index_of[moved_handle as usize] = Some(i as u32);
        }
        self.index_of[handle_id as usize] = None;
        Ok(())
    }

    fn modify(&mut self, handle_id: u32, spec: &RegionSpec) -> Result<()> {
        let i = self.dense(handle_id)?;
        for (k, iv) in spec.to_intervals().into_iter().enumerate() {
            self.regions.dims[k].set(i, iv);
        }
        Ok(())
    }
}

/// The Data Distribution Management service.
pub struct DdmService {
    space: RoutingSpace,
    engine: DdmEngine,
    federates: Vec<Federate>,
    subs: SideStore,
    upds: SideStore,
    /// Dynamic index over dimension 0 of the subscriptions (publish
    /// path), keyed by subscription **handle id** — stable across
    /// swap-removal, unlike dense indices.
    sub_index: Box<dyn DynamicMatcher>,
    /// Counters.
    pub notifications_routed: u64,
    pub matches_run: u64,
}

impl DdmService {
    /// Service with the default engine (the builder's defaults).
    pub fn new(space: RoutingSpace) -> Self {
        Self::with_engine(space, DdmEngine::default())
    }

    /// Service running every match on the given engine.
    pub fn with_engine(space: RoutingSpace, engine: DdmEngine) -> Self {
        let d = space.d().max(1);
        let sub_index = engine.dynamic();
        Self {
            space,
            engine,
            federates: Vec::new(),
            subs: SideStore::new(d),
            upds: SideStore::new(d),
            sub_index,
            notifications_routed: 0,
            matches_run: 0,
        }
    }

    pub fn space(&self) -> &RoutingSpace {
        &self.space
    }

    pub fn engine(&self) -> &DdmEngine {
        &self.engine
    }

    pub fn n_subscriptions(&self) -> usize {
        self.subs.len()
    }

    pub fn n_updates(&self) -> usize {
        self.upds.len()
    }

    // ---- federates -------------------------------------------------------

    pub fn join(&mut self, name: impl Into<String>) -> FederateId {
        let id = FederateId(self.federates.len() as u32);
        self.federates.push(Federate {
            name: name.into(),
            mailbox: VecDeque::new(),
        });
        id
    }

    pub fn federate_name(&self, id: FederateId) -> Option<&str> {
        self.federates.get(id.0 as usize).map(|f| f.name.as_str())
    }

    /// Drain a federate's mailbox.
    pub fn poll(&mut self, id: FederateId) -> Vec<Notification> {
        match self.federates.get_mut(id.0 as usize) {
            Some(f) => f.mailbox.drain(..).collect(),
            None => Vec::new(),
        }
    }

    pub fn mailbox_len(&self, id: FederateId) -> usize {
        self.federates
            .get(id.0 as usize)
            .map_or(0, |f| f.mailbox.len())
    }

    // ---- region registration ----------------------------------------------

    pub fn register(
        &mut self,
        fed: FederateId,
        kind: RegionKind,
        spec: &RegionSpec,
    ) -> Result<RegionHandle> {
        self.space.validate_ranges(&spec.ranges)?;
        if fed.0 as usize >= self.federates.len() {
            bail!("federate {} has not joined", fed.0);
        }
        let store = match kind {
            RegionKind::Subscription => &mut self.subs,
            RegionKind::Update => &mut self.upds,
        };
        let id = store.insert(spec, fed);
        if kind == RegionKind::Subscription {
            self.sub_index.insert(id, dim0(spec));
        }
        Ok(RegionHandle { kind, id })
    }

    pub fn modify(&mut self, handle: RegionHandle, spec: &RegionSpec) -> Result<()> {
        self.space.validate_ranges(&spec.ranges)?;
        match handle.kind {
            RegionKind::Subscription => {
                self.subs.modify(handle.id, spec)?;
                self.sub_index.modify(handle.id, dim0(spec));
            }
            RegionKind::Update => self.upds.modify(handle.id, spec)?,
        }
        Ok(())
    }

    pub fn delete(&mut self, handle: RegionHandle) -> Result<()> {
        match handle.kind {
            RegionKind::Subscription => {
                self.subs.delete(handle.id)?;
                self.sub_index.remove(handle.id);
            }
            RegionKind::Update => self.upds.delete(handle.id)?,
        }
        Ok(())
    }

    // ---- matching ----------------------------------------------------------

    /// Full match on the injected engine: every overlapping
    /// (subscription, update) handle pair.
    pub fn match_all(&mut self) -> Vec<(RegionHandle, RegionHandle)> {
        self.matches_run += 1;
        self.engine
            .pairs_nd(&self.subs.regions, &self.upds.regions)
            .into_iter()
            .map(|(si, uj)| {
                (
                    RegionHandle {
                        kind: RegionKind::Subscription,
                        id: self.subs.handle_of[si as usize],
                    },
                    RegionHandle {
                        kind: RegionKind::Update,
                        id: self.upds.handle_of[uj as usize],
                    },
                )
            })
            .collect()
    }

    /// Subscriptions overlapping one update region (the publish path):
    /// dimension-0 candidates from the engine's dynamic index,
    /// filtered on the remaining dimensions (§3's dynamic usage).
    pub fn overlapping_subscriptions(&mut self, update: RegionHandle) -> Result<Vec<RegionHandle>> {
        if update.kind != RegionKind::Update {
            bail!("overlapping_subscriptions takes an update handle");
        }
        let uj = self.upds.dense(update.id)?;
        let q0 = self.upds.regions.dims[0].get(uj);
        let mut keys = Vec::new();
        let ctx = self.engine.ctx();
        self.sub_index.query(&ctx, q0, &mut keys);
        let mut out = Vec::new();
        for key in keys {
            let si = self.subs.dense(key)?;
            let ok = (1..self.subs.regions.d()).all(|k| {
                self.subs.regions.dims[k]
                    .get(si)
                    .intersects(&self.upds.regions.dims[k].get(uj))
            });
            if ok {
                out.push(RegionHandle {
                    kind: RegionKind::Subscription,
                    id: key,
                });
            }
        }
        Ok(out)
    }

    /// Publish an update: route `payload` to every federate owning an
    /// overlapping subscription (at-most-once per overlapping region).
    pub fn publish(&mut self, update: RegionHandle, payload: u64) -> Result<usize> {
        let targets = self.overlapping_subscriptions(update)?;
        let from = self.upds.owner[self.upds.dense(update.id)?];
        let mut delivered = 0;
        for sub in targets {
            let dense = self.subs.dense(sub.id)?;
            let owner = self.subs.owner[dense];
            self.federates[owner.0 as usize].mailbox.push_back(Notification {
                from,
                update,
                subscription: sub,
                payload,
            });
            delivered += 1;
        }
        self.notifications_routed += delivered as u64;
        Ok(delivered)
    }
}

/// Dimension-0 interval of a region spec (the publish-path index key
/// space; remaining dimensions are filtered at query time).
fn dim0(spec: &RegionSpec) -> Interval {
    let (lo, hi) = spec.ranges[0];
    Interval::new(lo as f64, hi as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::Algo;

    fn engine(algo: Algo) -> DdmEngine {
        DdmEngine::builder().algo(algo).threads(2).ncells(64).build()
    }

    fn two_fed_service() -> (DdmService, FederateId, FederateId) {
        let mut svc = DdmService::with_engine(
            RoutingSpace::uniform(2, 1000),
            engine(Algo::Psbm),
        );
        let a = svc.join("vehicles");
        let b = svc.join("lights");
        (svc, a, b)
    }

    #[test]
    fn register_match_publish_roundtrip() {
        let (mut svc, veh, lights) = two_fed_service();
        let s1 = svc
            .register(veh, RegionKind::Subscription, &RegionSpec::rect((0, 100), (0, 100)))
            .unwrap();
        let _s2 = svc
            .register(veh, RegionKind::Subscription, &RegionSpec::rect((500, 600), (0, 100)))
            .unwrap();
        let u1 = svc
            .register(lights, RegionKind::Update, &RegionSpec::rect((50, 150), (50, 150)))
            .unwrap();

        // match_all sees exactly (s1, u1).
        let pairs = svc.match_all();
        assert_eq!(pairs, vec![(s1, u1)]);

        // publish routes one notification to the vehicles federate.
        let delivered = svc.publish(u1, 42).unwrap();
        assert_eq!(delivered, 1);
        let mail = svc.poll(veh);
        assert_eq!(mail.len(), 1);
        assert_eq!(mail[0].payload, 42);
        assert_eq!(mail[0].subscription, s1);
        assert!(svc.poll(veh).is_empty(), "mailbox drained");
    }

    #[test]
    fn validation_rejects_out_of_space() {
        let (mut svc, veh, _) = two_fed_service();
        let err = svc.register(
            veh,
            RegionKind::Subscription,
            &RegionSpec::rect((0, 100), (0, 2000)),
        );
        assert!(err.is_err());
    }

    #[test]
    fn modify_moves_matches() {
        let (mut svc, veh, lights) = two_fed_service();
        let s = svc
            .register(veh, RegionKind::Subscription, &RegionSpec::rect((0, 10), (0, 10)))
            .unwrap();
        let u = svc
            .register(lights, RegionKind::Update, &RegionSpec::rect((50, 60), (0, 10)))
            .unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![]);
        svc.modify(s, &RegionSpec::rect((55, 65), (0, 10))).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![s]);
        svc.modify(u, &RegionSpec::rect((100, 110), (0, 10))).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![]);
    }

    #[test]
    fn delete_with_swap_keeps_handles_stable() {
        let (mut svc, veh, lights) = two_fed_service();
        let spec = |x: u64| RegionSpec::rect((x, x + 10), (0, 10));
        let s0 = svc.register(veh, RegionKind::Subscription, &spec(0)).unwrap();
        let s1 = svc.register(veh, RegionKind::Subscription, &spec(100)).unwrap();
        let s2 = svc.register(veh, RegionKind::Subscription, &spec(200)).unwrap();
        let u = svc
            .register(lights, RegionKind::Update, &RegionSpec::rect((205, 215), (0, 10)))
            .unwrap();
        svc.delete(s0).unwrap(); // swap-remove displaces s2
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![s2]);
        svc.delete(s2).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![]);
        // s1 still valid.
        svc.modify(s1, &spec(210)).unwrap();
        assert_eq!(svc.overlapping_subscriptions(u).unwrap(), vec![s1]);
        // deleted handles error.
        assert!(svc.modify(s0, &spec(0)).is_err());
    }

    #[test]
    fn publish_fans_out_to_multiple_federates() {
        let mut svc =
            DdmService::with_engine(RoutingSpace::uniform(1, 1000), engine(Algo::Itm));
        let feds: Vec<FederateId> = (0..4).map(|i| svc.join(format!("f{i}"))).collect();
        for &f in &feds {
            svc.register(f, RegionKind::Subscription, &RegionSpec::interval(0, 500))
                .unwrap();
        }
        let pub_fed = svc.join("publisher");
        let u = svc
            .register(pub_fed, RegionKind::Update, &RegionSpec::interval(100, 200))
            .unwrap();
        let delivered = svc.publish(u, 7).unwrap();
        assert_eq!(delivered, 4);
        for &f in &feds {
            assert_eq!(svc.mailbox_len(f), 1);
        }
        assert_eq!(svc.notifications_routed, 4);
    }

    /// The acceptance scenario: the same HLA notification workload runs
    /// under engines with different matchers (ITM's native index plus
    /// three other algorithm families and the adaptive engine) and
    /// produces identical notifications. Swapping the algorithm is
    /// purely an `EngineBuilder` change.
    #[test]
    fn notification_scenario_is_engine_invariant() {
        fn run_scenario(engine: DdmEngine) -> (Vec<(RegionHandle, RegionHandle)>, Vec<Notification>) {
            let mut svc = DdmService::with_engine(RoutingSpace::uniform(2, 10_000), engine);
            let watchers = svc.join("watchers");
            let movers = svc.join("movers");
            let mut rng = crate::prng::Rng::new(0x5CEA);
            let mut subs = Vec::new();
            for _ in 0..60 {
                let x = rng.below(9000);
                let y = rng.below(9000);
                subs.push(
                    svc.register(
                        watchers,
                        RegionKind::Subscription,
                        &RegionSpec::rect((x, x + 600), (y, y + 600)),
                    )
                    .unwrap(),
                );
            }
            let mut upds = Vec::new();
            for _ in 0..40 {
                let x = rng.below(9000);
                let y = rng.below(9000);
                upds.push(
                    svc.register(
                        movers,
                        RegionKind::Update,
                        &RegionSpec::rect((x, x + 400), (y, y + 400)),
                    )
                    .unwrap(),
                );
            }
            // Churn: move a third of the subscriptions, delete a few.
            for (i, &s) in subs.iter().enumerate().take(20) {
                let x = rng.below(9000);
                svc.modify(s, &RegionSpec::rect((x, x + 600), (0, 600))).unwrap();
                if i % 5 == 0 {
                    svc.delete(s).unwrap();
                }
            }
            let mut pairs = svc.match_all();
            pairs.sort_by_key(|(a, b)| (a.id, b.id));
            let mut mail = Vec::new();
            for (step, &u) in upds.iter().enumerate() {
                svc.publish(u, step as u64).unwrap();
            }
            mail.extend(svc.poll(watchers));
            (pairs, mail)
        }

        let algos = [Algo::Itm, Algo::Psbm, Algo::Gbm, Algo::SbmBinary];
        let (ref_pairs, ref_mail) = run_scenario(engine(algos[0]));
        assert!(!ref_mail.is_empty());
        for &algo in &algos[1..] {
            let (pairs, mail) = run_scenario(engine(algo));
            assert_eq!(pairs, ref_pairs, "{}", algo.name());
            assert_eq!(mail, ref_mail, "{}", algo.name());
        }
        // And the adaptive engine routes the same notifications too.
        let auto = DdmEngine::builder().auto().threads(3).build();
        let (pairs, mail) = run_scenario(auto);
        assert_eq!(pairs, ref_pairs);
        assert_eq!(mail, ref_mail);
    }

    #[test]
    fn match_all_engines_agree_on_service_state() {
        let mut handles: Vec<Vec<(RegionHandle, RegionHandle)>> = Vec::new();
        for algo in Algo::ALL {
            let mut svc = DdmService::with_engine(
                RoutingSpace::uniform(2, 10_000),
                engine(algo),
            );
            let f = svc.join("f");
            let mut rng = crate::prng::Rng::new(0x44A);
            for _ in 0..80 {
                let x = rng.below(9000);
                let y = rng.below(9000);
                svc.register(
                    f,
                    RegionKind::Subscription,
                    &RegionSpec::rect((x, x + 500), (y, y + 500)),
                )
                .unwrap();
            }
            for _ in 0..60 {
                let x = rng.below(9000);
                let y = rng.below(9000);
                svc.register(
                    f,
                    RegionKind::Update,
                    &RegionSpec::rect((x, x + 400), (y, y + 400)),
                )
                .unwrap();
            }
            let mut pairs = svc.match_all();
            pairs.sort_by_key(|(a, b)| (a.id, b.id));
            handles.push(pairs);
        }
        for w in handles.windows(2) {
            assert_eq!(w[0], w[1]);
        }
        assert!(!handles[0].is_empty());
    }
}

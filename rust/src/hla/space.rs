//! HLA dimensions and routing spaces (IEEE 1516 OMT, paper §1).

use crate::bail;
use crate::error::Result;

/// One HLA dimension: integer values `0..upper`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dimension {
    pub name: String,
    pub upper: u64,
}

impl Dimension {
    pub fn new(name: impl Into<String>, upper: u64) -> Self {
        Self {
            name: name.into(),
            upper,
        }
    }
}

/// An ordered set of dimensions (what region specs range over).
#[derive(Debug, Clone, Default)]
pub struct RoutingSpace {
    pub dimensions: Vec<Dimension>,
}

impl RoutingSpace {
    pub fn new(dimensions: Vec<Dimension>) -> Self {
        Self { dimensions }
    }

    /// Convenience: a d-dimensional space with uniform upper bound.
    pub fn uniform(d: usize, upper: u64) -> Self {
        Self {
            dimensions: (0..d)
                .map(|i| Dimension::new(format!("dim{i}"), upper))
                .collect(),
        }
    }

    pub fn d(&self) -> usize {
        self.dimensions.len()
    }

    pub fn dim_index(&self, name: &str) -> Option<usize> {
        self.dimensions.iter().position(|d| d.name == name)
    }

    /// Validate a per-dimension list of half-open integer ranges.
    pub fn validate_ranges(&self, ranges: &[(u64, u64)]) -> Result<()> {
        if ranges.len() != self.d() {
            bail!(
                "region has {} ranges but the space has {} dimensions",
                ranges.len(),
                self.d()
            );
        }
        for (k, &(lo, hi)) in ranges.iter().enumerate() {
            let dim = &self.dimensions[k];
            if lo > hi {
                bail!("dimension '{}': range [{lo}, {hi}) has lo > hi", dim.name);
            }
            if hi > dim.upper {
                bail!(
                    "dimension '{}': upper bound {hi} exceeds dimension bound {}",
                    dim.name,
                    dim.upper
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_space() {
        let s = RoutingSpace::uniform(2, 100);
        assert_eq!(s.d(), 2);
        assert_eq!(s.dim_index("dim1"), Some(1));
        assert_eq!(s.dim_index("nope"), None);
    }

    #[test]
    fn validation() {
        let s = RoutingSpace::uniform(2, 100);
        assert!(s.validate_ranges(&[(0, 10), (5, 100)]).is_ok());
        assert!(s.validate_ranges(&[(0, 10)]).is_err()); // wrong arity
        assert!(s.validate_ranges(&[(0, 10), (5, 101)]).is_err()); // over bound
        assert!(s.validate_ranges(&[(11, 10), (0, 1)]).is_err()); // lo > hi
    }
}

//! # ddm — Parallel Data Distribution Management
//!
//! A reproduction of *"Parallel Data Distribution Management on
//! Shared-Memory Multiprocessors"* (Marzolla & D'Angelo, ACM TOMACS 2020,
//! DOI 10.1145/3369759) as a production-shaped library.
//!
//! The crate contains:
//!
//! * [`core`] — intervals, d-rectangles, regions and the d-dimensional
//!   reduction of the region matching problem (paper §2).
//! * [`exec`] — the shared-memory parallel runtime the paper builds on
//!   OpenMP for: a thread pool, chunked `parallel_for`, parallel merge
//!   sort and the two-level parallel prefix scan of paper Fig. 7.
//! * [`sets`] — pluggable active-set data structures (the paper's §5
//!   `std::set` / bit-vector / hash study).
//! * [`algos`] — the matching algorithms: BFM (Alg. 2), GBM (Alg. 3),
//!   SBM (Alg. 4), ITM (Alg. 5, §3) and **Parallel SBM** (Alg. 6+7, §4,
//!   the paper's main contribution), plus dynamic interval management.
//! * [`hla`] — a miniature HLA/RTI Data Distribution Management service:
//!   dimensions, region specifications, federates and notification
//!   routing (the system that consumes the matchers).
//! * [`workload`] — synthetic α-model workloads (§5) and a Köln-like
//!   vehicular trace generator (Fig. 14 substitution).
//! * [`runtime`] — PJRT/XLA execution of the AOT-compiled JAX+Pallas
//!   matching kernels (`artifacts/*.hlo.txt`).
//! * [`coordinator`] — the service layer: region registration, match
//!   scheduling, notification fan-out, metrics.
//! * [`bench`] — measurement harness: timing, statistics, speedup
//!   modeling, RSS metrics, paper-style table output.

pub mod core;
pub mod exec;
pub mod sets;
pub mod algos;
pub mod hla;
pub mod workload;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod cli;
pub mod config;
pub mod prng;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

//! # ddm — Parallel Data Distribution Management
//!
//! A reproduction of *"Parallel Data Distribution Management on
//! Shared-Memory Multiprocessors"* (Marzolla & D'Angelo, ACM TOMACS 2020,
//! DOI 10.1145/3369759) as a production-shaped library.
//!
//! ## Quickstart: the engine API
//!
//! All matching goes through [`engine::DdmEngine`], built with
//! [`engine::EngineBuilder`]. The engine owns a worker pool and an
//! algorithm backend behind the object-safe [`engine::Matcher`] trait,
//! so swapping algorithms — including out-of-tree ones — is a one-line
//! builder change:
//!
//! ```
//! use ddm::algos::Algo;
//! use ddm::core::{Interval, Regions1D};
//! use ddm::engine::DdmEngine;
//!
//! let engine = DdmEngine::builder()
//!     .algo(Algo::Psbm)   // or .auto(), or .matcher(my_backend)
//!     .threads(2)
//!     .build();
//! let subs = Regions1D::from_intervals(&[Interval::new(0.0, 2.0)]);
//! let upds = Regions1D::from_intervals(&[Interval::new(1.0, 3.0)]);
//! assert_eq!(engine.count_1d(&subs, &upds), 1);
//! assert_eq!(engine.pairs_1d(&subs, &upds), vec![(0, 0)]);
//! ```
//!
//! ## d-dimensional matching: the sweep-and-verify pipeline
//!
//! [`engine::DdmEngine::match_nd`] / [`engine::DdmEngine::pairs_nd`] /
//! [`engine::DdmEngine::count_nd`] match axis-parallel d-rectangles.
//! By default the engine runs the **native sweep-and-verify pipeline**
//! ([`core::ddim`]): it sweeps only the most selective dimension
//! (chosen by a sampled endpoint-density estimate, or pinned with
//! [`engine::EngineBuilder::sweep_dim`]) and verifies the remaining
//! dimensions inline at report time — no per-dimension pair set is
//! ever materialized. The paper's per-dimension reduction (§2,
//! footnote 1) stays available as a fallback via
//! [`engine::EngineBuilder::nd_mode`]:
//!
//! ```
//! use ddm::core::{Interval, RegionsNd};
//! use ddm::engine::{DdmEngine, NdMode};
//!
//! let mut subs = RegionsNd::new(2);
//! subs.push(&[Interval::new(0.0, 4.0), Interval::new(4.0, 9.0)]);
//! subs.push(&[Interval::new(2.0, 10.0), Interval::new(1.0, 6.0)]);
//! let mut upds = RegionsNd::new(2);
//! upds.push(&[Interval::new(1.0, 5.0), Interval::new(2.0, 7.0)]);
//!
//! let native = DdmEngine::builder().threads(2).build(); // native by default
//! assert_eq!(native.pairs_nd(&subs, &upds), vec![(0, 0), (1, 0)]);
//! assert_eq!(native.count_nd(&subs, &upds), 2);
//!
//! // The §2 per-dimension reduction gives the identical pair set.
//! let reduce = DdmEngine::builder()
//!     .threads(2)
//!     .nd_mode(NdMode::Reduction)
//!     .build();
//! assert_eq!(reduce.pairs_nd(&subs, &upds), native.pairs_nd(&subs, &upds));
//! ```
//!
//! ## Incremental matching: sessions and `MatchDiff`
//!
//! Dynamic workloads should not re-match from scratch. A
//! [`session::DdmSession`] (from [`engine::DdmEngine::session`])
//! stages batched region churn and commits **epochs**; each commit
//! applies the batch to per-dimension interval trees (paper §3's
//! dynamic interval management, all dimensions indexed) and returns a
//! [`session::MatchDiff`] — only the intersection pairs that appeared
//! or disappeared:
//!
//! ```
//! use ddm::core::Interval;
//! use ddm::engine::DdmEngine;
//!
//! let engine = DdmEngine::builder().threads(2).build();
//! let mut sess = engine.session(1);
//! sess.upsert_subscription(0, &[Interval::new(0.0, 2.0)]);
//! sess.upsert_update(7, &[Interval::new(1.0, 3.0)]);
//! let diff = sess.commit();
//! assert_eq!(diff.added, vec![(0, 7)]);
//! assert!(diff.removed.is_empty());
//!
//! sess.upsert_update(7, &[Interval::new(10.0, 12.0)]); // moved away
//! let diff = sess.commit();
//! assert_eq!(diff.removed, vec![(0, 7)]);
//! assert!(sess.pairs().is_empty());
//! ```
//!
//! Prefer sessions over repeated [`engine::DdmEngine::pairs_nd`]
//! whenever a minority of regions changes between reads; prefer the
//! static path for one-shot matches or when nearly everything moves
//! every step (`benches/abl_session.rs` measures the crossover).
//!
//! ## Wait-free reads: epoch snapshots
//!
//! Reads don't have to contend with the writer. Every session
//! publishes an immutable [`session::EpochSnapshot`] at each commit
//! (and flush): a refcounted view of the committed pair set whose
//! clone is one atomic increment and whose queries never take a lock —
//! the `session-read-no-lock` lint rule keeps it that way. Hand clones
//! to reader threads and keep committing; each reader keeps the epoch
//! it pinned until it drops it:
//!
//! ```
//! use ddm::core::Interval;
//! use ddm::engine::DdmEngine;
//! use ddm::session::EpochSnapshot;
//!
//! let engine = DdmEngine::builder().threads(2).build();
//! let mut sess = engine.session(1);
//! sess.upsert_subscription(0, &[Interval::new(0.0, 2.0)]);
//! sess.upsert_update(7, &[Interval::new(1.0, 3.0)]);
//! sess.commit();
//!
//! let snap: EpochSnapshot = sess.snapshot(); // O(1), wait-free to read
//! assert_eq!((snap.epoch(), snap.pairs()), (1, vec![(0, 7)]));
//!
//! let reader = std::thread::spawn({
//!     let snap = snap.clone();
//!     move || (snap.n_pairs(), snap.contains_pair(0, 7))
//! });
//! sess.upsert_update(7, &[Interval::new(10.0, 12.0)]); // moved away
//! sess.commit(); // publishes epoch 2; the reader's pin is untouched
//! assert_eq!(reader.join().unwrap(), (1, true));
//! assert_eq!(sess.snapshot().epoch(), 2);
//! assert!(sess.snapshot().pairs().is_empty());
//! ```
//!
//! Writers can overlap too:
//! [`session::DdmSession::commit_pipelined`] commits the staged batch
//! while pre-applying the *next* batch's interval-tree writes on a
//! second thread, and a bounded [`session::ingest_queue`] decouples
//! producers from the committing thread entirely — producers get a
//! typed [`session::Busy`] the moment the backlog bound is hit
//! (admission control, not unbounded buffering), and the server's
//! wire protocol forwards it as `Msg::Busy`. `benches/abl_rw.rs`
//! measures reader p50/p99 under churn against a lock-the-session
//! baseline.
//!
//! ## Sharded matching: partition the routing space itself
//!
//! Large, churny workloads can additionally stripe the routing space
//! into spatial **shards** ([`shard`]): each stripe owns an
//! independent session, epochs commit shard-parallel, and per-shard
//! diffs merge into one deduplicated [`session::MatchDiff`] — a pair
//! straddling a stripe boundary is reported exactly once, and a
//! region crossing a boundary while still intersecting its partner
//! reports nothing. Turning it on is one builder call:
//!
//! ```
//! use ddm::core::Interval;
//! use ddm::engine::DdmEngine;
//!
//! let engine = DdmEngine::builder().threads(2).shards(8).build();
//! let mut sess = engine.sharded_session(1, Interval::new(0.0, 1000.0));
//! sess.upsert_subscription(0, &[Interval::new(0.0, 400.0)]); // spans 4 stripes
//! sess.upsert_update(7, &[Interval::new(120.0, 130.0)]);
//! let diff = sess.commit();
//! assert_eq!(diff.added, vec![(0, 7)]); // boundary replicas dedup'd
//! assert_eq!(sess.shards(), 8);
//! ```
//!
//! The same builder setting routes everywhere: `engine.any_session(d,
//! span)` (what [`hla::DdmService`] uses) dispatches between the plain
//! and sharded paths, and the static matcher is wrapped in a
//! [`shard::ShardedMatcher`]. `benches/abl_shard.rs` sweeps shard
//! counts × churn rates against the unsharded session.
//!
//! ## Scratch ownership: the zero-allocation steady state
//!
//! Repeated matching reuses buffers instead of reallocating them
//! ([`core::scratch::MatchScratch`]): every [`engine::DdmEngine`]
//! owns one match scratch (endpoint array, radix sort buffers, GBM
//! binning block, per-worker pair sinks) shared by all its match
//! calls — back-to-back `match_nd`/`count_nd` calls on one engine
//! allocate nothing after the first — and every
//! [`session::DdmSession`] owns its own for the per-epoch recompute
//! and diff buffers (sharded sessions get per-shard scratch, one per
//! inner session). Engine scratch is attached through
//! [`engine::ExecCtx::scratch`] with `try_lock` semantics: concurrent
//! match calls on a shared engine degrade to per-call allocation,
//! never block. SBM/PSBM sort their endpoints by a compact `u64` key
//! with a parallel LSD radix sort ([`exec::radix`]; select the
//! merge-path comparison fallback with
//! [`engine::EngineBuilder::sort_algo`] or `--sort merge`).
//! `benches/abl_sort.rs` measures both and asserts warm calls are
//! allocation-free; `ddm match --repeat R` shows cold vs warm from
//! the CLI.
//!
//! ## Over the wire: the network service and federation
//!
//! Everything above also runs as a service ([`net`]): `ddm serve`
//! fronts an [`shard::AnySession`] behind a compact length-prefixed
//! binary protocol (pure `std`, no async runtime), `ddm route` serves
//! the federation topology, and [`net::FederationClient`] spreads a
//! workload across router + workers while merging per-worker diffs
//! with the same refcount discipline [`shard::ShardedSession`] uses
//! across shards — so the federated diff stream is byte-equal to the
//! in-process one. Driving a server from code:
//!
//! ```no_run
//! use ddm::core::Interval;
//! use ddm::net::{NetClient, RegionOp};
//!
//! fn main() -> ddm::Result<()> {
//!     // `ddm serve --listen 127.0.0.1:7777 --d 1` is running.
//!     let mut client = NetClient::connect("127.0.0.1:7777")?;
//!     client.op(RegionOp::UpsertSub { key: 0, rect: vec![Interval::new(0.0, 2.0)] })?;
//!     client.op(RegionOp::UpsertUpd { key: 7, rect: vec![Interval::new(1.0, 3.0)] })?;
//!     let diff = client.commit()?; // epoch closes server-side
//!     assert_eq!(diff.added, vec![(0, 7)]);
//!     println!("epoch {}: +{} -{}", diff.epoch, diff.added.len(), diff.removed.len());
//!     Ok(())
//! }
//! ```
//!
//! ## Durability: write-ahead log, checkpoints, recovery
//!
//! Sessions are in-memory by default; one builder call makes them
//! crash-consistent ([`durable`]): every staged op is appended to a
//! CRC-checked write-ahead log *before* the commit publishes its
//! snapshot, every commit closes with a marker carrying the epoch and
//! a pair-set fingerprint, and periodic checkpoints serialize the full
//! state and truncate the log. After a crash — even one that tore or
//! bit-flipped the log tail — recovery rebuilds the session at the
//! exact last durable epoch:
//!
//! ```
//! use ddm::core::Interval;
//! use ddm::engine::DdmEngine;
//!
//! let dir = std::env::temp_dir().join(format!("ddm-doc-wal-{}", std::process::id()));
//! let engine = DdmEngine::builder().threads(2).durability(&dir).build();
//! {
//!     let mut sess = engine.any_session(1, Interval::new(0.0, 100.0));
//!     sess.upsert_subscription(0, &[Interval::new(0.0, 2.0)]);
//!     sess.upsert_update(7, &[Interval::new(1.0, 3.0)]);
//!     sess.commit(); // durable: op records + commit marker hit the log first
//!     drop(sess);    // "kill -9": the in-memory state is gone
//! }
//! let (sess, report) = engine
//!     .recover_any_session(1, Interval::new(0.0, 100.0))
//!     .expect("recover");
//! assert_eq!((report.epoch, sess.epoch()), (1, 1));
//! assert!(sess.snapshot().contains_pair(0, 7));
//! std::fs::remove_dir_all(&dir).ok();
//! ```
//!
//! `ddm serve --wal DIR` runs the service durably, `--resume` brings a
//! killed server back at its last durable epoch, and `ddm wal-info
//! --dir DIR` inspects a log offline. The fault-injection suite
//! (`durable/faultfs.rs`, `tests/durable_recovery.rs`) proves that
//! *every* crash point recovers to a committed-epoch prefix.
//!
//! ## Observability: phase tracing and latency histograms
//!
//! Every commit above can narrate itself ([`obs`]): one builder call
//! turns on span capture in every session the engine creates, and the
//! spans drain at epoch boundaries. Disabled tracing costs one branch
//! per phase; enabled recording never allocates (fixed-capacity
//! per-worker sinks fanned in through the claims machinery — the
//! `obs-no-hot-alloc` lint rule keeps it that way):
//!
//! ```
//! use ddm::core::Interval;
//! use ddm::engine::DdmEngine;
//! use ddm::obs::{phase_totals, Phase};
//!
//! let engine = DdmEngine::builder().threads(2).trace(true).build();
//! let mut sess = engine.session(1);
//! sess.upsert_subscription(0, &[Interval::new(0.0, 2.0)]);
//! sess.upsert_update(7, &[Interval::new(1.0, 3.0)]);
//! sess.commit();
//! let spans = sess.drain_trace();
//! assert!(spans.iter().any(|s| s.phase == Phase::Commit.id()));
//! for (phase, total_ns, count, _items) in phase_totals(&spans) {
//!     println!("{}: {count} spans, {total_ns} ns", Phase::name_of(phase));
//! }
//! ```
//!
//! `ddm trace --out trace.json` writes the same spans as Chrome
//! trace-event JSON (load in `chrome://tracing` or Perfetto) and
//! `--overhead-check` asserts tracing costs under 5%; `ddm replay
//! --trace` prints per-phase totals for a churn replay; `ddm client
//! --metrics` renders the wire-delivered histograms (`commit_ns`, the
//! four `net_*_ns` stage histograms) as quantile tables plus the
//! slowest spans. The span taxonomy lives in [`obs::Phase`] and
//! ARCHITECTURE.md §"Observability".
//!
//! The crate contains:
//!
//! * [`engine`] — the unified matching API: the [`engine::Matcher`]
//!   trait all algorithms implement, the [`engine::DynamicMatcher`]
//!   incremental-index extension, and the [`engine::DdmEngine`] /
//!   [`engine::EngineBuilder`] entry points.
//! * [`session`] — epoch-based incremental matching: batched region
//!   churn staged into [`session::DdmSession`], applied in parallel,
//!   reported as [`session::MatchDiff`] intersection deltas; immutable
//!   per-epoch [`session::EpochSnapshot`]s for wait-free reads,
//!   pipelined commits, and the bounded [`session::ingest_queue`]
//!   front-end with typed [`session::Busy`] admission control.
//! * [`shard`] — spatial sharding: [`shard::SpacePartitioner`] stripes
//!   (uniform or sample-balanced), [`shard::ShardedSession`] with
//!   per-shard sessions and merged deduplicated diffs,
//!   [`shard::ShardedMatcher`] for the static path.
//! * [`core`] — intervals, d-rectangles, regions, the compact-key
//!   endpoint encoding ([`core::endpoint`]), the reusable match
//!   scratch ([`core::scratch`]), and the d-dimensional pipeline:
//!   native sweep-and-verify plus the paper-§2 reduction fallback
//!   ([`core::ddim`]).
//! * [`exec`] — the shared-memory parallel runtime the paper builds on
//!   OpenMP for: a thread pool, chunked `parallel_for`, parallel merge
//!   sort, the compact-key parallel radix sort ([`exec::radix`]) and
//!   the two-level parallel prefix scan of paper Fig. 7. All of its
//!   lock-free fan-in/scatter seams write through the claim-checked
//!   [`exec::claims`] layer: zero-cost in release, and with
//!   `--features race-check` every disjointness-contract violation
//!   becomes a deterministic panic. `cargo run -p xtask -- lint`
//!   enforces the accompanying source hygiene (SAFETY comments,
//!   lock-/panic-free hot paths); see ARCHITECTURE.md §"Unsafe code &
//!   verification".
//! * [`sets`] — pluggable active-set data structures (the paper's §5
//!   `std::set` / bit-vector / hash study).
//! * [`algos`] — the matching algorithms: BFM (Alg. 2), GBM (Alg. 3),
//!   SBM (Alg. 4), ITM (Alg. 5, §3) and **Parallel SBM** (Alg. 6+7, §4,
//!   the paper's main contribution), plus dynamic interval management.
//! * [`durable`] — crash-consistent durability: the write-ahead op
//!   log ([`durable::wal`]), epoch-snapshot checkpoint files
//!   ([`durable::snapfile`]), recovery to the last durable epoch
//!   ([`durable::recover`]), and the fault-injection harness
//!   (`durable::faultfs`, test/`failpoints`-gated).
//! * [`net`] — the network service: binary wire protocol
//!   ([`net::proto`]), nonblocking TCP server core ([`net::server`]),
//!   worker/router services, and the federation client that merges
//!   per-worker diffs exactly once ([`net::FederationClient`]).
//! * [`obs`] — observability: the sanctioned clock seam
//!   ([`obs::clock`]), log-bucketed mergeable latency histograms
//!   ([`obs::Histogram`]), the allocation-free span tracer
//!   ([`obs::SpanSink`] / [`obs::Tracer`]), and Chrome trace export
//!   ([`obs::chrome_trace_json`]).
//! * [`hla`] — a miniature HLA/RTI Data Distribution Management service:
//!   dimensions, region specifications, federates and notification
//!   routing (the system that consumes the matchers).
//! * [`workload`] — synthetic α-model workloads (§5) and a Köln-like
//!   vehicular trace generator (Fig. 14 substitution).
//! * [`runtime`] — PJRT/XLA execution of the AOT-compiled JAX+Pallas
//!   matching kernels (`artifacts/*.hlo.txt`).
//! * [`coordinator`] — the service layer: region registration, match
//!   scheduling, notification fan-out, metrics.
//! * [`bench`] — measurement harness: timing, statistics, speedup
//!   modeling, RSS metrics, paper-style table output.

// Style choices, not defects: index loops mirror the paper's
// pseudocode, and builder/ctor arities follow the domain.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::new_without_default,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args
)]

pub mod core;
pub mod durable;
pub mod engine;
pub mod error;
pub mod session;
pub mod shard;
pub mod net;
pub mod exec;
pub mod sets;
pub mod algos;
pub mod hla;
pub mod workload;
pub mod runtime;
pub mod coordinator;
pub mod obs;
pub mod bench;
pub mod cli;
pub mod config;
pub mod prng;

pub use durable::{DurabilityCfg, RecoverReport};
pub use engine::{DdmEngine, DynamicMatcher, EngineBuilder, ExecCtx, Matcher};
pub use session::{DdmSession, EpochSnapshot, MatchDiff, SessionParams};
pub use shard::{AnySession, ShardedMatcher, ShardedSession, SpacePartitioner};

/// Crate-wide result type.
pub type Result<T> = error::Result<T>;

//! `ddm` — the coordinator binary.
//!
//! Subcommands:
//!   ddm match      run one matching job and report K + wall-clock
//!   ddm xla-match  same, on the AOT-compiled XLA backend
//!   ddm replay     replay epochs of region churn (session diffs,
//!                  sharded session diffs, or full rebuild per epoch);
//!                  --trace prints per-phase totals from the obs tracer
//!   ddm trace      traced churn replay written as Chrome trace JSON
//!                  (load in chrome://tracing or Perfetto);
//!                  --overhead-check reruns the workload untraced vs
//!                  traced and dies if tracing costs more than 5%
//!   ddm serve      with --listen: network worker serving the binary
//!                  DDM protocol; without: scripted coordinator scenario
//!   ddm route      network router: serves the federation topology
//!   ddm client     scripted op stream against a worker or federation
//!   ddm bench-net  quick loopback throughput/latency measurement
//!   ddm wal-info   offline scan of a durability directory
//!   ddm info       host/Table-1 report + artifact status
//!
//! Examples:
//!   ddm match --algo psbm --n 1e6 --alpha 100 --threads 8 --set bit
//!   ddm match --algo psbm --n 1e6 --repeat 5 --sort radix   # cold vs warm
//!   ddm match --algo psbm --n 1e6 --sort merge              # A/B the sort
//!   ddm match --algo gbm --workload koln --scale 0.1 --ncells 3000
//!   ddm replay --n 50k --epochs 10 --churn 0.05 --mode session --verify
//!   ddm replay --mode sharded --shards 8 --hotspot 0.8 --verify
//!   ddm replay --workload koln --scale 0.05 --mode rebuild
//!   ddm replay --n 50k --epochs 10 --mode sharded --shards 4 --trace
//!   ddm trace --n 20k --epochs 5 --shards 4 --out trace.json
//!   ddm trace --n 20k --epochs 5 --overhead-check
//!   ddm match --algo psbm --n 1e6 --shards 8
//!   ddm xla-match --n 4096 --alpha 10
//!   ddm serve --config examples/service.toml
//!   ddm serve --listen 127.0.0.1:7777 --d 1 --shards 4 --span 0,1e6
//!   ddm serve --listen 127.0.0.1:7777 --backlog 4096   # Busy past 4096 queued ops
//!   ddm serve --listen 127.0.0.1:7777 --wal /var/lib/ddm       # durable epochs
//!   ddm serve --listen 127.0.0.1:7777 --wal /var/lib/ddm --resume --fsync
//!   ddm replay --n 50k --epochs 10 --record wal-dir            # log every epoch
//!   ddm replay --resume wal-dir --epochs 10                    # recover, keep churning
//!   ddm route --listen 127.0.0.1:7700 --workers 127.0.0.1:7701,127.0.0.1:7702 \
//!             --shards 4 --span 0,1e6
//!   ddm client --addr 127.0.0.1:7777 --n 1000 --epochs 5 --verify --metrics
//!   ddm client --addr 127.0.0.1:7777 --timeout-ms 2000 --n 1000
//!   ddm client --addr 127.0.0.1:7777 --n 0 --expect-epoch 11 \
//!             --expect-fingerprint 0x1c2d3e4f
//!   ddm client --router 127.0.0.1:7700 --n 1000 --shutdown
//!   ddm bench-net --n 2000 --conns 1,2,4
//!   ddm wal-info --dir wal-dir

use std::time::Instant;

use ddm::bench::{rss, sysinfo};
use ddm::cli::{die, Args};
use ddm::coordinator::{Coordinator, CoordinatorConfig};
use ddm::engine::{DdmEngine, NdMode, SweepDim};
use ddm::exec::SortAlgo;
use ddm::hla::{RegionKind, RegionSpec, RoutingSpace};
use ddm::sets::SetImpl;
use ddm::workload::koln::{koln_workload, KolnParams};
use ddm::workload::{alpha_workload, nd_alpha_workload, nd_correlated_workload, AlphaParams,
    NdAlphaParams};

fn usage() -> ! {
    eprintln!(
        "usage: ddm <match|xla-match|replay|trace|serve|route|client|bench-net|wal-info|info> \
         [options]\n\
         options are documented in rust/src/main.rs and README.md"
    );
    std::process::exit(2)
}

fn load_workload(args: &Args) -> (ddm::core::Regions1D, ddm::core::Regions1D, String) {
    let seed: u64 = args.opt("seed", 42u64);
    match args.get("workload").unwrap_or("alpha") {
        "koln" => {
            let p = KolnParams::default().scaled(args.opt("scale", 1.0f64));
            let (s, u) = koln_workload(seed, &p);
            (s, u, format!("koln positions={}", p.positions))
        }
        _ => {
            let p = AlphaParams {
                n_total: args.size("n", 1_000_000),
                alpha: args.opt("alpha", 100.0),
                space: args.opt("space", 1e6),
            };
            let (s, u) = alpha_workload(seed, &p);
            (s, u, format!("alpha N={} α={}", p.n_total, p.alpha))
        }
    }
}

/// Run one matching job: 1-D by default; `--d N` (or `--alphas
/// a0,a1,…`) switches to a d-dimensional workload and the N-D pipeline
/// (`--nd-mode native|reduce`, `--sweep-dim auto|k`, `--rho c` for the
/// correlated generator). `--sort radix|merge` A/Bs the endpoint sort;
/// `--repeat R` re-runs the match R times and reports cold vs warm
/// timings (warm calls reuse the engine's match scratch).
fn cmd_match(args: &Args) {
    let threads: usize = args.opt("threads", 4usize);
    let nd_mode: NdMode = args
        .try_opt("nd-mode")
        .unwrap_or_else(|e| die(&e))
        .unwrap_or_default();
    let sweep: SweepDim = args
        .try_opt("sweep-dim")
        .unwrap_or_else(|e| die(&e))
        .unwrap_or_default();
    let sort: SortAlgo = args
        .try_opt("sort")
        .unwrap_or_else(|e| die(&e))
        .unwrap_or_default();
    let repeat: usize = args.opt("repeat", 1usize);
    if repeat == 0 {
        die("--repeat=0: need at least one run");
    }
    let engine = DdmEngine::builder()
        .algo_str(args.get("algo").unwrap_or("psbm"))
        .unwrap_or_else(|e| die(&e))
        .threads(threads)
        .ncells(args.opt("ncells", 3000usize))
        .shards(args.opt("shards", 1usize))
        .nd_mode(nd_mode)
        .sweep_dim(sweep)
        .sort_algo(sort)
        .set_impl(
            args.get("set")
                .map(|s| s.parse::<SetImpl>().unwrap_or_else(|e| die(&e)))
                .unwrap_or(SetImpl::Sparse),
        )
        .build();

    // d > 1 (or an explicit per-dimension α list): N-D workload + the
    // engine's N-D pipeline.
    let alphas: Option<Vec<f64>> = args.try_list("alphas").unwrap_or_else(|e| die(&e));
    let d: usize = args.opt("d", alphas.as_ref().map_or(1, Vec::len));
    if d > 1 || alphas.is_some() {
        let alphas =
            alphas.unwrap_or_else(|| vec![args.opt("alpha", 100.0); d.max(1)]);
        if d != alphas.len() {
            die(&format!(
                "--d {d} disagrees with --alphas ({} values)",
                alphas.len()
            ));
        }
        let p = NdAlphaParams::skewed(
            args.size("n", 100_000),
            &alphas,
            args.opt("space", 1e6),
        );
        let seed: u64 = args.opt("seed", 42u64);
        let (subs, upds) = match args.try_opt::<f64>("rho").unwrap_or_else(|e| die(&e)) {
            Some(rho) => nd_correlated_workload(seed, &p, rho),
            None => nd_alpha_workload(seed, &p),
        };
        println!(
            "match: algo={} threads={} d={} nd-mode={:?} sweep-dim={:?} sort={} α={:?} N={}",
            engine.algo_name(),
            threads,
            p.d(),
            nd_mode,
            sweep,
            sort.name(),
            p.alphas,
            p.n_total
        );
        report_counts(repeat, || engine.count_nd(&subs, &upds));
        return;
    }

    let (subs, upds, desc) = load_workload(args);
    println!(
        "match: algo={} threads={} set={} sort={} workload=[{}]",
        engine.algo_name(),
        threads,
        engine.params().set_impl.name(),
        sort.name(),
        desc
    );
    report_counts(repeat, || engine.count_1d(&subs, &upds));
}

/// Run one counting job `repeat` times and report the cold (first)
/// and best-warm timings — warm runs reuse the engine's match
/// scratch, so the gap is the allocation + buffer-growth cost the
/// scratch eliminates. All runs must agree on K.
fn report_counts(repeat: usize, mut count: impl FnMut() -> u64) {
    let t0 = Instant::now();
    let k = count();
    let cold = t0.elapsed().as_secs_f64();
    let rss = rss::peak_rss_bytes().map(rss::fmt_bytes).unwrap_or_default();
    if repeat <= 1 {
        println!(
            "K={k} intersections in {} (peak RSS {rss})",
            ddm::bench::stats::fmt_secs(cold)
        );
        return;
    }
    let mut warm_best = f64::INFINITY;
    for r in 1..repeat {
        let t = Instant::now();
        let k2 = count();
        warm_best = warm_best.min(t.elapsed().as_secs_f64());
        if k2 != k {
            die(&format!("repeat run {r} returned K={k2}, first run K={k}"));
        }
    }
    println!(
        "K={k} intersections; cold {} warm {} (best of {} scratch-reusing runs; peak RSS {rss})",
        ddm::bench::stats::fmt_secs(cold),
        ddm::bench::stats::fmt_secs(warm_best),
        repeat - 1
    );
}

fn cmd_xla_match(args: &Args) {
    let dir = std::path::Path::new(ddm::runtime::DEFAULT_ARTIFACT_DIR);
    if !ddm::runtime::artifacts_available(dir) {
        if ddm::runtime::xla_enabled() {
            eprintln!("artifacts missing: run `make artifacts` first");
        } else {
            eprintln!(
                "XLA backend unavailable: rebuild with `--features xla` (and run `make artifacts`)"
            );
        }
        std::process::exit(1);
    }
    let (subs, upds, desc) = load_workload(args);
    println!("xla-match: workload=[{desc}]");
    let t0 = Instant::now();
    let be = ddm::runtime::XlaMatchBackend::load(dir).expect("backend");
    let t_load = t0.elapsed();
    let t1 = Instant::now();
    let k = be.match_counts_1d(&subs, &upds).expect("xla match");
    println!(
        "K={k} in {} (backend load+compile {})",
        ddm::bench::stats::fmt_secs(t1.elapsed().as_secs_f64()),
        ddm::bench::stats::fmt_secs(t_load.as_secs_f64()),
    );
}

/// Replay epochs of region churn over a workload: on a `DdmSession`
/// (staged batch + `MatchDiff` per epoch — the incremental path), on a
/// spatially sharded session (`--mode sharded --shards N`, per-shard
/// parallel commits with merged deduplicated diffs), or by full
/// re-match per epoch (`--mode rebuild`, the baseline both replace).
/// All modes run the identical deterministic move script — optionally
/// skewed with `--hotspot` — so their reported per-epoch pair churn
/// can be compared directly. `--record DIR` writes every committed
/// epoch to a durability directory; `--resume DIR` rebuilds the
/// session from one (verifying per-epoch fingerprints) and keeps
/// churning — together they make a crash/restart cycle scriptable.
fn cmd_replay(args: &Args) {
    use ddm::workload::churn::{diff_pair_counts, relocate, MoveScript};

    let threads: usize = args.opt("threads", 4usize);
    let epochs: usize = args.opt("epochs", 10usize);
    let churn: f64 = args.opt("churn", 0.05f64);
    let shards: usize = args.opt("shards", 4usize);
    let hotspot: f64 = args.opt("hotspot", 0.0f64);
    let mode = args.get("mode").unwrap_or("session").to_string();
    let seed: u64 = args.opt("seed", 42u64);
    let trace = args.flag("trace");
    if trace && mode == "rebuild" {
        die("--trace needs an incremental mode (session|sharded); rebuild has no commit phases");
    }
    // `--record DIR` logs every committed epoch to DIR; `--resume DIR`
    // rebuilds the session from DIR first and churns on from there.
    let record = args.get("record").map(str::to_string);
    let resume = args.get("resume").map(str::to_string);
    if record.is_some() && resume.is_some() {
        die("--record and --resume are exclusive (resume keeps logging into its own dir)");
    }
    let wal_dir = record.clone().or_else(|| resume.clone());
    if wal_dir.is_some() && mode == "rebuild" {
        die("--record/--resume need an incremental mode (session|sharded)");
    }
    if resume.is_some() && args.flag("verify") {
        die("--verify compares against a fresh static match; it cannot follow --resume");
    }

    let (mut subs, mut upds, desc) = match args.get("workload").unwrap_or("alpha") {
        "koln" => {
            let p = KolnParams::default().scaled(args.opt("scale", 0.05f64));
            let (s, u) = koln_workload(seed, &p);
            (s, u, format!("koln positions={}", p.positions))
        }
        _ => {
            let p = AlphaParams {
                n_total: args.size("n", 50_000),
                alpha: args.opt("alpha", 100.0),
                space: args.opt("space", 1e6),
            };
            let (s, u) = alpha_workload(seed, &p);
            (s, u, format!("alpha N={} α={}", p.n_total, p.alpha))
        }
    };
    let space_hi = subs
        .bounds()
        .map(|b| b.hi)
        .unwrap_or(1e6)
        .max(upds.bounds().map(|b| b.hi).unwrap_or(0.0));
    let n_regions = subs.len() + upds.len();
    let moves_per_epoch = ((n_regions as f64) * churn).ceil().max(1.0) as usize;
    let shard_note = if mode == "sharded" {
        format!(" shards={shards}")
    } else {
        String::new()
    };
    println!(
        "replay: mode={mode}{shard_note} epochs={epochs} churn={churn} hotspot={hotspot} \
         ({moves_per_epoch} moves/epoch) threads={threads} workload=[{desc}]"
    );

    let mut builder = DdmEngine::builder()
        .algo_str(args.get("algo").unwrap_or("psbm"))
        .unwrap_or_else(|e| die(&e))
        .threads(threads)
        .trace(trace);
    if let Some(dir) = &wal_dir {
        builder = with_durability(builder, args, dir);
    }
    if resume.is_some() {
        builder = builder.shards(if mode == "sharded" { shards } else { 1 });
    }
    let engine = builder.build();
    // All modes replay the identical deterministic move script.
    let mut script = MoveScript::with_hotspot(seed ^ 0xC0FFEE, hotspot);
    let (mut tot_added, mut tot_removed) = (0usize, 0usize);
    match mode.as_str() {
        "session" | "sharded" => {
            let mut spans: Vec<ddm::obs::SpanRecord> = Vec::new();
            let mut commit_wall = 0.0f64;
            let mut sess = if resume.is_some() {
                // Recovered state replaces the dense epoch-0 load; the
                // churn script then moves regions on top of it.
                let (sess, report) = engine
                    .recover_any_session(1, ddm::core::Interval::new(0.0, space_hi))
                    .unwrap_or_else(|e| die(&format!("--resume: {e}")));
                print_recover_report(&report);
                sess
            } else if mode == "sharded" {
                ddm::shard::AnySession::Sharded(engine.sharded_session_with(
                    1,
                    ddm::shard::SpacePartitioner::uniform(
                        shards,
                        0,
                        ddm::core::Interval::new(0.0, space_hi),
                    ),
                ))
            } else {
                ddm::shard::AnySession::Single(engine.session(1))
            };
            if resume.is_none() {
                let t0 = Instant::now();
                sess.load_dense_1d(&subs, &upds);
                let tc = Instant::now();
                let d0 = sess.commit();
                commit_wall += tc.elapsed().as_secs_f64();
                spans.extend(sess.drain_trace());
                println!(
                    "epoch 0: {} initial pairs in {}",
                    d0.added.len(),
                    ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
                );
            }
            let t1 = Instant::now();
            for e in 1..=epochs {
                for _ in 0..moves_per_epoch {
                    let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
                    if sub_side {
                        let iv = relocate(&mut subs, idx, frac, space_hi);
                        sess.upsert_subscription(idx as u32, &[iv]);
                    } else {
                        let iv = relocate(&mut upds, idx, frac, space_hi);
                        sess.upsert_update(idx as u32, &[iv]);
                    }
                }
                let tc = Instant::now();
                let d = sess.commit();
                commit_wall += tc.elapsed().as_secs_f64();
                spans.extend(sess.drain_trace());
                tot_added += d.added.len();
                tot_removed += d.removed.len();
                println!("epoch {e}: +{} -{} pairs", d.added.len(), d.removed.len());
            }
            let dt = t1.elapsed().as_secs_f64();
            println!(
                "{mode} replay: {} pairs live, +{tot_added} -{tot_removed} churned, \
                 {} per epoch",
                sess.n_pairs(),
                ddm::bench::stats::fmt_secs(dt / epochs.max(1) as f64)
            );
            if let Some(im) = sess.imbalance() {
                println!("shard imbalance: {im:.2} over {} shards", sess.shards());
            }
            if let Some(ws) = sess.wal_stats() {
                println!(
                    "wal: {} records / {} commits / {} checkpoints, {} bytes, {} fsyncs{}",
                    ws.records,
                    ws.commits,
                    ws.checkpoints,
                    ws.bytes,
                    ws.fsyncs,
                    sess.wal_error()
                        .map(|e| format!(" — DEGRADED: {e}"))
                        .unwrap_or_default()
                );
            }
            if trace {
                report_trace(&spans, commit_wall, sess.trace_dropped());
            }
            if args.flag("verify") {
                let want = engine.pairs_1d(&subs, &upds);
                assert_eq!(sess.pairs(), want, "{mode} state diverged from static match");
                println!("verify: {mode} pair set == fresh static match ({} pairs)", want.len());
            }
        }
        "rebuild" => {
            let t0 = Instant::now();
            let mut prev = engine.pairs_1d(&subs, &upds);
            println!(
                "epoch 0: {} initial pairs in {}",
                prev.len(),
                ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
            );
            let t1 = Instant::now();
            for e in 1..=epochs {
                for _ in 0..moves_per_epoch {
                    let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
                    if sub_side {
                        relocate(&mut subs, idx, frac, space_hi);
                    } else {
                        relocate(&mut upds, idx, frac, space_hi);
                    }
                }
                let cur = engine.pairs_1d(&subs, &upds);
                let (added, removed) = diff_pair_counts(&prev, &cur);
                tot_added += added;
                tot_removed += removed;
                println!("epoch {e}: +{added} -{removed} pairs");
                prev = cur;
            }
            let dt = t1.elapsed().as_secs_f64();
            println!(
                "rebuild replay: {} pairs live, +{tot_added} -{tot_removed} churned, \
                 {} per epoch",
                prev.len(),
                ddm::bench::stats::fmt_secs(dt / epochs.max(1) as f64)
            );
        }
        other => {
            eprintln!("unknown replay mode '{other}' (session|sharded|rebuild)");
            std::process::exit(2);
        }
    }
}

/// Per-phase totals (name, summed time, span count, items) from a
/// drained span list.
fn phase_table(spans: &[ddm::obs::SpanRecord]) -> ddm::bench::table::Table {
    let mut t = ddm::bench::table::Table::new(vec!["phase", "total", "spans", "items"]);
    for (phase, total_ns, count, items) in ddm::obs::phase_totals(spans) {
        t.row(vec![
            ddm::obs::Phase::name_of(phase).to_string(),
            ddm::bench::stats::fmt_secs(total_ns as f64 / 1e9),
            count.to_string(),
            items.to_string(),
        ]);
    }
    t
}

/// Print per-phase totals and cross-check the `commit` envelope total
/// against the measured commit wall-clock — the envelope tiles the
/// whole of `commit()`, so the two should agree to within a few
/// percent.
fn report_trace(spans: &[ddm::obs::SpanRecord], commit_wall_s: f64, dropped: u64) {
    phase_table(spans).print();
    let commit_ns = ddm::obs::phase_totals(spans)
        .iter()
        .find(|(p, ..)| *p == ddm::obs::Phase::Commit.id())
        .map_or(0, |&(_, total, _, _)| total);
    let commit_s = commit_ns as f64 / 1e9;
    let cov = if commit_wall_s > 0.0 {
        100.0 * commit_s / commit_wall_s
    } else {
        0.0
    };
    println!(
        "trace: {} spans ({dropped} dropped); commit envelope total {} vs measured \
         commit wall {} ({cov:.1}% coverage)",
        spans.len(),
        ddm::bench::stats::fmt_secs(commit_s),
        ddm::bench::stats::fmt_secs(commit_wall_s),
    );
}

/// One run of the `ddm trace` workload (alpha regions + churn moves):
/// returns the drained spans, the summed commit wall-clock in seconds,
/// and the span-drop count. Workload and move script are regenerated
/// from the seed on every call, so traced and untraced runs commit
/// identical epochs — that is what makes the `--overhead-check`
/// comparison apples-to-apples.
fn run_trace_workload(
    args: &Args,
    trace: bool,
    quiet: bool,
) -> (Vec<ddm::obs::SpanRecord>, f64, u64) {
    use ddm::workload::churn::{relocate, MoveScript};

    let threads: usize = args.opt("threads", 4usize);
    let epochs: usize = args.opt("epochs", 5usize);
    let churn: f64 = args.opt("churn", 0.05f64);
    let shards: usize = args.opt("shards", 1usize);
    let seed: u64 = args.opt("seed", 42u64);

    let p = AlphaParams {
        n_total: args.size("n", 20_000),
        alpha: args.opt("alpha", 100.0),
        space: args.opt("space", 1e6),
    };
    let (mut subs, mut upds) = alpha_workload(seed, &p);
    let space_hi = subs
        .bounds()
        .map(|b| b.hi)
        .unwrap_or(1e6)
        .max(upds.bounds().map(|b| b.hi).unwrap_or(0.0));
    let moves_per_epoch = (((subs.len() + upds.len()) as f64) * churn).ceil().max(1.0) as usize;

    let engine = DdmEngine::builder()
        .algo_str(args.get("algo").unwrap_or("psbm"))
        .unwrap_or_else(|e| die(&e))
        .threads(threads)
        .trace(trace)
        .build();
    let mut sess = if shards > 1 {
        ddm::shard::AnySession::Sharded(engine.sharded_session_with(
            1,
            ddm::shard::SpacePartitioner::uniform(
                shards,
                0,
                ddm::core::Interval::new(0.0, space_hi),
            ),
        ))
    } else {
        ddm::shard::AnySession::Single(engine.session(1))
    };
    if !quiet {
        println!(
            "trace: N={} epochs={epochs} churn={churn} ({moves_per_epoch} moves/epoch) \
             threads={threads} shards={shards} algo={}",
            p.n_total,
            engine.algo_name()
        );
    }

    let mut spans: Vec<ddm::obs::SpanRecord> = Vec::new();
    let mut commit_wall = 0.0f64;
    let mut script = MoveScript::with_hotspot(seed ^ 0xC0FFEE, 0.0);
    sess.load_dense_1d(&subs, &upds);
    for e in 0..=epochs {
        if e > 0 {
            for _ in 0..moves_per_epoch {
                let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
                if sub_side {
                    let iv = relocate(&mut subs, idx, frac, space_hi);
                    sess.upsert_subscription(idx as u32, &[iv]);
                } else {
                    let iv = relocate(&mut upds, idx, frac, space_hi);
                    sess.upsert_update(idx as u32, &[iv]);
                }
            }
        }
        let tc = Instant::now();
        let d = sess.commit();
        commit_wall += tc.elapsed().as_secs_f64();
        spans.extend(sess.drain_trace());
        if !quiet {
            println!("epoch {e}: +{} -{} pairs", d.added.len(), d.removed.len());
        }
    }
    (spans, commit_wall, sess.trace_dropped())
}

/// Traced churn replay written as Chrome trace JSON: every pipeline
/// phase (sort/sweep/residual, GBM bin/scan, stage/write/recompute,
/// per-shard commits, diff merge) becomes a duration event on its
/// worker lane — load the file in `chrome://tracing` or Perfetto.
/// Prints phase totals and the slowest spans alongside. With
/// `--overhead-check`, reruns the identical workload untraced and
/// traced (best-of-N commit walls) and dies if tracing costs more
/// than 5%.
fn cmd_trace(args: &Args) {
    let top: usize = args.opt("top", 10usize);
    let out = args.get("out").unwrap_or("trace.json").to_string();

    let (spans, commit_wall, dropped) = run_trace_workload(args, true, false);
    report_trace(&spans, commit_wall, dropped);
    let mut slow = ddm::bench::table::Table::new(vec!["phase", "lane", "dur", "items"]);
    for s in ddm::obs::top_slowest(&spans, top) {
        let lane = if s.worker == ddm::obs::trace::MASTER_WORKER {
            "master".to_string()
        } else {
            s.worker.to_string()
        };
        slow.row(vec![
            ddm::obs::Phase::name_of(s.phase).to_string(),
            lane,
            ddm::bench::stats::fmt_secs(s.dur_ns() as f64 / 1e9),
            s.items.to_string(),
        ]);
    }
    slow.print();
    std::fs::write(&out, ddm::obs::chrome_trace_json(&spans))
        .unwrap_or_else(|e| die(&format!("--out {out}: {e}")));
    println!(
        "trace: {} spans written to {out} (open in chrome://tracing or Perfetto)",
        spans.len()
    );

    if args.flag("overhead-check") {
        // Best-of-N damps scheduler noise: the minimum commit wall is
        // the least-perturbed run of each mode. Disabled tracing costs
        // one branch per phase; enabled costs a cursor write per span
        // — both should vanish inside real matching work, and 2 ms of
        // absolute slack keeps tiny workloads from failing on jitter.
        let reps: usize = args.opt("reps", 3usize);
        let (mut off, mut on) = (f64::INFINITY, f64::INFINITY);
        for _ in 0..reps.max(1) {
            off = off.min(run_trace_workload(args, false, true).1);
            on = on.min(run_trace_workload(args, true, true).1);
        }
        let pct = 100.0 * (on - off) / off.max(1e-9);
        println!(
            "overhead-check: untraced commit wall {} vs traced {} ({pct:+.2}%, best of {reps})",
            ddm::bench::stats::fmt_secs(off),
            ddm::bench::stats::fmt_secs(on),
        );
        if on > off * 1.05 + 0.002 {
            die(&format!(
                "tracing overhead {pct:.1}% exceeds the 5% budget \
                 (untraced {off:.6}s, traced {on:.6}s)"
            ));
        }
        println!("overhead-check: tracing overhead within the 5% budget");
    }
}

/// `ddm serve` fronts two very different things: with `--listen` it is
/// a network worker speaking the binary DDM protocol; without, the
/// original scripted coordinator scenario.
fn cmd_serve(args: &Args) {
    if args.get("listen").is_some() {
        cmd_serve_net(args);
    } else {
        cmd_serve_scripted(args);
    }
}

/// Apply the shared durability flags (`--wal DIR`, `--fsync`,
/// `--snap-every N`) to an engine builder.
fn with_durability(
    mut b: ddm::engine::EngineBuilder,
    args: &Args,
    dir: &str,
) -> ddm::engine::EngineBuilder {
    b = b.durability(dir);
    if args.flag("fsync") {
        b = b.durability_fsync(true);
    }
    if let Some(every) = args.try_opt::<u64>("snap-every").unwrap_or_else(|e| die(&e)) {
        b = b.durability_snapshot_every(every);
    }
    b
}

/// Print what a recovery rebuilt (shared by `serve --resume`, `replay
/// --resume` and `wal-info`).
fn print_recover_report(r: &ddm::durable::RecoverReport) {
    println!(
        "resume: epoch={} pairs={} fingerprint={:08x} \
         ({} snapshot regions + {} batches / {} ops replayed; \
         discarded {} torn tail bytes, {} uncommitted ops)",
        r.epoch, r.n_pairs, r.fingerprint, r.snapshot_regions, r.batches, r.ops,
        r.tail_bytes, r.open_ops
    );
}

/// Network worker: an [`AnySession`](ddm::shard::AnySession) behind
/// `ddm::net::serve`. Sharding mirrors the in-process builder surface:
/// `--cuts c1,c2,…` pins explicit global cut points (what a federation
/// worker gets from `ddm route`'s printed hints), `--shards N --span
/// LO,HI` builds uniform stripes, neither means a single unsharded
/// session. `--wal DIR` makes every committed epoch durable
/// (`--fsync`, `--snap-every N` tune the policy) and `--resume`
/// rebuilds the session from DIR before listening, so a killed worker
/// comes back at its last durable epoch. Runs until a wire `Shutdown`
/// arrives, then flushes, says `Goodbye`, joins every thread and
/// prints final metrics.
fn cmd_serve_net(args: &Args) {
    let listen = args.get("listen").unwrap_or("127.0.0.1:0").to_string();
    let d: usize = args.opt("d", 1usize);
    let threads: usize = args.opt("threads", 2usize);
    let split_dim: usize = args.opt("split-dim", 0usize);
    if d == 0 || split_dim >= d {
        die(&format!("--split-dim {split_dim} out of range for --d {d}"));
    }
    let cuts: Option<Vec<f64>> = args.try_list("cuts").unwrap_or_else(|e| die(&e));
    let shards: usize = args.opt("shards", 1usize);
    let resume = args.flag("resume");
    let mut builder = DdmEngine::builder()
        .algo_str(args.get("algo").unwrap_or("psbm"))
        .unwrap_or_else(|e| die(&e))
        .threads(threads)
        .trace(args.flag("trace"))
        // `--backlog N` bounds the worker's staged-op ingest queue:
        // beyond N queued ops, clients get a typed `Busy` reply.
        .ingest_backlog(args.opt("backlog", ddm::session::DEFAULT_INGEST_BACKLOG));
    match args.get("wal") {
        Some(dir) => builder = with_durability(builder, args, dir),
        None if resume => die("--resume needs --wal DIR"),
        None => {}
    }
    if resume {
        builder = builder.shards(shards).split_dim(split_dim);
    }
    let engine = builder.build();
    let session = if resume {
        if cuts.is_some() {
            die("--resume supports --shards/--span striping, not explicit --cuts");
        }
        let span: Vec<f64> = args.list("span", &[0.0, 1e6]);
        if span.len() != 2 || span[0] >= span[1] {
            die("--span needs LO,HI with LO < HI");
        }
        let (sess, report) = engine
            .recover_any_session(d, ddm::core::Interval::new(span[0], span[1]))
            .unwrap_or_else(|e| die(&format!("--resume: {e}")));
        print_recover_report(&report);
        sess
    } else {
        match cuts {
            Some(cuts) => ddm::shard::AnySession::Sharded(engine.sharded_session_with(
                d,
                ddm::shard::SpacePartitioner::from_cuts(split_dim, cuts),
            )),
            None if shards > 1 => {
                let span: Vec<f64> = args.list("span", &[0.0, 1e6]);
                if span.len() != 2 || span[0] >= span[1] {
                    die("--span needs LO,HI with LO < HI");
                }
                ddm::shard::AnySession::Sharded(engine.sharded_session_with(
                    d,
                    ddm::shard::SpacePartitioner::uniform(
                        shards,
                        split_dim,
                        ddm::core::Interval::new(span[0], span[1]),
                    ),
                ))
            }
            None => ddm::shard::AnySession::Single(engine.session(d)),
        }
    };
    let stripes = session.shards();
    let cfg = ddm::net::ServerConfig {
        listen,
        io_threads: args.opt("io-threads", 2usize),
    };
    let handle = ddm::net::serve(&cfg, ddm::net::WorkerService::new(session))
        .unwrap_or_else(|e| die(&format!("serve: {e}")));
    println!(
        "serve: worker on {} (d={d}, {stripes} stripe{})",
        handle.addr(),
        if stripes == 1 { "" } else { "s" }
    );
    write_port_file(args, handle.addr());
    let metrics = handle.join();
    println!("serve: stopped cleanly");
    metrics.table().print();
}

/// Network router: topology authority only. Builds the global shard
/// map (uniform cuts over `--span`, or explicit `--cuts`), assigns
/// contiguous stripe ranges to `--workers`, prints the exact `ddm
/// serve --cuts …` command for each worker (the local cut slice that
/// makes federated routing bit-identical to a flat sharded session),
/// and serves `GetTopology` until a wire `Shutdown`. `--probe` dials
/// every worker first (handshake bounded by `--timeout-ms`, default
/// 2000) so a dead or wedged worker fails the router fast instead of
/// surfacing as a hung federation client later.
fn cmd_route(args: &Args) {
    let listen = args.get("listen").unwrap_or("127.0.0.1:0").to_string();
    let d: usize = args.opt("d", 1usize);
    let split_dim: usize = args.opt("split-dim", 0usize);
    if d == 0 || split_dim >= d {
        die(&format!("--split-dim {split_dim} out of range for --d {d}"));
    }
    let workers: Vec<String> = args
        .try_list("workers")
        .unwrap_or_else(|e| die(&e))
        .unwrap_or_default();
    if workers.is_empty() {
        die("--workers ADDR1,ADDR2,… is required");
    }
    let cuts: Vec<f64> = match args.try_list("cuts").unwrap_or_else(|e| die(&e)) {
        Some(c) => c,
        None => {
            let shards: usize = args.opt("shards", workers.len());
            let span: Vec<f64> = args.list("span", &[0.0, 1e6]);
            if span.len() != 2 || span[0] >= span[1] {
                die("--span needs LO,HI with LO < HI");
            }
            ddm::shard::SpacePartitioner::uniform(
                shards,
                split_dim,
                ddm::core::Interval::new(span[0], span[1]),
            )
            .cuts()
            .to_vec()
        }
    };
    let shards = cuts.len() + 1;
    if workers.len() > shards {
        die(&format!(
            "{} workers but only {shards} stripes; drop workers or raise --shards",
            workers.len()
        ));
    }
    let table = ddm::net::assign_stripes(shards, &workers);
    for entry in &table {
        let local: Vec<String> = cuts[entry.first as usize..entry.last as usize]
            .iter()
            .map(|c| format!("{c}"))
            .collect();
        println!(
            "route: {} owns stripes {}..={}  →  ddm serve --listen {} --d {d} \
             --split-dim {split_dim} --cuts {}",
            entry.addr,
            entry.first,
            entry.last,
            entry.addr,
            if local.is_empty() {
                String::new()
            } else {
                local.join(",")
            }
        );
    }
    if args.flag("probe") {
        let timeout = std::time::Duration::from_millis(args.opt("timeout-ms", 2_000u64));
        for entry in &table {
            ddm::net::NetClient::connect_with(&entry.addr, timeout)
                .unwrap_or_else(|e| die(&format!("--probe {}: {e}", entry.addr)));
        }
        println!("route: probed {} worker(s), all reachable", table.len());
    }
    let topo = ddm::net::TopologySnapshot {
        d: d as u32,
        split_dim: split_dim as u32,
        cuts,
        workers: table,
    };
    let n_workers = workers.len();
    let cfg = ddm::net::ServerConfig {
        listen,
        io_threads: 1,
    };
    let handle = ddm::net::serve(&cfg, ddm::net::RouterService::new(topo))
        .unwrap_or_else(|e| die(&format!("route: {e}")));
    println!(
        "route: router on {} ({shards} stripes, {n_workers} workers)",
        handle.addr()
    );
    write_port_file(args, handle.addr());
    let metrics = handle.join();
    println!("route: stopped cleanly");
    metrics.table().print();
}

/// Write the bound address to `--port-file` (how scripts and CI find
/// an ephemeral port).
fn write_port_file(args: &Args, addr: std::net::SocketAddr) {
    if let Some(path) = args.get("port-file") {
        std::fs::write(path, addr.to_string())
            .unwrap_or_else(|e| die(&format!("--port-file {path}: {e}")));
    }
}

/// The deterministic churn script every net consumer replays: epoch 0
/// upserts `n` subscription + `n` update regions, later epochs move a
/// `churn` fraction (with 10% of moves being remove/re-insert churn).
/// Same seed ⇒ same ops, whether applied over a socket, through a
/// federation, or to an in-process session — which is what makes
/// `--verify` and the equivalence tests meaningful.
fn net_script(
    seed: u64,
    d: usize,
    n: usize,
    epochs: usize,
    churn: f64,
    space: f64,
) -> Vec<Vec<ddm::net::RegionOp>> {
    use ddm::net::RegionOp;
    let mut rng = ddm::prng::Rng::new(seed);
    let mut rect = |rng: &mut ddm::prng::Rng| -> Vec<ddm::core::Interval> {
        (0..d)
            .map(|_| {
                let lo = rng.uniform(0.0, space);
                ddm::core::Interval::new(lo, lo + rng.uniform(space * 1e-4, space * 1e-2))
            })
            .collect()
    };
    let mut out = Vec::with_capacity(epochs.max(1));
    let mut first = Vec::with_capacity(2 * n);
    for key in 0..n as u32 {
        first.push(RegionOp::UpsertSub { key, rect: rect(&mut rng) });
        first.push(RegionOp::UpsertUpd { key, rect: rect(&mut rng) });
    }
    out.push(first);
    if n == 0 {
        return out;
    }
    let moves = (((2 * n) as f64) * churn).ceil().max(1.0) as usize;
    for _ in 1..epochs.max(1) {
        let mut ops = Vec::with_capacity(moves);
        for _ in 0..moves {
            let key = rng.below(n as u64) as u32;
            let sub = rng.chance(0.5);
            if rng.chance(0.1) {
                ops.push(if sub {
                    RegionOp::RemoveSub { key }
                } else {
                    RegionOp::RemoveUpd { key }
                });
            } else {
                let r = rect(&mut rng);
                ops.push(if sub {
                    RegionOp::UpsertSub { key, rect: r }
                } else {
                    RegionOp::UpsertUpd { key, rect: r }
                });
            }
        }
        out.push(ops);
    }
    out
}

/// Apply one epoch of script ops to an in-process session (the verify
/// baseline).
fn apply_local(sess: &mut ddm::shard::AnySession, ops: &[ddm::net::RegionOp]) {
    use ddm::net::RegionOp;
    for op in ops {
        match op {
            RegionOp::UpsertSub { key, rect } => sess.upsert_subscription(*key, rect),
            RegionOp::UpsertUpd { key, rect } => sess.upsert_update(*key, rect),
            RegionOp::RemoveSub { key } => sess.remove_subscription(*key),
            RegionOp::RemoveUpd { key } => sess.remove_update(*key),
        }
    }
}

/// Apply one epoch of script ops through a federation client (which
/// routes each op to the workers owning its stripes).
fn apply_fed(
    fed: &mut ddm::net::FederationClient,
    ops: &[ddm::net::RegionOp],
) -> ddm::Result<()> {
    use ddm::net::RegionOp;
    for op in ops {
        match op {
            RegionOp::UpsertSub { key, rect } => fed.upsert_subscription(*key, rect)?,
            RegionOp::UpsertUpd { key, rect } => fed.upsert_update(*key, rect)?,
            RegionOp::RemoveSub { key } => fed.remove_subscription(*key)?,
            RegionOp::RemoveUpd { key } => fed.remove_update(*key)?,
        }
    }
    Ok(())
}

/// Scripted op stream against `--addr` (one worker) or `--router` (a
/// federation). Per epoch: stage ops, commit, report the diff.
/// `--verify` replays the identical script on an in-process session
/// and asserts every epoch's added/removed lists match (run it against
/// a freshly started server). `--timeout-ms N` bounds connect and
/// every read/write (0 = no deadline). `--expect-epoch N` /
/// `--expect-fingerprint HEX` assert the server's epoch and pair-set
/// fingerprint after the script runs (with `--n 0`, they audit a
/// freshly resumed server without staging anything). `--metrics`
/// prints the server metrics table; `--shutdown` stops the server(s)
/// and waits for `Goodbye`.
fn cmd_client(args: &Args) {
    let n: usize = args.size("n", 1000);
    let epochs: usize = args.opt("epochs", 5usize);
    let churn: f64 = args.opt("churn", 0.1f64);
    let seed: u64 = args.opt("seed", 42u64);
    let space: f64 = args.opt("space", 1e6);
    let timeout = std::time::Duration::from_millis(args.opt("timeout-ms", 30_000u64));

    enum Target {
        Single(ddm::net::NetClient),
        Fed(ddm::net::FederationClient),
    }
    let mut target = match (args.get("router"), args.get("addr")) {
        (Some(router), _) => Target::Fed(
            ddm::net::FederationClient::connect_with(router, timeout)
                .unwrap_or_else(|e| die(&format!("connect {router}: {e}"))),
        ),
        (None, Some(addr)) => Target::Single(
            ddm::net::NetClient::connect_with(addr, timeout)
                .unwrap_or_else(|e| die(&format!("connect {addr}: {e}"))),
        ),
        (None, None) => die("--addr ADDR or --router ADDR is required"),
    };
    let d = match &target {
        Target::Single(c) => c.d(),
        Target::Fed(f) => f.d(),
    };

    if n > 0 {
        let script = net_script(seed, d, n, epochs, churn, space);
        let mut verify = args.flag("verify").then(|| {
            ddm::shard::AnySession::Single(
                DdmEngine::builder()
                    .threads(args.opt("threads", 2usize))
                    .build()
                    .session(d),
            )
        });
        let t0 = Instant::now();
        let mut total_ops = 0usize;
        for (e, ops) in script.iter().enumerate() {
            total_ops += ops.len();
            let diff = match &mut target {
                Target::Single(c) => {
                    c.batch(ops.clone())
                        .and_then(|()| c.commit())
                        .unwrap_or_else(|err| die(&format!("epoch {e}: {err}")))
                }
                Target::Fed(f) => apply_fed(f, ops)
                    .and_then(|()| f.commit())
                    .unwrap_or_else(|err| die(&format!("epoch {e}: {err}"))),
            };
            println!(
                "epoch {e}: {} ops, +{} -{} pairs (epoch {})",
                ops.len(),
                diff.added.len(),
                diff.removed.len(),
                diff.epoch
            );
            if let Some(local) = verify.as_mut() {
                apply_local(local, ops);
                let want = local.commit();
                if want.added != diff.added || want.removed != diff.removed {
                    die(&format!(
                        "epoch {e}: server diff (+{} -{}) diverges from local replay (+{} -{})",
                        diff.added.len(),
                        diff.removed.len(),
                        want.added.len(),
                        want.removed.len()
                    ));
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "client: {total_ops} ops / {} epochs in {} ({:.0} ops/s){}",
            script.len(),
            ddm::bench::stats::fmt_secs(dt),
            total_ops as f64 / dt.max(1e-9),
            if verify.is_some() {
                " — verified against in-process replay"
            } else {
                ""
            }
        );
    }

    let expect_epoch: Option<u64> = args.try_opt("expect-epoch").unwrap_or_else(|e| die(&e));
    let expect_fp: Option<u32> = args.get("expect-fingerprint").map(|s| {
        u32::from_str_radix(s.trim_start_matches("0x"), 16)
            .unwrap_or_else(|e| die(&format!("--expect-fingerprint {s}: {e}")))
    });
    if expect_epoch.is_some() || expect_fp.is_some() {
        let (epoch, pairs) = match &mut target {
            Target::Single(c) => {
                let (epoch, _pending) =
                    c.sync(0xC0DE).unwrap_or_else(|e| die(&format!("sync: {e}")));
                let pairs = c.pairs().unwrap_or_else(|e| die(&format!("pairs: {e}")));
                (epoch, pairs)
            }
            Target::Fed(f) => {
                let pairs = f.pairs().unwrap_or_else(|e| die(&format!("pairs: {e}")));
                (f.epoch(), pairs)
            }
        };
        let packed: Vec<u64> = pairs
            .iter()
            .map(|&(s, u)| ddm::core::sink::pack_pair(s, u))
            .collect();
        let fp = ddm::durable::fingerprint_packed(&packed);
        println!(
            "state: epoch={epoch} pairs={} fingerprint={fp:08x}",
            pairs.len()
        );
        if let Some(want) = expect_epoch {
            if epoch != want {
                die(&format!("--expect-epoch {want}: server is at epoch {epoch}"));
            }
        }
        if let Some(want) = expect_fp {
            if fp != want {
                die(&format!(
                    "--expect-fingerprint {want:08x}: server pair set fingerprints to {fp:08x}"
                ));
            }
        }
    }

    if args.flag("metrics") {
        fn print_snapshot(m: &ddm::net::MetricsSnapshot) {
            m.table().print();
            if !m.hists.is_empty() {
                m.hist_table().print();
            }
            if !m.spans.is_empty() {
                println!("slowest spans:");
                m.span_table().print();
            }
        }
        match &mut target {
            Target::Single(c) => {
                let m = c.metrics().unwrap_or_else(|e| die(&format!("metrics: {e}")));
                print_snapshot(&m);
            }
            Target::Fed(f) => {
                let snaps = f
                    .worker_metrics()
                    .unwrap_or_else(|e| die(&format!("metrics: {e}")));
                for (i, m) in snaps.iter().enumerate() {
                    println!("worker {i}:");
                    print_snapshot(m);
                }
            }
        }
    }

    if args.flag("shutdown") {
        match &mut target {
            Target::Single(c) => {
                c.shutdown_server()
                    .and_then(|()| c.await_goodbye())
                    .map(|epoch| println!("client: server said goodbye at epoch {epoch}"))
                    .unwrap_or_else(|e| die(&format!("shutdown: {e}")));
            }
            Target::Fed(f) => {
                f.shutdown_workers()
                    .unwrap_or_else(|e| die(&format!("shutdown: {e}")));
                println!("client: all workers said goodbye");
            }
        }
    }
}

/// Quick loopback measurement (the full sweep lives in
/// `benches/abl_net.rs`): spawns an in-process worker server, drives
/// the churn script over `--conns` connections with disjoint key
/// ranges, and reports staged ops/s plus commit→diff latency. With one
/// connection the diff stream is asserted equal to an in-process
/// replay.
fn cmd_bench_net(args: &Args) {
    let n: usize = args.size("n", 2000);
    let epochs: usize = args.opt("epochs", 4usize);
    let conns_list: Vec<usize> = args.list("conns", &[1, 2, 4]);
    let seed: u64 = args.opt("seed", 42u64);
    let d: usize = args.opt("d", 1usize);

    let mut table = ddm::bench::table::Table::new(vec![
        "conns", "ops", "ops_per_s", "commit_ms", "p50_ms", "p99_ms", "added", "removed",
    ]);
    for &conns in &conns_list {
        let engine = DdmEngine::builder()
            .threads(args.opt("threads", 2usize))
            // Size the ingest backlog to the whole per-epoch op volume
            // so the bench measures throughput, not admission control.
            .ingest_backlog((2 * n).max(ddm::session::DEFAULT_INGEST_BACKLOG))
            .build();
        let service =
            ddm::net::WorkerService::new(ddm::shard::AnySession::Single(engine.session(d)));
        let handle = ddm::net::serve(&ddm::net::ServerConfig::default(), service)
            .unwrap_or_else(|e| die(&format!("bench-net: {e}")));
        let addr = handle.addr().to_string();
        let r = ddm::bench::netbench::bench_loopback(&addr, conns, n, epochs, seed, d)
            .unwrap_or_else(|e| die(&format!("bench-net ({conns} conns): {e}")));
        let _ = handle.shutdown();
        table.row(vec![
            conns.to_string(),
            r.ops.to_string(),
            format!("{:.0}", r.ops_per_s),
            format!("{:.3}", r.commit_latency_s * 1e3),
            format!("{:.3}", r.commit_p50_s * 1e3),
            format!("{:.3}", r.commit_p99_s * 1e3),
            r.added.to_string(),
            r.removed.to_string(),
        ]);
    }
    table.print();
}

/// Scripted scenario driven by a config file: a population of moving
/// vehicle federates publishing position updates each step.
fn cmd_serve_scripted(args: &Args) {
    let cfg_path = args.get("config").map(std::path::PathBuf::from);
    let cfg = cfg_path
        .as_deref()
        .map(|p| ddm::config::Config::load(p).expect("config loads"))
        .unwrap_or_else(|| ddm::config::Config::parse("").unwrap());
    let steps = args.opt("steps", cfg.int_or("serve", "steps", 50) as usize);
    let vehicles = args.opt("vehicles", cfg.int_or("serve", "vehicles", 200) as usize);
    let threads = args.opt("threads", cfg.int_or("serve", "threads", 2) as usize);
    let space_len = cfg.int_or("serve", "space", 100_000) as u64;

    let algo = cfg.str_or("serve", "algo", "psbm");
    let shards = args.opt("shards", cfg.int_or("serve", "shards", 1) as usize);
    let coord = Coordinator::spawn(CoordinatorConfig::new(
        RoutingSpace::uniform(1, space_len),
        DdmEngine::builder()
            .algo_str(args.get("algo").unwrap_or(&algo))
            .unwrap_or_else(|e| die(&e))
            .threads(threads)
            .shards(shards)
            .build(),
    ));
    let c = coord.client();
    let fed = c.join("vehicles");
    let mut rng = ddm::prng::Rng::new(args.opt("seed", 7u64));
    let mut handles = Vec::new();
    for _ in 0..vehicles {
        let x = rng.below(space_len - 200);
        let sub = c
            .register(fed, RegionKind::Subscription, RegionSpec::interval(x, x + 200))
            .unwrap();
        let upd = c
            .register(fed, RegionKind::Update, RegionSpec::interval(x + 50, x + 150))
            .unwrap();
        handles.push((sub, upd, x));
    }
    let t0 = Instant::now();
    let mut delivered = 0usize;
    for step in 0..steps {
        for (sub, upd, x) in handles.iter_mut() {
            *x = (*x + rng.below(20)).min(space_len - 200);
            c.modify(*sub, RegionSpec::interval(*x, *x + 200)).unwrap();
            c.modify(*upd, RegionSpec::interval(*x + 50, *x + 150)).unwrap();
            delivered += c.publish(*upd, step as u64).unwrap();
        }
        let _ = c.poll(fed);
    }
    let dt = t0.elapsed();
    println!(
        "serve: {steps} steps x {vehicles} vehicles -> {delivered} notifications in {} \
         ({:.0} publishes/s)",
        ddm::bench::stats::fmt_secs(dt.as_secs_f64()),
        (steps * vehicles) as f64 / dt.as_secs_f64()
    );
    let m = coord.shutdown();
    m.table().print();
}

/// Offline scan of a durability directory: decode the checkpoint and
/// the committed log tail (exactly what recovery would keep) without
/// building a session, and print the last durable epoch, pair count
/// and fingerprint — the values `ddm client --expect-epoch
/// --expect-fingerprint` asserts against a resumed server.
fn cmd_wal_info(args: &Args) {
    let Some(dir) = args.get("dir") else {
        die("--dir DIR is required");
    };
    let st = ddm::durable::recover::scan_dir(std::path::Path::new(dir))
        .unwrap_or_else(|e| die(&format!("wal-info {dir}: {e}")));
    println!(
        "epoch={} pairs={} fingerprint={:08x}",
        st.last_epoch, st.last_n_pairs, st.last_fingerprint
    );
    match &st.snapshot {
        Some(snap) => println!(
            "snapshot: epoch {} ({} subscription + {} update regions)",
            snap.epoch,
            snap.subs.len(),
            snap.upds.len()
        ),
        None => println!("snapshot: none"),
    }
    let batch_ops: usize = st.batches.iter().map(|b| b.ops.len()).sum();
    println!(
        "log: {} committed batches ({} ops) in {} records / {} bytes; \
         tail: {} torn bytes, {} uncommitted ops",
        st.batches.len(),
        batch_ops,
        st.log_records,
        st.log_bytes,
        st.tail_bytes,
        st.open_ops
    );
}

fn cmd_info(_args: &Args) {
    println!("host:");
    sysinfo::table1().print();
    let dir = std::path::Path::new(ddm::runtime::DEFAULT_ARTIFACT_DIR);
    if ddm::runtime::artifacts_available(dir) {
        let m = ddm::runtime::Manifest::load(dir).expect("manifest");
        println!("\nartifacts ({}):", m.entries.len());
        for e in m.entries {
            println!(
                "  {} kind={:?} n={} m={} d={} [{}]",
                e.name,
                e.kind,
                e.n,
                e.m,
                e.d,
                e.path.display()
            );
        }
    } else {
        println!("\nartifacts: NOT BUILT (run `make artifacts`)");
    }
}

fn main() {
    let all: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = all.first().cloned() else { usage() };
    let args = Args::from_iter(all.into_iter().skip(1));
    match cmd.as_str() {
        "match" => cmd_match(&args),
        "xla-match" => cmd_xla_match(&args),
        "replay" => cmd_replay(&args),
        "trace" => cmd_trace(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "client" => cmd_client(&args),
        "bench-net" => cmd_bench_net(&args),
        "wal-info" => cmd_wal_info(&args),
        "info" => cmd_info(&args),
        _ => usage(),
    }
}

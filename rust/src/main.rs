//! `ddm` — the coordinator binary.
//!
//! Subcommands:
//!   ddm match      run one matching job and report K + wall-clock
//!   ddm xla-match  same, on the AOT-compiled XLA backend
//!   ddm replay     replay epochs of region churn (session diffs,
//!                  sharded session diffs, or full rebuild per epoch)
//!   ddm serve      run the coordinator service on a scripted scenario
//!   ddm info       host/Table-1 report + artifact status
//!
//! Examples:
//!   ddm match --algo psbm --n 1e6 --alpha 100 --threads 8 --set bit
//!   ddm match --algo psbm --n 1e6 --repeat 5 --sort radix   # cold vs warm
//!   ddm match --algo psbm --n 1e6 --sort merge              # A/B the sort
//!   ddm match --algo gbm --workload koln --scale 0.1 --ncells 3000
//!   ddm replay --n 50k --epochs 10 --churn 0.05 --mode session --verify
//!   ddm replay --mode sharded --shards 8 --hotspot 0.8 --verify
//!   ddm replay --workload koln --scale 0.05 --mode rebuild
//!   ddm match --algo psbm --n 1e6 --shards 8
//!   ddm xla-match --n 4096 --alpha 10
//!   ddm serve --config examples/service.toml

use std::time::Instant;

use ddm::bench::{rss, sysinfo};
use ddm::cli::{die, Args};
use ddm::coordinator::{Coordinator, CoordinatorConfig};
use ddm::engine::{DdmEngine, NdMode, SweepDim};
use ddm::exec::SortAlgo;
use ddm::hla::{RegionKind, RegionSpec, RoutingSpace};
use ddm::sets::SetImpl;
use ddm::workload::koln::{koln_workload, KolnParams};
use ddm::workload::{alpha_workload, nd_alpha_workload, nd_correlated_workload, AlphaParams,
    NdAlphaParams};

fn usage() -> ! {
    eprintln!(
        "usage: ddm <match|xla-match|replay|serve|info> [options]\n\
         options are documented in rust/src/main.rs and README.md"
    );
    std::process::exit(2)
}

fn load_workload(args: &Args) -> (ddm::core::Regions1D, ddm::core::Regions1D, String) {
    let seed: u64 = args.opt("seed", 42u64);
    match args.get("workload").unwrap_or("alpha") {
        "koln" => {
            let p = KolnParams::default().scaled(args.opt("scale", 1.0f64));
            let (s, u) = koln_workload(seed, &p);
            (s, u, format!("koln positions={}", p.positions))
        }
        _ => {
            let p = AlphaParams {
                n_total: args.size("n", 1_000_000),
                alpha: args.opt("alpha", 100.0),
                space: args.opt("space", 1e6),
            };
            let (s, u) = alpha_workload(seed, &p);
            (s, u, format!("alpha N={} α={}", p.n_total, p.alpha))
        }
    }
}

/// Run one matching job: 1-D by default; `--d N` (or `--alphas
/// a0,a1,…`) switches to a d-dimensional workload and the N-D pipeline
/// (`--nd-mode native|reduce`, `--sweep-dim auto|k`, `--rho c` for the
/// correlated generator). `--sort radix|merge` A/Bs the endpoint sort;
/// `--repeat R` re-runs the match R times and reports cold vs warm
/// timings (warm calls reuse the engine's match scratch).
fn cmd_match(args: &Args) {
    let threads: usize = args.opt("threads", 4usize);
    let nd_mode: NdMode = args
        .try_opt("nd-mode")
        .unwrap_or_else(|e| die(&e))
        .unwrap_or_default();
    let sweep: SweepDim = args
        .try_opt("sweep-dim")
        .unwrap_or_else(|e| die(&e))
        .unwrap_or_default();
    let sort: SortAlgo = args
        .try_opt("sort")
        .unwrap_or_else(|e| die(&e))
        .unwrap_or_default();
    let repeat: usize = args.opt("repeat", 1usize);
    if repeat == 0 {
        die("--repeat=0: need at least one run");
    }
    let engine = DdmEngine::builder()
        .algo_str(args.get("algo").unwrap_or("psbm"))
        .unwrap_or_else(|e| die(&e))
        .threads(threads)
        .ncells(args.opt("ncells", 3000usize))
        .shards(args.opt("shards", 1usize))
        .nd_mode(nd_mode)
        .sweep_dim(sweep)
        .sort_algo(sort)
        .set_impl(
            args.get("set")
                .map(|s| s.parse::<SetImpl>().unwrap_or_else(|e| die(&e)))
                .unwrap_or(SetImpl::Sparse),
        )
        .build();

    // d > 1 (or an explicit per-dimension α list): N-D workload + the
    // engine's N-D pipeline.
    let alphas: Option<Vec<f64>> = args.try_list("alphas").unwrap_or_else(|e| die(&e));
    let d: usize = args.opt("d", alphas.as_ref().map_or(1, Vec::len));
    if d > 1 || alphas.is_some() {
        let alphas =
            alphas.unwrap_or_else(|| vec![args.opt("alpha", 100.0); d.max(1)]);
        if d != alphas.len() {
            die(&format!(
                "--d {d} disagrees with --alphas ({} values)",
                alphas.len()
            ));
        }
        let p = NdAlphaParams::skewed(
            args.size("n", 100_000),
            &alphas,
            args.opt("space", 1e6),
        );
        let seed: u64 = args.opt("seed", 42u64);
        let (subs, upds) = match args.try_opt::<f64>("rho").unwrap_or_else(|e| die(&e)) {
            Some(rho) => nd_correlated_workload(seed, &p, rho),
            None => nd_alpha_workload(seed, &p),
        };
        println!(
            "match: algo={} threads={} d={} nd-mode={:?} sweep-dim={:?} sort={} α={:?} N={}",
            engine.algo_name(),
            threads,
            p.d(),
            nd_mode,
            sweep,
            sort.name(),
            p.alphas,
            p.n_total
        );
        report_counts(repeat, || engine.count_nd(&subs, &upds));
        return;
    }

    let (subs, upds, desc) = load_workload(args);
    println!(
        "match: algo={} threads={} set={} sort={} workload=[{}]",
        engine.algo_name(),
        threads,
        engine.params().set_impl.name(),
        sort.name(),
        desc
    );
    report_counts(repeat, || engine.count_1d(&subs, &upds));
}

/// Run one counting job `repeat` times and report the cold (first)
/// and best-warm timings — warm runs reuse the engine's match
/// scratch, so the gap is the allocation + buffer-growth cost the
/// scratch eliminates. All runs must agree on K.
fn report_counts(repeat: usize, mut count: impl FnMut() -> u64) {
    let t0 = Instant::now();
    let k = count();
    let cold = t0.elapsed().as_secs_f64();
    let rss = rss::peak_rss_bytes().map(rss::fmt_bytes).unwrap_or_default();
    if repeat <= 1 {
        println!(
            "K={k} intersections in {} (peak RSS {rss})",
            ddm::bench::stats::fmt_secs(cold)
        );
        return;
    }
    let mut warm_best = f64::INFINITY;
    for r in 1..repeat {
        let t = Instant::now();
        let k2 = count();
        warm_best = warm_best.min(t.elapsed().as_secs_f64());
        if k2 != k {
            die(&format!("repeat run {r} returned K={k2}, first run K={k}"));
        }
    }
    println!(
        "K={k} intersections; cold {} warm {} (best of {} scratch-reusing runs; peak RSS {rss})",
        ddm::bench::stats::fmt_secs(cold),
        ddm::bench::stats::fmt_secs(warm_best),
        repeat - 1
    );
}

fn cmd_xla_match(args: &Args) {
    let dir = std::path::Path::new(ddm::runtime::DEFAULT_ARTIFACT_DIR);
    if !ddm::runtime::artifacts_available(dir) {
        if ddm::runtime::xla_enabled() {
            eprintln!("artifacts missing: run `make artifacts` first");
        } else {
            eprintln!(
                "XLA backend unavailable: rebuild with `--features xla` (and run `make artifacts`)"
            );
        }
        std::process::exit(1);
    }
    let (subs, upds, desc) = load_workload(args);
    println!("xla-match: workload=[{desc}]");
    let t0 = Instant::now();
    let be = ddm::runtime::XlaMatchBackend::load(dir).expect("backend");
    let t_load = t0.elapsed();
    let t1 = Instant::now();
    let k = be.match_counts_1d(&subs, &upds).expect("xla match");
    println!(
        "K={k} in {} (backend load+compile {})",
        ddm::bench::stats::fmt_secs(t1.elapsed().as_secs_f64()),
        ddm::bench::stats::fmt_secs(t_load.as_secs_f64()),
    );
}

/// Replay epochs of region churn over a workload: on a `DdmSession`
/// (staged batch + `MatchDiff` per epoch — the incremental path), on a
/// spatially sharded session (`--mode sharded --shards N`, per-shard
/// parallel commits with merged deduplicated diffs), or by full
/// re-match per epoch (`--mode rebuild`, the baseline both replace).
/// All modes run the identical deterministic move script — optionally
/// skewed with `--hotspot` — so their reported per-epoch pair churn
/// can be compared directly.
fn cmd_replay(args: &Args) {
    use ddm::workload::churn::{diff_pair_counts, relocate, MoveScript};

    let threads: usize = args.opt("threads", 4usize);
    let epochs: usize = args.opt("epochs", 10usize);
    let churn: f64 = args.opt("churn", 0.05f64);
    let shards: usize = args.opt("shards", 4usize);
    let hotspot: f64 = args.opt("hotspot", 0.0f64);
    let mode = args.get("mode").unwrap_or("session").to_string();
    let seed: u64 = args.opt("seed", 42u64);

    let (mut subs, mut upds, desc) = match args.get("workload").unwrap_or("alpha") {
        "koln" => {
            let p = KolnParams::default().scaled(args.opt("scale", 0.05f64));
            let (s, u) = koln_workload(seed, &p);
            (s, u, format!("koln positions={}", p.positions))
        }
        _ => {
            let p = AlphaParams {
                n_total: args.size("n", 50_000),
                alpha: args.opt("alpha", 100.0),
                space: args.opt("space", 1e6),
            };
            let (s, u) = alpha_workload(seed, &p);
            (s, u, format!("alpha N={} α={}", p.n_total, p.alpha))
        }
    };
    let space_hi = subs
        .bounds()
        .map(|b| b.hi)
        .unwrap_or(1e6)
        .max(upds.bounds().map(|b| b.hi).unwrap_or(0.0));
    let n_regions = subs.len() + upds.len();
    let moves_per_epoch = ((n_regions as f64) * churn).ceil().max(1.0) as usize;
    let shard_note = if mode == "sharded" {
        format!(" shards={shards}")
    } else {
        String::new()
    };
    println!(
        "replay: mode={mode}{shard_note} epochs={epochs} churn={churn} hotspot={hotspot} \
         ({moves_per_epoch} moves/epoch) threads={threads} workload=[{desc}]"
    );

    let engine = DdmEngine::builder()
        .algo_str(args.get("algo").unwrap_or("psbm"))
        .unwrap_or_else(|e| die(&e))
        .threads(threads)
        .build();
    // All modes replay the identical deterministic move script.
    let mut script = MoveScript::with_hotspot(seed ^ 0xC0FFEE, hotspot);
    let (mut tot_added, mut tot_removed) = (0usize, 0usize);
    match mode.as_str() {
        "session" | "sharded" => {
            let mut sess = if mode == "sharded" {
                ddm::shard::AnySession::Sharded(engine.sharded_session_with(
                    1,
                    ddm::shard::SpacePartitioner::uniform(
                        shards,
                        0,
                        ddm::core::Interval::new(0.0, space_hi),
                    ),
                ))
            } else {
                ddm::shard::AnySession::Single(engine.session(1))
            };
            let t0 = Instant::now();
            sess.load_dense_1d(&subs, &upds);
            let d0 = sess.commit();
            println!(
                "epoch 0: {} initial pairs in {}",
                d0.added.len(),
                ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
            );
            let t1 = Instant::now();
            for e in 1..=epochs {
                for _ in 0..moves_per_epoch {
                    let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
                    if sub_side {
                        let iv = relocate(&mut subs, idx, frac, space_hi);
                        sess.upsert_subscription(idx as u32, &[iv]);
                    } else {
                        let iv = relocate(&mut upds, idx, frac, space_hi);
                        sess.upsert_update(idx as u32, &[iv]);
                    }
                }
                let d = sess.commit();
                tot_added += d.added.len();
                tot_removed += d.removed.len();
                println!("epoch {e}: +{} -{} pairs", d.added.len(), d.removed.len());
            }
            let dt = t1.elapsed().as_secs_f64();
            println!(
                "{mode} replay: {} pairs live, +{tot_added} -{tot_removed} churned, \
                 {} per epoch",
                sess.n_pairs(),
                ddm::bench::stats::fmt_secs(dt / epochs.max(1) as f64)
            );
            if let Some(im) = sess.imbalance() {
                println!("shard imbalance: {im:.2} over {} shards", sess.shards());
            }
            if args.flag("verify") {
                let want = engine.pairs_1d(&subs, &upds);
                assert_eq!(sess.pairs(), want, "{mode} state diverged from static match");
                println!("verify: {mode} pair set == fresh static match ({} pairs)", want.len());
            }
        }
        "rebuild" => {
            let t0 = Instant::now();
            let mut prev = engine.pairs_1d(&subs, &upds);
            println!(
                "epoch 0: {} initial pairs in {}",
                prev.len(),
                ddm::bench::stats::fmt_secs(t0.elapsed().as_secs_f64())
            );
            let t1 = Instant::now();
            for e in 1..=epochs {
                for _ in 0..moves_per_epoch {
                    let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
                    if sub_side {
                        relocate(&mut subs, idx, frac, space_hi);
                    } else {
                        relocate(&mut upds, idx, frac, space_hi);
                    }
                }
                let cur = engine.pairs_1d(&subs, &upds);
                let (added, removed) = diff_pair_counts(&prev, &cur);
                tot_added += added;
                tot_removed += removed;
                println!("epoch {e}: +{added} -{removed} pairs");
                prev = cur;
            }
            let dt = t1.elapsed().as_secs_f64();
            println!(
                "rebuild replay: {} pairs live, +{tot_added} -{tot_removed} churned, \
                 {} per epoch",
                prev.len(),
                ddm::bench::stats::fmt_secs(dt / epochs.max(1) as f64)
            );
        }
        other => {
            eprintln!("unknown replay mode '{other}' (session|sharded|rebuild)");
            std::process::exit(2);
        }
    }
}

fn cmd_serve(args: &Args) {
    // Scripted scenario driven by a config file: a population of
    // moving vehicle federates publishing position updates each step.
    let cfg_path = args.get("config").map(std::path::PathBuf::from);
    let cfg = cfg_path
        .as_deref()
        .map(|p| ddm::config::Config::load(p).expect("config loads"))
        .unwrap_or_else(|| ddm::config::Config::parse("").unwrap());
    let steps = args.opt("steps", cfg.int_or("serve", "steps", 50) as usize);
    let vehicles = args.opt("vehicles", cfg.int_or("serve", "vehicles", 200) as usize);
    let threads = args.opt("threads", cfg.int_or("serve", "threads", 2) as usize);
    let space_len = cfg.int_or("serve", "space", 100_000) as u64;

    let algo = cfg.str_or("serve", "algo", "psbm");
    let shards = args.opt("shards", cfg.int_or("serve", "shards", 1) as usize);
    let coord = Coordinator::spawn(CoordinatorConfig::new(
        RoutingSpace::uniform(1, space_len),
        DdmEngine::builder()
            .algo_str(args.get("algo").unwrap_or(&algo))
            .unwrap_or_else(|e| die(&e))
            .threads(threads)
            .shards(shards)
            .build(),
    ));
    let c = coord.client();
    let fed = c.join("vehicles");
    let mut rng = ddm::prng::Rng::new(args.opt("seed", 7u64));
    let mut handles = Vec::new();
    for _ in 0..vehicles {
        let x = rng.below(space_len - 200);
        let sub = c
            .register(fed, RegionKind::Subscription, RegionSpec::interval(x, x + 200))
            .unwrap();
        let upd = c
            .register(fed, RegionKind::Update, RegionSpec::interval(x + 50, x + 150))
            .unwrap();
        handles.push((sub, upd, x));
    }
    let t0 = Instant::now();
    let mut delivered = 0usize;
    for step in 0..steps {
        for (sub, upd, x) in handles.iter_mut() {
            *x = (*x + rng.below(20)).min(space_len - 200);
            c.modify(*sub, RegionSpec::interval(*x, *x + 200)).unwrap();
            c.modify(*upd, RegionSpec::interval(*x + 50, *x + 150)).unwrap();
            delivered += c.publish(*upd, step as u64).unwrap();
        }
        let _ = c.poll(fed);
    }
    let dt = t0.elapsed();
    println!(
        "serve: {steps} steps x {vehicles} vehicles -> {delivered} notifications in {} \
         ({:.0} publishes/s)",
        ddm::bench::stats::fmt_secs(dt.as_secs_f64()),
        (steps * vehicles) as f64 / dt.as_secs_f64()
    );
    let m = coord.shutdown();
    m.table().print();
}

fn cmd_info(_args: &Args) {
    println!("host:");
    sysinfo::table1().print();
    let dir = std::path::Path::new(ddm::runtime::DEFAULT_ARTIFACT_DIR);
    if ddm::runtime::artifacts_available(dir) {
        let m = ddm::runtime::Manifest::load(dir).expect("manifest");
        println!("\nartifacts ({}):", m.entries.len());
        for e in m.entries {
            println!(
                "  {} kind={:?} n={} m={} d={} [{}]",
                e.name,
                e.kind,
                e.n,
                e.m,
                e.d,
                e.path.display()
            );
        }
    } else {
        println!("\nartifacts: NOT BUILT (run `make artifacts`)");
    }
}

fn main() {
    let all: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = all.first().cloned() else { usage() };
    let args = Args::from_iter(all.into_iter().skip(1));
    match cmd.as_str() {
        "match" => cmd_match(&args),
        "xla-match" => cmd_xla_match(&args),
        "replay" => cmd_replay(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(&args),
        _ => usage(),
    }
}

//! Blocking clients: one socket ([`NetClient`]) and the federation
//! front-end ([`FederationClient`]) that speaks to a router + workers.
//!
//! `FederationClient` is where exactly-once reporting crosses process
//! boundaries. It re-runs the refcount-merge protocol of
//! [`ShardedSession`](crate::shard::ShardedSession) one level up:
//!
//! * each worker owns contiguous global stripes and reports a
//!   *worker-local* diff per epoch — itself already a refcounted merge
//!   over that worker's stripes;
//! * the client folds worker diffs through a `pair → refcount` map and
//!   surfaces only `0 ↔ >0` transitions.
//!
//! Refcounts compose hierarchically: a pair is globally matched iff
//! some stripe matches it, a worker's diff is exactly its
//! worker-presence delta, so the client-side fold reproduces — pair
//! for pair, epoch for epoch — the diff a flat `ShardedSession` over
//! the same global cuts would emit. The integration suite and
//! `abl_net` assert that equality byte-for-byte.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::core::interval::Interval;
use crate::core::sink::{pack_pair, unpack_pair, PairVec};
use crate::session::MatchDiff;
use crate::shard::SpacePartitioner;

use super::proto::{MetricsSnapshot, Msg, RegionOp, Role, TopologySnapshot, PROTO_ID};

/// Typed client-side failure surface. `Busy` is the one *retryable*
/// error: the worker's admission control rejected staged ops
/// ([`Msg::Busy`]), and because region ops are idempotent last-writer-
/// wins upserts/removes, the cure is to back off and resend the
/// in-flight window — which is exactly what
/// [`FederationClient::settle`] does. Everything else is fatal for the
/// frames in flight (reconnect and resync, or give up).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NetError {
    /// Admission-control rejection: ops were dropped, retry after
    /// backoff. Carries the observed backlog depth and its limit.
    Busy { pending: u64, limit: u64 },
    /// Transport or protocol failure.
    Fatal(String),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Busy { pending, limit } => {
                write!(f, "server busy: backlog {pending}/{limit}, retry after backoff")
            }
            NetError::Fatal(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for NetError {}

/// What a [`NetClient::barrier`] round-trip observed on its way to the
/// matching `SyncAck`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BarrierInfo {
    /// Server epoch at the ack.
    pub epoch: u64,
    /// Ops staged (accepted) server-side at the ack.
    pub pending: u64,
    /// `Busy` rejections consumed while waiting — each one is an op
    /// the server dropped since the last barrier.
    pub busy: u64,
    /// Backlog limit from the last `Busy` frame (0 when none seen).
    pub limit: u64,
}

/// One blocking connection to a DDM server, with the `Hello`/`Welcome`
/// handshake already done.
///
/// Replies are matched by arrival order, so keep a connection to one
/// conversation at a time: a connection that `Subscribe`d should not
/// also issue `commit()` while *other* clients commit, or it may read
/// a broadcast diff as its reply (single-committer setups — every test
/// and bench here — are unambiguous).
pub struct NetClient {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    role: Role,
    d: usize,
    epoch: u64,
}

impl NetClient {
    /// Connect, handshake, and return a ready client. The socket gets
    /// a 30 s connect/read/write deadline (see
    /// [`connect_with`](Self::connect_with) to choose one) so a hung
    /// server turns into an error, never a stuck process.
    pub fn connect(addr: &str) -> crate::Result<Self> {
        Self::connect_with(addr, Duration::from_secs(30))
    }

    /// Connect with an explicit deadline applied to the TCP connect
    /// and, as read/write timeouts, to every frame after it (CLI
    /// `--timeout-ms`). A zero duration means no deadline anywhere —
    /// block forever, the pre-timeout behavior.
    pub fn connect_with(addr: &str, timeout: Duration) -> crate::Result<Self> {
        use std::net::ToSocketAddrs;
        let stream = if timeout.is_zero() {
            TcpStream::connect(addr)?
        } else {
            let mut last: Option<std::io::Error> = None;
            let mut found = None;
            for sa in addr.to_socket_addrs()? {
                match TcpStream::connect_timeout(&sa, timeout) {
                    Ok(s) => {
                        found = Some(s);
                        break;
                    }
                    Err(e) => last = Some(e),
                }
            }
            match (found, last) {
                (Some(s), _) => s,
                (None, Some(e)) => return Err(e.into()),
                (None, None) => crate::bail!("{addr} resolved to no addresses"),
            }
        };
        stream.set_nodelay(true)?;
        if !timeout.is_zero() {
            stream.set_read_timeout(Some(timeout))?;
            stream.set_write_timeout(Some(timeout))?;
        }
        let mut c = Self {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            role: Role::Worker,
            d: 0,
            epoch: 0,
        };
        c.send(&Msg::Hello { proto: PROTO_ID })?;
        match c.recv()? {
            Msg::Welcome { role, d, epoch } => {
                c.role = role;
                c.d = d as usize;
                c.epoch = epoch;
                Ok(c)
            }
            Msg::ErrorReply { code, msg } => {
                crate::bail!("handshake rejected by {addr}: error {code}: {msg}")
            }
            other => crate::bail!("unexpected handshake reply from {addr}: {other:?}"),
        }
    }

    /// Endpoint role from the handshake.
    pub fn role(&self) -> Role {
        self.role
    }

    /// Session dimensionality from the handshake.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Last epoch observed (handshake or most recent diff).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Override the read timeout (benches and tests shorten it).
    pub fn set_timeout(&mut self, t: Duration) -> crate::Result<()> {
        self.stream.set_read_timeout(Some(t))?;
        Ok(())
    }

    /// Read deadline per `recv` (`None`: block forever).
    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> crate::Result<()> {
        self.stream.set_read_timeout(t)?;
        Ok(())
    }

    /// Write deadline per `send` (`None`: block forever).
    pub fn set_write_timeout(&mut self, t: Option<Duration>) -> crate::Result<()> {
        self.stream.set_write_timeout(t)?;
        Ok(())
    }

    /// Encode and write one message (blocking until accepted).
    pub fn send(&mut self, msg: &Msg) -> crate::Result<()> {
        self.wbuf.clear();
        msg.encode(&mut self.wbuf);
        self.stream.write_all(&self.wbuf)?;
        Ok(())
    }

    /// Read the next message (blocking, bounded by the read timeout).
    pub fn recv(&mut self) -> crate::Result<Msg> {
        let mut tmp = [0u8; 16 * 1024];
        loop {
            if let Some((msg, used)) = Msg::decode(&self.rbuf)? {
                self.rbuf.drain(..used);
                if let Msg::Diff(d) = &msg {
                    self.epoch = d.epoch;
                }
                return Ok(msg);
            }
            let n = self.stream.read(&mut tmp)?;
            if n == 0 {
                crate::bail!("connection closed by server");
            }
            self.rbuf.extend_from_slice(&tmp[..n]);
        }
    }

    /// Next message, with server `ErrorReply` promoted to an error.
    fn recv_ok(&mut self, awaiting: &str) -> crate::Result<Msg> {
        match self.recv()? {
            Msg::ErrorReply { code, msg } => {
                crate::bail!("server error {code} while awaiting {awaiting}: {msg}")
            }
            msg => Ok(msg),
        }
    }

    /// Stage one region op (fire-and-forget; the server stages it into
    /// the session's LWW batch).
    pub fn op(&mut self, op: RegionOp) -> crate::Result<()> {
        self.send(&Msg::Op(op))
    }

    /// Stage a batch of ops in one frame.
    pub fn batch(&mut self, ops: Vec<RegionOp>) -> crate::Result<()> {
        self.send(&Msg::Batch(ops))
    }

    /// Apply staged ops without closing an epoch.
    pub fn flush(&mut self) -> crate::Result<()> {
        self.send(&Msg::Flush)
    }

    /// Close an epoch: commit and return the resulting diff.
    pub fn commit(&mut self) -> crate::Result<MatchDiff> {
        self.send(&Msg::Commit)?;
        self.await_diff()
    }

    /// Wait for the next [`Msg::Diff`] (skipping unrelated frames such
    /// as `SyncAck`s from earlier pipelined requests).
    pub fn await_diff(&mut self) -> crate::Result<MatchDiff> {
        loop {
            if let Msg::Diff(d) = self.recv_ok("diff")? {
                return Ok(d);
            }
        }
    }

    /// Round-trip a `Sync` token: returns `(epoch, staged ops)`. Acts
    /// as a barrier proving the server consumed everything sent before
    /// it.
    pub fn sync(&mut self, token: u64) -> crate::Result<(u64, u64)> {
        self.send(&Msg::Sync { token })?;
        loop {
            if let Msg::SyncAck {
                token: t,
                epoch,
                pending,
            } = self.recv_ok("sync ack")?
            {
                if t == token {
                    return Ok((epoch, pending));
                }
            }
        }
    }

    /// [`sync`](Self::sync), but accounting for admission control: any
    /// [`Msg::Busy`] consumed on the way to the ack is an op the
    /// server *dropped* since the last barrier, reported in
    /// [`BarrierInfo::busy`] so the caller knows its in-flight window
    /// needs resending ([`FederationClient::settle`] is that loop).
    pub fn barrier(&mut self, token: u64) -> crate::Result<BarrierInfo> {
        self.send(&Msg::Sync { token })?;
        let mut info = BarrierInfo::default();
        loop {
            match self.recv_ok("sync ack")? {
                Msg::SyncAck {
                    token: t,
                    epoch,
                    pending,
                } if t == token => {
                    info.epoch = epoch;
                    info.pending = pending;
                    return Ok(info);
                }
                Msg::Busy { limit, .. } => {
                    info.busy += 1;
                    info.limit = limit;
                }
                _ => {}
            }
        }
    }

    /// Ask for every future epoch's diff on this connection.
    pub fn subscribe(&mut self) -> crate::Result<()> {
        self.send(&Msg::Subscribe)
    }

    /// Fetch the retained pair set.
    pub fn pairs(&mut self) -> crate::Result<PairVec> {
        self.send(&Msg::GetPairs)?;
        loop {
            if let Msg::Pairs(p) = self.recv_ok("pairs")? {
                return Ok(p);
            }
        }
    }

    /// Fetch the server's metrics snapshot.
    pub fn metrics(&mut self) -> crate::Result<MetricsSnapshot> {
        self.send(&Msg::GetMetrics)?;
        loop {
            if let Msg::Metrics(m) = self.recv_ok("metrics")? {
                return Ok(m);
            }
        }
    }

    /// Fetch the federation topology (router endpoints only).
    pub fn topology(&mut self) -> crate::Result<TopologySnapshot> {
        self.send(&Msg::GetTopology)?;
        loop {
            if let Msg::Topology(t) = self.recv_ok("topology")? {
                return Ok(t);
            }
        }
    }

    /// Ask the server to shut down (it flushes, commits, and says
    /// `Goodbye` to everyone).
    pub fn shutdown_server(&mut self) -> crate::Result<()> {
        self.send(&Msg::Shutdown)
    }

    /// Wait for the server's `Goodbye`; returns its final epoch.
    pub fn await_goodbye(&mut self) -> crate::Result<u64> {
        loop {
            if let Msg::Goodbye { epoch } = self.recv_ok("goodbye")? {
                return Ok(epoch);
            }
        }
    }
}

/// Where a key currently lives: the inclusive worker range holding
/// replicas of its region.
type WorkerRange = (usize, usize);

/// A client of a whole federation: routes ops to the workers owning
/// each region's stripes, merges their per-epoch diffs exactly once.
pub struct FederationClient {
    part: SpacePartitioner,
    /// Global stripe index → worker index (non-decreasing, so a stripe
    /// range maps to a contiguous worker range).
    stripe_worker: Vec<usize>,
    workers: Vec<NetClient>,
    /// Worker addresses from the topology, kept for reconnects.
    addrs: Vec<String>,
    /// Per-worker ops sent since that worker's last clean barrier:
    /// the resend window admission control ([`NetError::Busy`]) and
    /// reconnects replay. Idempotent LWW ops make over-resending safe.
    inflight: Vec<Vec<RegionOp>>,
    sub_home: HashMap<u32, WorkerRange>,
    upd_home: HashMap<u32, WorkerRange>,
    /// packed pair → number of workers currently reporting it.
    pair_refs: HashMap<u64, u32>,
    epoch: u64,
    d: usize,
    /// Connect/read/write deadline for reconnects (what the original
    /// connections were made with).
    timeout: Duration,
    sync_token: u64,
    /// Backoff jitter source (seeded once from the monotonic clock).
    rng: crate::prng::Rng,
}

impl FederationClient {
    /// Connect to the router at `addr`, fetch the topology, connect to
    /// every worker. The router connection is dropped afterwards — it
    /// is not part of the hot path.
    pub fn connect(addr: &str) -> crate::Result<Self> {
        Self::connect_with(addr, Duration::from_secs(30))
    }

    /// [`connect`](Self::connect) with an explicit connect/read/write
    /// deadline applied to the router and every worker connection
    /// (and remembered for reconnects). Zero: no deadline.
    pub fn connect_with(addr: &str, timeout: Duration) -> crate::Result<Self> {
        let mut router = NetClient::connect_with(addr, timeout)?;
        if router.role() != Role::Router {
            crate::bail!("{addr} is not a router (role {:?})", router.role());
        }
        let topo = router.topology()?;
        Self::from_topology_timeout(&topo, timeout)
    }

    /// Build directly from a topology snapshot (what `connect` does
    /// after asking the router).
    pub fn from_topology(topo: &TopologySnapshot) -> crate::Result<Self> {
        Self::from_topology_timeout(topo, Duration::from_secs(30))
    }

    /// [`from_topology`](Self::from_topology) with an explicit worker
    /// connect/read/write deadline. Zero: no deadline.
    pub fn from_topology_timeout(
        topo: &TopologySnapshot,
        timeout: Duration,
    ) -> crate::Result<Self> {
        let shards = topo.shards();
        if topo.workers.is_empty() {
            crate::bail!("topology has no workers");
        }
        let mut stripe_worker = vec![usize::MAX; shards];
        for (w, entry) in topo.workers.iter().enumerate() {
            if entry.first > entry.last || entry.last as usize >= shards {
                crate::bail!(
                    "worker {} claims stripes {}..={} outside 0..{shards}",
                    entry.addr,
                    entry.first,
                    entry.last
                );
            }
            for s in entry.first..=entry.last {
                if stripe_worker[s as usize] != usize::MAX {
                    crate::bail!("stripe {s} claimed by two workers");
                }
                stripe_worker[s as usize] = w;
            }
        }
        if stripe_worker.contains(&usize::MAX) {
            crate::bail!("topology leaves stripes unowned");
        }
        if stripe_worker.windows(2).any(|w| w[1] < w[0]) {
            crate::bail!("worker stripe ranges must be listed in stripe order");
        }
        Self::from_topology_with(topo, stripe_worker, timeout)
    }

    fn from_topology_with(
        topo: &TopologySnapshot,
        stripe_worker: Vec<usize>,
        timeout: Duration,
    ) -> crate::Result<Self> {
        let mut workers = Vec::with_capacity(topo.workers.len());
        let mut addrs = Vec::with_capacity(topo.workers.len());
        for entry in &topo.workers {
            let c = NetClient::connect_with(&entry.addr, timeout)?;
            if c.d() != topo.d as usize {
                crate::bail!(
                    "worker {} serves d={} but topology says d={}",
                    entry.addr,
                    c.d(),
                    topo.d
                );
            }
            workers.push(c);
            addrs.push(entry.addr.clone());
        }
        let n = workers.len();
        Ok(Self {
            part: SpacePartitioner::from_cuts(topo.split_dim as usize, topo.cuts.clone()),
            stripe_worker,
            workers,
            addrs,
            inflight: vec![Vec::new(); n],
            sub_home: HashMap::new(),
            upd_home: HashMap::new(),
            pair_refs: HashMap::new(),
            epoch: 0,
            d: topo.d as usize,
            timeout,
            sync_token: 0,
            rng: crate::prng::Rng::new(crate::obs::clock::now_ns() | 1),
        })
    }

    /// Worker count.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Dimensionality of the federation's routing space.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Last merged epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Globally matched pair count (from the merge refcounts).
    pub fn n_pairs(&self) -> usize {
        self.pair_refs.len()
    }

    fn worker_range(&self, rect: &[Interval]) -> WorkerRange {
        let (a, b) = self.part.route_rect(rect);
        (self.stripe_worker[a], self.stripe_worker[b])
    }

    /// Send one op to worker `w`, recording it in the in-flight window
    /// so [`settle`](Self::settle) can resend it if the worker's
    /// admission control drops it (or the connection does).
    fn push_op(&mut self, w: usize, op: RegionOp) -> crate::Result<()> {
        self.inflight[w].push(op.clone());
        self.workers[w].op(op)
    }

    /// Route an upsert: the region goes (whole) to every worker whose
    /// stripes it overlaps; workers it *left* get a remove so stale
    /// replicas can't keep matching.
    fn upsert(&mut self, sub: bool, key: u32, rect: &[Interval]) -> crate::Result<()> {
        if rect.len() != self.d {
            crate::bail!("rect has {} dims, federation wants {}", rect.len(), self.d);
        }
        let (wa, wb) = self.worker_range(rect);
        let home = if sub {
            &mut self.sub_home
        } else {
            &mut self.upd_home
        };
        let old = home.insert(key, (wa, wb));
        if let Some((oa, ob)) = old {
            for w in oa..=ob {
                if w < wa || w > wb {
                    let op = if sub {
                        RegionOp::RemoveSub { key }
                    } else {
                        RegionOp::RemoveUpd { key }
                    };
                    self.push_op(w, op)?;
                }
            }
        }
        for w in wa..=wb {
            let op = if sub {
                RegionOp::UpsertSub {
                    key,
                    rect: rect.to_vec(),
                }
            } else {
                RegionOp::UpsertUpd {
                    key,
                    rect: rect.to_vec(),
                }
            };
            self.push_op(w, op)?;
        }
        Ok(())
    }

    /// Insert or move a subscription region.
    pub fn upsert_subscription(&mut self, key: u32, rect: &[Interval]) -> crate::Result<()> {
        self.upsert(true, key, rect)
    }

    /// Insert or move an update region.
    pub fn upsert_update(&mut self, key: u32, rect: &[Interval]) -> crate::Result<()> {
        self.upsert(false, key, rect)
    }

    /// Delete a subscription region everywhere it lives.
    pub fn remove_subscription(&mut self, key: u32) -> crate::Result<()> {
        if let Some((wa, wb)) = self.sub_home.remove(&key) {
            for w in wa..=wb {
                self.push_op(w, RegionOp::RemoveSub { key })?;
            }
        }
        Ok(())
    }

    /// Delete an update region everywhere it lives.
    pub fn remove_update(&mut self, key: u32) -> crate::Result<()> {
        if let Some((wa, wb)) = self.upd_home.remove(&key) {
            for w in wa..=wb {
                self.push_op(w, RegionOp::RemoveUpd { key })?;
            }
        }
        Ok(())
    }

    /// Prove every op sent so far actually landed in its worker's
    /// staged batch, retrying past admission control and transient
    /// transport failures:
    ///
    /// * a [`NetClient::barrier`] per worker counts the `Busy`
    ///   rejections since the last clean barrier;
    /// * rejections back off (capped exponential, jittered), then the
    ///   whole in-flight window is resent in backlog-sized chunks with
    ///   a `Flush` ahead of each chunk so the server drains room first
    ///   — safe because region ops are idempotent LWW;
    /// * a transport error reconnects to the worker's address,
    ///   resends the window, and re-barriers (epoch catch-up rides the
    ///   barrier's `SyncAck`).
    ///
    /// On success every in-flight window is empty. On giving up (the
    /// retry caps) the typed [`NetError`] is returned — `Busy` if the
    /// server still cannot absorb the window.
    pub fn settle(&mut self) -> crate::Result<()> {
        for w in 0..self.workers.len() {
            self.settle_worker(w)?;
        }
        Ok(())
    }

    fn settle_worker(&mut self, w: usize) -> crate::Result<()> {
        const MAX_BUSY_ROUNDS: u32 = 10;
        const MAX_RECONNECTS: u32 = 2;
        let mut rounds = 0u32;
        let mut reconnects = 0u32;
        loop {
            self.sync_token += 1;
            let token = self.sync_token;
            let info = match self.workers[w].barrier(token) {
                Ok(info) => info,
                Err(e) => {
                    if reconnects >= MAX_RECONNECTS {
                        return Err(e);
                    }
                    reconnects += 1;
                    self.reconnect(w)?;
                    self.resend(w, 0)?;
                    continue;
                }
            };
            if info.busy == 0 {
                self.inflight[w].clear();
                return Ok(());
            }
            rounds += 1;
            if rounds > MAX_BUSY_ROUNDS {
                return Err(NetError::Busy {
                    pending: info.pending,
                    limit: info.limit,
                }
                .into());
            }
            self.backoff_sleep(rounds);
            self.resend(w, info.limit)?;
        }
    }

    /// Capped exponential backoff with jitter: `2^round` ms capped at
    /// 64 ms, plus up to the same again of jitter so a fleet of
    /// clients rejected together does not retry together.
    fn backoff_sleep(&mut self, round: u32) {
        let base_ms = 1u64 << round.min(6);
        let jitter_ms = self.rng.below(base_ms + 1);
        std::thread::sleep(Duration::from_millis(base_ms + jitter_ms));
    }

    /// Resend worker `w`'s whole in-flight window in chunks of at most
    /// `limit` ops (0: a default chunk), with a `Flush` ahead of each
    /// chunk so the server drains its backlog into the session first.
    fn resend(&mut self, w: usize, limit: u64) -> crate::Result<()> {
        let chunk = usize::try_from(limit)
            .ok()
            .filter(|&n| n > 0)
            .unwrap_or(64);
        let n = self.inflight[w].len();
        for start in (0..n).step_by(chunk) {
            self.workers[w].send(&Msg::Flush)?;
            for i in start..(start + chunk).min(n) {
                let op = self.inflight[w][i].clone();
                self.workers[w].send(&Msg::Op(op))?;
            }
        }
        self.workers[w].send(&Msg::Flush)?;
        Ok(())
    }

    /// Replace worker `w`'s connection with a fresh one to the same
    /// address (new handshake, same deadline).
    fn reconnect(&mut self, w: usize) -> crate::Result<()> {
        let c = NetClient::connect_with(&self.addrs[w], self.timeout)?;
        if c.d() != self.d {
            crate::bail!(
                "worker {} came back serving d={} but the federation is d={}",
                self.addrs[w],
                c.d(),
                self.d
            );
        }
        self.workers[w] = c;
        Ok(())
    }

    /// Rebuild the client's merge state from the workers themselves
    /// (the recovery path after reconnects left the refcounts in
    /// doubt): re-count `pair → worker` refs from every worker's
    /// retained pair set and re-learn the epoch via a barrier. Returns
    /// the federation epoch.
    pub fn resync(&mut self) -> crate::Result<u64> {
        let mut refs: HashMap<u64, u32> = HashMap::new();
        for w in &mut self.workers {
            w.send(&Msg::GetPairs)?;
        }
        for w in &mut self.workers {
            loop {
                if let Msg::Pairs(p) = w.recv()? {
                    for &(s, u) in &p {
                        *refs.entry(pack_pair(s, u)).or_insert(0) += 1;
                    }
                    break;
                }
            }
        }
        self.pair_refs = refs;
        let mut epoch = 0u64;
        for w in 0..self.workers.len() {
            self.sync_token += 1;
            let info = self.workers[w].barrier(self.sync_token)?;
            epoch = epoch.max(info.epoch);
        }
        self.epoch = epoch;
        Ok(epoch)
    }

    /// Commit every worker (pipelined: all `Commit`s go out before any
    /// diff is read) and merge their diffs into the single global diff
    /// for this epoch. Pairs straddling a worker boundary report
    /// exactly once: the refcount fold only surfaces `0 ↔ >0`
    /// transitions, mirroring `ShardedSession::commit`. A
    /// [`settle`](Self::settle) runs first, so admission-control
    /// rejections and dropped connections are cured — not silently
    /// missing ops — before the epoch closes.
    pub fn commit(&mut self) -> crate::Result<MatchDiff> {
        self.settle()?;
        for w in &mut self.workers {
            w.send(&Msg::Commit)?;
        }
        let mut delta: HashMap<u64, i32> = HashMap::new();
        let mut epoch = 0u64;
        for w in &mut self.workers {
            let diff = w.await_diff()?;
            epoch = epoch.max(diff.epoch);
            for &(s, u) in &diff.added {
                *delta.entry(pack_pair(s, u)).or_insert(0) += 1;
            }
            for &(s, u) in &diff.removed {
                *delta.entry(pack_pair(s, u)).or_insert(0) -= 1;
            }
        }
        let mut added: PairVec = Vec::new();
        let mut removed: PairVec = Vec::new();
        for (pair, dv) in delta {
            if dv == 0 {
                continue;
            }
            let old = i64::from(self.pair_refs.get(&pair).copied().unwrap_or(0));
            let new = old + i64::from(dv);
            debug_assert!(new >= 0, "worker removed a pair it never added");
            if old == 0 && new > 0 {
                added.push(unpack_pair(pair));
            } else if old > 0 && new <= 0 {
                removed.push(unpack_pair(pair));
            }
            if new <= 0 {
                self.pair_refs.remove(&pair);
            } else {
                self.pair_refs.insert(pair, new as u32);
            }
        }
        added.sort_unstable();
        removed.sort_unstable();
        self.epoch = epoch;
        Ok(MatchDiff {
            epoch,
            added,
            removed,
        })
    }

    /// The global retained pair set: union of worker pair sets
    /// (replicas deduplicate, matching a flat session's `pairs()`).
    pub fn pairs(&mut self) -> crate::Result<PairVec> {
        for w in &mut self.workers {
            w.send(&Msg::GetPairs)?;
        }
        let mut packed: Vec<u64> = Vec::new();
        for w in &mut self.workers {
            loop {
                if let Msg::Pairs(p) = w.recv()? {
                    packed.extend(p.iter().map(|&(s, u)| pack_pair(s, u)));
                    break;
                }
            }
        }
        packed.sort_unstable();
        packed.dedup();
        Ok(packed.into_iter().map(unpack_pair).collect())
    }

    /// Metrics snapshot from every worker, in topology order.
    pub fn worker_metrics(&mut self) -> crate::Result<Vec<MetricsSnapshot>> {
        let mut out = Vec::with_capacity(self.workers.len());
        for w in &mut self.workers {
            out.push(w.metrics()?);
        }
        Ok(out)
    }

    /// Shorten every worker socket's read timeout.
    pub fn set_timeout(&mut self, t: Duration) -> crate::Result<()> {
        for w in &mut self.workers {
            w.set_timeout(t)?;
        }
        Ok(())
    }

    /// Ask every worker to shut down, waiting for each `Goodbye`.
    pub fn shutdown_workers(&mut self) -> crate::Result<()> {
        for w in &mut self.workers {
            w.shutdown_server()?;
        }
        for w in &mut self.workers {
            w.await_goodbye()?;
        }
        Ok(())
    }
}

//! Network-facing DDM service: wire protocol, TCP server, federation.
//!
//! Everything below `net/` is pure `std` — no async runtime, no serde,
//! no socket crates — in keeping with the crate's offline stance. The
//! layers, bottom-up:
//!
//! * [`wire`] — framing and primitive codecs: length-prefixed frames
//!   with a version byte, LEB128 varints, bit-exact `f64`, zero-copy
//!   reads from `&[u8]`, typed [`wire::WireError`]s for every way a
//!   frame can be wrong.
//! * [`proto`] — the message catalog ([`proto::Msg`], 19 frames):
//!   region ops, commits, `MatchDiff` deltas, topology and metrics
//!   snapshots, error replies. See its module docs for the full table.
//! * [`server`] — the nonblocking IO core: a listener thread, a few
//!   socket-owning IO threads, and one state thread that owns the
//!   [`server::Service`] — no locks anywhere, channels are the only
//!   synchronization.
//! * [`worker`] / [`router`] — the two services: a worker fronts an
//!   [`AnySession`](crate::shard::AnySession) (stages ops, commits
//!   epochs, streams diffs); a router serves the federation topology
//!   and stays out of the hot path.
//! * [`client`] — blocking [`client::NetClient`] for one socket and
//!   [`client::FederationClient`] which routes ops across workers and
//!   merges their diffs with the same refcount discipline
//!   `ShardedSession` uses across shards, so straddling pairs report
//!   exactly once even across process boundaries.
//!
//! The CLI fronts all of it: `ddm serve` (worker), `ddm route`
//! (router), `ddm client` (scripted workload driver), `ddm bench-net`
//! (loopback ablation).

pub mod client;
pub mod proto;
pub mod router;
pub mod server;
pub mod wire;
pub mod worker;

pub use client::{BarrierInfo, FederationClient, NetClient, NetError};
pub use proto::{MetricsSnapshot, Msg, RegionOp, Role, TopologySnapshot, WorkerEntry, PROTO_ID};
pub use router::{assign_stripes, RouterService};
pub use server::{serve, Outbox, ServerConfig, ServerHandle, Service, StageHists};
pub use wire::WireError;
pub use worker::WorkerService;

//! The frame catalog: every message that crosses a DDM socket.
//!
//! Layered on [`super::wire`]: this module owns *what* the frames mean
//! (tags, payload shapes, containers), `wire` owns *how* bytes are
//! framed and decoded. Encoding appends a complete frame into a
//! caller-owned `Vec<u8>`; decoding borrows from a `&[u8]` and only
//! allocates the containers the decoded message itself owns.
//!
//! | tag | message      | direction        | payload |
//! |-----|--------------|------------------|---------|
//! | 1   | `Hello`      | client → server  | protocol id |
//! | 2   | `Welcome`    | server → client  | role, d, epoch |
//! | 3   | `GetTopology`| client → router  | — |
//! | 4   | `Topology`   | router → client  | split dim, cuts, worker table |
//! | 5   | `Op`         | client → worker  | one region op |
//! | 6   | `Batch`      | client → worker  | op count + ops |
//! | 7   | `Flush`      | client → worker  | — |
//! | 8   | `Commit`     | client → worker  | — |
//! | 9   | `Diff`       | worker → client  | epoch + added/removed pairs |
//! | 10  | `Subscribe`  | client → worker  | — |
//! | 11  | `Sync`       | client → server  | token |
//! | 12  | `SyncAck`    | server → client  | token, epoch, staged ops |
//! | 13  | `GetPairs`   | client → worker  | — |
//! | 14  | `Pairs`      | worker → client  | retained pair set |
//! | 15  | `GetMetrics` | client → server  | — |
//! | 16  | `Metrics`    | server → client  | counters + gauges + histograms + slow spans |
//! | 17  | `ErrorReply` | server → client  | code + message |
//! | 18  | `Shutdown`   | client → server  | — |
//! | 19  | `Goodbye`    | server → client  | final epoch |
//! | 20  | `Busy`       | server → client  | backlog depth + limit |
//!
//! Pair lists ride a delta encoding over the packed `u64` key of
//! [`pack_pair`] — `MatchDiff` lists arrive sorted and duplicate-free,
//! so successive deltas are small positive varints. The decoder
//! *enforces* strict ascent, which doubles as a corruption check.

use crate::core::interval::Interval;
use crate::core::sink::{pack_pair, unpack_pair, PairVec};
use crate::coordinator::metrics::Metrics;
use crate::obs::{hist, Histogram, SpanRecord};
use crate::session::MatchDiff;

use super::wire::{self, Reader, WireError};

/// Protocol identifier a [`Msg::Hello`] announces; servers reject
/// anything else.
pub const PROTO_ID: u32 = 0xDD01;

/// Dimension cap for rectangles on the wire (matches practical DDM
/// routing spaces; bounds decode-side allocation).
pub const MAX_DIMS: usize = 64;

/// Error codes carried by [`Msg::ErrorReply`].
pub mod err_code {
    /// Message not valid for this endpoint (e.g. `GetTopology` at a
    /// worker).
    pub const UNSUPPORTED: u32 = 1;
    /// Frame failed to decode.
    pub const BAD_FRAME: u32 = 2;
    /// Handshake rejected (wrong protocol id).
    pub const BAD_HELLO: u32 = 3;
    /// Region op rejected (dimension mismatch).
    pub const BAD_OP: u32 = 4;
}

const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_GET_TOPOLOGY: u8 = 3;
const TAG_TOPOLOGY: u8 = 4;
const TAG_OP: u8 = 5;
const TAG_BATCH: u8 = 6;
const TAG_FLUSH: u8 = 7;
const TAG_COMMIT: u8 = 8;
const TAG_DIFF: u8 = 9;
const TAG_SUBSCRIBE: u8 = 10;
const TAG_SYNC: u8 = 11;
const TAG_SYNC_ACK: u8 = 12;
const TAG_GET_PAIRS: u8 = 13;
const TAG_PAIRS: u8 = 14;
const TAG_GET_METRICS: u8 = 15;
const TAG_METRICS: u8 = 16;
const TAG_ERROR: u8 = 17;
const TAG_SHUTDOWN: u8 = 18;
const TAG_GOODBYE: u8 = 19;
const TAG_BUSY: u8 = 20;

/// What kind of endpoint answered the handshake.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Owns sessions and matches regions.
    Worker,
    /// Topology authority only; never in the op hot path.
    Router,
}

impl Role {
    fn to_u8(self) -> u8 {
        match self {
            Role::Worker => 0,
            Role::Router => 1,
        }
    }

    fn from_u8(v: u8) -> Result<Self, WireError> {
        match v {
            0 => Ok(Role::Worker),
            1 => Ok(Role::Router),
            _ => Err(WireError::Malformed("unknown role")),
        }
    }
}

/// One staged region mutation — the wire twin of the
/// [`DdmSession`](crate::session::DdmSession) staging surface.
#[derive(Debug, Clone, PartialEq)]
pub enum RegionOp {
    /// Insert or move a subscription region.
    UpsertSub { key: u32, rect: Vec<Interval> },
    /// Insert or move an update region.
    UpsertUpd { key: u32, rect: Vec<Interval> },
    /// Delete a subscription region.
    RemoveSub { key: u32 },
    /// Delete an update region.
    RemoveUpd { key: u32 },
}

/// One worker's stripe assignment in a [`TopologySnapshot`]:
/// `addr` serves global stripes `first..=last`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerEntry {
    pub addr: String,
    pub first: u32,
    pub last: u32,
}

/// The federation shard map a router hands to clients: the split
/// dimension, the interior cut points (bit-exact, so client-side
/// routing reproduces server-side routing), and which worker owns
/// which contiguous stripe range.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologySnapshot {
    pub d: u32,
    pub split_dim: u32,
    pub cuts: Vec<f64>,
    pub workers: Vec<WorkerEntry>,
}

impl TopologySnapshot {
    /// Total stripe count (`cuts.len() + 1`).
    pub fn shards(&self) -> usize {
        self.cuts.len() + 1
    }
}

/// A point-in-time export of a server's [`Metrics`]: counters, gauges,
/// and log-bucketed histograms, sorted by name, plus the top-N slowest
/// phase spans the server has traced (empty when tracing is off).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    /// Quantile-readable distributions (commit latency, net stage
    /// times) — whole histograms travel, so the client computes
    /// p50/p99 itself instead of trusting pre-baked numbers.
    pub hists: Vec<(String, Histogram)>,
    /// The server's slowest spans, longest first
    /// ([`crate::obs::top_slowest`]).
    pub spans: Vec<SpanRecord>,
}

impl MetricsSnapshot {
    /// Snapshot the counters, gauges, and histograms of `m` (already
    /// name-sorted — `Metrics` stores them in `BTreeMap`s). `spans`
    /// starts empty; servers with a live tracer fill it via
    /// [`with_spans`](Self::with_spans).
    pub fn of(m: &Metrics) -> Self {
        Self {
            counters: m.counters.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            gauges: m.gauges.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            hists: m.hists.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            spans: Vec::new(),
        }
    }

    /// Attach the top-`n` slowest of `spans` to the snapshot.
    pub fn with_spans(mut self, spans: &[SpanRecord], n: usize) -> Self {
        self.spans = crate::obs::top_slowest(spans, n);
        self
    }

    /// Counter value by name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(k, _)| k == name)
            .map_or(0, |(_, v)| *v)
    }

    /// Gauge value by name.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    /// Histogram by name.
    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.iter().find(|(k, _)| k == name).map(|(_, v)| v)
    }

    /// Render counters and gauges as an aligned two-column table (for
    /// `ddm client --metrics`).
    pub fn table(&self) -> crate::bench::table::Table {
        let mut t = crate::bench::table::Table::new(vec!["metric", "value"]);
        for (k, v) in &self.counters {
            t.row(vec![k.clone(), v.to_string()]);
        }
        for (k, v) in &self.gauges {
            t.row(vec![k.clone(), format!("{v:.3}")]);
        }
        t
    }

    /// Render the histograms as a quantile table (empty table when the
    /// server exported none).
    pub fn hist_table(&self) -> crate::bench::table::Table {
        let ns = |v: u64| crate::bench::stats::fmt_secs(v as f64 / 1e9);
        let mut t = crate::bench::table::Table::new(vec![
            "histogram", "count", "mean", "p50", "p90", "p99", "max",
        ]);
        for (k, h) in &self.hists {
            t.row(vec![
                k.clone(),
                h.count().to_string(),
                ns(h.mean_ns()),
                ns(h.p50()),
                ns(h.p90()),
                ns(h.p99()),
                ns(h.max_ns()),
            ]);
        }
        t
    }

    /// Render the slow-span list (phase names resolved locally via
    /// [`Phase::name_of`](crate::obs::Phase::name_of)).
    pub fn span_table(&self) -> crate::bench::table::Table {
        let mut t = crate::bench::table::Table::new(vec![
            "phase", "lane", "dur", "items",
        ]);
        for s in &self.spans {
            let lane = if s.worker == crate::obs::trace::MASTER_WORKER {
                "master".to_string()
            } else {
                s.worker.to_string()
            };
            t.row(vec![
                crate::obs::Phase::name_of(s.phase).to_string(),
                lane,
                crate::bench::stats::fmt_secs(s.dur_ns() as f64 / 1e9),
                s.items.to_string(),
            ]);
        }
        t
    }
}

/// Every frame in the protocol. See the module docs for the catalog.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    Hello { proto: u32 },
    Welcome { role: Role, d: u32, epoch: u64 },
    GetTopology,
    Topology(TopologySnapshot),
    Op(RegionOp),
    Batch(Vec<RegionOp>),
    Flush,
    Commit,
    Diff(MatchDiff),
    Subscribe,
    Sync { token: u64 },
    SyncAck { token: u64, epoch: u64, pending: u64 },
    GetPairs,
    Pairs(PairVec),
    GetMetrics,
    Metrics(MetricsSnapshot),
    ErrorReply { code: u32, msg: String },
    Shutdown,
    Goodbye { epoch: u64 },
    /// Admission-control rejection: the worker's staged-op backlog is
    /// full. Carries the observed depth and the configured limit (the
    /// wire twin of [`session::Busy`](crate::session::Busy)); clients
    /// back off and retry instead of treating it as a session error.
    Busy { pending: u64, limit: u64 },
}

/// Encode one rectangle (varint d + 2·d bit-exact f64) — shared with
/// the durability snapshot format ([`crate::durable::snapfile`]).
pub(crate) fn put_rect(out: &mut Vec<u8>, rect: &[Interval]) {
    wire::put_varint(out, rect.len() as u64);
    for iv in rect {
        wire::put_f64(out, iv.lo);
        wire::put_f64(out, iv.hi);
    }
}

/// Decode one rectangle (inverse of [`put_rect`]; rejects `d == 0` or
/// `d > MAX_DIMS`).
pub(crate) fn read_rect(r: &mut Reader<'_>) -> Result<Vec<Interval>, WireError> {
    let d = r.count(16)?;
    if d == 0 || d > MAX_DIMS {
        return Err(WireError::Malformed("rect dimension out of range"));
    }
    let mut rect = Vec::with_capacity(d);
    for _ in 0..d {
        let lo = r.f64()?;
        let hi = r.f64()?;
        rect.push(Interval { lo, hi });
    }
    Ok(rect)
}

/// Encode one region op — shared with the WAL record format
/// ([`crate::durable::wal`]), which wraps these bytes in its own
/// CRC-checked frame.
pub(crate) fn put_op(out: &mut Vec<u8>, op: &RegionOp) {
    match op {
        RegionOp::UpsertSub { key, rect } => {
            wire::put_u8(out, 0);
            wire::put_varint(out, u64::from(*key));
            put_rect(out, rect);
        }
        RegionOp::UpsertUpd { key, rect } => {
            wire::put_u8(out, 1);
            wire::put_varint(out, u64::from(*key));
            put_rect(out, rect);
        }
        RegionOp::RemoveSub { key } => {
            wire::put_u8(out, 2);
            wire::put_varint(out, u64::from(*key));
        }
        RegionOp::RemoveUpd { key } => {
            wire::put_u8(out, 3);
            wire::put_varint(out, u64::from(*key));
        }
    }
}

fn read_key(r: &mut Reader<'_>) -> Result<u32, WireError> {
    u32::try_from(r.varint()?).map_err(|_| WireError::Malformed("region key exceeds u32"))
}

/// Decode one region op (inverse of [`put_op`]).
pub(crate) fn read_op(r: &mut Reader<'_>) -> Result<RegionOp, WireError> {
    let kind = r.u8()?;
    let key = read_key(r)?;
    Ok(match kind {
        0 => RegionOp::UpsertSub { key, rect: read_rect(r)? },
        1 => RegionOp::UpsertUpd { key, rect: read_rect(r)? },
        2 => RegionOp::RemoveSub { key },
        3 => RegionOp::RemoveUpd { key },
        _ => return Err(WireError::Malformed("unknown region-op kind")),
    })
}

/// Delta-encode a sorted duplicate-free pair list over packed keys.
fn put_pairs(out: &mut Vec<u8>, pairs: &[(u32, u32)]) {
    wire::put_varint(out, pairs.len() as u64);
    let mut prev = 0u64;
    for (i, &(s, u)) in pairs.iter().enumerate() {
        let packed = pack_pair(s, u);
        if i == 0 {
            wire::put_varint(out, packed);
        } else {
            // Strict sort order is a MatchDiff invariant; encode the
            // gap (≥ 1) so the decoder can verify it.
            debug_assert!(packed > prev, "pair list must be strictly sorted");
            wire::put_varint(out, packed - prev);
        }
        prev = packed;
    }
}

fn read_pairs(r: &mut Reader<'_>) -> Result<PairVec, WireError> {
    let n = r.count(1)?;
    let mut out: PairVec = Vec::with_capacity(n);
    let mut prev = 0u64;
    for i in 0..n {
        let v = r.varint()?;
        let packed = if i == 0 {
            v
        } else {
            if v == 0 {
                return Err(WireError::Malformed("pair list not strictly sorted"));
            }
            prev.checked_add(v)
                .ok_or(WireError::Malformed("pair delta overflows"))?
        };
        prev = packed;
        out.push(unpack_pair(packed));
    }
    Ok(out)
}

fn put_diff(out: &mut Vec<u8>, diff: &MatchDiff) {
    wire::put_varint(out, diff.epoch);
    put_pairs(out, &diff.added);
    put_pairs(out, &diff.removed);
}

fn read_diff(r: &mut Reader<'_>) -> Result<MatchDiff, WireError> {
    Ok(MatchDiff {
        epoch: r.varint()?,
        added: read_pairs(r)?,
        removed: read_pairs(r)?,
    })
}

impl Msg {
    /// Append this message as one complete frame.
    pub fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Msg::Hello { proto } => wire::frame(out, TAG_HELLO, |o| {
                wire::put_varint(o, u64::from(*proto));
            }),
            Msg::Welcome { role, d, epoch } => wire::frame(out, TAG_WELCOME, |o| {
                wire::put_u8(o, role.to_u8());
                wire::put_varint(o, u64::from(*d));
                wire::put_varint(o, *epoch);
            }),
            Msg::GetTopology => wire::frame(out, TAG_GET_TOPOLOGY, |_| {}),
            Msg::Topology(t) => wire::frame(out, TAG_TOPOLOGY, |o| {
                wire::put_varint(o, u64::from(t.d));
                wire::put_varint(o, u64::from(t.split_dim));
                wire::put_varint(o, t.cuts.len() as u64);
                for &c in &t.cuts {
                    wire::put_f64(o, c);
                }
                wire::put_varint(o, t.workers.len() as u64);
                for w in &t.workers {
                    wire::put_bytes(o, w.addr.as_bytes());
                    wire::put_varint(o, u64::from(w.first));
                    wire::put_varint(o, u64::from(w.last));
                }
            }),
            Msg::Op(op) => wire::frame(out, TAG_OP, |o| put_op(o, op)),
            Msg::Batch(ops) => wire::frame(out, TAG_BATCH, |o| {
                wire::put_varint(o, ops.len() as u64);
                for op in ops {
                    put_op(o, op);
                }
            }),
            Msg::Flush => wire::frame(out, TAG_FLUSH, |_| {}),
            Msg::Commit => wire::frame(out, TAG_COMMIT, |_| {}),
            Msg::Diff(diff) => wire::frame(out, TAG_DIFF, |o| put_diff(o, diff)),
            Msg::Subscribe => wire::frame(out, TAG_SUBSCRIBE, |_| {}),
            Msg::Sync { token } => wire::frame(out, TAG_SYNC, |o| {
                wire::put_varint(o, *token);
            }),
            Msg::SyncAck { token, epoch, pending } => wire::frame(out, TAG_SYNC_ACK, |o| {
                wire::put_varint(o, *token);
                wire::put_varint(o, *epoch);
                wire::put_varint(o, *pending);
            }),
            Msg::GetPairs => wire::frame(out, TAG_GET_PAIRS, |_| {}),
            Msg::Pairs(pairs) => wire::frame(out, TAG_PAIRS, |o| put_pairs(o, pairs)),
            Msg::GetMetrics => wire::frame(out, TAG_GET_METRICS, |_| {}),
            Msg::Metrics(m) => wire::frame(out, TAG_METRICS, |o| {
                wire::put_varint(o, m.counters.len() as u64);
                for (k, v) in &m.counters {
                    wire::put_bytes(o, k.as_bytes());
                    wire::put_varint(o, *v);
                }
                wire::put_varint(o, m.gauges.len() as u64);
                for (k, v) in &m.gauges {
                    wire::put_bytes(o, k.as_bytes());
                    wire::put_f64(o, *v);
                }
                wire::put_varint(o, m.hists.len() as u64);
                for (k, h) in &m.hists {
                    wire::put_bytes(o, k.as_bytes());
                    wire::put_varint(o, h.count());
                    wire::put_varint(o, h.total_ns());
                    wire::put_varint(o, h.max_ns());
                    // Trailing-zero buckets carry no information — trim
                    // them so an idle histogram costs a few bytes, not
                    // 64 varints.
                    let buckets = h.bucket_counts();
                    let nb = buckets.iter().rposition(|&b| b != 0).map_or(0, |i| i + 1);
                    wire::put_varint(o, nb as u64);
                    for &b in &buckets[..nb] {
                        wire::put_varint(o, b);
                    }
                }
                wire::put_varint(o, m.spans.len() as u64);
                for s in &m.spans {
                    wire::put_varint(o, u64::from(s.phase));
                    wire::put_varint(o, u64::from(s.worker));
                    wire::put_varint(o, s.t0_ns);
                    wire::put_varint(o, s.t1_ns);
                    wire::put_varint(o, s.items);
                }
            }),
            Msg::ErrorReply { code, msg } => wire::frame(out, TAG_ERROR, |o| {
                wire::put_varint(o, u64::from(*code));
                wire::put_bytes(o, msg.as_bytes());
            }),
            Msg::Shutdown => wire::frame(out, TAG_SHUTDOWN, |_| {}),
            Msg::Goodbye { epoch } => wire::frame(out, TAG_GOODBYE, |o| {
                wire::put_varint(o, *epoch);
            }),
            Msg::Busy { pending, limit } => wire::frame(out, TAG_BUSY, |o| {
                wire::put_varint(o, *pending);
                wire::put_varint(o, *limit);
            }),
        }
    }

    /// This message as a fresh frame buffer (convenience for one-off
    /// sends; batch paths reuse a buffer via [`Msg::encode`]).
    pub fn to_frame(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode(&mut out);
        out
    }

    /// Decode the frame at the head of `buf`.
    ///
    /// `Ok(None)` means the buffer holds an incomplete frame (read
    /// more); `Ok(Some((msg, consumed)))` yields the message and how
    /// many bytes to drain. All corruption — framing or payload — is a
    /// typed [`WireError`], never a panic.
    pub fn decode(buf: &[u8]) -> Result<Option<(Msg, usize)>, WireError> {
        let Some((ver, tag, payload, consumed)) = wire::split_frame(buf)? else {
            return Ok(None);
        };
        if ver != wire::VERSION {
            return Err(WireError::BadVersion(ver));
        }
        let mut r = Reader::new(payload);
        let msg = match tag {
            TAG_HELLO => Msg::Hello {
                proto: u32::try_from(r.varint()?)
                    .map_err(|_| WireError::Malformed("protocol id exceeds u32"))?,
            },
            TAG_WELCOME => Msg::Welcome {
                role: Role::from_u8(r.u8()?)?,
                d: u32::try_from(r.varint()?)
                    .map_err(|_| WireError::Malformed("dimension exceeds u32"))?,
                epoch: r.varint()?,
            },
            TAG_GET_TOPOLOGY => Msg::GetTopology,
            TAG_TOPOLOGY => {
                let d = u32::try_from(r.varint()?)
                    .map_err(|_| WireError::Malformed("dimension exceeds u32"))?;
                let split_dim = u32::try_from(r.varint()?)
                    .map_err(|_| WireError::Malformed("split dim exceeds u32"))?;
                let ncuts = r.count(8)?;
                let mut cuts = Vec::with_capacity(ncuts);
                for _ in 0..ncuts {
                    cuts.push(r.f64()?);
                }
                let nworkers = r.count(3)?;
                let mut workers = Vec::with_capacity(nworkers);
                for _ in 0..nworkers {
                    let addr = r.str()?.to_string();
                    let first = u32::try_from(r.varint()?)
                        .map_err(|_| WireError::Malformed("stripe index exceeds u32"))?;
                    let last = u32::try_from(r.varint()?)
                        .map_err(|_| WireError::Malformed("stripe index exceeds u32"))?;
                    workers.push(WorkerEntry { addr, first, last });
                }
                Msg::Topology(TopologySnapshot { d, split_dim, cuts, workers })
            }
            TAG_OP => Msg::Op(read_op(&mut r)?),
            TAG_BATCH => {
                let n = r.count(2)?;
                let mut ops = Vec::with_capacity(n);
                for _ in 0..n {
                    ops.push(read_op(&mut r)?);
                }
                Msg::Batch(ops)
            }
            TAG_FLUSH => Msg::Flush,
            TAG_COMMIT => Msg::Commit,
            TAG_DIFF => Msg::Diff(read_diff(&mut r)?),
            TAG_SUBSCRIBE => Msg::Subscribe,
            TAG_SYNC => Msg::Sync { token: r.varint()? },
            TAG_SYNC_ACK => Msg::SyncAck {
                token: r.varint()?,
                epoch: r.varint()?,
                pending: r.varint()?,
            },
            TAG_GET_PAIRS => Msg::GetPairs,
            TAG_PAIRS => Msg::Pairs(read_pairs(&mut r)?),
            TAG_GET_METRICS => Msg::GetMetrics,
            TAG_METRICS => {
                let nc = r.count(2)?;
                let mut counters = Vec::with_capacity(nc);
                for _ in 0..nc {
                    let k = r.str()?.to_string();
                    let v = r.varint()?;
                    counters.push((k, v));
                }
                let ng = r.count(9)?;
                let mut gauges = Vec::with_capacity(ng);
                for _ in 0..ng {
                    let k = r.str()?.to_string();
                    let v = r.f64()?;
                    gauges.push((k, v));
                }
                let nh = r.count(5)?;
                let mut hists = Vec::with_capacity(nh);
                for _ in 0..nh {
                    let k = r.str()?.to_string();
                    let count = r.varint()?;
                    let total_ns = r.varint()?;
                    let max_ns = r.varint()?;
                    let nb = r.count(1)?;
                    if nb > hist::BUCKETS {
                        return Err(WireError::Malformed("histogram bucket count exceeds 64"));
                    }
                    let mut buckets = [0u64; hist::BUCKETS];
                    for b in buckets.iter_mut().take(nb) {
                        *b = r.varint()?;
                    }
                    hists.push((k, Histogram::from_parts(count, total_ns, max_ns, &buckets)));
                }
                let nsp = r.count(5)?;
                let mut spans = Vec::with_capacity(nsp);
                for _ in 0..nsp {
                    let phase = u16::try_from(r.varint()?)
                        .map_err(|_| WireError::Malformed("span phase exceeds u16"))?;
                    let worker = u16::try_from(r.varint()?)
                        .map_err(|_| WireError::Malformed("span worker exceeds u16"))?;
                    spans.push(SpanRecord {
                        phase,
                        worker,
                        t0_ns: r.varint()?,
                        t1_ns: r.varint()?,
                        items: r.varint()?,
                    });
                }
                Msg::Metrics(MetricsSnapshot { counters, gauges, hists, spans })
            }
            TAG_ERROR => Msg::ErrorReply {
                code: u32::try_from(r.varint()?)
                    .map_err(|_| WireError::Malformed("error code exceeds u32"))?,
                msg: r.str()?.to_string(),
            },
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_GOODBYE => Msg::Goodbye { epoch: r.varint()? },
            TAG_BUSY => Msg::Busy {
                pending: r.varint()?,
                limit: r.varint()?,
            },
            other => return Err(WireError::BadTag(other)),
        };
        r.finish()?;
        Ok(Some((msg, consumed)))
    }

    /// Decode exactly one complete frame spanning all of `buf`:
    /// incomplete input is [`WireError::Truncated`], bytes past the
    /// frame are [`WireError::Trailing`]. The strict entry point the
    /// property suite drives.
    pub fn decode_exact(buf: &[u8]) -> Result<Msg, WireError> {
        match Msg::decode(buf)? {
            None => Err(WireError::Truncated),
            Some((_, consumed)) if consumed < buf.len() => {
                Err(WireError::Trailing(buf.len() - consumed))
            }
            Some((msg, _)) => Ok(msg),
        }
    }
}

/// Deterministic random message generator for the round-trip property
/// suite (kept out of `#[cfg(test)]` so integration tests and the
/// loopback bench can drive the same distribution).
pub fn arbitrary_msg(rng: &mut crate::prng::Rng, d: usize) -> Msg {
    fn rect(rng: &mut crate::prng::Rng, d: usize) -> Vec<Interval> {
        (0..d.max(1))
            .map(|_| {
                let lo = rng.uniform(-1e6, 1e6);
                Interval::new(lo, lo + rng.uniform(0.0, 1e4))
            })
            .collect()
    }
    fn op(rng: &mut crate::prng::Rng, d: usize) -> RegionOp {
        let key = rng.below(1 << 20) as u32;
        match rng.below(4) {
            0 => RegionOp::UpsertSub { key, rect: rect(rng, d) },
            1 => RegionOp::UpsertUpd { key, rect: rect(rng, d) },
            2 => RegionOp::RemoveSub { key },
            _ => RegionOp::RemoveUpd { key },
        }
    }
    fn pairs(rng: &mut crate::prng::Rng) -> PairVec {
        let n = rng.below(50) as usize;
        let mut packed: Vec<u64> = (0..n).map(|_| rng.next_u64() >> 8).collect();
        packed.sort_unstable();
        packed.dedup();
        packed.into_iter().map(unpack_pair).collect()
    }
    match rng.below(20) {
        0 => Msg::Hello { proto: PROTO_ID },
        1 => Msg::Welcome {
            role: if rng.chance(0.5) { Role::Worker } else { Role::Router },
            d: d as u32,
            epoch: rng.below(1 << 30),
        },
        2 => Msg::GetTopology,
        3 => {
            let shards = 1 + rng.below(8) as usize;
            let mut cuts: Vec<f64> = (1..shards).map(|_| rng.uniform(0.0, 1e6)).collect();
            cuts.sort_unstable_by(f64::total_cmp);
            let nworkers = 1 + rng.below(4);
            Msg::Topology(TopologySnapshot {
                d: d as u32,
                split_dim: rng.below(d.max(1) as u64) as u32,
                cuts,
                workers: (0..nworkers)
                    .map(|i| WorkerEntry {
                        addr: format!("127.0.0.1:{}", 4000 + i),
                        first: i as u32,
                        last: i as u32,
                    })
                    .collect(),
            })
        }
        4 => Msg::Op(op(rng, d)),
        5 => Msg::Batch((0..rng.below(20)).map(|_| op(rng, d)).collect()),
        6 => Msg::Flush,
        7 => Msg::Commit,
        8 => Msg::Diff(MatchDiff {
            epoch: rng.below(1 << 20),
            added: pairs(rng),
            removed: pairs(rng),
        }),
        9 => Msg::Subscribe,
        10 => Msg::Sync { token: rng.next_u64() },
        11 => Msg::SyncAck {
            token: rng.next_u64(),
            epoch: rng.below(1 << 20),
            pending: rng.below(1 << 16),
        },
        12 => Msg::GetPairs,
        13 => Msg::Pairs(pairs(rng)),
        14 => Msg::GetMetrics,
        15 => {
            let mut h = Histogram::default();
            for _ in 0..rng.below(200) {
                h.record(rng.below(1u64 << (1 + rng.below(40) as u32)));
            }
            let nspans = rng.below(8) as usize;
            Msg::Metrics(MetricsSnapshot {
                counters: vec![
                    ("commits".into(), rng.below(1 << 20)),
                    ("net_ops".into(), rng.below(1 << 30)),
                ],
                gauges: vec![("shard_imbalance".into(), rng.uniform(0.0, 8.0))],
                hists: vec![("commit_ns".into(), h)],
                spans: (0..nspans)
                    .map(|_| {
                        let t0 = rng.below(1 << 40);
                        SpanRecord {
                            phase: rng.below(16) as u16,
                            worker: rng.below(9) as u16,
                            t0_ns: t0,
                            t1_ns: t0 + rng.below(1 << 30),
                            items: rng.below(1 << 20),
                        }
                    })
                    .collect(),
            })
        }
        16 => Msg::ErrorReply {
            code: err_code::UNSUPPORTED,
            msg: "not here".to_string(),
        },
        17 => Msg::Shutdown,
        18 => Msg::Goodbye { epoch: rng.below(1 << 20) },
        _ => Msg::Busy {
            pending: rng.below(1 << 16),
            limit: 1 + rng.below(1 << 16),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn round_trip(msg: &Msg) {
        let buf = msg.to_frame();
        let (got, used) = Msg::decode(&buf).expect("decodes").expect("complete");
        assert_eq!(used, buf.len());
        assert_eq!(&got, msg);
        assert_eq!(&Msg::decode_exact(&buf).expect("exact"), msg);
    }

    #[test]
    fn every_variant_round_trips() {
        // Hit every arm of the generator across dimensions 1, 3, 5.
        for d in [1usize, 3, 5] {
            let mut rng = Rng::new(0xBEEF ^ d as u64);
            let mut seen = [false; 20];
            for _ in 0..2000 {
                let msg = arbitrary_msg(&mut rng, d);
                seen[variant_index(&msg)] = true;
                round_trip(&msg);
            }
            assert!(seen.iter().all(|&s| s), "generator missed a variant: {seen:?}");
        }
    }

    fn variant_index(m: &Msg) -> usize {
        match m {
            Msg::Hello { .. } => 0,
            Msg::Welcome { .. } => 1,
            Msg::GetTopology => 2,
            Msg::Topology(_) => 3,
            Msg::Op(_) => 4,
            Msg::Batch(_) => 5,
            Msg::Flush => 6,
            Msg::Commit => 7,
            Msg::Diff(_) => 8,
            Msg::Subscribe => 9,
            Msg::Sync { .. } => 10,
            Msg::SyncAck { .. } => 11,
            Msg::GetPairs => 12,
            Msg::Pairs(_) => 13,
            Msg::GetMetrics => 14,
            Msg::Metrics(_) => 15,
            Msg::ErrorReply { .. } => 16,
            Msg::Shutdown => 17,
            Msg::Goodbye { .. } => 18,
            Msg::Busy { .. } => 19,
        }
    }

    #[test]
    fn empty_payload_messages_are_two_byte_bodies() {
        for msg in [Msg::GetTopology, Msg::Flush, Msg::Commit, Msg::Subscribe,
                    Msg::GetPairs, Msg::GetMetrics, Msg::Shutdown] {
            let buf = msg.to_frame();
            assert_eq!(buf.len(), wire::HEADER, "{msg:?}");
            round_trip(&msg);
        }
    }

    #[test]
    fn pair_lists_delta_compress_and_enforce_sort_order() {
        let pairs: PairVec = vec![(0, 1), (0, 2), (3, 7), (1000, 0)];
        round_trip(&Msg::Pairs(pairs.clone()));
        // Hand-build an unsorted list (delta 0 = duplicate).
        let mut buf = Vec::new();
        wire::frame(&mut buf, 14, |o| {
            wire::put_varint(o, 2);
            wire::put_varint(o, 5);
            wire::put_varint(o, 0); // duplicate of the first entry
        });
        assert_eq!(
            Msg::decode(&buf),
            Err(WireError::Malformed("pair list not strictly sorted"))
        );
    }

    #[test]
    fn diff_round_trips_including_empty() {
        round_trip(&Msg::Diff(MatchDiff::default()));
        round_trip(&Msg::Diff(MatchDiff {
            epoch: 9,
            added: vec![(1, 2), (1, 3)],
            removed: vec![(0, 0)],
        }));
    }

    #[test]
    fn truncation_at_every_prefix_is_incomplete_or_typed_error() {
        let mut rng = Rng::new(77);
        for d in [1usize, 3, 5] {
            for _ in 0..200 {
                let buf = arbitrary_msg(&mut rng, d).to_frame();
                for cut in 0..buf.len() {
                    // Streaming view: a strict prefix is always
                    // "incomplete" (the length prefix promises more).
                    assert_eq!(Msg::decode(&buf[..cut]).expect("no error"), None);
                    // Strict view: typed Truncated error.
                    assert_eq!(Msg::decode_exact(&buf[..cut]), Err(WireError::Truncated));
                }
            }
        }
    }

    #[test]
    fn bit_flips_never_panic() {
        let mut rng = Rng::new(0xF11D);
        for d in [1usize, 3, 5] {
            for _ in 0..150 {
                let buf = arbitrary_msg(&mut rng, d).to_frame();
                for _ in 0..40 {
                    let mut bad = buf.clone();
                    let byte = rng.below(bad.len() as u64) as usize;
                    bad[byte] ^= 1 << rng.below(8);
                    // Any outcome is fine except a panic: Ok(None)
                    // (length grew), Ok(Some) (benign flip), or a
                    // typed error.
                    let _ = Msg::decode(&bad);
                    let _ = Msg::decode_exact(&bad);
                }
            }
        }
    }

    #[test]
    fn oversized_length_and_bad_version_and_bad_tag_are_typed() {
        let mut buf = Vec::new();
        wire::put_u32(&mut buf, (wire::MAX_FRAME + 7) as u32);
        buf.extend_from_slice(&[0u8; 16]);
        assert_eq!(Msg::decode(&buf), Err(WireError::Oversized(wire::MAX_FRAME + 7)));

        let mut buf = Msg::Commit.to_frame();
        buf[4] = 99; // version byte
        assert_eq!(Msg::decode(&buf), Err(WireError::BadVersion(99)));

        let mut buf = Msg::Commit.to_frame();
        buf[5] = 200; // tag byte
        assert_eq!(Msg::decode(&buf), Err(WireError::BadTag(200)));
    }

    #[test]
    fn trailing_bytes_inside_a_frame_are_typed() {
        let mut buf = Vec::new();
        wire::frame(&mut buf, 8, |o| wire::put_u8(o, 42)); // Commit + junk byte
        assert_eq!(Msg::decode(&buf), Err(WireError::Trailing(1)));
    }

    #[test]
    fn rect_dimension_bounds_are_enforced() {
        // d = 0
        let mut buf = Vec::new();
        wire::frame(&mut buf, 5, |o| {
            wire::put_u8(o, 0);
            wire::put_varint(o, 1);
            wire::put_varint(o, 0);
        });
        assert!(matches!(Msg::decode(&buf), Err(WireError::Malformed(_))));
        // d beyond MAX_DIMS with enough bytes to pass the count guard.
        let mut buf = Vec::new();
        wire::frame(&mut buf, 5, |o| {
            wire::put_u8(o, 0);
            wire::put_varint(o, 1);
            wire::put_varint(o, (MAX_DIMS + 1) as u64);
            for _ in 0..(MAX_DIMS + 1) * 2 {
                wire::put_f64(o, 0.0);
            }
        });
        assert!(matches!(Msg::decode(&buf), Err(WireError::Malformed(_))));
    }

    #[test]
    fn metrics_snapshot_reads_back_by_name() {
        let mut m = Metrics::default();
        m.inc("net_ops", 12);
        m.gauge("shard_imbalance", 1.5);
        for ns in [900u64, 1_000, 40_000, 1_000_000] {
            m.observe_ns("commit_ns", ns);
        }
        let spans = vec![
            SpanRecord { phase: 14, worker: crate::obs::trace::MASTER_WORKER, t0_ns: 10, t1_ns: 500, items: 3 },
            SpanRecord { phase: 9, worker: 1, t0_ns: 20, t1_ns: 90, items: 2 },
            SpanRecord { phase: 9, worker: 0, t0_ns: 20, t1_ns: 400, items: 2 },
        ];
        let snap = MetricsSnapshot::of(&m).with_spans(&spans, 2);
        assert_eq!(snap.counter("net_ops"), 12);
        assert_eq!(snap.counter("absent"), 0);
        assert_eq!(snap.gauge("shard_imbalance"), Some(1.5));
        // The whole histogram travels: the client reads quantiles off
        // the decoded copy, identical to the server's.
        let h = snap.hist("commit_ns").expect("histogram exported");
        assert_eq!(h.count(), 4);
        assert_eq!(h.p50(), m.hist("commit_ns").unwrap().p50());
        assert!(snap.hist("absent").is_none());
        // Top-2 slowest spans, longest first.
        assert_eq!(snap.spans.len(), 2);
        assert!(snap.spans[0].dur_ns() >= snap.spans[1].dur_ns());
        assert!(snap.table().render().contains("net_ops"));
        assert!(snap.hist_table().render().contains("commit_ns"));
        assert!(snap.span_table().render().contains("commit"));
        round_trip(&Msg::Metrics(snap));
    }

    #[test]
    fn multiple_frames_stream_decode_in_order() {
        let mut buf = Vec::new();
        Msg::Commit.encode(&mut buf);
        Msg::Sync { token: 5 }.encode(&mut buf);
        Msg::Goodbye { epoch: 3 }.encode(&mut buf);
        let mut at = 0;
        let mut got = Vec::new();
        while let Some((msg, used)) = Msg::decode(&buf[at..]).expect("clean stream") {
            got.push(msg);
            at += used;
        }
        assert_eq!(at, buf.len());
        assert_eq!(
            got,
            vec![Msg::Commit, Msg::Sync { token: 5 }, Msg::Goodbye { epoch: 3 }]
        );
    }
}

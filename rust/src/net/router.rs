//! The router service: topology authority, never in the hot path.
//!
//! A router answers exactly one substantive question — *which worker
//! owns which stripes?* — via [`Msg::GetTopology`]. Clients connect,
//! fetch the [`TopologySnapshot`] (split dimension, bit-exact cut
//! points, worker address table), then talk to workers directly;
//! region ops and diffs never traverse the router, so federation
//! throughput scales with workers, not with the router.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::metrics::Metrics;

use super::proto::{err_code, MetricsSnapshot, Msg, Role, TopologySnapshot, WorkerEntry, PROTO_ID};
use super::server::{Outbox, Service, StageHists};

/// Split `shards` global stripes across `workers` addresses into
/// contiguous ranges, balanced to within one stripe (the same
/// remainder-first spread the thread pool uses for chunking). Panics
/// if there are more workers than stripes.
pub fn assign_stripes(shards: usize, workers: &[String]) -> Vec<WorkerEntry> {
    assert!(!workers.is_empty(), "need at least one worker");
    assert!(
        workers.len() <= shards,
        "more workers ({}) than stripes ({shards})",
        workers.len()
    );
    let base = shards / workers.len();
    let extra = shards % workers.len();
    let mut first = 0usize;
    workers
        .iter()
        .enumerate()
        .map(|(i, addr)| {
            let take = base + usize::from(i < extra);
            let entry = WorkerEntry {
                addr: addr.clone(),
                first: first as u32,
                last: (first + take - 1) as u32,
            };
            first += take;
            entry
        })
        .collect()
}

/// [`Service`] implementation holding the federation's shard map.
pub struct RouterService {
    topo: TopologySnapshot,
    metrics: Metrics,
    stop: Option<Arc<AtomicBool>>,
    /// Server-core stage histograms, folded into live metrics replies.
    stages: StageHists,
}

impl RouterService {
    /// Serve `topo` to anyone who asks.
    pub fn new(topo: TopologySnapshot) -> Self {
        Self {
            topo,
            metrics: Metrics::default(),
            stop: None,
            stages: StageHists::default(),
        }
    }
}

impl Service for RouterService {
    fn bind_stop(&mut self, stop: Arc<AtomicBool>) {
        self.stop = Some(stop);
    }

    fn bind_stages(&mut self, stages: StageHists) {
        self.stages = stages;
    }

    fn on_open(&mut self, _conn: u64) {
        self.metrics.inc("net_conns", 1);
    }

    fn on_close(&mut self, _conn: u64) {}

    fn on_msg(&mut self, conn: u64, msg: Msg, out: &mut Outbox) {
        match msg {
            Msg::Hello { proto } => {
                if proto != PROTO_ID {
                    out.send(
                        conn,
                        &Msg::ErrorReply {
                            code: err_code::BAD_HELLO,
                            msg: format!("unknown protocol id {proto:#x}"),
                        },
                    );
                    out.close(conn);
                } else {
                    out.send(
                        conn,
                        &Msg::Welcome {
                            role: Role::Router,
                            d: self.topo.d,
                            epoch: 0,
                        },
                    );
                }
            }
            Msg::GetTopology => {
                self.metrics.inc("topology_reqs", 1);
                out.send(conn, &Msg::Topology(self.topo.clone()));
            }
            Msg::Sync { token } => out.send(
                conn,
                &Msg::SyncAck {
                    token,
                    epoch: 0,
                    pending: 0,
                },
            ),
            Msg::GetMetrics => {
                let mut m = self.metrics.clone();
                self.stages.merge_into(&mut m);
                out.send(conn, &Msg::Metrics(MetricsSnapshot::of(&m)));
            }
            Msg::Shutdown => {
                if let Some(stop) = &self.stop {
                    stop.store(true, Ordering::SeqCst);
                }
            }
            other => out.send(
                conn,
                &Msg::ErrorReply {
                    code: err_code::UNSUPPORTED,
                    msg: format!("router cannot handle {other:?}"),
                },
            ),
        }
    }

    fn on_shutdown(&mut self, open: &[u64], out: &mut Outbox) {
        for &conn in open {
            out.send(conn, &Msg::Goodbye { epoch: 0 });
        }
    }

    fn metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_stripes_is_contiguous_and_balanced() {
        let w = |n: usize| -> Vec<String> {
            (0..n).map(|i| format!("127.0.0.1:{}", 5000 + i)).collect()
        };
        for (shards, workers) in [(4, 2), (5, 2), (7, 3), (3, 3), (8, 1)] {
            let table = assign_stripes(shards, &w(workers));
            assert_eq!(table.len(), workers);
            assert_eq!(table[0].first, 0);
            assert_eq!(table[table.len() - 1].last as usize, shards - 1);
            for pair in table.windows(2) {
                assert_eq!(pair[1].first, pair[0].last + 1, "contiguous coverage");
            }
            let sizes: Vec<u32> = table.iter().map(|e| e.last - e.first + 1).collect();
            let (lo, hi) = (
                sizes.iter().copied().min().unwrap_or(0),
                sizes.iter().copied().max().unwrap_or(0),
            );
            assert!(hi - lo <= 1, "balanced to within one stripe: {sizes:?}");
        }
    }

    #[test]
    #[should_panic(expected = "more workers")]
    fn assign_stripes_rejects_worker_surplus() {
        let workers: Vec<String> = (0..3).map(|i| format!("w{i}")).collect();
        assign_stripes(2, &workers);
    }
}

//! Nonblocking TCP server core: listener + IO threads + one state
//! thread, pure `std`.
//!
//! The shape is thread-per-core in spirit but split by role so the
//! session owner never blocks on a socket:
//!
//! * **Listener thread** — accepts nonblocking, hands each connection
//!   to an IO thread round-robin (`conn_id % io_threads`, the same
//!   mapping the state thread uses to route replies).
//! * **IO threads** — each owns its connections outright: nonblocking
//!   reads into a per-connection buffer, frame splitting + decode
//!   ([`Msg::decode`]), partial-write buffering. Decoded messages flow
//!   to the state thread over an mpsc channel; reply frames flow back
//!   the same way. No connection is ever touched by two threads.
//! * **State thread** — owns the [`Service`] (session, metrics,
//!   subscribers) and is the only thread that mutates it, so the whole
//!   server needs **no locks at all** — the channels are the
//!   synchronization, in keeping with the exec layer's lock-free
//!   stance.
//!
//! Graceful shutdown (the clean stop path `ddm serve` lacked): the
//! shared stop flag is set — by [`ServerHandle::shutdown`] or by a
//! wire [`Msg::Shutdown`] — then the listener closes, the state thread
//! drains every event already queued, gives the service its
//! [`Service::on_shutdown`] hook (final commit + `Diff` to
//! subscribers + `Goodbye` to every client), and the IO threads flush
//! all pending writes before closing sockets and exiting. Every
//! thread is joined; the final [`Metrics`] come back to the caller.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown as SockShutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::coordinator::metrics::Metrics;
use crate::obs::{clock, AtomicHist};

use super::proto::{err_code, Msg};

/// The four server-stage histograms, shared across the threads that
/// feed them: `accept` (listener: accept → IO handoff), `decode` (IO
/// threads: one read's frame-split+decode batch), `state` (state
/// thread: one event batch through the service), `encode` (state
/// thread: reply-frame encoding inside the batch). Lock-free
/// ([`AtomicHist`]); [`serve`] creates one bundle, hands it to every
/// thread and to the service ([`Service::bind_stages`]) so live
/// metrics replies and the final [`Metrics`] report the same numbers.
#[derive(Clone, Default)]
pub struct StageHists {
    pub accept: Arc<AtomicHist>,
    pub decode: Arc<AtomicHist>,
    pub state: Arc<AtomicHist>,
    pub encode: Arc<AtomicHist>,
}

impl StageHists {
    /// Fold current snapshots into `m` under the `net_*_ns` histogram
    /// names (stages nothing has hit yet are skipped).
    pub fn merge_into(&self, m: &mut Metrics) {
        for (name, h) in [
            ("net_accept_ns", &self.accept),
            ("net_decode_ns", &self.decode),
            ("net_state_ns", &self.state),
            ("net_encode_ns", &self.encode),
        ] {
            let snap = h.snapshot();
            if !snap.is_empty() {
                m.merge_hist(name, &snap);
            }
        }
    }
}

/// Reply sink handed to [`Service`] hooks: frames to send and
/// connections to close, routed to the owning IO threads by the state
/// loop after each event batch.
pub struct Outbox {
    frames: Vec<(u64, Vec<u8>)>,
    closes: Vec<u64>,
    /// Nanoseconds spent encoding reply frames since the last
    /// [`take_encode_ns`](Self::take_encode_ns) (the state loop folds
    /// this into [`StageHists::encode`] per batch).
    encode_ns: u64,
}

impl Outbox {
    fn new() -> Self {
        Self {
            frames: Vec::new(),
            closes: Vec::new(),
            encode_ns: 0,
        }
    }

    /// Queue `msg` for connection `conn`.
    pub fn send(&mut self, conn: u64, msg: &Msg) {
        let t0 = clock::now_ns();
        self.frames.push((conn, msg.to_frame()));
        self.encode_ns += clock::now_ns().saturating_sub(t0);
    }

    /// Close `conn` once everything queued for it has flushed.
    pub fn close(&mut self, conn: u64) {
        self.closes.push(conn);
    }

    fn take_encode_ns(&mut self) -> u64 {
        std::mem::take(&mut self.encode_ns)
    }
}

/// What the state thread runs: the protocol brain behind the IO core.
/// [`WorkerService`](super::worker::WorkerService) (session owner) and
/// [`RouterService`](super::router::RouterService) (topology
/// authority) are the two implementations.
pub trait Service: Send + 'static {
    /// Receive the server's stop flag before any traffic; a service
    /// sets it to initiate shutdown (e.g. on a wire [`Msg::Shutdown`]).
    fn bind_stop(&mut self, stop: Arc<AtomicBool>);
    /// Receive the shared server-stage histograms before any traffic,
    /// so live metrics replies can include accept/decode/state/encode
    /// timing. Default: ignore them (the final [`Metrics`] still get
    /// them — the state loop merges on exit).
    fn bind_stages(&mut self, _stages: StageHists) {}
    /// A connection completed accept and is readable.
    fn on_open(&mut self, conn: u64);
    /// One decoded message from `conn`; replies go through `out`.
    fn on_msg(&mut self, conn: u64, msg: Msg, out: &mut Outbox);
    /// `conn` closed (EOF, error, or server-initiated).
    fn on_close(&mut self, conn: u64);
    /// Last chance before the server exits: `open` lists the live
    /// connections (flush staged work, farewell frames).
    fn on_shutdown(&mut self, open: &[u64], out: &mut Outbox);
    /// Surrender the final metrics (called once, after `on_shutdown`).
    fn metrics(&mut self) -> Metrics;
}

/// Server tuning: listen address and IO-thread count.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; use port `0` for an ephemeral port (the bound
    /// address comes back via [`ServerHandle::addr`]).
    pub listen: String,
    /// Socket-owning threads (≥ 1); connections are striped across
    /// them round-robin.
    pub io_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:0".to_string(),
            io_threads: 2,
        }
    }
}

/// Commands the listener and state threads send an IO thread.
enum IoCmd {
    /// Take ownership of a new connection.
    Conn(u64, TcpStream),
    /// Queue frame bytes for a connection.
    Frame(u64, Vec<u8>),
    /// Close a connection after its queue flushes.
    Close(u64),
    /// Flush every queue, close every socket, exit.
    Stop,
}

/// Events IO threads send the state thread.
enum Ev {
    Open(u64),
    Msg(u64, Msg),
    Closed(u64),
}

/// One connection, owned by exactly one IO thread.
struct Conn {
    stream: TcpStream,
    /// Unparsed inbound bytes (partial frames).
    rbuf: Vec<u8>,
    /// Outbound bytes not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// Prefix of `wbuf` already written.
    wpos: usize,
    /// Close once `wbuf` drains.
    closing: bool,
    /// Socket failed or EOF'd; reap immediately.
    dead: bool,
}

impl Conn {
    fn flushed(&self) -> bool {
        self.wpos >= self.wbuf.len()
    }
}

/// A running server. Dropping the handle does NOT stop the server —
/// call [`shutdown`](Self::shutdown) (or send a wire [`Msg::Shutdown`]
/// and [`join`](Self::join)) to stop it and collect final metrics.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    aux: Vec<JoinHandle<()>>,
    state: Option<JoinHandle<Metrics>>,
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared stop flag (for wiring into signal handlers or other
    /// external triggers).
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Initiate shutdown and wait for every thread: staged ops get a
    /// final commit, subscribers the final diff, clients a `Goodbye`,
    /// and all pending writes flush before sockets close.
    pub fn shutdown(mut self) -> Metrics {
        self.stop.store(true, Ordering::SeqCst);
        self.join_all()
    }

    /// Wait for the server to stop on its own (a wire
    /// [`Msg::Shutdown`] or an external [`stop_flag`](Self::stop_flag)
    /// store), then join every thread.
    pub fn join(mut self) -> Metrics {
        self.join_all()
    }

    fn join_all(&mut self) -> Metrics {
        let metrics = match self.state.take() {
            Some(h) => h.join().unwrap_or_default(),
            None => Metrics::default(),
        };
        for h in self.aux.drain(..) {
            let _ = h.join();
        }
        metrics
    }
}

/// Bind and spawn the server threads; returns immediately with the
/// handle (the bound address is `handle.addr()`).
pub fn serve<S: Service>(cfg: &ServerConfig, mut service: S) -> crate::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.listen)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stages = StageHists::default();
    service.bind_stop(Arc::clone(&stop));
    service.bind_stages(stages.clone());

    let nio = cfg.io_threads.max(1);
    let (ev_tx, ev_rx) = channel();
    let mut io_tx = Vec::with_capacity(nio);
    let mut aux = Vec::with_capacity(nio + 1);
    for _ in 0..nio {
        let (tx, rx) = channel();
        io_tx.push(tx);
        let ev = ev_tx.clone();
        let decode = Arc::clone(&stages.decode);
        aux.push(thread::spawn(move || io_loop(rx, ev, decode)));
    }
    drop(ev_tx);
    {
        let io_tx = io_tx.clone();
        let stop = Arc::clone(&stop);
        let accept = Arc::clone(&stages.accept);
        aux.push(thread::spawn(move || listen_loop(listener, io_tx, stop, accept)));
    }
    let state = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || state_loop(service, ev_rx, io_tx, stop, stages))
    };
    Ok(ServerHandle {
        addr,
        stop,
        aux,
        state: Some(state),
    })
}

/// Accept loop: nonblocking accept, stripe connections over IO
/// threads, exit when the stop flag rises (this closes the listener).
fn listen_loop(
    listener: TcpListener,
    io_tx: Vec<Sender<IoCmd>>,
    stop: Arc<AtomicBool>,
    accept_h: Arc<AtomicHist>,
) {
    let mut next_id: u64 = 0;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let t0 = clock::now_ns();
                let _ = stream.set_nodelay(true);
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let id = next_id;
                next_id += 1;
                let _ = io_tx[(id as usize) % io_tx.len()].send(IoCmd::Conn(id, stream));
                accept_h.record(clock::now_ns().saturating_sub(t0));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(1));
            }
            Err(_) => thread::sleep(Duration::from_millis(1)),
        }
    }
}

/// One IO thread: read/decode/forward inbound, buffer/flush outbound,
/// reap dead connections. On `Stop`, drains every write queue (bounded
/// grace) before closing sockets.
fn io_loop(rx: Receiver<IoCmd>, ev: Sender<Ev>, decode_h: Arc<AtomicHist>) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut tmp = [0u8; 16 * 1024];
    let mut stopping = false;
    // Grace iterations (×0.5 ms sleep when idle ≈ a few seconds) to
    // flush pending writes after Stop before force-closing.
    let mut grace: u32 = 4000;
    loop {
        let mut busy = false;

        // Commands from the listener and state threads.
        loop {
            match rx.try_recv() {
                Ok(IoCmd::Conn(id, stream)) => {
                    busy = true;
                    if stopping {
                        let _ = stream.shutdown(SockShutdown::Both);
                        continue;
                    }
                    conns.insert(
                        id,
                        Conn {
                            stream,
                            rbuf: Vec::new(),
                            wbuf: Vec::new(),
                            wpos: 0,
                            closing: false,
                            dead: false,
                        },
                    );
                    let _ = ev.send(Ev::Open(id));
                }
                Ok(IoCmd::Frame(id, bytes)) => {
                    busy = true;
                    if let Some(c) = conns.get_mut(&id) {
                        c.wbuf.extend_from_slice(&bytes);
                    }
                }
                Ok(IoCmd::Close(id)) => {
                    busy = true;
                    if let Some(c) = conns.get_mut(&id) {
                        c.closing = true;
                    }
                }
                Ok(IoCmd::Stop) => {
                    busy = true;
                    stopping = true;
                    for c in conns.values_mut() {
                        c.closing = true;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    stopping = true;
                    for c in conns.values_mut() {
                        c.closing = true;
                    }
                    break;
                }
            }
        }

        // Inbound: read, split frames, decode, forward.
        for (&id, c) in conns.iter_mut() {
            if c.closing || c.dead {
                continue;
            }
            loop {
                match c.stream.read(&mut tmp) {
                    Ok(0) => {
                        c.dead = true;
                        break;
                    }
                    Ok(n) => {
                        busy = true;
                        c.rbuf.extend_from_slice(&tmp[..n]);
                        let t_dec = clock::now_ns();
                        let mut at = 0;
                        loop {
                            match Msg::decode(&c.rbuf[at..]) {
                                Ok(Some((msg, used))) => {
                                    at += used;
                                    let _ = ev.send(Ev::Msg(id, msg));
                                }
                                Ok(None) => break,
                                Err(e) => {
                                    // Corrupt stream: typed reply, then
                                    // close (resync is not possible once
                                    // framing is untrusted).
                                    Msg::ErrorReply {
                                        code: err_code::BAD_FRAME,
                                        msg: e.to_string(),
                                    }
                                    .encode(&mut c.wbuf);
                                    c.closing = true;
                                    break;
                                }
                            }
                        }
                        if at > 0 {
                            c.rbuf.drain(..at);
                            decode_h.record(clock::now_ns().saturating_sub(t_dec));
                        }
                        if c.closing || n < tmp.len() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.dead = true;
                        break;
                    }
                }
            }
        }

        // Outbound: flush as much as each socket accepts.
        for c in conns.values_mut() {
            if c.dead {
                continue;
            }
            while !c.flushed() {
                match c.stream.write(&c.wbuf[c.wpos..]) {
                    Ok(0) => {
                        c.dead = true;
                        break;
                    }
                    Ok(n) => {
                        busy = true;
                        c.wpos += n;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.dead = true;
                        break;
                    }
                }
            }
            if c.flushed() && !c.wbuf.is_empty() {
                c.wbuf.clear();
                c.wpos = 0;
            }
        }

        // Reap: dead sockets now, closing ones once their queue flushed.
        let reap: Vec<u64> = conns
            .iter()
            .filter(|(_, c)| c.dead || (c.closing && c.flushed()))
            .map(|(&id, _)| id)
            .collect();
        for id in reap {
            if let Some(c) = conns.remove(&id) {
                let _ = c.stream.shutdown(SockShutdown::Both);
            }
            let _ = ev.send(Ev::Closed(id));
        }

        if stopping {
            if conns.is_empty() {
                return;
            }
            if !busy {
                grace = grace.saturating_sub(1);
                if grace == 0 {
                    // Flush grace exhausted: force-close what remains.
                    for c in conns.values() {
                        let _ = c.stream.shutdown(SockShutdown::Both);
                    }
                    return;
                }
            }
        }
        if !busy {
            thread::sleep(Duration::from_micros(500));
        }
    }
}

/// The state loop: single owner of the service. Batches queued events
/// between flushes; on stop, drains the backlog so the final commit
/// covers every op the server already received, then runs the
/// service's shutdown hook and stops the IO threads.
fn state_loop<S: Service>(
    mut service: S,
    ev_rx: Receiver<Ev>,
    io_tx: Vec<Sender<IoCmd>>,
    stop: Arc<AtomicBool>,
    stages: StageHists,
) -> Metrics {
    let mut open: Vec<u64> = Vec::new();
    let mut out = Outbox::new();
    loop {
        match ev_rx.recv_timeout(Duration::from_millis(2)) {
            Ok(ev) => {
                // One batch = everything already queued; its wall time
                // (minus the encode share, accounted separately) is
                // the state stage.
                let t_state = clock::now_ns();
                dispatch(&mut service, ev, &mut open, &mut out);
                while let Ok(ev) = ev_rx.try_recv() {
                    dispatch(&mut service, ev, &mut open, &mut out);
                }
                let enc = out.take_encode_ns();
                let batch = clock::now_ns().saturating_sub(t_state);
                stages.state.record(batch.saturating_sub(enc));
                if enc > 0 {
                    stages.encode.record(enc);
                }
                route(&mut out, &io_tx);
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    // Drain whatever the IO threads forwarded before the flag rose.
    while let Ok(ev) = ev_rx.try_recv() {
        dispatch(&mut service, ev, &mut open, &mut out);
    }
    service.on_shutdown(&open, &mut out);
    route(&mut out, &io_tx);
    for tx in &io_tx {
        let _ = tx.send(IoCmd::Stop);
    }
    // Final metrics carry the stage histograms; live GetMetrics
    // replies get the same numbers from the service's own copy of
    // `stages` ([`Service::bind_stages`]) — it snapshots, so there is
    // no double counting.
    let mut m = service.metrics();
    stages.merge_into(&mut m);
    m
}

fn dispatch<S: Service>(service: &mut S, ev: Ev, open: &mut Vec<u64>, out: &mut Outbox) {
    match ev {
        Ev::Open(id) => {
            open.push(id);
            service.on_open(id);
        }
        Ev::Msg(id, msg) => service.on_msg(id, msg, out),
        Ev::Closed(id) => {
            open.retain(|&c| c != id);
            service.on_close(id);
        }
    }
}

/// Route queued frames/closes to the IO thread owning each connection
/// (`conn % io_threads`, matching the listener's assignment).
fn route(out: &mut Outbox, io_tx: &[Sender<IoCmd>]) {
    for (conn, bytes) in out.frames.drain(..) {
        let _ = io_tx[(conn as usize) % io_tx.len()].send(IoCmd::Frame(conn, bytes));
    }
    for conn in out.closes.drain(..) {
        let _ = io_tx[(conn as usize) % io_tx.len()].send(IoCmd::Close(conn));
    }
}

//! Byte-level wire primitives: length-prefixed frames, LEB128 varints,
//! fixed-width floats, and a zero-copy cursor reader.
//!
//! This layer knows nothing about messages — [`super::proto`] owns the
//! frame catalog. The split mirrors the builder/container pattern:
//!
//! * **Encode** appends into a **caller-owned** `Vec<u8>` (no writer
//!   object, no intermediate buffers): [`put_varint`], [`put_f64`],
//!   [`frame`].
//! * **Decode** reads **zero-copy** from a `&[u8]` through [`Reader`],
//!   returning primitives and subslices borrowed from the input.
//!   Nothing in this file allocates on the decode path — the
//!   `wire-no-alloc-in-decode` xtask lint rule enforces it.
//!
//! Every decode returns `Result<_, WireError>`; corrupt or truncated
//! input is a typed error, never a panic. Frames:
//!
//! ```text
//! [body_len: u32 LE] [version: u8] [tag: u8] [payload: body_len-2 bytes]
//! ```
//!
//! `body_len` counts the version and tag bytes. Declared lengths above
//! [`MAX_FRAME`] are rejected before any buffering decision, so a
//! corrupt length prefix cannot drive allocation.

use std::fmt;

/// Wire protocol version carried in every frame.
pub const VERSION: u8 = 1;

/// Upper bound on a frame body; larger declared lengths are rejected
/// as [`WireError::Oversized`] without buffering.
pub const MAX_FRAME: usize = 16 << 20;

/// Frame header size: 4-byte length prefix + version + tag.
pub const HEADER: usize = 6;

/// Typed decode failure. Implements [`std::error::Error`], so `?`
/// converts it into the crate-wide [`Error`](crate::error::Error).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// Input ended in the middle of a value or declared frame body.
    Truncated,
    /// Frame length prefix exceeds [`MAX_FRAME`].
    Oversized(usize),
    /// Version byte does not match [`VERSION`].
    BadVersion(u8),
    /// Unknown message tag.
    BadTag(u8),
    /// Structurally invalid payload (overlong varint, bad UTF-8,
    /// unsorted pair list, out-of-range count…).
    Malformed(&'static str),
    /// Payload decoded cleanly but bytes remain in the frame body.
    Trailing(usize),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::Oversized(n) => write!(f, "declared frame body of {n} bytes exceeds cap"),
            WireError::BadVersion(v) => write!(f, "unsupported wire version {v}"),
            WireError::BadTag(t) => write!(f, "unknown message tag {t}"),
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
            WireError::Trailing(n) => write!(f, "{n} trailing bytes after payload"),
        }
    }
}

impl std::error::Error for WireError {}

/// Append one byte.
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

/// Append a fixed-width little-endian `u32` (the frame length prefix).
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a LEB128 varint (1–10 bytes; compact for the small keys,
/// counts and deltas that dominate region traffic).
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Append an `f64` as its 8 IEEE-754 bits, little-endian — bounds
/// cross the wire bit-exact, which the federation layer relies on for
/// identical routing on both sides.
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Append a length-prefixed byte string (varint length + bytes).
pub fn put_bytes(out: &mut Vec<u8>, v: &[u8]) {
    put_varint(out, v.len() as u64);
    out.extend_from_slice(v);
}

/// Append a complete frame: reserves the length prefix, writes the
/// version and tag, runs `payload`, then patches the prefix. The one
/// writer all messages funnel through, so a frame can never disagree
/// with its declared length.
pub fn frame<F: FnOnce(&mut Vec<u8>)>(out: &mut Vec<u8>, tag: u8, payload: F) {
    let at = out.len();
    put_u32(out, 0);
    put_u8(out, VERSION);
    put_u8(out, tag);
    payload(out);
    let body = (out.len() - at - 4) as u32;
    out[at..at + 4].copy_from_slice(&body.to_le_bytes());
}

/// Split the frame at the head of `buf`.
///
/// * `Ok(None)` — the buffer holds an incomplete frame; read more.
/// * `Ok(Some((version, tag, payload, consumed)))` — one whole frame;
///   `payload` excludes the version and tag bytes, `consumed` is the
///   total byte count to drain from the buffer.
/// * `Err` — the stream is corrupt at frame granularity (oversized or
///   impossible length); the connection cannot resync and should
///   close.
pub fn split_frame(buf: &[u8]) -> Result<Option<(u8, u8, &[u8], usize)>, WireError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let body = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if body > MAX_FRAME {
        return Err(WireError::Oversized(body));
    }
    if body < 2 {
        return Err(WireError::Malformed("frame body shorter than header"));
    }
    if buf.len() < 4 + body {
        return Ok(None);
    }
    Ok(Some((buf[4], buf[5], &buf[6..4 + body], 4 + body)))
}

/// Zero-copy cursor over a frame payload. Every accessor advances the
/// cursor and fails with a typed error instead of panicking; subslice
/// accessors borrow from the input.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Cursor over `buf`, positioned at the start.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8, WireError> {
        let b = *self.buf.get(self.pos).ok_or(WireError::Truncated)?;
        self.pos += 1;
        Ok(b)
    }

    /// Read a LEB128 varint (rejects encodings past 10 bytes and
    /// high-bit overflow into a 65th bit).
    pub fn varint(&mut self) -> Result<u64, WireError> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let byte = self.u8()?;
            if shift == 63 && byte > 1 {
                return Err(WireError::Malformed("varint overflows u64"));
            }
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(WireError::Malformed("varint too long"));
            }
        }
    }

    /// Read a fixed-width little-endian `f64` (bit-exact).
    pub fn f64(&mut self) -> Result<f64, WireError> {
        if self.remaining() < 8 {
            return Err(WireError::Truncated);
        }
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 8]);
        self.pos += 8;
        Ok(f64::from_bits(u64::from_le_bytes(b)))
    }

    /// Read a length-prefixed byte string, borrowed from the input.
    pub fn bytes(&mut self) -> Result<&'a [u8], WireError> {
        let len = self.varint()? as usize;
        if len > self.remaining() {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    /// Read a length-prefixed UTF-8 string, borrowed from the input.
    pub fn str(&mut self) -> Result<&'a str, WireError> {
        std::str::from_utf8(self.bytes()?).map_err(|_| WireError::Malformed("invalid UTF-8"))
    }

    /// Read a count that prefixes a list whose elements occupy at
    /// least `min_elem_bytes` each — bounds the count by the bytes
    /// actually present, so a corrupt count can never drive a huge
    /// allocation in the callers that do collect.
    pub fn count(&mut self, min_elem_bytes: usize) -> Result<usize, WireError> {
        let n = self.varint()? as usize;
        if n.saturating_mul(min_elem_bytes.max(1)) > self.remaining() {
            return Err(WireError::Truncated);
        }
        Ok(n)
    }

    /// Assert the payload was consumed exactly.
    pub fn finish(&self) -> Result<(), WireError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(WireError::Trailing(self.remaining()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_round_trips_edge_values() {
        for v in [
            0u64,
            1,
            127,
            128,
            16383,
            16384,
            u32::MAX as u64,
            u64::MAX - 1,
            u64::MAX,
        ] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            assert!(buf.len() <= 10);
            let mut r = Reader::new(&buf);
            assert_eq!(r.varint().unwrap(), v, "v={v}");
            r.finish().unwrap();
        }
    }

    #[test]
    fn varint_rejects_overlong_and_overflowing_encodings() {
        // 11 continuation bytes: too long.
        let buf = [0x80u8; 11];
        assert_eq!(
            Reader::new(&buf).varint(),
            Err(WireError::Malformed("varint too long"))
        );
        // 10 bytes whose top byte overflows the 64th bit.
        let mut buf = vec![0x80u8; 9];
        buf.push(0x02);
        assert_eq!(
            Reader::new(&buf).varint(),
            Err(WireError::Malformed("varint overflows u64"))
        );
        // Truncated mid-varint.
        assert_eq!(Reader::new(&[0x80u8]).varint(), Err(WireError::Truncated));
    }

    #[test]
    fn f64_is_bit_exact() {
        for v in [0.0, -0.0, 1.5, f64::MIN_POSITIVE, f64::MAX, f64::NEG_INFINITY, f64::NAN] {
            let mut buf = Vec::new();
            put_f64(&mut buf, v);
            let got = Reader::new(&buf).f64().unwrap();
            assert_eq!(got.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn bytes_and_str_borrow_and_validate() {
        let mut buf = Vec::new();
        put_bytes(&mut buf, b"hello");
        let mut r = Reader::new(&buf);
        assert_eq!(r.bytes().unwrap(), b"hello");
        r.finish().unwrap();

        let mut buf = Vec::new();
        put_bytes(&mut buf, &[0xff, 0xfe]);
        assert_eq!(
            Reader::new(&buf).str(),
            Err(WireError::Malformed("invalid UTF-8"))
        );

        // Declared length beyond the buffer: truncated, not a panic.
        let mut buf = Vec::new();
        put_varint(&mut buf, 100);
        buf.push(b'x');
        assert_eq!(Reader::new(&buf).bytes(), Err(WireError::Truncated));
    }

    #[test]
    fn frame_writes_and_splits() {
        let mut buf = Vec::new();
        frame(&mut buf, 7, |out| put_varint(out, 42));
        let (ver, tag, payload, used) = split_frame(&buf).unwrap().unwrap();
        assert_eq!((ver, tag, used), (VERSION, 7, buf.len()));
        let mut r = Reader::new(payload);
        assert_eq!(r.varint().unwrap(), 42);
        r.finish().unwrap();
    }

    #[test]
    fn split_frame_handles_partial_oversized_and_short_bodies() {
        let mut buf = Vec::new();
        frame(&mut buf, 3, |out| put_bytes(out, b"abc"));
        // Every strict prefix is "incomplete", never an error.
        for cut in 0..buf.len() {
            assert_eq!(split_frame(&buf[..cut]).unwrap(), None, "cut={cut}");
        }
        // Oversized declared length is rejected without buffering.
        let mut bad = Vec::new();
        put_u32(&mut bad, (MAX_FRAME + 1) as u32);
        assert_eq!(
            split_frame(&bad),
            Err(WireError::Oversized(MAX_FRAME + 1))
        );
        // A body too short to hold version+tag is malformed.
        let mut bad = Vec::new();
        put_u32(&mut bad, 1);
        bad.push(VERSION);
        assert!(matches!(split_frame(&bad), Err(WireError::Malformed(_))));
    }

    #[test]
    fn count_bounds_list_headers_by_available_bytes() {
        let mut buf = Vec::new();
        put_varint(&mut buf, 1 << 40); // absurd count, no elements
        assert_eq!(Reader::new(&buf).count(8), Err(WireError::Truncated));
        let mut buf = Vec::new();
        put_varint(&mut buf, 2);
        put_f64(&mut buf, 1.0);
        put_f64(&mut buf, 2.0);
        let mut r = Reader::new(&buf);
        assert_eq!(r.count(8).unwrap(), 2);
    }

    #[test]
    fn trailing_bytes_are_a_typed_error() {
        let mut buf = Vec::new();
        put_varint(&mut buf, 5);
        buf.push(0);
        let mut r = Reader::new(&buf);
        r.varint().unwrap();
        assert_eq!(r.finish(), Err(WireError::Trailing(1)));
    }
}

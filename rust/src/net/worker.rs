//! The worker service: a network front-end for one [`AnySession`].
//!
//! A worker owns a contiguous stripe-range of the federation's global
//! shard space (or the whole space when it runs alone behind `ddm
//! serve`). Decoded [`RegionOp`]s pass **admission control** first: a
//! bounded MPSC ingest queue
//! ([`ingest_queue`](crate::session::ingest_queue), sized by
//! [`SessionParams::ingest_backlog`](crate::session::SessionParams::ingest_backlog))
//! holds them until the next drain point (`Flush`, `Commit`,
//! shutdown), where they stage into the session's LWW batch path
//! exactly as local callers would. A full backlog rejects the op with
//! a typed [`Msg::Busy`] reply instead of buffering without bound —
//! clients back off and retry — and the live depth is exported as the
//! `ingest_backlog` gauge. Reads (`GetPairs`, `Sync`, `GetMetrics`)
//! answer from the session's wait-free
//! [`EpochSnapshot`](crate::session::EpochSnapshot) and the queue
//! gauges, so the state thread's read path never blocks a commit.
//!
//! Shutdown keeps the session honest: if any ops were staged or
//! flushed since the last commit, the worker closes one final epoch
//! and streams that diff before `Goodbye`, so a client that stops the
//! server mid-stream still observes every transition exactly once.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::metrics::Metrics;
use crate::obs::{clock, SpanRecord};
use crate::session::{ingest_queue, IngestReceiver, IngestSender, MatchDiff, Side};
use crate::shard::{AnySession, ShardedSession};

use super::proto::{err_code, MetricsSnapshot, Msg, RegionOp, Role, PROTO_ID};
use super::server::{Outbox, Service, StageHists};

/// Retained trace spans (newest win); [`Msg::GetMetrics`] replies carry
/// the top slowest out of this window.
const TRACE_LOG_CAP: usize = 1024;

/// Spans per [`MetricsSnapshot`] reply.
const SNAPSHOT_SPANS: usize = 32;

/// [`Service`] implementation wrapping a session (single or sharded).
pub struct WorkerService {
    session: AnySession,
    metrics: Metrics,
    /// Connections that asked for every epoch's diff.
    subscribers: Vec<u64>,
    /// Ops staged or flushed since the last commit (drives the final
    /// commit on shutdown — `pending_ops()` alone misses flushed work).
    dirty: bool,
    stop: Option<Arc<AtomicBool>>,
    /// Server-core stage histograms (accept/decode/state/encode),
    /// folded into metrics snapshots so live `GetMetrics` replies match
    /// the final table.
    stages: StageHists,
    /// Phase spans drained from the session after each traced commit,
    /// bounded to the most recent [`TRACE_LOG_CAP`].
    trace_log: Vec<SpanRecord>,
    /// Admission-controlled staged-op backlog: decoded ops enqueue
    /// here (bounded, typed `Busy` on overflow) and drain into the
    /// session at the next flush / commit / shutdown.
    ingest_tx: IngestSender,
    ingest_rx: IngestReceiver,
}

impl WorkerService {
    /// Wrap `session`; the server core calls everything else. The
    /// ingest backlog is sized from the session's
    /// [`ingest_backlog`](crate::session::SessionParams::ingest_backlog)
    /// parameter.
    pub fn new(session: AnySession) -> Self {
        let backlog = session.params().ingest_backlog;
        Self::with_backlog(session, backlog)
    }

    /// Wrap `session` with an explicit ingest-backlog bound (ops).
    pub fn with_backlog(session: AnySession, backlog: usize) -> Self {
        let (ingest_tx, ingest_rx) = ingest_queue(backlog);
        Self {
            session,
            metrics: Metrics::default(),
            subscribers: Vec::new(),
            dirty: false,
            stop: None,
            stages: StageHists::default(),
            trace_log: Vec::new(),
            ingest_tx,
            ingest_rx,
        }
    }

    fn stage(&mut self, conn: u64, op: RegionOp, out: &mut Outbox) {
        let d = self.session.d();
        let admitted = match op {
            RegionOp::UpsertSub { key, rect } => {
                if rect.len() != d {
                    self.reject_dims(conn, rect.len(), out);
                    return;
                }
                self.ingest_tx.try_upsert(Side::Subscription, key, &rect)
            }
            RegionOp::UpsertUpd { key, rect } => {
                if rect.len() != d {
                    self.reject_dims(conn, rect.len(), out);
                    return;
                }
                self.ingest_tx.try_upsert(Side::Update, key, &rect)
            }
            RegionOp::RemoveSub { key } => self.ingest_tx.try_remove(Side::Subscription, key),
            RegionOp::RemoveUpd { key } => self.ingest_tx.try_remove(Side::Update, key),
        };
        match admitted {
            Ok(()) => {
                self.dirty = true;
                self.metrics.inc("net_ops", 1);
            }
            Err(busy) => {
                self.metrics.inc("net_busy", 1);
                out.send(
                    conn,
                    &Msg::Busy {
                        pending: busy.pending,
                        limit: busy.limit,
                    },
                );
            }
        }
    }

    /// Drain the ingest backlog into the session's staging maps
    /// (refreshing the `ingest_backlog` gauge with the pre-drain
    /// depth), and return the drained count.
    fn drain_backlog(&mut self) -> usize {
        self.metrics
            .gauge("ingest_backlog", self.ingest_rx.depth() as f64);
        self.session.drain_ingest(&self.ingest_rx)
    }

    fn reject_dims(&mut self, conn: u64, got: usize, out: &mut Outbox) {
        out.send(
            conn,
            &Msg::ErrorReply {
                code: err_code::BAD_OP,
                msg: format!("rect has {got} dims, session wants {}", self.session.d()),
            },
        );
    }

    fn commit_epoch(&mut self) -> MatchDiff {
        self.drain_backlog();
        let t0 = clock::now_ns();
        let diff = self.session.commit();
        self.metrics
            .observe_ns("commit_ns", clock::now_ns().saturating_sub(t0));
        self.dirty = false;
        self.metrics.inc("commits", 1);
        self.metrics.inc("diff_added", diff.added.len() as u64);
        self.metrics.inc("diff_removed", diff.removed.len() as u64);
        if let Some(stats) = self.session.shard_stats() {
            self.metrics
                .gauge("shard_imbalance", ShardedSession::imbalance_of(&stats));
            if let Some(ti) = ShardedSession::commit_time_imbalance_of(&stats) {
                self.metrics.gauge("shard_time_imbalance", ti);
            }
        }
        if self.session.trace_enabled() {
            self.trace_log.extend(self.session.drain_trace());
            if self.trace_log.len() > TRACE_LOG_CAP {
                let excess = self.trace_log.len() - TRACE_LOG_CAP;
                self.trace_log.drain(..excess);
            }
        }
        self.publish_wal_gauges();
        diff
    }

    /// Export the session's WAL counters (when durability is attached)
    /// as gauges, so `GetMetrics` replies and the final table report
    /// how much the log has absorbed and whether it has degraded.
    fn publish_wal_gauges(&mut self) {
        let Some(stats) = self.session.wal_stats() else {
            return;
        };
        self.metrics.gauge("wal_bytes", stats.bytes as f64);
        self.metrics.gauge("wal_records", stats.records as f64);
        self.metrics.gauge("wal_commits", stats.commits as f64);
        self.metrics.gauge("wal_fsyncs", stats.fsyncs as f64);
        self.metrics.gauge("wal_checkpoints", stats.checkpoints as f64);
        self.metrics.gauge("wal_errors", stats.errors as f64);
    }

    /// Stream `diff` to every subscriber except `skip` (the committing
    /// connection gets its copy as the direct reply, never twice).
    fn stream_diff(&mut self, diff: &MatchDiff, skip: Option<u64>, out: &mut Outbox) {
        let mut sent = 0u64;
        for &s in &self.subscribers {
            if Some(s) == skip {
                continue;
            }
            out.send(s, &Msg::Diff(diff.clone()));
            sent += 1;
        }
        self.metrics.inc("net_diff_frames", sent);
    }
}

impl Service for WorkerService {
    fn bind_stop(&mut self, stop: Arc<AtomicBool>) {
        self.stop = Some(stop);
    }

    fn bind_stages(&mut self, stages: StageHists) {
        self.stages = stages;
    }

    fn on_open(&mut self, _conn: u64) {
        self.metrics.inc("net_conns", 1);
    }

    fn on_close(&mut self, conn: u64) {
        self.subscribers.retain(|&c| c != conn);
    }

    fn on_msg(&mut self, conn: u64, msg: Msg, out: &mut Outbox) {
        match msg {
            Msg::Hello { proto } => {
                if proto != PROTO_ID {
                    out.send(
                        conn,
                        &Msg::ErrorReply {
                            code: err_code::BAD_HELLO,
                            msg: format!("unknown protocol id {proto:#x}"),
                        },
                    );
                    out.close(conn);
                } else {
                    out.send(
                        conn,
                        &Msg::Welcome {
                            role: Role::Worker,
                            d: self.session.d() as u32,
                            epoch: self.session.epoch(),
                        },
                    );
                }
            }
            Msg::Op(op) => self.stage(conn, op, out),
            Msg::Batch(ops) => {
                for op in ops {
                    self.stage(conn, op, out);
                }
            }
            Msg::Flush => {
                self.drain_backlog();
                self.session.flush();
            }
            Msg::Commit => {
                let diff = self.commit_epoch();
                self.stream_diff(&diff, Some(conn), out);
                out.send(conn, &Msg::Diff(diff));
                self.metrics.inc("net_diff_frames", 1);
            }
            Msg::Subscribe => {
                if !self.subscribers.contains(&conn) {
                    self.subscribers.push(conn);
                }
            }
            Msg::Sync { token } => out.send(
                conn,
                &Msg::SyncAck {
                    token,
                    epoch: self.session.epoch(),
                    pending: (self.ingest_rx.depth() + self.session.pending_ops()) as u64,
                },
            ),
            Msg::GetPairs => {
                // Off-snapshot: an O(1) clone of the published epoch,
                // byte-identical to an in-process read at the same
                // point — the session is never locked or flushed here.
                let pairs = self.session.snapshot().pairs();
                out.send(conn, &Msg::Pairs(pairs));
            }
            Msg::GetMetrics => {
                self.metrics
                    .gauge("net_subscribers", self.subscribers.len() as f64);
                self.metrics
                    .gauge("ingest_backlog", self.ingest_rx.depth() as f64);
                self.publish_wal_gauges();
                // Fold the server-core stage histograms into a copy so
                // the live reply matches the final table without
                // double-counting into the service's own registry.
                let mut m = self.metrics.clone();
                self.stages.merge_into(&mut m);
                let snap =
                    MetricsSnapshot::of(&m).with_spans(&self.trace_log, SNAPSHOT_SPANS);
                out.send(conn, &Msg::Metrics(snap));
            }
            Msg::Shutdown => {
                if let Some(stop) = &self.stop {
                    stop.store(true, Ordering::SeqCst);
                }
            }
            other => out.send(
                conn,
                &Msg::ErrorReply {
                    code: err_code::UNSUPPORTED,
                    msg: format!("worker cannot handle {other:?}"),
                },
            ),
        }
    }

    fn on_shutdown(&mut self, open: &[u64], out: &mut Outbox) {
        // Flush staged AND queued work into one last epoch so nothing
        // the server acknowledged is silently dropped.
        if self.dirty || self.session.pending_ops() > 0 || self.ingest_rx.depth() > 0 {
            let diff = self.commit_epoch();
            self.stream_diff(&diff, None, out);
        }
        let epoch = self.session.epoch();
        for &conn in open {
            out.send(conn, &Msg::Goodbye { epoch });
        }
    }

    fn metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }
}

//! Chrome trace-event export: turn a span timeline into JSON that
//! `chrome://tracing` / Perfetto render as one lane per worker/shard.
//!
//! The format is the Trace Event Format's complete-event (`"ph":"X"`)
//! flavor: one object per span with microsecond `ts`/`dur`, `tid` =
//! worker lane, plus `"M"` metadata events naming each lane. Built on
//! the crate's serde-free [`json`](crate::config::json) writer.

use std::collections::BTreeMap;

use crate::config::json;

use super::trace::{SpanRecord, MASTER_WORKER};
use super::Phase;

/// The `n` slowest spans, longest first (ties broken by start time so
/// the order is deterministic). Used for the wire's top-span export
/// and the `ddm trace` summary.
pub fn top_slowest(records: &[SpanRecord], n: usize) -> Vec<SpanRecord> {
    let mut v: Vec<SpanRecord> = records.to_vec();
    v.sort_by(|a, b| {
        b.dur_ns()
            .cmp(&a.dur_ns())
            .then(a.t0_ns.cmp(&b.t0_ns))
            .then(a.worker.cmp(&b.worker))
    });
    v.truncate(n);
    v
}

/// Per-phase rollup of a timeline: `(phase id, total ns, span count,
/// total items)` in phase-id order. The acceptance check "span totals
/// ≈ commit wall-clock" and the `ddm trace` summary read this.
pub fn phase_totals(records: &[SpanRecord]) -> Vec<(u16, u64, u64, u64)> {
    let mut acc: BTreeMap<u16, (u64, u64, u64)> = BTreeMap::new();
    for r in records {
        let e = acc.entry(r.phase).or_insert((0, 0, 0));
        e.0 += r.dur_ns();
        e.1 += 1;
        e.2 += r.items;
    }
    acc.into_iter().map(|(p, (ns, n, items))| (p, ns, n, items)).collect()
}

/// Human label for a worker lane.
fn lane_name(worker: u16) -> String {
    if worker == MASTER_WORKER {
        "master".to_string()
    } else {
        format!("worker {worker}")
    }
}

/// Render a timeline as a Chrome trace-event JSON document. Spans
/// become complete events (`ph: "X"`, `ts`/`dur` in microseconds,
/// `tid` = worker lane); each lane also gets a `thread_name` metadata
/// event so chrome://tracing shows "master" / "worker 3" instead of
/// raw tids. Load via chrome://tracing → Load, or ui.perfetto.dev.
pub fn chrome_trace_json(records: &[SpanRecord]) -> String {
    let mut events: Vec<String> = Vec::with_capacity(records.len() + 8);

    // One thread_name metadata event per lane, lowest tid first.
    let mut lanes: Vec<u16> = records.iter().map(|r| r.worker).collect();
    lanes.sort_unstable();
    lanes.dedup();
    for &w in &lanes {
        events.push(json::object(&[
            ("name", json::string("thread_name")),
            ("ph", json::string("M")),
            ("pid", "1".to_string()),
            ("tid", w.to_string()),
            (
                "args",
                json::object(&[("name", json::string(&lane_name(w)))]),
            ),
        ]));
    }

    for r in records {
        events.push(json::object(&[
            ("name", json::string(Phase::name_of(r.phase))),
            ("cat", json::string("ddm")),
            ("ph", json::string("X")),
            ("pid", "1".to_string()),
            ("tid", r.worker.to_string()),
            // Trace-event times are microseconds (fractions allowed).
            ("ts", json::num(r.t0_ns as f64 / 1000.0)),
            ("dur", json::num(r.dur_ns() as f64 / 1000.0)),
            (
                "args",
                json::object(&[
                    ("items", r.items.to_string()),
                    ("phase_id", r.phase.to_string()),
                ]),
            ),
        ]));
    }

    json::object(&[
        ("displayTimeUnit", json::string("ms")),
        ("traceEvents", json::array(&events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(phase: Phase, worker: u16, t0: u64, t1: u64, items: u64) -> SpanRecord {
        SpanRecord {
            phase: phase.id(),
            worker,
            t0_ns: t0,
            t1_ns: t1,
            items,
        }
    }

    #[test]
    fn top_slowest_orders_by_duration_then_start() {
        let rs = vec![
            rec(Phase::Sort, 0, 0, 50, 1),
            rec(Phase::Sweep, 1, 10, 300, 2),
            rec(Phase::Commit, 2, 5, 55, 3), // same dur as Sort, later start
        ];
        let top = top_slowest(&rs, 2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].phase, Phase::Sweep.id());
        assert_eq!(top[1].phase, Phase::Sort.id(), "earlier start wins the tie");
        assert!(top_slowest(&rs, 10).len() == 3);
        assert!(top_slowest(&[], 5).is_empty());
    }

    #[test]
    fn phase_totals_roll_up_duration_count_items() {
        let rs = vec![
            rec(Phase::Sort, 0, 0, 10, 100),
            rec(Phase::Sort, 1, 0, 20, 50),
            rec(Phase::Sweep, 0, 10, 15, 7),
        ];
        let totals = phase_totals(&rs);
        assert_eq!(totals.len(), 2);
        assert_eq!(totals[0], (Phase::Sort.id(), 30, 2, 150));
        assert_eq!(totals[1], (Phase::Sweep.id(), 5, 1, 7));
    }

    /// Minimal structural JSON check: balanced braces/brackets outside
    /// strings, no trailing garbage. (CI additionally parses the real
    /// artifact with a full JSON parser.)
    fn assert_balanced_json(s: &str) {
        let (mut depth, mut in_str, mut esc) = (0i64, false, false);
        for c in s.chars() {
            if in_str {
                if esc {
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else if c == '"' {
                    in_str = false;
                }
                continue;
            }
            match c {
                '"' => in_str = true,
                '{' | '[' => depth += 1,
                '}' | ']' => {
                    depth -= 1;
                    assert!(depth >= 0, "unbalanced close in {s}");
                }
                _ => {}
            }
        }
        assert!(!in_str, "unterminated string");
        assert_eq!(depth, 0, "unbalanced JSON");
    }

    #[test]
    fn chrome_trace_is_well_formed_and_lane_labelled() {
        let rs = vec![
            rec(Phase::Sort, 0, 1000, 2500, 10),
            rec(Phase::Commit, MASTER_WORKER, 0, 5000, 1),
        ];
        let out = chrome_trace_json(&rs);
        assert_balanced_json(&out);
        assert!(out.starts_with('{') && out.ends_with('}'));
        assert!(out.contains("\"traceEvents\""));
        assert!(out.contains("\"name\":\"sort\""));
        assert!(out.contains("\"name\":\"master\""), "master lane named");
        assert!(out.contains("\"name\":\"worker 0\""));
        assert!(out.contains("\"ts\":1"), "microsecond timestamps");
        assert!(out.contains("\"dur\":1.5"), "1500ns → 1.5µs");
        // 2 spans + 2 lane-metadata events.
        assert_eq!(out.matches("\"ph\":").count(), 4);
    }

    #[test]
    fn empty_timeline_still_renders_valid_json() {
        let out = chrome_trace_json(&[]);
        assert_balanced_json(&out);
        assert!(out.contains("\"traceEvents\":[]"));
    }
}

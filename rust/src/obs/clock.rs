//! The monotonic clock seam: nanoseconds since the process's first
//! observation.
//!
//! The `xtask lint` wallclock rule bans `Instant::now` outside the
//! measurement layer so hot code cannot sneak in timing side effects;
//! `obs/` is the one sanctioned owner of the clock (the lint carries an
//! `obs/` exemption). Everything that needs a timestamp — span sinks,
//! histograms, the net server's stage timers — calls [`now_ns`], which
//! keeps timestamps small (they fit traces and varints comfortably),
//! mutually comparable within one process, and mockable in tests via
//! plain arithmetic on the returned values.

use std::sync::OnceLock;
use std::time::Instant;

/// Process-wide anchor: the instant of the first [`now_ns`] call.
static ANCHOR: OnceLock<Instant> = OnceLock::new();

/// Monotonic nanoseconds since the first call in this process. The
/// first call returns a small value (not 0 exactly — initialization
/// itself takes time), every later call is ≥ any earlier one.
#[inline]
pub fn now_ns() -> u64 {
    ANCHOR.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_ns_is_monotonic() {
        let a = now_ns();
        let b = now_ns();
        let c = now_ns();
        assert!(a <= b && b <= c, "{a} {b} {c}");
    }

    #[test]
    fn now_ns_advances() {
        let a = now_ns();
        // Burn a little real time; even coarse clocks advance over a sleep.
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = now_ns();
        assert!(b > a, "clock did not advance: {a} -> {b}");
        assert!(b - a >= 1_000_000, "slept 2ms but measured {}ns", b - a);
    }
}

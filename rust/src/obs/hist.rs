//! Log-bucketed latency histograms: power-of-two buckets over
//! nanoseconds, mergeable across workers, quantile-readable.
//!
//! Bucket `b` holds values `v` with `floor(log2(v)) == b` (value 0
//! shares bucket 0 with value 1), so 64 buckets cover the whole `u64`
//! range and recording is a handful of integer ops — no allocation, no
//! floating point, safe for per-event use on service paths. Quantiles
//! are read by walking the cumulative counts to the nearest-rank
//! target bucket and reporting that bucket's upper bound (clamped to
//! the observed max), which is exact to within one power-of-two bucket
//! of the true order statistic — the merge/quantile property tests
//! below pin both guarantees down.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two buckets (covers all of `u64`).
pub const BUCKETS: usize = 64;

/// Bucket index of a nanosecond value: `floor(log2(v))`, with 0 → 0.
#[inline]
pub fn bucket_index(ns: u64) -> usize {
    if ns <= 1 {
        0
    } else {
        63 - ns.leading_zeros() as usize
    }
}

/// Inclusive upper bound of bucket `b` (`2^(b+1) - 1`).
#[inline]
pub fn bucket_hi(b: usize) -> u64 {
    if b >= 63 {
        u64::MAX
    } else {
        (1u64 << (b + 1)) - 1
    }
}

/// A log-bucketed histogram of nanosecond values. `Default` is empty.
#[derive(Clone, PartialEq, Eq)]
pub struct Histogram {
    count: u64,
    /// Sum of recorded values (saturating — ~584 years of nanoseconds
    /// before that matters).
    total_ns: u64,
    max_ns: u64,
    buckets: [u64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            count: 0,
            total_ns: 0,
            max_ns: 0,
            buckets: [0; BUCKETS],
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count)
            .field("mean_ns", &self.mean_ns())
            .field("p50_ns", &self.p50())
            .field("p99_ns", &self.p99())
            .field("max_ns", &self.max_ns)
            .finish()
    }
}

impl Histogram {
    /// Record one value.
    #[inline]
    pub fn record(&mut self, ns: u64) {
        self.count += 1;
        self.total_ns = self.total_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
        self.buckets[bucket_index(ns)] += 1;
    }

    /// Record a `Duration` (convenience for callers holding one).
    #[inline]
    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Fold `other` into `self`. Merging is commutative and
    /// associative (bucket counts are plain sums), so per-worker
    /// histograms can fan in, in any order, to the same result.
    pub fn merge(&mut self, other: &Histogram) {
        self.count += other.count;
        self.total_ns = self.total_ns.saturating_add(other.total_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
    }

    /// Recorded values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Largest recorded value.
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Sum of recorded values (saturating).
    pub fn total_ns(&self) -> u64 {
        self.total_ns
    }

    /// Exact arithmetic mean of the recorded values (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            (self.total_ns as u128 / self.count as u128) as u64
        }
    }

    /// Nearest-rank quantile, reported as the target bucket's upper
    /// bound clamped to the observed max: exact to within one
    /// power-of-two bucket of the true order statistic. `q` is clamped
    /// to [0, 1]; an empty histogram reports 0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                return bucket_hi(b).min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// Median.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 90th percentile.
    pub fn p90(&self) -> u64 {
        self.quantile(0.90)
    }

    /// 99th percentile.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// The raw bucket counts (wire encode reads these).
    pub fn bucket_counts(&self) -> &[u64; BUCKETS] {
        &self.buckets
    }

    /// Rebuild from transported parts (wire decode). Bucket arrays
    /// shorter than [`BUCKETS`] are zero-extended, longer ones
    /// truncated — a forward-compatibility hedge, not a normal path.
    pub fn from_parts(count: u64, total_ns: u64, max_ns: u64, buckets: &[u64]) -> Self {
        let mut h = Histogram {
            count,
            total_ns,
            max_ns,
            buckets: [0; BUCKETS],
        };
        for (a, &b) in h.buckets.iter_mut().zip(buckets.iter()) {
            *a = b;
        }
        h
    }
}

/// Lock-free shared histogram for threads that cannot hand their
/// samples to an owner (the net server's listener and IO threads):
/// relaxed atomic bucket increments, snapshot on demand. `max` uses
/// `fetch_max`, so the snapshot's max is exact; `count`/`total` are
/// independently relaxed, so a snapshot taken mid-record can be off by
/// the in-flight sample — fine for metrics, by design.
pub struct AtomicHist {
    count: AtomicU64,
    total_ns: AtomicU64,
    max_ns: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Default for AtomicHist {
    fn default() -> Self {
        Self {
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl AtomicHist {
    /// Record one value (relaxed; callers want throughput, not
    /// cross-thread ordering).
    #[inline]
    pub fn record(&self, ns: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Copy the current contents into a plain [`Histogram`].
    pub fn snapshot(&self) -> Histogram {
        let mut h = Histogram {
            count: self.count.load(Ordering::Relaxed),
            total_ns: self.total_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
            buckets: [0; BUCKETS],
        };
        for (a, b) in h.buckets.iter_mut().zip(self.buckets.iter()) {
            *a = b.load(Ordering::Relaxed);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn bucket_index_is_floor_log2() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(1023), 9);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(u64::MAX), 63);
        for b in 0..BUCKETS {
            assert!(bucket_hi(b) >= 1u64 << b, "bucket {b}");
        }
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::default();
        assert!(h.is_empty());
        assert_eq!(h.mean_ns(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p99(), 0);
        assert_eq!(h.max_ns(), 0);
    }

    #[test]
    fn single_value_quantiles_clamp_to_max() {
        let mut h = Histogram::default();
        h.record(1000);
        // Bucket hi of 1000 is 1023; the clamp brings every quantile
        // back to the observed max.
        assert_eq!(h.p50(), 1000);
        assert_eq!(h.p99(), 1000);
        assert_eq!(h.mean_ns(), 1000);
        assert_eq!(h.count(), 1);
    }

    /// Nearest-rank oracle on a sorted copy.
    fn oracle_quantile(sorted: &[u64], q: f64) -> u64 {
        let n = sorted.len();
        let target = ((q * n as f64).ceil() as usize).clamp(1, n);
        sorted[target - 1]
    }

    /// Satellite: merging K worker histograms is order-independent,
    /// and quantiles land within one power-of-two bucket of a
    /// sorted-oracle nearest-rank quantile.
    #[test]
    fn merge_is_order_independent_and_quantiles_track_oracle() {
        crate::bench::prop::prop_check("hist-merge-quantile", 0x0B5, |rng| {
            let k = 1 + rng.below(8) as usize;
            let mut workers: Vec<Histogram> = (0..k).map(|_| Histogram::default()).collect();
            let mut all: Vec<u64> = Vec::new();
            for w in 0..k {
                let n = rng.below(200);
                for _ in 0..n {
                    // Mix magnitudes: ns-scale through seconds-scale.
                    let v = rng.below(1u64 << (3 + rng.below(28) as u32));
                    workers[w].record(v);
                    all.push(v);
                }
            }
            // Merge forward and in reverse; fold into empty histograms.
            let mut fwd = Histogram::default();
            for w in &workers {
                fwd.merge(w);
            }
            let mut rev = Histogram::default();
            for w in workers.iter().rev() {
                rev.merge(w);
            }
            crate::bench::prop::expect_eq(&fwd.count(), &rev.count(), "count")?;
            crate::bench::prop::expect_eq(&fwd.total_ns(), &rev.total_ns(), "total")?;
            crate::bench::prop::expect_eq(&fwd.max_ns(), &rev.max_ns(), "max")?;
            crate::bench::prop::expect_eq(fwd.bucket_counts(), rev.bucket_counts(), "buckets")?;

            if all.is_empty() {
                return Ok(());
            }
            all.sort_unstable();
            for &q in &[0.0, 0.5, 0.9, 0.99, 1.0] {
                let got = fwd.quantile(q);
                let want = oracle_quantile(&all, q);
                let (gb, wb) = (bucket_index(got), bucket_index(want));
                if gb.abs_diff(wb) > 1 {
                    return Err(format!(
                        "q={q}: got {got} (bucket {gb}) vs oracle {want} (bucket {wb})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn from_parts_round_trips_bucket_counts() {
        let mut h = Histogram::default();
        let mut rng = Rng::new(7);
        for _ in 0..500 {
            h.record(rng.below(1 << 30));
        }
        let back = Histogram::from_parts(
            h.count(),
            h.total_ns(),
            h.max_ns(),
            h.bucket_counts(),
        );
        assert_eq!(back.count(), h.count());
        assert_eq!(back.p50(), h.p50());
        assert_eq!(back.p99(), h.p99());
        assert_eq!(back.bucket_counts(), h.bucket_counts());
        // Short/long arrays do not panic.
        let short = Histogram::from_parts(1, 5, 5, &[1, 0, 0]);
        assert_eq!(short.count(), 1);
    }

    #[test]
    fn atomic_hist_matches_serial_under_threads() {
        let hist = std::sync::Arc::new(AtomicHist::default());
        let mut want = Histogram::default();
        let per_thread: Vec<Vec<u64>> = (0..4)
            .map(|t| {
                let mut rng = Rng::new(0xA7 + t);
                (0..1000).map(|_| rng.below(1 << 20)).collect()
            })
            .collect();
        for vs in &per_thread {
            for &v in vs {
                want.record(v);
            }
        }
        let handles: Vec<_> = per_thread
            .into_iter()
            .map(|vs| {
                let h = std::sync::Arc::clone(&hist);
                std::thread::spawn(move || {
                    for v in vs {
                        h.record(v);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        let got = hist.snapshot();
        assert_eq!(got.count(), want.count());
        assert_eq!(got.total_ns(), want.total_ns());
        assert_eq!(got.max_ns(), want.max_ns());
        assert_eq!(got.bucket_counts(), want.bucket_counts());
    }
}

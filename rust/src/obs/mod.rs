//! Observability: phase-level tracing and log-bucketed histograms for
//! the whole match pipeline.
//!
//! The source paper evaluates PSBM by decomposing wall-clock time into
//! its phases (endpoint build, sort, local scan, merge); this module is
//! that decomposition turned into a first-class subsystem the engine,
//! session, shard, and net layers all report through:
//!
//! * [`clock`] — the one sanctioned monotonic-nanosecond seam. The
//!   `xtask lint` wallclock rule bans `Instant::now` in hot modules;
//!   `obs/` owns the clock, everyone else calls [`clock::now_ns`].
//! * [`Histogram`] — log-bucketed (power-of-two buckets over
//!   nanoseconds) latency distribution: p50/p90/p99/max, mergeable
//!   across workers, wire-serializable. Replaces the mean/max-only
//!   view of [`LatencyStat`](crate::coordinator::metrics::LatencyStat)
//!   wherever tail latency matters.
//! * [`trace`] — span records (phase id, worker id, start, end,
//!   items) written into fixed-size per-worker buffers
//!   ([`SpanSink`]: no growth, ever — enforced by the
//!   `obs-no-hot-alloc` lint rule) and fanned in at epoch boundaries
//!   via the claims machinery ([`Tracer`]/[`TraceFan`]). Disabled
//!   tracing is a branch: no clock read, no write, no allocation.
//! * [`chrome`] — export a span list as Chrome trace-event JSON
//!   (`chrome://tracing` / Perfetto), one lane per worker/shard.
//!
//! ## Quickstart
//!
//! ```
//! use ddm::obs::{clock, Histogram, Phase, SpanSink};
//!
//! let mut hist = Histogram::default();
//! let mut sink = SpanSink::with_capacity(1024);
//! let t0 = sink.start();               // 0 when the sink is disabled
//! // … do a phase of work …
//! sink.record(Phase::Sweep, 0, t0, 42); // end-timestamped at the call
//! hist.record(clock::now_ns().saturating_sub(t0));
//! assert_eq!(sink.records().len(), 1);
//! assert!(hist.p99() >= hist.p50());
//! ```
//!
//! End to end: `DdmEngine::builder().trace(true)` turns on span
//! capture in every session the engine creates, `ddm replay --trace`
//! / `ddm trace --out trace.json` dump a commit timeline, and `ddm
//! client --metrics` renders the wire-delivered histograms.

pub mod chrome;
pub mod clock;
pub mod hist;
pub mod trace;

pub use chrome::{chrome_trace_json, phase_totals, top_slowest};
pub use hist::{AtomicHist, Histogram};
pub use trace::{SpanRecord, SpanSink, TraceFan, Tracer};

/// The span taxonomy: every traced phase of the pipeline. Stable ids
/// (the `u16` in [`SpanRecord`]) so traces and wire payloads survive
/// reordering here — append only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u16)]
pub enum Phase {
    /// SBM/PSBM endpoint build + radix/merge sort passes.
    Sort = 0,
    /// SBM/PSBM sweep over the sorted endpoint list.
    Sweep = 1,
    /// `FilterSink` residual-dimension verification (items = pairs
    /// checked; the span brackets the sweep that drove them).
    Residual = 2,
    /// GBM counting-sort binning into the flat CSR cell lists.
    GbmBin = 3,
    /// GBM per-cell scan (brute force within each grid cell).
    GbmScan = 4,
    /// Session commit: staged-op apply (routing + LWW coalescing).
    StageApply = 5,
    /// Session commit phase A: parallel per-dimension tree writes.
    TreeWrite = 6,
    /// Session commit phase B: recompute of affected regions.
    Recompute = 7,
    /// Session commit phase C: diff vs the retained pair set.
    DiffMerge = 8,
    /// One shard's whole commit inside a `ShardedSession` fan-out
    /// (worker id = shard id; the per-lane view of commit imbalance).
    ShardCommit = 9,
    /// Net server: frame decode batches in the IO threads.
    NetDecode = 10,
    /// Net server: state-thread message handling.
    NetState = 11,
    /// Net server: reply-frame encode in the state thread.
    NetEncode = 12,
    /// Net server: listener accept → IO-thread handoff.
    NetAccept = 13,
    /// A whole commit (session or wire), end to end.
    Commit = 14,
    /// Session publish: rebuild + RCU swap of the epoch's immutable
    /// read snapshot (items = pairs in the new snapshot).
    SnapshotSwap = 15,
    /// Zero-length marker after a snapshot swap; items = reader
    /// handles still pinning the previous epoch's payload.
    ReaderPin = 16,
    /// Dwell of a drained ingest batch in the bounded MPSC backlog
    /// (oldest enqueue → drain; items = ops drained).
    BacklogWait = 17,
    /// Write-ahead log append: buffered op records / the commit marker
    /// hitting the log file (items = records written).
    WalAppend = 18,
    /// Write-ahead log `fsync` after a commit marker
    /// ([`DurabilityCfg::fsync_commits`](crate::durable::DurabilityCfg)).
    WalFsync = 19,
    /// Recovery replay envelope: snapshot decode + committed log-tail
    /// re-apply (items = regions + ops replayed).
    RecoverScan = 20,
}

impl Phase {
    /// Every phase, in id order (the taxonomy table in
    /// ARCHITECTURE.md mirrors this).
    pub const ALL: [Phase; 21] = [
        Phase::Sort,
        Phase::Sweep,
        Phase::Residual,
        Phase::GbmBin,
        Phase::GbmScan,
        Phase::StageApply,
        Phase::TreeWrite,
        Phase::Recompute,
        Phase::DiffMerge,
        Phase::ShardCommit,
        Phase::NetDecode,
        Phase::NetState,
        Phase::NetEncode,
        Phase::NetAccept,
        Phase::Commit,
        Phase::SnapshotSwap,
        Phase::ReaderPin,
        Phase::BacklogWait,
        Phase::WalAppend,
        Phase::WalFsync,
        Phase::RecoverScan,
    ];

    /// Stable wire/trace id.
    #[inline]
    pub fn id(self) -> u16 {
        self as u16
    }

    /// Inverse of [`id`](Self::id); `None` for ids from a newer build.
    pub fn from_id(id: u16) -> Option<Phase> {
        Phase::ALL.get(id as usize).copied()
    }

    /// Short name (trace lanes, metric rows).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Sort => "sort",
            Phase::Sweep => "sweep",
            Phase::Residual => "residual",
            Phase::GbmBin => "gbm_bin",
            Phase::GbmScan => "gbm_scan",
            Phase::StageApply => "stage_apply",
            Phase::TreeWrite => "tree_write",
            Phase::Recompute => "recompute",
            Phase::DiffMerge => "diff_merge",
            Phase::ShardCommit => "shard_commit",
            Phase::NetDecode => "net_decode",
            Phase::NetState => "net_state",
            Phase::NetEncode => "net_encode",
            Phase::NetAccept => "net_accept",
            Phase::Commit => "commit",
            Phase::SnapshotSwap => "snapshot_swap",
            Phase::ReaderPin => "reader_pin",
            Phase::BacklogWait => "backlog_wait",
            Phase::WalAppend => "wal_append",
            Phase::WalFsync => "wal_fsync",
            Phase::RecoverScan => "recover_scan",
        }
    }

    /// Name for a raw id, tolerating ids this build does not know.
    pub fn name_of(id: u16) -> &'static str {
        Phase::from_id(id).map_or("unknown", Phase::name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_ids_round_trip_and_are_dense() {
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(p.id() as usize, i, "{p:?} id not dense");
            assert_eq!(Phase::from_id(p.id()), Some(*p));
            assert!(!p.name().is_empty());
        }
        assert_eq!(Phase::from_id(Phase::ALL.len() as u16), None);
        assert_eq!(Phase::name_of(999), "unknown");
    }
}

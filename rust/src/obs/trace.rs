//! Span capture: fixed-size per-worker buffers fanned in at epoch
//! boundaries through the claims machinery.
//!
//! The shape mirrors the pool's own fan-out/fan-in: a [`Tracer`]
//! (owned by whoever owns the epoch — an engine, a session, the net
//! state thread) hands each worker a private [`SpanSink`] through a
//! [`TraceFan`]; workers stamp [`SpanRecord`]s into their sink with no
//! locks and no allocation (the buffer is a `Box<[SpanRecord]>` filled
//! by cursor — the `obs-no-hot-alloc` lint rule bans growth calls in
//! the record path); after the join barrier the tracer absorbs every
//! sink back, appends the spans to its master timeline, and recycles
//! the buffers for the next epoch. A disabled tracer hands out
//! zero-capacity sinks whose `start` is `0` and whose `record` is a
//! single branch — no clock read, no write, no allocation.

use crate::exec::claims::{FanSlots, TakeCells};

use super::clock;
use super::Phase;

/// Worker id used for spans recorded on the master thread (the
/// serial parts of a commit, whole-commit envelopes, net stages).
pub const MASTER_WORKER: u16 = u16::MAX;

/// One traced span: a phase of work on one worker's timeline.
/// `phase` is a [`Phase`] id kept raw so records survive taxonomy
/// growth; times come from [`clock::now_ns`] and are comparable
/// across every record in a process.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SpanRecord {
    /// [`Phase`] id ([`Phase::name_of`] renders it).
    pub phase: u16,
    /// Worker/shard lane, or [`MASTER_WORKER`].
    pub worker: u16,
    /// Span start, nanoseconds ([`clock::now_ns`] domain).
    pub t0_ns: u64,
    /// Span end, same domain; `>= t0_ns` for clock-stamped records.
    pub t1_ns: u64,
    /// Work-proportional item count (endpoints sorted, pairs checked,
    /// frames decoded — phase-specific, see the taxonomy docs).
    pub items: u64,
}

impl SpanRecord {
    /// All-zero record (buffer fill value).
    pub const ZERO: SpanRecord = SpanRecord {
        phase: 0,
        worker: 0,
        t0_ns: 0,
        t1_ns: 0,
        items: 0,
    };

    /// Span duration in nanoseconds (0 for malformed records).
    #[inline]
    pub fn dur_ns(&self) -> u64 {
        self.t1_ns.saturating_sub(self.t0_ns)
    }
}

/// A worker-private span buffer: fixed capacity decided at
/// construction, overflow drops (and counts) rather than grows, so
/// recording is branch + store. Capacity 0 is the disabled sink:
/// [`start`](Self::start) skips the clock read and
/// [`record`](Self::record) is one branch.
#[derive(Debug)]
pub struct SpanSink {
    buf: Box<[SpanRecord]>,
    len: usize,
    dropped: u64,
}

impl Default for SpanSink {
    /// The disabled sink — so structs embedding one (e.g.
    /// [`MatchScratch`](crate::core::scratch::MatchScratch)) can keep
    /// deriving `Default` with tracing off.
    fn default() -> SpanSink {
        SpanSink::disabled()
    }
}

impl SpanSink {
    /// A sink holding up to `cap` spans between drains.
    pub fn with_capacity(cap: usize) -> SpanSink {
        SpanSink {
            buf: vec![SpanRecord::ZERO; cap].into_boxed_slice(),
            len: 0,
            dropped: 0,
        }
    }

    /// The no-op sink (capacity 0 — an empty `Box<[T]>` does not
    /// allocate, so disabled tracing costs nothing to construct).
    pub fn disabled() -> SpanSink {
        SpanSink::with_capacity(0)
    }

    /// Whether this sink captures anything at all.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Maximum spans held between drains.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Read the clock for a span about to begin — or skip the clock
    /// entirely and return 0 when disabled.
    #[inline]
    pub fn start(&self) -> u64 {
        if self.is_enabled() {
            clock::now_ns()
        } else {
            0
        }
    }

    /// Close a span begun at [`start`](Self::start): end-timestamps it
    /// now and appends it. Disabled sinks return after one branch.
    #[inline]
    pub fn record(&mut self, phase: Phase, worker: u16, t0_ns: u64, items: u64) {
        if self.buf.is_empty() {
            return;
        }
        let t1_ns = clock::now_ns();
        self.record_raw(SpanRecord {
            phase: phase.id(),
            worker,
            t0_ns,
            t1_ns,
            items,
        });
    }

    /// Append a pre-built record (tests and callers that timed the
    /// work themselves). Full or disabled sinks count a drop instead.
    #[inline]
    pub fn record_raw(&mut self, rec: SpanRecord) {
        if self.len < self.buf.len() {
            self.buf[self.len] = rec;
            self.len += 1;
        } else {
            self.dropped += 1;
        }
    }

    /// The spans recorded since the last drain, in record order.
    pub fn records(&self) -> &[SpanRecord] {
        &self.buf[..self.len]
    }

    /// Spans lost to a full buffer since the last drain.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Move every record into `out`, reset the cursor, and return the
    /// drop count (also reset). The buffer keeps its capacity.
    pub fn drain_into(&mut self, out: &mut Vec<SpanRecord>) -> u64 {
        out.extend_from_slice(&self.buf[..self.len]);
        self.len = 0;
        std::mem::take(&mut self.dropped)
    }

    /// Discard buffered records and the drop count.
    pub fn clear(&mut self) {
        self.len = 0;
        self.dropped = 0;
    }
}

/// One epoch's fan-out of sinks to workers. Worker `p` borrows its
/// private sink with [`with`](Self::with); the claims machinery
/// ([`TakeCells`] out, [`FanSlots`] back) makes "each lane touched by
/// exactly one worker" a checked invariant under `race-check` instead
/// of a comment. The barrier between the workers and
/// [`Tracer::absorb`] is the caller's fork-join region, exactly as for
/// every other fan in the crate.
pub struct TraceFan {
    cells: TakeCells<SpanSink>,
    slots: FanSlots<SpanSink>,
}

impl TraceFan {
    fn new(sinks: Vec<SpanSink>) -> TraceFan {
        let n = sinks.len();
        TraceFan {
            cells: TakeCells::new(sinks, "obs::trace::fan"),
            slots: FanSlots::new(n, "obs::trace::fan"),
        }
    }

    /// Number of worker lanes (0 for a disabled tracer's fan).
    pub fn lanes(&self) -> usize {
        self.cells.len()
    }

    /// Run `f` with worker `p`'s private sink. Each lane must be used
    /// at most once per fan (a second use panics — deterministically,
    /// with a site diagnostic under `race-check`). On a disabled
    /// tracer's fan (no lanes) `f` gets a throwaway no-op sink, so
    /// call sites need no enabled/disabled branches.
    pub fn with<R>(&self, p: usize, f: impl FnOnce(&mut SpanSink) -> R) -> R {
        if self.cells.is_empty() {
            let mut off = SpanSink::disabled();
            return f(&mut off);
        }
        // SAFETY: lane p is taken at most once per fan — a repeat take
        // panics in the Option backstop (and in the claim word under
        // race-check) before any aliased access can happen.
        let mut sink = unsafe { self.cells.take(p) };
        let r = f(&mut sink);
        // SAFETY: slot p is put exactly once, by the same caller that
        // took cell p; the caller's fork-join barrier orders this put
        // before the absorb that reads it.
        unsafe { self.slots.put(p, sink) };
        r
    }

    /// Recover every sink — used lanes (from the return slots) and
    /// never-used lanes (still in the cells) — after the join barrier.
    fn into_sinks(self) -> impl Iterator<Item = SpanSink> {
        self.slots
            .into_values()
            .flatten()
            .chain(self.cells.into_remaining())
    }
}

/// The epoch-level span collector: owns the master timeline, hands
/// out per-worker sinks ([`fan`](Self::fan)), absorbs them back at
/// the epoch boundary, and recycles their buffers so steady-state
/// tracing allocates nothing per epoch.
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    cap_per_worker: usize,
    records: Vec<SpanRecord>,
    dropped: u64,
    pool: Vec<SpanSink>,
}

/// Default per-worker sink capacity (spans per epoch per worker).
pub const DEFAULT_SINK_CAP: usize = 4096;

impl Tracer {
    /// The no-op tracer: every sink it hands out is disabled, every
    /// span call is a branch.
    pub fn disabled() -> Tracer {
        Tracer {
            enabled: false,
            cap_per_worker: 0,
            records: Vec::new(),
            dropped: 0,
            pool: Vec::new(),
        }
    }

    /// A live tracer whose per-worker sinks hold `cap_per_worker`
    /// spans between epoch drains.
    pub fn enabled(cap_per_worker: usize) -> Tracer {
        Tracer {
            enabled: true,
            cap_per_worker: cap_per_worker.max(1),
            records: Vec::new(),
            dropped: 0,
            pool: Vec::new(),
        }
    }

    /// Construct from a boolean knob ([`DEFAULT_SINK_CAP`] when on).
    pub fn new(on: bool) -> Tracer {
        if on {
            Tracer::enabled(DEFAULT_SINK_CAP)
        } else {
            Tracer::disabled()
        }
    }

    /// Whether spans are being captured.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Clock read for a master-side span (0 when disabled, like
    /// [`SpanSink::start`]).
    #[inline]
    pub fn start(&self) -> u64 {
        if self.enabled {
            clock::now_ns()
        } else {
            0
        }
    }

    /// Close a master-lane span begun at [`start`](Self::start).
    pub fn span(&mut self, phase: Phase, t0_ns: u64, items: u64) {
        if !self.enabled {
            return;
        }
        let t1_ns = clock::now_ns();
        self.records.push(SpanRecord {
            phase: phase.id(),
            worker: MASTER_WORKER,
            t0_ns,
            t1_ns,
            items,
        });
    }

    /// Append a fully specified span (callers that timed the work
    /// themselves and know the lane — shard commits, net stages).
    pub fn span_at(&mut self, phase: Phase, worker: u16, t0_ns: u64, t1_ns: u64, items: u64) {
        if !self.enabled {
            return;
        }
        self.records.push(SpanRecord {
            phase: phase.id(),
            worker,
            t0_ns,
            t1_ns,
            items,
        });
    }

    /// A single worker sink: recycled from the pool when one is
    /// available, freshly allocated otherwise, disabled when the
    /// tracer is. Return it via [`absorb_sink`](Self::absorb_sink).
    pub fn make_sink(&mut self) -> SpanSink {
        if !self.enabled {
            return SpanSink::disabled();
        }
        match self.pool.pop() {
            Some(s) => s,
            None => SpanSink::with_capacity(self.cap_per_worker),
        }
    }

    /// Drain `sink` into the master timeline and recycle its buffer.
    pub fn absorb_sink(&mut self, mut sink: SpanSink) {
        self.dropped += sink.drain_into(&mut self.records);
        if sink.capacity() == self.cap_per_worker && self.enabled {
            self.pool.push(sink);
        }
    }

    /// Drain a caller-retained sink (one embedded in long-lived
    /// scratch) without taking ownership of its buffer.
    pub fn absorb_from(&mut self, sink: &mut SpanSink) {
        self.dropped += sink.drain_into(&mut self.records);
    }

    /// Fan out `n` worker lanes for one parallel region. Disabled
    /// tracers fan zero lanes (and [`TraceFan::with`] no-ops), so the
    /// disabled path allocates nothing.
    pub fn fan(&mut self, n: usize) -> TraceFan {
        if !self.enabled {
            return TraceFan::new(Vec::new());
        }
        let sinks: Vec<SpanSink> = (0..n).map(|_| self.make_sink()).collect();
        TraceFan::new(sinks)
    }

    /// Absorb every lane of a fan after its join barrier: spans are
    /// appended to the master timeline, buffers recycled.
    pub fn absorb(&mut self, fan: TraceFan) {
        for sink in fan.into_sinks() {
            self.absorb_sink(sink);
        }
    }

    /// The master timeline so far (fan-in order: master spans in call
    /// order, worker spans grouped per absorb).
    pub fn records(&self) -> &[SpanRecord] {
        &self.records
    }

    /// Total spans lost to full sinks.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Take the timeline (e.g. to export), leaving the tracer running.
    pub fn drain(&mut self) -> Vec<SpanRecord> {
        std::mem::take(&mut self.records)
    }

    /// Discard the timeline and drop count.
    pub fn clear(&mut self) {
        self.records.clear();
        self.dropped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::pool::scoped_region;

    #[test]
    fn disabled_sink_is_inert() {
        let mut s = SpanSink::disabled();
        assert!(!s.is_enabled());
        assert_eq!(s.start(), 0);
        s.record(Phase::Sort, 3, 0, 10);
        assert!(s.records().is_empty());
        assert_eq!(s.dropped(), 0);
    }

    #[test]
    fn sink_records_and_drops_at_capacity() {
        let mut s = SpanSink::with_capacity(2);
        let t0 = s.start();
        s.record(Phase::Sweep, 1, t0, 5);
        s.record(Phase::Sort, 1, t0, 6);
        s.record(Phase::Residual, 1, t0, 7); // full → dropped
        assert_eq!(s.records().len(), 2);
        assert_eq!(s.dropped(), 1);
        assert_eq!(s.records()[0].phase, Phase::Sweep.id());
        assert!(s.records()[0].t1_ns >= s.records()[0].t0_ns);

        let mut out = Vec::new();
        assert_eq!(s.drain_into(&mut out), 1);
        assert_eq!(out.len(), 2);
        assert!(s.records().is_empty());
        assert_eq!(s.dropped(), 0);
        assert_eq!(s.capacity(), 2, "drain keeps the buffer");
    }

    #[test]
    fn tracer_master_spans_use_the_master_lane() {
        let mut t = Tracer::enabled(8);
        let t0 = t.start();
        t.span(Phase::Commit, t0, 100);
        t.span_at(Phase::ShardCommit, 3, 10, 20, 7);
        assert_eq!(t.records().len(), 2);
        assert_eq!(t.records()[0].worker, MASTER_WORKER);
        assert_eq!(t.records()[1].worker, 3);
        assert_eq!(t.records()[1].dur_ns(), 10);

        let mut off = Tracer::disabled();
        assert_eq!(off.start(), 0);
        off.span(Phase::Commit, 0, 1);
        off.span_at(Phase::Commit, 0, 0, 9, 1);
        assert!(off.records().is_empty());
    }

    #[test]
    fn fan_absorb_collects_used_and_unused_lanes() {
        let mut t = Tracer::enabled(16);
        let fan = t.fan(4);
        assert_eq!(fan.lanes(), 4);
        // Only lanes 0 and 2 do any work this epoch.
        fan.with(0, |s| s.record_raw(SpanRecord { phase: 0, worker: 0, t0_ns: 1, t1_ns: 2, items: 1 }));
        fan.with(2, |s| s.record_raw(SpanRecord { phase: 1, worker: 2, t0_ns: 3, t1_ns: 9, items: 2 }));
        t.absorb(fan);
        assert_eq!(t.records().len(), 2);
        assert_eq!(t.pool.len(), 4, "all four buffers recycled");

        // The next epoch reuses the pooled buffers — no new allocation.
        let fan2 = t.fan(4);
        assert_eq!(t.pool.len(), 0);
        t.absorb(fan2);
        assert_eq!(t.pool.len(), 4);
    }

    #[test]
    fn disabled_tracer_fan_is_a_no_op_everywhere() {
        let mut t = Tracer::disabled();
        let fan = t.fan(8);
        assert_eq!(fan.lanes(), 0);
        let r = fan.with(5, |s| {
            assert!(!s.is_enabled());
            s.record(Phase::Sort, 5, s.start(), 1);
            42
        });
        assert_eq!(r, 42);
        t.absorb(fan);
        assert!(t.records().is_empty());
    }

    /// Canonical order for comparing timelines across worker counts.
    fn canon(mut v: Vec<SpanRecord>) -> Vec<SpanRecord> {
        v.sort_by_key(|r| (r.worker, r.t0_ns, r.phase, r.items));
        v
    }

    /// Satellite: span fan-in is bit-stable across P ∈ {1, 2, 4, 8} —
    /// the same deterministic per-lane records come back identical no
    /// matter how many workers wrote them (and under `race-check` the
    /// claims machinery verifies each lane was touched exactly once).
    #[test]
    fn fan_in_is_bit_stable_across_worker_counts() {
        const LANES: usize = 8;
        let run = |nthreads: usize| -> Vec<SpanRecord> {
            let mut t = Tracer::enabled(64);
            let fan = t.fan(LANES);
            {
                let fan = &fan;
                scoped_region(nthreads, |p| {
                    // Static lane assignment: worker p handles lanes
                    // p, p+nthreads, … so every P covers all lanes.
                    for lane in (p..LANES).step_by(nthreads) {
                        fan.with(lane, |s| {
                            for k in 0..10u64 {
                                s.record_raw(SpanRecord {
                                    phase: (k % 3) as u16,
                                    worker: lane as u16,
                                    t0_ns: 100 * lane as u64 + k,
                                    t1_ns: 100 * lane as u64 + k + 5,
                                    items: k * k,
                                });
                            }
                        });
                    }
                });
            }
            t.absorb(fan);
            canon(t.drain())
        };
        let want = run(1);
        assert_eq!(want.len(), LANES * 10);
        for p in [2usize, 4, 8] {
            assert_eq!(run(p), want, "P={p} fan-in differs from serial");
        }
    }
}
